"""Calibrate A_gate / D_gate / E_gate against the paper's TSMC28 anchors
and validate every *other* published claim with the frozen constants.

Anchors (fit):
  A_gate : Fig. 6a  — INT8 8K-weight macro layout area 0.079 mm^2
  D_gate : Fig. 7c  — 64K design-space average delay: INT2 1.2 ns,
           FP32 10.9 ns (log-space two-point fit)
  E_gate : Fig. 8a  — design A (INT8, 64K): 22 TOPS/W @ 0.9 V, 10%
           activity (TOPS/W is D_gate-free, so this isolates E_gate)

Held-out validations (reported, NOT fitted):
  Fig. 6b BF16 8K area 0.085 mm^2 (+ pre-align block 0.006 mm^2)
  Fig. 7a/b 64K average area 0.2 -> 60 mm^2, energy 0.3 -> 103 nJ
  Fig. 8  design A 1.9 TOPS/mm^2; design B (BF16 64K) 20.2 TOPS/W,
          1.8 TOPS/mm^2
"""
from __future__ import annotations

import json
import math
import pathlib

import numpy as np

from repro.core import explorer, nsga2
from repro.core.cells import TechParams
from repro.core.macros import physical, macro_costs
from repro.core.precision import PAPER_SWEEP, get

CFG = nsga2.NSGA2Config(pop_size=128, generations=64)
ACTIVITY = 0.1   # paper's Fig. 8 operating point ("10% sparsity")


def front(prec: str, w: int):
    return explorer.explore(prec, w, CFG, method="brute")


def calibrate() -> dict:
    # --- A_gate from INT8 8K min-area layout ------------------------------
    f_int8_8k = front("int8", 8192)
    a_norm = min(p.area for p in f_int8_8k)
    A_gate = 0.079 * 1e6 / a_norm                       # um^2 / gate

    # --- D_gate from Fig. 7c delay endpoints (geometric two-point fit) ----
    d_int2 = np.mean([p.delay for p in front("int2", 65536)])
    d_fp32 = np.mean([p.delay for p in front("fp32", 65536)])
    D_gate = math.exp(
        0.5 * (math.log(1.2e3 / d_int2) + math.log(10.9e3 / d_fp32))
    )                                                    # ps / gate-delay

    # --- E_gate from design A (22 TOPS/W); pick the front point that also
    # best matches 1.9 TOPS/mm^2 under the fitted A_gate -------------------
    cands = []
    for p in front("int8", 65536):
        # TOPS/W = (T/D_gate) / (E*E_gate*act/(D*D_gate)) = T*D/(E*E_gate*act)
        e_gate = p.throughput * p.delay / (p.energy * ACTIVITY * 22.0) * 1e3
        # fJ units: T [ops/gate-delay], D [gate], E [gate] ->
        # TOPS/W = T*D/(E * E_gate_fJ * act) * 1e3  (1e-12/1e-15 bookkeeping)
        area_mm2 = p.area * A_gate * 1e-6
        tops_mm2 = (p.throughput / (D_gate * 1e-12) * 1e-12) / area_mm2
        cands.append((abs(tops_mm2 - 1.9), e_gate, p, tops_mm2))
    cands.sort(key=lambda c: c[0])
    _, E_gate, design_a, a_topsmm2 = cands[0]

    tech = TechParams(A_gate_um2=A_gate, D_gate_ps=D_gate, E_gate_fJ=E_gate)
    return {"tech": tech, "design_a": design_a, "design_a_topsmm2": a_topsmm2}


def validate(tech: TechParams) -> dict:
    out = {}
    # Fig 6b: BF16 8K min-area + its pre-align block
    fb = front("bf16", 8192)
    pmin = min(fb, key=lambda p: p.area)
    costs = macro_costs(
        float(pmin.N), float(pmin.H), float(pmin.L), float(pmin.k), get("bf16")
    )
    out["bf16_8k_area_mm2"] = (tech.area_mm2(float(np.asarray(costs.area))),
                               0.085)
    out["bf16_8k_prealign_mm2"] = (
        tech.area_mm2(float(np.asarray(costs.area_align))), 0.006)

    # Fig 7 endpoints at 64K (averages over the Pareto front)
    for prec, area_t, energy_t, delay_t in (
        ("int2", 0.2, 0.3, 1.2), ("fp32", 60.0, 103.0, 10.9)
    ):
        pts = front(prec, 65536)
        ph_area = np.mean([p.area_mm2 / 0.55 * tech.A_gate_um2 for p in pts])
        # recompute with this tech
        areas = [p.area * tech.A_gate_um2 * 1e-6 for p in pts]
        energies = [p.energy * tech.E_gate_fJ * 1e-6 for p in pts]
        delays = [p.delay * tech.D_gate_ps * 1e-3 for p in pts]
        out[f"{prec}_64k_avg_area_mm2"] = (float(np.mean(areas)), area_t)
        out[f"{prec}_64k_avg_energy_nJ"] = (float(np.mean(energies)), energy_t)
        out[f"{prec}_64k_avg_delay_ns"] = (float(np.mean(delays)), delay_t)

    # Fig 8 design B: best BF16-64K TOPS/W point
    fbb = front("bf16", 65536)
    best = None
    for p in fbb:
        tw = p.throughput * p.delay / (p.energy * tech.E_gate_fJ * ACTIVITY) * 1e3
        tm = (p.throughput / (tech.D_gate_ps * 1e-12) * 1e-12) / (
            p.area * tech.A_gate_um2 * 1e-6)
        if best is None or abs(tw - 20.2) < abs(best[0] - 20.2):
            best = (tw, tm, p)
    out["design_b_tops_w"] = (best[0], 20.2)
    out["design_b_tops_mm2"] = (best[1], 1.8)
    return out


def main():
    cal = calibrate()
    tech = cal["tech"]
    print(f"# calibrated: A_gate={tech.A_gate_um2:.4f} um^2 "
          f"D_gate={tech.D_gate_ps:.2f} ps E_gate={tech.E_gate_fJ:.4f} fJ")
    val = validate(tech)
    rows = []
    for k, (got, want) in val.items():
        rel = abs(got - want) / abs(want)
        rows.append((k, got, want, rel))
        print(f"calibration.{k},{got:.4g},target={want} rel_err={rel:.2%}")
    res = {
        "A_gate_um2": tech.A_gate_um2,
        "D_gate_ps": tech.D_gate_ps,
        "E_gate_fJ": tech.E_gate_fJ,
        "design_a": cal["design_a"].summary(),
        "validations": {k: {"got": g, "target": w, "rel": r}
                        for k, g, w, r in rows},
    }
    pathlib.Path("results").mkdir(exist_ok=True)
    pathlib.Path("results/calibration.json").write_text(json.dumps(res, indent=2))
    return res


if __name__ == "__main__":
    main()
