"""Continuous-batching vs bucketed-batch serving benchmark, plus the
shared-prefix paging trace.

Serves ONE mixed-length greedy arrival trace (mixed prompt lengths AND
mixed n_tokens) through both paths:

  * ``bucketed`` — the historical ``Engine`` + ``bucket_requests`` loop:
    requests group into equal-prompt-length batches and every batch is
    held until its LONGEST generation finishes (and pays one prefill
    compile per distinct prompt length),
  * ``continuous`` — ``serve.Scheduler``: a fixed pool of decode slots
    over the paged KV cache, one jitted decode program, bucketed burst
    prefill; slots retire and recycle per request, so throughput is
    bounded by slot count instead of the slowest bucket member.

A third child, ``prefix``, serves a SHARED-PREFIX trace (many requests
over one long system prompt — the serve-trace shape DCIM evaluation
harnesses produce) twice: through the paged scheduler with prefix reuse
+ burst prefill, and through the PR-3 monolithic scheduler
(``paged=False``) that must prefill every prompt in full.  Prefix reuse
turns the repeated prefix prefill into page refcounting, so useful
tokens/s rises with the shared fraction; tokens must stay identical.

A fourth child, ``session``, measures the PERSISTENT-SESSION win: the
same shared-prefix trace served twice through ONE scheduler, whose
``ServeSession`` keeps the device pool and prefix cache alive between
``serve()`` calls.  Trace 2 must record cross-trace prefix hits (its
FIRST request — the cold miss of a per-trace pool — now hits the pages
trace 1 filled), compile nothing new, and serve identical tokens.

A fifth child, ``multitenant``, fires a BURSTY OVERLOAD trace at one
background-pumped session from concurrent producer threads — three
priority classes (batch/web/interactive), a bounded queue that sheds
part of the burst, chunked prefill for the long batch prompts, and
preemption armed.  It reports p50/p99 submit-to-done latency per
priority class and the shed count, and FAILS (nonzero exit) if any
admitted request loses tokens versus an uncontended reference serve of
the same trace, or if the second burst compiles new programs.

A sixth child, ``sharded``, runs on a FORCED-8-DEVICE host platform
(``XLA_FLAGS=--xla_force_host_platform_device_count=8`` set by the
parent before the child's jax import) and serves the standard trace at
tp=1 and tp=8 through ``Scheduler(tp=...)``.  Tokens must be
bitwise-identical across widths and the compiled-program count must not
grow — the child exits nonzero otherwise.  It reports tokens/s and
tokens/s-per-device; on a host CPU where all forced devices share the
same cores, per-device is the honest throughput figure.

Reports useful tokens/s (only the tokens each request asked for count)
and p50/p99 request completion latency, cold (first trace, compiles
included) and warm (second trace).  Paths must produce IDENTICAL greedy
tokens per request — the token-exactness guard that keeps the
comparison honest (scheduling and caching are never numerics changes).

Each path runs in its OWN subprocess so all are measured cold; the
record lands in ``BENCH_serve.json`` at the repo root via
``core.results.ResultStore`` (CI regenerates it with ``--smoke``).

Usage:
  PYTHONPATH=src python -m benchmarks.bench_serve            # full
  PYTHONPATH=src python -m benchmarks.bench_serve --smoke    # CI-sized
"""
from __future__ import annotations

import argparse
import hashlib
import json
import pathlib
import platform
import threading
import time

import numpy as np

from .common import run_child

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

ARCH = "qwen2.5-3b"


def _trace(smoke: bool):
    """Deterministic mixed-length trace: (prompts, n_tokens per request)."""
    n_req = 16 if smoke else 32
    rng = np.random.default_rng(0)
    from repro import configs

    cfg = configs.get_smoke_config(ARCH)
    plens = rng.choice([3, 5, 8, 11, 13, 16, 20], size=n_req)
    ntoks = rng.choice([4, 8, 12, 20, 28] if smoke else [8, 16, 32, 48, 64],
                       size=n_req)
    prompts = [rng.integers(0, cfg.vocab_size, p).astype(np.int32)
               for p in plens]
    return cfg, prompts, [int(n) for n in ntoks]


def _prefix_trace(smoke: bool):
    """Shared-prefix trace: every request = one long common system
    prefix + a short unique tail.  Uses a lossless cache dtype so prefix
    reuse is active (the reuse gate requires token-exactness)."""
    import dataclasses

    from repro import configs

    n_req = 16 if smoke else 32
    prefix_len = 80 if smoke else 160
    rng = np.random.default_rng(7)
    cfg = dataclasses.replace(
        configs.get_smoke_config(ARCH), cache_dtype="float32"
    )
    prefix = rng.integers(0, cfg.vocab_size, prefix_len).astype(np.int32)
    tails = rng.choice([2, 3, 5, 8], size=n_req)
    prompts = [
        np.concatenate(
            [prefix, rng.integers(0, cfg.vocab_size, t).astype(np.int32)]
        )
        for t in tails
    ]
    ntoks = [int(n) for n in rng.choice([2, 3, 4], size=n_req)]
    max_len = 128 if smoke else 256
    return cfg, prompts, ntoks, max_len, prefix_len


def _percentiles(lat):
    lat = np.asarray(sorted(lat))
    return {
        "p50_s": round(float(np.percentile(lat, 50)), 4),
        "p99_s": round(float(np.percentile(lat, 99)), 4),
    }


def _digest(tokens_by_rid):
    body = json.dumps([[int(t) for t in tokens_by_rid[r]]
                       for r in sorted(tokens_by_rid)])
    return hashlib.sha1(body.encode()).hexdigest()


def _serve_continuous(cfg, params, prompts, ntoks, max_len, max_slots):
    from repro.serve import Request, Scheduler

    sched = Scheduler(cfg, params, max_slots=max_slots, max_len=max_len)
    reqs = [Request(prompt=p, n_tokens=n) for p, n in zip(prompts, ntoks)]

    def run():
        t0 = time.perf_counter()
        results = sched.serve(reqs)
        wall = time.perf_counter() - t0
        toks = {r.rid: r.generated for r in results}
        lat = [r.finished_wall_s for r in results]
        return wall, toks, lat

    cold = run()
    warm = run()
    extra = {
        "decode_steps": sched.last_stats.decode_steps,
        "prefills": sched.last_stats.prefills,
        "occupancy": round(sched.last_stats.occupancy, 3),
        "compiled_programs": sched.compile_counts()["total"],
    }
    return cold, warm, extra


def _serve_prefix(cfg, params, prompts, ntoks, max_len):
    """Shared-prefix trace through prefix-reuse paging vs the PR-3
    monolithic scheduler; both continuous, same slots, same trace."""
    from repro.serve import Request, Scheduler

    reqs = [Request(prompt=p, n_tokens=n) for p, n in zip(prompts, ntoks)]
    out = {}
    for tag, opts in (
        ("reuse", dict(paged=True, prefix_reuse=True, burst_prefill=True,
                       page_size=8)),
        ("monolithic", dict(paged=False)),
    ):
        sched = Scheduler(cfg, params, max_slots=4, max_len=max_len, **opts)

        def run():
            t0 = time.perf_counter()
            results = sched.serve(reqs)
            wall = time.perf_counter() - t0
            toks = {r.rid: r.generated for r in results}
            lat = [r.finished_wall_s for r in results]
            return wall, toks, lat

        cold, warm = run(), run()
        stats = sched.last_stats
        out[tag] = {
            "cold": cold, "warm": warm,
            "extra": {
                "prefills": stats.prefills,
                "prefill_batches": stats.prefill_batches,
                "prefix_reuse_active": stats.prefix_reuse_active,
                "prefix_hit_tokens": (
                    stats.paging["prefix_hit_tokens"] if stats.paging else 0
                ),
                "compiled_programs": sched.compile_counts()["total"],
            },
        }
    return out


def _serve_session(cfg, params, prompts, ntoks, max_len):
    """The warm-session trace: the SAME shared-prefix trace through one
    persistent session, twice.  Trace 1 fills the prefix pages (compiles
    included); trace 2 hits them cross-trace — no pool rebuild, no new
    compiles, identical tokens."""
    from repro.serve import Request, Scheduler

    sched = Scheduler(cfg, params, max_slots=4, max_len=max_len, page_size=8)
    reqs = [Request(prompt=p, n_tokens=n) for p, n in zip(prompts, ntoks)]

    def run():
        t0 = time.perf_counter()
        results = sched.serve(reqs)
        wall = time.perf_counter() - t0
        toks = {r.rid: r.generated for r in results}
        stats = sched.last_stats
        return {
            "wall": wall, "toks": toks,
            "lat": [r.finished_wall_s for r in results],
            "prefix_hit_tokens": stats.paging["prefix_hit_tokens"],
            "cross_trace_hit_tokens": stats.paging["cross_trace_hit_tokens"],
            "prefix_misses": stats.paging["prefix_misses"],
            "compiled_programs": sched.compile_counts()["total"],
        }

    return run(), run()


def _multitenant_trace(smoke: bool):
    """Bursty three-class trace: priority-1 batch jobs with long
    (chunk-length) prompts and generations, priority-2 web traffic,
    priority-3 interactive requests with short prompts and tight
    latency expectations.  Lossless cache dtype so chunked prefill and
    preemption are active."""
    import dataclasses

    from repro import configs

    per_class = 6 if smoke else 12
    rng = np.random.default_rng(11)
    cfg = dataclasses.replace(
        configs.get_smoke_config(ARCH), cache_dtype="float32"
    )
    prompts, ntoks, prios = [], [], []
    for prio, plen_pool, ntok_pool in (
        (1, [28, 36, 40], [6, 8]),
        (2, [8, 12, 16], [4, 6]),
        (3, [3, 5, 7], [2, 3]),
    ):
        for _ in range(per_class):
            p = int(rng.choice(plen_pool))
            prompts.append(rng.integers(0, cfg.vocab_size, p).astype(np.int32))
            ntoks.append(int(rng.choice(ntok_pool)))
            prios.append(prio)
    return cfg, prompts, ntoks, prios, 64


def _serve_multitenant(cfg, params, prompts, ntoks, prios, max_len,
                       smoke: bool):
    """Two overload bursts from concurrent producers against ONE driven
    session, then an uncontended reference serve for the token guard."""
    from repro.serve import Request, Scheduler

    n = len(prompts)
    # A deliberately tight queue bound: the cold burst's compile-heavy
    # first steps make 3 producers pile 18 submits onto a 4-deep queue,
    # so admission control visibly sheds under overload.
    sched = Scheduler(cfg, params, max_slots=4, max_len=max_len, page_size=8,
                      max_queue=4, prefill_chunk=8)
    session = sched.session()

    def burst(rid_base):
        lock = threading.Lock()
        waits = []          # (rid, priority, handle, t_submitted)
        shed = []
        by_thread = {t: [i for i in range(n) if i % 3 == t] for t in range(3)}

        def producer(tid):
            for i in by_thread[tid]:
                req = Request(
                    prompt=prompts[i], n_tokens=ntoks[i], rid=rid_base + i,
                    priority=prios[i], tenant=f"class{prios[i]}",
                )
                t_sub = time.perf_counter()
                try:
                    h = session.submit(req)
                except ValueError:       # queue overloaded: shed
                    with lock:
                        shed.append(i)
                    continue
                with lock:
                    waits.append((i, prios[i], h, t_sub))

        # A burst may span several traces (the session can idle briefly
        # between producer waves), so per-burst counters are deltas of
        # the session-lifetime totals, not last_stats of the final trace.
        pre = (session.total_preemptions, session.total_prefill_chunks,
               session.total_shed)
        t0 = time.perf_counter()
        with session.driving():
            threads = [threading.Thread(target=producer, args=(t,))
                       for t in range(3)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            lat_by_class, toks = {}, {}
            for i, prio, h, t_sub in waits:
                res = h.wait(timeout=1800)
                lat_by_class.setdefault(prio, []).append(
                    time.perf_counter() - t_sub
                )
                toks[i] = res.generated
        wall = time.perf_counter() - t0
        return {
            "wall": wall, "toks": toks, "shed": sorted(shed),
            "lat_by_class": lat_by_class,
            "preemptions": session.total_preemptions - pre[0],
            "prefill_chunks": session.total_prefill_chunks - pre[1],
            "stats_shed": session.total_shed - pre[2],
            "compiled_programs": sched.compile_counts()["total"],
        }

    b1 = burst(0)
    b2 = burst(1000)

    # Uncontended reference: same requests, same rids (same PRNG streams),
    # fresh scheduler, no queue bound — what each admitted request's
    # tokens MUST be, independent of interleaving/shedding/preemption.
    ref_sched = Scheduler(cfg, params, max_slots=4, max_len=max_len,
                          page_size=8)
    ref = {r.rid: r.generated
           for r in ref_sched.serve(
               [Request(prompt=prompts[i], n_tokens=ntoks[i], rid=i)
                for i in range(n)]
           )}
    ok = True
    for b, base in ((b1, 0), (b2, 1000)):
        for i, toks in b["toks"].items():
            if (len(toks) != ntoks[i]
                    or not np.array_equal(np.asarray(toks), ref[i])):
                ok = False

    # Compile-budget contract under concurrency: one decode program and
    # at most one prefill program per (tail bucket, pow2 burst width).
    # Raw totals may legitimately grow between bursts — burst 2 hits
    # burst 1's cached prefix pages, shortening tails into a bucket the
    # cold burst never used — so we assert the budget formula instead.
    counts = sched.compile_counts()
    widths = [w for w in (1, 2, 4, 8, 16) if w <= 4]
    budget_ok = (
        counts["decode"] == 1
        and all(v <= len(widths) for v in counts["prefill"].values())
        and counts["total"] <= 1 + len(widths) * len(sched.prefill_buckets)
    )
    return b1, b2, ok, budget_ok


def _serve_sharded(smoke: bool):
    """Tensor-parallel serving on the forced-8-device host: the standard
    mixed trace at tp=1 vs tp=8 through ``Scheduler(tp=...)``.  Greedy
    tokens must be bitwise-identical across widths (the exactness
    invariant the ``repro.dist`` serving rules guarantee) and the record
    carries tokens/s AND tokens/s-per-device — on a host CPU the per-
    device figure is the honest one, since 8 forced devices share the
    same cores."""
    import dataclasses

    import jax

    from repro import configs  # noqa: F401  (via _trace)
    from repro.models import lm
    from repro.serve import Request, Scheduler

    n_dev = jax.device_count()
    if n_dev != 8:
        raise SystemExit(f"sharded child expected 8 forced devices, "
                         f"got {n_dev}")
    cfg, prompts, ntoks = _trace(smoke)
    cfg = dataclasses.replace(cfg, cache_dtype="float32")
    max_len = 64 if smoke else 128
    params = lm.init(jax.random.PRNGKey(0), cfg)
    useful = sum(ntoks)
    rec = {"path": "sharded", "devices": n_dev, "n_requests": len(prompts),
           "useful_tokens": useful}
    keys = {}
    for tp in (1, n_dev):
        sched = Scheduler(cfg, params, max_slots=4, max_len=max_len,
                          page_size=8, tp=tp)
        reqs = [Request(prompt=p, n_tokens=n)
                for p, n in zip(prompts, ntoks)]

        def run():
            t0 = time.perf_counter()
            results = sched.serve(reqs)
            wall = time.perf_counter() - t0
            toks = {r.rid: r.generated for r in results}
            return wall, toks, [r.finished_wall_s for r in results]

        cold, warm = run(), run()
        keys[tp] = _digest(cold[1])
        sub = _path_record(f"tp{tp}", useful, cold, warm, {
            "compiled_programs": sched.compile_counts()["total"],
            "decode_programs": sched.compile_counts()["decode"],
        })
        sub["warm_tokens_per_s_per_device"] = round(
            sub["warm_tokens_per_s"] / tp, 2
        )
        rec[f"tp{tp}"] = sub
    rec["tokens_identical"] = len(set(keys.values())) == 1
    rec["compiles_identical"] = (
        rec["tp1"]["compiled_programs"] == rec[f"tp{n_dev}"]["compiled_programs"]
    )
    print(json.dumps(rec))
    if not rec["tokens_identical"] or not rec["compiles_identical"]:
        raise SystemExit(1)     # exactness guard: fail the parent loudly


def _serve_bucketed(cfg, params, prompts, ntoks, max_len):
    from repro.serve import Engine, bucket_requests

    eng = Engine(cfg, params, max_len=max_len)
    buckets = bucket_requests([list(p) for p in prompts])

    def run():
        t0 = time.perf_counter()
        toks, lat = {}, []
        for idx, arr in buckets:
            # The whole bucket runs until its longest request finishes —
            # that is the pathology continuous batching removes.
            n_max = max(ntoks[i] for i in idx)
            out = eng.generate(arr, n_tokens=n_max, request_ids=idx)
            done = time.perf_counter() - t0
            for row, i in enumerate(idx):
                toks[i] = out.tokens[row, out.prompt_len:out.prompt_len + ntoks[i]]
                lat.append(done)
        return time.perf_counter() - t0, toks, lat

    cold = run()
    warm = run()
    return cold, warm, {"n_buckets": len(buckets)}


def _path_record(path, useful, cold, warm, extra):
    rec = {"path": path, "useful_tokens": useful, **extra}
    for tag, (wall, toks, lat) in (("cold", cold), ("warm", warm)):
        rec[f"{tag}_s"] = round(wall, 3)
        rec[f"{tag}_tokens_per_s"] = round(useful / max(wall, 1e-9), 2)
        rec[f"{tag}_latency"] = _percentiles(lat)
    rec["tokens_key"] = _digest(cold[1])
    rec["cold_warm_identical"] = _digest(cold[1]) == _digest(warm[1])
    return rec


def run_one(path: str, smoke: bool) -> None:
    """Child-process entry: run one serving path cold, print JSON."""
    import jax

    from repro.models import lm

    if path == "sharded":
        _serve_sharded(smoke)
        return

    if path == "session":
        cfg, prompts, ntoks, max_len, prefix_len = _prefix_trace(smoke)
        params = lm.init(jax.random.PRNGKey(0), cfg)
        useful = sum(ntoks)
        t1, t2 = _serve_session(cfg, params, prompts, ntoks, max_len)
        rec = {
            "path": "session",
            "n_requests": len(prompts),
            "shared_prefix_tokens": int(prefix_len),
            "useful_tokens": useful,
            "tokens_identical": _digest(t1["toks"]) == _digest(t2["toks"]),
            "compiles_unchanged": (
                t1["compiled_programs"] == t2["compiled_programs"]
            ),
            "warm_speedup": round(t1["wall"] / max(t2["wall"], 1e-9), 2),
        }
        for tag, t in (("trace1", t1), ("trace2", t2)):
            rec[tag] = {
                "wall_s": round(t["wall"], 3),
                "tokens_per_s": round(useful / max(t["wall"], 1e-9), 2),
                "latency": _percentiles(t["lat"]),
                "prefix_hit_tokens": t["prefix_hit_tokens"],
                "cross_trace_hit_tokens": t["cross_trace_hit_tokens"],
                "prefix_misses": t["prefix_misses"],
                "compiled_programs": t["compiled_programs"],
            }
        print(json.dumps(rec))
        return

    if path == "multitenant":
        cfg, prompts, ntoks, prios, max_len = _multitenant_trace(smoke)
        params = lm.init(jax.random.PRNGKey(0), cfg)
        b1, b2, tokens_ok, budget_ok = _serve_multitenant(
            cfg, params, prompts, ntoks, prios, max_len, smoke
        )
        rec = {
            "path": "multitenant",
            "n_requests": len(prompts),
            "classes": sorted(set(prios)),
            "tokens_match_reference": bool(tokens_ok),
            "compiles_within_budget": bool(budget_ok),
        }
        for tag, b in (("burst1", b1), ("burst2", b2)):
            served = sum(len(t) for t in b["toks"].values())
            rec[tag] = {
                "wall_s": round(b["wall"], 3),
                "served_tokens": served,
                "tokens_per_s": round(served / max(b["wall"], 1e-9), 2),
                "shed_requests": len(b["shed"]),
                "preemptions": b["preemptions"],
                "prefill_chunks": b["prefill_chunks"],
                "compiled_programs": b["compiled_programs"],
                "latency_by_class": {
                    f"priority_{p}": _percentiles(lat)
                    for p, lat in sorted(b["lat_by_class"].items())
                },
            }
        print(json.dumps(rec))
        return

    if path == "prefix":
        cfg, prompts, ntoks, max_len, prefix_len = _prefix_trace(smoke)
        params = lm.init(jax.random.PRNGKey(0), cfg)
        both = _serve_prefix(cfg, params, prompts, ntoks, max_len)
        useful = sum(ntoks)
        rec = {
            "path": "prefix",
            "n_requests": len(prompts),
            "shared_prefix_tokens": int(prefix_len),
            "prompt_tokens": int(sum(p.size for p in prompts)),
        }
        for tag, r in both.items():
            rec[tag] = _path_record(tag, useful, r["cold"], r["warm"], r["extra"])
        rec["tokens_identical"] = (
            rec["reuse"]["tokens_key"] == rec["monolithic"]["tokens_key"]
        )
        for t in ("warm", "cold"):
            rec[f"{t}_speedup"] = round(
                rec["reuse"][f"{t}_tokens_per_s"]
                / max(rec["monolithic"][f"{t}_tokens_per_s"], 1e-9), 2
            )
        print(json.dumps(rec))
        return

    cfg, prompts, ntoks = _trace(smoke)
    max_len = 64 if smoke else 128
    params = lm.init(jax.random.PRNGKey(0), cfg)
    if path == "continuous":
        cold, warm, extra = _serve_continuous(
            cfg, params, prompts, ntoks, max_len, max_slots=4
        )
    else:
        cold, warm, extra = _serve_bucketed(cfg, params, prompts, ntoks, max_len)
    print(json.dumps(_path_record(path, sum(ntoks), cold, warm, extra)))


def _spawn(path: str, smoke: bool, n_devices: int = 0) -> dict:
    argv = ["-m", "benchmarks.bench_serve", "--run-one", path]
    if smoke:
        argv.append("--smoke")
    env_extra = None
    if n_devices:
        # Forced host devices must be set BEFORE the child imports jax.
        env_extra = {
            "XLA_FLAGS":
                f"--xla_force_host_platform_device_count={n_devices}"
        }
    return run_child(argv, env_extra=env_extra,
                     label=f"bench_serve[{path}]")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized trace (16 requests, short generations)")
    ap.add_argument("--out-root", default=str(REPO_ROOT))
    ap.add_argument("--run-one",
                    choices=["continuous", "bucketed", "prefix", "session",
                             "multitenant", "sharded"],
                    help=argparse.SUPPRESS)  # child-process mode
    args = ap.parse_args()

    if args.run_one:
        run_one(args.run_one, args.smoke)
        return 0

    import jax

    t0 = time.perf_counter()
    cont = _spawn("continuous", args.smoke)
    buck = _spawn("bucketed", args.smoke)
    pref = _spawn("prefix", args.smoke)
    sess = _spawn("session", args.smoke)
    mt = _spawn("multitenant", args.smoke)
    shard = _spawn("sharded", args.smoke, n_devices=8)
    _, prompts, _ = _trace(args.smoke)

    rec = {
        "arch": ARCH,
        "n_requests": len(prompts),
        "useful_tokens": cont["useful_tokens"],
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "continuous": cont,
        "bucketed": buck,
        "prefix_trace": pref,
        "warm_session": sess,
        "multitenant": mt,
        "sharded": shard,
        "warm_speedup": round(
            cont["warm_tokens_per_s"] / max(buck["warm_tokens_per_s"], 1e-9), 2
        ),
        "cold_speedup": round(
            cont["cold_tokens_per_s"] / max(buck["cold_tokens_per_s"], 1e-9), 2
        ),
        "tokens_identical": cont["tokens_key"] == buck["tokens_key"],
        "smoke": bool(args.smoke),
    }

    from repro.core.results import ResultStore

    store = ResultStore(args.out_root)
    path = store.put("BENCH_serve", rec, kind="benchmark",
                     wall_s=time.perf_counter() - t0)
    print(
        f"continuous={cont['warm_tokens_per_s']} tok/s "
        f"bucketed={buck['warm_tokens_per_s']} tok/s "
        f"(warm {rec['warm_speedup']}x, cold {rec['cold_speedup']}x) "
        f"p99 {cont['warm_latency']['p99_s']}s vs "
        f"{buck['warm_latency']['p99_s']}s "
        f"tokens_identical={rec['tokens_identical']} -> {path}"
    )
    print(
        f"prefix trace: reuse={pref['reuse']['warm_tokens_per_s']} tok/s "
        f"monolithic={pref['monolithic']['warm_tokens_per_s']} tok/s "
        f"(warm {pref['warm_speedup']}x) "
        f"hit_tokens={pref['reuse']['prefix_hit_tokens']} "
        f"tokens_identical={pref['tokens_identical']}"
    )
    print(
        f"warm session: trace2={sess['trace2']['tokens_per_s']} tok/s vs "
        f"trace1={sess['trace1']['tokens_per_s']} tok/s "
        f"({sess['warm_speedup']}x) "
        f"cross_trace_hit_tokens={sess['trace2']['cross_trace_hit_tokens']} "
        f"compiles_unchanged={sess['compiles_unchanged']} "
        f"tokens_identical={sess['tokens_identical']}"
    )
    p99s = " ".join(
        f"{k}={v['p99_s']}s"
        for k, v in mt["burst2"]["latency_by_class"].items()
    )
    print(
        f"multitenant: {mt['burst2']['tokens_per_s']} tok/s "
        f"shed={mt['burst1']['shed_requests']}+"
        f"{mt['burst2']['shed_requests']} "
        f"preemptions={mt['burst2']['preemptions']} "
        f"chunks={mt['burst2']['prefill_chunks']} p99 {p99s} "
        f"tokens_match_reference={mt['tokens_match_reference']} "
        f"compiles_within_budget={mt['compiles_within_budget']}"
    )
    print(
        f"sharded: tp8={shard['tp8']['warm_tokens_per_s']} tok/s "
        f"({shard['tp8']['warm_tokens_per_s_per_device']}/dev) vs "
        f"tp1={shard['tp1']['warm_tokens_per_s']} tok/s "
        f"programs={shard['tp8']['compiled_programs']} "
        f"tokens_identical={shard['tokens_identical']}"
    )
    if not rec["tokens_identical"]:
        print("ERROR: continuous and bucketed paths served different tokens")
        return 1
    if not pref["tokens_identical"]:
        print("ERROR: prefix reuse changed the served tokens")
        return 1
    if not sess["tokens_identical"]:
        print("ERROR: session persistence changed the served tokens")
        return 1
    if sess["trace2"]["cross_trace_hit_tokens"] <= 0:
        print("ERROR: warm-session trace recorded no cross-trace prefix hits")
        return 1
    if not sess["compiles_unchanged"]:
        print("ERROR: the warm-session trace compiled new programs")
        return 1
    if not mt["tokens_match_reference"]:
        print("ERROR: an admitted multitenant request lost tokens vs the "
              "uncontended reference serve")
        return 1
    if not mt["compiles_within_budget"]:
        print("ERROR: multitenant bursts compiled beyond the "
              "1 decode + one prefill per (bucket, width) budget")
        return 1
    if rec["warm_speedup"] <= 1.0:
        print("WARNING: continuous batching did not beat the bucketed path")
    if pref["warm_speedup"] <= 1.0:
        print("WARNING: prefix reuse did not beat the monolithic scheduler")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
