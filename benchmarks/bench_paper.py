"""Benchmarks reproducing each paper table/figure.

  fig6  : generated 8K layouts (INT8 / BF16) — areas vs 0.079 / 0.085 mm^2
  fig7  : 64K design-space sweep across 8 precisions — avg area/energy/
          delay/throughput of the Pareto front (trend table)
  fig8  : INT8 + BF16 TOPS/W and TOPS/mm^2 across W_store 4K..128K
  table1: feature comparison is qualitative — emitted as capability checks
  dse   : explorer wall-time per scenario (paper: <= 30 min) + NSGA-II
          front quality vs the exhaustive oracle
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from repro.codegen import generate
from repro.core import explorer, nsga2
from repro.core.cells import CALIBRATED
from repro.core.precision import PAPER_SWEEP
from repro.core.space import DesignSpace
from repro.core.precision import get as get_precision

from .common import emit

CFG = nsga2.NSGA2Config(pop_size=128, generations=64)
ACTIVITY = 0.1


def bench_fig6():
    for prec, target in (("int8", 0.079), ("bf16", 0.085)):
        t0 = time.perf_counter()
        pts = explorer.explore(prec, 8192, CFG, method="brute")
        pmin = min(pts, key=lambda p: p.area_mm2)
        with tempfile.TemporaryDirectory() as d:
            rep = generate(pmin, d)
        dt = (time.perf_counter() - t0) * 1e6
        emit(
            f"fig6.{prec}_8k_layout", dt,
            f"area_mm2={pmin.area_mm2:.4f} target={target}"
            f" audit_ok={rep['audit']['ok']}"
            f" die_mm2={rep['floorplan']['die_area_mm2']:.4f}",
        )


def bench_fig7():
    for prec in PAPER_SWEEP:
        t0 = time.perf_counter()
        pts = explorer.explore(prec.name, 65536, CFG, method="brute",
                               activity=1.0)
        dt = (time.perf_counter() - t0) * 1e6
        emit(
            f"fig7.{prec.name}_64k", dt,
            f"n={len(pts)}"
            f" avg_area_mm2={np.mean([p.area_mm2 for p in pts]):.3f}"
            f" avg_energy_nJ={np.mean([p.energy_nJ for p in pts]):.3f}"
            f" avg_delay_ns={np.mean([p.delay_ns for p in pts]):.3f}"
            f" avg_tops={np.mean([p.tops for p in pts]):.3f}",
        )


def bench_fig8():
    anchors = {("int8", 65536): (22.0, 1.9), ("bf16", 65536): (20.2, 1.8)}
    for prec in ("int8", "bf16"):
        for w in (4096, 8192, 16384, 32768, 65536, 131072):
            t0 = time.perf_counter()
            pts = explorer.explore(prec, w, CFG, method="brute",
                                   activity=ACTIVITY)
            best = max(pts, key=lambda p: p.tops_per_w)
            dt = (time.perf_counter() - t0) * 1e6
            note = ""
            if (prec, w) in anchors:
                tw, tm = anchors[(prec, w)]
                note = f" paper_designAB=({tw},{tm})"
            emit(
                f"fig8.{prec}_{w}", dt,
                f"best_tops_w={best.tops_per_w:.1f}"
                f" tops_mm2={best.tops_per_mm2:.2f}{note}",
            )


def bench_table1_capabilities():
    """Table I row 'SEGA-DCIM': INT & Float, estimation model, Pareto
    design space, automatic trade-offs — demonstrated programmatically.
    Both scenarios run in ONE batched NSGA-II (scenario-table pipeline)."""
    t0 = time.perf_counter()
    union = explorer.explore_multi(
        [("int8", 4096), ("bf16", 4096)], CFG, batched=True
    )
    kinds = {p.precision for p in union}
    dt = (time.perf_counter() - t0) * 1e6
    emit(
        "table1.multi_precision_pareto", dt,
        f"precisions={sorted(kinds)} union_front={len(union)}"
        f" automatic=True batched=True",
    )


def bench_dse():
    # Wall-time per (precision, W_store) scenario; paper budget: 30 min.
    for prec, w in (("int8", 65536), ("fp32", 131072)):
        space = DesignSpace(prec=get_precision(prec), w_store=w)
        t0 = time.perf_counter()
        res = nsga2.run(space, CFG)
        wall = time.perf_counter() - t0
        # warm second run (compile amortized across scenarios in practice)
        t0 = time.perf_counter()
        nsga2.run(space, CFG)
        warm = time.perf_counter() - t0
        oracle = explorer.brute_force_front(space)
        got = {tuple(g) for g in res.front_genes}
        want = {tuple(g) for g in oracle}
        emit(
            f"dse.{prec}_{w}", wall * 1e6,
            f"wall_s={wall:.2f} warm_s={warm:.2f} paper_budget_s=1800"
            f" speedup={1800 / wall:.0f}x"
            f" oracle_coverage={len(got & want) / len(want):.2%}",
        )

    # Paper-faithful eager loop vs the jitted-scan DSE (§Perf-DSE).
    space = DesignSpace(prec=get_precision("int8"), w_store=65536)
    small = nsga2.NSGA2Config(pop_size=64, generations=32)
    t0 = time.perf_counter()
    nsga2.run_unjitted(space, small)
    t_unjit = time.perf_counter() - t0
    t0 = time.perf_counter()
    nsga2.run(space, small)
    t_jit = time.perf_counter() - t0
    emit(
        "dse.unjit_vs_jit", t_unjit * 1e6,
        f"unjit_s={t_unjit:.2f} jit_s={t_jit:.2f}"
        f" speedup={t_unjit / max(t_jit, 1e-9):.1f}x",
    )
    # Batched multi-scenario DSE has its own trajectory benchmark:
    # benchmarks/bench_dse.py -> BENCH_dse.json.


def main():
    bench_fig6()
    bench_fig7()
    bench_fig8()
    bench_table1_capabilities()
    bench_dse()


if __name__ == "__main__":
    main()
