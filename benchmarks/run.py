"""Benchmark runner: one section per paper table/figure + kernels +
framework integration.  Emits ``name,us_per_call,derived`` CSV."""
from __future__ import annotations


def main() -> None:
    print("name,us_per_call,derived")
    from . import bench_calibration, bench_dcimmap, bench_kernels, bench_paper

    print("# --- calibration (anchors + held-out validation) ---")
    bench_calibration.main()
    print("# --- paper figures (Fig. 6/7/8, Table I, DSE budget) ---")
    bench_paper.main()
    print("# --- Pallas kernels ---")
    bench_kernels.main()
    print("# --- arch -> DCIM provisioning (framework integration) ---")
    bench_dcimmap.main()


if __name__ == "__main__":
    main()
