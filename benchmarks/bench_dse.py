"""Batched vs sequential multi-scenario DSE benchmark.

Measures, for a fixed 8-scenario mixed INT/FP sweep:

  * ``sequential_s`` — ``explore_multi(batched=False)``: the historical
    per-scenario loop that re-traces and re-jits NSGA-II for every
    (precision, W_store) scenario,
  * ``batched_s`` — ``explore_multi(batched=True)``: ONE jitted program
    over the :class:`repro.core.scenario.ScenarioTable` (scenario params
    as traced data, ``vmap`` over the scenario axis),
  * warm per-generation NSGA-II throughput of the batched program,

checks the two paths return identical fronts, and writes the record to
``BENCH_dse.json`` at the repo root (the DSE perf trajectory; CI
regenerates it with ``--smoke`` on every PR).

Each path runs in its OWN subprocess so both are measured cold — jit
caches warmed by one path would otherwise subsidize the other.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_dse            # full (paper cfg)
  PYTHONPATH=src python -m benchmarks.bench_dse --smoke    # CI-sized
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import platform
import subprocess
import sys
import time

SCENARIOS = [
    ("int2", 16384), ("int4", 16384), ("int8", 65536), ("int16", 32768),
    ("fp8", 16384), ("bf16", 32768), ("fp16", 65536), ("fp32", 131072),
]

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _cfg(smoke: bool):
    from repro.core import nsga2

    return (
        nsga2.NSGA2Config(pop_size=32, generations=8)
        if smoke
        else nsga2.NSGA2Config(pop_size=128, generations=64)
    )


def run_one(path: str, smoke: bool) -> None:
    """Child-process entry: run one pipeline cold, print a JSON line."""
    from repro.core import explorer

    cfg = _cfg(smoke)
    t0 = time.perf_counter()
    pts = explorer.explore_multi(SCENARIOS, cfg, batched=(path == "batched"))
    elapsed = time.perf_counter() - t0
    front = sorted(
        [p.precision, p.w_store] + [int(g) for g in p.genes] for p in pts
    )
    # Stable cross-process digest (str hash() is per-process randomized).
    import hashlib

    digest = hashlib.sha1(json.dumps(front).encode()).hexdigest()
    print(json.dumps({
        "path": path,
        "seconds": round(elapsed, 3),
        "front_size": len(pts),
        "front_key": digest,
    }))


def _spawn(path: str, smoke: bool) -> dict:
    env = dict(os.environ)
    src = str(REPO_ROOT / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "benchmarks.bench_dse", "--run-one", path]
    if smoke:
        cmd.append("--smoke")
    out = subprocess.run(
        cmd, capture_output=True, text=True, env=env, cwd=REPO_ROOT,
        timeout=1800,
    )
    if out.returncode != 0:
        raise RuntimeError(f"{path} run failed:\n{out.stderr[-2000:]}")
    return json.loads(out.stdout.splitlines()[-1])


def _warm_throughput(smoke: bool) -> dict:
    """Warm per-generation throughput of the batched NSGA-II program."""
    import jax
    import jax.numpy as jnp

    from repro.core import nsga2
    from repro.core.scenario import ScenarioTable

    cfg = _cfg(smoke)
    table = ScenarioTable.from_specs(SCENARIOS)
    key = jax.random.PRNGKey(cfg.seed)
    keys = jnp.broadcast_to(key, (len(table),) + key.shape)
    jax.block_until_ready(nsga2._run_batched_jit(table, cfg, keys))  # warm
    iters = 3
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(nsga2._run_batched_jit(table, cfg, keys))
    warm = (time.perf_counter() - t0) / iters
    gens_total = cfg.generations * len(table)
    return {
        "warm_batched_s": round(warm, 4),
        "per_generation_ms": round(warm / max(gens_total, 1) * 1e3, 4),
        "individuals_per_s": round(
            gens_total * cfg.pop_size / max(warm, 1e-9), 1
        ),
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (small population / few generations)")
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_dse.json"))
    ap.add_argument("--run-one", choices=["batched", "sequential"],
                    help=argparse.SUPPRESS)  # child-process mode
    args = ap.parse_args()

    if args.run_one:
        run_one(args.run_one, args.smoke)
        return 0

    import jax

    cfg = _cfg(args.smoke)
    batched = _spawn("batched", args.smoke)
    sequential = _spawn("sequential", args.smoke)

    rec = {
        "scenarios": [list(s) for s in SCENARIOS],
        "config": {
            "pop_size": cfg.pop_size, "generations": cfg.generations,
            "seed": cfg.seed, "use_pallas": cfg.use_pallas,
        },
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "batched_s": batched["seconds"],
        "sequential_s": sequential["seconds"],
        "speedup": round(
            sequential["seconds"] / max(batched["seconds"], 1e-9), 2
        ),
        "front_size": batched["front_size"],
        "fronts_identical": (
            batched["front_key"] == sequential["front_key"]
            and batched["front_size"] == sequential["front_size"]
        ),
        "smoke": bool(args.smoke),
    }
    rec.update(_warm_throughput(args.smoke))

    from repro.core.results import dump_json

    path = dump_json(args.out, rec)
    print(f"batched={rec['batched_s']}s sequential={rec['sequential_s']}s "
          f"speedup={rec['speedup']}x fronts_identical={rec['fronts_identical']} "
          f"per_gen={rec['per_generation_ms']}ms -> {path}")
    if not rec["fronts_identical"]:
        print("ERROR: batched and sequential fronts differ")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
