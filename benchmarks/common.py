"""Benchmark helpers: timing, CSV emission (name,us_per_call,derived),
and the subprocess-child harness every benchmark driver runs its
measured sections through (cold-start isolation + hard failure
propagation)."""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time
from typing import Callable, Optional, Sequence

import jax

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def run_child(argv: Sequence[str], *, timeout: int = 1800,
              env_extra: Optional[dict] = None, label: str = "child",
              echo: bool = False) -> dict:
    """Run ``python <argv...>`` as a benchmark child and return the JSON
    record on its LAST stdout line.

    This is the one place child results enter a benchmark record, and it
    fails loudly on both hazards that used to produce silently-stale
    JSON sections: a nonzero child exit (crash after partial output) and
    a last stdout line that is not a JSON object (crash message swallowed
    by ``splitlines()[-1]``).  Either raises ``RuntimeError`` carrying
    the child's stderr tail, so ``--smoke`` CI runs abort instead of
    re-publishing the previous record.

    ``echo=True`` forwards the child's progress lines (everything except
    the final JSON record) to this process's stdout.
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO_ROOT / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    if env_extra:
        env.update(env_extra)
    out = subprocess.run(
        [sys.executable, *argv], capture_output=True, text=True, env=env,
        cwd=REPO_ROOT, timeout=timeout,
    )
    if out.returncode != 0:
        raise RuntimeError(
            f"{label} failed (rc={out.returncode}):\n"
            f"--- stdout tail ---\n{out.stdout[-1000:]}\n"
            f"--- stderr tail ---\n{out.stderr[-2000:]}"
        )
    lines = out.stdout.splitlines()
    last = lines[-1] if lines else ""
    try:
        rec = json.loads(last)
    except ValueError:
        rec = None
    if not isinstance(rec, dict):
        raise RuntimeError(
            f"{label} produced no JSON record on its last stdout line "
            f"(got {last[:200]!r}):\n"
            f"--- stderr tail ---\n{out.stderr[-2000:]}"
        )
    if echo:
        for line in lines[:-1]:
            print(line)
    return rec


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds (blocks on jax outputs)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
