"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs pure-jnp oracle.
On CPU these measure the XLA lowering of the kernel body; on TPU the same
entry points run the compiled Pallas kernels."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import emit, time_fn


def main():
    rng = np.random.default_rng(0)

    # pareto_rank: P x P dominance
    for P in (128, 512, 1024):
        F = jnp.asarray(rng.normal(size=(P, 4)).astype(np.float32))
        us_k = time_fn(ops.dominance_matrix, F)
        us_r = time_fn(ref.dominance_matrix_ref, F)
        emit(f"pareto_rank.P{P}", us_k,
             f"ref_us={us_r:.1f} pairs_per_s={P * P / us_k * 1e6:.3g}")

    # dcim_mvm: bit-serial exact int matmul
    for M, K, N in ((128, 512, 128), (256, 2048, 256)):
        x = jnp.asarray(rng.integers(-128, 128, (M, K)).astype(np.int32))
        w = jnp.asarray(rng.integers(-128, 128, (K, N)).astype(np.int32))
        us_k = time_fn(lambda a, b: ops.dcim_mvm(a, b, B_x=8, B_w=8, k=4), x, w)
        us_r = time_fn(ref.dcim_mvm_ref, x, w)
        macs = M * K * N
        emit(f"dcim_mvm.{M}x{K}x{N}", us_k,
             f"ref_us={us_r:.1f} gmacs_per_s={macs / us_k * 1e-3:.2f}")

    # fp_prealign
    for shape in ((64, 16, 64), (256, 32, 128)):
        x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
        us_k = time_fn(
            lambda a: ops._pre.fp_prealign_pallas(a, B_M=8), x)
        us_r = time_fn(lambda a: ref.fp_prealign_ref(a, B_M=8), x)
        emit(f"fp_prealign.{'x'.join(map(str, shape))}", us_k,
             f"ref_us={us_r:.1f}")

    # composed FP-DCIM matmul vs f32 matmul accuracy+speed
    x = jnp.asarray(rng.normal(size=(64, 256)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32))
    us_k = time_fn(lambda a, b: ops.dcim_fp_matmul(a, b, H=64, B_M=8, B_w=8, k=4), x, w)
    got = np.asarray(ops.dcim_fp_matmul(x, w, H=64, B_M=8, B_w=8, k=4))
    want = np.asarray(ref.fp_matmul_f32_ref(x, w))
    rel = np.median(np.abs(got - want) / np.maximum(np.abs(want), 1.0))
    emit("dcim_fp_matmul.64x256x64", us_k, f"median_rel_err={rel:.2e}")


if __name__ == "__main__":
    main()
