"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs pure-jnp oracle.
On CPU these measure the XLA lowering of the kernel body; on TPU the same
entry points run the compiled Pallas kernels.

Persists ``BENCH_kernels.json`` at the repo root (one record per kernel
size, plus the composed FP-DCIM matmul accuracy figure); CI regenerates
it with ``--smoke`` on every PR::

  PYTHONPATH=src python -m benchmarks.bench_kernels --smoke

The sweep runs in a SUBPROCESS child (``common.run_child``) so the
timings are cold and, critically, so a crashing sweep fails the parent
instead of leaving last run's ``BENCH_kernels.json`` in place looking
current; ``--in-process`` keeps the old single-process path for
debugging under a debugger/profiler.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import platform

from .common import emit, run_child, time_fn

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

# (full, smoke) problem sizes per kernel.
_PARETO_P = ((128, 512, 1024), (128, 256))
_MVM_MKN = (((128, 512, 128), (256, 2048, 256)), ((64, 256, 64),))
_PREALIGN = (((64, 16, 64), (256, 32, 128)), ((64, 16, 64),))
# (B, Hk, G, hd, page, nb): slots x kv-heads x group x head-dim, paged KV
_PAGED_DECODE = (((8, 8, 4, 128, 16, 32), (16, 8, 8, 128, 16, 64)),
                 ((4, 2, 4, 64, 16, 8),))
# (B, T, Hk, G, hd, L): burst width x tail x heads x context capacity
_PREFIX = (((8, 64, 8, 4, 128, 512),), ((4, 16, 2, 4, 64, 128),))


def run(smoke: bool) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    kernels: dict = {}

    # pareto_rank: P x P dominance
    for P in _PARETO_P[smoke]:
        F = jnp.asarray(rng.normal(size=(P, 4)).astype(np.float32))
        us_k = time_fn(ops.dominance_matrix, F)
        us_r = time_fn(ref.dominance_matrix_ref, F)
        pairs = round(P * P / us_k * 1e6, 1)
        emit(f"pareto_rank.P{P}", us_k,
             f"ref_us={us_r:.1f} pairs_per_s={pairs:.3g}")
        kernels[f"pareto_rank.P{P}"] = {
            "us": round(us_k, 1), "ref_us": round(us_r, 1),
            "pairs_per_s": pairs,
        }

    # dcim_mvm: bit-serial exact int matmul
    for M, K, N in _MVM_MKN[smoke]:
        x = jnp.asarray(rng.integers(-128, 128, (M, K)).astype(np.int32))
        w = jnp.asarray(rng.integers(-128, 128, (K, N)).astype(np.int32))
        us_k = time_fn(lambda a, b: ops.dcim_mvm(a, b, B_x=8, B_w=8, k=4), x, w)
        us_r = time_fn(ref.dcim_mvm_ref, x, w)
        macs = M * K * N
        gmacs = round(macs / us_k * 1e-3, 2)
        emit(f"dcim_mvm.{M}x{K}x{N}", us_k,
             f"ref_us={us_r:.1f} gmacs_per_s={gmacs:.2f}")
        kernels[f"dcim_mvm.{M}x{K}x{N}"] = {
            "us": round(us_k, 1), "ref_us": round(us_r, 1),
            "gmacs_per_s": gmacs,
        }

    # fp_prealign — through the public dispatcher (XLA ref on CPU, the
    # compiled kernel on TPU), vs the ref timed directly.
    for shape in _PREALIGN[smoke]:
        M, G, H = shape
        x = jnp.asarray(rng.normal(size=(M, G * H)).astype(np.float32))
        xg = x.reshape(shape)
        us_k = time_fn(lambda a: ops.fp_prealign(a, H=H, B_M=8), x)
        us_r = time_fn(lambda a: ref.fp_prealign_ref(a, B_M=8), xg)
        name = f"fp_prealign.{'x'.join(map(str, shape))}"
        emit(name, us_k, f"ref_us={us_r:.1f}")
        kernels[name] = {"us": round(us_k, 1), "ref_us": round(us_r, 1)}

    # paged_decode: fused block-table attention vs the XLA gather+attend
    # baseline it replaces.  "us" is the auto dispatch (fused kernel on
    # TPU, XLA ref on CPU); "interp_us" times the kernel body through
    # the Pallas interpreter (parity-path cost, not a perf figure).
    for B, Hk, G, hd, page, nb in _PAGED_DECODE[smoke]:
        n_pages = 1 + B * nb
        S = nb * page
        kp = jnp.asarray(rng.standard_normal((n_pages, page, Hk, hd)),
                         jnp.bfloat16)
        vp = jnp.asarray(rng.standard_normal((n_pages, page, Hk, hd)),
                         jnp.bfloat16)
        bt = jnp.asarray(
            rng.permutation(np.arange(1, n_pages))[: B * nb].reshape(B, nb),
            jnp.int32)
        q = jnp.asarray(rng.standard_normal((B, 1, Hk * G, hd)), jnp.float32)
        pos = jnp.asarray(rng.integers(S // 2, S, B), jnp.int32)
        us_k = time_fn(lambda *a: ops.paged_decode_gqa(*a), q, kp, vp, bt, pos)
        us_r = time_fn(lambda *a: ops.paged_decode_gqa(*a, backend="xla"),
                       q, kp, vp, bt, pos)
        us_i = time_fn(
            lambda *a: ops.paged_decode_gqa(*a, backend="pallas_interpret"),
            q, kp, vp, bt, pos)
        toks = round(B / us_k * 1e6, 1)
        kv_bytes = 2 * B * S * Hk * hd * kp.dtype.itemsize   # K+V read
        name = f"paged_decode.B{B}xS{S}xH{Hk * G}x{hd}"
        emit(name, us_k, f"ref_us={us_r:.1f} interp_us={us_i:.1f} "
             f"tokens_per_s={toks:.4g}")
        kernels[name] = {
            "us": round(us_k, 1), "ref_us": round(us_r, 1),
            "interp_us": round(us_i, 1), "tokens_per_s": toks,
            "kv_bytes_per_step": kv_bytes,
        }

    # prefix_prefill: fused [ctx ; causal tail] vs concat+prefix_attention
    for B, T, Hk, G, hd, L in _PREFIX[smoke]:
        kc = jnp.asarray(rng.standard_normal((B, L, Hk, hd)), jnp.float32)
        vc = jnp.asarray(rng.standard_normal((B, L, Hk, hd)), jnp.float32)
        kt = jnp.asarray(rng.standard_normal((B, T, Hk, hd)), jnp.float32)
        vt = jnp.asarray(rng.standard_normal((B, T, Hk, hd)), jnp.float32)
        q = jnp.asarray(rng.standard_normal((B, T, Hk * G, hd)), jnp.float32)
        ctx = jnp.asarray(rng.integers(0, L + 1, B), jnp.int32)
        us_k = time_fn(lambda *a: ops.prefix_prefill(*a), q, kc, vc, kt, vt, ctx)
        us_r = time_fn(lambda *a: ops.prefix_prefill(*a, backend="xla"),
                       q, kc, vc, kt, vt, ctx)
        us_i = time_fn(
            lambda *a: ops.prefix_prefill(*a, backend="pallas_interpret"),
            q, kc, vc, kt, vt, ctx)
        toks = round(B * T / us_k * 1e6, 1)
        score_bytes = 4 * B * Hk * G * T * (L + T)   # f32 scores the XLA path
        name = f"prefix_prefill.B{B}xT{T}xL{L}xH{Hk * G}x{hd}"
        emit(name, us_k, f"ref_us={us_r:.1f} interp_us={us_i:.1f} "
             f"tokens_per_s={toks:.4g}")
        kernels[name] = {
            "us": round(us_k, 1), "ref_us": round(us_r, 1),
            "interp_us": round(us_i, 1), "tokens_per_s": toks,
            "xla_score_bytes": score_bytes,
        }

    # composed FP-DCIM matmul vs f32 matmul accuracy+speed
    x = jnp.asarray(rng.normal(size=(64, 256)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32))
    us_k = time_fn(lambda a, b: ops.dcim_fp_matmul(a, b, H=64, B_M=8, B_w=8, k=4), x, w)
    got = np.asarray(ops.dcim_fp_matmul(x, w, H=64, B_M=8, B_w=8, k=4))
    want = np.asarray(ref.fp_matmul_f32_ref(x, w))
    rel = float(np.median(np.abs(got - want) / np.maximum(np.abs(want), 1.0)))
    emit("dcim_fp_matmul.64x256x64", us_k, f"median_rel_err={rel:.2e}")
    kernels["dcim_fp_matmul.64x256x64"] = {
        "us": round(us_k, 1), "median_rel_err": rel,
    }

    return {
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "smoke": bool(smoke),
        "kernels": kernels,
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (smallest problem sizes only)")
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_kernels.json"))
    ap.add_argument("--in-process", action="store_true",
                    help="run the sweep in this process (debugging)")
    ap.add_argument("--run-one", choices=["sweep"],
                    help=argparse.SUPPRESS)  # child-process mode
    args = ap.parse_args()

    if args.run_one:        # child: sweep, JSON record on the last line
        print(json.dumps(run(args.smoke)))
        return 0

    if args.in_process:
        rec = run(args.smoke)
    else:
        argv = ["-m", "benchmarks.bench_kernels", "--run-one", "sweep"]
        if args.smoke:
            argv.append("--smoke")
        rec = run_child(argv, label="bench_kernels[sweep]", echo=True)

    from repro.core.results import dump_json

    path = dump_json(args.out, rec)
    print(f"{len(rec['kernels'])} kernel size(s) "
          f"[{rec['backend']}] -> {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
