"""Architecture -> DCIM provisioning benchmark: runs the explorer-driven
mapper for every assigned architecture (the framework-level integration
of the paper's compiler)."""
from __future__ import annotations

import time

from repro import configs
from repro.core import nsga2
from repro.dcimmap import plan

from .common import emit

CFG = nsga2.NSGA2Config(pop_size=64, generations=32)


def main():
    for arch in configs.ARCH_NAMES:
        t0 = time.perf_counter()
        p = plan(arch, precision="int8", w_store=65536, cfg_nsga=CFG)
        dt = (time.perf_counter() - t0) * 1e6
        emit(
            f"dcimmap.{arch}", dt,
            f"macros={p.n_macros} area_mm2={p.total_area_mm2:.0f}"
            f" power_W={p.total_power_W:.1f} tok_s={p.tokens_per_s:.1f}"
            f" unmappable={len(p.unmappable)}",
        )


if __name__ == "__main__":
    main()
