"""Config registry scaffolding + the assigned input-shape matrix."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List

from repro.models.config import LMConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


# The assigned LM shape set (identical for all 10 archs).
SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

# Production defaults for the full-size configs (dry-run only — smoke
# tests use the reduced configs).
PROD = dict(
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    attn_chunk_q=512,
    attn_chunk_kv=1024,
    mamba_chunk=256,
    loss_chunk=512,
    cache_dtype="bfloat16",
)


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    name: str
    family: str                      # dense|moe|ssm|hybrid|vlm|audio
    config: Callable[..., LMConfig]
    smoke: Callable[[], LMConfig]
    sub_quadratic: bool = False      # may run long_500k

    def shape_names(self) -> List[str]:
        out = ["train_4k", "prefill_32k", "decode_32k"]
        if self.sub_quadratic:
            out.append("long_500k")
        return out


REGISTRY: Dict[str, ArchEntry] = {}


def register(entry: ArchEntry):
    REGISTRY[entry.name] = entry
    return entry
