"""The 10 assigned architectures, exactly as specified in the assignment
brief (sources noted inline).  Each entry has a full production config
and a reduced same-family smoke config (small layers/width/experts) that
runs one forward/train step on CPU.
"""
from __future__ import annotations

from repro.models.attention import MLAConfig
from repro.models.config import LMConfig
from repro.models.mamba import SSMConfig
from repro.models.moe import MoEConfig

from .base import PROD, ArchEntry, register


# --- qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191] -----
def qwen2_vl_72b(**ov) -> LMConfig:
    kw = dict(
        name="qwen2-vl-72b",
        n_layers=80, d_model=8192, n_heads=64, n_kv=8, d_ff=29568,
        vocab_size=152064, head_dim=128, qkv_bias=True,
        pos="mrope", mrope_sections=(16, 24, 24), rope_theta=1e6,
        external_embed=True,        # patch/text embeds from the stub frontend
        **PROD,
    )
    kw.update(ov)
    return LMConfig(**kw).validate()


def qwen2_vl_72b_smoke() -> LMConfig:
    return qwen2_vl_72b(
        n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128, head_dim=16,
        vocab_size=512, mrope_sections=(4, 2, 2),
        param_dtype="float32", compute_dtype="float32", remat=False,
        attn_chunk_q=8, attn_chunk_kv=8, loss_chunk=0,
    )


# --- musicgen-large [audio] — decoder-only over EnCodec tokens [2306.05284] --
def musicgen_large(**ov) -> LMConfig:
    kw = dict(
        name="musicgen-large",
        n_layers=48, d_model=2048, n_heads=32, n_kv=32, d_ff=8192,
        vocab_size=2048, pos="sinusoidal", norm="ln", act="gelu",
        **PROD,
    )
    kw.update(ov)
    return LMConfig(**kw).validate()


def musicgen_large_smoke() -> LMConfig:
    return musicgen_large(
        n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=128, vocab_size=256,
        param_dtype="float32", compute_dtype="float32", remat=False,
        attn_chunk_q=8, attn_chunk_kv=8, loss_chunk=0,
    )


# --- moonshot-v1-16b-a3b [moe] — 64e top-6 [hf:moonshotai/Moonlight-16B-A3B] --
def moonshot_v1_16b(**ov) -> LMConfig:
    kw = dict(
        name="moonshot-v1-16b-a3b",
        n_layers=48, d_model=2048, n_heads=16, n_kv=16, d_ff=1408,
        vocab_size=163840, head_dim=128,
        ffn_kind="moe",
        moe=MoEConfig(n_experts=64, top_k=6, d_ff=1408, group_size=256),
        **PROD,
    )
    kw.update(ov)
    return LMConfig(**kw).validate()


def moonshot_v1_16b_smoke() -> LMConfig:
    return moonshot_v1_16b(
        n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=64, head_dim=16,
        vocab_size=512,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=64, group_size=16),
        param_dtype="float32", compute_dtype="float32", remat=False,
        attn_chunk_q=8, attn_chunk_kv=8, loss_chunk=0,
    )


# --- deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP [2412.19437]
def deepseek_v3_671b(**ov) -> LMConfig:
    kw = dict(
        name="deepseek-v3-671b",
        n_layers=61, d_model=7168, n_heads=128, n_kv=128, d_ff=2048,
        vocab_size=129280,
        attn_kind="mla",
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
        ffn_kind="moe",
        moe=MoEConfig(n_experts=256, top_k=8, d_ff=2048, n_shared=1,
                      group_size=256),
        mtp=True,
        **PROD,
    )
    kw.update(ov)
    return LMConfig(**kw).validate()


def deepseek_v3_671b_smoke() -> LMConfig:
    return deepseek_v3_671b(
        n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=64, vocab_size=512,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                      qk_rope_dim=8, v_head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=64, n_shared=1, group_size=16),
        param_dtype="float32", compute_dtype="float32", remat=False,
        attn_chunk_q=8, attn_chunk_kv=8, loss_chunk=0,
    )


# --- falcon-mamba-7b [ssm] — mamba1, attn-free [arXiv:2410.05355] -------------
def falcon_mamba_7b(**ov) -> LMConfig:
    kw = dict(
        name="falcon-mamba-7b",
        n_layers=64, d_model=4096, n_heads=1, n_kv=1, d_ff=0,
        vocab_size=65024,
        mixer="mamba", ffn_kind="none",
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, dt_rank=256),
        ssm_impl="pallas",   # adopted after §Perf I5 (serving path only)
        **PROD,
    )
    kw.update(ov)
    return LMConfig(**kw).validate()


def falcon_mamba_7b_smoke() -> LMConfig:
    return falcon_mamba_7b(
        n_layers=2, d_model=64, vocab_size=256,
        ssm=SSMConfig(d_state=8, d_conv=4, expand=2, dt_rank=8),
        param_dtype="float32", compute_dtype="float32", remat=False,
        mamba_chunk=8, loss_chunk=0,
    )


# --- phi4-mini-3.8b [dense] — RoPE SwiGLU GQA [arXiv:2412.08905] ---------------
def phi4_mini_3p8b(**ov) -> LMConfig:
    kw = dict(
        name="phi4-mini-3.8b",
        n_layers=32, d_model=3072, n_heads=24, n_kv=8, d_ff=8192,
        vocab_size=200064, head_dim=128, tie_embeddings=True,
        **PROD,
    )
    kw.update(ov)
    return LMConfig(**kw).validate()


def phi4_mini_3p8b_smoke() -> LMConfig:
    return phi4_mini_3p8b(
        n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128, head_dim=16,
        vocab_size=512,
        param_dtype="float32", compute_dtype="float32", remat=False,
        attn_chunk_q=8, attn_chunk_kv=8, loss_chunk=0,
    )


# --- qwen2.5-14b [dense] — GQA, QKV bias [hf:Qwen/Qwen2.5] ---------------------
def qwen2p5_14b(**ov) -> LMConfig:
    kw = dict(
        name="qwen2.5-14b",
        n_layers=48, d_model=5120, n_heads=40, n_kv=8, d_ff=13824,
        vocab_size=152064, head_dim=128, qkv_bias=True,
        **PROD,
    )
    kw.update(ov)
    return LMConfig(**kw).validate()


def qwen2p5_14b_smoke() -> LMConfig:
    return qwen2p5_14b(
        n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128, head_dim=16,
        vocab_size=512,
        param_dtype="float32", compute_dtype="float32", remat=False,
        attn_chunk_q=8, attn_chunk_kv=8, loss_chunk=0,
    )


# --- qwen2.5-3b [dense] --------------------------------------------------------
def qwen2p5_3b(**ov) -> LMConfig:
    kw = dict(
        name="qwen2.5-3b",
        n_layers=36, d_model=2048, n_heads=16, n_kv=2, d_ff=11008,
        vocab_size=151936, head_dim=128, qkv_bias=True,
        **PROD,
    )
    kw.update(ov)
    return LMConfig(**kw).validate()


def qwen2p5_3b_smoke() -> LMConfig:
    return qwen2p5_3b(
        n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128, head_dim=16,
        vocab_size=512,
        param_dtype="float32", compute_dtype="float32", remat=False,
        attn_chunk_q=8, attn_chunk_kv=8, loss_chunk=0,
    )


# --- mistral-nemo-12b [dense] — 128k ctx [hf:mistralai/Mistral-Nemo-Base-2407] --
def mistral_nemo_12b(**ov) -> LMConfig:
    kw = dict(
        name="mistral-nemo-12b",
        n_layers=40, d_model=5120, n_heads=32, n_kv=8, d_ff=14336,
        vocab_size=131072, head_dim=128,
        **PROD,
    )
    kw.update(ov)
    return LMConfig(**kw).validate()


def mistral_nemo_12b_smoke() -> LMConfig:
    return mistral_nemo_12b(
        n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128, head_dim=16,
        vocab_size=512,
        param_dtype="float32", compute_dtype="float32", remat=False,
        attn_chunk_q=8, attn_chunk_kv=8, loss_chunk=0,
    )


# --- jamba-v0.1-52b [hybrid] — Mamba+attn 1:7, MoE 16e top-2 [arXiv:2403.19887] -
def jamba_v0p1_52b(**ov) -> LMConfig:
    kw = dict(
        name="jamba-v0.1-52b",
        n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336,
        vocab_size=65536, head_dim=128,
        mixer="hybrid", hybrid_period=8, hybrid_attn_index=4,
        ffn_kind="moe", moe_every=2, moe_offset=1,
        moe=MoEConfig(n_experts=16, top_k=2, d_ff=14336, group_size=256),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, dt_rank=256),
        ssm_impl="pallas",   # adopted after §Perf I5 (serving path only)
        **PROD,
    )
    kw.update(ov)
    return LMConfig(**kw).validate()


def jamba_v0p1_52b_smoke() -> LMConfig:
    return jamba_v0p1_52b(
        n_layers=8, d_model=64, n_heads=4, n_kv=2, d_ff=128, head_dim=16,
        vocab_size=512,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=64, group_size=16),
        ssm=SSMConfig(d_state=8, d_conv=4, expand=2, dt_rank=8),
        param_dtype="float32", compute_dtype="float32", remat=False,
        attn_chunk_q=8, attn_chunk_kv=8, mamba_chunk=8, loss_chunk=0,
    )


ENTRIES = [
    ArchEntry("qwen2-vl-72b", "vlm", qwen2_vl_72b, qwen2_vl_72b_smoke),
    ArchEntry("musicgen-large", "audio", musicgen_large, musicgen_large_smoke),
    ArchEntry("moonshot-v1-16b-a3b", "moe", moonshot_v1_16b, moonshot_v1_16b_smoke),
    ArchEntry("deepseek-v3-671b", "moe", deepseek_v3_671b, deepseek_v3_671b_smoke),
    ArchEntry("falcon-mamba-7b", "ssm", falcon_mamba_7b, falcon_mamba_7b_smoke,
              sub_quadratic=True),
    ArchEntry("phi4-mini-3.8b", "dense", phi4_mini_3p8b, phi4_mini_3p8b_smoke),
    ArchEntry("qwen2.5-14b", "dense", qwen2p5_14b, qwen2p5_14b_smoke),
    ArchEntry("qwen2.5-3b", "dense", qwen2p5_3b, qwen2p5_3b_smoke),
    ArchEntry("mistral-nemo-12b", "dense", mistral_nemo_12b, mistral_nemo_12b_smoke),
    ArchEntry("jamba-v0.1-52b", "hybrid", jamba_v0p1_52b, jamba_v0p1_52b_smoke,
              sub_quadratic=True),
]

for e in ENTRIES:
    register(e)
