"""Architecture/config registry: 10 assigned archs x 4 shapes (see
DESIGN.md §4).  ``get_config(name)`` builds the full production config,
``get_smoke_config(name)`` the reduced same-family config used in CPU
smoke tests."""
from __future__ import annotations

from typing import List

from repro.models.config import LMConfig

from . import archs  # noqa: F401  (populates REGISTRY)
from .base import REGISTRY, SHAPES, ArchEntry, ShapeSpec  # noqa: F401

ARCH_NAMES: List[str] = list(REGISTRY)


def entry(name: str) -> ArchEntry:
    try:
        return REGISTRY[name]
    except KeyError as e:  # pragma: no cover
        raise ValueError(f"unknown arch {name!r}; known: {ARCH_NAMES}") from e


def get_config(name: str, **overrides) -> LMConfig:
    return entry(name).config(**overrides)


def get_smoke_config(name: str) -> LMConfig:
    return entry(name).smoke()


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; skipped cells annotated."""
    out = []
    for name, e in REGISTRY.items():
        allowed = set(e.shape_names())
        for sname, spec in SHAPES.items():
            if sname in allowed:
                out.append((name, sname, spec, "run"))
            elif include_skipped:
                out.append((name, sname, spec, "skip:full-attention-500k"))
    return out
