"""Deterministic, checkpointable data pipeline.

Two sources behind one iterator protocol (``next_batch() -> batch``,
``state() -> dict``, ``restore(state)``):

 * SyntheticLM — stateless-RNG token stream keyed by (seed, step): any
   step's batch is reproducible from the cursor alone, so resuming from
   a checkpoint replays the exact stream (fault-tolerance requirement).
 * TokenFileDataset — memory-mapped binary token file (uint16/uint32),
   sliced into (seq+1)-token windows, sharded round-robin across
   data-parallel readers.

Batches: {"tokens" (B, S) int32, "targets" (B, S) int32,
"loss_mask" (B, S) f32}.
"""
from __future__ import annotations

import dataclasses
import pathlib
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    step: int = 0

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, self.step))
        toks = rng.integers(
            0, self.vocab_size, size=(self.batch_size, self.seq_len + 1)
        ).astype(np.int32)
        self.step += 1
        return {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:],
            "loss_mask": np.ones((self.batch_size, self.seq_len), np.float32),
        }

    def state(self) -> dict:
        return {"kind": "synthetic", "seed": self.seed, "step": self.step}

    def restore(self, state: dict):
        assert state["kind"] == "synthetic"
        self.seed = state["seed"]
        self.step = state["step"]


@dataclasses.dataclass
class TokenFileDataset:
    """Binary token file -> (seq+1) windows, sharded across readers."""

    path: str
    seq_len: int
    batch_size: int
    dtype: str = "uint16"
    shard_index: int = 0
    num_shards: int = 1
    cursor: int = 0            # window index within this shard
    pad_id: int = 0

    def __post_init__(self):
        self._tokens = np.memmap(self.path, dtype=self.dtype, mode="r")
        self._n_windows = len(self._tokens) // (self.seq_len + 1)
        if self._n_windows < self.num_shards:
            raise ValueError("dataset smaller than shard count")

    def _window(self, i: int) -> np.ndarray:
        w = self.seq_len + 1
        return np.asarray(self._tokens[i * w : (i + 1) * w], np.int32)

    def next_batch(self) -> Dict[str, np.ndarray]:
        rows = []
        per_shard = self._n_windows // self.num_shards
        for _ in range(self.batch_size):
            local = self.cursor % per_shard
            rows.append(self._window(local * self.num_shards + self.shard_index))
            self.cursor += 1
        toks = np.stack(rows)
        return {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:],
            "loss_mask": (toks[:, 1:] != self.pad_id).astype(np.float32),
        }

    def state(self) -> dict:
        return {
            "kind": "file",
            "cursor": self.cursor,
            "shard_index": self.shard_index,
            "num_shards": self.num_shards,
        }

    def restore(self, state: dict):
        assert state["kind"] == "file"
        self.cursor = state["cursor"]
        self.shard_index = state["shard_index"]
        self.num_shards = state["num_shards"]


def write_token_file(path, tokens: np.ndarray, dtype="uint16"):
    np.asarray(tokens, dtype).tofile(path)
    return pathlib.Path(path)


def make_dataset(cfg: dict):
    kind = cfg.get("kind", "synthetic")
    if kind == "synthetic":
        return SyntheticLM(
            vocab_size=cfg["vocab_size"], seq_len=cfg["seq_len"],
            batch_size=cfg["batch_size"], seed=cfg.get("seed", 0),
        )
    return TokenFileDataset(
        path=cfg["path"], seq_len=cfg["seq_len"], batch_size=cfg["batch_size"],
        dtype=cfg.get("dtype", "uint16"),
        shard_index=cfg.get("shard_index", 0),
        num_shards=cfg.get("num_shards", 1),
    )
