from .pipeline import SyntheticLM, TokenFileDataset, make_dataset  # noqa: F401
