"""DCIM functional simulator: execute real MVM workloads *as the
generated macro would*, bit-exactly, with cycle/energy accounting from
the cost model.

``DCIMMacroSim`` wraps one explored design point:

  * ``mvm(x, w)`` — integer path: per-tensor symmetric quantization to
    B_x/B_w bits, exact bit-serial MAC (kernels.dcim_mvm), dequantize.
  * ``mvm_fp(x, w)`` — pre-aligned block-FP path (kernels.dcim_fp_matmul)
    with group height H from the design.
  * ``account(M, K, N)`` — cycles / latency / energy for that workload on
    this macro (tiling over N columns x H rows, B_x/k cycles per pass),
    which is what the dcimmap layer aggregates per architecture.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.cells import CALIBRATED, TechParams, TSMC28
from repro.core.explorer import ParetoPoint
from repro.core.macros import macro_costs, physical
from repro.core.precision import Precision, get as get_precision
from repro.kernels import ops


def quantize_sym(x, bits: int):
    """Per-tensor symmetric quantization -> (int32 codes, scale)."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    qmax = 2 ** (bits - 1) - 1
    scale = amax / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax).astype(jnp.int32)
    return q, scale


@dataclasses.dataclass
class DCIMMacroSim:
    precision: Precision
    N: int
    H: int
    L: int
    k: int
    tech: TechParams = CALIBRATED
    activity: float = 1.0

    @classmethod
    def from_point(cls, p: ParetoPoint, **kw) -> "DCIMMacroSim":
        return cls(precision=get_precision(p.precision), N=p.N, H=p.H, L=p.L,
                   k=p.k, **kw)

    @property
    def w_store(self) -> int:
        return self.N * self.H * self.L // self.precision.B_w

    # --- numerics -----------------------------------------------------------
    def mvm(self, x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
        """Integer DCIM execution of y = x @ w (float in/out)."""
        p = self.precision
        assert not p.is_fp
        qx, sx = quantize_sym(x, p.B_x)
        qw, sw = quantize_sym(w, p.B_w)
        y = ops.dcim_mvm(qx, qw, B_x=p.B_x, B_w=p.B_w, k=self.k)
        return y.astype(jnp.float32) * (sx * sw)

    def mvm_fp(self, x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
        """Pre-aligned block-FP DCIM execution (group height = H)."""
        p = self.precision
        assert p.is_fp
        K = x.shape[-1]
        H = math.gcd(self.H, K)
        return ops.dcim_fp_matmul(x, w, H=H, B_M=p.B_M, B_w=p.B_w, k=self.k)

    def __call__(self, x, w):
        return self.mvm_fp(x, w) if self.precision.is_fp else self.mvm(x, w)

    def matmul(self, x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
        """Rank-polymorphic ``x @ w`` through the macro's numerics:
        x (..., K) @ w (K, N) -> (..., N).  This is the shape contract of
        ``models.common.dense``, so the sim can stand in for every model
        projection (see :func:`dcim_numerics`)."""
        K = x.shape[-1]
        y = self(x.reshape(-1, K).astype(jnp.float32), w.astype(jnp.float32))
        return y.reshape(x.shape[:-1] + (w.shape[-1],))

    # --- cost accounting ------------------------------------------------------
    def account(self, M: int, K: int, N_out: int) -> dict:
        """Latency/energy for an (M, K) x (K, N_out) MVM stream on this
        macro.  The array holds H*L rows x (N/B_w) weight columns per
        load; weights are streamed in tiles; inputs take ceil(B_x/k)
        cycles per row-pass (the paper's throughput model)."""
        p = self.precision
        costs = macro_costs(
            float(self.N), float(self.H), float(self.L), float(self.k), p, TSMC28
        )
        phys = physical(costs, self.tech, self.activity)
        cols_per_load = self.N // p.B_w          # output channels resident
        rows_per_pass = self.H                   # reduction rows per pass
        passes_k = math.ceil(K / rows_per_pass)
        loads_n = math.ceil(N_out / (cols_per_load * self.L))
        cycles_per_pass = math.ceil(p.B_x / self.k)
        total_cycles = M * passes_k * loads_n * cycles_per_pass * self.L
        delay_ns = float(np.asarray(phys.delay_ns))
        energy_nJ = float(np.asarray(phys.energy_nJ))
        lat_ns = total_cycles * delay_ns
        return {
            "cycles": int(total_cycles),
            "latency_us": lat_ns * 1e-3,
            "energy_uJ": total_cycles * energy_nJ * 1e-3,
            "macs": M * K * N_out,
            "tops_effective": (2.0 * M * K * N_out) / max(lat_ns, 1e-9) * 1e-3,
            "weight_loads": loads_n * passes_k,
        }


@contextlib.contextmanager
def dcim_numerics(sim: DCIMMacroSim):
    """Route every ``models.common.dense`` matmul through ``sim``.

    Any model program *traced* inside this context — Engine prefill /
    decode, the Scheduler's slotted decode — executes its projections
    with the generated macro's numerics (bit-serial integer or
    pre-aligned block-FP) instead of the float path.  The hook is read at
    trace time, so keep the context active around the serving calls; the
    jitted programs then retain the DCIM path for their lifetime.
    """
    from repro.models import common as _common

    prev = _common.set_mvm_impl(sim.matmul)
    try:
        yield sim
    finally:
        _common.set_mvm_impl(prev)
