"""DCIM functional simulator: execute real MVM workloads *as the
generated macro would*, bit-exactly, with cycle/energy accounting from
the cost model.

``DCIMMacroSim`` wraps one explored design point:

  * ``mvm(x, w)`` — integer path: per-tensor symmetric quantization to
    B_x/B_w bits, exact bit-serial MAC (kernels.dcim_mvm), dequantize.
  * ``mvm_fp(x, w)`` — pre-aligned block-FP path (kernels.dcim_fp_matmul)
    with group height H from the design.
  * ``account(M, K, N)`` — cycles / latency / energy for that workload on
    this macro (tiling over N columns x H rows, B_x/k cycles per pass),
    which is what the dcimmap layer aggregates per architecture.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math

import jax.numpy as jnp
import numpy as np

from repro.core.cells import CALIBRATED, TechParams, TSMC28
from repro.core.explorer import ParetoPoint
from repro.core.macros import macro_costs, physical
from repro.core.precision import Precision, get as get_precision
from repro.kernels import ops


def quantize_sym(x, bits: int):
    """Per-tensor symmetric quantization -> (int32 codes, scale).

    The clip range is symmetric ([-qmax, qmax], NOT the two's-complement
    [-qmax-1, qmax]): the scale is ``amax / qmax``, so the ``-qmax-1``
    code would dequantize to ``-amax * (qmax+1)/qmax`` — outside the
    representable range the scale promises.  The precision lint recovers
    the bit width from these clip constants and rejects asymmetric
    bounds."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    qmax = 2 ** (bits - 1) - 1
    scale = amax / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32)
    return q, scale


@dataclasses.dataclass
class DCIMMacroSim:
    precision: Precision
    N: int
    H: int
    L: int
    k: int
    tech: TechParams = CALIBRATED
    activity: float = 1.0

    @classmethod
    def from_point(cls, p: ParetoPoint, **kw) -> "DCIMMacroSim":
        return cls(precision=get_precision(p.precision), N=p.N, H=p.H, L=p.L,
                   k=p.k, **kw)

    @property
    def w_store(self) -> int:
        return self.N * self.H * self.L // self.precision.B_w

    # --- numerics -----------------------------------------------------------
    def mvm(self, x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
        """Integer DCIM execution of y = x @ w (float in/out)."""
        p = self.precision
        assert not p.is_fp
        qx, sx = quantize_sym(x, p.B_x)
        qw, sw = quantize_sym(w, p.B_w)
        y = ops.dcim_mvm(qx, qw, B_x=p.B_x, B_w=p.B_w, k=self.k)
        return y.astype(jnp.float32) * (sx * sw)

    def mvm_fp(self, x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
        """Pre-aligned block-FP DCIM execution (group height = H)."""
        p = self.precision
        assert p.is_fp
        K = x.shape[-1]
        H = math.gcd(self.H, K)
        return ops.dcim_fp_matmul(x, w, H=H, B_M=p.B_M, B_w=p.B_w, k=self.k)

    def __call__(self, x, w):
        return self.mvm_fp(x, w) if self.precision.is_fp else self.mvm(x, w)

    def matmul(self, x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
        """Rank-polymorphic ``x @ w`` through the macro's numerics:
        x (..., K) @ w (K, N) -> (..., N).  This is the shape contract of
        ``models.common.dense``, so the sim can stand in for every model
        projection (see :func:`dcim_numerics`)."""
        K = x.shape[-1]
        y = self(x.reshape(-1, K).astype(jnp.float32), w.astype(jnp.float32))
        return y.reshape(x.shape[:-1] + (w.shape[-1],))

    # --- cost accounting ------------------------------------------------------
    def account(self, M: int, K: int, N_out: int) -> dict:
        """Latency/energy for an (M, K) x (K, N_out) MVM stream on this
        macro.  The array holds H*L rows x (N/B_w) weight columns per
        load; weights are streamed in tiles; inputs take ceil(B_x/k)
        cycles per row-pass (the paper's throughput model)."""
        p = self.precision
        costs = macro_costs(
            float(self.N), float(self.H), float(self.L), float(self.k), p, TSMC28
        )
        phys = physical(costs, self.tech, self.activity)
        cols_per_load = self.N // p.B_w          # output channels resident
        rows_per_pass = self.H                   # reduction rows per pass
        passes_k = math.ceil(K / rows_per_pass)
        loads_n = math.ceil(N_out / (cols_per_load * self.L))
        cycles_per_pass = math.ceil(p.B_x / self.k)
        total_cycles = M * passes_k * loads_n * cycles_per_pass * self.L
        delay_ns = float(np.asarray(phys.delay_ns))
        energy_nJ = float(np.asarray(phys.energy_nJ))
        lat_ns = total_cycles * delay_ns
        return {
            "cycles": int(total_cycles),
            "latency_us": lat_ns * 1e-3,
            "energy_uJ": total_cycles * energy_nJ * 1e-3,
            "macs": M * K * N_out,
            "tops_effective": (2.0 * M * K * N_out) / max(lat_ns, 1e-9) * 1e-3,
            "weight_loads": loads_n * passes_k,
        }


@contextlib.contextmanager
def dcim_numerics(sim: DCIMMacroSim):
    """Route every ``models.common.dense`` matmul through ``sim``.

    Any model program *traced* inside this context — Engine prefill /
    decode, the Scheduler's slotted decode — executes its projections
    with the generated macro's numerics (bit-serial integer or
    pre-aligned block-FP) instead of the float path.  The hook is read at
    trace time, so keep the context active around the serving calls; the
    jitted programs then retain the DCIM path for their lifetime.
    """
    from repro.models import common as _common

    prev = _common.set_mvm_impl(sim.matmul)
    try:
        yield sim
    finally:
        _common.set_mvm_impl(prev)


# ------------------------------ lint contract --------------------------------
from repro.analysis.registry import (  # noqa: E402
    Built,
    ExactnessGate,
    PrecisionPolicy,
    register_contract,
)


@register_contract(
    "sim.dcim_serve",
    checks=("precision",),
    description="dcim_sim-routed serve programs traced at int8 and fp8 "
                "under a bf16 lossless-cache config: every dense MVM "
                "must provably route through the quantize->dcim_mvm/"
                "dcim_fp_matmul pipeline (zero raw fp dots in the dense "
                "island), the quantizer clip / pre-align constants must "
                "recover the core.precision bit widths, and the "
                "exactness gates must re-derive from the bf16 pool "
                "leaves",
)
def _build_dcim_serve_contract() -> Built:
    import dataclasses as _dc
    from functools import partial

    import jax

    from repro import configs
    from repro.analysis.jaxpr_tools import pytree_leaf_specs
    from repro.models import lm
    from repro.serve.scheduler import _burst_prefill_fn, _decode_paged_fn

    # bf16 compute with a bf16 (lossless, cache == compute) pool: the
    # gates claim enabled and the precision check re-derives that.
    cfg = configs.get_smoke_config("qwen2.5-3b")
    cfg = _dc.replace(
        cfg, param_dtype="bfloat16", compute_dtype="bfloat16",
        cache_dtype="bfloat16",
    )
    params = lm.init(jax.random.PRNGKey(0), cfg)
    S, page_size, pages_per_slot = 2, 8, 4
    pool = lm.init_paged_pool(
        cfg, S, S * pages_per_slot + 1, page_size
    )
    B, T = 2, 8
    decode_args = (
        params, pool,
        jnp.zeros((S,), jnp.int32), jnp.zeros((S,), jnp.int32),
        jnp.zeros((S,), jnp.bool_),
        jnp.zeros((S, pages_per_slot), jnp.int32),
        jnp.zeros((S, 2), jnp.uint32), jnp.zeros((S,), jnp.int32),
        jnp.zeros((S,), jnp.float32),
    )
    prefill_args = (
        params, pool,
        jnp.zeros((B, T), jnp.int32),
        jnp.zeros((B, pages_per_slot), jnp.int32),
        jnp.asarray([0, 1], jnp.int32),
        jnp.zeros((B,), jnp.int32),
        jnp.full((B,), T, jnp.int32),
        jnp.zeros((B, 2), jnp.uint32),
        jnp.zeros((B,), jnp.float32),
    )

    # One integer and one FP design point, macro dims matching the model
    # widths (fp group height 16 divides every reduction dim here).
    sims = {
        "int8": DCIMMacroSim(
            precision=get_precision("int8"), N=64, H=64, L=4, k=4
        ),
        "fp8": DCIMMacroSim(
            precision=get_precision("fp8"), N=64, H=16, L=4, k=4
        ),
    }
    hot_jaxprs = []
    dcim_programs = {}
    for name, sim in sims.items():
        with dcim_numerics(sim):
            decode_jaxpr = jax.make_jaxpr(
                partial(_decode_paged_fn, cfg=cfg)
            )(*decode_args)
            prefill_jaxpr = jax.make_jaxpr(partial(
                _burst_prefill_fn, cfg=cfg, page_size=page_size,
                use_context=True,
            ))(*prefill_args)
        hot_jaxprs += [
            (f"decode_{name}", decode_jaxpr),
            (f"prefill_{name}", prefill_jaxpr),
        ]
        dcim_programs[f"decode_{name}"] = name
        dcim_programs[f"prefill_{name}"] = name

    pool_leaves = pytree_leaf_specs(pool)
    gates = [
        ExactnessGate("prefix_reuse", True, "prefill_int8", pool_leaves),
        ExactnessGate("preempt", True, "decode_int8", pool_leaves),
    ]
    return Built(
        hot_jaxprs=hot_jaxprs,
        precision=PrecisionPolicy(
            compute_dtype=cfg.compute_dtype,
            dcim_programs=dcim_programs,
            gates=gates,
        ),
    )
