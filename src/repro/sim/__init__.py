"""DCIM functional simulation: bit-exact macro execution + accounting."""
from .functional import DCIMMacroSim, dcim_numerics, quantize_sym  # noqa: F401
