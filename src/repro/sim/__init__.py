"""DCIM functional simulation: bit-exact macro execution + accounting."""
from .functional import DCIMMacroSim, quantize_sym  # noqa: F401
