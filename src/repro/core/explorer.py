"""MOGA-based design space explorer (paper Fig. 4, §III-B).

Drives NSGA-II per (precision, W_store, template), merges fronts across
templates/precisions into one candidate set (re-extracting the joint
Pareto front, as the paper's "Pareto set containing both integer and
floating-point solutions"), applies *user-defined distillation*
(application constraints), and hands selected points to the
template-based generator.

Also provides the exhaustive brute-force oracle (the log2-linear storage
constraint makes the space finitely enumerable) and a distributed
*island-model* NSGA-II over a JAX mesh (`shard_map` + ring migration via
``lax.ppermute``) so the DSE itself scales to pods.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import nsga2
from .cells import CALIBRATED, CellLibrary, TechParams, TSMC28
from .macros import physical
from .pareto import pareto_front_mask
from .precision import Precision, get as get_precision
from .space import DesignSpace, N_GENES


@dataclasses.dataclass
class ParetoPoint:
    """One explored design, fully described for reports and codegen."""

    precision: str
    w_store: int
    N: int
    H: int
    L: int
    k: int
    genes: np.ndarray
    # normalized costs
    area: float
    delay: float
    energy: float
    throughput: float
    # physical metrics (calibrated tech, activity applied)
    area_mm2: float
    delay_ns: float
    energy_nJ: float
    tops: float
    tops_per_w: float
    tops_per_mm2: float

    @property
    def objectives(self) -> np.ndarray:
        return np.array(
            [self.area, self.delay, self.energy, -self.throughput], np.float32
        )

    def summary(self) -> str:
        return (
            f"{self.precision:>5} W={self.w_store:>6} N={self.N:<5} H={self.H:<5}"
            f" L={self.L:<3} k={self.k:<2} | {self.area_mm2:8.4f} mm^2"
            f" {self.delay_ns:6.2f} ns {self.energy_nJ:8.4f} nJ"
            f" {self.tops:7.3f} TOPS {self.tops_per_w:8.2f} TOPS/W"
        )


def _points_from_genes(
    space: DesignSpace,
    genes: np.ndarray,
    tech: TechParams,
    activity: float,
) -> List[ParetoPoint]:
    if genes.size == 0:
        return []
    g = jnp.asarray(genes.reshape(-1, N_GENES))
    costs = space.costs(g)
    phys = physical(costs, tech, activity)
    N, H, L, k = (np.asarray(x) for x in space.decode(g))
    out = []
    for i in range(genes.shape[0]):
        out.append(
            ParetoPoint(
                precision=space.prec.name,
                w_store=space.w_store,
                N=int(N[i]),
                H=int(H[i]),
                L=int(L[i]),
                k=int(k[i]),
                genes=np.asarray(genes[i]),
                area=float(costs.area[i]),
                delay=float(costs.delay[i]),
                energy=float(costs.energy[i]),
                throughput=float(costs.throughput[i]),
                area_mm2=float(phys.area_mm2[i]),
                delay_ns=float(phys.delay_ns[i]),
                energy_nJ=float(phys.energy_nJ[i]),
                tops=float(phys.tops[i]),
                tops_per_w=float(phys.tops_per_w[i]),
                tops_per_mm2=float(phys.tops_per_mm2[i]),
            )
        )
    return out


def brute_force_front(space: DesignSpace) -> np.ndarray:
    """Exact Pareto-optimal genomes by full enumeration (the oracle)."""
    genes = jnp.asarray(space.enumerate_feasible())
    F, v = space.evaluate(genes)
    mask = np.asarray(pareto_front_mask(F, v))
    return np.asarray(genes)[mask]


def explore(
    precision: str | Precision,
    w_store: int,
    cfg: nsga2.NSGA2Config = nsga2.NSGA2Config(),
    lib: CellLibrary = TSMC28,
    tech: TechParams = CALIBRATED,
    activity: float = 1.0,
    method: str = "nsga2",
    include_selection_mux: bool = False,
) -> List[ParetoPoint]:
    """Explore one (precision, W_store) scenario; returns its Pareto set."""
    prec = get_precision(precision) if isinstance(precision, str) else precision
    space = DesignSpace(
        prec=prec, w_store=w_store, lib=lib,
        include_selection_mux=include_selection_mux,
    )
    if method == "brute":
        fg = brute_force_front(space)
    else:
        fg = nsga2.run(space, cfg).front_genes
    return _points_from_genes(space, fg, tech, activity)


def explore_multi(
    scenarios: Sequence[tuple],
    cfg: nsga2.NSGA2Config = nsga2.NSGA2Config(),
    cross_dominate: bool = False,
    **kw,
) -> List[ParetoPoint]:
    """Union of per-scenario fronts — the paper's merged INT+FP candidate
    set handed to user distillation.

    ``scenarios`` is a list of (precision, w_store).  By default points
    of different precisions do NOT dominate each other (an INT8 design is
    not a functional substitute for a BF16 one; the paper's distillation
    step picks by application).  ``cross_dominate=True`` re-reduces the
    union to a single joint front instead.
    """
    pts: List[ParetoPoint] = []
    for prec, w in scenarios:
        pts.extend(explore(prec, w, cfg, **kw))
    if not pts or not cross_dominate:
        return pts
    F = jnp.asarray(np.stack([p.objectives for p in pts]))
    mask = np.asarray(pareto_front_mask(F))
    return [p for p, m in zip(pts, mask) if m]


def distill(
    points: Sequence[ParetoPoint],
    max_area_mm2: Optional[float] = None,
    max_power_mW: Optional[float] = None,
    max_delay_ns: Optional[float] = None,
    min_tops: Optional[float] = None,
    min_tops_per_w: Optional[float] = None,
    top: Optional[int] = None,
    sort_by: str = "edp",
) -> List[ParetoPoint]:
    """User-defined distillation (paper Fig. 4): filter the Pareto set by
    application constraints, then rank by a scalar figure of merit."""
    sel = []
    for p in points:
        power_mW = p.energy_nJ / max(p.delay_ns, 1e-12) * 1e3
        if max_area_mm2 is not None and p.area_mm2 > max_area_mm2:
            continue
        if max_power_mW is not None and power_mW > max_power_mW:
            continue
        if max_delay_ns is not None and p.delay_ns > max_delay_ns:
            continue
        if min_tops is not None and p.tops < min_tops:
            continue
        if min_tops_per_w is not None and p.tops_per_w < min_tops_per_w:
            continue
        sel.append(p)
    keyfns = {
        "edp": lambda p: p.energy_nJ * p.delay_ns,
        "area": lambda p: p.area_mm2,
        "delay": lambda p: p.delay_ns,
        "energy": lambda p: p.energy_nJ,
        "tops": lambda p: -p.tops,
        "tops_per_w": lambda p: -p.tops_per_w,
    }
    sel.sort(key=keyfns[sort_by])
    return sel[:top] if top else sel


# --------------------------------------------------------------------------
# Island-model NSGA-II: population-parallel DSE over a device mesh.
# --------------------------------------------------------------------------
def run_islands(
    space: DesignSpace,
    cfg: nsga2.NSGA2Config = nsga2.NSGA2Config(),
    mesh: Optional[Mesh] = None,
    axis: str = "data",
    rounds: int = 4,
    gens_per_round: int = 16,
    n_migrants: int = 8,
) -> nsga2.NSGA2Result:
    """NSGA-II islands, one per device along ``axis``; every round the
    best ``n_migrants`` individuals migrate along a ring
    (``lax.ppermute``) and replace the worst.  Scales the paper's DSE to
    pods with zero algorithmic drift (islands are plain NSGA-II).
    """
    if mesh is None:
        dev = np.array(jax.devices())
        mesh = Mesh(dev.reshape(-1), (axis,))
    n_isl = mesh.shape[axis]
    step = nsga2.make_step(space, cfg)

    def island_body(pop, key):
        # pop: (1, P, 3) local block -> squeeze island dim inside shard_map
        pop = pop[0]
        key = key[0]

        def one_round(carry, r):
            pop, key = carry
            key = jax.random.fold_in(key, r)
            (pop, _), visited = lax.scan(
                step, (pop, key), jnp.arange(gens_per_round)
            )
            F, v = space.evaluate(pop)
            ranks, crowd = nsga2._rank_and_crowd(F, v, cfg.use_pallas)
            crowd_c = jnp.where(jnp.isinf(crowd), 1e30, crowd)
            order = jnp.lexsort((-crowd_c, ranks))
            best = pop[order[:n_migrants]]
            if n_isl > 1:
                perm = [(i, (i + 1) % n_isl) for i in range(n_isl)]
                incoming = lax.ppermute(best, axis, perm)
            else:
                incoming = best
            pop = pop.at[order[-n_migrants:]].set(incoming)
            return (pop, key), visited.reshape(-1, N_GENES)

        (pop, _), visited = lax.scan(one_round, (pop, key), jnp.arange(rounds))
        archive = jnp.concatenate([visited.reshape(-1, N_GENES), pop], axis=0)
        return pop[None], archive[None]

    key = jax.random.PRNGKey(cfg.seed)
    keys = jax.random.split(key, n_isl)
    pops = jax.vmap(lambda k: nsga2.init_population(space, cfg, k))(keys)

    from repro.dist.compat import shard_map

    body = shard_map(
        island_body,
        mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=(P(axis), P(axis)),
        check_vma=False,
    )
    pops, archives = jax.jit(body)(pops, keys)
    pop = np.asarray(pops).reshape(-1, N_GENES)

    # Front over the union of all islands' elitist archives.
    arch = np.unique(np.asarray(archives).reshape(-1, N_GENES), axis=0)
    aF, av = space.evaluate(jnp.asarray(arch))
    mask = np.asarray(pareto_front_mask(aF, av)) & (np.asarray(av) <= 0)
    fg = arch[mask]
    fF = np.asarray(aF)[mask]

    F, v = space.evaluate(jnp.asarray(pop))
    F, v = np.asarray(F), np.asarray(v)
    ranks = np.asarray(
        pareto_front_mask(jnp.asarray(F), jnp.asarray(v))
    ) == False  # noqa: E712 - 0 for front, 1 otherwise
    return nsga2.NSGA2Result(
        genes=pop,
        objectives=F,
        violation=v,
        ranks=ranks.astype(np.int32),
        front_genes=fg,
        front_objectives=fF,
    )


def timed_explore(precision: str, w_store: int, cfg=None) -> dict:
    """DSE wall-time probe for the paper's '30 minutes per scenario' claim."""
    cfg = cfg or nsga2.NSGA2Config()
    t0 = time.perf_counter()
    pts = explore(precision, w_store, cfg)
    t1 = time.perf_counter()
    return dict(
        precision=precision,
        w_store=w_store,
        seconds=t1 - t0,
        front_size=len(pts),
    )
