"""MOGA-based design space explorer (paper Fig. 4, §III-B).

Drives NSGA-II across (precision, W_store, template) scenarios, merges
fronts across templates/precisions into one candidate set (re-extracting
the joint Pareto front, as the paper's "Pareto set containing both
integer and floating-point solutions"), applies *user-defined
distillation* (application constraints), and hands selected points to
the template-based generator.

Since the scenario-table refactor, ``explore_multi`` is *batched by
default*: scenario parameters are traced data
(:class:`repro.core.scenario.ScenarioTable`), so all S scenarios evolve
in ONE jitted program (one trace, S x P populations) instead of
re-tracing NSGA-II per scenario.  The sequential per-scenario loop is
kept (``batched=False``) as the equivalence/benchmark reference.

Also provides the exhaustive brute-force oracle (the log2-linear storage
constraint makes the space finitely enumerable) and distributed
*island-model* NSGA-II over a JAX mesh: :func:`run_islands` (one
scenario, islands along one axis) and :func:`run_islands_multi`
(scenario x island sharding on a 2-D mesh via ``repro.dist`` logical
axes; ring migration via ``lax.ppermute`` stays per-scenario).
"""
from __future__ import annotations

import dataclasses
import math
import time
from functools import partial
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from . import nsga2
from . import scenario as scen_mod
from .cells import CALIBRATED, CellLibrary, TechParams, TSMC28
from .macros import physical
from .pareto import pareto_front_mask
from .precision import Precision, get as get_precision
from .scenario import N_GENES, ScenarioTable
from .space import DesignSpace


@dataclasses.dataclass
class ParetoPoint:
    """One explored design, fully described for reports and codegen."""

    precision: str
    w_store: int
    N: int
    H: int
    L: int
    k: int
    genes: np.ndarray
    # normalized costs
    area: float
    delay: float
    energy: float
    throughput: float
    # physical metrics (calibrated tech, activity applied)
    area_mm2: float
    delay_ns: float
    energy_nJ: float
    tops: float
    tops_per_w: float
    tops_per_mm2: float

    @property
    def objectives(self) -> np.ndarray:
        return np.array(
            [self.area, self.delay, self.energy, -self.throughput], np.float32
        )

    def summary(self) -> str:
        return (
            f"{self.precision:>5} W={self.w_store:>6} N={self.N:<5} H={self.H:<5}"
            f" L={self.L:<3} k={self.k:<2} | {self.area_mm2:8.4f} mm^2"
            f" {self.delay_ns:6.2f} ns {self.energy_nJ:8.4f} nJ"
            f" {self.tops:7.3f} TOPS {self.tops_per_w:8.2f} TOPS/W"
        )


@partial(jax.jit, static_argnums=(2, 3))
def _point_metrics_jit(row, genes, tech: TechParams, activity: float):
    c = scen_mod.costs(row, genes)
    return c, physical(c, tech, activity), scen_mod.decode(row, genes)


def _points_from_genes(
    space: DesignSpace,
    genes: np.ndarray,
    tech: TechParams,
    activity: float,
    bucket: Optional[int] = None,
) -> List[ParetoPoint]:
    if genes.size == 0:
        return []
    gp, n = scen_mod.pad_to_bucket(genes.reshape(-1, N_GENES), bucket)
    costs, phys, nhlk = jax.tree.map(
        lambda a: np.asarray(a)[:n],
        _point_metrics_jit(space.scenario, jnp.asarray(gp), tech, activity),
    )
    N, H, L, k = nhlk
    out = []
    for i in range(genes.shape[0]):
        out.append(
            ParetoPoint(
                precision=space.prec.name,
                w_store=space.w_store,
                N=int(N[i]),
                H=int(H[i]),
                L=int(L[i]),
                k=int(k[i]),
                genes=np.asarray(genes[i]),
                area=float(costs.area[i]),
                delay=float(costs.delay[i]),
                energy=float(costs.energy[i]),
                throughput=float(costs.throughput[i]),
                area_mm2=float(phys.area_mm2[i]),
                delay_ns=float(phys.delay_ns[i]),
                energy_nJ=float(phys.energy_nJ[i]),
                tops=float(phys.tops[i]),
                tops_per_w=float(phys.tops_per_w[i]),
                tops_per_mm2=float(phys.tops_per_mm2[i]),
            )
        )
    return out


def _normalize_scenarios(scenarios: Sequence[tuple]) -> List[tuple]:
    out = []
    for prec, w in scenarios:
        out.append((get_precision(prec) if isinstance(prec, str) else prec, w))
    return out


def brute_force_front(space: DesignSpace) -> np.ndarray:
    """Exact Pareto-optimal genomes by full enumeration (the oracle).

    Routed through the same jitted evaluate+front program as the NSGA-II
    archive extraction (``enumerate_feasible`` only yields
    zero-violation genomes, so the feasibility mask is a no-op here)."""
    genes = space.enumerate_feasible()
    gp, n = scen_mod.pad_to_bucket(genes)
    _, _, mask = nsga2._archive_front_jit(space.scenario, jnp.asarray(gp))
    return np.asarray(genes)[np.asarray(mask)[:n]]


def explore(
    precision: str | Precision,
    w_store: int,
    cfg: nsga2.NSGA2Config = nsga2.NSGA2Config(),
    lib: CellLibrary = TSMC28,
    tech: TechParams = CALIBRATED,
    activity: float = 1.0,
    method: str = "nsga2",
    include_selection_mux: bool = False,
) -> List[ParetoPoint]:
    """Explore one (precision, W_store) scenario; returns its Pareto set.

    ``method``: ``"nsga2"`` (batched pipeline, scenario params as traced
    data), ``"nsga2-static"`` (historical one-jit-per-space reference),
    or ``"brute"`` (exhaustive oracle).
    """
    prec = get_precision(precision) if isinstance(precision, str) else precision
    space = DesignSpace(
        prec=prec, w_store=w_store, lib=lib,
        include_selection_mux=include_selection_mux,
    )
    if method == "brute":
        fg = brute_force_front(space)
    elif method == "nsga2-static":
        fg = nsga2.run_static(space, cfg).front_genes
    elif method == "nsga2":
        fg = nsga2.run(space, cfg).front_genes
    else:
        raise ValueError(f"unknown method {method!r}")
    return _points_from_genes(space, fg, tech, activity)


def explore_multi(
    scenarios: Sequence[tuple],
    cfg: nsga2.NSGA2Config = nsga2.NSGA2Config(),
    cross_dominate: bool = False,
    batched: bool = True,
    lib: CellLibrary = TSMC28,
    tech: TechParams = CALIBRATED,
    activity: float = 1.0,
    method: str = "nsga2",
    include_selection_mux: bool = False,
    store=None,
    record_name: str = "explore_multi",
) -> List[ParetoPoint]:
    """Union of per-scenario fronts — the paper's merged INT+FP candidate
    set handed to user distillation.

    ``scenarios`` is a list of (precision, w_store).  With
    ``batched=True`` (default) all scenarios run in ONE jitted NSGA-II
    (``nsga2.run_batched`` over a :class:`ScenarioTable`); with
    ``batched=False`` the historical sequential loop runs one jit per
    scenario — both produce identical fronts (tested).

    By default points of different precisions do NOT dominate each other
    (an INT8 design is not a functional substitute for a BF16 one; the
    paper's distillation step picks by application).
    ``cross_dominate=True`` re-reduces the union to a single joint front
    instead.

    ``store`` may be a :class:`repro.core.results.ResultStore`; the
    merged front and wall-time are then persisted under ``record_name``.
    """
    t0 = time.perf_counter()
    specs = _normalize_scenarios(scenarios)
    pts: List[ParetoPoint] = []
    if batched and method == "nsga2" and specs:
        table = ScenarioTable.from_specs(
            specs, lib=lib, include_selection_mux=include_selection_mux
        )
        results = nsga2.run_batched(table, cfg)
        # One padded shape for every scenario's front -> one
        # _point_metrics_jit compile for the whole batch.
        sizes = [r.front_genes.shape[0] for r in results if r.front_genes.size]
        bucket = scen_mod._bucket(max(sizes)) if sizes else None
        for (prec, w), res in zip(specs, results):
            space = DesignSpace(
                prec=prec, w_store=w, lib=lib,
                include_selection_mux=include_selection_mux,
            )
            pts.extend(
                _points_from_genes(
                    space, res.front_genes, tech, activity, bucket=bucket
                )
            )
    else:
        # Sequential reference: one (re-)jit per scenario.
        seq_method = "nsga2-static" if method == "nsga2" else method
        for prec, w in specs:
            pts.extend(
                explore(
                    prec, w, cfg, lib=lib, tech=tech, activity=activity,
                    method=seq_method,
                    include_selection_mux=include_selection_mux,
                )
            )
    if pts and cross_dominate:
        F = jnp.asarray(np.stack([p.objectives for p in pts]))
        mask = np.asarray(pareto_front_mask(F))
        pts = [p for p, m in zip(pts, mask) if m]
    if store is not None:
        from .results import front_payload

        payload = front_payload(pts)
        payload["scenarios"] = [(p.name, w) for p, w in specs]
        payload["batched"] = batched
        payload["cross_dominate"] = cross_dominate
        store.put(record_name, payload, kind="dse",
                  wall_s=time.perf_counter() - t0)
    return pts


def distill(
    points: Sequence[ParetoPoint],
    max_area_mm2: Optional[float] = None,
    max_power_mW: Optional[float] = None,
    max_delay_ns: Optional[float] = None,
    min_tops: Optional[float] = None,
    min_tops_per_w: Optional[float] = None,
    top: Optional[int] = None,
    sort_by: str = "edp",
) -> List[ParetoPoint]:
    """User-defined distillation (paper Fig. 4): filter the Pareto set by
    application constraints, then rank by a scalar figure of merit."""
    sel = []
    for p in points:
        power_mW = p.energy_nJ / max(p.delay_ns, 1e-12) * 1e3
        if max_area_mm2 is not None and p.area_mm2 > max_area_mm2:
            continue
        if max_power_mW is not None and power_mW > max_power_mW:
            continue
        if max_delay_ns is not None and p.delay_ns > max_delay_ns:
            continue
        if min_tops is not None and p.tops < min_tops:
            continue
        if min_tops_per_w is not None and p.tops_per_w < min_tops_per_w:
            continue
        sel.append(p)
    keyfns = {
        "edp": lambda p: p.energy_nJ * p.delay_ns,
        "area": lambda p: p.area_mm2,
        "delay": lambda p: p.delay_ns,
        "energy": lambda p: p.energy_nJ,
        "tops": lambda p: -p.tops,
        "tops_per_w": lambda p: -p.tops_per_w,
    }
    sel.sort(key=keyfns[sort_by])
    return sel[:top] if top else sel


# --------------------------------------------------------------------------
# Island-model NSGA-II: population-parallel DSE over a device mesh.
# --------------------------------------------------------------------------
def _island_body(row, cfg, n_isl, axis, rounds, gens_per_round, n_migrants):
    """Per-island evolution for one scenario: ``pop (P, 3), key -> (pop,
    archive)``.  Every round the best ``n_migrants`` individuals migrate
    along a ring over mesh axis ``axis`` (``lax.ppermute``) and replace
    the worst.  Shared by the single-scenario and scenario x island
    runners."""
    step = nsga2.make_step(row, cfg)

    def body(pop, key):
        def one_round(carry, r):
            pop, key = carry
            key = jax.random.fold_in(key, r)
            (pop, _), visited = lax.scan(
                step, (pop, key), jnp.arange(gens_per_round)
            )
            F, v = scen_mod.evaluate(row, pop)
            ranks, crowd = nsga2._rank_and_crowd(F, v, cfg.use_pallas)
            crowd_c = jnp.where(jnp.isinf(crowd), 1e30, crowd)
            order = jnp.lexsort((-crowd_c, ranks))
            best = pop[order[:n_migrants]]
            if n_isl > 1:
                perm = [(i, (i + 1) % n_isl) for i in range(n_isl)]
                incoming = lax.ppermute(best, axis, perm)
            else:
                incoming = best
            pop = pop.at[order[-n_migrants:]].set(incoming)
            return (pop, key), visited.reshape(-1, N_GENES)

        (pop, _), visited = lax.scan(one_round, (pop, key), jnp.arange(rounds))
        archive = jnp.concatenate([visited.reshape(-1, N_GENES), pop], axis=0)
        return pop, archive

    return body


def _islands_result(row, pops, archives) -> nsga2.NSGA2Result:
    """Pool one scenario's islands and extract the archive front."""
    pop = np.asarray(pops).reshape(-1, N_GENES)
    F, v = scen_mod.evaluate(row, jnp.asarray(pop))
    F, v = np.asarray(F), np.asarray(v)
    # 0 for the pooled population's front, 1 otherwise.
    ranks = (~np.asarray(
        pareto_front_mask(jnp.asarray(F), jnp.asarray(v))
    )).astype(np.int32)
    archive = np.concatenate(
        [np.asarray(archives).reshape(-1, N_GENES), pop], axis=0
    )
    return nsga2._extract_result(row, pop, F, v, ranks, archive)


def run_islands(
    space: DesignSpace,
    cfg: nsga2.NSGA2Config = nsga2.NSGA2Config(),
    mesh: Optional[Mesh] = None,
    axis: str = "data",
    rounds: int = 4,
    gens_per_round: int = 16,
    n_migrants: int = 8,
) -> nsga2.NSGA2Result:
    """NSGA-II islands, one per device along ``axis``; every round the
    best ``n_migrants`` individuals migrate along a ring
    (``lax.ppermute``) and replace the worst.  Scales the paper's DSE to
    pods with zero algorithmic drift (islands are plain NSGA-II).
    """
    if mesh is None:
        dev = np.array(jax.devices())
        mesh = Mesh(dev.reshape(-1), (axis,))
    n_isl = mesh.shape[axis]
    row = space.scenario
    island = _island_body(
        row, cfg, n_isl, axis, rounds, gens_per_round, n_migrants
    )

    def island_body(pop, key):
        # pop: (1, P, 3) local block -> squeeze island dim inside shard_map
        pop, archive = island(pop[0], key[0])
        return pop[None], archive[None]

    key = jax.random.PRNGKey(cfg.seed)
    keys = jax.random.split(key, n_isl)
    pops = jax.vmap(lambda k: nsga2.init_population(row, cfg, k))(keys)

    from repro.dist.compat import shard_map

    body = shard_map(
        island_body,
        mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=(P(axis), P(axis)),
        check_vma=False,
    )
    pops, archives = jax.jit(body)(pops, keys)
    return _islands_result(row, pops, archives)


def run_islands_multi(
    scenarios: Sequence[tuple] | ScenarioTable,
    cfg: nsga2.NSGA2Config = nsga2.NSGA2Config(),
    mesh: Optional[Mesh] = None,
    rounds: int = 4,
    gens_per_round: int = 16,
    n_migrants: int = 8,
    scenario_axis: str = "scenario",
    island_axis: str = "island",
) -> List[nsga2.NSGA2Result]:
    """Scenario x island sharded DSE: S scenarios, each with one NSGA-II
    island per device along ``island_axis``, scenarios sharded (and
    locally vmapped) along ``scenario_axis``.

    The 2-D mesh layout is resolved through ``repro.dist`` logical axes
    (``MeshContext`` with ``{"scenario": scenario_axis, "island":
    island_axis}`` rules) so the same code runs from a 1-chip CPU box
    (everything local, ring degenerate) to a pod slice.  Ring migration
    (``lax.ppermute``) runs over ``island_axis`` only — migration never
    crosses scenarios, keeping each scenario plain island NSGA-II.
    """
    table = (
        scenarios
        if isinstance(scenarios, ScenarioTable)
        else ScenarioTable.from_specs(_normalize_scenarios(scenarios))
    )
    S = len(table)
    if mesh is None:
        dev = np.array(jax.devices())
        s_mesh = math.gcd(S, dev.size)
        mesh = Mesh(
            dev.reshape(s_mesh, dev.size // s_mesh),
            (scenario_axis, island_axis),
        )
    from repro.dist.sharding import MeshContext

    ctx = MeshContext(
        mesh,
        rules={"scenario": (scenario_axis,), "island": (island_axis,)},
    )
    n_isl = mesh.shape[island_axis]
    if S % mesh.shape[scenario_axis]:
        raise ValueError(
            f"{S} scenarios not divisible by scenario mesh axis "
            f"{mesh.shape[scenario_axis]}"
        )

    key = jax.random.PRNGKey(cfg.seed)
    keys = jax.random.split(key, n_isl)                     # (I, 2)
    keys = jnp.broadcast_to(keys, (S,) + keys.shape)        # (S, I, 2)
    # Per-scenario gene boxes: vmap the init over scenarios x islands.
    pops = jax.vmap(
        lambda row, k: jax.vmap(
            lambda kk: nsga2.init_population(row, cfg, kk)
        )(k)
    )(table, keys)

    def shard_body(tbl, pops, keys):
        # tbl leaves: (S_loc, ...); pops/keys: (S_loc, 1, ...) — one
        # island per device along island_axis, local scenarios vmapped.
        def one_scenario(row, pop, key):
            island = _island_body(
                row, cfg, n_isl, island_axis, rounds, gens_per_round,
                n_migrants,
            )
            pop, archive = island(pop[0], key[0])
            return pop[None], archive[None]

        return jax.vmap(one_scenario)(tbl, pops, keys)

    from repro.dist.compat import shard_map

    # Logical layout via repro.dist: scenarios on scenario_axis, islands
    # on island_axis; the table's per-scenario params shard with their
    # scenario block.
    scen_spec = ctx.spec(("scenario",), (S,))
    both_spec = ctx.spec(("scenario", "island"), (S, n_isl))
    body = shard_map(
        shard_body,
        mesh=mesh,
        in_specs=(scen_spec, both_spec, both_spec),
        out_specs=(both_spec, both_spec),
        check_vma=False,
    )
    pops, archives = jax.jit(body)(table, pops, keys)
    return [
        _islands_result(table.row(i), pops[i], archives[i]) for i in range(S)
    ]


def timed_explore(precision: str, w_store: int, cfg=None) -> dict:
    """DSE wall-time probe for the paper's '30 minutes per scenario' claim."""
    cfg = cfg or nsga2.NSGA2Config()
    t0 = time.perf_counter()
    pts = explore(precision, w_store, cfg)
    t1 = time.perf_counter()
    return dict(
        precision=precision,
        w_store=w_store,
        seconds=t1 - t0,
        front_size=len(pts),
    )
