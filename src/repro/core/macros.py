"""Whole-macro cost models (paper Tables V & VI) + derived metrics.

``int_macro`` implements Table V (multiply-based integer DCIM) and
``fp_macro`` Table VI (pre-aligned floating-point DCIM).  Both broadcast
over jnp arrays, so a whole NSGA-II population (or the full enumerated
design space) is evaluated in a single call.

Outputs are NOR-normalized (area in A_gate, delay in D_gate, per-cycle
energy in E_gate).  Throughput follows the paper exactly:

    T = (N / B_w) * H * 2 * (k / B_x) * (1 / D)      [ops per gate-delay]

``physical`` converts to mm^2 / ns / nJ / TOPS / TOPS/W / TOPS/mm^2 with a
``TechParams`` calibration, including the activity (sparsity) factor the
paper applies for its Fig. 8 comparison (10% input activity).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import components as c
from . import modules as m
from .cells import CellLibrary, TechParams, TSMC28, CALIBRATED
from .precision import Precision


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MacroCosts:
    """NOR-normalized macro costs. All fields broadcast together."""

    area: jnp.ndarray        # A_gate units
    delay: jnp.ndarray       # D_gate units (critical path per cycle)
    energy: jnp.ndarray      # E_gate units (per cycle)
    throughput: jnp.ndarray  # ops per D_gate (2 ops per MAC)
    sram_bits: jnp.ndarray   # N*H*L
    # Component breakdown (normalized area) for reports/floorplanning.
    area_sram: jnp.ndarray
    area_mul: jnp.ndarray
    area_tree: jnp.ndarray
    area_accu: jnp.ndarray
    area_fusion: jnp.ndarray
    area_align: jnp.ndarray
    area_convert: jnp.ndarray

    def objectives(self) -> jnp.ndarray:
        """Stack the paper's 4 objectives [A, D, E, -T] on a last axis."""
        return jnp.stack(
            [self.area, self.delay, self.energy, -self.throughput], axis=-1
        )


def int_macro(
    N,
    H,
    L,
    k,
    B_w,
    B_x,
    lib: CellLibrary = TSMC28,
    include_selection_mux: bool = False,
) -> MacroCosts:
    """Table V — multiply-based integer DCIM.

    ``include_selection_mux=False`` reproduces the printed Table V, which
    omits the per-compute-unit L:1 weight-selection gate of Fig. 5; the
    extended model adds ``N*H*k`` L:1 muxes (one per NOR input bit).
    """
    N = jnp.asarray(N, jnp.float32)
    H = jnp.asarray(H, jnp.float32)
    L = jnp.asarray(L, jnp.float32)
    k = jnp.asarray(k, jnp.float32)
    B_w = jnp.asarray(B_w, jnp.float32)
    B_x = jnp.asarray(B_x, jnp.float32)

    a_sram = N * H * L * lib.A_SRAM
    a_mul = N * H * k * lib.A_NOR
    a_tree = N * c.tree_area(H, k, lib)
    a_accu = N * c.accu_area(B_x, H, lib)
    a_fusion = N / B_w * c.fusion_area(B_w, B_x, H, lib)

    e_mul = N * H * k * lib.E_NOR
    e_tree = N * c.tree_energy(H, k, lib)
    e_accu = N * c.accu_energy(B_x, H, lib)
    e_fusion = N / B_w * c.fusion_energy(B_w, B_x, H, lib)

    d_path = lib.D_NOR + c.tree_delay(H, k, lib) + c.accu_delay(B_x, H, lib)
    d_fusion = c.fusion_delay(B_w, B_x, H, lib)

    if include_selection_mux:
        a_mul = a_mul + N * H * m.sel_area(L, lib)
        e_mul = e_mul + N * H * m.sel_energy(L, lib)
        d_path = d_path + m.sel_delay(L, lib)

    area = a_sram + a_mul + a_tree + a_accu + a_fusion
    energy = e_mul + e_tree + e_accu + e_fusion
    delay = jnp.maximum(d_path, d_fusion)
    thpt = N / B_w * H * 2.0 * (k / B_x) / delay
    zero = jnp.zeros_like(area)

    return MacroCosts(
        area=area,
        delay=delay,
        energy=energy,
        throughput=thpt,
        sram_bits=N * H * L,
        area_sram=a_sram,
        area_mul=a_mul,
        area_tree=a_tree,
        area_accu=a_accu,
        area_fusion=a_fusion,
        area_align=zero,
        area_convert=zero,
    )


def fp_macro(
    N,
    H,
    L,
    k,
    B_w,
    B_E,
    B_M,
    lib: CellLibrary = TSMC28,
    include_selection_mux: bool = False,
) -> MacroCosts:
    """Table VI — pre-aligned floating-point DCIM.

    The integer core runs on aligned mantissas (B_x -> B_M); one
    pre-alignment unit serves the whole array (Fig. 3) and N/B_w INT->FP
    converters sit after the result-fusion units.
    """
    N = jnp.asarray(N, jnp.float32)
    B_w = jnp.asarray(B_w, jnp.float32)
    B_E = jnp.asarray(B_E, jnp.float32)
    B_M = jnp.asarray(B_M, jnp.float32)

    core = int_macro(
        N, H, L, k, B_w, B_M, lib, include_selection_mux=include_selection_mux
    )
    B_r = c.result_width(B_w, B_M, H)

    a_align = c.align_area(H, B_E, B_M, lib)
    a_convert = c.convert_area(N, B_w, B_E, B_r, lib)
    e_align = c.align_energy(H, B_E, B_M, lib)
    e_convert = c.convert_energy(N, B_w, B_E, B_r, lib)
    d_align = c.align_delay(H, B_E, B_M, lib)
    d_convert = c.convert_delay(B_E, B_r, lib)

    area = core.area + a_align + a_convert
    energy = core.energy + e_align + e_convert
    delay = jnp.maximum(jnp.maximum(d_align, core.delay), d_convert)
    thpt = N / B_w * jnp.asarray(H, jnp.float32) * 2.0 * (
        jnp.asarray(k, jnp.float32) / B_M
    ) / delay

    return MacroCosts(
        area=area,
        delay=delay,
        energy=energy,
        throughput=thpt,
        sram_bits=core.sram_bits,
        area_sram=core.area_sram,
        area_mul=core.area_mul,
        area_tree=core.area_tree,
        area_accu=core.area_accu,
        area_fusion=core.area_fusion,
        area_align=jnp.broadcast_to(a_align, area.shape),
        area_convert=jnp.broadcast_to(a_convert, area.shape),
    )


def macro_costs(
    N, H, L, k, prec: Precision, lib: CellLibrary = TSMC28, **kw
) -> MacroCosts:
    """Dispatch on precision (INT -> Table V, FP -> Table VI)."""
    if prec.is_fp:
        return fp_macro(N, H, L, k, prec.B_w, prec.B_E, prec.B_M, lib, **kw)
    return int_macro(N, H, L, k, prec.B_w, prec.B_x, lib, **kw)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PhysicalMetrics:
    area_mm2: jnp.ndarray
    delay_ns: jnp.ndarray
    energy_nJ: jnp.ndarray      # per cycle, at the given activity
    freq_GHz: jnp.ndarray
    power_mW: jnp.ndarray
    tops: jnp.ndarray
    tops_per_w: jnp.ndarray
    tops_per_mm2: jnp.ndarray


def physical(
    costs: MacroCosts,
    tech: TechParams = CALIBRATED,
    activity: float = 1.0,
) -> PhysicalMetrics:
    """Convert normalized costs to physical metrics.

    ``activity`` scales dynamic energy: the paper reports Fig. 8 at "10%
    sparsity", i.e. an input-activity factor of 0.1 on switching energy.
    """
    area_mm2 = tech.area_mm2(costs.area)
    delay_ns = tech.delay_ns(costs.delay)
    energy_nJ = tech.energy_nJ(costs.energy) * activity
    freq_GHz = 1.0 / jnp.maximum(delay_ns, 1e-9)
    power_mW = energy_nJ * freq_GHz * 1e3           # nJ/cycle * Gcycle/s
    # throughput [ops/D_gate] -> ops/s: divide by D_gate seconds.
    ops = costs.throughput / (tech.D_gate_ps * 1e-12)
    tops = ops * 1e-12
    tops_per_w = tops / jnp.maximum(power_mW * 1e-3, 1e-12)
    tops_per_mm2 = tops / jnp.maximum(area_mm2, 1e-12)
    return PhysicalMetrics(
        area_mm2=area_mm2,
        delay_ns=delay_ns,
        energy_nJ=energy_nJ,
        freq_GHz=freq_GHz,
        power_mW=power_mW,
        tops=tops,
        tops_per_w=tops_per_w,
        tops_per_mm2=tops_per_mm2,
    )
