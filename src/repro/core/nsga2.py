"""Fully-jitted NSGA-II (Deb et al. 2002) over the DCIM design space.

This is the paper's "MOGA-based design space explorer" core: 4 objectives
[A, D, E, -T], constrained domination for the storage-equality-derived
box violation, binary tournament selection, uniform crossover and
step/reset mutation on the integer log2 genome, (mu + lambda) elitist
survival.  The entire generations loop is a single ``lax.fori_loop``
inside one ``jax.jit`` — a full DSE run takes milliseconds, vs. the
paper's 30-minute budget per (precision, W_store) point.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .pareto import crowding_distance, non_dominated_sort
from .space import DesignSpace, N_GENES


@dataclasses.dataclass(frozen=True)
class NSGA2Config:
    pop_size: int = 128
    generations: int = 64
    p_crossover: float = 0.9
    p_mutate: float = 0.3
    p_step_mutate: float = 0.5   # fraction of mutations that are +/-1 steps
    seed: int = 0
    use_pallas: bool = False     # dominance matrix via the pareto_rank kernel


@dataclasses.dataclass
class NSGA2Result:
    genes: np.ndarray        # (P, 3) final population
    objectives: np.ndarray   # (P, 4)
    violation: np.ndarray    # (P,)
    ranks: np.ndarray        # (P,)
    front_genes: np.ndarray  # (F, 3) deduped feasible rank-0 set
    front_objectives: np.ndarray  # (F, 4)


def _rank_and_crowd(F, v, use_pallas: bool):
    dom = None
    if use_pallas:
        from repro.kernels import ops as kops  # lazy: core stays standalone

        dom = kops.dominance_matrix(F, v)
    ranks = non_dominated_sort(F, v, dom=dom)
    crowd = crowding_distance(F, ranks)
    return ranks, crowd


def _tournament(key, ranks, crowd, n):
    P = ranks.shape[0]
    ka, kb = jax.random.split(key)
    i = jax.random.randint(ka, (n,), 0, P)
    j = jax.random.randint(kb, (n,), 0, P)
    better_i = (ranks[i] < ranks[j]) | (
        (ranks[i] == ranks[j]) & (crowd[i] > crowd[j])
    )
    return jnp.where(better_i, i, j)


def _make_children(key, pop, ranks, crowd, cfg: NSGA2Config, lo, hi):
    P = pop.shape[0]
    ksa, ksb, kxp, kxm, kmm, kms, kmr, kmp = jax.random.split(key, 8)
    pa = pop[_tournament(ksa, ranks, crowd, P)]
    pb = pop[_tournament(ksb, ranks, crowd, P)]

    do_x = jax.random.bernoulli(kxp, cfg.p_crossover, (P, 1))
    xmask = jax.random.bernoulli(kxm, 0.5, (P, N_GENES))
    child = jnp.where(do_x & xmask, pb, pa)

    mmask = jax.random.bernoulli(kmm, cfg.p_mutate, (P, N_GENES))
    step = jax.random.randint(kms, (P, N_GENES), 0, 2) * 2 - 1
    reset = jax.random.randint(kmr, (P, N_GENES), lo[None, :], hi[None, :] + 1)
    use_step = jax.random.bernoulli(kmp, cfg.p_step_mutate, (P, N_GENES))
    mutated = jnp.where(use_step, child + step, reset)
    child = jnp.where(mmask, mutated, child)
    return jnp.clip(child, lo[None, :], hi[None, :]).astype(jnp.int32)


def _survivors(F, v, comb, P, use_pallas):
    ranks, crowd = _rank_and_crowd(F, v, use_pallas)
    crowd_c = jnp.where(jnp.isinf(crowd), 1e30, crowd)
    order = jnp.lexsort((-crowd_c, ranks))
    return comb[order[:P]]


def make_step(space: DesignSpace, cfg: NSGA2Config):
    lo = jnp.asarray(space.gene_lo)
    hi = jnp.asarray(space.gene_hi)

    def step(carry, gen):
        pop, key = carry
        key, kc = jax.random.split(jax.random.fold_in(key, gen))
        F, v = space.evaluate(pop)
        ranks, crowd = _rank_and_crowd(F, v, cfg.use_pallas)
        children = _make_children(kc, pop, ranks, crowd, cfg, lo, hi)
        comb = jnp.concatenate([pop, children], axis=0)
        Fc, vc = space.evaluate(comb)
        pop = _survivors(Fc, vc, comb, cfg.pop_size, cfg.use_pallas)
        # Children are emitted for the elitist archive: the returned front
        # is extracted from *every candidate ever evaluated*, so a design
        # visited at gen 3 and later crowded out is never lost.
        return (pop, key), children

    return step


def init_population(space: DesignSpace, cfg: NSGA2Config, key) -> jnp.ndarray:
    lo = jnp.asarray(space.gene_lo)
    hi = jnp.asarray(space.gene_hi)
    return jax.random.randint(
        key, (cfg.pop_size, N_GENES), lo[None, :], hi[None, :] + 1, jnp.int32
    )


@partial(jax.jit, static_argnums=(0, 1))
def _run_jit(space: DesignSpace, cfg: NSGA2Config, key):
    pop = init_population(space, cfg, key)
    step = make_step(space, cfg)
    (pop, _), visited = lax.scan(step, (pop, key), jnp.arange(cfg.generations))
    F, v = space.evaluate(pop)
    ranks, _ = _rank_and_crowd(F, v, cfg.use_pallas)
    archive = jnp.concatenate([visited.reshape(-1, N_GENES), pop], axis=0)
    return pop, F, v, ranks, archive


def run(space: DesignSpace, cfg: NSGA2Config = NSGA2Config()) -> NSGA2Result:
    """Run NSGA-II; the returned front is the non-dominated subset of the
    *elitist archive* (every candidate ever evaluated), deduplicated —
    a design visited early and later crowded out is never lost."""
    from .pareto import pareto_front_mask

    key = jax.random.PRNGKey(cfg.seed)
    pop, F, v, ranks, archive = _run_jit(space, cfg, key)
    pop, F, v, ranks = map(np.asarray, (pop, F, v, ranks))
    # Dedup on host, then evaluate the archive *outside* the jitted loop:
    # in-loop float32 reassociation can differ by 1 ULP, which would make
    # objectives inconsistent with external (oracle) evaluation.
    arch = np.unique(np.asarray(archive), axis=0)
    aF, av = space.evaluate(jnp.asarray(arch))
    mask = np.asarray(pareto_front_mask(aF, av)) & (np.asarray(av) <= 0.0)
    fg = arch[mask]
    fF = np.asarray(aF)[mask]
    return NSGA2Result(
        genes=pop,
        objectives=F,
        violation=v,
        ranks=ranks,
        front_genes=fg,
        front_objectives=fF,
    )


def run_unjitted(space: DesignSpace, cfg: NSGA2Config = NSGA2Config()) -> NSGA2Result:
    """Paper-faithful baseline: eager per-generation dispatch (no jit of
    the generations loop).  Identical operators and results modulo RNG
    stream; exists so EXPERIMENTS.md §Perf-DSE can quantify the win of
    compiling the whole DSE into one XLA program."""
    from .pareto import pareto_front_mask

    key = jax.random.PRNGKey(cfg.seed)
    lo = jnp.asarray(space.gene_lo)
    hi = jnp.asarray(space.gene_hi)
    pop = init_population(space, cfg, key)
    visited = [np.asarray(pop)]
    for gen in range(cfg.generations):
        key, kc = jax.random.split(jax.random.fold_in(key, gen))
        F, v = space.evaluate(pop)
        ranks, crowd = _rank_and_crowd(F, v, cfg.use_pallas)
        children = _make_children(kc, pop, ranks, crowd, cfg, lo, hi)
        comb = jnp.concatenate([pop, children], axis=0)
        Fc, vc = space.evaluate(comb)
        pop = _survivors(Fc, vc, comb, cfg.pop_size, cfg.use_pallas)
        pop.block_until_ready()
        visited.append(np.asarray(children))
    F, v = space.evaluate(pop)
    ranks, _ = _rank_and_crowd(F, v, cfg.use_pallas)

    arch = np.unique(np.concatenate(visited + [np.asarray(pop)]), axis=0)
    aF, av = space.evaluate(jnp.asarray(arch))
    mask = np.asarray(pareto_front_mask(aF, av)) & (np.asarray(av) <= 0.0)
    return NSGA2Result(
        genes=np.asarray(pop), objectives=np.asarray(F),
        violation=np.asarray(v), ranks=np.asarray(ranks),
        front_genes=arch[mask], front_objectives=np.asarray(aF)[mask],
    )
