"""Fully-jitted NSGA-II (Deb et al. 2002) over the DCIM design space.

This is the paper's "MOGA-based design space explorer" core: 4 objectives
[A, D, E, -T], constrained domination for the storage-equality-derived
box violation, binary tournament selection, uniform crossover and
step/reset mutation on the integer log2 genome, (mu + lambda) elitist
survival.  The entire generations loop is a single ``lax.scan``
inside one ``jax.jit`` — a full DSE run takes milliseconds, vs. the
paper's 30-minute budget per (precision, W_store) point.

Scenario parameters (bit-widths, bounds) are *traced data* — a
:class:`repro.core.scenario.ScenarioTable` row — so the whole algorithm
is ``vmap``-able over a leading scenario axis: :func:`run_batched`
evolves S scenarios' populations in ONE jitted program (one trace, S x P
individuals).  :func:`run_static` keeps the historical one-jit-per-space
path as the equivalence/benchmark reference, and :func:`run_unjitted`
the paper-faithful eager baseline.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import scenario as scen_mod
from .pareto import crowding_distance, non_dominated_sort, pareto_front_mask
from .scenario import N_GENES, ScenarioTable, as_row


@dataclasses.dataclass(frozen=True)
class NSGA2Config:
    pop_size: int = 128
    generations: int = 64
    p_crossover: float = 0.9
    p_mutate: float = 0.3
    p_step_mutate: float = 0.5   # fraction of mutations that are +/-1 steps
    seed: int = 0
    # Dominance matrix via the pareto_rank Pallas kernel: compiled on TPU,
    # interpreter-lowered to XLA on CPU (bit-exact either way, and tested
    # against the jnp path).  Set False to force the pure-jnp dominance.
    use_pallas: bool = True


@dataclasses.dataclass
class NSGA2Result:
    genes: np.ndarray        # (P, 3) final population
    objectives: np.ndarray   # (P, 4)
    violation: np.ndarray    # (P,)
    ranks: np.ndarray        # (P,)
    front_genes: np.ndarray  # (F, 3) deduped feasible rank-0 set
    front_objectives: np.ndarray  # (F, 4)


def _rank_and_crowd(F, v, use_pallas: bool):
    dom = None
    if use_pallas:
        from repro.kernels import ops as kops  # lazy: core stays standalone

        dom = kops.dominance_matrix(F, v)
    ranks = non_dominated_sort(F, v, dom=dom)
    crowd = crowding_distance(F, ranks)
    return ranks, crowd


def _tournament(key, ranks, crowd, n):
    P = ranks.shape[0]
    ka, kb = jax.random.split(key)
    i = jax.random.randint(ka, (n,), 0, P)
    j = jax.random.randint(kb, (n,), 0, P)
    better_i = (ranks[i] < ranks[j]) | (
        (ranks[i] == ranks[j]) & (crowd[i] > crowd[j])
    )
    return jnp.where(better_i, i, j)


def _make_children(key, pop, ranks, crowd, cfg: NSGA2Config, lo, hi):
    P = pop.shape[0]
    ksa, ksb, kxp, kxm, kmm, kms, kmr, kmp = jax.random.split(key, 8)
    pa = pop[_tournament(ksa, ranks, crowd, P)]
    pb = pop[_tournament(ksb, ranks, crowd, P)]

    do_x = jax.random.bernoulli(kxp, cfg.p_crossover, (P, 1))
    xmask = jax.random.bernoulli(kxm, 0.5, (P, N_GENES))
    child = jnp.where(do_x & xmask, pb, pa)

    mmask = jax.random.bernoulli(kmm, cfg.p_mutate, (P, N_GENES))
    step = jax.random.randint(kms, (P, N_GENES), 0, 2) * 2 - 1
    reset = jax.random.randint(kmr, (P, N_GENES), lo[None, :], hi[None, :] + 1)
    use_step = jax.random.bernoulli(kmp, cfg.p_step_mutate, (P, N_GENES))
    mutated = jnp.where(use_step, child + step, reset)
    child = jnp.where(mmask, mutated, child)
    return jnp.clip(child, lo[None, :], hi[None, :]).astype(jnp.int32)


def _survivors(F, v, comb, P, use_pallas):
    ranks, crowd = _rank_and_crowd(F, v, use_pallas)
    crowd_c = jnp.where(jnp.isinf(crowd), 1e30, crowd)
    order = jnp.lexsort((-crowd_c, ranks))
    return comb[order[:P]]


def make_step(space_or_row, cfg: NSGA2Config):
    """One NSGA-II generation as a ``lax.scan`` body.

    ``space_or_row`` may be a ``DesignSpace`` (bounds become trace
    constants, the historical behavior) or a ``ScenarioTable`` row of
    tracers (the batched path, ``vmap``-ed over scenarios)."""
    row = as_row(space_or_row)
    lo = jnp.asarray(row.gene_lo)
    hi = jnp.asarray(row.gene_hi)

    def step(carry, gen):
        pop, key = carry
        key, kc = jax.random.split(jax.random.fold_in(key, gen))
        F, v = scen_mod.evaluate(row, pop)
        ranks, crowd = _rank_and_crowd(F, v, cfg.use_pallas)
        children = _make_children(kc, pop, ranks, crowd, cfg, lo, hi)
        comb = jnp.concatenate([pop, children], axis=0)
        Fc, vc = scen_mod.evaluate(row, comb)
        pop = _survivors(Fc, vc, comb, cfg.pop_size, cfg.use_pallas)
        # Children are emitted for the elitist archive: the returned front
        # is extracted from *every candidate ever evaluated*, so a design
        # visited at gen 3 and later crowded out is never lost.
        return (pop, key), children

    return step


def init_population(space_or_row, cfg: NSGA2Config, key) -> jnp.ndarray:
    row = as_row(space_or_row)
    lo = jnp.asarray(row.gene_lo)
    hi = jnp.asarray(row.gene_hi)
    return jax.random.randint(
        key, (cfg.pop_size, N_GENES), lo[None, :], hi[None, :] + 1, jnp.int32
    )


def _evolve(row, cfg: NSGA2Config, key):
    """Init + generations scan for one scenario row.

    Shared by the batched (vmap-ed) and static (per-space jit) runners so
    both execute the identical program modulo whether scenario params are
    tracers or constants.  Final population ranking happens eagerly on
    the host (:func:`_final_ranks`): it would otherwise lower a second
    copy of the rank/crowd graph outside the scan and roughly double the
    compile time of the batched program."""
    pop = init_population(row, cfg, key)
    step = make_step(row, cfg)
    (pop, _), visited = lax.scan(step, (pop, key), jnp.arange(cfg.generations))
    F, v = scen_mod.evaluate(row, pop)
    archive = jnp.concatenate([visited.reshape(-1, N_GENES), pop], axis=0)
    return pop, F, v, archive


@partial(jax.jit, static_argnums=(2,))
def _ranks_jit(F, v, use_pallas: bool):
    dom = None
    if use_pallas:
        from repro.kernels import ops as kops

        dom = kops.dominance_matrix(F, v)
    return non_dominated_sort(F, v, dom=dom)


def _final_ranks(F, v, cfg: NSGA2Config) -> np.ndarray:
    return np.asarray(_ranks_jit(jnp.asarray(F), jnp.asarray(v), cfg.use_pallas))


@jax.jit
def _archive_front_jit(row, genes):
    """Evaluate a (bucket-padded) archive and mask its feasible Pareto
    front in one compiled program.  Padding rows are copies of row 0, so
    they change no real entry's domination status."""
    F, v = scen_mod.evaluate(row, genes)
    mask = pareto_front_mask(F, v) & (v <= 0.0)
    return F, v, mask


@partial(jax.jit, static_argnums=(1,))
def _run_batched_jit(table: ScenarioTable, cfg: NSGA2Config, keys):
    return jax.vmap(lambda row, key: _evolve(row, cfg, key))(table, keys)


@partial(jax.jit, static_argnums=(0, 1))
def _run_static_jit(space, cfg: NSGA2Config, key):
    return _evolve(space.scenario, cfg, key)


def _extract_result(
    row, pop, F, v, ranks, archive, bucket=None, deduped=False
) -> NSGA2Result:
    """Host-side front extraction from the elitist archive.

    Dedup on host, then re-evaluate the archive through the shared
    bucketed front program (``_archive_front_jit``) — the same program
    the brute-force oracle uses — instead of trusting in-loop values:
    in-loop float32 fusion can differ by 1 ULP, which would make
    objectives inconsistent with external (oracle) evaluation.

    ``bucket`` optionally pins the padded archive shape so several
    scenarios share one compile, and ``deduped=True`` skips the
    ``np.unique`` for archives the caller already deduplicated (see
    :func:`run_batched`)."""
    if deduped:
        arch = np.asarray(archive).reshape(-1, N_GENES)
    else:
        arch = np.unique(np.asarray(archive).reshape(-1, N_GENES), axis=0)
    arch_p, n = scen_mod.pad_to_bucket(arch, bucket)
    # row() slices back to numpy scalars — transfer explicitly.
    # device_put (not jnp.asarray): converting a host scalar's dtype
    # routes through convert_element_type, an *implicit* transfer that
    # jax.transfer_guard("disallow") rejects.
    row = jax.tree.map(jax.device_put, row)
    aF, av, mask = jax.tree.map(
        lambda a: np.asarray(a)[:n],
        _archive_front_jit(row, jnp.asarray(arch_p)),
    )
    return NSGA2Result(
        genes=np.asarray(pop),
        objectives=np.asarray(F),
        violation=np.asarray(v),
        ranks=np.asarray(ranks),
        front_genes=arch[mask],
        front_objectives=np.asarray(aF)[mask],
    )


def _seed_key(seed: int):
    """PRNGKey whose seed transfer is *explicit* (``device_put``).

    ``jax.random.PRNGKey(int)`` moves the seed scalar host->device
    implicitly, which trips ``jax.transfer_guard("disallow")`` — the
    transfers lint replays :func:`run_batched` under that guard."""
    return jax.random.PRNGKey(jax.device_put(np.int64(seed)))


def run_batched(
    table: ScenarioTable, cfg: NSGA2Config = NSGA2Config()
) -> List[NSGA2Result]:
    """Evolve ALL scenarios of ``table`` in one jitted, vmapped program.

    Each scenario uses the same RNG stream as a standalone
    :func:`run`/:func:`run_static` call with the same config, so the
    batched fronts match the sequential per-scenario path exactly."""
    S = len(table)
    key = _seed_key(cfg.seed)
    keys = jnp.broadcast_to(key, (S,) + key.shape)
    # Tables are built with numpy leaves; transfer them explicitly so
    # the jit call itself stays clean under jax.transfer_guard (the
    # transfers lint replays this path under "disallow").
    table = jax.tree.map(jax.device_put, table)
    out = _run_batched_jit(table, cfg, keys)
    # Extraction below is host-side (np.unique, per-scenario slicing):
    # pull the batch to host ONCE.  Indexing the device arrays per
    # scenario instead would implicitly transfer each index scalar —
    # the transfers lint runs this path under a disallow guard.
    pops, F, v, archives = (np.asarray(x) for x in out)
    # Dedup every scenario's archive first, then extract all fronts
    # through ONE padded shape: S scenarios share a single
    # ``_archive_front_jit`` compile instead of one per distinct size.
    arches = [
        np.unique(np.asarray(archives[i]).reshape(-1, N_GENES), axis=0)
        for i in range(S)
    ]
    bucket = scen_mod._bucket(max(a.shape[0] for a in arches))
    return [
        _extract_result(
            table.row(i), pops[i], F[i], v[i],
            _final_ranks(F[i], v[i], cfg), arches[i],
            bucket=bucket, deduped=True,
        )
        for i in range(S)
    ]


def run(space, cfg: NSGA2Config = NSGA2Config()) -> NSGA2Result:
    """Run NSGA-II for one scenario through the batched pipeline (S=1).

    The returned front is the non-dominated subset of the *elitist
    archive* (every candidate ever evaluated), deduplicated — a design
    visited early and later crowded out is never lost."""
    return run_batched(space.to_table(), cfg)[0]


def run_static(space, cfg: NSGA2Config = NSGA2Config()) -> NSGA2Result:
    """Historical per-scenario path: ``space`` is a *static* jit argument,
    so every distinct (precision, W_store) re-traces and re-compiles.

    Kept as the sequential reference that :func:`run_batched` is tested
    against (bit-identical fronts) and benchmarked against
    (``benchmarks/bench_dse.py``)."""
    key = jax.random.PRNGKey(cfg.seed)
    pop, F, v, archive = _run_static_jit(space, cfg, key)
    return _extract_result(
        space.scenario, pop, F, v, _final_ranks(F, v, cfg), archive
    )


def run_unjitted(space, cfg: NSGA2Config = NSGA2Config()) -> NSGA2Result:
    """Paper-faithful baseline: eager per-generation dispatch (no jit of
    the generations loop).  Identical operators and results modulo RNG
    stream; exists so EXPERIMENTS.md §Perf-DSE can quantify the win of
    compiling the whole DSE into one XLA program."""
    row = space.scenario
    key = jax.random.PRNGKey(cfg.seed)
    lo = jnp.asarray(row.gene_lo)
    hi = jnp.asarray(row.gene_hi)
    pop = init_population(row, cfg, key)
    visited = [np.asarray(pop)]
    for gen in range(cfg.generations):
        key, kc = jax.random.split(jax.random.fold_in(key, gen))
        F, v = scen_mod.evaluate(row, pop)
        ranks, crowd = _rank_and_crowd(F, v, cfg.use_pallas)
        children = _make_children(kc, pop, ranks, crowd, cfg, lo, hi)
        comb = jnp.concatenate([pop, children], axis=0)
        Fc, vc = scen_mod.evaluate(row, comb)
        pop = _survivors(Fc, vc, comb, cfg.pop_size, cfg.use_pallas)
        pop.block_until_ready()
        visited.append(np.asarray(children))
    F, v = scen_mod.evaluate(row, pop)
    ranks, _ = _rank_and_crowd(F, v, cfg.use_pallas)
    archive = np.concatenate(visited + [np.asarray(pop)])
    return _extract_result(row, pop, F, v, ranks, archive)


# ------------------------------ lint contract --------------------------------
from repro.analysis.registry import Built, Replay, register_contract  # noqa: E402


@register_contract(
    "nsga2.run_batched",
    checks=("recompile", "transfers", "precision"),
    description="batched DSE at a tiny budget: two scenario tables with "
                "equal shapes but different contents must share ONE "
                "compiled program (scenario params are traced data), "
                "the host pipeline must transfer only explicitly, and "
                "the traced evolve program must hold f32 discipline "
                "(no f64 from python-float scenario params)",
)
def _build_nsga2_contract() -> Built:
    from repro.analysis.jaxpr_tools import canonical_signature
    from repro.analysis.registry import PrecisionPolicy

    cfg = NSGA2Config(pop_size=16, generations=4)
    t1 = ScenarioTable.from_specs([("int8", 16384), ("int4", 16384)])
    # Same static metadata as t1 (all-INT => any_fp/all_fp agree), so a
    # single compiled program must serve both tables.
    t2 = ScenarioTable.from_specs([("int16", 32768), ("int2", 16384)])

    base = int(_run_batched_jit._cache_size())
    signatures = []
    key = jax.random.PRNGKey(cfg.seed)
    for t in (t1, t2):
        keys = jnp.broadcast_to(key, (len(t),) + key.shape)
        signatures.append((
            "run_batched",
            canonical_signature((jax.tree.map(jnp.asarray, t), keys)),
        ))
        run_batched(t, cfg)
    grown = int(_run_batched_jit._cache_size()) - base
    replay = Replay(
        signatures=signatures,
        max_programs={"run_batched": 1},
        live_counts={"run_batched": grown},
        live_budget={"run_batched": 1},
    )

    def hot():
        return run_batched(t1, cfg)

    keys1 = jnp.broadcast_to(key, (len(t1),) + key.shape)
    evolve_jaxpr = jax.make_jaxpr(
        lambda t, k: _run_batched_jit(t, cfg, k)
    )(jax.tree.map(jnp.asarray, t1), keys1)
    return Built(
        hot=hot, hot_label="run_batched pipeline", replay=replay,
        hot_jaxprs=[("run_batched", evolve_jaxpr)],
        precision=PrecisionPolicy(compute_dtype="float32"),
    )
