"""DCIM component cost models (paper Table IV).

Components: adder tree, shift accumulator, result-fusion unit, FP
pre-alignment, INT->FP converter.  All functions broadcast over jnp
arrays; tree summations are implemented as *static masked loops* (max
log2 H = 11 for H <= 2048, max log2 B_r = 7) so they stay jit/vmap
friendly with non-uniform H across a population.
"""
from __future__ import annotations

import jax.numpy as jnp

from . import modules as m
from .cells import CellLibrary, TSMC28

_MAX_LOG2_H = 12   # H <= 4096 covered; paper bounds H <= 2048
_MAX_LOG2_BR = 7   # B_r = B_w + B_M + log2 H <= 59 for FP32


def _log2(n):
    return jnp.log2(jnp.maximum(jnp.asarray(n, jnp.float32), 1.0))


# --- Adder tree: H k-bit inputs, levels n = 0 .. log2(H)-1 -----------------
def tree_area(H, k, lib: CellLibrary = TSMC28):
    H = jnp.asarray(H, jnp.float32)
    k = jnp.asarray(k, jnp.float32)
    lg = _log2(H)
    out = jnp.zeros(jnp.broadcast_shapes(H.shape, k.shape), jnp.float32)
    for n in range(_MAX_LOG2_H):
        mask = n < lg
        out = out + jnp.where(mask, m.add_area(k + n, lib) * H / 2.0 ** (n + 1), 0.0)
    return out


def tree_delay(H, k, lib: CellLibrary = TSMC28):
    H = jnp.asarray(H, jnp.float32)
    k = jnp.asarray(k, jnp.float32)
    lg = _log2(H)
    out = jnp.zeros(jnp.broadcast_shapes(H.shape, k.shape), jnp.float32)
    for n in range(_MAX_LOG2_H):
        mask = n < lg
        out = out + jnp.where(mask, m.add_delay(k + n, lib), 0.0)
    return out


def tree_energy(H, k, lib: CellLibrary = TSMC28):
    H = jnp.asarray(H, jnp.float32)
    k = jnp.asarray(k, jnp.float32)
    lg = _log2(H)
    out = jnp.zeros(jnp.broadcast_shapes(H.shape, k.shape), jnp.float32)
    for n in range(_MAX_LOG2_H):
        mask = n < lg
        out = out + jnp.where(mask, m.add_energy(k + n, lib) * H / 2.0 ** (n + 1), 0.0)
    return out


# --- Shift accumulator: width B = B_x + log2 H ------------------------------
def _accu_width(B_x, H):
    return jnp.asarray(B_x, jnp.float32) + _log2(H)


def accu_area(B_x, H, lib: CellLibrary = TSMC28):
    B = _accu_width(B_x, H)
    return B * lib.A_DFF + m.shift_area(B, lib) + m.add_area(B, lib)


def accu_delay(B_x, H, lib: CellLibrary = TSMC28):
    B = _accu_width(B_x, H)
    return m.shift_delay(B, lib) + m.add_delay(B, lib)


def accu_energy(B_x, H, lib: CellLibrary = TSMC28):
    B = _accu_width(B_x, H)
    return B * lib.E_DFF + m.shift_energy(B, lib) + m.add_energy(B, lib)


# --- Result-fusion unit ------------------------------------------------------
def fusion_area(B_w, B_x, H, lib: CellLibrary = TSMC28):
    B_w = jnp.asarray(B_w, jnp.float32)
    w = jnp.asarray(B_x, jnp.float32) + _log2(H)          # per-column width
    return (B_w - 1.0) * (w - 1.0) * lib.A_FA + (B_w + w - 1.0) * lib.A_HA


def fusion_delay(B_w, B_x, H, lib: CellLibrary = TSMC28):
    B_w = jnp.asarray(B_w, jnp.float32)
    w = jnp.asarray(B_x, jnp.float32) + _log2(H)
    return (w - 1.0) * lib.D_HA + (B_w - 1.0) * lib.D_FA


def fusion_energy(B_w, B_x, H, lib: CellLibrary = TSMC28):
    B_w = jnp.asarray(B_w, jnp.float32)
    w = jnp.asarray(B_x, jnp.float32) + _log2(H)
    return (B_w - 1.0) * (w - 1.0) * lib.E_FA + (B_w + w - 1.0) * lib.E_HA


# --- FP pre-alignment: comparison tree + H mantissa barrel shifters ---------
# sum_{i=1..log2 H} H/2^i == H - 1 comparators (closed form kept explicit to
# mirror Table IV).
def align_area(H, B_E, B_M, lib: CellLibrary = TSMC28):
    H = jnp.asarray(H, jnp.float32)
    return (H - 1.0) * m.comp_area(B_E, lib) + H * m.shift_area(B_M, lib)


def align_delay(H, B_E, B_M, lib: CellLibrary = TSMC28):
    return jnp.maximum(
        _log2(H) * m.comp_delay(B_E, lib), m.shift_delay(B_M, lib)
    )


def align_energy(H, B_E, B_M, lib: CellLibrary = TSMC28):
    H = jnp.asarray(H, jnp.float32)
    return (H - 1.0) * m.comp_energy(B_E, lib) + H * m.shift_energy(B_M, lib)


# --- INT -> FP converter -----------------------------------------------------
def result_width(B_w, B_M, H):
    """B_r = B_w + B_M + log2 H (paper §III-B1)."""
    return jnp.asarray(B_w, jnp.float32) + jnp.asarray(B_M, jnp.float32) + _log2(H)


def _convert_tree(B_r, a_or, a_mux):
    """sum_{l=1..log2 B_r} ((B_r/2^l - 1)*c_OR + (B_r/2^l)*c_MUX).

    B_r is generally not a power of two; the paper's sum is evaluated with
    real-valued halving up to ceil(log2 B_r) levels.
    """
    B_r = jnp.asarray(B_r, jnp.float32)
    levels = jnp.ceil(_log2(B_r))
    out = jnp.zeros_like(B_r)
    for l in range(1, _MAX_LOG2_BR + 1):
        mask = l <= levels
        frac = B_r / 2.0 ** l
        out = out + jnp.where(mask, jnp.maximum(frac - 1.0, 0.0) * a_or + frac * a_mux, 0.0)
    return out


def convert_area(N, B_w, B_E, B_r, lib: CellLibrary = TSMC28):
    N = jnp.asarray(N, jnp.float32)
    B_w = jnp.asarray(B_w, jnp.float32)
    per = _convert_tree(B_r, lib.A_OR, lib.A_MUX) + m.add_area(B_E, lib)
    return N / B_w * per


def convert_delay(B_E, B_r, lib: CellLibrary = TSMC28):
    return _log2(B_r) * (lib.D_OR + lib.D_MUX) + m.add_delay(B_E, lib)


def convert_energy(N, B_w, B_E, B_r, lib: CellLibrary = TSMC28):
    N = jnp.asarray(N, jnp.float32)
    B_w = jnp.asarray(B_w, jnp.float32)
    per = _convert_tree(B_r, lib.E_OR, lib.E_MUX) + m.add_energy(B_E, lib)
    return N / B_w * per
