"""The SEGA-DCIM design space (paper Eq. (2)/(3) + §IV bounds).

Design variables (all powers of two, as in the paper's experiments):

    N = B_w * 2^j   columns          (N > 4*B_w  =>  j >= 3)
    H = 2^h         column height    (H <= 2048)
    L = 2^l         weights / compute unit  (L <= 64)
    k = 2^kk        input bits per cycle    (k <= B_x)

The storage constraint  N*H*L = W_store*B_w  (Eq. 2; Eq. 3 with the B_M
typo corrected to the stored weight width, DESIGN.md §8.2) becomes linear
in log2:  j + h + l = log2(W_store).  The genome is (j, h, kk); ``l`` is
*derived*, so the equality constraint is satisfied by construction and
only the box bound on l can be violated (handled by Deb's
constrained-domination).  This also means the whole space is finitely
enumerable, giving an exact Pareto oracle to validate NSGA-II against.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from . import scenario as scenario_mod
from .cells import CellLibrary, TSMC28
from .macros import MacroCosts
from .precision import Precision
from .scenario import N_GENES, ScenarioTable  # noqa: F401  (re-export)


@dataclasses.dataclass(frozen=True)
class DesignSpace:
    prec: Precision
    w_store: int
    h_min_log2: int = 1          # H >= 2
    h_max_log2: int = 11         # H <= 2048 (paper §IV)
    l_max_log2: int = 6          # L <= 64   (paper §IV)
    j_min: int = 3               # N > 4*B_w (paper §IV)
    lib: CellLibrary = TSMC28
    include_selection_mux: bool = False

    def __post_init__(self):
        if self.w_store & (self.w_store - 1):
            raise ValueError(f"W_store must be a power of two, got {self.w_store}")

    @property
    def s_log2(self) -> int:
        return int(math.log2(self.w_store))

    @property
    def j_max(self) -> int:
        # j + h + l = s with h >= h_min, l >= 0.
        return self.s_log2 - self.h_min_log2

    @property
    def kk_max(self) -> int:
        return int(math.floor(math.log2(self.prec.B_x)))

    @property
    def gene_lo(self) -> np.ndarray:
        return np.array([self.j_min, self.h_min_log2, 0], np.int32)

    @property
    def gene_hi(self) -> np.ndarray:
        return np.array([self.j_max, self.h_max_log2, self.kk_max], np.int32)

    # --- the scenario row: bridge into the batched pipeline ------------------
    @property
    def scenario(self) -> ScenarioTable:
        """This space as a scalar-field :class:`ScenarioTable` row.

        Cached per instance (the space is frozen) so repeated evaluation
        reuses the same arrays and hits the same jit caches.
        """
        row = getattr(self, "_scenario_row", None)
        if row is None:
            row = scenario_mod.ScenarioTable.from_spaces([self]).row(0)
            object.__setattr__(self, "_scenario_row", row)
        return row

    def to_table(self) -> ScenarioTable:
        """This space as a 1-scenario table (leading axis kept)."""
        return scenario_mod.ScenarioTable.from_spaces([self])

    # --- decoding ----------------------------------------------------------
    def derived_l(self, genes: jnp.ndarray) -> jnp.ndarray:
        return scenario_mod.derived_l(self.scenario, genes)

    def decode(self, genes: jnp.ndarray) -> Tuple[jnp.ndarray, ...]:
        """genes (..., 3) int32 -> (N, H, L, k) float32 arrays.

        ``l`` is clamped into its box for cost evaluation; the true
        violation is reported separately by :meth:`violation`.
        """
        return scenario_mod.decode(self.scenario, genes)

    def violation(self, genes: jnp.ndarray) -> jnp.ndarray:
        return scenario_mod.violation(self.scenario, genes)

    # --- evaluation ----------------------------------------------------------
    def costs(self, genes: jnp.ndarray) -> MacroCosts:
        return scenario_mod.costs(self.scenario, genes)

    def evaluate(self, genes: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """genes (..., 3) -> (objectives (..., 4) [A, D, E, -T], violation).

        Delegates to :func:`repro.core.scenario.evaluate` — the single
        pipeline shared with the batched multi-scenario explorer."""
        return scenario_mod.evaluate(self.scenario, genes)

    # --- exhaustive oracle ----------------------------------------------------
    def enumerate_feasible(self) -> np.ndarray:
        """All feasible genomes, shape (n, 3) — the exact-design-space oracle."""
        out = []
        for j in range(self.j_min, self.j_max + 1):
            for h in range(self.h_min_log2, self.h_max_log2 + 1):
                l = self.s_log2 - j - h
                if not (0 <= l <= self.l_max_log2):
                    continue
                for kk in range(0, self.kk_max + 1):
                    out.append((j, h, kk))
        if not out:
            raise ValueError(
                f"design space empty for {self.prec.name}, W_store={self.w_store}"
            )
        return np.asarray(out, np.int32)

    def describe(self, genes: np.ndarray) -> dict:
        """Human-readable design point for reports / the generator."""
        g = np.asarray(genes).reshape(3)
        N, H, L, k = (int(float(x)) for x in self.decode(jnp.asarray(g)))
        return dict(
            precision=self.prec.name,
            w_store=self.w_store,
            N=N,
            H=H,
            L=L,
            k=k,
            B_w=self.prec.B_w,
            B_x=self.prec.B_x,
            B_E=self.prec.B_E,
            sram_bits=N * H * L,
        )
