"""SEGA-DCIM core: cost models, design space, NSGA-II explorer."""
from . import cells, components, explorer, macros, modules, nsga2, pareto, precision, results, scenario, space  # noqa: F401
from .cells import CALIBRATED, CellLibrary, TechParams, TSMC28  # noqa: F401
from .explorer import ParetoPoint, brute_force_front, distill, explore, explore_multi, run_islands, run_islands_multi  # noqa: F401
from .macros import MacroCosts, fp_macro, int_macro, macro_costs, physical  # noqa: F401
from .nsga2 import NSGA2Config, NSGA2Result  # noqa: F401
from .precision import Precision  # noqa: F401
from .results import ResultStore  # noqa: F401
from .scenario import ScenarioTable  # noqa: F401
from .space import DesignSpace  # noqa: F401
