"""Digital logic module cost models (paper Table II).

Every function is pure jnp and broadcasts over arbitrary array shapes so
the whole design space can be evaluated in one vmap/vectorized call.
``N`` arguments may be any positive value (the paper's formulas use real
``log2 N``; the explorer only ever passes powers of two).

Cost triplets are returned as ``(area, delay, energy)`` in NOR-gate
normalized units (see cells.py).
"""
from __future__ import annotations

import jax.numpy as jnp

from .cells import CellLibrary, TSMC28


def _log2(n):
    return jnp.log2(jnp.maximum(jnp.asarray(n, jnp.float32), 1.0))


# --- 1-bit x N-bit multiplier (k NOR gates, Fig. 5) -----------------------
def mul_area(N, lib: CellLibrary = TSMC28):
    return jnp.asarray(N, jnp.float32) * lib.A_NOR


def mul_delay(N, lib: CellLibrary = TSMC28):
    return jnp.full_like(jnp.asarray(N, jnp.float32), lib.D_NOR)


def mul_energy(N, lib: CellLibrary = TSMC28):
    return jnp.asarray(N, jnp.float32) * lib.E_NOR


# --- N-bit ripple-carry adder ---------------------------------------------
def add_area(N, lib: CellLibrary = TSMC28):
    N = jnp.asarray(N, jnp.float32)
    return (N - 1.0) * lib.A_FA + lib.A_HA


def add_delay(N, lib: CellLibrary = TSMC28):
    N = jnp.asarray(N, jnp.float32)
    return (N - 1.0) * lib.D_FA + lib.D_HA


def add_energy(N, lib: CellLibrary = TSMC28):
    N = jnp.asarray(N, jnp.float32)
    return (N - 1.0) * lib.E_FA + lib.E_HA


# --- N:1 mux ---------------------------------------------------------------
def sel_area(N, lib: CellLibrary = TSMC28):
    N = jnp.asarray(N, jnp.float32)
    return (N - 1.0) * lib.A_MUX


def sel_delay(N, lib: CellLibrary = TSMC28):
    return _log2(N) * lib.D_MUX


def sel_energy(N, lib: CellLibrary = TSMC28):
    N = jnp.asarray(N, jnp.float32)
    return (N - 1.0) * lib.E_MUX


# --- N-bit barrel shifter (N parallel N:1 muxes) ---------------------------
def shift_area(N, lib: CellLibrary = TSMC28):
    N = jnp.asarray(N, jnp.float32)
    return N * sel_area(N, lib)


def shift_delay(N, lib: CellLibrary = TSMC28):
    if lib.shifter_delay_model == "mux_tree":
        return sel_delay(N, lib)
    # As printed in Table II: (log2 N) * D_sel(N) == (log2 N)^2 * D_MUX.
    return _log2(N) * sel_delay(N, lib)


def shift_energy(N, lib: CellLibrary = TSMC28):
    N = jnp.asarray(N, jnp.float32)
    return N * sel_energy(N, lib)


# --- N-bit comparator (simplified to an adder, paper §III-B1) ---------------
def comp_area(N, lib: CellLibrary = TSMC28):
    return add_area(N, lib)


def comp_delay(N, lib: CellLibrary = TSMC28):
    return add_delay(N, lib)


def comp_energy(N, lib: CellLibrary = TSMC28):
    return add_energy(N, lib)
