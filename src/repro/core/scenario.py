"""Array-valued scenario parameters: the batched DSE evaluation pipeline.

Historically every (precision, W_store) scenario was a frozen
``DesignSpace`` whose bit-widths and bounds were *Python closure
constants*, so ``jax.jit`` specialized one XLA program per scenario and
``explore_multi`` re-traced/re-compiled NSGA-II ``S`` times.  A
:class:`ScenarioTable` lifts those constants into stacked ``(S,)``
arrays — precision bit-widths, the log2 storage budget, derived-gene
bounds — so scenario parameters become *traced data*: one program
evaluates (and evolves, via ``jax.vmap`` in ``nsga2.run_batched``) all
scenarios at once.

Everything here is shape-polymorphic over the scenario prefix: table
fields may be ``(S,)`` arrays (whole-table evaluation), scalars (a
single row, e.g. under ``vmap`` or from ``DesignSpace.scenario``), or
any leading shape in between.  ``DesignSpace.evaluate`` delegates to
:func:`evaluate`, so the sequential, batched, brute-force-oracle and
island paths all share ONE evaluation pipeline.  Host-facing consumers
go through :func:`evaluate_host`, which buckets gene sets to
power-of-two shapes so a handful of compiled evaluate+front programs
serve the archive, the oracle and the explorer alike.

Downstream of the front: ``dcimmap.plan`` provisions the winning design
for a whole architecture, ``sim.DCIMMacroSim`` executes its numerics,
and the serving stack (``repro.serve``, paged KV cache + shared-prefix
reuse) evaluates it against token traffic — see docs/architecture.md
for the full DSE -> codegen -> sim -> models -> serve flow and
docs/dse.md for the batched-DSE API.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .cells import CellLibrary, TSMC28
from .macros import MacroCosts, fp_macro, int_macro
from .precision import Precision, get as get_precision

N_GENES = 3  # (j, h, kk)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ScenarioTable:
    """Stacked per-scenario cost-model parameters and genome bounds.

    Data fields carry a leading scenario prefix (``(S,)`` for a table,
    ``()`` for a row); metadata fields are static and must be uniform
    across the scenarios of one table (they select the trace, not the
    data).
    """

    # --- traced data (leading scenario prefix) -----------------------------
    b_w: jnp.ndarray          # int32 — weight bits held in the SRAM array
    b_x: jnp.ndarray          # int32 — streamed input bits (B_M for FP)
    b_e: jnp.ndarray          # int32 — exponent bits (0 for INT)
    is_fp: jnp.ndarray        # bool  — FP (Table VI) vs INT (Table V)
    s_log2: jnp.ndarray       # int32 — log2(W_store)
    l_max_log2: jnp.ndarray   # int32 — box bound on the derived gene l
    gene_lo: jnp.ndarray      # int32 (..., 3)
    gene_hi: jnp.ndarray      # int32 (..., 3)
    # --- static metadata ---------------------------------------------------
    lib: CellLibrary = dataclasses.field(
        metadata=dict(static=True), default=TSMC28
    )
    include_selection_mux: bool = dataclasses.field(
        metadata=dict(static=True), default=False
    )
    # Whether any/all scenarios are floating point — static so INT-only
    # (or FP-only) tables trace exactly the single-dispatch cost model.
    any_fp: bool = dataclasses.field(metadata=dict(static=True), default=False)
    all_fp: bool = dataclasses.field(metadata=dict(static=True), default=False)

    # --- construction ------------------------------------------------------
    @classmethod
    def from_specs(
        cls,
        scenarios: Sequence[tuple],
        lib: CellLibrary = TSMC28,
        include_selection_mux: bool = False,
        **space_kw,
    ) -> "ScenarioTable":
        """Build from ``[(precision, w_store), ...]`` pairs (the
        ``explore_multi`` scenario list)."""
        from .space import DesignSpace  # lazy: space.py imports this module

        spaces = [
            DesignSpace(
                prec=get_precision(p) if isinstance(p, str) else p,
                w_store=w,
                lib=lib,
                include_selection_mux=include_selection_mux,
                **space_kw,
            )
            for p, w in scenarios
        ]
        return cls.from_spaces(spaces)

    @classmethod
    def from_spaces(cls, spaces: Sequence) -> "ScenarioTable":
        """Stack ``DesignSpace`` instances into one table.

        Static knobs (cell library, selection-mux model) must agree: they
        pick the compiled program, not per-scenario data.
        """
        if not spaces:
            raise ValueError("at least one scenario required")
        lib = spaces[0].lib
        mux = spaces[0].include_selection_mux
        for sp in spaces:
            if sp.lib != lib or sp.include_selection_mux != mux:
                raise ValueError(
                    "all scenarios of one table must share lib and "
                    "include_selection_mux (these are static metadata)"
                )
        # Fields are host numpy arrays: concrete even when the table is
        # built under an active jit trace (e.g. the cached
        # ``DesignSpace.scenario`` property inside ``nsga2.run_static``);
        # jax converts them to device constants at first use.
        i32 = lambda xs: np.asarray(xs, np.int32)  # noqa: E731
        fps = [bool(sp.prec.is_fp) for sp in spaces]
        return cls(
            b_w=i32([sp.prec.B_w for sp in spaces]),
            b_x=i32([sp.prec.B_x for sp in spaces]),
            b_e=i32([sp.prec.B_E for sp in spaces]),
            is_fp=np.asarray(fps, np.bool_),
            s_log2=i32([sp.s_log2 for sp in spaces]),
            l_max_log2=i32([sp.l_max_log2 for sp in spaces]),
            gene_lo=np.stack([sp.gene_lo for sp in spaces]).astype(np.int32),
            gene_hi=np.stack([sp.gene_hi for sp in spaces]).astype(np.int32),
            lib=lib,
            include_selection_mux=mux,
            any_fp=any(fps),
            all_fp=all(fps),
        )

    # --- shape helpers ------------------------------------------------------
    def __len__(self) -> int:
        return int(np.shape(self.b_w)[0]) if np.ndim(self.b_w) else 1

    def row(self, i: int) -> "ScenarioTable":
        """Scalar-field view of scenario ``i``.

        Indexes on the host (numpy) so the row stays concrete even when
        first accessed under an active jit trace (e.g. the cached
        ``DesignSpace.scenario`` property inside ``nsga2.run_static``)."""
        return jax.tree.map(lambda a: np.asarray(a)[i], self)


def as_row(space_or_row):
    """Coerce a ``DesignSpace`` (or pass through a table/row) for the
    row-wise entry points below."""
    if isinstance(space_or_row, ScenarioTable):
        return space_or_row
    return space_or_row.scenario  # DesignSpace's cached scalar row


def _pref(x, genes: jnp.ndarray) -> jnp.ndarray:
    """Right-pad a scenario-prefix field with singleton axes so it
    broadcasts against per-genome arrays derived from ``genes`` (shape
    ``prefix + pop_dims + (N_GENES,)``)."""
    x = jnp.asarray(x)
    return x.reshape(x.shape + (1,) * (genes.ndim - 1 - x.ndim))


# --- decoding ----------------------------------------------------------------
def derived_l(table: ScenarioTable, genes: jnp.ndarray) -> jnp.ndarray:
    """The storage-equality-derived gene: l = log2(W_store) - j - h."""
    return _pref(table.s_log2, genes) - genes[..., 0] - genes[..., 1]


def decode(table: ScenarioTable, genes: jnp.ndarray) -> Tuple[jnp.ndarray, ...]:
    """genes ``(..., 3)`` int32 -> (N, H, L, k) float32 arrays.

    ``l`` is clamped into its box for cost evaluation; the true violation
    is reported separately by :func:`violation`.
    """
    one = jnp.int32(1)
    j = genes[..., 0].astype(jnp.int32)
    h = genes[..., 1].astype(jnp.int32)
    l = jnp.clip(
        derived_l(table, genes).astype(jnp.int32),
        0,
        _pref(table.l_max_log2, genes),
    )
    kk = genes[..., 2].astype(jnp.int32)
    # Integer bit-shifts: jnp.exp2 is inexact on some backends.
    N = (_pref(table.b_w, genes).astype(jnp.int32) * (one << j)).astype(
        jnp.float32
    )
    return (
        N,
        (one << h).astype(jnp.float32),
        (one << l).astype(jnp.float32),
        (one << kk).astype(jnp.float32),
    )


def violation(table: ScenarioTable, genes: jnp.ndarray) -> jnp.ndarray:
    l = derived_l(table, genes).astype(jnp.float32)
    l_max = _pref(table.l_max_log2, genes).astype(jnp.float32)
    return jnp.maximum(-l, 0.0) + jnp.maximum(l - l_max, 0.0)


# --- evaluation --------------------------------------------------------------
def costs(table: ScenarioTable, genes: jnp.ndarray) -> MacroCosts:
    """Whole-macro costs with scenario parameters as traced data.

    INT-only / FP-only tables trace exactly the corresponding Table V /
    Table VI model; mixed tables compute both and select per scenario
    (the models share the integer core, so the overhead is the small FP
    pre-align/convert term).
    """
    N, H, L, k = decode(table, genes)
    b_w = _pref(table.b_w, genes).astype(jnp.float32)
    b_x = _pref(table.b_x, genes).astype(jnp.float32)
    b_e = _pref(table.b_e, genes).astype(jnp.float32)
    kw = dict(lib=table.lib, include_selection_mux=table.include_selection_mux)
    if not table.any_fp:
        return int_macro(N, H, L, k, b_w, b_x, **kw)
    if table.all_fp:
        return fp_macro(N, H, L, k, b_w, b_e, b_x, **kw)
    ci = int_macro(N, H, L, k, b_w, b_x, **kw)
    cf = fp_macro(N, H, L, k, b_w, b_e, b_x, **kw)
    fp = _pref(table.is_fp, genes)
    pick = lambda a, b: jnp.where(fp, a, b)  # noqa: E731
    return MacroCosts(
        **{
            f.name: pick(getattr(cf, f.name), getattr(ci, f.name))
            for f in dataclasses.fields(MacroCosts)
        }
    )


def evaluate(
    table: ScenarioTable, genes: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """genes ``(..., 3)`` -> (objectives ``(..., 4)`` [A, D, E, -T],
    violation ``(...,)``) — THE evaluation pipeline: every consumer
    (sequential, batched, islands, brute-force oracle) routes through
    here."""
    return costs(table, genes).objectives(), violation(table, genes)


# --- host-side (out-of-loop) evaluation --------------------------------------
@jax.jit
def _evaluate_jit(row: ScenarioTable, genes: jnp.ndarray):
    return evaluate(row, genes)


def _bucket(n: int) -> int:
    """Next power of two: pads host-side gene sets to a handful of shapes
    so the jitted evaluation compiles once, not once per archive size."""
    return 1 << max(int(math.ceil(math.log2(max(n, 1)))), 0)


def pad_to_bucket(
    genes: np.ndarray, bucket: int | None = None
) -> Tuple[np.ndarray, int]:
    """Pad ``genes`` to ``bucket`` rows (default: next power of two).

    Callers evaluating several gene sets back-to-back can pass one shared
    ``bucket`` (>= every set's length) so all sets hit the SAME compiled
    shape — one jit compile instead of one per distinct size.  Padding
    rows are copies of row 0: evaluation is elementwise per row, so they
    change no real entry's values or domination status."""
    genes = np.asarray(genes).reshape(-1, N_GENES)
    n = genes.shape[0]
    if bucket is None:
        bucket = _bucket(n)
    elif bucket < n:
        raise ValueError(f"bucket {bucket} < {n} rows")
    pad = bucket - n
    if pad:
        genes = np.concatenate([genes, np.repeat(genes[:1], pad, axis=0)])
    return genes, n


def evaluate_host(
    row: ScenarioTable, genes: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """Jitted, shape-bucketed evaluation for host-side consumers (archive
    fronts, the brute-force oracle): genes ``(n, 3)`` -> numpy
    ``(F (n, 4), v (n,))``.

    Rows are *data* to the jit, so all scenarios of a table — and every
    same-bucket archive — share one compiled program instead of paying
    eager per-op dispatch."""
    gp, n = pad_to_bucket(genes)
    F, v = _evaluate_jit(row, jnp.asarray(gp))
    return np.asarray(F)[:n], np.asarray(v)[:n]
