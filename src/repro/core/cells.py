"""Standard-cell cost table (paper Table III) and physical calibration.

All area/delay/energy figures are *normalized to a NOR gate* of the
TSMC28 digital PDK, exactly as the paper does.  ``TechParams`` carries
the three physical scalars (A_gate, D_gate, E_gate) that convert
normalized costs to um^2 / ps / fJ; they are calibrated against the
paper's published anchor points in ``benchmarks/bench_calibration.py``
(the PDK itself is not available in this environment — see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CellLibrary:
    """Table III — costs normalized to a NOR gate (A_gate/D_gate/E_gate)."""

    A_NOR: float = 1.0
    D_NOR: float = 1.0
    E_NOR: float = 1.0

    A_OR: float = 1.3
    D_OR: float = 1.0
    E_OR: float = 2.3

    A_MUX: float = 2.2
    D_MUX: float = 2.2
    E_MUX: float = 3.0

    A_HA: float = 4.3
    D_HA: float = 2.5
    E_HA: float = 6.9

    A_FA: float = 5.7
    D_FA: float = 3.3
    E_FA: float = 8.4

    A_DFF: float = 6.6
    E_DFF: float = 9.6          # DFF delay is N/A in the paper (pipelined)

    A_SRAM: float = 2.2         # 6T cell, hard-wired read: D = E = 0
    D_SRAM: float = 0.0
    E_SRAM: float = 0.0

    # Shifter delay model. The paper's Table II prints
    #   D_shift(N) = (log2 N) * D_sel(N)  ==  (log2 N)^2 * D_MUX
    # which double-counts the mux-tree depth of a barrel shifter whose
    # area is N * A_sel(N).  "as_printed" reproduces the paper;
    # "mux_tree" uses the physically-consistent D_sel(N).  (DESIGN.md §8.3)
    shifter_delay_model: str = "as_printed"


TSMC28 = CellLibrary()


@dataclasses.dataclass(frozen=True)
class TechParams:
    """Physical normalization constants for one technology node.

    Calibrated against the paper's anchors (DESIGN.md §7):
      * A_gate: INT8/8K-weight macro layout area = 0.079 mm^2 (Fig. 6a)
      * D_gate: 64K design-space average delays 1.2 ns (INT2) .. 10.9 ns
        (FP32) (Fig. 7c)
      * E_gate: design A (INT8, 64K) = 22 TOPS/W at 0.9 V, 10% activity
        (Fig. 8a)
    """

    name: str = "tsmc28-calibrated"
    # Fitted by benchmarks/bench_calibration.py against the paper's
    # anchors (Fig. 6a, Fig. 7c endpoints, design A TOPS/W); all other
    # published numbers are held-out validations — see EXPERIMENTS.md.
    A_gate_um2: float = 0.4260  # NOR2 footprint, um^2
    D_gate_ps: float = 33.46    # NOR2 prop delay, ps
    E_gate_fJ: float = 0.4282   # NOR2 switching energy, fJ
    voltage: float = 0.9        # supply used in the paper's Fig. 8

    def area_mm2(self, a_norm):
        """Normalized area -> mm^2."""
        return a_norm * self.A_gate_um2 * 1e-6

    def delay_ns(self, d_norm):
        """Normalized delay -> ns."""
        return d_norm * self.D_gate_ps * 1e-3

    def energy_nJ(self, e_norm):
        """Normalized per-cycle energy -> nJ."""
        return e_norm * self.E_gate_fJ * 1e-6

    def with_(self, **kw) -> "TechParams":
        return dataclasses.replace(self, **kw)


# Frozen calibration — fitted once by benchmarks/bench_calibration.py and
# then used for every EXPERIMENTS.md claim check.  See EXPERIMENTS.md §Repro.
CALIBRATED = TechParams()
