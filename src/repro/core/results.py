"""Tiny JSON result persistence shared by the explorer, dry-runs and
benchmarks.

A *record* is a plain JSON-able dict.  :class:`ResultStore` keeps one
record per name under a root directory (``<root>/<name>.json``), written
atomically, with a small ``_record`` envelope (name / kind / wall-time /
creation time) merged in so downstream tooling can inventory runs
without knowing each producer's schema.  Consumers that predate the
store (e.g. ``launch.roofline.analyze_record``) keep working: payload
keys stay at the top level.

``to_jsonable`` normalizes numpy scalars/arrays, dataclasses, paths and
sets so producers can hand over raw result objects (Pareto fronts,
roofline rows) without per-site conversion boilerplate.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import time
from typing import Any, Dict, Iterator, List, Optional

import numpy as np


def to_jsonable(obj: Any) -> Any:
    """Recursively convert ``obj`` into JSON-serializable primitives."""
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return to_jsonable(obj.tolist())
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return to_jsonable(dataclasses.asdict(obj))
    if isinstance(obj, dict):
        return {str(k): to_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple, set, frozenset)):
        return [to_jsonable(v) for v in obj]
    if isinstance(obj, pathlib.Path):
        return str(obj)
    if hasattr(obj, "tolist"):  # jax arrays and other array-likes
        return to_jsonable(obj.tolist())
    return str(obj)


def dump_json(path: os.PathLike | str, record: Dict[str, Any]) -> pathlib.Path:
    """Atomic JSON write (tmp file + rename) with numpy-safe encoding."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(path.suffix + ".tmp")
    tmp.write_text(json.dumps(to_jsonable(record), indent=2, sort_keys=False))
    os.replace(tmp, path)
    return path


class ResultStore:
    """One JSON record per name under ``root`` (``<root>/<name>.json``)."""

    def __init__(self, root: os.PathLike | str):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    def path(self, name: str) -> pathlib.Path:
        if "/" in name or name.startswith("."):
            raise ValueError(f"record names must be flat, got {name!r}")
        return self.root / f"{name}.json"

    def __contains__(self, name: str) -> bool:
        return self.path(name).exists()

    def names(self) -> List[str]:
        return sorted(p.stem for p in self.root.glob("*.json"))

    def put(
        self,
        name: str,
        payload: Dict[str, Any],
        kind: str = "record",
        wall_s: Optional[float] = None,
    ) -> pathlib.Path:
        """Persist ``payload`` (top-level keys preserved) with a
        ``_record`` envelope merged in."""
        rec = dict(payload)
        rec["_record"] = {
            "name": name,
            "kind": kind,
            "wall_s": wall_s,
            "created_unix": time.time(),
        }
        return dump_json(self.path(name), rec)

    def get(self, name: str) -> Dict[str, Any]:
        return json.loads(self.path(name).read_text())

    def records(self) -> Iterator[Dict[str, Any]]:
        for name in self.names():
            yield self.get(name)


def front_payload(points) -> Dict[str, Any]:
    """Serialize a list of ``explorer.ParetoPoint`` into a record payload
    (shared by ``explore_multi``, benchmarks and reports)."""
    return {
        "n_points": len(points),
        "points": [to_jsonable(p) for p in points],
    }
