"""Pareto utilities: dominance, non-dominated sorting, crowding, hypervolume.

Implements Eq. (1) of the paper (Pareto dominance in a minimization
context) plus Deb's constrained-domination rule used by the NSGA-II
explorer.  Everything is jit/vmap friendly; the O(P^2 M) dominance matrix
can alternatively be produced by the ``pareto_rank`` Pallas kernel
(kernels/pareto_rank.py) — both paths are tested against each other.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def _sanitize(F: jnp.ndarray) -> jnp.ndarray:
    """Replace NaN with +inf so broken candidates lose every comparison."""
    return jnp.where(jnp.isnan(F), jnp.inf, F)


def dominates(u: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Eq. (1): u pareto-dominates v (minimization), broadcasting on the
    leading axes; objectives are on the last axis."""
    le = jnp.all(u <= v, axis=-1)
    lt = jnp.any(u < v, axis=-1)
    return le & lt


def dominance_matrix(F: jnp.ndarray, violation: jnp.ndarray | None = None) -> jnp.ndarray:
    """D[i, j] == True iff candidate i (constrained-)dominates candidate j.

    Constrained domination (Deb 2002): a feasible point dominates any
    infeasible point; among infeasible points, smaller total violation
    dominates; among feasible points, plain Pareto dominance applies.
    """
    F = _sanitize(F)
    pd = dominates(F[:, None, :], F[None, :, :])
    if violation is None:
        return pd
    v = jnp.asarray(violation, jnp.float32)
    feas_i = (v <= 0.0)[:, None]
    feas_j = (v <= 0.0)[None, :]
    both_feas = feas_i & feas_j
    return (both_feas & pd) | (v[:, None] < v[None, :])


def pareto_front_mask(F: jnp.ndarray, violation: jnp.ndarray | None = None) -> jnp.ndarray:
    """Boolean mask of globally non-dominated candidates."""
    D = dominance_matrix(F, violation)
    return ~jnp.any(D, axis=0)


def non_dominated_sort(
    F: jnp.ndarray,
    violation: jnp.ndarray | None = None,
    dom: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Front ranks (0 = best) by iterative peeling of the dominance matrix.

    ``dom`` may be supplied (e.g. from the Pallas kernel) to skip the
    in-graph matrix construction.
    """
    P = F.shape[0]
    D = dominance_matrix(F, violation) if dom is None else dom

    def cond(state):
        ranks, r = state
        return (r < P) & jnp.any(ranks >= P)

    def body(state):
        ranks, r = state
        unassigned = ranks >= P
        dom_cnt = jnp.sum(D & unassigned[:, None], axis=0)
        front = unassigned & (dom_cnt == 0)
        return jnp.where(front, r, ranks), r + 1

    ranks0 = jnp.full((P,), P, jnp.int32)
    ranks, _ = lax.while_loop(cond, body, (ranks0, jnp.int32(0)))
    return ranks


def crowding_distance(F: jnp.ndarray, ranks: jnp.ndarray) -> jnp.ndarray:
    """NSGA-II crowding distance, computed per front (objective ranges are
    normalized within each front).  Boundary points get +inf."""
    F = _sanitize(F)
    P, M = F.shape
    big = jnp.where(jnp.isinf(F), jnp.nan, F)
    # Per-front objective ranges via segment reductions keyed by rank.
    fmin = jax.ops.segment_min(F, ranks, num_segments=P)
    fmax = jax.ops.segment_max(F, ranks, num_segments=P)
    rng = jnp.maximum((fmax - fmin)[ranks], 1e-12)   # (P, M)
    del big

    pos = jnp.arange(P)
    d = jnp.zeros((P,), jnp.float32)
    for mth in range(M):
        order = jnp.lexsort((F[:, mth], ranks))
        f_s = F[order, mth]
        r_s = ranks[order]
        same_prev = (jnp.roll(r_s, 1) == r_s) & (pos > 0)
        same_next = (jnp.roll(r_s, -1) == r_s) & (pos < P - 1)
        gap = jnp.roll(f_s, -1) - jnp.roll(f_s, 1)
        contrib = jnp.where(
            same_prev & same_next,
            gap / rng[order, mth],
            jnp.inf,
        )
        d = d.at[order].add(contrib.astype(jnp.float32))
    return d


def hypervolume_mc(
    F: jnp.ndarray,
    ref: jnp.ndarray,
    key: jax.Array,
    n_samples: int = 200_000,
) -> jnp.ndarray:
    """Monte-Carlo hypervolume (minimization, w.r.t. reference point ``ref``).

    Used as a front-quality metric when comparing NSGA-II to the
    brute-force oracle; exact HV in 4D is unnecessary for that purpose.
    """
    F = _sanitize(F)
    lo = jnp.min(F, axis=0)
    box = jnp.maximum(ref - lo, 1e-12)
    u = jax.random.uniform(key, (n_samples, F.shape[-1]))
    pts = lo + u * box
    dominated = jnp.any(jnp.all(F[None, :, :] <= pts[:, None, :], axis=-1), axis=1)
    return jnp.mean(dominated) * jnp.prod(box)
