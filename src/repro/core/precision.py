"""Precision formats supported by SEGA-DCIM (paper §I, §IV).

The paper evaluates INT2/4/8/16 and FP8/FP16/BF16/FP32. For integer
formats the DCIM stores the full two's-complement weight (``B_w`` bits)
and streams ``B_x``-bit inputs ``k`` bits per cycle. For floating-point
formats the *pre-aligned* architecture stores the weight mantissa
(including the hidden bit) as an integer of width ``B_w = mantissa+1``
and streams the aligned input mantissa (``B_M = mantissa+1`` bits), while
exponents (``B_E`` bits) only traverse the pre-alignment comparison tree
and the INT->FP converter.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class Precision:
    """A numeric format as seen by the DCIM cost model."""

    name: str
    is_fp: bool
    # INT: B_w == B_x == bits.  FP: B_w = stored weight mantissa width
    # (mantissa bits + hidden bit), B_x == B_M = input mantissa width,
    # B_E = exponent width.
    bits: int          # total storage bits of the *external* format
    B_w: int           # weight bits held in the SRAM array
    B_x: int           # input bits streamed through the input buffer
    B_E: int = 0       # exponent bits (FP only)

    @property
    def B_M(self) -> int:
        """Input mantissa width (FP); alias of B_x for FP formats."""
        return self.B_x

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return self.name


def _int(name: str, bits: int) -> Precision:
    return Precision(name=name, is_fp=False, bits=bits, B_w=bits, B_x=bits)


def _fp(name: str, bits: int, exp: int, man: int) -> Precision:
    # man excludes the hidden bit; stored/streamed mantissas include it.
    return Precision(
        name=name, is_fp=True, bits=bits, B_w=man + 1, B_x=man + 1, B_E=exp
    )


INT2 = _int("int2", 2)
INT4 = _int("int4", 4)
INT8 = _int("int8", 8)
INT16 = _int("int16", 16)
FP8 = _fp("fp8", 8, exp=4, man=3)      # E4M3
FP16 = _fp("fp16", 16, exp=5, man=10)
BF16 = _fp("bf16", 16, exp=8, man=7)
FP32 = _fp("fp32", 32, exp=8, man=23)

REGISTRY: Dict[str, Precision] = {
    p.name: p for p in (INT2, INT4, INT8, INT16, FP8, FP16, BF16, FP32)
}

# The sweep order used by the paper's Fig. 7 (x axis INT2 -> FP32).
PAPER_SWEEP = (INT2, INT4, INT8, INT16, FP8, BF16, FP16, FP32)


def get(name: str) -> Precision:
    try:
        return REGISTRY[name.lower()]
    except KeyError as e:  # pragma: no cover - defensive
        raise ValueError(
            f"unknown precision {name!r}; known: {sorted(REGISTRY)}"
        ) from e
