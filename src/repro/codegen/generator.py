"""End-to-end template-based DCIM generator (paper Fig. 4, right side).

Pipeline for each *selected* Pareto point (generation only runs on
user-distilled designs, exactly as the paper stages it):

  explorer.ParetoPoint  ->  DcimDesign
    -> netlists (structural Verilog, per-component files + macro top)
    -> gate-census audit vs the analytic cost model
    -> floorplan (DEF-like placement + area report; Innovus stand-in)
    -> report.json

``generate(point, outdir)`` writes everything under ``outdir``.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Union

from repro.core.cells import CALIBRATED, CellLibrary, TechParams, TSMC28
from repro.core.explorer import ParetoPoint
from repro.core.precision import get as get_precision

from . import audit as audit_mod
from . import floorplan as fp_mod
from .templates import CELL_LIB_V
from .verilog import DcimDesign, generate_netlists


def design_from_point(
    p: Union[ParetoPoint, dict], include_selection_mux: bool = True
) -> DcimDesign:
    if isinstance(p, ParetoPoint):
        d = dict(
            precision=p.precision, w_store=p.w_store,
            N=p.N, H=p.H, L=p.L, k=p.k,
        )
    else:
        d = dict(p)
    prec = get_precision(d["precision"])
    return DcimDesign(
        precision=prec.name,
        is_fp=prec.is_fp,
        w_store=int(d["w_store"]),
        N=int(d["N"]),
        H=int(d["H"]),
        L=int(d["L"]),
        k=int(d["k"]),
        B_w=prec.B_w,
        B_x=prec.B_x,
        B_E=prec.B_E,
        include_selection_mux=include_selection_mux,
    )


def generate(
    point: Union[ParetoPoint, dict, DcimDesign],
    outdir: Union[str, pathlib.Path],
    tech: TechParams = CALIBRATED,
    lib: CellLibrary = TSMC28,
    utilization: float = 0.7,
    include_selection_mux: bool = True,
) -> dict:
    """Generate RTL + floorplan + reports for one design point."""
    d = (
        point
        if isinstance(point, DcimDesign)
        else design_from_point(point, include_selection_mux)
    )
    out = pathlib.Path(outdir)
    (out / "rtl").mkdir(parents=True, exist_ok=True)

    net = generate_netlists(d)
    for fname, text in net["files"].items():
        (out / "rtl" / fname).write_text(text)
    (out / "rtl" / "cell_lib.v").write_text(CELL_LIB_V)

    audit = audit_mod.audit(d, net["census"], lib)
    plan = fp_mod.floorplan(d, tech, lib, utilization)
    (out / "floorplan.def").write_text(plan["def"])

    report = dict(
        design=dataclasses.asdict(d),
        census=net["census"],
        audit={k: v for k, v in audit.items() if k != "mismatches"}
        | {"mismatches": {k: list(v) for k, v in audit["mismatches"].items()}},
        floorplan=plan["summary"],
        files=sorted(net["files"]) + ["cell_lib.v"],
    )
    (out / "report.json").write_text(json.dumps(report, indent=2, default=str))
    return report
