"""Structural Verilog emitters for the template-based DCIM generator.

Every datapath block that the cost model counts (Table II/IV) is emitted
*structurally* — explicit FA/HA/MUX2/NOR/DFF/SRAM/OR instances — so the
generated netlist's gate census can be audited 1:1 against the analytic
model (tests/test_codegen.py does exactly that).  Glue logic (wiring,
selects of non-counted controls) uses behavioral assigns.

Cell library ports follow a simple convention:
  NOR2  (a, b, y)        FA (a, b, cin, s, cout)    HA (a, b, s, cout)
  MUX2  (a, b, sel, y)   DFF (d, clk, q)            OR2 (a, b, y)
  SRAM6T(bl, blb, wl, q, qb)
"""
from __future__ import annotations

import math
from typing import List


class Netlist:
    """Accumulates module text + an exact instance census."""

    def __init__(self, name: str):
        self.name = name
        self.lines: List[str] = []
        self.counts = {k: 0 for k in ("NOR", "OR", "MUX2", "HA", "FA", "DFF", "SRAM")}
        self._uid = 0

    def uid(self, prefix: str) -> str:
        self._uid += 1
        return f"{prefix}_{self._uid}"

    def w(self, line: str = ""):
        self.lines.append(line)

    # --- structural cells ---------------------------------------------------
    def nor(self, a, b, y):
        self.counts["NOR"] += 1
        self.w(f"  NOR2 {self.uid('nor')} (.a({a}), .b({b}), .y({y}));")

    def or2(self, a, b, y):
        self.counts["OR"] += 1
        self.w(f"  OR2 {self.uid('or')} (.a({a}), .b({b}), .y({y}));")

    def mux2(self, a, b, sel, y):
        self.counts["MUX2"] += 1
        self.w(f"  MUX2 {self.uid('mux')} (.a({a}), .b({b}), .sel({sel}), .y({y}));")

    def ha(self, a, b, s, co):
        self.counts["HA"] += 1
        self.w(f"  HA {self.uid('ha')} (.a({a}), .b({b}), .s({s}), .cout({co}));")

    def fa(self, a, b, ci, s, co):
        self.counts["FA"] += 1
        self.w(
            f"  FA {self.uid('fa')} (.a({a}), .b({b}), .cin({ci}), .s({s}), .cout({co}));"
        )

    def dff(self, d, q):
        self.counts["DFF"] += 1
        self.w(f"  DFF {self.uid('dff')} (.d({d}), .clk(clk), .q({q}));")

    def sram(self, wl, q):
        self.counts["SRAM"] += 1
        self.w(
            f"  SRAM6T {self.uid('sram')} (.bl(bl), .blb(blb), .wl({wl}), .q({q}), .qb());"
        )

    # --- composite blocks (mirror Table II exactly) ---------------------------
    def ripple_adder(self, n: int, a: str, b: str, s: str):
        """N-bit ripple-carry: 1 HA + (N-1) FA (Table II)."""
        if n < 1:
            return
        carry = self.uid("c")
        self.w(f"  wire [{n}:0] {carry};")
        self.ha(f"{a}[0]", f"{b}[0]", f"{s}[0]", f"{carry}[1]")
        for i in range(1, n):
            self.fa(f"{a}[{i}]", f"{b}[{i}]", f"{carry}[{i}]", f"{s}[{i}]", f"{carry}[{i+1}]")

    def mux_n1(self, n: int, inputs: List[str], sel: str, y: str):
        """N:1 mux as a tree of (N-1) MUX2 (Table II)."""
        level = list(inputs)
        depth = 0
        while len(level) > 1:
            nxt = []
            for i in range(0, len(level) - 1, 2):
                w = self.uid("m")
                self.w(f"  wire {w};")
                self.mux2(level[i], level[i + 1], f"{sel}[{depth}]", w)
                nxt.append(w)
            if len(level) % 2:
                nxt.append(level[-1])
            level = nxt
            depth += 1
        if level[0] != y:
            self.w(f"  assign {y} = {level[0]};")

    def barrel_shifter(self, n: int, a: str, sh: str, y: str):
        """N-bit barrel shifter == N parallel N:1 muxes (Table II:
        A_shift = N * A_sel(N))."""
        for bit in range(n):
            ins = [f"{a}[{min(bit + s, n - 1)}]" for s in range(n)]
            self.mux_n1(n, ins, sh, f"{y}[{bit}]")

    def comparator(self, n: int, a: str, b: str, gt: str):
        """Exponent comparator, simplified to an N-bit adder (paper
        §III-B1): emitted as a subtractor-shaped ripple chain."""
        s = self.uid("cmps")
        self.w(f"  wire [{n - 1}:0] {s};")
        self.ripple_adder(n, a, b, s)
        self.w(f"  assign {gt} = {s}[{n - 1}];")

    def module_header(self, ports: str):
        self.w(f"module {self.name} ({ports});")

    def endmodule(self):
        self.w("endmodule")

    def text(self) -> str:
        return "\n".join(self.lines) + "\n"


CELL_LIB_V = """\
// Customized cell library stubs (placement/LVS views come from the PDK).
module NOR2 (input a, input b, output y);   assign y = ~(a | b); endmodule
module OR2  (input a, input b, output y);   assign y = a | b;    endmodule
module MUX2 (input a, input b, input sel, output y); assign y = sel ? b : a; endmodule
module HA   (input a, input b, output s, output cout); assign s = a ^ b; assign cout = a & b; endmodule
module FA   (input a, input b, input cin, output s, output cout);
  assign s = a ^ b ^ cin; assign cout = (a & b) | (cin & (a ^ b)); endmodule
module DFF  (input d, input clk, output reg q); always @(posedge clk) q <= d; endmodule
module SRAM6T (inout bl, inout blb, input wl, output q, output qb);
  // 6T cell stub: storage modeled behaviorally for simulation.
  reg state; assign q = state; assign qb = ~state;
  always @(posedge wl) state <= bl;
endmodule
"""


def log2i(x: int) -> int:
    r = int(math.log2(x))
    assert 2**r == x, f"{x} not a power of two"
    return r
