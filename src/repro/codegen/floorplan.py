"""Deterministic floorplanner — the stand-in for the commercial P&R step.

Places the macro's three regions exactly as the paper's Fig. 6 layouts
do: the SRAM/compute array in the middle (N column strips, each strip =
L*H cells + H compute units + adder tree + shift accumulator), the
result-fusion + INT->FP converter row at the bottom, and the FP
pre-alignment block on the left edge.  Geometry is derived from the same
gate census the cost model uses, at a configurable placement utilization
(default 70%, a typical Innovus target).

Outputs a DEF-like placement text + a JSON-able summary whose total area
is compared against the analytic model in tests.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List

from repro.core.cells import CellLibrary, TechParams, TSMC28, CALIBRATED

from . import audit
from .verilog import DcimDesign


@dataclasses.dataclass
class Block:
    name: str
    x_um: float
    y_um: float
    w_um: float
    h_um: float

    @property
    def area_um2(self) -> float:
        return self.w_um * self.h_um


def _region_area_um2(census: Dict[str, int], lib: CellLibrary,
                     tech: TechParams, util: float) -> float:
    return audit.census_area(census, lib) * tech.A_gate_um2 / util


def floorplan(
    d: DcimDesign,
    tech: TechParams = CALIBRATED,
    lib: CellLibrary = TSMC28,
    utilization: float = 0.7,
) -> dict:
    lg = int(math.log2(d.H))
    z = audit._zero

    # Region censuses.
    array_census = z()
    array_census["SRAM"] = d.N * d.H * d.L
    cu = audit.compute_unit_census(d)
    col_logic = audit._add(
        audit._add(audit.tree_census(d.H, d.k), audit.accu_census(d.B_x, d.H)),
        cu, mult=d.H,
    )
    array_census = audit._add(array_census, col_logic, d.N)

    bottom_census = audit._add(z(), audit.fusion_census(d.B_w, d.B_x, d.H),
                               d.N // d.B_w)
    left_census = z()
    if d.is_fp:
        bottom_census = audit._add(
            bottom_census,
            audit.int2fp_census(d.B_w + d.B_x + lg, d.B_E),
            d.N // d.B_w,
        )
        left_census = audit.prealign_census(d.H, d.B_E, d.B_x)

    a_array = _region_area_um2(array_census, lib, tech, utilization)
    a_bottom = _region_area_um2(bottom_census, lib, tech, utilization)
    a_left = _region_area_um2(left_census, lib, tech, utilization)

    # Array: N column strips side by side; aspect ratio ~= 1 overall.
    total = a_array + a_bottom + a_left
    side = math.sqrt(total)
    array_w = side if a_left == 0 else side * a_array / (a_array + a_left)
    array_h = a_array / array_w
    left_w = 0.0 if a_left == 0 else a_left / array_h
    bottom_h = a_bottom / (left_w + array_w) if a_bottom else 0.0

    blocks: List[Block] = []
    if a_left:
        blocks.append(Block("fp_prealign", 0.0, bottom_h, left_w, array_h))
    col_w = array_w / d.N
    for c in range(d.N):
        blocks.append(
            Block(f"column[{c}]", left_w + c * col_w, bottom_h, col_w, array_h)
        )
    if a_bottom:
        blocks.append(
            Block("fusion_convert_row", 0.0, 0.0, left_w + array_w, bottom_h)
        )

    die_w = left_w + array_w
    die_h = bottom_h + array_h
    summary = dict(
        design=dataclasses.asdict(d),
        utilization=utilization,
        die_w_um=die_w,
        die_h_um=die_h,
        die_area_mm2=die_w * die_h * 1e-6,
        array_area_mm2=a_array * 1e-6,
        prealign_area_mm2=a_left * 1e-6,
        periphery_area_mm2=a_bottom * 1e-6,
        cell_area_mm2=audit.census_area(audit.macro_census(d), lib)
        * tech.A_gate_um2 * 1e-6,
        n_blocks=len(blocks),
    )
    return {"blocks": blocks, "summary": summary, "def": _emit_def(d, blocks, die_w, die_h)}


def _emit_def(d: DcimDesign, blocks: List[Block], die_w: float, die_h: float) -> str:
    lines = [
        "VERSION 5.8 ;",
        f"DESIGN dcim_macro_{d.precision}_{d.w_store} ;",
        "UNITS DISTANCE MICRONS 1000 ;",
        f"DIEAREA ( 0 0 ) ( {int(die_w * 1000)} {int(die_h * 1000)} ) ;",
        f"COMPONENTS {len(blocks)} ;",
    ]
    for b in blocks:
        lines.append(
            f"- {b.name} dcim_block + PLACED ( {int(b.x_um * 1000)}"
            f" {int(b.y_um * 1000)} ) N ;"
        )
    lines += ["END COMPONENTS", "END DESIGN"]
    return "\n".join(lines) + "\n"
