"""Analytic gate census (exact integer counts from Tables II/IV/V) and
the netlist <-> cost-model consistency audit.

The cost model's area is literally (gate census) . (per-cell areas); the
generator emits those gates structurally.  ``audit()`` checks both
directions: census equality per cell type, and census-area == Table V/VI
area (exact for INT; the INT->FP normalize tree uses integer ceil counts
vs the paper's real-valued halving, so FP is checked to <1%).
"""
from __future__ import annotations

import math
from typing import Dict

import numpy as np

from repro.core.cells import CellLibrary, TSMC28
from repro.core.macros import fp_macro, int_macro

from .verilog import DcimDesign

CELLS = ("NOR", "OR", "MUX2", "HA", "FA", "DFF", "SRAM")


def _zero() -> Dict[str, int]:
    return {k: 0 for k in CELLS}


def _add(a, b, mult=1):
    return {k: a[k] + mult * b[k] for k in CELLS}


def adder_census(n: int) -> Dict[str, int]:
    c = _zero()
    c["FA"] = n - 1
    c["HA"] = 1
    return c


def sel_census(n: int) -> Dict[str, int]:
    c = _zero()
    c["MUX2"] = max(n - 1, 0)
    return c


def shifter_census(n: int) -> Dict[str, int]:
    c = _zero()
    c["MUX2"] = n * max(n - 1, 0)
    return c


def tree_census(H: int, k: int) -> Dict[str, int]:
    c = _zero()
    for lvl in range(int(math.log2(H))):
        cnt = H >> (lvl + 1)
        c = _add(c, adder_census(k + lvl), cnt)
    return c


def accu_census(B_x: int, H: int) -> Dict[str, int]:
    B = B_x + int(math.log2(H))
    c = _zero()
    c["DFF"] = B
    c = _add(c, shifter_census(B))
    return _add(c, adder_census(B))


def fusion_census(B_w: int, B_x: int, H: int) -> Dict[str, int]:
    w = B_x + int(math.log2(H))
    c = _zero()
    c["FA"] = (B_w - 1) * (w - 1)
    c["HA"] = B_w + w - 1
    return c


def prealign_census(H: int, B_E: int, B_M: int) -> Dict[str, int]:
    c = _zero()
    c = _add(c, adder_census(B_E), H - 1)       # comparator tree
    return _add(c, shifter_census(B_M), H)      # mantissa barrel shifters


def int2fp_census(B_r: int, B_E: int) -> Dict[str, int]:
    """Integer (emitted) counts; the paper's Table IV uses real-valued
    halving, so this differs from the analytic area by <1%."""
    c = _zero()
    for l in range(1, math.ceil(math.log2(B_r)) + 1):
        c["OR"] += max(math.ceil(B_r / 2**l) - 1, 0)
        c["MUX2"] += math.ceil(B_r / 2**l)
    return _add(c, adder_census(B_E))


def compute_unit_census(d: DcimDesign) -> Dict[str, int]:
    c = _zero()
    c["NOR"] = d.k
    if d.include_selection_mux and d.L > 1:
        c = _add(c, sel_census(d.L))
    return c


def macro_census(d: DcimDesign) -> Dict[str, int]:
    """Analytic census for the whole macro (Table V/VI assembly)."""
    c = _zero()
    # CU appears H times per column; tree + accumulator once per column.
    per_col = _add(
        _add(tree_census(d.H, d.k), accu_census(d.B_x, d.H)),
        compute_unit_census(d),
        mult=d.H,
    )
    c = _add(c, per_col, d.N)
    c = _add(c, fusion_census(d.B_w, d.B_x, d.H), d.N // d.B_w)
    c["SRAM"] += d.N * d.H * d.L
    if d.is_fp:
        c = _add(c, prealign_census(d.H, d.B_E, d.B_x))
        c = _add(c, int2fp_census(d.B_w + d.B_x + int(math.log2(d.H)), d.B_E),
                 d.N // d.B_w)
    return c


def census_area(census: Dict[str, int], lib: CellLibrary = TSMC28) -> float:
    return (
        census["NOR"] * lib.A_NOR
        + census["OR"] * lib.A_OR
        + census["MUX2"] * lib.A_MUX
        + census["HA"] * lib.A_HA
        + census["FA"] * lib.A_FA
        + census["DFF"] * lib.A_DFF
        + census["SRAM"] * lib.A_SRAM
    )


def model_area(d: DcimDesign, lib: CellLibrary = TSMC28) -> float:
    if d.is_fp:
        mc = fp_macro(
            float(d.N), float(d.H), float(d.L), float(d.k),
            d.B_w, d.B_E, d.B_x, lib,
            include_selection_mux=d.include_selection_mux,
        )
    else:
        mc = int_macro(
            float(d.N), float(d.H), float(d.L), float(d.k),
            d.B_w, d.B_x, lib,
            include_selection_mux=d.include_selection_mux,
        )
    return float(np.asarray(mc.area))


def audit(d: DcimDesign, emitted_census: Dict[str, int],
          lib: CellLibrary = TSMC28) -> dict:
    """Three-way consistency: emitted netlist census == analytic census,
    and analytic-census area == Table V/VI area."""
    analytic = macro_census(d)
    mismatches = {
        k: (emitted_census[k], analytic[k])
        for k in CELLS
        if emitted_census[k] != analytic[k]
    }
    a_census = census_area(analytic, lib)
    a_model = model_area(d, lib)
    rel = abs(a_census - a_model) / max(a_model, 1e-9)
    return dict(
        census_match=not mismatches,
        mismatches=mismatches,
        census_area=a_census,
        model_area=a_model,
        area_rel_err=rel,
        ok=(not mismatches) and (rel < (0.01 if d.is_fp else 1e-5)),
    )
