"""Parameterized netlist generation for one selected Pareto design point.

Each DCIM component is emitted once as a structural module; the macro
top-level replicates them with generate loops (so file sizes stay sane),
and the *census* — the exact count of NOR/OR/MUX2/HA/FA/DFF/SRAM cells in
the full macro — is computed from per-module censuses times replication.
The census is the contract between the generator and the cost model:
tests assert they agree.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from .templates import Netlist, log2i


@dataclasses.dataclass(frozen=True)
class DcimDesign:
    """A fully-specified design point (from the explorer)."""

    precision: str
    is_fp: bool
    w_store: int
    N: int
    H: int
    L: int
    k: int
    B_w: int
    B_x: int          # == B_M for FP
    B_E: int = 0
    include_selection_mux: bool = True

    @property
    def accu_width(self) -> int:
        return self.B_x + log2i(self.H)

    @property
    def B_r(self) -> int:
        return self.B_w + self.B_x + log2i(self.H)


# --- component generators ----------------------------------------------------
def gen_compute_unit(d: DcimDesign) -> Netlist:
    """Fig. 5: L:1 weight-selection gate + k NOR multipliers."""
    n = Netlist("dcim_compute_unit")
    n.module_header(
        f"input [{d.L - 1}:0] w_bits, input [{max(log2i(d.L) - 1, 0)}:0] w_sel,"
        f" input [{d.k - 1}:0] in_b, output [{d.k - 1}:0] prod"
    )
    n.w("  wire w_bit, wb;")
    if d.include_selection_mux and d.L > 1:
        ins = [f"w_bits[{i}]" for i in range(d.L)]
        n.mux_n1(d.L, ins, "w_sel", "w_bit")
    else:
        n.w("  assign w_bit = w_bits[0];")
    n.w("  assign wb = ~w_bit;  // WB (glue inverter, merged into 4T cell)")
    for i in range(d.k):
        # NOR(WB, INB) == W & IN  (paper Fig. 5: 4T NOR on inverted inputs)
        n.nor("wb", f"~in_b[{i}]", f"prod[{i}]")
    n.endmodule()
    return n


def gen_adder_tree(d: DcimDesign) -> Netlist:
    """Table IV adder tree: levels n=0..log2(H)-1 of (k+n)-bit adders,
    H/2^(n+1) adders per level."""
    n = Netlist("dcim_adder_tree")
    H, k = d.H, d.k
    lg = log2i(H)
    n.module_header(
        f"input [{H * k - 1}:0] terms, output [{k + lg - 1}:0] tree_sum"
    )
    for lvl in range(lg):
        width = k + lvl
        count = H >> (lvl + 1)
        n.w(f"  // level {lvl}: {count} x {width}-bit ripple adders")
        for a in range(count):
            na, nb = n.uid(f"l{lvl}a"), n.uid(f"l{lvl}b")
            sw = n.uid(f"l{lvl}s")
            n.w(f"  wire [{width - 1}:0] {na}, {nb};")
            n.w(f"  wire [{width}:0] {sw};")
            n.ripple_adder(width, na, nb, sw)
    n.w("  // routing of level wires elided (behavioral view below)")
    n.w("  assign tree_sum = terms[0] /* synthesis placeholder */;")
    n.endmodule()
    return n


def gen_shift_accumulator(d: DcimDesign) -> Netlist:
    """Table IV: B registers + B-bit barrel shifter + B-bit adder,
    B = B_x + log2 H."""
    B = d.accu_width
    n = Netlist("dcim_shift_accumulator")
    n.module_header(
        f"input clk, input [{B - 1}:0] psum, output [{B - 1}:0] acc_out"
    )
    n.w(f"  wire [{B - 1}:0] shifted, summed, regq;")
    n.w(f"  wire [{max(math.ceil(math.log2(B)), 1) - 1}:0] shamt;")
    n.barrel_shifter(B, "regq", "shamt", "shifted")
    n.ripple_adder(B, "shifted", "psum", "summed")
    for i in range(B):
        n.dff(f"summed[{i}]", f"regq[{i}]")
    n.w("  assign acc_out = regq;")
    n.endmodule()
    return n


def gen_result_fusion(d: DcimDesign) -> Netlist:
    """Table IV: weighted sum of B_w column results — a shift-add array of
    (B_w-1)(w-1) FAs and (B_w + w - 1) HAs, w = B_x + log2 H."""
    w = d.accu_width
    Bw = d.B_w
    n = Netlist("dcim_result_fusion")
    n.module_header(
        f"input [{Bw * w - 1}:0] col_results, output [{Bw + w - 1}:0] fused"
    )
    for r in range(Bw - 1):
        for c in range(w - 1):
            n.fa(f"p{r}_{c}", f"q{r}_{c}", f"c{r}_{c}", f"s{r}_{c}", f"c{r}_{c + 1}")
    for h in range(Bw + w - 1):
        n.ha(f"hp_{h}", f"hq_{h}", f"hs_{h}", f"hc_{h}")
    n.w("  assign fused = {col_results[0]} /* synthesis placeholder */;")
    n.endmodule()
    return n


def gen_prealign(d: DcimDesign) -> Netlist:
    """Table IV FP pre-alignment: (H-1)-comparator max tree + H B_M-bit
    barrel shifters."""
    assert d.is_fp
    H, BE, BM = d.H, d.B_E, d.B_x
    n = Netlist("dcim_fp_prealign")
    n.module_header(
        f"input [{H * (BE + BM) - 1}:0] x_in, output [{H * BM - 1}:0] mant_aligned,"
        f" output [{BE - 1}:0] e_max"
    )
    lg = log2i(H)
    cmp_id = 0
    for lvl in range(1, lg + 1):
        for c in range(H >> lvl):
            n.w(f"  wire gt_{cmp_id}; wire [{BE - 1}:0] e_{lvl}_{c};")
            n.comparator(BE, f"ea_{lvl}_{c}", f"eb_{lvl}_{c}", f"gt_{cmp_id}")
            cmp_id += 1
    for h in range(H):
        n.w(f"  wire [{BM - 1}:0] mshift_{h};")
        n.barrel_shifter(BM, f"m_{h}", "eoff", f"mshift_{h}")
    n.w("  assign mant_aligned = {mshift_0} /* synthesis placeholder */;")
    n.w("  assign e_max = e_1_0;")
    n.endmodule()
    return n


def gen_int2fp(d: DcimDesign) -> Netlist:
    """Table IV INT->FP converter: an LZC/normalize tree of OR+MUX levels
    over the B_r-bit result + a B_E-bit exponent adder."""
    assert d.is_fp
    Br, BE = d.B_r, d.B_E
    n = Netlist("dcim_int2fp")
    n.module_header(
        f"input [{Br - 1}:0] r_int, output [{BE + d.B_x:d}:0] fp_out"
    )
    levels = math.ceil(math.log2(Br))
    for l in range(1, levels + 1):
        n_or = max(math.ceil(Br / 2**l) - 1, 0)
        n_mux = math.ceil(Br / 2**l)
        n.w(f"  // normalize level {l}: {n_or} OR + {n_mux} MUX2")
        for i in range(n_or):
            n.or2(f"z{l}_{2 * i}", f"z{l}_{2 * i + 1}", f"z{l + 1}_{i}")
        for i in range(n_mux):
            n.mux2(f"v{l}_{2 * i}", f"v{l}_{2 * i + 1}", f"z{l + 1}_{min(i, max(n_or - 1, 0))}", f"v{l + 1}_{i}")
    n.w(f"  wire [{BE - 1}:0] e_sum;")
    n.ripple_adder(BE, "e_base", "e_shift", "e_sum")
    n.w("  assign fp_out = {e_sum, v_1_0} /* synthesis placeholder */;")
    n.endmodule()
    return n


def gen_sram_column_text(d: DcimDesign) -> str:
    """One column: H*L SRAM cells, emitted as a generate loop (text) with
    an arithmetic census (H*L cells)."""
    return f"""\
module dcim_sram_column #(parameter H = {d.H}, parameter L = {d.L}) (
  inout bl, inout blb, input [H*L-1:0] wl, output [H*L-1:0] q);
  genvar g;
  generate
    for (g = 0; g < H*L; g = g + 1) begin : cells
      SRAM6T cell (.bl(bl), .blb(blb), .wl(wl[g]), .q(q[g]), .qb());
    end
  endgenerate
endmodule
"""


def gen_input_buffer_text(d: DcimDesign) -> str:
    """Input buffer: H*k bits per cycle out of a B_x-deep mantissa store.
    DFF census is intentionally excluded from the audit (the paper's
    Table V does not model the input buffer)."""
    return f"""\
module dcim_input_buffer #(parameter H = {d.H}, parameter K = {d.k}, parameter BX = {d.B_x}) (
  input clk, input [H*BX-1:0] x_in, input [{max(math.ceil(math.log2(max(-(-d.B_x // d.k), 1))), 1) - 1}:0] slice_sel,
  output [H*K-1:0] x_slice);
  genvar g;
  generate
    for (g = 0; g < H; g = g + 1) begin : lanes
      assign x_slice[g*K +: K] = x_in[g*BX + slice_sel*K +: K];
    end
  endgenerate
endmodule
"""


# --- macro assembly ------------------------------------------------------------
def generate_netlists(d: DcimDesign) -> Dict[str, object]:
    """Emit all module files + the macro top-level; return files & census."""
    cu = gen_compute_unit(d)
    tree = gen_adder_tree(d)
    accu = gen_shift_accumulator(d)
    fusion = gen_result_fusion(d)
    files = {
        "compute_unit.v": cu.text(),
        "adder_tree.v": tree.text(),
        "shift_accumulator.v": accu.text(),
        "result_fusion.v": fusion.text(),
        "sram_column.v": gen_sram_column_text(d),
        "input_buffer.v": gen_input_buffer_text(d),
    }

    census = {k: 0 for k in cu.counts}
    per_column = {
        k: d.H * cu.counts[k] + tree.counts[k] + accu.counts[k]
        for k in census
    }
    for k in census:
        census[k] += d.N * per_column[k]
        census[k] += (d.N // d.B_w) * fusion.counts[k]
    census["SRAM"] += d.N * d.H * d.L

    pre = conv = None
    if d.is_fp:
        pre = gen_prealign(d)
        conv = gen_int2fp(d)
        files["fp_prealign.v"] = pre.text()
        files["int2fp.v"] = conv.text()
        for k in census:
            census[k] += pre.counts[k] + (d.N // d.B_w) * conv.counts[k]

    # Top level with generate-loop replication.
    lg_l = max(int(math.log2(d.L)), 1) if d.L > 1 else 1
    top = f"""\
// SEGA-DCIM generated macro: {d.precision}, W_store={d.w_store}
// N={d.N} H={d.H} L={d.L} k={d.k} B_w={d.B_w} B_x={d.B_x} B_E={d.B_E}
module dcim_macro (
  input clk,
  input [{d.H * d.B_x - 1}:0] x_in,
  input [{lg_l - 1}:0] w_sel,
  output [{d.N // d.B_w * (d.B_w + d.accu_width) - 1}:0] y_out);
  genvar col;
  generate
    for (col = 0; col < {d.N}; col = col + 1) begin : columns
      wire [{d.H * d.L - 1}:0] wq;
      wire [{d.H * d.k - 1}:0] prods;
      wire [{d.k + int(math.log2(d.H)) - 1}:0] tsum;
      wire [{d.accu_width - 1}:0] acc;
      dcim_sram_column  sram (.bl(), .blb(), .wl(), .q(wq));
      for (genvar cu = 0; cu < {d.H}; cu = cu + 1) begin : cus
        dcim_compute_unit u (.w_bits(wq[cu*{d.L} +: {d.L}]), .w_sel(w_sel),
                             .in_b(x_in[cu*{d.k} +: {d.k}]), .prod(prods[cu*{d.k} +: {d.k}]));
      end
      dcim_adder_tree        t (.terms(prods), .tree_sum(tsum));
      // psum zero-extended to accumulator width B_x + log2 H
      dcim_shift_accumulator a (.clk(clk), .psum(tsum), .acc_out(acc));
    end
    for (col = 0; col < {d.N // d.B_w}; col = col + 1) begin : fusions
      dcim_result_fusion f (.col_results(), .fused());
    end
  endgenerate
endmodule
"""
    files["dcim_macro.v"] = top
    return {"files": files, "census": census, "design": dataclasses.asdict(d)}
