"""Template-based DCIM generator: structural Verilog netlists, gate-census
audit vs the cost model, and a deterministic floorplanner (P&R stand-in)."""
from .generator import design_from_point, generate  # noqa: F401
from .verilog import DcimDesign, generate_netlists  # noqa: F401
