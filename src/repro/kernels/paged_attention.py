"""Fused paged-attention Pallas kernels (vLLM-style).

Decode: one kernel reads K/V pages THROUGH the block table — the
``(B, nb)`` page list and the ``(B,)`` position vector are scalar-
prefetched (``PrefetchScalarGridSpec``) so the BlockSpec index maps can
steer each grid step's DMA at the page the slot actually owns.  Pages
stream into a VMEM scratch gather buffer; at the slot's last page the
kernel runs the masked attend over the full gathered sequence.  The XLA
path this replaces (``attention._gather_pages`` + ``decode_attention``)
materializes a contiguous ``(B, nb * page, ...)`` HBM copy of every
slot's pages per layer per step; here the gather lives only in VMEM.

Prefill: one kernel attends ``[reused-context ; causal tail]`` without
ever materializing the concatenated K/V or the ``(B, Hk, G, T, L+T)``
score tensor in HBM — context and tail blocks are copied side by side
into a VMEM scratch (the "concat" is per-cell, on-chip) and scores live
per (batch row, q tile) in VMEM.

Bitwise parity: every kernel keeps the reference path's exact compute
structure — one masked single-normalization softmax over the full key
axis and single dot-generals for scores and PV (NOT a rescaling online-
softmax accumulation, which changes summation trees and breaks the
serving stack's token-exactness contracts).  Page-granular gathering is
safe because each score element is an independent dot over the head
dim; masking, softmax and the PV contraction run over the full gathered
axis exactly as ``attention.decode_attention`` / ``prefix_attention``
do.  Parity is asserted bitwise in tests/test_paged_attention.py.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


# ----------------------------- decode: GQA -----------------------------------
def _decode_gqa_kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                       k_s, v_s, *, page: int, nb: int):
    """Grid (B, nb), pages innermost.  Each step DMAs one page of K/V
    (selected by the block-table index maps) into the gather scratch; the
    last page runs mask + softmax + PV over the full sequence."""
    b = pl.program_id(0)
    i = pl.program_id(1)
    k_s[pl.ds(i * page, page)] = k_ref[0]
    v_s[pl.ds(i * page, page)] = v_ref[0]

    @pl.when(i == nb - 1)
    def _attend():
        S = nb * page
        s = jnp.einsum(
            "hgd,shd->hgs", q_ref[0], k_s[...],
            preferred_element_type=jnp.float32,
        )
        iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, S), 2)
        s = jnp.where(iota <= pos_ref[b], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o_ref[0] = jnp.einsum(
            "hgs,shd->hgd", p.astype(v_s.dtype), v_s[...],
            preferred_element_type=jnp.float32,
        )


def paged_decode_gqa_pallas(q, k_pages, v_pages, block_table, pos,
                            interpret: bool = False):
    """Fused paged GQA decode.

    q: (B, 1, H, hd); k_pages/v_pages: (n_pages, page, Hk, hd[v]);
    block_table: (B, nb) int32 page ids; pos: (B,) int32 per-row
    lengths.  Returns (B, 1, H, hdv) f32 — bitwise identical to
    ``decode_attention(q, gather(k), gather(v), pos)``.  Rows whose
    table points at the reserved garbage page 0 (inactive slots, pos
    clamped to 0) are handled by the mask exactly as in the reference.
    """
    B, _, H, hd = q.shape
    _, page, Hk, _ = k_pages.shape
    hdv = v_pages.shape[-1]
    G = H // Hk
    nb = block_table.shape[1]
    S = nb * page
    scale = 1.0 / math.sqrt(hd)
    qg = (q * scale).reshape(B, Hk, G, hd)

    out = pl.pallas_call(
        functools.partial(_decode_gqa_kernel, page=page, nb=nb),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, nb),
            in_specs=[
                pl.BlockSpec((1, Hk, G, hd), lambda b, i, bt, ps: (b, 0, 0, 0)),
                pl.BlockSpec(
                    (1, page, Hk, hd), lambda b, i, bt, ps: (bt[b, i], 0, 0, 0)
                ),
                pl.BlockSpec(
                    (1, page, Hk, hdv), lambda b, i, bt, ps: (bt[b, i], 0, 0, 0)
                ),
            ],
            out_specs=pl.BlockSpec(
                (1, Hk, G, hdv), lambda b, i, bt, ps: (b, 0, 0, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((S, Hk, hd), k_pages.dtype),
                pltpu.VMEM((S, Hk, hdv), v_pages.dtype),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hk, G, hdv), jnp.float32),
        interpret=interpret,
    )(block_table, pos, qg, k_pages, v_pages)
    return out.reshape(B, 1, H, hdv)


# ----------------------------- decode: MLA -----------------------------------
def _decode_mla_kernel(bt_ref, pos_ref, qa_ref, qr_ref, c_ref, r_ref, o_ref,
                       c_s, r_s, *, page: int, nb: int, scale: float):
    b = pl.program_id(0)
    i = pl.program_id(1)
    c_s[pl.ds(i * page, page)] = c_ref[0]
    r_s[pl.ds(i * page, page)] = r_ref[0]

    @pl.when(i == nb - 1)
    def _attend():
        S = nb * page
        ckv = c_s[...].astype(jnp.float32)
        krope = r_s[...].astype(jnp.float32)
        s = (
            jnp.einsum("hr,sr->hs", qa_ref[0], ckv,
                       preferred_element_type=jnp.float32)
            + jnp.einsum("hd,sd->hs", qr_ref[0], krope,
                         preferred_element_type=jnp.float32)
        ) * scale
        iota = jax.lax.broadcasted_iota(jnp.int32, (1, S), 1)
        s = jnp.where(iota <= pos_ref[b], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o_ref[0] = jnp.einsum(
            "hs,sr->hr", p, ckv, preferred_element_type=jnp.float32
        )


def paged_decode_mla_pallas(q_abs, q_rope, ckv_pages, krope_pages,
                            block_table, pos, scale: float,
                            interpret: bool = False):
    """Fused paged absorbed-MLA decode, in the compressed c_kv space.

    q_abs: (B, 1, H, r) f32 absorbed queries; q_rope: (B, 1, H, dr);
    ckv_pages: (n_pages, page, r); krope_pages: (n_pages, page, dr).
    Returns the (B, 1, H, r) f32 context (the ``w_uv`` up-projection
    stays outside) — bitwise identical to ``mla_attend_core`` over the
    gathered per-slot views.  ``scale`` multiplies the SUMMED nope+rope
    scores, matching the reference's post-sum scaling."""
    B, _, H, r = q_abs.shape
    dr = q_rope.shape[-1]
    _, page, _ = ckv_pages.shape
    nb = block_table.shape[1]
    S = nb * page
    qa = q_abs.astype(jnp.float32).reshape(B, H, r)
    qr = q_rope.astype(jnp.float32).reshape(B, H, dr)

    out = pl.pallas_call(
        functools.partial(_decode_mla_kernel, page=page, nb=nb, scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, nb),
            in_specs=[
                pl.BlockSpec((1, H, r), lambda b, i, bt, ps: (b, 0, 0)),
                pl.BlockSpec((1, H, dr), lambda b, i, bt, ps: (b, 0, 0)),
                pl.BlockSpec(
                    (1, page, r), lambda b, i, bt, ps: (bt[b, i], 0, 0)
                ),
                pl.BlockSpec(
                    (1, page, dr), lambda b, i, bt, ps: (bt[b, i], 0, 0)
                ),
            ],
            out_specs=pl.BlockSpec((1, H, r), lambda b, i, bt, ps: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((S, r), ckv_pages.dtype),
                pltpu.VMEM((S, dr), krope_pages.dtype),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, r), jnp.float32),
        interpret=interpret,
    )(block_table, pos, qa, qr, ckv_pages, krope_pages)
    return out.reshape(B, 1, H, r)


# ------------------------- prefill: [ctx ; causal tail] -----------------------
def _prefix_kernel(ctx_ref, q_ref, *refs, L: int, T: int, Tt: int):
    """Grid (B, Tp // Tt), q tiles innermost.  At each row's first tile
    the context and tail K/V blocks are copied side by side into the
    gather scratch (the on-chip "concat"); every tile then runs one
    masked softmax + PV over the full L+T axis."""
    if L:
        kc_ref, vc_ref, kt_ref, vt_ref, o_ref, k_s, v_s = refs
    else:
        kt_ref, vt_ref, o_ref, k_s, v_s = refs
    b = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _load():
        if L:
            k_s[pl.ds(0, L)] = kc_ref[0]
            v_s[pl.ds(0, L)] = vc_ref[0]
        k_s[pl.ds(L, T)] = kt_ref[0]
        v_s[pl.ds(L, T)] = vt_ref[0]

    s = jnp.einsum(
        "qhgd,shd->hgqs", q_ref[0], k_s[...],
        preferred_element_type=jnp.float32,
    )                                               # (Hk, G, Tt, L+T)
    col = jax.lax.broadcasted_iota(jnp.int32, (Tt, L + T), 1)
    row = jax.lax.broadcasted_iota(jnp.int32, (Tt, L + T), 0) + t * Tt
    mask = jnp.where(col < L, col < ctx_ref[b], (col - L) <= row)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o_ref[0] = jnp.einsum(
        "hgqs,shd->qhgd", p.astype(v_s.dtype), v_s[...],
        preferred_element_type=jnp.float32,
    )


def prefix_prefill_pallas(q, k_ctx, v_ctx, k_tail, v_tail, ctx_len,
                          tail_block: int = 8, interpret: bool = False):
    """Fused [reused-context ; causal-tail] prefill attention.

    q: (B, T, H, hd) tail queries at absolute positions ctx_len + t;
    k_ctx/v_ctx: (B, L, Hk, hd[v]) gathered context pages (None when
    the scheduler compiles the prefix machinery out — L == 0);
    k_tail/v_tail: (B, T, Hk, hd[v]); ctx_len: (B,) int32 valid context
    lengths.  Returns (B, T, H, hdv) f32 — bitwise identical to
    ``prefix_attention(q, concat([k_ctx, k_tail]), ..., ctx_len, L)``
    without materializing the concat or the (B, Hk, G, T, L+T) score
    tensor in HBM.  T is tiled by ``tail_block`` (softmax rows are
    per-query, so tiling cannot change any output bit); q is zero-padded
    up to the tile multiple and the pad rows sliced off."""
    B, T, H, hd = q.shape
    Hk = k_tail.shape[2]
    hdv = v_tail.shape[-1]
    G = H // Hk
    L = 0 if k_ctx is None else k_ctx.shape[1]
    scale = 1.0 / math.sqrt(hd)
    qg = (q * scale).reshape(B, T, Hk, G, hd)
    Tt = min(tail_block, T)
    Tp = -(-T // Tt) * Tt
    if Tp != T:
        qg = jnp.pad(qg, ((0, 0), (0, Tp - T), (0, 0), (0, 0), (0, 0)))

    def _idx_q(b, t, ctx):
        return (b, t, 0, 0, 0)

    def _idx_kv(b, t, ctx):
        return (b, 0, 0, 0)

    in_specs = [pl.BlockSpec((1, Tt, Hk, G, hd), _idx_q)]
    operands = [qg]
    if L:
        in_specs += [
            pl.BlockSpec((1, L, Hk, hd), _idx_kv),
            pl.BlockSpec((1, L, Hk, hdv), _idx_kv),
        ]
        operands += [k_ctx, v_ctx]
    in_specs += [
        pl.BlockSpec((1, T, Hk, hd), _idx_kv),
        pl.BlockSpec((1, T, Hk, hdv), _idx_kv),
    ]
    operands += [k_tail, v_tail]

    out = pl.pallas_call(
        functools.partial(_prefix_kernel, L=L, T=T, Tt=Tt),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, Tp // Tt),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, Tt, Hk, G, hdv), _idx_q),
            scratch_shapes=[
                pltpu.VMEM((L + T, Hk, hd), k_tail.dtype),
                pltpu.VMEM((L + T, Hk, hdv), v_tail.dtype),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Tp, Hk, G, hdv), jnp.float32),
        interpret=interpret,
    )(ctx_len, *operands)
    return out[:, :T].reshape(B, T, H, hdv)
