"""Pure-jnp oracles for every Pallas kernel in this package.

Each oracle is written *differently* from its kernel (no shared bit
tricks where avoidable) so that agreement is meaningful:

  * dominance_matrix_ref: broadcasted jnp comparisons.
  * dcim_mvm_ref: plain exact integer matmul (what a full-precision DCIM
    macro must compute).
  * dcim_mvm_structural_ref: the bit-serial decomposition in straight
    jnp — validates the algebra of the dataflow independently of Pallas.
  * fp_prealign_ref: mantissa/exponent via jnp.frexp (float path) instead
    of the kernel's int32 bit-twiddling.
"""
from __future__ import annotations

import jax.numpy as jnp


# --- pareto_rank -----------------------------------------------------------
def dominance_matrix_ref(F, violation=None):
    F = jnp.where(jnp.isnan(F), jnp.inf, F.astype(jnp.float32))
    le = jnp.all(F[:, None, :] <= F[None, :, :], axis=-1)
    lt = jnp.any(F[:, None, :] < F[None, :, :], axis=-1)
    pdom = le & lt
    if violation is None:
        return pdom
    v = violation.astype(jnp.float32)
    feas = v <= 0.0
    return (feas[:, None] & feas[None, :] & pdom) | (v[:, None] < v[None, :])


# --- dcim_mvm ---------------------------------------------------------------
def dcim_mvm_ref(x, w):
    """Exact integer matmul — the semantic spec of the DCIM macro."""
    return jnp.matmul(
        x.astype(jnp.int32), w.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )


def dcim_mvm_structural_ref(x, w, B_x=8, B_w=8, k=4, x_signed=True, w_signed=True):
    """The bit-serial dataflow (slices x bit-planes + two's-complement
    corrections) in pure jnp."""
    x = x.astype(jnp.int32)
    w = w.astype(jnp.int32)
    U = jnp.bitwise_and(x, (1 << B_x) - 1)
    V = jnp.bitwise_and(w, (1 << B_w) - 1)
    n_slices = -(-B_x // k)
    acc = jnp.zeros((x.shape[0], w.shape[1]), jnp.int32)
    for b in range(B_w):
        v_plane = jnp.bitwise_and(jnp.right_shift(V, b), 1)
        for s in range(n_slices):
            u_slice = jnp.bitwise_and(jnp.right_shift(U, s * k), (1 << k) - 1)
            acc = acc + (jnp.matmul(u_slice, v_plane) << (b + s * k))
    if w_signed:
        neg_w = (w < 0).astype(jnp.int32)
        acc = acc - (jnp.matmul(U, neg_w) << B_w)
    if x_signed:
        neg_x = (x < 0).astype(jnp.int32)
        acc = acc - (jnp.matmul(neg_x, V) << B_x)
        if w_signed:
            acc = acc + (jnp.matmul(neg_x, neg_w) << (B_x + B_w))
    return acc


# --- fp_prealign -------------------------------------------------------------
def fp_prealign_ref(x, B_M=8):
    """x: (M, G, H) f32 -> aligned int32 mantissas + biased group exponents,
    via jnp.frexp (no bit twiddling).  Subnormals flush to zero, matching
    the hardware datapath."""
    x = x.astype(jnp.float32)
    tiny = 2.0 ** -126
    is_zero = jnp.abs(x) < tiny
    frac, e = jnp.frexp(jnp.where(is_zero, 1.0, x))   # |frac| in [0.5, 1)
    exp = jnp.where(is_zero, 0, e + 126)              # IEEE biased exponent
    mant = jnp.floor(jnp.abs(frac) * (1 << B_M)).astype(jnp.int32)
    mant = jnp.where(is_zero, 0, mant)
    mant = jnp.where(x < 0, -mant, mant)
    emax = jnp.max(exp, axis=-1)
    shift = jnp.minimum(emax[..., None] - exp, 31)
    # Arithmetic right shift == floor division by 2^shift without the
    # int32 overflow of (1 << 31).
    aligned = jnp.right_shift(mant, shift)
    return aligned.astype(jnp.int32), emax.astype(jnp.int32)


def fp_matmul_f32_ref(x, w):
    """Plain float32 matmul — the accuracy yardstick for the pre-aligned
    block-FP pipeline."""
    return jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))


# --- selective_scan -----------------------------------------------------------
def selective_scan_ref(u, dt, B_c, C_c, A, D_skip, h0=None):
    """Sequential-oracle Mamba-1 recurrence in pure jnp (lax.scan over
    time): h_t = exp(dt A) h_{t-1} + dt u B_t ;  y_t = h_t . C_t + D u_t."""
    import jax

    u = u.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    Bsz, S, D = u.shape
    N = B_c.shape[-1]
    h = jnp.zeros((Bsz, D, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, xs):
        u_t, dt_t, b_t, c_t = xs
        h = jnp.exp(dt_t[..., None] * A) * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t) + D_skip * u_t
        return h, y

    xs = (u.swapaxes(0, 1), dt.swapaxes(0, 1),
          B_c.astype(jnp.float32).swapaxes(0, 1),
          C_c.astype(jnp.float32).swapaxes(0, 1))
    h, ys = jax.lax.scan(step, h, xs)
    return ys.swapaxes(0, 1), h
