"""Public jit'd wrappers around the Pallas kernels, and the backend
dispatch layer.

On TPU the kernels run compiled (``interpret=False``).  Off TPU every
public entry point auto-falls back to a bit-identical XLA reference —
the Pallas interpreter lowers the kernel body to XLA ops too, but pays
a large tracing/compile overhead per call (BENCH_kernels.json showed
interpreter-mode ``dcim_mvm`` at ~60x its XLA structural ref on CPU),
so the interpreter is reserved for parity tests, which force it with
``interpret=True`` / ``AttnBackend.PALLAS_INTERPRET``.

The attention dispatchers (:func:`paged_decode_gqa`,
:func:`paged_decode_mla`, :func:`prefix_prefill`) follow the same
pattern behind the :class:`AttnBackend` enum; ``LMConfig.attn_backend``
threads the choice through the serving stack with zero call-site churn.
"""
from __future__ import annotations

import enum
import functools
import math

import jax
import jax.numpy as jnp

from . import dcim_mvm as _mvm
from . import fp_prealign as _pre
from . import paged_attention as _pa
from . import pareto_rank as _rank
from . import ref as _ref


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


# --- attention backend dispatch ----------------------------------------------
class AttnBackend(str, enum.Enum):
    """Which implementation serves the paged-attention entry points.

    AUTO resolves to PALLAS on TPU and XLA elsewhere.  The XLA path is
    the original gather+attend reference in ``repro.models.attention``;
    PALLAS is the fused kernel in ``repro.kernels.paged_attention``
    (bitwise identical — asserted in tests/test_paged_attention.py);
    PALLAS_INTERPRET forces the Pallas interpreter off-TPU so parity
    tests and end-to-end serving runs exercise the kernel body on CPU.
    """

    AUTO = "auto"
    XLA = "xla"
    PALLAS = "pallas"
    PALLAS_INTERPRET = "pallas_interpret"


def resolve_attn_backend(backend=None) -> AttnBackend:
    b = AttnBackend(backend) if backend else AttnBackend.AUTO
    if b is AttnBackend.AUTO:
        return AttnBackend.XLA if _interpret_default() else AttnBackend.PALLAS
    return b


def paged_decode_gqa(q, k_pages, v_pages, block_table, pos, backend=None):
    """Paged GQA decode attention: (B, 1, H, hd) q against the slot's
    pages.  XLA: gather a contiguous per-slot view, run
    ``decode_attention``.  PALLAS: the fused block-table kernel."""
    b = resolve_attn_backend(backend)
    if b is AttnBackend.XLA:
        from repro.models import attention as _attn

        return _attn.decode_attention(
            q,
            _attn._gather_pages(k_pages, block_table),
            _attn._gather_pages(v_pages, block_table),
            pos,
        )
    return _pa.paged_decode_gqa_pallas(
        q, k_pages, v_pages, block_table, pos,
        interpret=b is AttnBackend.PALLAS_INTERPRET,
    )


def paged_decode_mla(q_abs, q_rope, ckv_pages, krope_pages, block_table,
                     pos, scale: float, backend=None):
    """Paged absorbed-MLA decode in the compressed c_kv space; returns
    the (B, 1, H, r) f32 context (``w_uv`` up-projection stays with the
    caller)."""
    b = resolve_attn_backend(backend)
    if b is AttnBackend.XLA:
        from repro.models import attention as _attn

        return _attn.mla_attend_core(
            q_abs, q_rope,
            _attn._gather_pages(ckv_pages, block_table),
            _attn._gather_pages(krope_pages, block_table),
            pos, scale,
        )
    return _pa.paged_decode_mla_pallas(
        q_abs, q_rope, ckv_pages, krope_pages, block_table, pos, scale,
        interpret=b is AttnBackend.PALLAS_INTERPRET,
    )


def prefix_prefill(q, k_ctx, v_ctx, k_tail, v_tail, ctx_len, backend=None):
    """[reused-context ; causal-tail] prefill attention.  ``k_ctx`` /
    ``v_ctx`` are None when the prefix machinery is compiled out (L=0).
    XLA: concatenate and run ``prefix_attention``; PALLAS: the fused
    kernel (no HBM concat, no (B, Hk, G, T, L+T) score tensor)."""
    b = resolve_attn_backend(backend)
    if b is AttnBackend.XLA:
        from repro.models import attention as _attn

        if k_ctx is None:
            return _attn.prefix_attention(q, k_tail, v_tail, ctx_len, 0)
        return _attn.prefix_attention(
            q,
            jnp.concatenate([k_ctx, k_tail], axis=1),
            jnp.concatenate([v_ctx, v_tail], axis=1),
            ctx_len, k_ctx.shape[1],
        )
    return _pa.prefix_prefill_pallas(
        q, k_ctx, v_ctx, k_tail, v_tail, ctx_len,
        interpret=b is AttnBackend.PALLAS_INTERPRET,
    )


# --- pareto_rank -------------------------------------------------------------
def dominance_matrix(F, violation=None, interpret: bool | None = None):
    """(P, M) objectives -> (P, P) bool constrained-dominance matrix.

    On TPU this is the compiled Pallas ``pareto_rank`` kernel.  On CPU
    (``interpret=None`` auto-detection) it falls back to the broadcasted
    XLA dominance from ``repro.core.pareto`` — bit-identical (tested in
    tests/test_kernels.py) and much cheaper to compile than interpreter
    mode, which matters when NSGA-II vmaps the dominance over a scenario
    axis.  Pass ``interpret=True`` to force the Pallas interpreter (the
    kernel-parity tests do)."""
    if interpret is None and _interpret_default():
        from repro.core import pareto

        return pareto.dominance_matrix(
            jnp.asarray(F),
            None if violation is None else jnp.asarray(violation),
        )
    out = _rank.dominance_matrix_pallas(
        jnp.asarray(F),
        None if violation is None else jnp.asarray(violation),
        interpret=False if interpret is None else interpret,
    )
    return out.astype(jnp.bool_)


# --- dcim_mvm ----------------------------------------------------------------
@functools.partial(
    jax.jit,
    static_argnames=("B_x", "B_w", "k", "x_signed", "w_signed", "interpret"),
)
def dcim_mvm(
    x,
    w,
    B_x: int = 8,
    B_w: int = 8,
    k: int = 4,
    x_signed: bool = True,
    w_signed: bool = True,
    interpret: bool | None = None,
):
    """Exact integer matmul through the DCIM bit-serial dataflow.

    Off TPU (``interpret=None`` auto-detection) this dispatches to the
    XLA structural reference — the same bit-serial decomposition in
    plain jnp, bitwise identical to the kernel (tested in
    tests/test_kernels.py) and much faster than interpreter mode on
    CPU (jitted here: the decomposition's many slice/shift ops would
    otherwise pay per-op eager dispatch).  ``interpret=True`` forces
    the Pallas interpreter (parity tests do)."""
    if interpret is None and _interpret_default():
        return _ref.dcim_mvm_structural_ref(
            jnp.asarray(x), jnp.asarray(w), B_x=B_x, B_w=B_w, k=k,
            x_signed=x_signed, w_signed=w_signed,
        )
    return _mvm.dcim_mvm_pallas(
        jnp.asarray(x),
        jnp.asarray(w),
        B_x=B_x,
        B_w=B_w,
        k=k,
        x_signed=x_signed,
        w_signed=w_signed,
        interpret=False if interpret is None else interpret,
    )


# --- fp_prealign ---------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("H", "B_M", "interpret"))
def fp_prealign(x, H: int, B_M: int = 8, interpret: bool | None = None):
    """x: (M, K) f32, groups of H along K -> (mant (M, G, H) int32,
    group biased exponents (M, G) int32).

    Off TPU (``interpret=None``) dispatches to the frexp-based XLA
    reference (bitwise identical, tested in tests/test_kernels.py);
    ``interpret=True`` forces the Pallas interpreter."""
    M, K = x.shape
    assert K % H == 0, f"K={K} not divisible by group height H={H}"
    xg = jnp.asarray(x, jnp.float32).reshape(M, K // H, H)
    if interpret is None and _interpret_default():
        return _ref.fp_prealign_ref(xg, B_M=B_M)
    return _pre.fp_prealign_pallas(
        xg, B_M=B_M,
        interpret=False if interpret is None else interpret,
    )


# --- composed pre-aligned block-FP matmul (FP-DCIM pipeline) -------------------
@functools.partial(
    jax.jit, static_argnames=("H", "B_M", "B_w", "k", "interpret")
)
def dcim_fp_matmul(
    x,
    w,
    H: int = 64,
    B_M: int = 8,
    B_w: int = 8,
    k: int = 4,
    interpret: bool | None = None,
):
    """Full pre-aligned FP-DCIM pipeline (paper Fig. 3), end to end:

      1. online: pre-align input mantissas per H-group along K,
      2. offline: pre-align weight mantissas per H-group along K,
      3. integer mantissa MAC in the DCIM array (dcim_mvm per group),
      4. INT->FP conversion: scale each group's integer partial sum by
         2^(ex + ew) and accumulate in f32.

    x: (M, K) f32;  w: (K, N) f32;  returns (M, N) f32 approximating x @ w
    with block-FP (shared-group-exponent) numerics.
    """
    # ``interpret`` stays tri-state through the public dispatchers:
    # None auto-falls back to the XLA refs off TPU, True forces the
    # Pallas interpreter end to end (parity tests).
    interp = interpret
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and K % H == 0
    G = K // H

    mant_x, ex = fp_prealign(x, H, B_M, interpret=interp)          # (M,G,H),(M,G)
    mant_w, ew = fp_prealign(w.T, H, B_w, interpret=interp)        # (N,G,H),(N,G)

    narrow = (B_M + 1) + (B_w + 1) + math.ceil(math.log2(H)) <= 31

    if narrow:
        # Per-group integer MAC; exact in int32 (the hardware's B_r-wide
        # accumulator fits).  vmap over groups; each group is an exact
        # integer matmul through the bit-serial kernel.
        def group_mm(mx, mw):                                      # (M,H),(N,H)
            return dcim_mvm(
                mx, mw.T, B_x=B_M + 1, B_w=B_w + 1, k=k,
                x_signed=True, w_signed=True, interpret=interp,
            ).astype(jnp.float32)
    else:
        # Wide-mantissa path (FP32): split each mantissa into a signed
        # high half and an unsigned 12-bit low half; 4 partial integer
        # matmuls emulate the hardware's B_r-wide adder.  The 2^24/2^12
        # recombination happens in f32 (one extra rounding vs hardware,
        # bounded by 2^-24 relative).
        SPLIT = 12
        # Operand magnitudes: |hi| <= 2^(B-SPLIT), lo < 2^SPLIT.
        worst = 2 ** (2 * max(max(B_M, B_w) - SPLIT, SPLIT))
        if H * worst > 2**31:
            raise ValueError(
                f"H={H} too large for wide-mantissa emulation (B_M={B_M})"
            )

        def group_mm(mx, mw):
            xh, xl = mx >> SPLIT, mx & ((1 << SPLIT) - 1)
            wh, wl = mw >> SPLIT, mw & ((1 << SPLIT) - 1)

            def mm(a, b, bx, bw, xs, ws):
                return dcim_mvm(
                    a, b.T, B_x=bx, B_w=bw, k=k,
                    x_signed=xs, w_signed=ws, interpret=interp,
                ).astype(jnp.float32)

            hi_bits = max(B_M, B_w) + 1 - SPLIT + 1
            p_hh = mm(xh, wh, hi_bits, hi_bits, True, True)
            p_hl = mm(xh, wl, hi_bits, SPLIT, True, False)
            p_lh = mm(xl, wh, SPLIT, hi_bits, False, True)
            p_ll = mm(xl, wl, SPLIT, SPLIT, False, False)
            return (
                p_hh * float(2 ** (2 * SPLIT))
                + (p_hl + p_lh) * float(2**SPLIT)
                + p_ll
            )

    partials = jax.vmap(group_mm, in_axes=(1, 1))(mant_x, mant_w)  # (G,M,N)

    # INT->FP converter: 2^(ex+ew) group scale, remove the two mantissa
    # fixed-point offsets (B_M-1 / B_w-1) and the two IEEE biases (127).
    scale = jnp.exp2(
        ex[:, :, None].astype(jnp.float32)
        + ew.T[None, :, :].astype(jnp.float32)
        - (2 * 127 + (B_M - 1) + (B_w - 1))
    )                                                              # (M,G,N)
    out = jnp.sum(partials.transpose(1, 0, 2) * scale, axis=1)
    return out.astype(jnp.float32)


# ------------------------------ lint contract --------------------------------
from repro.analysis.registry import Built, PallasTrace, register_contract


@register_contract(
    "kernels.pallas",
    checks=("pallas", "precision"),
    description="every Pallas kernel traced at representative shapes: "
                "BlockSpec lane/sublane tiling, grid coverage of the "
                "padded arrays, interpreter-fallback accounting, and "
                "kernel-level precision hygiene (no f64, integer/low-"
                "precision dots declare their accumulator; register "
                "upcasts inside kernels are idiomatic, so the widening "
                "audit is off)",
)
def _build_kernels_contract() -> Built:
    from repro.kernels.pareto_rank import dominance_matrix_pallas
    from repro.kernels.selective_scan import selective_scan_pallas

    fallback = _interpret_default()
    traces = []

    F = jnp.zeros((130, 4), jnp.float32)
    traces.append(PallasTrace(
        "pareto_rank.dominance_matrix_pallas",
        jax.make_jaxpr(
            lambda f: dominance_matrix_pallas(f, interpret=True)
        )(F),
        interpret_fallback=fallback,
    ))

    x8 = jnp.zeros((32, 64), jnp.int32)
    w8 = jnp.zeros((64, 16), jnp.int32)
    traces.append(PallasTrace(
        "dcim_mvm.dcim_mvm_pallas",
        jax.make_jaxpr(
            lambda a, b: _mvm.dcim_mvm_pallas(
                a, b, B_x=8, B_w=8, k=4, interpret=True
            )
        )(x8, w8),
        interpret_fallback=fallback,
    ))

    xg = jnp.zeros((10, 3, 64), jnp.float32)
    traces.append(PallasTrace(
        "fp_prealign.fp_prealign_pallas",
        jax.make_jaxpr(
            lambda a: _pre.fp_prealign_pallas(a, B_M=8, interpret=True)
        )(xg),
        interpret_fallback=fallback,
    ))

    B, S, D, N = 2, 64, 128, 16
    traces.append(PallasTrace(
        "selective_scan.selective_scan_pallas",
        jax.make_jaxpr(
            lambda u, dt, b, c, a, d: selective_scan_pallas(
                u, dt, b, c, a, d, interpret=True
            )
        )(
            jnp.zeros((B, S, D), jnp.float32),
            jnp.zeros((B, S, D), jnp.float32),
            jnp.zeros((B, S, N), jnp.float32),
            jnp.zeros((B, S, N), jnp.float32),
            -jnp.ones((D, N), jnp.float32),
            jnp.zeros((D,), jnp.float32),
        ),
        interpret_fallback=fallback,
    ))

    # Fused paged-attention kernels, at TPU-representative shapes
    # (hd = 128 lanes).  Their block-table / position index maps take
    # scalar-prefetch refs, which the grid-coverage evaluator cannot
    # replay — that surfaces as a lint *warning*, by design.
    Bd, Hk, G, hd = 2, 2, 4, 128
    page, nb = 8, 3
    traces.append(PallasTrace(
        "paged_attention.paged_decode_gqa_pallas",
        jax.make_jaxpr(
            lambda q, kp, vp, bt, ps: _pa.paged_decode_gqa_pallas(
                q, kp, vp, bt, ps, interpret=True
            )
        )(
            jnp.zeros((Bd, 1, Hk * G, hd), jnp.float32),
            jnp.zeros((nb * Bd + 1, page, Hk, hd), jnp.bfloat16),
            jnp.zeros((nb * Bd + 1, page, Hk, hd), jnp.bfloat16),
            jnp.zeros((Bd, nb), jnp.int32),
            jnp.zeros((Bd,), jnp.int32),
        ),
        interpret_fallback=fallback,
    ))

    T, L = 8, 16
    traces.append(PallasTrace(
        "paged_attention.prefix_prefill_pallas",
        jax.make_jaxpr(
            lambda q, kc, vc, kt, vt, cl: _pa.prefix_prefill_pallas(
                q, kc, vc, kt, vt, cl, interpret=True
            )
        )(
            jnp.zeros((Bd, T, Hk * G, hd), jnp.float32),
            jnp.zeros((Bd, L, Hk, hd), jnp.bfloat16),
            jnp.zeros((Bd, L, Hk, hd), jnp.bfloat16),
            jnp.zeros((Bd, T, Hk, hd), jnp.bfloat16),
            jnp.zeros((Bd, T, Hk, hd), jnp.bfloat16),
            jnp.zeros((Bd,), jnp.int32),
        ),
        interpret_fallback=fallback,
    ))

    from repro.analysis.registry import PrecisionPolicy

    return Built(pallas=traces, precision=PrecisionPolicy(
        compute_dtype="float32", audit_widening=False,
    ))
