"""Public jit'd wrappers around the Pallas kernels.

On TPU the kernels run compiled (``interpret=False``); on CPU they run in
Pallas interpret mode, which lowers the kernel body to regular XLA ops —
bit-exact with the TPU path and still jit-compatible.  ``interpret`` is
auto-detected from the default backend unless forced.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import dcim_mvm as _mvm
from . import fp_prealign as _pre
from . import pareto_rank as _rank


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


# --- pareto_rank -------------------------------------------------------------
def dominance_matrix(F, violation=None, interpret: bool | None = None):
    """(P, M) objectives -> (P, P) bool constrained-dominance matrix.

    On TPU this is the compiled Pallas ``pareto_rank`` kernel.  On CPU
    (``interpret=None`` auto-detection) it falls back to the broadcasted
    XLA dominance from ``repro.core.pareto`` — bit-identical (tested in
    tests/test_kernels.py) and much cheaper to compile than interpreter
    mode, which matters when NSGA-II vmaps the dominance over a scenario
    axis.  Pass ``interpret=True`` to force the Pallas interpreter (the
    kernel-parity tests do)."""
    if interpret is None and _interpret_default():
        from repro.core import pareto

        return pareto.dominance_matrix(
            jnp.asarray(F),
            None if violation is None else jnp.asarray(violation),
        )
    out = _rank.dominance_matrix_pallas(
        jnp.asarray(F),
        None if violation is None else jnp.asarray(violation),
        interpret=False if interpret is None else interpret,
    )
    return out.astype(jnp.bool_)


# --- dcim_mvm ----------------------------------------------------------------
def dcim_mvm(
    x,
    w,
    B_x: int = 8,
    B_w: int = 8,
    k: int = 4,
    x_signed: bool = True,
    w_signed: bool = True,
    interpret: bool | None = None,
):
    """Exact integer matmul through the DCIM bit-serial dataflow."""
    return _mvm.dcim_mvm_pallas(
        jnp.asarray(x),
        jnp.asarray(w),
        B_x=B_x,
        B_w=B_w,
        k=k,
        x_signed=x_signed,
        w_signed=w_signed,
        interpret=_interpret_default() if interpret is None else interpret,
    )


# --- fp_prealign ---------------------------------------------------------------
def fp_prealign(x, H: int, B_M: int = 8, interpret: bool | None = None):
    """x: (M, K) f32, groups of H along K -> (mant (M, G, H) int32,
    group biased exponents (M, G) int32)."""
    M, K = x.shape
    assert K % H == 0, f"K={K} not divisible by group height H={H}"
    xg = jnp.asarray(x, jnp.float32).reshape(M, K // H, H)
    return _pre.fp_prealign_pallas(
        xg, B_M=B_M,
        interpret=_interpret_default() if interpret is None else interpret,
    )


# --- composed pre-aligned block-FP matmul (FP-DCIM pipeline) -------------------
@functools.partial(
    jax.jit, static_argnames=("H", "B_M", "B_w", "k", "interpret")
)
def dcim_fp_matmul(
    x,
    w,
    H: int = 64,
    B_M: int = 8,
    B_w: int = 8,
    k: int = 4,
    interpret: bool | None = None,
):
    """Full pre-aligned FP-DCIM pipeline (paper Fig. 3), end to end:

      1. online: pre-align input mantissas per H-group along K,
      2. offline: pre-align weight mantissas per H-group along K,
      3. integer mantissa MAC in the DCIM array (dcim_mvm per group),
      4. INT->FP conversion: scale each group's integer partial sum by
         2^(ex + ew) and accumulate in f32.

    x: (M, K) f32;  w: (K, N) f32;  returns (M, N) f32 approximating x @ w
    with block-FP (shared-group-exponent) numerics.
    """
    interp = _interpret_default() if interpret is None else interpret
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and K % H == 0
    G = K // H

    mant_x, ex = fp_prealign(x, H, B_M, interpret=interp)          # (M,G,H),(M,G)
    mant_w, ew = fp_prealign(w.T, H, B_w, interpret=interp)        # (N,G,H),(N,G)

    import math

    narrow = (B_M + 1) + (B_w + 1) + math.ceil(math.log2(H)) <= 31

    if narrow:
        # Per-group integer MAC; exact in int32 (the hardware's B_r-wide
        # accumulator fits).  vmap over groups; each group is an exact
        # integer matmul through the bit-serial kernel.
        def group_mm(mx, mw):                                      # (M,H),(N,H)
            return _mvm.dcim_mvm_pallas(
                mx, mw.T, B_x=B_M + 1, B_w=B_w + 1, k=k,
                x_signed=True, w_signed=True, interpret=interp,
            ).astype(jnp.float32)
    else:
        # Wide-mantissa path (FP32): split each mantissa into a signed
        # high half and an unsigned 12-bit low half; 4 partial integer
        # matmuls emulate the hardware's B_r-wide adder.  The 2^24/2^12
        # recombination happens in f32 (one extra rounding vs hardware,
        # bounded by 2^-24 relative).
        SPLIT = 12
        # Operand magnitudes: |hi| <= 2^(B-SPLIT), lo < 2^SPLIT.
        worst = 2 ** (2 * max(max(B_M, B_w) - SPLIT, SPLIT))
        if H * worst > 2**31:
            raise ValueError(
                f"H={H} too large for wide-mantissa emulation (B_M={B_M})"
            )

        def group_mm(mx, mw):
            xh, xl = mx >> SPLIT, mx & ((1 << SPLIT) - 1)
            wh, wl = mw >> SPLIT, mw & ((1 << SPLIT) - 1)

            def mm(a, b, bx, bw, xs, ws):
                return _mvm.dcim_mvm_pallas(
                    a, b.T, B_x=bx, B_w=bw, k=k,
                    x_signed=xs, w_signed=ws, interpret=interp,
                ).astype(jnp.float32)

            hi_bits = max(B_M, B_w) + 1 - SPLIT + 1
            p_hh = mm(xh, wh, hi_bits, hi_bits, True, True)
            p_hl = mm(xh, wl, hi_bits, SPLIT, True, False)
            p_lh = mm(xl, wh, SPLIT, hi_bits, False, True)
            p_ll = mm(xl, wl, SPLIT, SPLIT, False, False)
            return (
                p_hh * float(2 ** (2 * SPLIT))
                + (p_hl + p_lh) * float(2**SPLIT)
                + p_ll
            )

    partials = jax.vmap(group_mm, in_axes=(1, 1))(mant_x, mant_w)  # (G,M,N)

    # INT->FP converter: 2^(ex+ew) group scale, remove the two mantissa
    # fixed-point offsets (B_M-1 / B_w-1) and the two IEEE biases (127).
    scale = jnp.exp2(
        ex[:, :, None].astype(jnp.float32)
        + ew.T[None, :, :].astype(jnp.float32)
        - (2 * 127 + (B_M - 1) + (B_w - 1))
    )                                                              # (M,G,N)
    out = jnp.sum(partials.transpose(1, 0, 2) * scale, axis=1)
    return out.astype(jnp.float32)


# ------------------------------ lint contract --------------------------------
from repro.analysis.registry import Built, PallasTrace, register_contract


@register_contract(
    "kernels.pallas",
    checks=("pallas",),
    description="every Pallas kernel traced at representative shapes: "
                "BlockSpec lane/sublane tiling, grid coverage of the "
                "padded arrays, interpreter-fallback accounting",
)
def _build_kernels_contract() -> Built:
    from repro.kernels.pareto_rank import dominance_matrix_pallas
    from repro.kernels.selective_scan import selective_scan_pallas

    fallback = _interpret_default()
    traces = []

    F = jnp.zeros((130, 4), jnp.float32)
    traces.append(PallasTrace(
        "pareto_rank.dominance_matrix_pallas",
        jax.make_jaxpr(
            lambda f: dominance_matrix_pallas(f, interpret=True)
        )(F),
        interpret_fallback=fallback,
    ))

    x8 = jnp.zeros((32, 64), jnp.int32)
    w8 = jnp.zeros((64, 16), jnp.int32)
    traces.append(PallasTrace(
        "dcim_mvm.dcim_mvm_pallas",
        jax.make_jaxpr(
            lambda a, b: _mvm.dcim_mvm_pallas(
                a, b, B_x=8, B_w=8, k=4, interpret=True
            )
        )(x8, w8),
        interpret_fallback=fallback,
    ))

    xg = jnp.zeros((10, 3, 64), jnp.float32)
    traces.append(PallasTrace(
        "fp_prealign.fp_prealign_pallas",
        jax.make_jaxpr(
            lambda a: _pre.fp_prealign_pallas(a, B_M=8, interpret=True)
        )(xg),
        interpret_fallback=fallback,
    ))

    B, S, D, N = 2, 64, 128, 16
    traces.append(PallasTrace(
        "selective_scan.selective_scan_pallas",
        jax.make_jaxpr(
            lambda u, dt, b, c, a, d: selective_scan_pallas(
                u, dt, b, c, a, d, interpret=True
            )
        )(
            jnp.zeros((B, S, D), jnp.float32),
            jnp.zeros((B, S, D), jnp.float32),
            jnp.zeros((B, S, N), jnp.float32),
            jnp.zeros((B, S, N), jnp.float32),
            -jnp.ones((D, N), jnp.float32),
            jnp.zeros((D,), jnp.float32),
        ),
        interpret_fallback=fallback,
    ))

    return Built(pallas=traces)
