"""Pallas kernel: fused Mamba-1 selective scan.

The associative-scan formulation moves (S, d_inner, d_state) arrays
through log2(chunk) combine levels — ~16 HBM passes over the state
tensor (the dominant memory term of the SSM cells, see EXPERIMENTS.md
§Perf).  The TPU-native fix is a fused kernel: the recurrent state
h (bd, N) lives in VMEM scratch for the whole sequence; u/dt/B/C stream
through once and y streams out once — optimal HBM traffic.

Grid: (batch, d_inner/bd, S/st) with the sequence dimension innermost;
the VMEM scratch state persists across the sequential S grid steps (the
standard TPU accumulator pattern).  Inside a block a fori_loop walks the
st timesteps with (bd, N) VPU updates:

    h   = exp(dt * A) * h + (dt * u) * B_t
    y_t = h . C_t + D * u_t

Validated against the pure-jnp sequential oracle (ref.selective_scan_ref)
and cross-checked against the associative-scan path in models/mamba.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

BLOCK_D = 256
BLOCK_S = 512


def _scan_kernel(u_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, h0_ref,
                 y_ref, hout_ref, h_scr):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        h_scr[...] = h0_ref[0]                        # (bd, N)

    u = u_ref[0]                                      # (st, bd)
    dt = dt_ref[0]                                    # (st, bd)
    bmat = b_ref[0]                                   # (st, N)
    cmat = c_ref[0]                                   # (st, N)
    a = a_ref[...]                                    # (bd, N)
    d = d_ref[...]                                    # (bd,)
    st = u.shape[0]

    def step(t, carry):
        h, y = carry
        dt_t = dt[t][:, None]                         # (bd, 1)
        u_t = u[t][:, None]
        h = jnp.exp(dt_t * a) * h + (dt_t * u_t) * bmat[t][None, :]
        y_t = jnp.sum(h * cmat[t][None, :], axis=-1) + d * u[t]
        return h, y.at[t].set(y_t)

    y0 = jnp.zeros((st, u.shape[1]), jnp.float32)
    h, y = jax.lax.fori_loop(0, st, step, (h_scr[...], y0))
    h_scr[...] = h
    y_ref[0] = y
    hout_ref[0] = h


@functools.partial(
    jax.jit, static_argnames=("block_d", "block_s", "interpret")
)
def selective_scan_pallas(
    u: jnp.ndarray,       # (B, S, D)
    dt: jnp.ndarray,      # (B, S, D)
    B_c: jnp.ndarray,     # (B, S, N)
    C_c: jnp.ndarray,     # (B, S, N)
    A: jnp.ndarray,       # (D, N), negative
    D_skip: jnp.ndarray,  # (D,)
    h0: jnp.ndarray | None = None,   # (B, D, N)
    block_d: int = BLOCK_D,
    block_s: int = BLOCK_S,
    interpret: bool = True,
):
    """Returns (y (B, S, D) f32, h_last (B, D, N) f32)."""
    Bsz, S, D = u.shape
    N = B_c.shape[-1]
    bd = min(block_d, D)
    st = min(block_s, S)
    assert D % bd == 0 and S % st == 0, (D, bd, S, st)
    if h0 is None:
        h0 = jnp.zeros((Bsz, D, N), jnp.float32)

    y, h_last = pl.pallas_call(
        _scan_kernel,
        grid=(Bsz, D // bd, S // st),
        in_specs=[
            pl.BlockSpec((1, st, bd), lambda b, di, si: (b, si, di)),  # u
            pl.BlockSpec((1, st, bd), lambda b, di, si: (b, si, di)),  # dt
            pl.BlockSpec((1, st, N), lambda b, di, si: (b, si, 0)),    # B
            pl.BlockSpec((1, st, N), lambda b, di, si: (b, si, 0)),    # C
            pl.BlockSpec((bd, N), lambda b, di, si: (di, 0)),          # A
            pl.BlockSpec((bd,), lambda b, di, si: (di,)),              # D
            pl.BlockSpec((1, bd, N), lambda b, di, si: (b, di, 0)),    # h0
        ],
        out_specs=[
            pl.BlockSpec((1, st, bd), lambda b, di, si: (b, si, di)),
            pl.BlockSpec((1, bd, N), lambda b, di, si: (b, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, S, D), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, D, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(
        u.astype(jnp.float32), dt.astype(jnp.float32),
        B_c.astype(jnp.float32), C_c.astype(jnp.float32),
        A.astype(jnp.float32), D_skip.astype(jnp.float32),
        h0.astype(jnp.float32),
    )
    return y, h_last
