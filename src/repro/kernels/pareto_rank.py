"""Pallas kernel: pairwise (constrained-)Pareto dominance matrix.

The NSGA-II hot spot is the O(P^2 * M) dominance computation performed
every generation.  On TPU we tile the P x P comparison space into
(BI, BJ) VMEM blocks; each grid cell loads a (BI, M) and a (BJ, M) strip
of the objective matrix (M is tiny — 4 for SEGA-DCIM), broadcasts to
(BI, BJ, M) in VREGs and reduces over M on the VPU.  Output is an int8
matrix D with D[i, j] == 1 iff candidate i constrained-dominates j.

Constrained domination (Deb 2002) folds the violation scalar in:
  i dominates j  <=>  (feas_i & feas_j & pareto_dom(i, j)) | (v_i < v_j)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default VMEM tile: 128 x 128 comparisons; a (128, M=4) f32 strip is
# 2 KiB, the int8 output tile is 16 KiB — comfortably within VMEM.
BLOCK_I = 128
BLOCK_J = 128


def _dominance_kernel(fi_ref, fj_ref, vi_ref, vj_ref, out_ref):
    fi = fi_ref[...]          # (BI, M) objectives of candidates i
    fj = fj_ref[...]          # (BJ, M) objectives of candidates j
    vi = vi_ref[...]          # (BI,)   constraint violation of i
    vj = vj_ref[...]          # (BJ,)   violation of j

    le = jnp.all(fi[:, None, :] <= fj[None, :, :], axis=-1)   # (BI, BJ)
    lt = jnp.any(fi[:, None, :] < fj[None, :, :], axis=-1)
    pdom = le & lt

    feas_i = (vi <= 0.0)[:, None]
    feas_j = (vj <= 0.0)[None, :]
    cdom = (feas_i & feas_j & pdom) | (vi[:, None] < vj[None, :])
    out_ref[...] = cdom.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("block_i", "block_j", "interpret"))
def dominance_matrix_pallas(
    F: jnp.ndarray,
    violation: jnp.ndarray | None = None,
    block_i: int = BLOCK_I,
    block_j: int = BLOCK_J,
    interpret: bool = True,
) -> jnp.ndarray:
    """(P, M) objectives [+ (P,) violation] -> (P, P) int8 dominance matrix.

    Inputs are padded to the block grid with +inf objectives / +inf
    violation; padded rows dominate nothing and the padded region is
    sliced away, so results are exact for any P.
    """
    P, M = F.shape
    F = jnp.where(jnp.isnan(F), jnp.inf, F.astype(jnp.float32))
    v = (
        jnp.zeros((P,), jnp.float32)
        if violation is None
        else violation.astype(jnp.float32)
    )

    Pi = pl.cdiv(P, block_i) * block_i
    Pj = pl.cdiv(P, block_j) * block_j
    Ppad = max(Pi, Pj)
    Fp = jnp.full((Ppad, M), jnp.inf, jnp.float32).at[:P].set(F)
    vp = jnp.full((Ppad,), jnp.float32(jnp.inf)).at[:P].set(v)

    grid = (Ppad // block_i, Ppad // block_j)
    out = pl.pallas_call(
        _dominance_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_i, M), lambda i, j: (i, 0)),
            pl.BlockSpec((block_j, M), lambda i, j: (j, 0)),
            pl.BlockSpec((block_i,), lambda i, j: (i,)),
            pl.BlockSpec((block_j,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_i, block_j), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Ppad, Ppad), jnp.int8),
        interpret=interpret,
    )(Fp, Fp, vp, vp)
    return out[:P, :P]
