"""Pallas kernel: bit-serial DCIM matrix-vector/matrix multiply.

TPU-native re-expression of the paper's multiply-based DCIM dataflow
(Fig. 3/5).  The hardware streams ``k`` input bits per cycle against
1-bit weight planes; a column adder tree sums H products, the shift
accumulator folds the B_x/k input slices, and the result-fusion unit
folds the B_w weight bit-planes.  On TPU the natural mapping is:

  * weight bit-plane  V_b = (W >> b) & 1          (VPU bit ops, in VMEM)
  * input k-bit slice U_s = (U >> s*k) & (2^k-1)
  * "adder tree"      = one MXU matmul  U_s @ V_b  (int32 accumulate)
  * shift-accumulate  = sum_s 2^(k*s) * (.)
  * result fusion     = sum_b 2^b     * (.)

Signedness is handled exactly with two's-complement correction terms:
with U = X mod 2^Bx, V = W mod 2^Bw, neg_x = [X<0], neg_w = [W<0]:

  X @ W = U@V - 2^Bw * U@neg_w - 2^Bx * neg_x@V + 2^(Bx+Bw) * neg_x@neg_w

so the kernel's output equals an exact integer matmul — which is what a
full-precision DCIM macro computes.  The grid is (M/BM, N/BN, K/BK) with
int32 accumulation over the K dimension in VMEM.

Validity range: |Y| < 2^31.  Guaranteed when K * 2^(Bx+Bw) < 2^31, e.g.
any K <= 32768 for INT8 x INT8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned tiles.
BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128


def _dcim_mvm_kernel(x_ref, w_ref, out_ref, *, B_x, B_w, k, x_signed, w_signed):
    x = x_ref[...].astype(jnp.int32)            # (BM, BK)
    w = w_ref[...].astype(jnp.int32)            # (BK, BN)

    # Two's-complement unsigned views.
    U = jnp.bitwise_and(x, (1 << B_x) - 1)
    V = jnp.bitwise_and(w, (1 << B_w) - 1)

    def dot(a, b):
        # int32 x int32 -> int32 contraction; the MXU path on TPU.
        return jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
        )

    n_slices = -(-B_x // k)                      # ceil(B_x / k)
    acc = jnp.zeros(out_ref.shape, jnp.int32)
    # Result fusion over weight bit-planes x shift-accumulate over slices.
    for b in range(B_w):
        v_plane = jnp.bitwise_and(jnp.right_shift(V, b), 1)
        for s in range(n_slices):
            u_slice = jnp.bitwise_and(
                jnp.right_shift(U, s * k), (1 << k) - 1
            )
            acc = acc + (dot(u_slice, v_plane) << (b + s * k))

    # Sign-correction matmuls (exact two's complement).
    if w_signed:
        neg_w = (w < 0).astype(jnp.int32)
        acc = acc - (dot(U, neg_w) << B_w)
    if x_signed:
        neg_x = (x < 0).astype(jnp.int32)
        acc = acc - (dot(neg_x, V) << B_x)
        if w_signed:
            acc = acc + (dot(neg_x, neg_w) << (B_x + B_w))

    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(pl.program_id(2) != 0)
    def _accum():
        out_ref[...] += acc


@functools.partial(
    jax.jit,
    static_argnames=(
        "B_x", "B_w", "k", "x_signed", "w_signed",
        "block_m", "block_n", "block_k", "interpret",
    ),
)
def dcim_mvm_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    B_x: int = 8,
    B_w: int = 8,
    k: int = 4,
    x_signed: bool = True,
    w_signed: bool = True,
    block_m: int = BLOCK_M,
    block_n: int = BLOCK_N,
    block_k: int = BLOCK_K,
    interpret: bool = True,
) -> jnp.ndarray:
    """Exact integer matmul via the DCIM bit-serial dataflow.

    x: (M, K) int32 in [-2^(Bx-1), 2^(Bx-1)) (or [0, 2^Bx) unsigned)
    w: (K, N) int32 in the analogous B_w range
    returns (M, N) int32 == x @ w
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    Mp = pl.cdiv(M, block_m) * block_m
    Np = pl.cdiv(N, block_n) * block_n
    Kp = pl.cdiv(K, block_k) * block_k
    xp = jnp.zeros((Mp, Kp), jnp.int32).at[:M, :K].set(x.astype(jnp.int32))
    wp = jnp.zeros((Kp, Np), jnp.int32).at[:K, :N].set(w.astype(jnp.int32))

    kernel = functools.partial(
        _dcim_mvm_kernel,
        B_x=B_x, B_w=B_w, k=k, x_signed=x_signed, w_signed=w_signed,
    )
    out = pl.pallas_call(
        kernel,
        grid=(Mp // block_m, Np // block_n, Kp // block_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.int32),
        interpret=interpret,
    )(xp, wp)
    return out[:M, :N]
