"""Pallas kernel: floating-point pre-alignment (paper Fig. 3, §III-A).

Implements the FP Pre-alignment module: for each group of H values along
the reduction axis, (1) a comparison tree finds the maximum exponent
X_Emax, (2) each value's mantissa (hidden bit included, two's-complement
signed, B_M bits) is barrel-shifted right by (X_Emax - X_E).  The aligned
mantissas can then feed the integer DCIM array directly; the group
exponent is consumed by the INT->FP converter after accumulation.

On TPU this is pure VPU work on f32 bit patterns in VMEM: exponent
extraction is a shift/mask of the bitcast int32, the max-tree is a
reduction over the trailing (H) axis, and the alignment shift is an
arithmetic right-shift.  Grid tiles (rows x groups); each block holds
(BM, BG, H) values.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 8
BLOCK_GROUPS = 8


def _prealign_kernel(x_ref, mant_ref, emax_ref, *, B_M):
    x = x_ref[...]                                   # (BM, BG, H) f32
    bits = jax.lax.bitcast_convert_type(x, jnp.int32)
    sign = jnp.right_shift(bits, 31) & 1
    exp = jnp.right_shift(bits, 23) & 0xFF           # biased exponent
    frac = bits & 0x7FFFFF

    # B_M-bit magnitude mantissa including the hidden bit.  IEEE zero /
    # subnormals (exp == 0) carry no hidden bit -> mantissa 0 (hardware
    # flushes subnormals, as does the paper's datapath).
    full = jnp.where(exp > 0, frac | (1 << 23), 0)
    mant = jnp.right_shift(full, 23 - (B_M - 1))     # in [2^(B_M-1), 2^B_M)
    mant = jnp.where(sign == 1, -mant, mant)         # two's complement

    emax = jnp.max(exp, axis=-1, keepdims=True)      # comparison tree
    shift = jnp.minimum(emax - exp, 31)
    aligned = jnp.right_shift(mant, shift)           # arithmetic shift

    mant_ref[...] = aligned
    emax_ref[...] = emax[..., 0]


@functools.partial(
    jax.jit,
    static_argnames=("B_M", "block_rows", "block_groups", "interpret"),
)
def fp_prealign_pallas(
    x: jnp.ndarray,
    B_M: int = 8,
    block_rows: int = BLOCK_ROWS,
    block_groups: int = BLOCK_GROUPS,
    interpret: bool = True,
):
    """x: (M, G, H) float32 -> (aligned int32 mantissas (M, G, H),
    biased group exponents (M, G) int32)."""
    M, G, H = x.shape
    Mp = pl.cdiv(M, block_rows) * block_rows
    Gp = pl.cdiv(G, block_groups) * block_groups
    xp = jnp.zeros((Mp, Gp, H), jnp.float32).at[:M, :G].set(
        x.astype(jnp.float32)
    )
    kernel = functools.partial(_prealign_kernel, B_M=B_M)
    mant, emax = pl.pallas_call(
        kernel,
        grid=(Mp // block_rows, Gp // block_groups),
        in_specs=[
            pl.BlockSpec((block_rows, block_groups, H), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, block_groups, H), lambda i, j: (i, j, 0)),
            pl.BlockSpec((block_rows, block_groups), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Mp, Gp, H), jnp.int32),
            jax.ShapeDtypeStruct((Mp, Gp), jnp.int32),
        ],
        interpret=interpret,
    )(xp)
    return mant[:M, :G], emax[:M, :G]
