"""Pallas TPU kernels for SEGA-DCIM hot spots (validated on CPU via
interpret mode): pareto_rank (NSGA-II dominance), dcim_mvm (bit-serial
DCIM MAC), fp_prealign (FP pre-alignment)."""
from . import ops, ref  # noqa: F401
