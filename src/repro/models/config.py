"""LMConfig: one config dataclass covering all 10 assigned architectures
(dense / GQA / MLA / MoE / SSM / hybrid / external-embed backbones)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from .attention import MLAConfig
from .mamba import SSMConfig
from .moe import MoEConfig

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # positions / norms / activations
    qkv_bias: bool = False
    pos: str = "rope"                 # rope | mrope | sinusoidal
    rope_theta: float = 1_000_000.0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    norm: str = "rms"                 # rms | ln
    act: str = "swiglu"               # swiglu | gelu

    # mixer structure
    attn_kind: str = "gqa"            # gqa | mla
    mixer: str = "attn"               # attn | mamba | hybrid
    hybrid_period: int = 8            # jamba: 1 attn : 7 mamba
    hybrid_attn_index: int = 4        # position of the attn layer in a period
    ffn_kind: str = "dense"           # dense | moe | none
    moe_every: int = 1                # MoE on layers i with i % moe_every == moe_offset
    moe_offset: int = 0

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None

    tie_embeddings: bool = False
    external_embed: bool = False      # vlm/audio stub: inputs are embeddings
    mtp: bool = False                 # DeepSeek multi-token prediction head
    mtp_weight: float = 0.3
    aux_loss_weight: float = 0.001

    # numerics / execution
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = False
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 512
    mamba_chunk: int = 128
    loss_chunk: int = 0               # 0: unchunked CE
    ssm_impl: str = "assoc"           # assoc | pallas (fused kernel, fwd-only)
    cache_dtype: str = "bfloat16"
    # Paged-attention implementation: auto | xla | pallas | pallas_interpret
    # (kernels.ops.AttnBackend; auto = fused Pallas kernels on TPU, the
    # bit-identical XLA gather+attend reference elsewhere).
    attn_backend: str = "auto"

    @property
    def pdtype(self):
        return DTYPES[self.param_dtype]

    @property
    def cdtype(self):
        return DTYPES[self.compute_dtype]

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    # --- per-layer kinds ------------------------------------------------------
    def mixer_kind(self, i: int) -> str:
        if self.mixer == "attn":
            return "mla" if self.attn_kind == "mla" else "gqa"
        if self.mixer == "mamba":
            return "mamba"
        if i % self.hybrid_period == self.hybrid_attn_index:
            return "mla" if self.attn_kind == "mla" else "gqa"
        return "mamba"

    def ffn_of(self, i: int) -> str:
        if self.ffn_kind == "none":
            return "none"
        if self.ffn_kind == "moe" and (i % self.moe_every == self.moe_offset):
            return "moe"
        return "dense"

    def layer_kinds(self):
        return [(self.mixer_kind(i), self.ffn_of(i)) for i in range(self.n_layers)]

    def scan_period(self) -> int:
        """Smallest period p such that layer kinds repeat with period p and
        p divides n_layers — the unroll size inside the layer scan."""
        kinds = self.layer_kinds()
        for p in range(1, self.n_layers + 1):
            if self.n_layers % p:
                continue
            if all(kinds[i] == kinds[i % p] for i in range(self.n_layers)):
                return p
        return self.n_layers  # pragma: no cover

    def validate(self):
        assert self.d_model % self.n_heads == 0 or self.head_dim, self.name
        assert self.n_heads % max(self.n_kv, 1) == 0, "GQA group must divide"
        if self.mixer in ("mamba", "hybrid"):
            assert self.ssm is not None, "ssm config required"
        if self.ffn_kind == "moe":
            assert self.moe is not None
        if self.attn_kind == "mla":
            assert self.mla is not None
        if self.pos == "mrope":
            assert sum(self.mrope_sections) == self.hd // 2
        assert self.attn_backend in ("auto", "xla", "pallas", "pallas_interpret"), (
            self.attn_backend
        )
        return self
