"""Attention mixers: chunked-flash GQA (online softmax, O(chunk^2) memory)
and DeepSeek-style MLA (low-rank Q/KV, absorbed decode).

Layouts: activations are (B, S, H, hd); caches are (B, S_max, Hk, hd)
(GQA) or (B, S_max, r_kv)/(B, S_max, d_rope) (MLA compressed cache —
the whole point of MLA).

The paged serving paths (``*_apply_decode_paged`` / ``*_apply_prefix``)
route their attention core through ``repro.kernels.ops`` behind the
``AttnBackend`` enum (``cfg.attn_backend``): the fused Pallas
paged-attention kernels on TPU, this module's gather+attend reference
elsewhere — bitwise identical by construction (single-normalization
softmax on both sides; asserted in tests/test_paged_attention.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import repl_act, shard_act
from repro.kernels import ops as kops
from . import common
from .common import apply_mrope, apply_rope, dense, dense_init

NEG_INF = -1e30


# =============================== chunked flash ===============================
@common.in_island("attn")
def flash_attention(
    q: jnp.ndarray,            # (B, Sq, H, hd)
    k: jnp.ndarray,            # (B, Sk, Hk, hd)
    v: jnp.ndarray,            # (B, Sk, Hk, hdv)
    q_offset=0,                # global position of q[0] (causal masking)
    kv_valid: Optional[jnp.ndarray] = None,   # number of valid kv positions
    causal: bool = True,
    q_chunk: int = 512,
    kv_chunk: int = 512,
) -> jnp.ndarray:
    B, Sq, H, hd = q.shape
    _, Sk, Hk, hdv = v.shape
    G = H // Hk
    scale = 1.0 / math.sqrt(q.shape[-1])

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    # Pad both sequence dims up to chunk multiples; padded KV is masked
    # out via kv_valid, padded Q rows are sliced off the output.
    Sq_p = -(-Sq // q_chunk) * q_chunk
    Sk_p = -(-Sk // kv_chunk) * kv_chunk
    if Sk_p != Sk:
        kv_valid = jnp.minimum(
            Sk if kv_valid is None else kv_valid, Sk
        )
        k = jnp.pad(k, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    nq, nk = Sq_p // q_chunk, Sk_p // kv_chunk

    qg = (q * scale).reshape(B, nq, q_chunk, Hk, G, hd).swapaxes(0, 1)
    kg = k.reshape(B, nk, kv_chunk, Hk, hd).swapaxes(0, 1)
    vg = v.reshape(B, nk, kv_chunk, Hk, hdv).swapaxes(0, 1)

    kpos_base = jnp.arange(kv_chunk)
    qpos_base = jnp.arange(q_chunk)

    def attend_q_chunk(qi, qc, kg_use, vg_use):
        """One q chunk against kv chunks [0, kg_use.shape[0])."""
        qpos = q_offset + qi * q_chunk + qpos_base      # (qc,)

        def kv_body(carry, kx):
            m, l, o = carry
            kj, kc, vc = kx
            s = jnp.einsum(
                "bqhgd,bchd->bhgqc", qc, kc,
                preferred_element_type=jnp.float32,
            )                                           # (B, Hk, G, qc, kc)
            kpos = kj * kv_chunk + kpos_base
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if kv_valid is not None:
                mask &= kpos[None, :] < kv_valid
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # NB: fully-masked rows have s == m_new == NEG_INF; the explicit
            # re-mask keeps exp(0) == 1 from leaking into l/o.
            p = jnp.where(
                mask[None, None, None], jnp.exp(s - m_new[..., None]), 0.0
            )
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum(
                "bhgqc,bchd->bqhgd", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32,
            )
            o_new = o * alpha.transpose(0, 3, 1, 2)[..., None] + pv
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, Hk, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hk, G, q_chunk), jnp.float32)
        o0 = jnp.zeros((B, q_chunk, Hk, G, hdv), jnp.float32)
        (m, l, o), _ = jax.lax.scan(
            kv_body, (m0, l0, o0),
            (jnp.arange(kg_use.shape[0]), kg_use, vg_use),
        )
        denom = jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        # Cast to the output dtype *inside* the q-chunk body: the scan then
        # stacks bf16 chunks instead of f32 + a full-stack convert after
        # (2x stacked-buffer traffic, §Perf iteration I4).
        return (o / denom).astype(v.dtype)

    if causal and 1 < nq <= 16 and isinstance(q_offset, int) and q_offset == 0:
        # Causal chunk skipping (§Perf I7): q chunk qi only attends kv
        # chunks 0..qi.  Unrolling the q loop lets each inner scan stop at
        # the diagonal — ~2x less attention compute/traffic than masking
        # all nk chunks.  Only worth the HLO-size cost for small nq.
        outs = []
        for qi in range(nq):
            # last q position in this chunk is (qi+1)*q_chunk - 1; it may
            # attend kv positions <= itself -> chunks [0, ceil(.../kc)).
            k_hi = min(-(-((qi + 1) * q_chunk) // kv_chunk), nk)
            k_hi = max(k_hi, 1)
            outs.append(attend_q_chunk(qi, qg[qi], kg[:k_hi], vg[:k_hi]))
        out = jnp.stack(outs, 0).swapaxes(0, 1).reshape(B, Sq_p, H, hdv)
        return out[:, :Sq]

    def q_body(_, qx):
        qi, qc = qx
        return None, attend_q_chunk(qi, qc, kg, vg)

    _, out = jax.lax.scan(q_body, None, (jnp.arange(nq), qg))
    out = out.swapaxes(0, 1).reshape(B, Sq_p, H, hdv)
    return out[:, :Sq]


@common.in_island("attn")
def decode_attention(
    q: jnp.ndarray,            # (B, 1, H, hd)
    k_cache: jnp.ndarray,      # (B, S_max, Hk, hd)
    v_cache: jnp.ndarray,      # (B, S_max, Hk, hdv)
    pos,                       # current length (q is at index pos):
                               # scalar, or (B,) per-row positions
) -> jnp.ndarray:
    B, _, H, hd = q.shape
    _, S, Hk, hdv = v_cache.shape
    G = H // Hk
    scale = 1.0 / math.sqrt(hd)
    qg = (q * scale).reshape(B, Hk, G, hd)
    s = jnp.einsum(
        "bhgd,bshd->bhgs", qg, k_cache, preferred_element_type=jnp.float32
    )
    if jnp.ndim(pos) == 1:
        mask = jnp.arange(S)[None, :] <= pos[:, None]       # (B, S)
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    else:
        mask = jnp.arange(S) <= pos
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return o.reshape(B, 1, H, hdv)


@common.in_island("attn")
def prefix_attention(
    q: jnp.ndarray,            # (B, T, H, hd) tail queries
    k_all: jnp.ndarray,        # (B, L + T, Hk, hd)  [ctx pages ; tail]
    v_all: jnp.ndarray,        # (B, L + T, Hk, hdv)
    ctx_len: jnp.ndarray,      # (B,) valid context positions (0 disables ctx)
    L: int,                    # static context capacity (ctx rows in k_all)
) -> jnp.ndarray:
    """Attention for tail-only prefill over a reused prefix.

    Keys are the concatenation of a gathered page context (rows
    ``[0, L)``, valid where ``j < ctx_len[b]``) and the tail's own K/V
    (rows ``[L, L+T)``, causal within the tail).  Every query attends at
    least its own tail position, so the softmax is never fully masked
    even for ``ctx_len == 0`` rows (burst members without a prefix hit)
    or right-padded tail rows.  One plain masked softmax — prefill runs
    once per request, so O(T * (L+T)) score memory is acceptable where
    the chunked-flash path would need an lse-merge."""
    B, T, H, hd = q.shape
    Hk = k_all.shape[2]
    G = H // Hk
    scale = 1.0 / math.sqrt(hd)
    qg = (q * scale).reshape(B, T, Hk, G, hd)
    s = jnp.einsum(
        "bqhgd,bshd->bhgqs", qg, k_all, preferred_element_type=jnp.float32
    )                                                   # (B, Hk, G, T, L+T)
    mask_ctx = jnp.arange(L)[None, :] < ctx_len[:, None]          # (B, L)
    mask_tail = jnp.arange(T)[None, :] <= jnp.arange(T)[:, None]  # (T, T)
    mask = jnp.concatenate(
        [
            jnp.broadcast_to(mask_ctx[:, None, :], (B, T, L)),
            jnp.broadcast_to(mask_tail[None], (B, T, T)),
        ],
        axis=-1,
    )                                                   # (B, T, L+T)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bhgqs,bshd->bqhgd", p.astype(v_all.dtype), v_all,
        preferred_element_type=jnp.float32,
    )
    return o.reshape(B, T, H, v_all.shape[-1])


def _gather_pages(pages: jnp.ndarray, block_table: jnp.ndarray) -> jnp.ndarray:
    """(n_pages, page, ...) pages + (B, nb) block table -> contiguous
    per-slot views (B, nb * page, ...)."""
    g = pages[block_table]                      # (B, nb, page, ...)
    return g.reshape(g.shape[0], -1, *g.shape[3:])


# ================================= GQA =======================================
def gqa_init(key, cfg, dtype):
    hd = cfg.head_dim or cfg.d_model // cfg.n_heads
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, dtype, cfg.qkv_bias),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv * hd, dtype, cfg.qkv_bias),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv * hd, dtype, cfg.qkv_bias),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, dtype, False),
    }


def _positions(cfg, B, S, offset, position_ids):
    if position_ids is not None:
        return position_ids
    pos = jnp.arange(S)[None, :] + offset
    if cfg.pos == "mrope":
        return jnp.broadcast_to(pos[None], (3, B, S))
    return jnp.broadcast_to(pos, (B, S))


def gqa_qkv(p, x, cfg, offset=0, position_ids=None):
    B, S, _ = x.shape
    hd = cfg.head_dim or cfg.d_model // cfg.n_heads
    q = dense(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
    k = dense(p["wk"], x).reshape(B, S, cfg.n_kv, hd)
    v = dense(p["wv"], x).reshape(B, S, cfg.n_kv, hd)
    pos = _positions(cfg, B, S, offset, position_ids)
    if cfg.pos == "rope":
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    elif cfg.pos == "mrope":
        q = apply_mrope(q, pos, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, pos, cfg.mrope_sections, cfg.rope_theta)
    q = shard_act(q, ("batch", None, "heads", None))
    k = shard_act(k, ("batch", None, "kv_heads", None))
    v = shard_act(v, ("batch", None, "kv_heads", None))
    return q, k, v


def gqa_apply_train(p, x, cfg, position_ids=None):
    q, k, v = gqa_qkv(p, x, cfg, 0, position_ids)
    o = flash_attention(
        q, k, v, causal=True, q_chunk=cfg.attn_chunk_q, kv_chunk=cfg.attn_chunk_kv
    )
    B, S = x.shape[:2]
    o = shard_act(o, ("batch", None, "heads", None))
    # Exact serving gathers heads before the wo contraction (repl_act is
    # a no-op outside an exact mesh context) — same at every wo below.
    o = repl_act(o)
    return dense(p["wo"], o.reshape(B, S, -1).astype(x.dtype)), (k, v)


def gqa_apply_decode(p, x, cfg, cache, pos, position_ids=None):
    """cache: dict(k=(B, S_max, Hk, hd), v=...); x: (B, 1, D).
    ``pos`` is a scalar (all rows at the same length) or a (B,) vector of
    per-row lengths (slot-based serving: each slot decodes at its own
    position)."""
    B = x.shape[0]
    per_row = jnp.ndim(pos) == 1
    off = pos[:, None] if per_row else pos
    q, k, v = gqa_qkv(p, x, cfg, off, position_ids)
    if per_row:
        rows = jnp.arange(B)
        k_cache = cache["k"].at[rows, pos].set(k[:, 0].astype(cache["k"].dtype))
        v_cache = cache["v"].at[rows, pos].set(v[:, 0].astype(cache["v"].dtype))
    else:
        k_cache = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
    o = decode_attention(q, k_cache, v_cache, pos)
    y = dense(p["wo"], repl_act(o).reshape(B, 1, -1).astype(x.dtype))
    return y, {"k": k_cache, "v": v_cache}


def gqa_apply_decode_paged(p, x, cfg, cache, block_table, pos):
    """Slot-decode through a paged KV pool: cache k/v are
    (n_pages, page, Hk, hd) shared pages, ``block_table`` is the (B, nb)
    per-slot page list, ``pos`` the (B,) per-row lengths.  The new K/V
    scatters into page ``bt[b, pos // page]`` row ``pos % page`` (always
    a page the slot owns alone — shared prefix pages are fully covered
    by the prompt and decode writes start at the prompt end), then the
    attention runs through ``kops.paged_decode_gqa``: on the XLA backend
    the slot's pages gather into a contiguous (B, nb * page, ...) view
    for the same masked ``decode_attention`` the monolithic path runs;
    on the Pallas backend the fused kernel reads the pages through the
    block table in VMEM (bitwise identical)."""
    B = x.shape[0]
    q, k, v = gqa_qkv(p, x, cfg, pos[:, None])
    page = cache["k"].shape[1]
    pg = jnp.take_along_axis(block_table, (pos // page)[:, None], axis=1)[:, 0]
    rw = pos % page
    k_pages = cache["k"].at[pg, rw].set(k[:, 0].astype(cache["k"].dtype))
    v_pages = cache["v"].at[pg, rw].set(v[:, 0].astype(cache["v"].dtype))
    o = kops.paged_decode_gqa(
        q, k_pages, v_pages, block_table, pos, backend=cfg.attn_backend
    )
    y = dense(p["wo"], repl_act(o).reshape(B, 1, -1).astype(x.dtype))
    return y, {"k": k_pages, "v": v_pages}


def gqa_apply_prefix(p, x, cfg, cache, block_table, ctx_len, wr_pg, wr_rw,
                     use_context: bool = True):
    """Paged (burst) prefill of tail tokens over an optional reused
    prefix: queries sit at absolute positions ``ctx_len[b] + t`` (RoPE),
    attend the gathered context pages (valid where ``j < ctx_len``) plus
    the tail causally, and the tail K/V scatters into the slot's pages
    at ``(wr_pg, wr_rw)`` (right-pad writes land in the garbage page).
    The context is read from the *pre-write* pool — a request never
    shares a page with a burst member whose fill is still pending (the
    scheduler splits such bursts), so the gather sees only pages filled
    by earlier programs.

    ``use_context=False`` (static) compiles the prefix machinery out:
    when the scheduler's prefix reuse is gated off, ``ctx_len`` is
    always 0 and gathering max_len always-masked context keys per layer
    would be pure waste."""
    B, T, _ = x.shape
    q, k, v = gqa_qkv(p, x, cfg, ctx_len[:, None])
    if use_context:
        with common.precision_island("attn"):
            k_ctx = _gather_pages(cache["k"], block_table).astype(k.dtype)
            v_ctx = _gather_pages(cache["v"], block_table).astype(v.dtype)
    else:
        k_ctx = v_ctx = None
    o = kops.prefix_prefill(
        q, k_ctx, v_ctx, k, v, ctx_len, backend=cfg.attn_backend
    )
    k_pages = cache["k"].at[wr_pg, wr_rw].set(k.astype(cache["k"].dtype))
    v_pages = cache["v"].at[wr_pg, wr_rw].set(v.astype(cache["v"].dtype))
    y = dense(p["wo"], repl_act(o).reshape(B, T, -1).astype(x.dtype))
    return y, {"k": k_pages, "v": v_pages}


# ================================= MLA =======================================
@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


def mla_init(key, cfg, dtype):
    m: MLAConfig = cfg.mla
    H = cfg.n_heads
    ks = jax.random.split(key, 5)
    return {
        "q_a": dense_init(ks[0], cfg.d_model, m.q_lora_rank, dtype),
        "q_norm": common.norm_init(m.q_lora_rank, "rms", dtype),
        "q_b": dense_init(
            ks[1], m.q_lora_rank, H * (m.qk_nope_dim + m.qk_rope_dim), dtype
        ),
        "kv_a": dense_init(
            ks[2], cfg.d_model, m.kv_lora_rank + m.qk_rope_dim, dtype
        ),
        "kv_norm": common.norm_init(m.kv_lora_rank, "rms", dtype),
        "kv_b": dense_init(
            ks[3], m.kv_lora_rank, H * (m.qk_nope_dim + m.v_head_dim), dtype
        ),
        "wo": dense_init(ks[4], H * m.v_head_dim, cfg.d_model, dtype),
    }


def _mla_q(p, x, cfg, offset):
    m: MLAConfig = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    cq = common.norm_apply(p["q_norm"], dense(p["q_a"], x), "rms")
    q = dense(p["q_b"], cq).reshape(B, S, H, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    pos = jnp.arange(S)[None, :] + offset
    q_rope = apply_rope(q_rope, jnp.broadcast_to(pos, (B, S)), cfg.rope_theta)
    return q_nope, q_rope


def _mla_ckv(p, x, cfg, offset):
    m: MLAConfig = cfg.mla
    B, S, _ = x.shape
    a = dense(p["kv_a"], x)
    c_kv, k_rope = jnp.split(a, [m.kv_lora_rank], axis=-1)
    c_kv = common.norm_apply(p["kv_norm"], c_kv, "rms")
    pos = jnp.arange(S)[None, :] + offset
    k_rope = apply_rope(
        k_rope[:, :, None, :], jnp.broadcast_to(pos, (B, S)), cfg.rope_theta
    )[:, :, 0]
    return c_kv, k_rope


def mla_apply_train(p, x, cfg, position_ids=None):
    """Prefill/train MLA: reconstruct per-head K/V from the compressed
    cache, chunked-flash attention over (nope+rope) keys."""
    m: MLAConfig = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _mla_q(p, x, cfg, 0)
    c_kv, k_rope = _mla_ckv(p, x, cfg, 0)

    kvb = dense(p["kv_b"], c_kv).reshape(B, S, H, m.qk_nope_dim + m.v_head_dim)
    k_nope, v = jnp.split(kvb, [m.qk_nope_dim], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, m.qk_rope_dim))],
        axis=-1,
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    q = shard_act(q, ("batch", None, "heads", None))
    k = shard_act(k, ("batch", None, "heads", None))
    v = shard_act(v, ("batch", None, "heads", None))
    o = flash_attention(
        q, k, v, causal=True, q_chunk=cfg.attn_chunk_q, kv_chunk=cfg.attn_chunk_kv
    )
    y = dense(p["wo"], repl_act(o).reshape(B, S, -1).astype(x.dtype))
    return y, (c_kv, k_rope)


def _mla_absorb_weights(p, cfg):
    m: MLAConfig = cfg.mla
    H = cfg.n_heads
    w_kv_b = p["kv_b"]["w"].reshape(m.kv_lora_rank, H, m.qk_nope_dim + m.v_head_dim)
    return w_kv_b[:, :, : m.qk_nope_dim], w_kv_b[:, :, m.qk_nope_dim:]


@common.in_island("attn")
def _mla_absorb_q(p, cfg, q_nope):
    """Absorb ``w_uk`` into the nope queries: returns the (B, q, H, r)
    f32 absorbed queries, the post-sum score scale, and ``w_uv`` for the
    caller's up-projection."""
    m: MLAConfig = cfg.mla
    w_uk, w_uv = _mla_absorb_weights(p, cfg)
    q_abs = jnp.einsum(
        "bqhd,rhd->bqhr", q_nope.astype(jnp.float32), w_uk.astype(jnp.float32)
    )
    scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    return q_abs, scale, w_uv


@common.in_island("attn")
def mla_attend_core(q_abs, q_rope, ckv, krope, pos, scale):
    """The absorbed-MLA masked attend over contiguous cache views:
    scores and context computed in the compressed c_kv space.  ``pos``
    is a scalar or a (B,) vector; rows past ``pos`` are masked.  Returns
    the (B, q, H, r) f32 context — ``w_uv`` stays with the caller.  This
    is the XLA reference the fused ``paged_decode_mla_pallas`` kernel is
    bitwise-checked against (and the shared core of the monolithic and
    paged decode paths, so the two can never diverge numerically)."""
    s = (
        jnp.einsum("bqhr,bsr->bhqs", q_abs, ckv.astype(jnp.float32))
        + jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32), krope.astype(jnp.float32))
    ) * scale                                          # (B,H,1,S)
    S_max = ckv.shape[1]
    if jnp.ndim(pos) == 1:
        mask = jnp.arange(S_max)[None, :] <= pos[:, None]   # (B, S)
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    else:
        mask = jnp.arange(S_max) <= pos
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bsr->bqhr", pattn, ckv.astype(jnp.float32))


@common.in_island("attn")
def _mla_absorbed_attend(p, cfg, q_nope, q_rope, ckv, krope, pos):
    """One absorbed-MLA decode attention against a contiguous
    (B, S, r_kv)/(B, S, d_rope) cache view: absorb, attend, up-project."""
    q_abs, scale, w_uv = _mla_absorb_q(p, cfg, q_nope)
    ctx = mla_attend_core(q_abs, q_rope, ckv, krope, pos, scale)
    return jnp.einsum("bqhr,rhd->bqhd", ctx, w_uv.astype(jnp.float32))


def mla_apply_decode(p, x, cfg, cache, pos):
    """Absorbed MLA decode: scores/context computed in the compressed
    c_kv space — the cache stays (B, S, r_kv) + (B, S, d_rope).  ``pos``
    is a scalar or a (B,) vector of per-row lengths (slotted serving)."""
    B = x.shape[0]
    per_row = jnp.ndim(pos) == 1
    off = pos[:, None] if per_row else pos
    q_nope, q_rope = _mla_q(p, x, cfg, off)           # (B,1,H,dn),(B,1,H,dr)
    c_new, kr_new = _mla_ckv(p, x, cfg, off)          # (B,1,rkv),(B,1,dr)
    if per_row:
        rows = jnp.arange(B)
        ckv = cache["c_kv"].at[rows, pos].set(c_new[:, 0].astype(cache["c_kv"].dtype))
        krope = cache["k_rope"].at[rows, pos].set(kr_new[:, 0].astype(cache["k_rope"].dtype))
    else:
        ckv = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, pos, 0)
        )
        krope = jax.lax.dynamic_update_slice(
            cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), (0, pos, 0)
        )
    o = _mla_absorbed_attend(p, cfg, q_nope, q_rope, ckv, krope, pos)
    y = dense(p["wo"], repl_act(o).reshape(B, 1, -1).astype(x.dtype))
    return y, {"c_kv": ckv, "k_rope": krope}


def mla_apply_decode_paged(p, x, cfg, cache, block_table, pos):
    """Absorbed MLA decode through a paged compressed cache: pages are
    (n_pages, page, r_kv)/(n_pages, page, d_rope); the new row scatters
    into the slot's page at ``pos``, then ``kops.paged_decode_mla`` runs
    ``mla_attend_core`` over the block table — via an XLA gather or the
    fused Pallas kernel, per ``cfg.attn_backend``."""
    B = x.shape[0]
    q_nope, q_rope = _mla_q(p, x, cfg, pos[:, None])
    c_new, kr_new = _mla_ckv(p, x, cfg, pos[:, None])
    page = cache["c_kv"].shape[1]
    pg = jnp.take_along_axis(block_table, (pos // page)[:, None], axis=1)[:, 0]
    rw = pos % page
    ckv_pages = cache["c_kv"].at[pg, rw].set(c_new[:, 0].astype(cache["c_kv"].dtype))
    kr_pages = cache["k_rope"].at[pg, rw].set(kr_new[:, 0].astype(cache["k_rope"].dtype))
    q_abs, scale, w_uv = _mla_absorb_q(p, cfg, q_nope)
    ctx = kops.paged_decode_mla(
        q_abs, q_rope, ckv_pages, kr_pages, block_table, pos, scale,
        backend=cfg.attn_backend,
    )
    with common.precision_island("attn"):
        o = jnp.einsum("bqhr,rhd->bqhd", ctx, w_uv.astype(jnp.float32))
    y = dense(p["wo"], repl_act(o).reshape(B, 1, -1).astype(x.dtype))
    return y, {"c_kv": ckv_pages, "k_rope": kr_pages}


def mla_apply_prefix(p, x, cfg, cache, block_table, ctx_len, wr_pg, wr_rw,
                     use_context: bool = True):
    """Paged (burst) MLA prefill over an optional reused prefix: per-head
    K/V are reconstructed from the compressed cache for BOTH the gathered
    context pages and the tail (exactly as ``mla_apply_train``
    reconstructs them for a full prompt), then one ``prefix_attention``
    runs the ctx+causal-tail mask.  The tail's compressed rows scatter
    into the slot's pages at ``(wr_pg, wr_rw)``.  ``use_context=False``
    (static) compiles the context gather out, as in
    ``gqa_apply_prefix``."""
    m: MLAConfig = cfg.mla
    B, T, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _mla_q(p, x, cfg, ctx_len[:, None])
    c_kv, k_rope = _mla_ckv(p, x, cfg, ctx_len[:, None])

    if use_context:
        with common.precision_island("attn"):
            ckv_ctx = _gather_pages(
                cache["c_kv"], block_table
            ).astype(c_kv.dtype)
            kr_ctx = _gather_pages(
                cache["k_rope"], block_table
            ).astype(k_rope.dtype)
        L = ckv_ctx.shape[1]
        c_all = jnp.concatenate([ckv_ctx, c_kv], axis=1)     # (B, L+T, rkv)
        kr_all = jnp.concatenate([kr_ctx, k_rope], axis=1)   # (B, L+T, dr)
    else:
        c_all, kr_all, L = c_kv, k_rope, 0

    kvb = dense(p["kv_b"], c_all).reshape(B, L + T, H, m.qk_nope_dim + m.v_head_dim)
    k_nope, v = jnp.split(kvb, [m.qk_nope_dim], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr_all[:, :, None, :], (B, L + T, H, m.qk_rope_dim))],
        axis=-1,
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    # Hand ctx/tail slices to the dispatcher: the XLA backend re-concats
    # them (bitwise a no-op), the Pallas backend attends them fused.
    o = kops.prefix_prefill(
        q,
        k[:, :L] if L else None,
        v[:, :L] if L else None,
        k[:, L:], v[:, L:], ctx_len,
        backend=cfg.attn_backend,
    )

    ckv_pages = cache["c_kv"].at[wr_pg, wr_rw].set(c_kv.astype(cache["c_kv"].dtype))
    kr_pages = cache["k_rope"].at[wr_pg, wr_rw].set(k_rope.astype(cache["k_rope"].dtype))
    y = dense(p["wo"], repl_act(o).reshape(B, T, -1).astype(x.dtype))
    return y, {"c_kv": ckv_pages, "k_rope": kr_pages}
