"""LM assembly: Embed -> scan(blocks) -> Norm -> Head, with train / prefill
/ decode entry points for every assigned architecture.

Serving caches come in two layouts: monolithic per-slot regions
(``init_cache`` / ``prefill`` / ``decode_step`` / ``insert_cache_slot``)
and the paged pool (``init_paged_pool`` / ``prefill_paged`` /
``decode_step_paged``) where attention K/V lives in shared refcounted
pages addressed through per-slot block tables — see docs/serving.md.
Either pool is allocated ONCE per ``serve.ServeSession`` and reused
across traces (every compiled program donates and rebinds it);
``pool_nbytes`` reports the persistent footprint.

Layers are scanned in groups of ``cfg.scan_period()`` (1 for uniform
stacks; 8 for Jamba's 1-attn:7-mamba interleave) so the HLO stays small
at 61-80 layers.  Activation remat wraps each scanned group.  Sequence
parallelism is annotated on the residual stream between blocks
(``shard_act(x, ("batch", "seq_sp", None))``).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import repl_act, shard_act
from . import attention as attn
from . import common, mamba as ssm, moe as moe_mod
from .common import (
    dense,
    dense_init,
    last_valid_hidden,
    norm_apply,
    norm_init,
    page_write_indices,
)
from .config import LMConfig


# ------------------------------- init ---------------------------------------
def _init_mixer(key, cfg: LMConfig, kind: str):
    if kind == "gqa":
        return attn.gqa_init(key, cfg, cfg.pdtype)
    if kind == "mla":
        return attn.mla_init(key, cfg, cfg.pdtype)
    return ssm.mamba_init(key, cfg, cfg.pdtype)


def _init_ffn(key, cfg: LMConfig, kind: str):
    if kind == "none":
        return {}
    if kind == "moe":
        return moe_mod.moe_init(key, cfg, cfg.pdtype)
    return common.ffn_init(key, cfg.d_model, cfg.d_ff, cfg.act, cfg.pdtype)


def _init_block(key, cfg: LMConfig, mk: str, fk: str):
    k1, k2 = jax.random.split(key)
    p = {"ln1": norm_init(cfg.d_model, cfg.norm, cfg.pdtype),
         "mixer": _init_mixer(k1, cfg, mk)}
    if fk != "none":
        p["ln2"] = norm_init(cfg.d_model, cfg.norm, cfg.pdtype)
        p["ffn"] = _init_ffn(k2, cfg, fk)
    return p


def init(key, cfg: LMConfig) -> Dict[str, Any]:
    cfg.validate()
    period = cfg.scan_period()
    groups = cfg.n_layers // period
    keys = jax.random.split(key, 4)

    blocks = []
    for pos in range(period):
        mk, fk = cfg.mixer_kind(pos), cfg.ffn_of(pos)
        per_group = [
            _init_block(
                jax.random.fold_in(keys[0], g * period + pos), cfg, mk, fk
            )
            for g in range(groups)
        ]
        blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_group))

    params: Dict[str, Any] = {"blocks": blocks, "ln_f": norm_init(cfg.d_model, cfg.norm, cfg.pdtype)}
    if not cfg.external_embed:
        params["embed"] = {
            "w": (jax.random.normal(keys[1], (cfg.vocab_size, cfg.d_model)) * 0.02).astype(cfg.pdtype)
        }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(keys[2], cfg.d_model, cfg.vocab_size, cfg.pdtype)
    if cfg.mtp:
        params["mtp"] = {
            "proj": dense_init(keys[3], 2 * cfg.d_model, cfg.d_model, cfg.pdtype),
            "block": _init_block(
                jax.random.fold_in(keys[3], 1), cfg, cfg.mixer_kind(0), cfg.ffn_of(0)
            ),
            "ln": norm_init(cfg.d_model, cfg.norm, cfg.pdtype),
        }
    return params


# ------------------------------- blocks ---------------------------------------
def _block_train(bp, x, cfg: LMConfig, mk: str, fk: str, position_ids, training: bool = True, valid_len=None):
    h = norm_apply(bp["ln1"], x, cfg.norm)
    aux = jnp.float32(0.0)
    if mk == "gqa":
        y, kv = attn.gqa_apply_train(bp["mixer"], h, cfg, position_ids)
        cacheable = {"k": kv[0], "v": kv[1]}
    elif mk == "mla":
        y, kv = attn.mla_apply_train(bp["mixer"], h, cfg, position_ids)
        cacheable = {"c_kv": kv[0], "k_rope": kv[1]}
    else:
        y, cacheable = ssm.mamba_mix(
            bp["mixer"], h, cfg, cfg.mamba_chunk, return_state=True,
            training=training, valid_len=valid_len,
        )
    x = x + y
    if fk != "none":
        h2 = norm_apply(bp["ln2"], x, cfg.norm)
        if fk == "moe":
            y2, aux = moe_mod.moe_apply(bp["ffn"], h2, cfg, training=training)
        else:
            y2 = common.ffn_apply(bp["ffn"], h2, cfg.act)
        x = x + y2
    x = shard_act(x, ("batch", "seq_sp", None))
    return x, cacheable, aux


def _apply_ffn(bp, x, cfg: LMConfig, fk: str):
    """Inference-mode FFN half of a block (shared by the decode and
    paged-prefill block bodies)."""
    if fk == "none":
        return x
    h2 = norm_apply(bp["ln2"], x, cfg.norm)
    if fk == "moe":
        y2, _ = moe_mod.moe_apply(bp["ffn"], h2, cfg, training=False)
    else:
        y2 = common.ffn_apply(bp["ffn"], h2, cfg.act)
    return x + y2


def _block_decode(bp, x, cfg: LMConfig, mk: str, fk: str, cache, pos, position_ids):
    h = norm_apply(bp["ln1"], x, cfg.norm)
    if mk == "gqa":
        y, cache = attn.gqa_apply_decode(bp["mixer"], h, cfg, cache, pos, position_ids)
    elif mk == "mla":
        y, cache = attn.mla_apply_decode(bp["mixer"], h, cfg, cache, pos)
    else:
        y, cache = ssm.mamba_step(bp["mixer"], h, cfg, cache)
    return _apply_ffn(bp, x + y, cfg, fk), cache


def _block_decode_paged(bp, x, cfg: LMConfig, mk: str, fk: str, cache,
                        block_tables, pos):
    """One decode block over a paged pool: attention mixers read/write
    shared pages through the block table; SSM mixers keep per-slot O(1)
    state (rows [0, B) of the pool's n_slots+1 rows — the last row is
    the garbage slot that absorbs burst-padding prefill writes)."""
    B = x.shape[0]
    h = norm_apply(bp["ln1"], x, cfg.norm)
    if mk == "gqa":
        y, cache = attn.gqa_apply_decode_paged(
            bp["mixer"], h, cfg, cache, block_tables, pos
        )
    elif mk == "mla":
        y, cache = attn.mla_apply_decode_paged(
            bp["mixer"], h, cfg, cache, block_tables, pos
        )
    else:
        y, new = ssm.mamba_step(
            bp["mixer"], h, cfg,
            {"h": cache["h"][:B], "conv": cache["conv"][:B]},
        )
        cache = {
            "h": cache["h"].at[:B].set(new["h"]),
            "conv": cache["conv"].at[:B].set(new["conv"].astype(cache["conv"].dtype)),
        }
    return _apply_ffn(bp, x + y, cfg, fk), cache


def _block_prefill_paged(bp, x, cfg: LMConfig, mk: str, fk: str, cache,
                         block_tables, ctx_len, tail_valid, wr_pg, wr_rw,
                         slots, use_context: bool):
    """One paged-prefill block: attention mixers attend [reused prefix
    pages ; causal tail] and scatter the tail K/V into the slot's pages;
    SSM mixers run the chunked mix over the tail (per-row valid_len) and
    scatter the post-prompt state at ``slots`` (prefix reuse never
    applies to SSM layers — the scheduler guarantees ctx_len == 0 for
    architectures with recurrent state).  ``use_context=False`` (static)
    compiles the context gather out entirely — the shape a scheduler
    with prefix reuse gated off uses."""
    h = norm_apply(bp["ln1"], x, cfg.norm)
    if mk == "gqa":
        y, cache = attn.gqa_apply_prefix(
            bp["mixer"], h, cfg, cache, block_tables, ctx_len, wr_pg, wr_rw,
            use_context,
        )
    elif mk == "mla":
        y, cache = attn.mla_apply_prefix(
            bp["mixer"], h, cfg, cache, block_tables, ctx_len, wr_pg, wr_rw,
            use_context,
        )
    else:
        y, state = ssm.mamba_mix(
            bp["mixer"], h, cfg, cfg.mamba_chunk, return_state=True,
            training=False, valid_len=tail_valid,
        )
        cache = {
            "h": cache["h"].at[slots].set(state["h"]),
            "conv": cache["conv"].at[slots].set(state["conv"].astype(cache["conv"].dtype)),
        }
    return _apply_ffn(bp, x + y, cfg, fk), cache


# ------------------------------ embedding -------------------------------------
def embed_inputs(params, batch: Dict[str, Any], cfg: LMConfig, offset=0):
    if cfg.external_embed:
        x = batch["embeds"].astype(cfg.cdtype)
    else:
        x = params["embed"]["w"].astype(cfg.cdtype)[batch["tokens"]]
    if cfg.pos == "sinusoidal":
        B, S = x.shape[:2]
        pos = jnp.arange(S)[None, :] + offset
        x = x + common.sinusoidal_positions(pos, cfg.d_model).astype(x.dtype)
    return shard_act(x, ("batch", "seq_sp", None))


def _head_logits(params, h, cfg: LMConfig):
    with common.precision_island("logits"):
        if cfg.tie_embeddings:
            w = params["embed"]["w"]
            w = w if w.dtype == h.dtype else w.astype(h.dtype)
            logits = jnp.matmul(
                h, w.T, preferred_element_type=jnp.float32
            ).astype(h.dtype)
        else:
            logits = dense(params["head"], h)
        # Exact serving gathers vocab-sharded logits so argmax/categorical
        # sampling runs fully replicated (identical reduction order and RNG
        # bits on every device); no-op outside an exact mesh context.
        return repl_act(logits)


# ------------------------------- forward --------------------------------------
def forward_hidden(params, x, cfg: LMConfig, position_ids=None, collect_cache=False, training=True, valid_len=None):
    """Scan the block stack; returns (h, stacked cacheables, aux_sum).
    ``valid_len`` marks trailing positions as right-padding for cache
    collection (see ``mamba_mix``); attention needs no mask — causality
    already keeps right-pads out of every valid position's output."""
    period = cfg.scan_period()
    kinds = [(cfg.mixer_kind(i), cfg.ffn_of(i)) for i in range(period)]

    def group_body(x, group_params):
        caches = []
        aux = jnp.float32(0.0)
        for pos in range(period):
            mk, fk = kinds[pos]
            x, c, a = _block_train(group_params[pos], x, cfg, mk, fk, position_ids, training, valid_len)
            caches.append(c)
            aux = aux + a
        return x, (tuple(caches), aux)

    if cfg.remat:
        group_body = jax.checkpoint(group_body)

    def scan_body(x, gp):
        return group_body(x, gp)

    x, (caches, auxs) = jax.lax.scan(scan_body, x, tuple(params["blocks"]))
    return x, (caches if collect_cache else None), jnp.sum(auxs)


def loss_fn(params, batch: Dict[str, Any], cfg: LMConfig):
    """Training loss: chunked CE + MoE aux (+ MTP branch for DeepSeek)."""
    x = embed_inputs(params, batch, cfg)
    pos_ids = batch.get("position_ids")
    h, _, aux = forward_hidden(params, x, cfg, pos_ids)
    h = norm_apply(params["ln_f"], h, cfg.norm)

    targets = batch["targets"]
    mask = batch.get("loss_mask")

    def logits32(hh):
        with common.precision_island("logits"):
            return _head_logits(params, hh, cfg).astype(jnp.float32)

    loss = common.softmax_xent_chunked(
        logits32, h, targets, mask, cfg.loss_chunk,
    )
    metrics = {"ce": loss, "aux": aux}
    loss = loss + cfg.aux_loss_weight * aux

    if cfg.mtp and not cfg.external_embed:
        # DeepSeek-style depth-1 MTP: combine h_t with embed(token_{t+1})
        # to predict token_{t+2} through one extra block.
        emb = params["embed"]["w"].astype(h.dtype)[batch["tokens"][:, 1:]]
        comb = jnp.concatenate([h[:, :-1], emb], axis=-1)
        z = dense(params["mtp"]["proj"], comb)
        mk, fk = cfg.mixer_kind(0), cfg.ffn_of(0)
        z, _, _ = _block_train(params["mtp"]["block"], z, cfg, mk, fk, None)
        z = norm_apply(params["mtp"]["ln"], z, cfg.norm)
        t2 = targets[:, 1:]
        m2 = None if mask is None else mask[:, 1:]
        mtp_loss = common.softmax_xent_chunked(
            logits32, z, t2, m2, cfg.loss_chunk,
        )
        metrics["mtp"] = mtp_loss
        loss = loss + cfg.mtp_weight * mtp_loss

    metrics["loss"] = loss
    return loss, metrics


# ----------------------------- caches / serving --------------------------------
def init_cache(cfg: LMConfig, batch: int, max_len: int):
    """Abstract-friendly cache pytree matching the scanned block layout."""
    period = cfg.scan_period()
    groups = cfg.n_layers // period
    cdt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.cache_dtype]

    def one(mk):
        if mk == "gqa":
            return {
                "k": jnp.zeros((groups, batch, max_len, cfg.n_kv, cfg.hd), cdt),
                "v": jnp.zeros((groups, batch, max_len, cfg.n_kv, cfg.hd), cdt),
            }
        if mk == "mla":
            m = cfg.mla
            return {
                "c_kv": jnp.zeros((groups, batch, max_len, m.kv_lora_rank), cdt),
                "k_rope": jnp.zeros((groups, batch, max_len, m.qk_rope_dim), cdt),
            }
        c = ssm.mamba_cache_init(cfg, batch, cdt)
        return jax.tree.map(
            lambda a: jnp.zeros((groups,) + a.shape, a.dtype), c
        )

    return tuple(one(cfg.mixer_kind(pos)) for pos in range(period))


def decode_step(params, inputs, pos, caches, cfg: LMConfig):
    """One decode step: inputs {"tokens": (B,1)} | {"embeds": (B,1,D)};
    pos = current length (new token written at index pos) — a scalar
    (classic equal-length batch) or a (B,) vector of per-row lengths
    (slot-based continuous batching: every slot decodes at its own
    position inside ONE program)."""
    x = embed_inputs(
        params, inputs, cfg,
        offset=pos[:, None] if jnp.ndim(pos) == 1 else pos,
    )
    period = cfg.scan_period()
    kinds = [(cfg.mixer_kind(i), cfg.ffn_of(i)) for i in range(period)]
    pos_ids = inputs.get("position_ids")

    def scan_body(x, xs):
        gp, gcaches = xs
        new_caches = []
        for p_i in range(period):
            mk, fk = kinds[p_i]
            x, c = _block_decode(gp[p_i], x, cfg, mk, fk, gcaches[p_i], pos, pos_ids)
            new_caches.append(c)
        return x, tuple(new_caches)

    x, new_caches = jax.lax.scan(scan_body, x, (tuple(params["blocks"]), caches))
    h = norm_apply(params["ln_f"], x, cfg.norm)
    logits = _head_logits(params, h, cfg)
    return logits, new_caches


def prefill(params, batch, cfg: LMConfig, max_len: Optional[int] = None,
            valid_len=None):
    """Run the full prompt; returns (caches padded to max_len, last-token
    logits).  SSM mixers carry O(1) state; attention mixers stack K/V.

    ``valid_len`` (traced scalar) supports *bucketed* prefill: the prompt
    is right-padded to a bucket length, positions >= valid_len are
    padding, and the returned logits are taken at index valid_len - 1
    (the last real token).  Right-pads never reach a real position's
    output (causal attention) or the returned SSM state / conv tail
    (identity recurrence steps, see ``mamba_mix``); the K/V cache rows in
    [valid_len, S) hold pad junk, which is safe because decode at
    position p overwrites row p before the causal mask first exposes it."""
    x = embed_inputs(params, batch, cfg)
    S = x.shape[1]
    B = x.shape[0]
    max_len = max_len or S
    pos_ids = batch.get("position_ids")
    h, caches, _ = forward_hidden(params, x, cfg, pos_ids, collect_cache=True,
                                  training=False, valid_len=valid_len)
    h = norm_apply(params["ln_f"], h, cfg.norm)
    logits = _head_logits(params, last_valid_hidden(h, valid_len), cfg)

    period = cfg.scan_period()
    cdt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.cache_dtype]
    full = init_cache(cfg, B, max_len)
    out = []
    for p_i in range(period):
        mk = cfg.mixer_kind(p_i)
        got = caches[p_i]           # stacked over groups, seq dim = S
        if mk == "gqa":
            out.append({
                "k": jax.lax.dynamic_update_slice(
                    full[p_i]["k"], got["k"].astype(cdt), (0, 0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(
                    full[p_i]["v"], got["v"].astype(cdt), (0, 0, 0, 0, 0)),
            })
        elif mk == "mla":
            out.append({
                "c_kv": jax.lax.dynamic_update_slice(
                    full[p_i]["c_kv"], got["c_kv"].astype(cdt), (0, 0, 0, 0)),
                "k_rope": jax.lax.dynamic_update_slice(
                    full[p_i]["k_rope"], got["k_rope"].astype(cdt), (0, 0, 0, 0)),
            })
        else:
            # Mamba prefill: the chunked mix returns the exact
            # post-prompt state {"h", "conv"} per layer.
            out.append(
                {"h": got["h"], "conv": got["conv"].astype(cdt)}
            )
    return tuple(out), logits


# --------------------------- paged KV-cache pool -------------------------------
def init_paged_pool(cfg: LMConfig, n_slots: int, n_pages: int, page_size: int,
                    mesh=None):
    """Paged cache pool: attention caches are SHARED pages instead of
    per-slot monolithic regions.

    Attention leaves are (groups, n_pages, page_size, ...) — a slot's
    logical (max_len, ...) cache is the concatenation of the pages its
    block-table row names, which lets fully-covered prompt-prefix pages
    be refcounted across requests (shared-prefix reuse).  Page 0 is the
    reserved GARBAGE page: never allocated, it absorbs the clamped
    writes of inactive decode slots and the right-pad writes of burst
    prefill, so junk can never land in a live page.

    SSM state is O(1) in sequence length, so it stays per-slot:
    (groups, n_slots + 1, ...), where row ``n_slots`` is the garbage
    SLOT that absorbs the state writes of burst-padding rows.

    With ``mesh`` (a tensor-parallel serving mesh, axis ``"model"``) the
    K/V page leaves are laid out head-sharded via
    ``dist.sharding.serve_pool_sharding_tree`` — the one serving buffer
    whose per-device footprint shrinks with tp — while MLA latent pages
    and SSM states replicate (their contractions must stay exact)."""
    period = cfg.scan_period()
    groups = cfg.n_layers // period
    cdt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.cache_dtype]

    def one(mk):
        if mk == "gqa":
            return {
                "k": jnp.zeros((groups, n_pages, page_size, cfg.n_kv, cfg.hd), cdt),
                "v": jnp.zeros((groups, n_pages, page_size, cfg.n_kv, cfg.hd), cdt),
            }
        if mk == "mla":
            m = cfg.mla
            return {
                "c_kv": jnp.zeros((groups, n_pages, page_size, m.kv_lora_rank), cdt),
                "k_rope": jnp.zeros((groups, n_pages, page_size, m.qk_rope_dim), cdt),
            }
        c = ssm.mamba_cache_init(cfg, n_slots + 1, cdt)
        return jax.tree.map(
            lambda a: jnp.zeros((groups,) + a.shape, a.dtype), c
        )

    pool = tuple(one(cfg.mixer_kind(pos)) for pos in range(period))
    if mesh is not None:
        from repro.dist.sharding import serve_pool_sharding_tree

        pool = jax.device_put(pool, serve_pool_sharding_tree(pool, mesh))
    return pool


def decode_step_paged(params, inputs, pos, pool, block_tables, cfg: LMConfig):
    """One decode step over all slots, reading/writing attention caches
    THROUGH the block tables (``(B, max_len // page_size)`` int32 page
    ids per slot) inside the one jitted program.  ``pos`` is the (B,)
    per-slot length vector; masking makes the result bitwise identical
    to ``decode_step`` over equivalent monolithic per-slot caches.

    The attention core dispatches on ``cfg.attn_backend``
    (``kernels.ops.AttnBackend``): the fused paged-attention Pallas
    kernels on TPU, the XLA gather+attend reference elsewhere — the
    backends are bitwise identical, so this program's exactness
    contracts are backend-independent."""
    x = embed_inputs(params, inputs, cfg, offset=pos[:, None])
    period = cfg.scan_period()
    kinds = [(cfg.mixer_kind(i), cfg.ffn_of(i)) for i in range(period)]

    def scan_body(x, xs):
        gp, gcaches = xs
        new_caches = []
        for p_i in range(period):
            mk, fk = kinds[p_i]
            x, c = _block_decode_paged(
                gp[p_i], x, cfg, mk, fk, gcaches[p_i], block_tables, pos
            )
            new_caches.append(c)
        return x, tuple(new_caches)

    x, new_pool = jax.lax.scan(scan_body, x, (tuple(params["blocks"]), pool))
    h = norm_apply(params["ln_f"], x, cfg.norm)
    logits = _head_logits(params, h, cfg)
    return logits, new_pool


def prefill_paged(params, batch, cfg: LMConfig, pool, block_tables, slots,
                  ctx_len, tail_valid, page_size: int,
                  use_context: bool = True):
    """Batched burst prefill into the paged pool.

    ``batch["tokens"]`` is (B, T): each row holds one admitted request's
    prompt TAIL (the part after its reused prefix), right-padded to the
    tail bucket T.  Per row: ``ctx_len`` counts reused prefix tokens
    (0 without a hit), ``tail_valid`` the real tail tokens, ``slots``
    the decode slot (the garbage slot ``n_slots`` for burst padding
    rows), and ``block_tables[b]`` the slot's page list — prefix pages
    resident and already filled, tail pages freshly allocated.

    Tail positions are absolute (``ctx_len + t``) for RoPE/sinusoidal
    embeddings; attention runs [prefix pages ; causal tail]; tail K/V
    scatters into the slot's pages (pads to the garbage page); SSM state
    scatters at ``slots``.  ``use_context=False`` (static, for
    schedulers whose prefix reuse is gated off — ctx_len is then always
    0) skips the per-layer context gather entirely.  Returns
    (pool, (B, 1, V) logits at each row's last real token).

    This is also the scheduler's **chunked-prefill** entry: a
    continuation chunk passes the already-filled token count as
    ``ctx_len`` and the next chunk as the tail.  Nothing here needs the
    chunk boundary to be page-aligned — positions are absolute via
    ``offset=ctx_len``, the context gather reads whole pages but masks
    attention at ``j < ctx_len[b]``, and ``page_write_indices`` scatters
    a tail starting mid-page.  Each chunk therefore computes bitwise
    what a single full prefill would at those positions (the exactness
    gate in serve.Scheduler holds the cases where it could differ —
    SSM state, lossy cache dtype — out of this path)."""
    tokens = batch["tokens"]
    B, T = tokens.shape
    x = embed_inputs(params, batch, cfg, offset=ctx_len[:, None])
    wr_pg, wr_rw = page_write_indices(
        block_tables, ctx_len, tail_valid, T, page_size
    )
    period = cfg.scan_period()
    kinds = [(cfg.mixer_kind(i), cfg.ffn_of(i)) for i in range(period)]

    def scan_body(x, xs):
        gp, gcaches = xs
        new_caches = []
        for p_i in range(period):
            mk, fk = kinds[p_i]
            x, c = _block_prefill_paged(
                gp[p_i], x, cfg, mk, fk, gcaches[p_i], block_tables,
                ctx_len, tail_valid, wr_pg, wr_rw, slots, use_context,
            )
            new_caches.append(c)
        return x, tuple(new_caches)

    x, new_pool = jax.lax.scan(scan_body, x, (tuple(params["blocks"]), pool))
    h = norm_apply(params["ln_f"], x, cfg.norm)
    logits = _head_logits(params, last_valid_hidden(h, tail_valid), cfg)
    return new_pool, logits


def pool_nbytes(pool) -> int:
    """Device footprint of a cache pool (paged or monolithic) in bytes.

    The pool is the biggest long-lived buffer of the serving stack;
    since ``serve.ServeSession`` allocates it exactly once per session
    (it used to be rebuilt per trace) the session reports this number
    through ``ServeStats.pool_bytes`` so capacity planning can see what
    persists across traces."""
    return int(sum(
        leaf.size * leaf.dtype.itemsize for leaf in jax.tree.leaves(pool)
    ))


def insert_cache_slot(pool, row_caches, slot):
    """Overwrite slot ``slot`` of a pooled cache (batch dim 1, after the
    stacked-groups dim 0) with a freshly prefilled batch-of-1 cache.

    The WHOLE per-slot region is replaced — K/V rows beyond the new
    prompt come from ``init_cache`` zeros, so nothing of the slot's
    previous occupant survives recycling (no cross-request KV leakage).
    """
    return jax.tree.map(
        lambda pool_leaf, new_leaf: pool_leaf.at[:, slot].set(
            new_leaf[:, 0].astype(pool_leaf.dtype)
        ),
        pool, row_caches,
    )


# ------------------------------ lint contract --------------------------------
from repro.analysis.registry import Built, register_contract  # noqa: E402


@register_contract(
    "lm.prefill_paged",
    checks=("donation", "transfers", "precision"),
    description="batched paged prefill at a smoke config: the donated "
                "pool must alias in the compiled module, a pool-"
                "rebinding call must run clean under a transfer guard, "
                "and the traced program must satisfy the f32 precision "
                "policy (no f64, declared dot accumulation, widening "
                "only inside islands)",
)
def _build_prefill_paged_contract() -> Built:
    from repro import configs
    from repro.analysis.jaxpr_tools import compile_unit
    from repro.analysis.registry import PrecisionPolicy

    cfg = configs.get_smoke_config("qwen2.5-3b")
    params = init(jax.random.PRNGKey(0), cfg)
    n_slots, page_size, pages_per_slot = 2, 8, 4
    pool = init_paged_pool(
        cfg, n_slots, n_slots * pages_per_slot + 1, page_size
    )
    B, T = 2, 8

    def entry(params, pool, tokens, block_tables, slots, ctx_len, tail_valid):
        return prefill_paged(
            params, {"tokens": tokens}, cfg, pool, block_tables, slots,
            ctx_len, tail_valid, page_size, False,
        )

    jitted = jax.jit(entry, donate_argnums=(1,))
    call_args = (
        jnp.zeros((B, T), jnp.int32),
        jnp.zeros((B, pages_per_slot), jnp.int32),
        jnp.asarray([0, 1], jnp.int32),
        jnp.zeros((B,), jnp.int32),
        jnp.full((B,), T, jnp.int32),
    )
    unit = compile_unit(
        "prefill_paged", jitted, (params, pool) + call_args,
        donate_argnums=(1,),
    )

    # Rebinding call loop, exactly like the serve session drives it: the
    # donated pool is consumed and replaced by the returned one.
    state = {"pool": pool}

    def hot():
        new_pool, logits = jitted(params, state["pool"], *call_args)
        state["pool"] = new_pool
        return jax.block_until_ready(logits)

    prefill_jaxpr = jax.make_jaxpr(entry)(params, pool, *call_args)
    return Built(
        compiled=[unit], hot=hot, hot_label="prefill_paged call",
        hot_jaxprs=[("prefill_paged", prefill_jaxpr)],
        precision=PrecisionPolicy(compute_dtype=cfg.compute_dtype),
    )
