"""Mixture-of-Experts FFN: top-k router + GShard-style einsum dispatch.

The dispatch/combine tensors are one-hot over (expert, capacity-slot) per
token group; with experts sharded on the "model" mesh axis GSPMD lowers
the dispatch einsums to all-to-alls — the standard expert-parallel
pattern.  Optional always-on shared experts (DeepSeek-V3) ride the dense
FFN path.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import repl_act, shard_act
from .common import dense, dense_init, ffn_apply, ffn_init


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                    # per-expert hidden dim
    n_shared: int = 0            # always-on shared experts (DeepSeek)
    capacity_factor: float = 1.25
    group_size: int = 256        # tokens per dispatch group


def moe_init(key, cfg, dtype):
    m: MoEConfig = cfg.moe
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(cfg.d_model)
    p = {
        "router": dense_init(ks[0], cfg.d_model, m.n_experts, jnp.float32),
        "w_gate": (
            jax.random.normal(ks[1], (m.n_experts, cfg.d_model, m.d_ff)) * scale
        ).astype(dtype),
        "w_up": (
            jax.random.normal(ks[2], (m.n_experts, cfg.d_model, m.d_ff)) * scale
        ).astype(dtype),
        "w_down": (
            jax.random.normal(ks[3], (m.n_experts, m.d_ff, cfg.d_model))
            * (1.0 / math.sqrt(m.d_ff))
        ).astype(dtype),
    }
    if m.n_shared:
        p["shared"] = ffn_init(ks[4], cfg.d_model, m.d_ff * m.n_shared, "swiglu", dtype)
    return p


def _group(x, group_size):
    B, S, D = x.shape
    T = B * S
    tg = min(group_size, T)
    while T % tg:
        tg -= 1
    return x.reshape(T // tg, tg, D), tg


def _expert_weights(p):
    # §Perf iterations I1/I2 (see EXPERIMENTS.md):
    #  - expert weights re-constrained *inside* the layer-scan body so the
    #    FSDP all-gather happens per layer (1 layer's experts) instead of
    #    GSPMD hoisting one whole-stack gather before the loop;
    #  - dispatched activations keep their token-group dim on the data
    #    axes ("batch"); replicating it forced a full token all-gather
    #    per layer in the baseline.
    return (
        shard_act(p["w_gate"], ("experts", None, "fsdp")),
        shard_act(p["w_up"], ("experts", None, "fsdp")),
        shard_act(p["w_down"], ("experts", "fsdp", None)),
    )


def moe_apply(p, x, cfg, training: bool = True):
    """x: (B, S, D) -> (B, S, D).  Capacity-based token dropping (GShard);
    returns the combined expert outputs (+ shared experts, + aux loss kept
    in metrics by the caller via ``moe_apply.last_aux`` pattern avoided —
    aux loss is returned explicitly).

    Inference (``training=False``) is *drop-free*: every token reaches
    all of its top-k experts.  Capacity drops are a load-balancing
    training artifact; at serving time they would make a token's output
    depend on which other tokens share its dispatch group — i.e. on
    batch composition and prompt padding — which breaks the
    continuous-batching contract that scheduling never changes numerics.
    The inference path therefore routes per token with no capacity axis
    at all (see below)."""
    m: MoEConfig = cfg.moe
    B, S, D = x.shape
    # Decode (S == 1): every token is its own dispatch group.  Grouping
    # across the batch dim would couple co-scheduled requests — one
    # slot's token could evict another's expert-capacity slot — so a
    # slotted decode step must route each row independently (and match
    # a batch-of-1 decode bit for bit).
    xg, tg = _group(x, 1 if S == 1 else m.group_size)  # (G, Tg, D)
    G = xg.shape[0]
    E = m.n_experts

    logits = dense(p["router"], xg.astype(jnp.float32))          # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, m.top_k)               # (G, Tg, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # Load-balancing auxiliary loss (Switch/GShard).
    frac_tokens = jnp.mean(
        jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32), axis=(0, 1)
    )
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs)

    w_gate, w_up, w_down = _expert_weights(p)

    if not training:
        # Drop-free inference without the capacity axis: a drop-free
        # GShard layout would need C = Tg capacity slots, making the
        # dispatch/combine one-hots (Tg, E, Tg) — quadratic in group
        # size and pure bookkeeping when nothing can ever drop.  Instead
        # every expert runs every token (same static GEMM shapes as the
        # full-capacity layout, E/top_k more work than the routed ideal)
        # and the top-k gates combine the outputs.  Per-token math only:
        # independent of batch composition, grouping and prompt padding.
        gates = jnp.sum(
            jax.nn.one_hot(idx, E, dtype=jnp.float32) * gate_vals[..., None],
            axis=2,
        )                                                        # (G, Tg, E)
        h = jax.nn.silu(jnp.einsum("gtd,edf->egtf", xg, w_gate.astype(xg.dtype)))
        h = h * jnp.einsum("gtd,edf->egtf", xg, w_up.astype(xg.dtype))
        h = shard_act(h, ("experts", "batch", None, "ff"))
        ye = jnp.einsum("egtf,efd->egtd", h, w_down.astype(xg.dtype))
        # Exact serving gathers the expert dim before the combine: the
        # weighted sum over experts must associate exactly as it does on
        # one device (top_k >= 3 sums are order-sensitive, and sharded
        # zeros for unrouted experts flip -0.0 signs).
        ye = repl_act(ye)
        y = jnp.einsum("gte,egtd->gtd", gates.astype(ye.dtype), ye)
        y = y.reshape(B, S, D)
        if m.n_shared:
            y = y + ffn_apply(p["shared"], x, "swiglu")
        return y, aux

    C = max(int(math.ceil(tg * m.top_k / E * m.capacity_factor)), 1)

    # Position-in-expert bookkeeping, slot-ordered (GShard).
    dispatch = jnp.zeros((G, tg, E, C), jnp.bfloat16)
    combine = jnp.zeros((G, tg, E, C), jnp.float32)
    counts = jnp.zeros((G, E), jnp.int32)
    for kk in range(m.top_k):
        e_k = idx[..., kk]                                       # (G, Tg)
        onehot = jax.nn.one_hot(e_k, E, dtype=jnp.int32)         # (G, Tg, E)
        pos = counts[:, None, :] + jnp.cumsum(onehot, axis=1) - onehot
        pos_tok = jnp.sum(pos * onehot, axis=-1)                 # (G, Tg)
        keep = pos_tok < C
        slot = jax.nn.one_hot(jnp.where(keep, pos_tok, C), C + 1, dtype=jnp.float32)[..., :C]
        d_k = onehot.astype(jnp.float32)[..., None] * slot[:, :, None, :]
        dispatch = dispatch + d_k.astype(jnp.bfloat16)
        combine = combine + d_k * (gate_vals[..., kk] * keep)[..., None, None]
        counts = counts + jnp.sum(onehot, axis=1)

    xe = jnp.einsum("gtec,gtd->egcd", dispatch.astype(xg.dtype), xg)
    xe = shard_act(xe, ("experts", "batch", None, None))
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xe, w_gate.astype(xe.dtype)))
    h = h * jnp.einsum("egcd,edf->egcf", xe, w_up.astype(xe.dtype))
    ye = jnp.einsum("egcf,efd->egcd", h, w_down.astype(xe.dtype))
    ye = shard_act(ye, ("experts", "batch", None, None))
    ye = repl_act(ye)
    y = jnp.einsum("gtec,egcd->gtd", combine.astype(ye.dtype), ye)

    y = y.reshape(B, S, D)
    if m.n_shared:
        y = y + ffn_apply(p["shared"], x, "swiglu")
    return y, aux
