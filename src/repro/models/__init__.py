"""Model zoo: composable block algebra covering all 10 assigned archs."""
from . import attention, common, config, lm, mamba, moe  # noqa: F401
from .config import LMConfig  # noqa: F401
