"""Mamba-1 (selective SSM) mixer with chunked parallel scan.

Training/prefill: the sequence is cut into chunks; within a chunk the
linear recurrence  h_t = exp(dt_t A) h_{t-1} + dt_t B_t u_t  is solved
with ``jax.lax.associative_scan`` (log-depth, materializes only
(chunk, d_inner, d_state) states), and chunk boundary states are carried
by an outer ``lax.scan`` — memory O(S/chunk * d_inner * d_state) instead
of O(S * d_inner * d_state).

Decode: O(1) state update — the reason SSMs run the 500k-context cell.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard_act
from .common import dense, dense_init


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0          # 0 -> d_model / 16


def ssm_dims(cfg):
    s: SSMConfig = cfg.ssm
    d_inner = s.expand * cfg.d_model
    dt_rank = s.dt_rank or max(cfg.d_model // 16, 1)
    return d_inner, dt_rank


def mamba_init(key, cfg, dtype):
    s: SSMConfig = cfg.ssm
    d_inner, dt_rank = ssm_dims(cfg)
    ks = jax.random.split(key, 6)
    # S4D-real initialization of A; dt bias so softplus(dt) spans
    # [1e-3, 1e-1] as in the Mamba reference.
    A = jnp.tile(jnp.arange(1, s.d_state + 1, dtype=jnp.float32), (d_inner, 1))
    dt = jnp.exp(
        jax.random.uniform(ks[0], (d_inner,))
        * (math.log(0.1) - math.log(1e-3))
        + math.log(1e-3)
    )
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))   # inverse softplus
    return {
        "in_proj": dense_init(ks[1], cfg.d_model, 2 * d_inner, dtype),
        "conv_w": (
            jax.random.normal(ks[2], (d_inner, s.d_conv)) / math.sqrt(s.d_conv)
        ).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": dense_init(ks[3], d_inner, dt_rank + 2 * s.d_state, dtype),
        "dt_proj": dense_init(ks[4], dt_rank, d_inner, jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[5], d_inner, cfg.d_model, dtype),
    }


def _ssm_raw(p, u, cfg):
    """u: (B, S, Di) post-conv -> (dt, B_c, C_c, A) recurrence inputs."""
    s: SSMConfig = cfg.ssm
    _, dt_rank = ssm_dims(cfg)
    xp = dense(p["x_proj"], u)
    dt_in, Bc, Cc = jnp.split(xp, [dt_rank, dt_rank + s.d_state], axis=-1)
    dt = jax.nn.softplus(
        dense(p["dt_proj"], dt_in.astype(jnp.float32)) + p["dt_bias"]
    )                                                    # (B, S, Di)
    A = -jnp.exp(p["A_log"])                             # (Di, N)
    return dt, Bc.astype(jnp.float32), Cc.astype(jnp.float32), A


def _ssm_coeffs(p, u, cfg):
    """u: (B, S, Di) post-conv activations -> (dA, dBu, C) scan coefficients."""
    dt, Bc, Cc, A = _ssm_raw(p, u, cfg)
    dA = jnp.exp(dt[..., None] * A)                      # (B, S, Di, N)
    dBu = (dt * u.astype(jnp.float32))[..., None] * Bc[..., None, :]
    return dA, dBu, Cc


def _scan_chunk(h0, dA, dBu):
    """Solve h_t = dA_t h_{t-1} + dBu_t within one chunk via associative
    scan; h0: (B, Di, N); dA/dBu: (B, C, Di, N).  Returns all h_t."""
    def combine(a, b):
        a1, b1 = a
        a2, b2 = b
        return a1 * a2, b1 * a2 + b2

    aa, bb = jax.lax.associative_scan(combine, (dA, dBu), axis=1)
    return aa * h0[:, None] + bb                          # (B, C, Di, N)


def _valid_mask(S: int, valid_len) -> jnp.ndarray:
    """(1, S) or (B, S) bool mask of real (non-right-pad) positions.
    ``valid_len`` may be a traced scalar (one valid length for the whole
    batch — single-request bucketed prefill) or a (B,) vector (batched
    burst prefill: each co-batched request has its own tail length)."""
    vl = jnp.asarray(valid_len)
    if vl.ndim == 0:
        vl = vl[None]
    return jnp.arange(S)[None, :] < vl[:, None]


def _pallas_scan(p, u, cfg, valid_len=None):
    """Fused Pallas selective scan (§Perf: one HBM pass instead of the
    associative scan's ~16).  Wrapped in shard_map when a mesh context is
    active: the recurrence is local in (batch, d_inner), sequential in S
    — no cross-device communication.  Forward-only (serving/prefill)."""
    from repro.dist import sharding as shd
    from repro.kernels.selective_scan import selective_scan_pallas

    dt, Bc, Cc, A = _ssm_raw(p, u, cfg)
    if valid_len is not None:
        # dt = 0 at padded steps -> dA = exp(0) = 1, dBu = dt*B*u = 0:
        # the kernel carries the state through pads unchanged.
        dt = jnp.where(_valid_mask(u.shape[1], valid_len)[..., None], dt, 0.0)
    D_skip = p["D"]

    def run(u_, dt_, b_, c_, a_, d_):
        y, h = selective_scan_pallas(u_, dt_, b_, c_, a_, d_)
        return y, h

    ctx = shd.current()
    if ctx is None:
        return run(u, dt, Bc, Cc, A, D_skip)

    from repro.dist.compat import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = ctx.mesh
    bspec = ctx.spec(("batch", None, "d_inner"), u.shape)
    sspec = ctx.spec(("batch", None, None), Bc.shape)
    aspec = ctx.spec(("d_inner", None), A.shape)
    dspec = ctx.spec(("d_inner",), D_skip.shape)
    hspec = ctx.spec(("batch", "d_inner", None),
                     (u.shape[0], u.shape[2], A.shape[1]))
    return shard_map(
        run, mesh=mesh,
        in_specs=(bspec, bspec, sspec, sspec, aspec, dspec),
        out_specs=(bspec, hspec),
        check_vma=False,
    )(u, dt, Bc, Cc, A, D_skip)


def mamba_mix(p, x, cfg, chunk: int, return_state: bool = False,
              training: bool = True, valid_len=None):
    """x: (B, S, D) -> (B, S, D), full-sequence (train/prefill).
    With ``return_state`` also returns the decode cache {"h", "conv"}
    capturing the post-prompt SSM state and conv tail.  When
    ``cfg.ssm_impl == "pallas"`` and not training, the recurrence runs in
    the fused Pallas kernel (no autodiff rule -> training keeps the
    differentiable associative scan).

    ``valid_len`` (traced scalar, or a (B,) vector of per-row lengths for
    batched burst prefill) marks positions >= valid_len as
    right-padding: their recurrence step is forced to the identity
    (dA = 1, dBu = 0, i.e. dt = 0) so the returned state is the state
    after the *valid* prefix, and the conv tail is taken ending at
    ``valid_len`` — bucketed prefill pads prompts without perturbing the
    decode cache.  Outputs at padded positions are unspecified."""
    s: SSMConfig = cfg.ssm
    d_inner, _ = ssm_dims(cfg)
    B, S, _ = x.shape
    xz = dense(p["in_proj"], x)
    u, z = jnp.split(xz, 2, axis=-1)                      # (B, S, Di) each
    u = shard_act(u, ("batch", None, "d_inner"))
    u_raw = u                                             # pre-conv (cache tail)

    # Depthwise causal conv, width d_conv.
    w = p["conv_w"].astype(u.dtype)                       # (Di, K)
    upad = jnp.pad(u, ((0, 0), (s.d_conv - 1, 0), (0, 0)))
    conv = sum(
        upad[:, i : i + S] * w[:, i] for i in range(s.d_conv)
    ) + p["conv_b"].astype(u.dtype)
    u = jax.nn.silu(conv)

    if getattr(cfg, "ssm_impl", "assoc") == "pallas" and not training:
        y, h_last = _pallas_scan(p, u, cfg, valid_len=valid_len)
    else:
        dA, dBu, Cc = _ssm_coeffs(p, u, cfg)
        if valid_len is not None:
            keep = _valid_mask(S, valid_len)[..., None, None]
            dA = jnp.where(keep, dA, 1.0)
            dBu = jnp.where(keep, dBu, 0.0)

        chunk = min(chunk, S)
        while S % chunk:
            chunk -= 1
        n = S // chunk

        def body(h, xs):
            dAc, dBuc = xs                                # (B, C, Di, N)
            hs = _scan_chunk(h, dAc, dBuc)
            return hs[:, -1], hs

        dAc = dA.reshape(B, n, chunk, d_inner, s.d_state).swapaxes(0, 1)
        dBuc = dBu.reshape(B, n, chunk, d_inner, s.d_state).swapaxes(0, 1)
        h0 = jnp.zeros((B, d_inner, s.d_state), jnp.float32)
        h_last, hs = jax.lax.scan(body, h0, (dAc, dBuc))
        hs = hs.swapaxes(0, 1).reshape(B, S, d_inner, s.d_state)
        y = jnp.einsum("bsdn,bsn->bsd", hs, Cc) + p["D"] * u.astype(jnp.float32)
    # (the Pallas kernel applies the D skip internally)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = shard_act(y, ("batch", None, "d_inner"))
    out = dense(p["out_proj"], y)
    if not return_state:
        return out
    if valid_len is None:
        tail = u_raw[:, S - (s.d_conv - 1):, :] if S >= s.d_conv - 1 else jnp.pad(
            u_raw, ((0, 0), (s.d_conv - 1 - S, 0), (0, 0))
        )
    else:
        # Window of d_conv-1 pre-conv inputs ending at valid_len; the
        # left zero-pad makes valid_len < d_conv-1 match the short-prompt
        # branch above bit for bit.
        upad_l = jnp.pad(u_raw, ((0, 0), (s.d_conv - 1, 0), (0, 0)))
        vl = jnp.asarray(valid_len)
        if vl.ndim == 0:
            tail = jax.lax.dynamic_slice(
                upad_l, (0, valid_len, 0),
                (u_raw.shape[0], s.d_conv - 1, u_raw.shape[2]),
            )
        else:
            # Per-row valid lengths (batched burst prefill): gather each
            # row's window — same values dynamic_slice would produce row
            # by row.
            idx = vl[:, None] + jnp.arange(s.d_conv - 1)[None, :]
            tail = jnp.take_along_axis(upad_l, idx[..., None], axis=1)
    return out, {"h": h_last, "conv": tail}


def mamba_cache_init(cfg, batch: int, dtype=jnp.float32):
    s: SSMConfig = cfg.ssm
    d_inner, _ = ssm_dims(cfg)
    return {
        "h": jnp.zeros((batch, d_inner, s.d_state), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, d_inner), dtype),
    }


def mamba_step(p, x, cfg, cache):
    """Single-token decode: x (B, 1, D); O(1) state update."""
    s: SSMConfig = cfg.ssm
    B = x.shape[0]
    xz = dense(p["in_proj"], x[:, 0])
    u, z = jnp.split(xz, 2, axis=-1)                      # (B, Di)

    hist = jnp.concatenate([cache["conv"], u[:, None].astype(cache["conv"].dtype)], axis=1)
    w = p["conv_w"].astype(u.dtype)                       # (Di, K)
    conv = jnp.einsum("bkd,dk->bd", hist.astype(u.dtype), w) + p["conv_b"].astype(u.dtype)
    uc = jax.nn.silu(conv)

    dA, dBu, Cc = _ssm_coeffs(p, uc[:, None], cfg)        # (B,1,Di,N) etc.
    h = cache["h"] * dA[:, 0] + dBu[:, 0]
    y = jnp.einsum("bdn,bn->bd", h, Cc[:, 0]) + p["D"] * uc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = dense(p["out_proj"], y[:, None])
    return out, {"h": h, "conv": hist[:, 1:]}
