"""Shared model building blocks: norms, positions, FFNs, init helpers.

Pure-functional style: every module is an ``init(key, ...) -> params``
plus an ``apply(params, x, ...)`` pair operating on plain dict pytrees.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import repl_act, shard_act


def precision_island(name: str):
    """Declare a deliberate precision island around a block of ops.

    A thin wrapper over ``jax.named_scope`` with a tagged prefix: every
    equation traced inside carries ``island:<name>`` on its name stack,
    which ``repro.analysis.dtype_flow`` reads back to exempt the
    region's deliberate widening casts (f32 norms, rope tables, logits,
    optimizer moments, the DCIM quantize pipeline) from the precision
    lint.  Zero runtime cost — name stacks exist only in trace
    metadata."""
    return jax.named_scope(f"island:{name}")


def in_island(name: str):
    """Decorator form of :func:`precision_island` for whole functions."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapped(*args, **kwargs):
            with precision_island(name):
                return fn(*args, **kwargs)
        return wrapped
    return deco


def dense_init(key, d_in: int, d_out: int, dtype, bias: bool = False, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


# Pluggable matmul implementation for every ``dense`` in the model stack.
# ``repro.sim.functional.dcim_numerics`` installs a DCIM macro simulator
# here so serving (Engine / Scheduler) executes projections with the
# generated macro's numerics; ``None`` is the plain float path.  The hook
# is read at trace time, so jitted programs bake in whichever
# implementation was active when they were first called.
_MVM_IMPL = None


def set_mvm_impl(fn):
    """Install ``fn(x, w) -> y`` as the dense matmul; returns the
    previous implementation (for restore-on-exit context managers)."""
    global _MVM_IMPL
    prev = _MVM_IMPL
    _MVM_IMPL = fn
    return prev


def dense(p, x):
    with precision_island("dense"):
        # Cast only on a real mismatch: a no-op convert_element_type in
        # the jaxpr would read as a spurious cast site to the lint.
        w = p["w"] if p["w"].dtype == x.dtype else p["w"].astype(x.dtype)
        if _MVM_IMPL is None:
            y = jnp.matmul(x, w, preferred_element_type=jnp.float32)
            y = y.astype(x.dtype)
        else:
            y = _MVM_IMPL(x, w).astype(x.dtype)
        if "b" in p:
            b = p["b"] if p["b"].dtype == x.dtype else p["b"].astype(x.dtype)
            y = y + b
    return y


def last_valid_hidden(h, valid_len):
    """Gather the hidden state of the last *real* token per row.

    ``h`` is (B, S, D); ``valid_len`` is None (take index S-1), a traced
    scalar (all rows share one valid length — single-request bucketed
    prefill), or a (B,) vector of per-row valid lengths (batched burst
    prefill, where co-batched requests have different tail lengths).
    Rows with ``valid_len == 0`` (burst padding) clamp to index 0; their
    output is junk the caller must ignore.  Returns (B, 1, D)."""
    if valid_len is None:
        return h[:, -1:]
    idx = jnp.maximum(jnp.asarray(valid_len, jnp.int32) - 1, 0)
    if idx.ndim == 0:
        idx = jnp.broadcast_to(idx, (h.shape[0],))
    return jnp.take_along_axis(h, idx[:, None, None], axis=1)


def page_write_indices(block_tables, ctx_len, tail_valid, T, page_size):
    """(page, row) scatter indices for writing T tail positions into a
    paged KV pool.

    Position ``t`` of row ``b`` lands at global sequence position
    ``ctx_len[b] + t``, i.e. page ``block_tables[b, g // page_size]``,
    row ``g % page_size``.  Positions at or past ``tail_valid`` (bucket
    right-padding) are redirected to the reserved garbage page 0 so pad
    junk can never overwrite a live page.  Returns two (B, T) int32
    arrays (page_idx, row_idx)."""
    gpos = ctx_len[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    in_tail = jnp.arange(T)[None, :] < tail_valid[:, None]
    pg = jnp.take_along_axis(block_tables, gpos // page_size, axis=1)
    pg = jnp.where(in_tail, pg, 0)
    rw = jnp.where(in_tail, gpos % page_size, 0)
    return pg.astype(jnp.int32), rw.astype(jnp.int32)


# --- norms -------------------------------------------------------------------
def norm_init(d: int, kind: str, dtype):
    if kind == "rms":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def norm_apply(p, x, kind: str, eps: float = 1e-5):
    with precision_island("norm"):
        xf = x.astype(jnp.float32)
        if kind == "rms":
            y = xf * jax.lax.rsqrt(
                jnp.mean(xf * xf, axis=-1, keepdims=True) + eps
            )
        else:
            mu = jnp.mean(xf, axis=-1, keepdims=True)
            var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
            y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32)
        if "bias" in p:
            y = y + p["bias"].astype(jnp.float32)
        return y.astype(x.dtype)


# --- rotary positions ----------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, n_heads, head_dim); positions: broadcastable to (..., S)."""
    with precision_island("rope"):
        hd = x.shape[-1]
        inv = rope_freqs(hd, theta)                               # (hd/2,)
        ang = positions[..., None].astype(jnp.float32) * inv      # (..., S, hd/2)
        cos = jnp.cos(ang)[..., None, :]                          # (..., S, 1, hd/2)
        sin = jnp.sin(ang)[..., None, :]
        x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
        out = jnp.concatenate(
            [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
        )
        return out.astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray,
    positions: jnp.ndarray,   # (3, ..., S) — temporal / height / width ids
    sections,                 # e.g. (16, 24, 24); sums to head_dim // 2
    theta: float,
) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE: the rotary half-dims are partitioned into
    3 sections, each rotated by its own position stream."""
    with precision_island("rope"):
        hd = x.shape[-1]
        assert sum(sections) == hd // 2, (sections, hd)
        inv = rope_freqs(hd, theta)                               # (hd/2,)
        # Section id per rotary channel.
        sec_id = jnp.repeat(
            jnp.arange(3), jnp.asarray(sections), total_repeat_length=hd // 2
        )
        # positions: (3, ..., S) -> per-channel positions (..., S, hd/2)
        pos = jnp.moveaxis(positions, 0, -1)                      # (..., S, 3)
        pos_c = jnp.take_along_axis(
            pos.astype(jnp.float32),
            jnp.broadcast_to(
                sec_id, pos.shape[:-1] + (hd // 2,)
            ).astype(jnp.int32),
            axis=-1,
        )                                                         # (..., S, hd/2)
        ang = pos_c * inv
        cos = jnp.cos(ang)[..., None, :]
        sin = jnp.sin(ang)[..., None, :]
        x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
        out = jnp.concatenate(
            [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
        )
        return out.astype(x.dtype)


def sinusoidal_positions(positions: jnp.ndarray, d_model: int) -> jnp.ndarray:
    """MusicGen-style fixed sinusoidal embeddings; positions (..., S)."""
    half = d_model // 2
    freq = jnp.exp(
        -math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half
    )
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --- feed-forward ---------------------------------------------------------------
def ffn_init(key, d_model: int, d_ff: int, act: str, dtype, bias: bool = False):
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d_model, d_ff, dtype, bias)}
    if act == "swiglu":
        p["w_gate"] = dense_init(ks[1], d_model, d_ff, dtype, bias)
    p["w_down"] = dense_init(ks[2], d_ff, d_model, dtype, bias)
    return p


def ffn_apply(p, x, act: str):
    up = dense(p["w_up"], x)
    if act == "swiglu":
        h = jax.nn.silu(dense(p["w_gate"], x)) * up
    elif act == "gelu":
        h = jax.nn.gelu(up)
    else:  # pragma: no cover
        raise ValueError(act)
    h = shard_act(h, ("batch", None, "ff"))
    # Exact serving gathers the ff dim before the w_down contraction.
    return dense(p["w_down"], repl_act(h))


def softmax_xent_chunked(
    logits_fn,
    h: jnp.ndarray,              # (B, S, D) final hidden states
    targets: jnp.ndarray,        # (B, S) int32
    mask: Optional[jnp.ndarray],
    chunk: int = 0,
):
    """Cross-entropy over a (possibly huge) vocab without materializing the
    full (B, S, V) logits: scan over sequence chunks.  ``logits_fn`` maps
    (B, C, D) -> (B, C, V)."""
    B, S, D = h.shape
    if chunk <= 0 or S % chunk != 0 or S == chunk:
        logits = logits_fn(h)
        return _xent(logits, targets, mask)

    n = S // chunk
    hs = h.reshape(B, n, chunk, D).swapaxes(0, 1)          # (n, B, C, D)
    ts = targets.reshape(B, n, chunk).swapaxes(0, 1)
    ms = None if mask is None else mask.reshape(B, n, chunk).swapaxes(0, 1)

    def body(carry, xs):
        tot, cnt = carry
        if ms is None:
            hc, tc = xs
            mc = None
        else:
            hc, tc, mc = xs
        loss, weight = _xent(logits_fn(hc), tc, mc, reduce=False)
        return (tot + loss, cnt + weight), None

    xs = (hs, ts) if ms is None else (hs, ts, ms)
    (tot, cnt), _ = jax.lax.scan(body, (0.0, 0.0), xs)
    return tot / jnp.maximum(cnt, 1.0)


def _xent(logits, targets, mask, reduce: bool = True):
    with precision_island("xent"):
        # Pin the (..., V) logits (and, through the transpose rule of
        # with_sharding_constraint, their cotangent) to the vocab-sharded
        # layout the unembedding produces.  Without the annotation the SPMD
        # partitioner has to invent a sharding for the logits cotangent
        # inside the transposed loss-chunk scan and falls back to an
        # "involuntary full rematerialization" copy of the full (B, C, V)
        # tensor on the 2x16x16 production mesh.
        logits = shard_act(
            logits.astype(jnp.float32),
            ("batch",) + (None,) * (logits.ndim - 2) + ("vocab",),
        )
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
        nll = logz - gold
        if mask is None:
            mask = jnp.ones_like(nll)
        mask = mask.astype(jnp.float32)
        tot = jnp.sum(nll * mask)
        cnt = jnp.sum(mask)
        if reduce:
            return tot / jnp.maximum(cnt, 1.0)
        return tot, cnt
