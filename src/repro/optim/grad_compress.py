"""Int8 gradient compression with error feedback.

For bandwidth-bound data parallelism: a *compressed ring all-reduce* —
both the reduce-scatter and all-gather phases move int8 payloads (+ per
block f32 scales, 1/512 overhead) over the wire via ``lax.ppermute``,
with int32/f32 accumulation on-device and re-quantization at each hop
(exactly how production compressed rings behave; the re-quantization
noise is absorbed by error feedback).

 * ``compress_decompress``: pure quantize->dequantize with error feedback
   (usable under pjit to emulate wire precision anywhere).
 * ``ring_allreduce_int8``: the shard_map collective.
 * ``mean_grads_int8``: pytree wrapper used by the trainer's
   ``grad_compression="int8"`` mode.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

_BLOCK = 512


def quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (..., L) f32 -> int8 payload + per-block f32 scales."""
    blocks = x.reshape(x.shape[:-1] + (-1, _BLOCK)) if x.shape[-1] % _BLOCK == 0 \
        else None
    if blocks is None:
        pad = (-x.shape[-1]) % _BLOCK
        xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
        blocks = xp.reshape(x.shape[:-1] + (-1, _BLOCK))
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray, length: int) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(q.shape[:-2] + (-1,))
    return flat[..., :length]


def compress_decompress(g: jnp.ndarray, err: jnp.ndarray):
    """Quantize+dequantize with error feedback; returns (g_hat, new_err)."""
    corrected = (g.astype(jnp.float32) + err).reshape(-1)
    q, s = quantize(corrected)
    deq = dequantize(q, s, corrected.size).reshape(g.shape)
    return deq.astype(g.dtype), (corrected.reshape(g.shape) - deq)


def ring_allreduce_int8(x: jnp.ndarray, axis: str, n: int) -> jnp.ndarray:
    """Compressed ring all-reduce (sum) of a flat f32 vector over ``axis``.
    Wire traffic is int8 payload + f32 block scales in both phases."""
    if n == 1:
        return x
    L = -(-x.size // n)
    xp = jnp.pad(x.reshape(-1), (0, n * L - x.size)).reshape(n, L)
    rank = lax.axis_index(axis)
    fwd = [(i, (i + 1) % n) for i in range(n)]

    def hop(chunk_f32):
        q, s = quantize(chunk_f32)
        q = lax.ppermute(q, axis, fwd)
        s = lax.ppermute(s, axis, fwd)
        return dequantize(q, s, L)

    # Phase 1: reduce-scatter.  After n-1 hops, chunk (rank+1) mod n on
    # each device holds the full sum.
    def rs_body(step, acc):
        idx_send = (rank - step) % n
        got = hop(lax.dynamic_index_in_dim(acc, idx_send, keepdims=False))
        idx_recv = (rank - step - 1) % n
        upd = lax.dynamic_index_in_dim(acc, idx_recv, keepdims=False) + got
        return lax.dynamic_update_index_in_dim(acc, upd, idx_recv, 0)

    acc = lax.fori_loop(0, n - 1, rs_body, xp)

    # Phase 2: all-gather the reduced chunks (int8 on the wire).
    def ag_body(step, acc):
        idx_send = (rank + 1 - step) % n
        got = hop(lax.dynamic_index_in_dim(acc, idx_send, keepdims=False))
        idx_recv = (rank - step) % n
        return lax.dynamic_update_index_in_dim(acc, got, idx_recv, 0)

    acc = lax.fori_loop(0, n - 1, ag_body, acc)
    return acc.reshape(-1)[: x.size].reshape(x.shape)


def mean_grads_int8(grads, errors, axis: str, n: int):
    """Pytree compressed-mean with error feedback; shard_map body."""

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        summed = ring_allreduce_int8(corrected, axis, n) / n
        # Error feedback vs what this device injected into the wire.
        q, s = quantize(corrected.reshape(-1))
        deq = dequantize(q, s, corrected.size).reshape(g.shape)
        return summed.astype(g.dtype), corrected - deq

    flat_g, td = jax.tree.flatten(grads)
    flat_e = td.flatten_up_to(errors)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return td.unflatten([o[0] for o in outs]), td.unflatten([o[1] for o in outs])


def init_error_state(params):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), params)


def int8_allreduce_grads(grads, errors, axis: str):  # pragma: no cover - alias
    n = jax.lax.axis_size(axis)
    return mean_grads_int8(grads, errors, axis, n)
