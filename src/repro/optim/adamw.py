"""AdamW with optional 8-bit (block-quantized) moments.

The 8-bit variant stores m/v as int8 with per-block fp32 scales
(block = trailing dim), cutting optimizer memory 4x — one of the
distributed-optimization tricks used for the biggest assigned configs.
Interface matches optax: ``init(params) -> state``, ``update(grads,
state, params) -> (updates, state)``.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common


class Transform(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Any]


_BLOCK = 256


def _q8(x: jnp.ndarray):
    flat = x.reshape(-1)
    pad = (-flat.size) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    return flat[: int(jnp.prod(jnp.asarray(shape)))].reshape(shape)


def adamw(
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    quantize_moments: bool = False,
) -> Transform:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        def one(p):
            z = jnp.zeros_like(p, jnp.float32)
            if quantize_moments:
                qm, sm = _q8(z)
                qv, sv = _q8(z)
                return {"m_q": qm, "m_s": sm, "v_q": qv, "v_s": sv}
            # Distinct buffers: aliasing m and v to one zeros array makes
            # any donate_argnums train step donate the same buffer twice.
            return {"m": z, "v": jnp.zeros_like(p, jnp.float32)}

        return {"mu": jax.tree.map(one, params), "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr_fn(step)

        @common.in_island("optimizer")
        def one(g, s, p):
            g = g.astype(jnp.float32)
            if quantize_moments:
                m = _dq8(s["m_q"], s["m_s"], g.shape)
                v = _dq8(s["v_q"], s["v_s"], g.shape)
            else:
                m, v = s["m"], s["v"]
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / (1 - b1 ** step.astype(jnp.float32))
            vhat = v / (1 - b2 ** step.astype(jnp.float32))
            upd = -lr_t * (
                mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            )
            if quantize_moments:
                qm, sm = _q8(m)
                qv, sv = _q8(v)
                return upd, {"m_q": qm, "m_s": sm, "v_q": qv, "v_s": sv}
            return upd, {"m": m, "v": v}

        flat_g, treedef = jax.tree.flatten(grads)
        flat_s = treedef.flatten_up_to(state["mu"])
        flat_p = treedef.flatten_up_to(params)
        outs = [one(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        updates = treedef.unflatten([o[0] for o in outs])
        mu = treedef.unflatten([o[1] for o in outs])
        return updates, {"mu": mu, "step": step}

    return Transform(init, update)


def apply_updates(params, updates):
    with common.precision_island("optimizer"):
        return jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
            params, updates,
        )
