"""Optimizers + schedules + distributed-optimization tricks (gradient
compression, factored/quantized moments)."""
from .adamw import adamw  # noqa: F401
from .adafactor import adafactor  # noqa: F401
from .schedule import cosine_warmup  # noqa: F401
from .grad_compress import compress_decompress, int8_allreduce_grads  # noqa: F401


def get_optimizer(name: str, lr, **kw):
    if name == "adamw":
        return adamw(lr, **kw)
    if name == "adamw8bit":
        return adamw(lr, quantize_moments=True, **kw)
    if name == "adafactor":
        return adafactor(lr, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
