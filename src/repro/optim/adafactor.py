"""Adafactor (Shazeer & Stern 2018): factored second moments — O(n+m)
optimizer state for an (n, m) weight instead of O(nm).  The default
optimizer for the 671B-class configs, where full Adam moments would not
fit the per-device HBM budget (see EXPERIMENTS.md §Dry-run)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .adamw import Transform


def adafactor(
    lr,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
    min_dim_size_to_factor: int = 128,
) -> Transform:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def factored(shape):
        return (
            len(shape) >= 2
            and shape[-1] >= min_dim_size_to_factor
            and shape[-2] >= min_dim_size_to_factor
        )

    def init(params):
        def one(p):
            if factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p, jnp.float32)}

        return {"mu": jax.tree.map(one, params), "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** -decay
        lr_t = lr_fn(step)

        def one(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if "vr" in s:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                u = (
                    g
                    / jnp.sqrt(vr / denom)[..., None]
                    / jnp.sqrt(vc)[..., None, :]
                )
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g / jnp.sqrt(v)
                new_s = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            upd = -lr_t * (u + weight_decay * p.astype(jnp.float32))
            return upd, new_s

        flat_g, treedef = jax.tree.flatten(grads)
        flat_s = treedef.flatten_up_to(state["mu"])
        flat_p = treedef.flatten_up_to(params)
        outs = [one(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        return treedef.unflatten([o[0] for o in outs]), {
            "mu": treedef.unflatten([o[1] for o in outs]),
            "step": step,
        }

    return Transform(init, update)
