"""Host-side accounting for the paged KV-cache pool: page allocation,
refcounts, and the shared-prefix hash index.

The device side (``repro.models.lm.init_paged_pool`` and friends) is a
dumb array of pages; every policy decision lives here, on the host:

* **allocation** — pages are a fixed pool of ids ``1 .. n_pages-1``
  (page 0 is the reserved garbage page that absorbs masked writes).
  Allocation prefers never-used/plain-freed pages and falls back to
  evicting least-recently-used *cached* prefix pages.
* **refcounts** — a page's refcount is the number of live requests whose
  block table names it.  Shared prefix pages are refcounted up on every
  hit; retirement decrements.  A prefix page whose refcount drops to 0
  is not freed — it moves to the CACHED state (content intact, still in
  the hash index) so a later request with the same prefix can still hit
  it; it is only reclaimed when allocation pressure evicts it.
* **prefix index** — prompts are hashed at page granularity with a
  rolling chain (``h_i = sha1(h_{i-1} || tokens[i*page : (i+1)*page])``)
  so a chain hash identifies the ENTIRE prefix up to that page, not just
  the page's own tokens.  ``match_prefix`` walks the chain and returns
  the longest resident run of pages.  Only pages fully covered by the
  prompt are ever indexed — a page decode will write into must stay
  private.  The match is additionally capped one token short of the full
  prompt so every admitted request prefills at least its last token
  (the logits source for its first sampled token).
* **chain cleanup** — the index remembers each hash's parent/children;
  evicting a page drops its (chain-unreachable) descendants too: cached
  orphans go straight back to the free list, live orphans lose their
  index entry and free like private pages at retirement.  Without this,
  a ``ServeSession``'s pool — which persists across traces — would
  slowly fill its LRU with unreachable pages.
* **trace accounting** — a persistent session calls ``begin_trace()``
  at each trace boundary; a prefix hit on a page filled by an EARLIER
  trace counts as a *cross-trace* hit (``PageStats.cross_trace_hits``),
  the warm-session signal surfaced through ``ServeStats``.

``check_page_capacity`` is the page-pool half of the admission contract:
like :func:`repro.serve.engine.check_capacity` it raises ``ValueError``
(a real error, not an assert) for requests that could never be served
even by an empty pool.  Transient exhaustion — enough total pages, but
other requests hold them — is not an error: the scheduler keeps the
request queued until retirements free pages.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

GARBAGE_PAGE = 0


def pages_needed(prompt_len: int, n_tokens: int, page_size: int) -> int:
    """Pages a request can touch over its whole life: prompt positions
    [0, P) plus decode writes at [P, P + n_tokens - 1) (the last sampled
    token is returned but never written back)."""
    return -(-(prompt_len + max(n_tokens, 1) - 1) // page_size)


def check_page_capacity(prompt_len: int, n_tokens: int, page_size: int,
                        usable_pages: int) -> None:
    """Admission control for the paged pool: reject requests that exceed
    the pool outright (mirrors ``serve.check_capacity``'s ValueError
    contract — transient exhaustion is handled by queueing instead)."""
    need = pages_needed(prompt_len, n_tokens, page_size)
    if need > usable_pages:
        raise ValueError(
            f"request exceeds page-pool capacity: prompt length "
            f"{prompt_len} + n_tokens {n_tokens} needs {need} pages of "
            f"{page_size} tokens > {usable_pages} usable pages; shorten "
            f"the prompt, request fewer tokens, or build the Scheduler "
            f"with more pages"
        )


def prefix_page_hashes(prompt: np.ndarray, page_size: int) -> List[str]:
    """Chain hashes for every page FULLY covered by the prompt.  Entry i
    identifies tokens [0, (i+1)*page_size) — the whole prefix, so equal
    hashes imply equal prefixes (up to SHA-1 collisions)."""
    prompt = np.asarray(prompt, np.int32)
    out: List[str] = []
    h = hashlib.sha1(b"kv-prefix")
    for i in range(prompt.size // page_size):
        h = h.copy()
        h.update(prompt[i * page_size:(i + 1) * page_size].tobytes())
        out.append(h.hexdigest())
    return out


@dataclasses.dataclass
class PageStats:
    """Counters exposed through ``Scheduler.last_stats``.

    ``prefix_hits`` counts every page served from the index;
    ``cross_trace_hits`` is the subset whose page was *filled by an
    earlier trace* of the same session (see ``PagePool.begin_trace``) —
    the warm-session signal a persistent ``ServeSession`` exists to
    produce.  Counters are cumulative over the pool's lifetime; per-trace
    views are diffs of two snapshots (``PageStats.delta``)."""
    n_pages: int = 0                  # usable pages (garbage excluded)
    page_size: int = 0
    prefix_hits: int = 0              # pages reused via the prefix index
    prefix_misses: int = 0            # full prompt pages that had to be filled
    prefix_hit_tokens: int = 0        # prompt tokens whose prefill was skipped
    cross_trace_hits: int = 0         # hits on pages filled by an earlier trace
    cross_trace_hit_tokens: int = 0   # their token count
    evictions: int = 0                # cached prefix pages reclaimed
    orphaned_live: int = 0            # live pages unindexed by a parent eviction
    peak_pages_in_use: int = 0        # max live (refcount > 0) pages
    cached_pages: int = 0             # refcount-0 pages still in the index

    # Gauges keep their current value in a per-trace delta; everything
    # else is a monotonic counter and diffs.
    _GAUGES = ("n_pages", "page_size", "peak_pages_in_use", "cached_pages")

    def as_dict(self) -> dict:
        """Lifetime counters as a plain dict.  ``Scheduler.last_stats``
        carries per-trace :meth:`delta` views, not this."""
        return dataclasses.asdict(self)

    def delta(self, since: "PageStats") -> dict:
        """Per-trace view: counters since the ``since`` snapshot, gauges
        at their current value."""
        out = {}
        for f in dataclasses.fields(self):
            cur = getattr(self, f.name)
            out[f.name] = (
                cur if f.name in self._GAUGES else cur - getattr(since, f.name)
            )
        return out

    def snapshot(self) -> "PageStats":
        return dataclasses.replace(self)


class PagePool:
    """Host-side page allocator + refcounts + shared-prefix index.

    Pages move between three states: FREE (unallocated, content
    meaningless), LIVE (refcount > 0, named by at least one block
    table), and CACHED (refcount 0 but content is an indexed prompt
    prefix — reusable until evicted, LRU order)."""

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError(f"need >= 2 pages (one is garbage), got {n_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.page_size = page_size
        self.n_pages = n_pages
        self.usable_pages = n_pages - 1           # page 0 is garbage
        self._free: List[int] = list(range(n_pages - 1, 0, -1))  # pop() -> 1 first
        self._ref = np.zeros(n_pages, np.int32)
        # chain hash -> page id, for pages whose content is an indexed
        # prompt prefix (LIVE or CACHED).
        self._index: Dict[str, int] = {}
        self._page_hash: Dict[int, str] = {}      # inverse of _index
        # Chain structure of the index: hash -> parent hash / child
        # hashes, so evicting a parent can free its (now unreachable)
        # descendants' accounting instead of letting them squat in the
        # LRU (see _orphan_descendants).
        self._parent: Dict[str, Optional[str]] = {}
        self._children: Dict[str, List[str]] = {}
        # CACHED pages in LRU order (oldest first).
        self._lru: "OrderedDict[int, None]" = OrderedDict()
        # Trace accounting for persistent sessions: begin_trace() bumps
        # the id; a hit on a page filled under an older id is a
        # cross-trace hit.  A pool that never sees begin_trace() stays
        # in trace 0 and counts everything as intra-trace.
        self.trace_id = 0
        self._page_trace: Dict[int, int] = {}
        self.stats = PageStats(n_pages=self.usable_pages, page_size=page_size)

    def begin_trace(self) -> None:
        """Mark a trace boundary: pages indexed before this call count
        as cross-trace when hit afterwards."""
        self.trace_id += 1

    # ------------------------------ queries ---------------------------------
    def refcount(self, page: int) -> int:
        return int(self._ref[page])

    @property
    def live_pages(self) -> int:
        return int((self._ref[1:] > 0).sum())

    def available(self) -> int:
        """Pages allocatable right now: free + evictable cached."""
        return len(self._free) + len(self._lru)

    def check_conservation(self) -> None:
        """Page-conservation invariant: every usable page is exactly one
        of FREE, CACHED (zero-ref, indexed, evictable) or LIVE — i.e.
        ``available() + live_pages == usable_pages`` — and no cached
        page carries a reference.  Raises ``RuntimeError`` on violation.
        Cheap enough for tests to call after every operation; the
        scheduler's preemption path (release + later re-allocate of the
        same prefix) must preserve it at every step."""
        free, cached, live = len(self._free), len(self._lru), self.live_pages
        if free + cached + live != self.usable_pages:
            raise RuntimeError(
                f"page accounting violated: {free} free + {cached} cached "
                f"+ {live} live != {self.usable_pages} usable"
            )
        for page in self._lru:
            if self._ref[page] != 0:
                raise RuntimeError(
                    f"cached page {page} holds refcount {int(self._ref[page])}"
                )

    def match_prefix(self, prompt: np.ndarray) -> Tuple[List[int], List[str]]:
        """Longest resident prefix run for ``prompt``.

        Returns ``(pages, hashes)`` where ``hashes`` covers every fully
        prompt-covered page (capped one token short of the prompt so the
        tail prefill is never empty) and ``pages[:k]`` are the resident
        pages for the first ``k`` hashes.  The walk stops at the first
        miss: a resident child behind an evicted parent is unreachable
        by construction (chain hashing)."""
        prompt = np.asarray(prompt, np.int32)
        # Cap: at least the last prompt token must be prefilled.
        max_pages = (prompt.size - 1) // self.page_size
        hashes = prefix_page_hashes(prompt, self.page_size)[:max_pages]
        pages: List[int] = []
        for h in hashes:
            page = self._index.get(h)
            if page is None:
                break
            pages.append(page)
        return pages, hashes

    # ----------------------------- transitions ------------------------------
    def _unlink_from_parent(self, h: str) -> None:
        par = self._parent.pop(h, None)
        if par is not None:
            sibs = self._children.get(par)
            if sibs is not None:
                if h in sibs:
                    sibs.remove(h)
                if not sibs:
                    del self._children[par]

    def _orphan_descendants(self, h: str) -> None:
        """Chain hashing makes every descendant of an evicted hash
        unreachable by ``match_prefix`` (the walk stops at the first
        miss), so keeping them indexed only leaks accounting: a CACHED
        orphan squats in the LRU competing with reachable pages, and a
        LIVE orphan would re-enter the LRU at release and squat forever.
        Free them instead: cached orphans go straight back to the free
        list (counted as evictions — they are reclaimed cache), live
        orphans just lose their index entry and free like private pages
        when their tenant retires.  Iterative (a worklist, not
        recursion): a long prompt's chain can be thousands of pages
        deep."""
        work = list(self._children.pop(h, []))
        while work:
            c = work.pop()
            work.extend(self._children.pop(c, []))
            page = self._index.pop(c, None)
            if page is None:                    # already dropped
                continue
            self._page_hash.pop(page, None)
            self._parent.pop(c, None)
            self._page_trace.pop(page, None)
            if self._ref[page] == 0:
                self._lru.pop(page, None)
                self._free.append(page)
                self.stats.evictions += 1
            else:
                self.stats.orphaned_live += 1

    def _evict_one(self) -> int:
        page, _ = self._lru.popitem(last=False)       # oldest cached page
        h = self._page_hash.pop(page)
        del self._index[h]
        self._unlink_from_parent(h)
        self._page_trace.pop(page, None)
        self.stats.evictions += 1
        self._orphan_descendants(h)
        return page

    def allocate(self, n: int) -> List[int]:
        """Allocate ``n`` fresh private pages (refcount 1 each), evicting
        LRU cached prefix pages under pressure.  Raises RuntimeError on
        true exhaustion — the scheduler checks ``available()`` first, so
        hitting this is a bug, not an admission-control path."""
        if n > self.available():
            raise RuntimeError(
                f"page pool exhausted: need {n}, have {self.available()}"
            )
        out = []
        for _ in range(n):
            page = self._free.pop() if self._free else self._evict_one()
            self._ref[page] = 1
            out.append(page)
        self._track_peak()
        return out

    def _cross_trace_count(self, pages: List[int]) -> int:
        return sum(
            1 for p in pages
            if self._page_trace.get(p, self.trace_id) < self.trace_id
        )

    def ref(self, pages: List[int]) -> None:
        """Take a reference on resident prefix pages (a hit).  CACHED
        pages return to LIVE.  Hits on pages filled by an earlier trace
        (older ``trace_id``) also count as cross-trace hits."""
        for page in pages:
            if self._ref[page] == 0:
                self._lru.pop(page, None)
            self._ref[page] += 1
        cross = self._cross_trace_count(pages)
        self.stats.prefix_hits += len(pages)
        self.stats.prefix_hit_tokens += len(pages) * self.page_size
        self.stats.cross_trace_hits += cross
        self.stats.cross_trace_hit_tokens += cross * self.page_size
        self._track_peak()

    def unref(self, pages: List[int]) -> None:
        """Roll back a :meth:`ref` that did not lead to an admission
        (e.g. the page pool could not cover the request's fresh pages).
        Reverses both the refcounts and the hit counters the ref charged
        (cross-trace ones included — page fill-trace ids cannot change
        between a ref and its rollback); ``peak_pages_in_use`` stays a
        true high-water mark, transient pins included."""
        self.release(pages)
        cross = self._cross_trace_count(pages)
        self.stats.prefix_hits -= len(pages)
        self.stats.prefix_hit_tokens -= len(pages) * self.page_size
        self.stats.cross_trace_hits -= cross
        self.stats.cross_trace_hit_tokens -= cross * self.page_size

    def register_prefix(self, hashes: List[str], pages: List[int],
                        parent: Optional[str] = None) -> None:
        """Index freshly-allocated pages as prefix pages (content is
        filled by the admission's prefill program before any later
        admission can look them up).  ``hashes`` is a contiguous chain
        run: entry ``i+1`` is a child of entry ``i``; ``parent`` is the
        chain hash preceding ``hashes[0]`` (``None`` for a chain root) —
        the linkage eviction uses to free orphaned descendants."""
        for i, (h, page) in enumerate(zip(hashes, pages)):
            old = self._index.get(h)
            if old is not None:
                # Either a re-registration of the same pair (no-op) or
                # the same prefix filled twice concurrently (burst
                # split): keep the existing entry — the new page stays a
                # private unindexed page — and keep the existing chain
                # links either way.
                continue
            self._index[h] = page
            self._page_hash[page] = h
            self._page_trace[page] = self.trace_id
            par = hashes[i - 1] if i > 0 else parent
            self._parent[h] = par
            if par is not None:
                self._children.setdefault(par, []).append(h)
        self.stats.prefix_misses += len(hashes)

    def release(self, pages: List[int]) -> None:
        """Drop one reference per page.  Zero-ref indexed pages become
        CACHED (evictable, still hittable); zero-ref private pages go
        straight back to the free list.  CACHED-not-freed is what makes
        scheduler preemption cheap: an evicted request's registered
        prefix pages stay hittable, so its resume re-prefills only the
        unregistered tail unless allocation pressure evicted them."""
        for page in pages:
            if self._ref[page] < 1:
                raise ValueError(f"page {page} is not live")
            self._ref[page] -= 1
            if self._ref[page] == 0:
                if page in self._page_hash:
                    self._lru[page] = None
                    self._lru.move_to_end(page)
                else:
                    self._free.append(page)
        self.stats.cached_pages = len(self._lru)

    def _track_peak(self) -> None:
        self.stats.peak_pages_in_use = max(
            self.stats.peak_pages_in_use, self.live_pages
        )
        self.stats.cached_pages = len(self._lru)
