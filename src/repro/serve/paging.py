"""Host-side accounting for the paged KV-cache pool: page allocation,
refcounts, and the shared-prefix hash index.

The device side (``repro.models.lm.init_paged_pool`` and friends) is a
dumb array of pages; every policy decision lives here, on the host:

* **allocation** — pages are a fixed pool of ids ``1 .. n_pages-1``
  (page 0 is the reserved garbage page that absorbs masked writes).
  Allocation prefers never-used/plain-freed pages and falls back to
  evicting least-recently-used *cached* prefix pages.
* **refcounts** — a page's refcount is the number of live requests whose
  block table names it.  Shared prefix pages are refcounted up on every
  hit; retirement decrements.  A prefix page whose refcount drops to 0
  is not freed — it moves to the CACHED state (content intact, still in
  the hash index) so a later request with the same prefix can still hit
  it; it is only reclaimed when allocation pressure evicts it.
* **prefix index** — prompts are hashed at page granularity with a
  rolling chain (``h_i = sha1(h_{i-1} || tokens[i*page : (i+1)*page])``)
  so a chain hash identifies the ENTIRE prefix up to that page, not just
  the page's own tokens.  ``match_prefix`` walks the chain and returns
  the longest resident run of pages.  Only pages fully covered by the
  prompt are ever indexed — a page decode will write into must stay
  private.  The match is additionally capped one token short of the full
  prompt so every admitted request prefills at least its last token
  (the logits source for its first sampled token).

``check_page_capacity`` is the page-pool half of the admission contract:
like :func:`repro.serve.engine.check_capacity` it raises ``ValueError``
(a real error, not an assert) for requests that could never be served
even by an empty pool.  Transient exhaustion — enough total pages, but
other requests hold them — is not an error: the scheduler keeps the
request queued until retirements free pages.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Dict, List, Tuple

import numpy as np

GARBAGE_PAGE = 0


def pages_needed(prompt_len: int, n_tokens: int, page_size: int) -> int:
    """Pages a request can touch over its whole life: prompt positions
    [0, P) plus decode writes at [P, P + n_tokens - 1) (the last sampled
    token is returned but never written back)."""
    return -(-(prompt_len + max(n_tokens, 1) - 1) // page_size)


def check_page_capacity(prompt_len: int, n_tokens: int, page_size: int,
                        usable_pages: int) -> None:
    """Admission control for the paged pool: reject requests that exceed
    the pool outright (mirrors ``serve.check_capacity``'s ValueError
    contract — transient exhaustion is handled by queueing instead)."""
    need = pages_needed(prompt_len, n_tokens, page_size)
    if need > usable_pages:
        raise ValueError(
            f"request exceeds page-pool capacity: prompt length "
            f"{prompt_len} + n_tokens {n_tokens} needs {need} pages of "
            f"{page_size} tokens > {usable_pages} usable pages; shorten "
            f"the prompt, request fewer tokens, or build the Scheduler "
            f"with more pages"
        )


def prefix_page_hashes(prompt: np.ndarray, page_size: int) -> List[str]:
    """Chain hashes for every page FULLY covered by the prompt.  Entry i
    identifies tokens [0, (i+1)*page_size) — the whole prefix, so equal
    hashes imply equal prefixes (up to SHA-1 collisions)."""
    prompt = np.asarray(prompt, np.int32)
    out: List[str] = []
    h = hashlib.sha1(b"kv-prefix")
    for i in range(prompt.size // page_size):
        h = h.copy()
        h.update(prompt[i * page_size:(i + 1) * page_size].tobytes())
        out.append(h.hexdigest())
    return out


@dataclasses.dataclass
class PageStats:
    """Counters exposed through ``Scheduler.last_stats``."""
    n_pages: int = 0                  # usable pages (garbage excluded)
    page_size: int = 0
    prefix_hits: int = 0              # pages reused via the prefix index
    prefix_misses: int = 0            # full prompt pages that had to be filled
    prefix_hit_tokens: int = 0        # prompt tokens whose prefill was skipped
    evictions: int = 0                # cached prefix pages reclaimed
    peak_pages_in_use: int = 0        # max live (refcount > 0) pages
    cached_pages: int = 0             # refcount-0 pages still in the index

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class PagePool:
    """Host-side page allocator + refcounts + shared-prefix index.

    Pages move between three states: FREE (unallocated, content
    meaningless), LIVE (refcount > 0, named by at least one block
    table), and CACHED (refcount 0 but content is an indexed prompt
    prefix — reusable until evicted, LRU order)."""

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError(f"need >= 2 pages (one is garbage), got {n_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.page_size = page_size
        self.n_pages = n_pages
        self.usable_pages = n_pages - 1           # page 0 is garbage
        self._free: List[int] = list(range(n_pages - 1, 0, -1))  # pop() -> 1 first
        self._ref = np.zeros(n_pages, np.int32)
        # chain hash -> page id, for pages whose content is an indexed
        # prompt prefix (LIVE or CACHED).
        self._index: Dict[str, int] = {}
        self._page_hash: Dict[int, str] = {}      # inverse of _index
        # CACHED pages in LRU order (oldest first).
        self._lru: "OrderedDict[int, None]" = OrderedDict()
        self.stats = PageStats(n_pages=self.usable_pages, page_size=page_size)

    # ------------------------------ queries ---------------------------------
    def refcount(self, page: int) -> int:
        return int(self._ref[page])

    @property
    def live_pages(self) -> int:
        return int((self._ref[1:] > 0).sum())

    def available(self) -> int:
        """Pages allocatable right now: free + evictable cached."""
        return len(self._free) + len(self._lru)

    def match_prefix(self, prompt: np.ndarray) -> Tuple[List[int], List[str]]:
        """Longest resident prefix run for ``prompt``.

        Returns ``(pages, hashes)`` where ``hashes`` covers every fully
        prompt-covered page (capped one token short of the prompt so the
        tail prefill is never empty) and ``pages[:k]`` are the resident
        pages for the first ``k`` hashes.  The walk stops at the first
        miss: a resident child behind an evicted parent is unreachable
        by construction (chain hashing)."""
        prompt = np.asarray(prompt, np.int32)
        # Cap: at least the last prompt token must be prefilled.
        max_pages = (prompt.size - 1) // self.page_size
        hashes = prefix_page_hashes(prompt, self.page_size)[:max_pages]
        pages: List[int] = []
        for h in hashes:
            page = self._index.get(h)
            if page is None:
                break
            pages.append(page)
        return pages, hashes

    # ----------------------------- transitions ------------------------------
    def _evict_one(self) -> int:
        page, _ = self._lru.popitem(last=False)       # oldest cached page
        h = self._page_hash.pop(page)
        del self._index[h]
        self.stats.evictions += 1
        return page

    def allocate(self, n: int) -> List[int]:
        """Allocate ``n`` fresh private pages (refcount 1 each), evicting
        LRU cached prefix pages under pressure.  Raises RuntimeError on
        true exhaustion — the scheduler checks ``available()`` first, so
        hitting this is a bug, not an admission-control path."""
        if n > self.available():
            raise RuntimeError(
                f"page pool exhausted: need {n}, have {self.available()}"
            )
        out = []
        for _ in range(n):
            page = self._free.pop() if self._free else self._evict_one()
            self._ref[page] = 1
            out.append(page)
        self._track_peak()
        return out

    def ref(self, pages: List[int]) -> None:
        """Take a reference on resident prefix pages (a hit).  CACHED
        pages return to LIVE."""
        for page in pages:
            if self._ref[page] == 0:
                self._lru.pop(page, None)
            self._ref[page] += 1
        self.stats.prefix_hits += len(pages)
        self.stats.prefix_hit_tokens += len(pages) * self.page_size
        self._track_peak()

    def unref(self, pages: List[int]) -> None:
        """Roll back a :meth:`ref` that did not lead to an admission
        (e.g. the page pool could not cover the request's fresh pages).
        Reverses both the refcounts and the hit counters the ref charged;
        ``peak_pages_in_use`` stays a true high-water mark, transient
        pins included."""
        self.release(pages)
        self.stats.prefix_hits -= len(pages)
        self.stats.prefix_hit_tokens -= len(pages) * self.page_size

    def register_prefix(self, hashes: List[str], pages: List[int]) -> None:
        """Index freshly-allocated pages as prefix pages (content is
        filled by the admission's prefill program before any later
        admission can look them up)."""
        for h, page in zip(hashes, pages):
            old = self._index.get(h)
            if old is not None and old != page:
                # The same prefix was filled twice concurrently (burst
                # split); keep the existing entry, the new page stays a
                # private unindexed page.
                continue
            self._index[h] = page
            self._page_hash[page] = h
        self.stats.prefix_misses += len(hashes)

    def release(self, pages: List[int]) -> None:
        """Drop one reference per page.  Zero-ref indexed pages become
        CACHED (evictable, still hittable); zero-ref private pages go
        straight back to the free list."""
        for page in pages:
            if self._ref[page] < 1:
                raise ValueError(f"page {page} is not live")
            self._ref[page] -= 1
            if self._ref[page] == 0:
                if page in self._page_hash:
                    self._lru[page] = None
                    self._lru.move_to_end(page)
                else:
                    self._free.append(page)
        self.stats.cached_pages = len(self._lru)

    def _track_peak(self) -> None:
        self.stats.peak_pages_in_use = max(
            self.stats.peak_pages_in_use, self.live_pages
        )
        self.stats.cached_pages = len(self._lru)
