"""Batched serving engine: prefill + decode with KV/SSM caches.

Requests are grouped into equal-prompt-length batches (length bucketing);
generation is greedy or temperature sampling.  Sampling is *per request*:
PRNG keys derive from ``(seed, request_id)`` (``derive_request_keys``) so
a request's sampled continuation is reproducible no matter which batch,
slot or arrival order served it — the property the continuous-batching
scheduler (``repro.serve.scheduler``: paged KV-cache pool, shared-prefix
reuse, burst prefill) is verified against.  The Engine is deliberately
the SIMPLE path: per-request `generate` here defines the reference
tokens for every scheduler feature (docs/serving.md).

DCIM-numerics execution of linear layers (the bridge to the paper's
compiler) lives in ``repro.sim.functional``; pass ``dcim_sim=`` to route
every projection through a generated macro's numerics.
"""
from __future__ import annotations

import contextlib
import dataclasses
from functools import partial
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import common, lm
from repro.models.config import LMConfig


def numerics_ctx(dcim_sim):
    """Context installing ``dcim_sim`` as the dense-matmul implementation
    for programs traced inside it (no-op when ``dcim_sim`` is None).
    Shared by Engine and Scheduler so the two serving paths can never
    diverge in how the DCIM hook is applied."""
    if dcim_sim is None:
        return contextlib.nullcontext()
    from repro.sim.functional import dcim_numerics

    return dcim_numerics(dcim_sim)


def check_capacity(prompt_len: int, n_tokens: int, max_len: int) -> None:
    """Admission control shared by Engine and Scheduler: a real error,
    not an assert — oversize requests must be rejected in optimized
    (-O) deployments too."""
    if prompt_len + n_tokens > max_len:
        raise ValueError(
            f"request exceeds engine capacity: prompt length {prompt_len} + "
            f"n_tokens {n_tokens} = {prompt_len + n_tokens} > max_len "
            f"{max_len}; shorten the prompt, request fewer "
            f"tokens, or build the Engine with a larger max_len"
        )


def check_unique_rids(request_ids) -> None:
    """Admission-contract sibling of :func:`check_capacity`, shared by
    the batch ``serve()`` path and per-request session submission:
    results are keyed — and PRNG streams derived — by rid, so two
    requests sharing an id would silently overwrite each other's output
    and sample from the same stream.  A real ``ValueError``, not an
    assert."""
    rids = list(request_ids)
    if len(set(rids)) != len(rids):
        dup = sorted({r for r in rids if rids.count(r) > 1})
        raise ValueError(f"duplicate request ids {dup}")


def check_queue_capacity(queued: int, incoming: int, max_queue) -> None:
    """Overload-shedding sibling of :func:`check_capacity`: a bounded
    submission queue rejects arrivals it cannot absorb instead of
    growing without limit under sustained overload.  ``max_queue=None``
    means unbounded (the default).  A real ``ValueError`` — the same
    shed-and-retry contract as the capacity checks — raised BEFORE any
    state changes, so a shed submission leaves the session untouched."""
    if max_queue is None:
        return
    if queued + incoming > max_queue:
        raise ValueError(
            f"queue overloaded: {queued} queued + {incoming} incoming > "
            f"max_queue {max_queue}; retry after the backlog drains or "
            f"build the Scheduler with a larger max_queue"
        )


@partial(jax.jit, static_argnums=(0,))
def _base_key(seed: int):
    # seed is a *static* arg: the key is baked into the compiled constant,
    # so deriving it involves no host->device transfer at call time — the
    # transfers lint runs the scheduler submit path under
    # jax.transfer_guard("disallow").  One compile per distinct seed.
    return jax.random.PRNGKey(seed)


def derive_request_keys(seed: int, request_ids) -> jnp.ndarray:
    """Per-request PRNG base keys: ``fold_in(PRNGKey(seed), rid)``.

    Keys depend only on (seed, request id) — never on batch composition,
    slot assignment or arrival order — so sampled generations reproduce
    across serving paths.  Returns a (B, 2) uint32 key batch."""
    base = _base_key(int(seed))
    rids = jax.device_put(np.asarray(request_ids, np.int32))
    return jax.vmap(lambda r: jax.random.fold_in(base, r))(rids)


def sample_tokens(logits, keys, steps, temperature):
    """Sample one token per row: logits (B, V); keys (B, 2) per-request
    base keys; steps (B,) number of tokens already sampled for that
    request (the per-step fold); temperature scalar or (B,).

    temperature <= 0 rows take the argmax (greedy); positive rows sample
    categorically at ``fold_in(key, step)``.  Both branches are computed
    and selected with ``where`` so temperature stays *traced* — mixed
    greedy/sampled slot pools run in one compiled program."""
    with common.precision_island("logits"):
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        t = jnp.broadcast_to(
            jnp.asarray(temperature, jnp.float32), greedy.shape
        )

        def one(key, step, row, tt):
            k = jax.random.fold_in(key, step)
            return jax.random.categorical(
                k, row.astype(jnp.float32) / jnp.maximum(tt, 1e-6)
            ).astype(jnp.int32)

        sampled = jax.vmap(one)(
            keys, jnp.asarray(steps, jnp.int32), logits, t
        )
        return jnp.where(t > 0.0, sampled, greedy)


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray           # (B, prompt + generated)
    prompt_len: int
    steps: int


class Engine:
    def __init__(self, cfg: LMConfig, params, max_len: int = 512,
                 dcim_sim=None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.dcim_sim = dcim_sim
        self._decode = jax.jit(
            partial(lm.decode_step, cfg=cfg), static_argnames=()
        )
        self._prefill = jax.jit(
            lambda p, b: lm.prefill(p, b, cfg, max_len=max_len)
        )

    def _numerics(self):
        return numerics_ctx(self.dcim_sim)

    def generate(
        self,
        prompts: np.ndarray,            # (B, P) int32, equal lengths
        n_tokens: int = 32,
        temperature: float = 0.0,
        seed: int = 0,
        request_ids=None,               # (B,) ids for PRNG derivation
    ) -> GenerationResult:
        B, P = prompts.shape
        check_capacity(P, n_tokens, self.max_len)
        rids = np.arange(B) if request_ids is None else np.asarray(request_ids)
        keys = derive_request_keys(seed, rids)
        with self._numerics():
            caches, logits = self._prefill(
                self.params, {"tokens": jnp.asarray(prompts)}
            )
            out = [jnp.asarray(prompts)]
            cur = sample_tokens(
                logits[:, -1], keys, np.zeros(B, np.int32), temperature
            )
            if n_tokens > 0:
                out.append(cur[:, None])
            # Token t is sampled from the decode at position P + t - 1;
            # the last requested token needs no further decode.
            for t in range(n_tokens - 1):
                logits, caches = self._decode(
                    self.params, {"tokens": cur[:, None]}, P + t, caches
                )
                cur = sample_tokens(
                    logits[:, -1], keys, np.full(B, t + 1, np.int32),
                    temperature,
                )
                out.append(cur[:, None])
        tokens = np.asarray(jnp.concatenate(out, axis=1))
        return GenerationResult(tokens=tokens, prompt_len=P, steps=n_tokens)


def bucket_requests(prompt_lists: List[List[int]]):
    """Group variable-length prompts into equal-length batches."""
    buckets = {}
    for i, p in enumerate(prompt_lists):
        buckets.setdefault(len(p), []).append((i, p))
    out = []
    for plen, items in sorted(buckets.items()):
        idx = [i for i, _ in items]
        arr = np.asarray([p for _, p in items], np.int32)
        out.append((idx, arr))
    return out
