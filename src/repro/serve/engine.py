"""Batched serving engine: prefill + decode with KV/SSM caches.

Requests are grouped into equal-prompt-length batches (length bucketing);
generation is greedy or temperature sampling.  DCIM-numerics execution of
linear layers (the bridge to the paper's compiler) lives in
``repro.sim.functional`` and is validated against this engine's float
path in tests/test_dcim_sim.py.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import LMConfig


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray           # (B, prompt + generated)
    prompt_len: int
    steps: int


class Engine:
    def __init__(self, cfg: LMConfig, params, max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._decode = jax.jit(
            partial(lm.decode_step, cfg=cfg), static_argnames=()
        )
        self._prefill = jax.jit(
            lambda p, b: lm.prefill(p, b, cfg, max_len=max_len)
        )

    def generate(
        self,
        prompts: np.ndarray,            # (B, P) int32, equal lengths
        n_tokens: int = 32,
        temperature: float = 0.0,
        seed: int = 0,
    ) -> GenerationResult:
        B, P = prompts.shape
        if P + n_tokens > self.max_len:
            # A real error, not an assert: oversize requests must be
            # rejected in optimized (-O) deployments too.
            raise ValueError(
                f"request exceeds engine capacity: prompt length {P} + "
                f"n_tokens {n_tokens} = {P + n_tokens} > max_len "
                f"{self.max_len}; shorten the prompt, request fewer "
                f"tokens, or build the Engine with a larger max_len"
            )
        caches, logits = self._prefill(self.params, {"tokens": jnp.asarray(prompts)})
        key = jax.random.PRNGKey(seed)
        out = [jnp.asarray(prompts)]
        cur = self._sample(logits[:, -1], key, temperature)
        for t in range(n_tokens):
            out.append(cur[:, None])
            logits, caches = self._decode(
                self.params, {"tokens": cur[:, None]}, P + t, caches
            )
            key, sub = jax.random.split(key)
            cur = self._sample(logits[:, -1], sub, temperature)
        tokens = np.asarray(jnp.concatenate(out, axis=1))
        return GenerationResult(tokens=tokens, prompt_len=P, steps=n_tokens)

    @staticmethod
    def _sample(logits, key, temperature):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


def bucket_requests(prompt_lists: List[List[int]]):
    """Group variable-length prompts into equal-length batches."""
    buckets = {}
    for i, p in enumerate(prompt_lists):
        buckets.setdefault(len(p), []).append((i, p))
    out = []
    for plen, items in sorted(buckets.items()):
        idx = [i for i, _ in items]
        arr = np.asarray([p for _, p in items], np.int32)
        out.append((idx, arr))
    return out
