"""Continuous-batching serving: paged KV-cache pool, persistent
sessions, streaming delivery, concurrent multi-tenant front-end.

The bucketed ``Engine`` holds every request of an equal-length batch
until the WHOLE batch finishes — one long generation stalls the bucket
and throughput collapses under mixed-length traffic.  The ``Scheduler``
instead owns a fixed pool of ``max_slots`` decode slots and runs ONE
jitted decode program per step over all slots.

Since the paged-pool PR the cache is no longer a monolithic per-slot
region but a **paged pool** (vLLM-style): attention K/V lives in shared
fixed-size pages (``lm.init_paged_pool``), each slot holds a block
table of page ids, and the decode program reads/writes THROUGH the
block table (``lm.decode_step_paged``).  SSM state stays per-slot —
it is O(1) in sequence length, so there is nothing to page.  On top of
paging:

  * **shared-prefix reuse** — prompts are hashed at page granularity
    with a rolling chain (``serve.paging.PagePool``); a new request
    whose prefix pages are resident refcounts them and prefills only
    its tail, attending to the reused pages as context
    (``lm.prefill_paged``).  Retired requests' prefix pages stay cached
    (refcount 0, still indexed) until allocation pressure evicts them,
    so reuse works across sequential requests, not just concurrent
    ones.  Reuse auto-disables when it cannot be token-exact: configs
    with SSM layers (recurrent state is not per-position shareable) or
    a lossy ``cache_dtype`` (reused pages would round the context the
    reference prefill saw at compute precision).
  * **batched burst prefill** — all requests admitted at one step
    prefill together in one padded ``(B, bucket)`` program instead of
    one at a time; programs are keyed by (prompt-tail bucket,
    power-of-two batch width), keeping the compile budget bounded.

All serve-loop *state* lives in a long-lived :class:`ServeSession`: the
device cache pool, the ``PagePool`` prefix index, the slot allocator
and the per-slot host arrays are built ONCE and survive across an
arbitrary sequence of ``submit()`` / ``step()`` / ``serve()`` calls.
A system-prompt prefix filled by one trace is therefore a *hit* in the
next (``PageStats.cross_trace_hits``) instead of the cold miss the old
per-``serve()`` pool rebuild forced.  ``submit()`` returns a
:class:`StreamHandle` whose tokens are observable as they are produced
(``on_token`` per-step callback, iterator-style ``stream()`` drain);
``Scheduler.serve()`` is a thin batch wrapper over the scheduler's
persistent default session.

**Multi-tenant front-end** (this layer is what makes the session safe
under real concurrent traffic — docs/serving.md "Multi-tenant
serving"):

  * **thread safety** — every session entry point takes one re-entrant
    lock (a ``threading.Condition``); producers on any number of
    threads may ``submit()``/``stream()``/``wait()`` concurrently.
  * **single pump** — ``start()`` launches ONE background pump thread
    that owns ``step()``; while it runs, ``step()`` from any other
    thread raises (double-stepping a tick from two threads was the
    historical ``stream()`` race) and blocking observers wait on the
    condition instead of pumping.  Without a driver the session stays
    cooperatively pumped exactly as before, now under the lock.
  * **priority / fairness** — ``Request.priority`` (weight >= 1,
    higher = more slot share) selects admissions by stride scheduling:
    each class accumulates virtual time ``1/priority`` per admission
    and the eligible class with the least virtual time admits next
    (FIFO within a class; a lone class reduces to plain FIFO).
  * **admission control / shedding** — ``max_queue`` bounds the
    pending queue; an overloaded ``submit()``/``serve()`` raises the
    shared ``ValueError`` contract (``engine.check_queue_capacity``)
    and the session stays untouched, so callers can retry/back off.
  * **preemption** — under slot/page pressure a strictly
    higher-priority arrival evicts the lowest-priority occupant: its
    pages are released (registered prefix pages stay CACHED in the
    ``PagePool`` chain index) and the victim re-queues; on re-admission
    it re-prefills ``prompt + generated[:-1]`` — hitting its own
    still-cached pages — and resumes decoding at the exact position it
    left, so its token stream is unchanged.
  * **chunked prefill** — with ``prefill_chunk=C`` a long prompt tail
    fills C tokens per scheduler tick (one batched program over all
    chunking slots) instead of monopolizing a tick with one huge
    prefill, so co-tenant decode steps interleave with the fill.

Both paging features are ``Scheduler`` options that default ON;
``paged=False`` reproduces the pre-paging monolithic per-slot behavior
exactly (that path still runs ``lm.prefill`` + ``lm.insert_cache_slot``,
through the same persistent session machinery).  Preemption and chunked
prefill share prefix reuse's exactness gate (attention-only cache at
compute precision): re-prefilled K/V is bitwise what decode wrote, for
the same reason reused prefix pages are — masked lanes are arithmetic
zeros under XLA's order-preserving reductions.

Scheduling never changes numerics: for greedy decoding the served
tokens are *token-exact* against ``Engine.generate`` run per request
(tests/test_serve_scheduler.py, tests/test_serve_session.py,
tests/test_serve_concurrent.py), with paging, prefix reuse, burst
prefill, session persistence, priorities, preemption and chunked
prefill all enabled — regardless of tenant interleaving.  Admission
control raises the shared ``ValueError`` capacity contract
(``serve.check_capacity`` + per-pool ``paging.check_page_capacity`` +
``serve.check_unique_rids`` + ``serve.check_queue_capacity``).  See
docs/serving.md for the full design.
"""
from __future__ import annotations

import bisect
import contextlib
import dataclasses
import threading
import time
from collections import deque
from functools import partial
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Set, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.registry import Built, Replay, register_contract
from repro.dist import sharding as shd
from repro.models import lm
from repro.models.config import LMConfig

from .engine import (
    check_capacity,
    check_queue_capacity,
    check_unique_rids,
    derive_request_keys,
    numerics_ctx,
    sample_tokens,
)
from .paging import PagePool, check_page_capacity, pages_needed


@dataclasses.dataclass
class Request:
    """One generation request for the continuous-batching scheduler."""
    prompt: np.ndarray                 # (P,) int32 token ids
    n_tokens: int = 32
    temperature: float = 0.0
    rid: Optional[int] = None          # defaults to submission index
    arrival: int = 0                   # earliest scheduler step it may join
                                       # (relative to the current trace)
    priority: int = 1                  # fairness weight (>= 1, higher = more
                                       # slot share; may preempt lower classes)
    tenant: str = "default"            # reporting label, carried to the result

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: np.ndarray                 # (P + generated,) prompt included
    prompt_len: int
    arrival: int
    admitted_step: int                 # FIRST admission (preemptions keep it)
    finished_step: int
    finished_wall_s: float             # seconds since the trace started
    prefix_hit_tokens: int = 0         # prompt tokens served from cached pages
    priority: int = 1
    tenant: str = "default"
    preemptions: int = 0               # times this request was evicted+resumed

    @property
    def generated(self) -> np.ndarray:
        return self.tokens[self.prompt_len:]


@dataclasses.dataclass
class ServeStats:
    steps: int                         # scheduler ticks, idle ones included
    decode_steps: int
    prefills: int                      # requests prefilled
    max_slots: int
    generated_tokens: int
    wall_s: float
    occupancy: float                   # mean fraction of slots active per decode step
    prefill_batches: int = 0           # prefill programs launched (== prefills
                                       # without burst batching)
    prefix_reuse_active: bool = False
    paging: Optional[dict] = None      # per-trace PageStats delta in paged mode
                                       # (cross_trace_* fields count hits on
                                       # pages filled by EARLIER traces)
    trace_index: int = 0               # which trace of the session this was
    pool_bytes: int = 0                # device cache-pool footprint (persists
                                       # across traces)
    preemptions: int = 0               # occupants evicted for a higher class
    prefill_chunks: int = 0            # chunked-prefill rows advanced
    shed: int = 0                      # submissions rejected by max_queue


class SlotAllocator:
    """Fixed pool of decode slot ids with LIFO reuse.

    LIFO keeps a just-retired slot's cache region hot: it is overwritten
    by the very next admission instead of cycling through the pool."""

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"need at least one slot, got {n_slots}")
        self.n_slots = n_slots
        self._free: List[int] = list(reversed(range(n_slots)))
        self._busy: set = set()

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def busy(self) -> frozenset:
        return frozenset(self._busy)

    def acquire(self) -> int:
        if not self._free:
            raise RuntimeError("no free slot")
        slot = self._free.pop()
        self._busy.add(slot)
        return slot

    def release(self, slot: int) -> None:
        if slot not in self._busy:
            raise ValueError(f"slot {slot} is not in use")
        self._busy.discard(slot)
        self._free.append(slot)


def default_prefill_buckets(max_len: int) -> List[int]:
    """Powers of two up to max_len (max_len always included): a bounded
    set of compiled prefill shapes serves every admissible prompt."""
    buckets = []
    b = 2
    while b < max_len:
        buckets.append(b)
        b *= 2
    buckets.append(max_len)
    return buckets


def _prefill_fn(params, pool, tokens, valid_len, slot, key, temp, *,
                cfg: LMConfig, max_len: int):
    """Legacy (paged=False) prefill, jitted once per prompt bucket:
    prefill one request (right-padded to the bucket), overwrite slot
    ``slot`` of the monolithic pool with its cache, sample its first
    token at per-request step 0."""
    caches, logits = lm.prefill(
        params, {"tokens": tokens}, cfg, max_len=max_len, valid_len=valid_len
    )
    pool = lm.insert_cache_slot(pool, caches, slot)
    tok0 = sample_tokens(
        logits[:, -1], key[None], jnp.zeros((1,), jnp.int32), temp
    )[0]
    return shd.constrain_pool(pool), tok0


def _decode_fn(params, pool, cur, pos, active, keys, steps, temps, *,
               cfg: LMConfig):
    """Legacy (paged=False) decode, jitted exactly once: one step over
    ALL slots.  ``pos`` is the per-slot length vector; inactive slots are
    clamped to position 0 so their (discarded) writes stay in bounds, and
    their sampled token is masked to -1 so host code can never mistake it
    for output."""
    pos_eff = jnp.where(active, pos, 0)
    logits, pool = lm.decode_step(
        params, {"tokens": cur[:, None]}, pos_eff, pool, cfg
    )
    nxt = sample_tokens(logits[:, -1], keys, steps, temps)
    # Pin the returned (donated) pool's layout to the committed input
    # layout, so sharded serving never recompiles on pool rebinding.
    return shd.constrain_pool(pool), jnp.where(active, nxt, -1)


def _decode_paged_fn(params, pool, cur, pos, active, block_tables, keys,
                     steps, temps, *, cfg: LMConfig):
    """Jitted exactly once: one decode step over ALL slots, reading the
    paged pool through the block tables.  Inactive slots clamp to
    position 0 AND carry an all-garbage block table row, so their
    discarded writes land in the reserved garbage page — never in a
    page another request owns."""
    pos_eff = jnp.where(active, pos, 0)
    logits, pool = lm.decode_step_paged(
        params, {"tokens": cur[:, None]}, pos_eff, pool, block_tables, cfg
    )
    nxt = sample_tokens(logits[:, -1], keys, steps, temps)
    return shd.constrain_pool(pool), jnp.where(active, nxt, -1)


def _burst_prefill_fn(params, pool, tokens, block_tables, slots, ctx_len,
                      tail_valid, keys, temps, *, cfg: LMConfig,
                      page_size: int, use_context: bool):
    """Jitted once per (tail bucket, burst width): prefill a whole
    admission burst (or one chunked-prefill advance over all chunking
    slots) into the paged pool and sample each member's first token at
    per-request step 0.  Padding rows carry tail_valid == 0, the
    garbage slot and an all-garbage block table; their sampled token is
    junk the host ignores (as is every non-final chunk row's).
    ``use_context`` is False when neither prefix reuse nor chunked
    prefill can produce a nonzero ctx_len — the compiled program then
    skips the context gather entirely."""
    pool, logits = lm.prefill_paged(
        params, {"tokens": tokens}, cfg, pool, block_tables, slots,
        ctx_len, tail_valid, page_size, use_context,
    )
    toks = sample_tokens(
        logits[:, -1], keys, jnp.zeros((tokens.shape[0],), jnp.int32), temps
    )
    return shd.constrain_pool(pool), toks


class StreamHandle:
    """Observable handle for one submitted request.

    Tokens land on the handle as the session produces them — the first
    token at admission (sampled by the prefill program), one more per
    decode step until retirement (EOS or ``n_tokens``).  Three ways to
    observe them:

      * ``on_token(handle, token)`` — called synchronously for every
        produced token, from inside :meth:`ServeSession.step`, after
        that step's slot bookkeeping has completed (so a raising
        callback interrupts the caller but never corrupts the session;
        callbacks it pre-empted fire on the next ``step()``).  With a
        background driver, delivery is pinned to the pump thread —
        never to whichever thread happens to observe.
      * ``stream()`` — an iterator yielding tokens as they are
        produced.  On a driven session it *blocks* on delivered tokens
        (the single-pump invariant: it never steps a session a driver
        owns); on an undriven session it pumps ``step()`` cooperatively
        under the session lock, as it always did.
      * ``wait(timeout=None)`` — block until the request retires and
        return its :class:`RequestResult`.

    ``result`` is the final :class:`RequestResult` (``None`` until the
    request retires); ``generated`` is the tokens produced *so far*."""

    def __init__(self, session: "ServeSession", request: Request,
                 key: np.ndarray,
                 on_token: Optional[Callable[["StreamHandle", int], None]] = None):
        self.session = session
        self.request = request
        self.rid = request.rid
        self.key = np.asarray(key)
        self.on_token = on_token
        self.result: Optional[RequestResult] = None
        self._tokens: List[int] = []
        self._seq = -1                   # session submission order
        self._admitted: Optional[int] = None   # first admission step
        self._hit_tokens0 = 0            # prefix hits at first admission
        self._preempt_count = 0
        self._blocked_at: Optional[int] = None  # step first resource-blocked

    @property
    def done(self) -> bool:
        return self.result is not None

    @property
    def n_generated(self) -> int:
        return len(self._tokens)

    @property
    def generated(self) -> np.ndarray:
        with self.session._cv:
            return np.asarray(self._tokens, np.int32)

    def wait(self, timeout: Optional[float] = None) -> RequestResult:
        """Block until this request retires; returns its result.  On a
        driven session this waits on the pump; otherwise it pumps the
        session cooperatively.  Raises ``TimeoutError`` if ``timeout``
        (seconds) elapses first, and re-raises a pump failure."""
        sess = self.session
        deadline = None if timeout is None else time.monotonic() + timeout
        with sess._cv:
            while not self.done:
                sess._raise_pump_error()
                if sess._driven_elsewhere():
                    if not sess._cv_wait(deadline):
                        raise TimeoutError(
                            f"request {self.rid} not done after {timeout}s"
                        )
                else:
                    sess._step_locked()
            return self.result

    def stream(self) -> Iterator[int]:
        """Yield this request's generated tokens in order.  Never holds
        the session lock across a ``yield`` — consumers may block
        arbitrarily.  With a background driver this blocks on delivered
        tokens; without one it drives ``step()`` itself (other
        concurrently-submitted requests make progress too — their
        handles fill while this one streams)."""
        i = 0
        sess = self.session
        while True:
            with sess._cv:
                while not self._tokens[i:] and not self.done:
                    sess._raise_pump_error()
                    if sess._driven_elsewhere():
                        sess._cv.wait()
                    else:
                        sess._step_locked()
                batch = self._tokens[i:]
                finished = self.done
            if not batch and finished:
                return
            for tok in batch:
                yield tok
            i += len(batch)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        state = "done" if self.done else "live"
        return f"StreamHandle(rid={self.rid}, {state}, {self.n_generated} tokens)"


class ServeSession:
    """Long-lived serving state over one :class:`Scheduler`'s compiled
    programs.

    The device cache pool (paged or monolithic), the ``PagePool`` host
    index, the slot allocator and the per-slot host arrays are built
    once, here, and survive across traces: a *trace* is one busy period
    — it begins when a request is submitted to an idle session and ends
    when the last live request retires.  Step numbers (``arrival``,
    ``admitted_step``, ``finished_step``) are relative to the current
    trace, so back-to-back ``serve()`` calls see the same schedule they
    always did — but prefix pages cached by an earlier trace are HITS
    (``ServeStats.paging["cross_trace_hits"]``), not cold misses, and
    no device allocation or jit compile happens between traces.

    ``submit()`` enqueues one request and returns its
    :class:`StreamHandle`; ``step()`` runs one scheduler tick
    (admissions, chunked-prefill advance, then one decode step over all
    slots); ``drain()`` steps (or, driven, waits) until idle;
    ``serve()`` is submit-all + drain with batch-level validation,
    returning results in submission order.

    **Threading model** (docs/architecture.md): ONE re-entrant lock —
    the condition ``_cv`` — guards all session state; every public
    method takes it, so any number of producer threads may submit,
    stream and wait concurrently.  ``start()`` spawns the single
    background pump thread that then exclusively owns ``step()`` (the
    single-pump invariant); ``stop()`` joins it.  ``on_token``
    callbacks run on whichever thread executes the step — the pump
    thread, when driven — while holding the session lock, so a callback
    may re-enter ``submit()`` directly; a callback must NOT block
    waiting for another thread's session call (that thread needs this
    lock), and threads a callback signals may safely call ``submit()``
    — they simply serialize behind the running step."""

    def __init__(self, sched: "Scheduler"):
        self.s = sched
        S = sched.max_slots
        if sched.paged:
            self.pool = lm.init_paged_pool(
                sched.cfg, S, sched.n_pages, sched.page_size,
                mesh=sched.mesh,
            )
            self.ppool: Optional[PagePool] = PagePool(
                sched.n_pages, sched.page_size
            )
            self.btables = np.zeros((S, sched.pages_per_slot), np.int32)
        else:
            self.pool = lm.init_cache(sched.cfg, S, sched.max_len)
            self.ppool = None
            self.btables = None
        self.pool_bytes = lm.pool_nbytes(self.pool)
        self.alloc = SlotAllocator(S)
        self.pos = np.zeros(S, np.int32)
        self.active = np.zeros(S, bool)
        self.cur = np.zeros(S, np.int32)
        self.keys = np.zeros((S, 2), np.uint32)
        self.steps = np.zeros(S, np.int32)     # tokens sampled per occupant
        self.temps = np.zeros(S, np.float32)
        self.occupant: List[Optional[dict]] = [None] * S

        # Pending admissions, sorted by (arrival, submission seq) — FIFO
        # within an arrival step.  A plain list: fairness selection and
        # preemption re-queueing remove/insert at arbitrary positions.
        self.queue: List[StreamHandle] = []
        self._seq = 0                          # submission order counter
        # Stride-scheduling state: virtual time per priority class and
        # the floor newly-active classes start from (so a newcomer class
        # neither monopolizes nor starves).
        self._vt: Dict[int, float] = {}
        self._vt_floor = 0.0
        # Slots whose prompt is still being chunk-prefilled (inactive
        # for decode, but busy in the allocator).
        self._chunk_slots: Set[int] = set()
        # Tokens recorded but whose on_token callbacks have not fired
        # yet: callbacks run AFTER a step's slot bookkeeping completes,
        # so a raising callback can never leave the session half-updated
        # (undelivered callbacks fire on the next step()/drain()).
        self._events: "deque[Tuple[StreamHandle, int]]" = deque()
        self._live_rids: Set[int] = set()
        self._next_rid = 0                     # submit() auto-id counter
        self.trace_index = -1                  # bumped at each trace start
        self._in_trace = False
        self.last_stats: Optional[ServeStats] = None

        # Concurrency: one condition (re-entrant lock) guards ALL of the
        # state above; the optional background pump is the only thread
        # allowed to step while it runs.
        # Session-lifetime totals (never reset by trace boundaries; the
        # per-trace values land on ServeStats).  A multi-trace driver —
        # e.g. a bursty producer pool that lets the session idle
        # mid-burst — reads deltas of these instead of stitching
        # last_stats together.
        self.total_preemptions = 0
        self.total_prefill_chunks = 0
        self.total_shed = 0
        self._cv = threading.Condition(threading.RLock())
        self._driver: Optional[threading.Thread] = None
        self._driver_ident: Optional[int] = None
        self._stop_flag = False
        self._pump_error: Optional[BaseException] = None
        self._in_step = False
        self._reset_trace_counters()

    # --------------------------- trace lifecycle -----------------------------
    def _reset_trace_counters(self) -> None:
        self.step_idx = 0
        self.decode_steps = 0
        self.prefills = 0
        self.prefill_batches = 0
        self.active_slot_steps = 0
        self.gen_tokens = 0
        self.preemptions = 0
        self.prefill_chunks = 0
        self.shed = 0
        self._t0 = time.perf_counter()
        self._pg0 = self.ppool.stats.snapshot() if self.ppool else None

    def _ensure_trace(self) -> None:
        if self._in_trace:
            return
        self.trace_index += 1
        self._in_trace = True
        if self.ppool is not None:
            self.ppool.begin_trace()
        self._reset_trace_counters()

    def _finalize_trace(self) -> None:
        self._in_trace = False
        stats = ServeStats(
            steps=self.step_idx,
            decode_steps=self.decode_steps,
            prefills=self.prefills,
            max_slots=self.s.max_slots,
            generated_tokens=self.gen_tokens,
            wall_s=time.perf_counter() - self._t0,
            occupancy=(
                self.active_slot_steps / (self.decode_steps * self.s.max_slots)
                if self.decode_steps else 0.0
            ),
            prefill_batches=self.prefill_batches,
            prefix_reuse_active=self.s.prefix_reuse_active,
            paging=(
                self.ppool.stats.delta(self._pg0)
                if self.ppool is not None else None
            ),
            trace_index=self.trace_index,
            pool_bytes=self.pool_bytes,
            preemptions=self.preemptions,
            prefill_chunks=self.prefill_chunks,
            shed=self.shed,
        )
        self.last_stats = stats
        self.s.last_stats = stats

    @property
    def idle(self) -> bool:
        """No queued, no decoding and no chunk-prefilling requests."""
        return (not self.queue and not self.active.any()
                and not self._chunk_slots)

    # ------------------------------- driver ----------------------------------
    def _driven_elsewhere(self) -> bool:
        """A background pump owns stepping and this is not its thread."""
        return (self._driver is not None
                and threading.get_ident() != self._driver_ident)

    def _raise_pump_error(self) -> None:
        """Re-raise (once) an exception that killed the pump — typically
        a raising ``on_token`` callback.  Mirrors cooperative semantics:
        the raise interrupts one observer; the session itself stays
        consistent and resumable."""
        err, self._pump_error = self._pump_error, None
        if err is not None:
            raise err

    def _cv_wait(self, deadline: Optional[float]) -> bool:
        """Wait on the condition until notified; False on deadline."""
        if deadline is None:
            self._cv.wait()
            return True
        remaining = deadline - time.monotonic()
        if remaining <= 0:
            return False
        self._cv.wait(remaining)
        return True

    def start(self) -> "ServeSession":
        """Launch the background pump thread.  While it runs it is the
        ONLY thread allowed to call ``step()`` — producers submit and
        block on handles/``wait_idle()`` instead.  Idempotent errors:
        raises if a driver is already attached."""
        with self._cv:
            if self._driver is not None:
                raise RuntimeError("session already has a background driver")
            self._pump_error = None
            self._stop_flag = False
            t = threading.Thread(
                target=self._pump, name="serve-session-pump", daemon=True
            )
            self._driver = t
            self._driver_ident = None    # set by the pump itself, under _cv
            t.start()
        return self

    def stop(self) -> None:
        """Stop and join the background pump.  Re-raises (once) an error
        that killed the pump, so a raising callback is never silently
        swallowed by a ``driving()`` exit."""
        with self._cv:
            t = self._driver
            self._stop_flag = True
            self._cv.notify_all()
        if t is not None:
            t.join()
        with self._cv:
            self._driver = None
            self._driver_ident = None
            self._raise_pump_error()

    @contextlib.contextmanager
    def driving(self):
        """``with session.driving():`` — pump in the background for the
        block's duration (``start()``/``stop()`` bracket)."""
        self.start()
        try:
            yield self
        finally:
            self.stop()

    def _pump(self) -> None:
        with self._cv:
            self._driver_ident = threading.get_ident()
            self._cv.notify_all()
            while True:
                while not self._stop_flag and self.idle:
                    self._cv.wait()
                if self._stop_flag:
                    return
                try:
                    self._step_locked()
                except BaseException as e:    # stash for observers, die
                    self._pump_error = e
                    self._driver = None
                    self._driver_ident = None
                    self._cv.notify_all()
                    return

    def wait_idle(self, timeout: Optional[float] = None) -> None:
        """Block until every queued and live request has retired.  On a
        driven session waits on the pump; otherwise pumps cooperatively
        (== ``drain()``).  Raises ``TimeoutError`` on expiry."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while True:
                self._raise_pump_error()
                if self._driven_elsewhere():
                    if self.idle and not self._events:
                        return
                    if not self._cv_wait(deadline):
                        raise TimeoutError(f"session not idle after {timeout}s")
                    continue
                while not self.idle:
                    self._step_locked()
                self._emit_events()
                return

    # --------------------------- token delivery ------------------------------
    def _record_token(self, handle: StreamHandle, tok: int) -> None:
        """Record a produced token on its handle; the on_token callback
        is deferred to the end of the current step so user code runs
        only against consistent session state."""
        handle._tokens.append(int(tok))
        self.gen_tokens += 1
        if handle.on_token is not None:
            self._events.append((handle, int(tok)))

    def _emit_events(self) -> None:
        """Deliver deferred on_token callbacks.  Only ever called from
        the stepping thread — the pump, when a driver is attached — so
        callback delivery is pinned to one thread regardless of how many
        observers are blocked on the session."""
        while self._events:
            handle, tok = self._events.popleft()
            handle.on_token(handle, tok)

    # ----------------------------- submission --------------------------------
    def _validate(self, req: Request) -> None:
        if req.n_tokens < 1:
            raise ValueError(f"request {req.rid}: n_tokens must be >= 1")
        if req.prompt.size < 1:
            raise ValueError(f"request {req.rid}: empty prompt")
        if req.priority < 1:
            raise ValueError(
                f"request {req.rid}: priority must be >= 1, got {req.priority}"
            )
        check_capacity(req.prompt.size, req.n_tokens, self.s.max_len)
        if self.s.paged:
            check_page_capacity(
                req.prompt.size, req.n_tokens, self.s.page_size,
                self.s.n_pages - 1,
            )
        if req.rid in self._live_rids:
            # Results are keyed (and PRNG streams derived) by rid: a
            # collision with a LIVE request would overwrite its output
            # and share its sampling stream.
            raise ValueError(
                f"duplicate request id {req.rid}: a request with this id "
                f"is still queued or decoding in this session"
            )

    def _check_queue_room(self, incoming: int) -> None:
        """Overload shedding: reject (ValueError) submissions that would
        overflow ``max_queue``.  Counted per trace in ``ServeStats.shed``;
        preemption re-queues are exempt (they were already admitted)."""
        try:
            check_queue_capacity(len(self.queue), incoming, self.s.max_queue)
        except ValueError:
            self.shed += incoming
            self.total_shed += incoming
            raise

    def _auto_rid(self) -> int:
        while self._next_rid in self._live_rids:
            self._next_rid += 1
        rid = self._next_rid
        self._next_rid += 1
        return rid

    @staticmethod
    def _qkey(handle: StreamHandle) -> Tuple[int, int]:
        return (handle.request.arrival, handle._seq)

    def _insert_sorted(self, handle: StreamHandle) -> None:
        keys = [self._qkey(h) for h in self.queue]
        self.queue.insert(bisect.bisect_right(keys, self._qkey(handle)), handle)

    def _enqueue(self, req: Request, seed: Optional[int],
                 on_token=None, sorted_insert: bool = True) -> StreamHandle:
        """Post-validation enqueue shared by ``submit`` and ``serve``."""
        seed = self.s.seed if seed is None else seed
        # np.asarray BEFORE the [0]: indexing the device array with a
        # Python int would transfer the index constant implicitly (the
        # transfers lint runs submit/step under a disallow guard).
        key = np.asarray(derive_request_keys(seed, [req.rid]))[0]
        self._ensure_trace()
        handle = StreamHandle(self, req, key, on_token=on_token)
        handle._seq = self._seq
        self._seq += 1
        self._live_rids.add(req.rid)
        if sorted_insert:
            self._insert_sorted(handle)
        else:
            self.queue.append(handle)   # caller re-sorts the batch once
        return handle

    def _requeue(self, handle: StreamHandle) -> None:
        """Re-queue a preempted (already admitted) request; bypasses
        validation and shedding — its rid stays live, its delivered
        tokens stay delivered."""
        self._insert_sorted(handle)

    def submit(
        self,
        request: Union[Request, np.ndarray, list],
        seed: Optional[int] = None,
        on_token: Optional[Callable[[StreamHandle, int], None]] = None,
    ) -> StreamHandle:
        """Enqueue one request (validated now — the shared ``ValueError``
        capacity/rid/queue contracts — but admitted by a later
        ``step()``).  Thread-safe: any producer thread may call this,
        including an ``on_token`` callback (it already holds the session
        lock).  Safe to call mid-trace: the request joins the current
        trace with ``arrival`` relative to its step counter.  A failed
        validation leaves the session untouched and reusable."""
        with self._cv:
            req = request if isinstance(request, Request) else Request(prompt=request)
            if req.rid is None:
                req = dataclasses.replace(req, rid=self._auto_rid())
            self._validate(req)
            self._check_queue_room(1)
            handle = self._enqueue(req, seed, on_token=on_token)
            self._cv.notify_all()       # wake the pump / blocked observers
            return handle

    def serve(
        self,
        requests: Sequence[Union[Request, np.ndarray, list]],
        seed: Optional[int] = None,
    ) -> List[RequestResult]:
        """Submit a whole arrival trace and drain it to completion;
        results come back in submission order and the trace's
        ``ServeStats`` lands on ``last_stats`` (and on the scheduler).
        The WHOLE batch is validated before any request is enqueued, so
        a rejected trace leaves the session state untouched.  On a
        driven session this blocks until the batch's handles are done
        (the pump does the stepping).  Default rids count up from 0
        (the historical submission-index ids) but skip ids still live
        in the session, so serving a batch alongside in-flight
        ``submit()`` handles cannot spuriously collide."""
        with self._cv:
            reqs: List[Request] = []
            taken = set(self._live_rids)
            for i, r in enumerate(requests):
                if not isinstance(r, Request):
                    r = Request(prompt=r)
                if r.rid is None:
                    rid = i                 # historical submission-index default
                    while rid in taken:     # ...unless a live/assigned id holds it
                        rid += 1
                    r = dataclasses.replace(r, rid=rid)
                    taken.add(rid)
                reqs.append(r)
            check_unique_rids([r.rid for r in reqs])
            for r in reqs:
                self._validate(r)
            if not reqs:
                # On an idle session an empty serve() still lands fresh
                # stats: an empty trace begins and finalizes immediately
                # (all-zero counters) instead of leaving a previous trace's
                # numbers up.  Mid-trace (live submit() handles) it must NOT
                # finalize — that would publish partial stats and reset the
                # running trace's counters under its in-flight requests.
                if self.idle:
                    self._ensure_trace()
                    self._finalize_trace()
                return []
            self._check_queue_room(len(reqs))
            handles = [self._enqueue(r, seed, sorted_insert=False) for r in reqs]
            # One stable sort for the whole batch: equal (arrival, seq)
            # cannot occur, so submission order is preserved exactly.
            self.queue.sort(key=self._qkey)
            self._cv.notify_all()
            if self._driven_elsewhere():
                while not all(h.done for h in handles):
                    self._raise_pump_error()
                    if not self._driven_elsewhere():
                        break           # driver stopped: finish cooperatively
                    self._cv.wait()
                if not all(h.done for h in handles):
                    self.drain()
            else:
                self.drain()
            return [h.result for h in handles]

    # ------------------------------ stepping ---------------------------------
    def drain(self) -> None:
        """Until the session is idle: step it (cooperative) or wait on
        the pump (driven), then flush any deferred on_token callbacks —
        so a drain() after a raising callback always delivers what the
        raise pre-empted, even when the session is already idle."""
        with self._cv:
            while True:
                self._raise_pump_error()
                if self._driven_elsewhere():
                    if self.idle and not self._events:
                        return
                    self._cv.wait()
                    continue
                if self.idle:
                    self._emit_events()
                    return
                self._step_locked()

    def step(self) -> int:
        """One scheduler tick: admit every queued request that fits
        (fairness-ordered, preempting lower classes under pressure),
        advance chunked prefills, then run one decode step over the
        active slots.  Returns the number of tokens delivered to handles
        this tick (admission first-tokens included).  On an idle session
        this is a no-op returning 0.  While a background driver runs,
        only the pump thread may call this — any other thread gets a
        ``RuntimeError`` (the single-pump invariant)."""
        with self._cv:
            if self._driven_elsewhere():
                raise RuntimeError(
                    "a background pump owns this session (start() was "
                    "called): step() from another thread would double-pump "
                    "a tick; wait on handles / stream() instead, or stop() "
                    "the driver first"
                )
            return self._step_locked()

    def _step_locked(self) -> int:
        """Step body; caller holds ``_cv``."""
        try:
            if self.idle:
                self._emit_events()      # callbacks a raising peer pre-empted
                return 0
            before = self.gen_tokens
            self._in_step = True
            with self.s._numerics():
                if self.s.paged:
                    self._admit_all_paged()
                    self._advance_chunks()
                else:
                    self._admit_legacy()
                if not self.active.any():
                    if self._chunk_slots:
                        # Chunk-only tick: prefill progressed, nothing
                        # decodes yet.
                        self.step_idx += 1
                    elif (self.queue
                          and self.queue[0].request.arrival <= self.step_idx):
                        # An eligible request exists, nothing is running
                        # and nothing is chunk-filling: no live request
                        # holds pages, so available() must cover any
                        # admissible request (check_page_capacity passed
                        # at submission).  Transient waits — pages pinned
                        # by live/chunking occupants — never reach here.
                        raise RuntimeError(
                            "admission stalled with an idle pool — page "
                            "accounting bug: no live request holds pages, "
                            "yet an eligible request cannot be admitted"
                        )
                    elif self.queue:
                        # Nothing running: jump straight to the next arrival
                        # instead of ticking through the gap.
                        self.step_idx = max(
                            self.step_idx + 1, self.queue[0].request.arrival
                        )
                    else:
                        self._finalize_trace()
                    # Snapshot before callbacks run: a callback may submit()
                    # a follow-up request, beginning a new trace that resets
                    # the counters this return value is computed from.
                    produced = self.gen_tokens - before
                    self._emit_events()
                    return produced
                self._decode_once()
            if self.idle:
                self._finalize_trace()
            produced = self.gen_tokens - before
            self._emit_events()
            return produced
        finally:
            self._in_step = False
            self._cv.notify_all()

    def _decode_once(self) -> None:
        if self.s.paged:
            # Chunk-prefilling slots are inactive for decode but their
            # block tables hold REAL pages; mask them to all-garbage so
            # the inactive slots' clamped writes land in the garbage
            # page, not in a page mid-fill.
            bt = self.btables
            if self._chunk_slots:
                bt = np.where(self.active[:, None], bt, 0)
            self.pool, nxt = self.s._decode(
                self.s.params, self.pool, jnp.asarray(self.cur),
                jnp.asarray(self.pos), jnp.asarray(self.active),
                jnp.asarray(bt), jnp.asarray(self.keys),
                jnp.asarray(self.steps), jnp.asarray(self.temps),
            )
        else:
            self.pool, nxt = self.s._decode(
                self.s.params, self.pool, jnp.asarray(self.cur),
                jnp.asarray(self.pos), jnp.asarray(self.active),
                jnp.asarray(self.keys), jnp.asarray(self.steps),
                jnp.asarray(self.temps),
            )
        nxt = np.asarray(nxt)
        self.decode_steps += 1
        self.active_slot_steps += int(self.active.sum())
        self.step_idx += 1
        self.pos[self.active] += 1
        self.steps[self.active] += 1
        for slot in np.flatnonzero(self.active):
            tok = int(nxt[slot])
            st = self.occupant[slot]
            self._record_token(st["handle"], tok)
            st["remaining"] -= 1
            self.cur[slot] = tok
            if st["remaining"] == 0 or tok == self.s.eos_id:
                self._finish(slot)

    # --------------------------- slot bookkeeping ----------------------------
    def _finish(self, slot: int) -> None:
        st = self.occupant[slot]
        handle: StreamHandle = st["handle"]
        req = handle.request
        handle.result = RequestResult(
            rid=req.rid,
            tokens=np.concatenate(
                [req.prompt, np.asarray(handle._tokens, np.int32)]
            ),
            prompt_len=req.prompt.size,
            arrival=req.arrival,
            admitted_step=st["admitted"],
            finished_step=self.step_idx,
            finished_wall_s=time.perf_counter() - self._t0,
            prefix_hit_tokens=st["prefix_hit_tokens"],
            priority=req.priority,
            tenant=req.tenant,
            preemptions=handle._preempt_count,
        )
        self._live_rids.discard(req.rid)
        if self.s.paged:
            self.ppool.release(st["pages"])
            # An inactive slot's clamped decode write must land in
            # the garbage page, never in a (possibly reallocated)
            # page of the retired occupant.
            self.btables[slot, :] = 0
        self.occupant[slot] = None
        self.active[slot] = False
        self.alloc.release(slot)

    def _seat(self, slot: int, handle: StreamHandle, tok0: int,
              admitted: int, pages: List[int], hit_tokens: int) -> None:
        """Common post-prefill bookkeeping for both modes.  A handle
        with tokens already delivered is a preemption RESUME: its
        re-prefill covered ``prompt + generated[:-1]``, its sampled
        ``tok0`` is discarded (the original sample was already
        delivered) and decode continues mid-stream."""
        req = handle.request
        k = handle.n_generated
        if handle._admitted is None:
            handle._admitted = admitted
            handle._hit_tokens0 = min(hit_tokens, req.prompt.size)
        if k:
            # Resume: k tokens were sampled before eviction, the last
            # one has not been decoded yet.  pos = P + k - 1 restores
            # the decode-entry invariant pos = prompt_len + steps - 1.
            self.occupant[slot] = {
                "handle": handle, "remaining": req.n_tokens - k,
                "admitted": handle._admitted, "pages": pages,
                "prefix_hit_tokens": handle._hit_tokens0,
            }
            self.pos[slot] = req.prompt.size + k - 1
            self.active[slot] = True
            self.cur[slot] = handle._tokens[-1]
            self.keys[slot] = handle.key
            self.steps[slot] = k
            self.temps[slot] = req.temperature
            return
        self.occupant[slot] = {
            "handle": handle, "remaining": req.n_tokens - 1,
            "admitted": handle._admitted, "pages": pages,
            "prefix_hit_tokens": handle._hit_tokens0,
        }
        self.pos[slot] = req.prompt.size
        self.active[slot] = True
        self.cur[slot] = tok0
        self.keys[slot] = handle.key
        self.steps[slot] = 1
        self.temps[slot] = req.temperature
        self._record_token(handle, tok0)
        if self.occupant[slot]["remaining"] == 0 or tok0 == self.s.eos_id:
            self._finish(slot)

    # ----------------------------- fairness ----------------------------------
    def _effective_prompt(self, handle: StreamHandle) -> np.ndarray:
        """What admission must prefill: the prompt, plus — for a
        preemption resume — every generated token except the last (the
        last was sampled but its K/V not yet written by decode)."""
        k = handle.n_generated
        if not k:
            return handle.request.prompt
        return np.concatenate(
            [handle.request.prompt, np.asarray(handle._tokens[:-1], np.int32)]
        )

    def _select_candidate(self, blocked: Set[int]) -> Optional[StreamHandle]:
        """Stride scheduling over priority classes: among classes with
        an eligible (arrival reached, class not ``blocked``) queued
        request, pick the one with the least virtual time — ties to the
        higher priority — and return its FIFO head.  A single class
        reduces to plain arrival-order FIFO."""
        best: Optional[StreamHandle] = None
        best_key: Optional[Tuple[float, int]] = None
        seen: Set[int] = set()
        for h in self.queue:                  # sorted by (arrival, seq)
            if h.request.arrival > self.step_idx:
                break
            p = h.request.priority
            if p in blocked or p in seen:
                continue
            seen.add(p)
            vt = max(self._vt.get(p, self._vt_floor), self._vt_floor)
            key = (vt, -p)
            if best_key is None or key < best_key:
                best, best_key = h, key
        return best

    def _charge(self, priority: int) -> None:
        """Advance a class's virtual time by its stride (1/priority) on
        admission; the floor tracks the last admitted pass so a newly
        active class starts level with the field."""
        vt = max(self._vt.get(priority, self._vt_floor), self._vt_floor)
        self._vt_floor = vt
        self._vt[priority] = vt + 1.0 / priority

    # ---------------------------- preemption ---------------------------------
    def _preempt_one(self, for_handle: StreamHandle) -> bool:
        """Evict ONE occupant of strictly lower priority than
        ``for_handle`` (lowest class first, least progress within it —
        the cheapest resume).  Returns False when no such victim exists;
        the caller retries admission after each eviction, so no more
        occupants are evicted than the admission needs.

        Fires only under SUSTAINED pressure: the candidate must have
        been resource-blocked since an earlier step.  A merely backlogged
        higher class never evicts the admission the stride scheduler
        just seated (seat-then-evict thrash would waste every victim's
        prefill), and short-occupancy traffic keeps its weighted share —
        slots that free every step satisfy the higher class without any
        preemption at all."""
        if not self.s.preempt_active:
            return False
        if (for_handle._blocked_at is None
                or for_handle._blocked_at >= self.step_idx):
            return False
        p = for_handle.request.priority
        victims = [
            s for s in range(self.s.max_slots)
            if self.occupant[s] is not None
            and self.occupant[s]["handle"].request.priority < p
        ]
        if not victims:
            return False

        def cost(s: int):
            h = self.occupant[s]["handle"]
            return (h.request.priority, h.n_generated, -s)

        self._preempt_slot(min(victims, key=cost))
        return True

    def _preempt_slot(self, slot: int) -> None:
        """Evict ``slot``'s occupant: release its pages (registered
        prefix pages become CACHED — the resume's ``match_prefix`` hits
        them) and re-queue the handle.  Delivered tokens stay delivered;
        the resume path re-prefills the rest bitwise-identically."""
        st = self.occupant[slot]
        handle: StreamHandle = st["handle"]
        self.ppool.release(st["pages"])
        self.btables[slot, :] = 0
        self._chunk_slots.discard(slot)
        self.occupant[slot] = None
        self.active[slot] = False
        self.alloc.release(slot)
        handle._preempt_count += 1
        self.preemptions += 1
        self.total_preemptions += 1
        self._requeue(handle)

    # --------------------------- legacy admission ----------------------------
    def _admit_legacy(self) -> None:
        while self.alloc.free_count:
            handle = self._select_candidate(set())
            if handle is None:
                return
            self.queue.remove(handle)
            self._charge(handle.request.priority)
            req = handle.request
            slot = self.alloc.acquire()
            P = req.prompt.size
            bucket = self.s._bucket_for(P)
            padded = np.zeros((1, bucket), np.int32)
            padded[0, :P] = req.prompt
            # Explicit conversions only: a raw np scalar as a jit arg is
            # an implicit host->device transfer (the transfers lint runs
            # this path under jax.transfer_guard("disallow")).
            self.pool, tok0 = self.s._prefill_jit(bucket)(
                self.s.params, self.pool, jnp.asarray(padded),
                jnp.asarray(np.int32(P)), jnp.asarray(np.int32(slot)),
                jnp.asarray(handle.key),
                jnp.asarray(np.float32(req.temperature)),
            )
            self.prefills += 1
            self.prefill_batches += 1
            self._seat(slot, handle, int(tok0), self.step_idx, [], 0)

    # ---------------------------- paged admission ----------------------------
    def _try_admit_paged(self, handle: StreamHandle, pending: Set[int]):
        """Reserve a slot + pages for ``handle``'s request.  Returns an
        admission dict, None (cannot admit now: no slot / not enough
        pages), or "conflict" (its prefix pages are pending fill in the
        current burst group — flush the group first).  A chunked
        admission (tail longer than ``prefill_chunk``) reserves its slot
        and ALL its pages but defers the fill to ``_advance_chunks``;
        pending pages are no conflict for it — they are filled before
        its first chunk runs."""
        if not self.alloc.free_count:
            return None
        req = handle.request
        ppool = self.ppool
        prompt_eff = self._effective_prompt(handle)
        need = pages_needed(req.prompt.size, req.n_tokens, self.s.page_size)
        if self.s.prefix_reuse_active:
            matched, hashes = ppool.match_prefix(prompt_eff)
        else:
            matched, hashes = [], []
        ctx = len(matched) * self.s.page_size
        tail = prompt_eff[ctx:]
        chunked = (self.s.chunk_active
                   and tail.size > self.s.prefill_chunk)
        if not chunked and pending.intersection(matched):
            return "conflict"
        ppool.ref(matched)          # pin before allocation can evict
        fresh_needed = need - len(matched)
        if fresh_needed > ppool.available():
            ppool.unref(matched)    # roll back the pin (and its stats)
            return None
        fresh = ppool.allocate(fresh_needed)
        pages = matched + fresh
        registered = len(matched)
        if (self.s.prefix_reuse_active and not chunked
                and len(hashes) > len(matched)):
            # Non-chunked: the whole tail fills this step, so every
            # covered page can be indexed now.  Chunked admissions
            # register incrementally as chunks fill (_advance_chunks) —
            # indexing an unfilled page would let a concurrent match
            # attend garbage.
            ppool.register_prefix(
                hashes[len(matched):], pages[len(matched):len(hashes)],
                parent=hashes[len(matched) - 1] if matched else None,
            )
            registered = len(hashes)
        slot = self.alloc.acquire()
        self.btables[slot, :need] = pages
        self.btables[slot, need:] = 0
        return {
            "handle": handle, "slot": slot, "pages": pages, "ctx_len": ctx,
            "tail": tail, "fresh": fresh, "chunked": chunked,
            "hashes": hashes, "registered": registered,
            "prompt_eff": prompt_eff,
        }

    def _run_group(self, group: List[dict]) -> None:
        S = self.s.max_slots
        Bg = len(group)
        Bpad = 1 << (Bg - 1).bit_length()
        bucket = self.s._bucket_for(max(len(g["tail"]) for g in group))
        tokens = np.zeros((Bpad, bucket), np.int32)
        bt = np.zeros((Bpad, self.s.pages_per_slot), np.int32)
        slots_arr = np.full(Bpad, S, np.int32)      # garbage slot default
        ctx = np.zeros(Bpad, np.int32)
        tv = np.zeros(Bpad, np.int32)
        temps_g = np.zeros(Bpad, np.float32)
        keys_g = np.zeros((Bpad, 2), np.uint32)
        for i, g in enumerate(group):
            T = len(g["tail"])
            tokens[i, :T] = g["tail"]
            bt[i] = self.btables[g["slot"]]
            slots_arr[i] = g["slot"]
            ctx[i] = g["ctx_len"]
            tv[i] = T
            temps_g[i] = g["handle"].request.temperature
            keys_g[i] = g["handle"].key
        self.pool, toks = self.s._prefill_jit((bucket, Bpad))(
            self.s.params, self.pool, jnp.asarray(tokens), jnp.asarray(bt),
            jnp.asarray(slots_arr), jnp.asarray(ctx), jnp.asarray(tv),
            jnp.asarray(keys_g), jnp.asarray(temps_g),
        )
        toks = np.asarray(toks)
        self.prefills += Bg
        self.prefill_batches += 1
        for i, g in enumerate(group):
            self._seat(g["slot"], g["handle"], int(toks[i]), self.step_idx,
                       g["pages"], g["ctx_len"])

    def _seat_chunking(self, adm: dict) -> None:
        """Seat a chunked admission: slot and pages are reserved, the
        slot stays decode-inactive while ``_advance_chunks`` fills its
        tail ``prefill_chunk`` tokens per tick."""
        slot, handle = adm["slot"], adm["handle"]
        if handle._admitted is None:
            handle._admitted = self.step_idx
            handle._hit_tokens0 = min(adm["ctx_len"],
                                      handle.request.prompt.size)
        self.occupant[slot] = {
            "handle": handle, "remaining": None,   # set at activation
            "admitted": handle._admitted, "pages": adm["pages"],
            "prefix_hit_tokens": handle._hit_tokens0,
            "chunk": {
                "prompt_eff": adm["prompt_eff"], "filled": adm["ctx_len"],
                "hashes": adm["hashes"], "registered": adm["registered"],
            },
        }
        self._chunk_slots.add(slot)

    def _register_chunk_pages(self, slot: int, ck: dict) -> None:
        """Index the prefix pages a chunk fill just completed (never
        ahead of the fill: a concurrent match on an unfilled page would
        attend garbage).  Hashes another request registered first are
        skipped by ``register_prefix`` — our copy stays private."""
        if not self.s.prefix_reuse_active:
            return
        hashes = ck["hashes"]
        reg = ck["registered"]
        cover = min(ck["filled"] // self.s.page_size, len(hashes))
        if cover > reg:
            pages = self.occupant[slot]["pages"]
            self.ppool.register_prefix(
                hashes[reg:cover], pages[reg:cover],
                parent=hashes[reg - 1] if reg else None,
            )
            ck["registered"] = cover

    def _advance_chunks(self) -> None:
        """One chunked-prefill advance: every chunking slot fills its
        next ``prefill_chunk`` tokens in ONE batched prefill program
        (same (bucket, width) key space as burst prefill), so co-tenant
        decode steps interleave with a long prompt's fill instead of
        stalling behind it.  A slot whose tail completes activates for
        decode with its first token sampled from the final chunk's
        logits."""
        if not self._chunk_slots:
            return
        rows = sorted(self._chunk_slots)
        C = self.s.prefill_chunk
        S = self.s.max_slots
        plan = []
        for slot in rows:
            ck = self.occupant[slot]["chunk"]
            take = min(C, ck["prompt_eff"].size - ck["filled"])
            plan.append((slot, ck, take))
        Bg = len(plan)
        Bpad = 1 << (Bg - 1).bit_length()
        bucket = self.s._bucket_for(max(take for _, _, take in plan))
        tokens = np.zeros((Bpad, bucket), np.int32)
        bt = np.zeros((Bpad, self.s.pages_per_slot), np.int32)
        slots_arr = np.full(Bpad, S, np.int32)
        ctx = np.zeros(Bpad, np.int32)
        tv = np.zeros(Bpad, np.int32)
        temps_g = np.zeros(Bpad, np.float32)
        keys_g = np.zeros((Bpad, 2), np.uint32)
        for i, (slot, ck, take) in enumerate(plan):
            handle = self.occupant[slot]["handle"]
            filled = ck["filled"]
            tokens[i, :take] = ck["prompt_eff"][filled:filled + take]
            bt[i] = self.btables[slot]
            slots_arr[i] = slot
            ctx[i] = filled
            tv[i] = take
            temps_g[i] = handle.request.temperature
            keys_g[i] = handle.key
        self.pool, toks = self.s._prefill_jit((bucket, Bpad))(
            self.s.params, self.pool, jnp.asarray(tokens), jnp.asarray(bt),
            jnp.asarray(slots_arr), jnp.asarray(ctx), jnp.asarray(tv),
            jnp.asarray(keys_g), jnp.asarray(temps_g),
        )
        toks = np.asarray(toks)
        self.prefill_batches += 1
        for i, (slot, ck, take) in enumerate(plan):
            ck["filled"] += take
            self.prefill_chunks += 1
            self.total_prefill_chunks += 1
            self._register_chunk_pages(slot, ck)
            if ck["filled"] == ck["prompt_eff"].size:
                self._chunk_slots.discard(slot)
                st = self.occupant[slot]
                self.prefills += 1
                # Activate for decode; _seat rebuilds the occupant (the
                # slot stays acquired) and handles EOS/n_tokens==1 —
                # resume handles keep their delivered stream.
                self.occupant[slot] = None
                self._seat(slot, st["handle"], int(toks[i]), st["admitted"],
                           st["pages"], st["prefix_hit_tokens"])

    def _admit_round_paged(self) -> bool:
        """One admission round: build and run one burst group in
        fairness order.  A candidate that cannot admit blocks its class
        for the round (other classes may still fit); the round's FIRST
        candidate may preempt strictly-lower-priority occupants.
        Returns True when anything was admitted or a conflict flushed —
        both mean another round may make progress."""
        group: List[dict] = []
        chunk_seats: List[dict] = []
        pending: Set[int] = set()
        blocked: Set[int] = set()
        conflict = False
        head = True      # only the round's FIRST candidate has head rights
        while True:
            handle = self._select_candidate(blocked)
            if handle is None:
                break
            adm = self._try_admit_paged(handle, pending)
            if adm is None and head:
                # Head of the round under sustained pressure: evict one
                # victim at a time until it fits or no lower class
                # remains.  Losing as round HEAD (nothing admitted ahead
                # of it this round) is the pressure signal — a candidate
                # that merely queued behind this round's admissions is
                # not blocked, it is just not next.
                while adm is None and self._preempt_one(handle):
                    adm = self._try_admit_paged(handle, pending)
                if adm is None and handle._blocked_at is None:
                    handle._blocked_at = self.step_idx
            head = False
            if adm == "conflict":
                conflict = True     # flush the group; retry next round
                break
            if adm is None:
                blocked.add(handle.request.priority)
                continue
            self.queue.remove(handle)
            handle._blocked_at = None
            if handle._admitted is None:
                # A preemption resume was already charged at its first
                # admission — its class does not pay twice for one
                # request's slot share.
                self._charge(handle.request.priority)
            if adm.pop("chunked"):
                chunk_seats.append(adm)
            else:
                group.append(adm)
                pending.update(adm["fresh"])
                if not self.s.burst_prefill:
                    break
        for adm in chunk_seats:
            self._seat_chunking(adm)
        if group:
            self._run_group(group)  # may finish slots -> keep admitting
        return bool(group) or bool(chunk_seats) or conflict

    def _admit_all_paged(self) -> None:
        """Admit as many eligible requests as fit, in fairness order, in
        burst groups; a group flushes when a member's prefix pages are
        still pending fill by the group itself (its context gather must
        see them filled), or when burst batching is disabled."""
        while self._admit_round_paged():
            pass


class Scheduler:
    """Continuous-batching engine over a paged KV-cache pool.

    The scheduler owns the *compiled programs* and their configuration;
    all serve-loop state lives in a persistent :class:`ServeSession`
    (``session()``), created lazily on first use and shared by every
    ``serve()`` / ``submit()`` / ``step()`` call — so the device pool,
    the prefix cache and the jit caches survive across traces.

    Multi-tenant options: ``max_queue`` sheds overload at submission
    (``ValueError``), ``preempt`` lets strictly-higher-priority arrivals
    evict lower-class occupants (resumed bitwise-exactly from their
    still-cached pages), ``prefill_chunk`` caps how many prompt tokens
    one tick may prefill so long prompts cannot stall co-tenant decode.
    Preemption and chunked prefill need the same exactness conditions as
    prefix reuse (no SSM layers, lossless cache dtype) and auto-disable
    otherwise.

    Compiled-program budget across ANY trace — and across every trace
    of a session — is one decode program plus, in paged mode, one
    prefill program per (tail bucket, power-of-two burst width) pair
    actually used (chunked-prefill advances draw from the SAME keyed
    program set); with ``paged=False`` one prefill program per prompt
    bucket.  ``compile_counts`` exposes the jit cache sizes so tests
    assert this instead of eyeballing."""

    def __init__(
        self,
        cfg: LMConfig,
        params,
        max_slots: int = 4,
        max_len: int = 512,
        prefill_buckets: Optional[Sequence[int]] = None,
        eos_id: Optional[int] = None,
        seed: int = 0,
        dcim_sim=None,
        paged: bool = True,
        page_size: int = 16,
        n_pages: Optional[int] = None,
        prefix_reuse: bool = True,
        burst_prefill: bool = True,
        attn_backend: Optional[str] = None,
        max_queue: Optional[int] = None,
        preempt: bool = True,
        prefill_chunk: Optional[int] = None,
        mesh=None,
        tp: Optional[int] = None,
    ):
        if attn_backend is not None:
            # Thread the paged-attention backend (kernels.ops.AttnBackend)
            # through every jitted program via the config — zero call-site
            # churn; None keeps cfg's own setting (default "auto").
            cfg = dataclasses.replace(cfg, attn_backend=attn_backend).validate()
        # Tensor/expert-parallel serving mesh.  Like attn_backend this is
        # pure plumbing with zero call-site churn: params and the paged
        # pool are laid out by the exact serving rules
        # (dist.sharding.serve_param_sharding_tree /
        # serve_pool_sharding_tree) and every trace/call runs inside
        # _numerics()'s use_mesh, so the SAME jitted programs partition
        # over the mesh while greedy tokens stay bitwise-identical to the
        # single-device run (all communication is all-gather).
        if tp is not None:
            if mesh is not None:
                raise ValueError("pass either mesh= or tp=, not both")
            tp = int(tp)
            if tp < 1:
                raise ValueError(f"tp must be >= 1, got {tp}")
            if tp > jax.device_count():
                raise ValueError(
                    f"tp={tp} exceeds {jax.device_count()} visible device(s)"
                )
            mesh = jax.make_mesh((tp,), ("model",))
        self.mesh = mesh
        self.mesh_ctx = None if mesh is None else shd.serving_context(mesh)
        if self.mesh is not None:
            params = jax.device_put(
                params, shd.serve_param_sharding_tree(params, self.mesh)
            )
        self.cfg = cfg
        self.params = params
        self.max_slots = int(max_slots)
        self.max_len = int(max_len)
        self.eos_id = eos_id
        self.seed = seed
        self.dcim_sim = dcim_sim
        if prefill_buckets is None:
            prefill_buckets = default_prefill_buckets(self.max_len)
        buckets = sorted(set(int(b) for b in prefill_buckets))
        if not buckets or buckets[0] < 1 or buckets[-1] > self.max_len:
            raise ValueError(f"bad prefill buckets {buckets} for max_len {self.max_len}")
        if buckets[-1] != self.max_len:
            buckets.append(self.max_len)   # every admissible prompt fits somewhere
        self.prefill_buckets = buckets
        if max_slots < 1:
            raise ValueError(f"need at least one slot, got {max_slots}")

        self.paged = bool(paged)
        self.page_size = int(page_size)
        self.burst_prefill = bool(burst_prefill) and self.paged
        if self.paged:
            if self.max_len % self.page_size:
                raise ValueError(
                    f"max_len {self.max_len} must be a multiple of "
                    f"page_size {self.page_size}"
                )
            self.pages_per_slot = self.max_len // self.page_size
            if n_pages is None:
                # Every slot can hold a full max_len sequence even with
                # zero sharing, plus the garbage page.
                n_pages = self.max_slots * self.pages_per_slot + 1
            self.n_pages = int(n_pages)
            if self.n_pages < 2:
                raise ValueError(f"need >= 2 pages, got {self.n_pages}")
        else:
            self.pages_per_slot = 0
            self.n_pages = 0
        # Prefix reuse must be token-exact against full recompute:
        #  * SSM layers carry recurrent state — a page's K/V analogue
        #    does not exist, and skipping prefix prefill would skip the
        #    state the tail depends on;
        #  * a lossy cache dtype would hand the tail prefill ROUNDED
        #    context where the reference prefill attends compute-dtype
        #    values.
        # Preemption-resume and chunked prefill re-prefill positions the
        # reference computed in one pass (decode-written ones included),
        # attending earlier pages as context — exact under precisely the
        # same conditions, so they share the gate.
        period = cfg.scan_period()
        has_ssm = any(cfg.mixer_kind(i) == "mamba" for i in range(period))
        self._ctx_exact = (
            not has_ssm and cfg.cache_dtype == cfg.compute_dtype
        )
        self.prefix_reuse = bool(prefix_reuse) and self.paged
        self.prefix_reuse_active = self.prefix_reuse and self._ctx_exact
        self.max_queue = None if max_queue is None else int(max_queue)
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.preempt_active = bool(preempt) and self.paged and self._ctx_exact
        self.prefill_chunk = (
            None if prefill_chunk is None else int(prefill_chunk)
        )
        if self.prefill_chunk is not None and self.prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1, got {prefill_chunk}"
            )
        self.chunk_active = (
            self.prefill_chunk is not None and self.paged and self._ctx_exact
        )
        # The prefill program's context gather compiles in only when
        # some admission can carry ctx_len > 0.
        self._use_context = self.prefix_reuse_active or self.chunk_active

        # The cache pool is donated: every program call rebinds the
        # session's pool to the returned value, and aliasing lets XLA
        # update the biggest buffer of the hot loop in place instead of
        # copying it per step.
        decode = _decode_paged_fn if self.paged else _decode_fn
        self._decode = jax.jit(partial(decode, cfg=cfg), donate_argnums=(1,))
        self._prefills: Dict[Union[int, Tuple[int, int]], "jax.stages.Wrapped"] = {}
        self.last_stats: Optional[ServeStats] = None
        self._session: Optional[ServeSession] = None

    # ----------------------------- plumbing ---------------------------------
    def _numerics(self):
        """The context every program trace/call runs under.  All jit
        entry points funnel through ``ServeSession._step_locked`` (and
        the contract replays), which wraps its whole body in this — so
        installing the serving mesh here shards every program with zero
        call-site churn."""
        if self.mesh_ctx is None:
            return numerics_ctx(self.dcim_sim)
        stack = contextlib.ExitStack()
        stack.enter_context(numerics_ctx(self.dcim_sim))
        stack.enter_context(shd.use_mesh(self.mesh_ctx))
        return stack

    def _bucket_for(self, prompt_len: int) -> int:
        for b in self.prefill_buckets:
            if b >= prompt_len:
                return b
        raise ValueError(        # unreachable: buckets end at max_len
            f"prompt length {prompt_len} exceeds every bucket"
        )

    def _prefill_jit(self, key):
        """Legacy mode keys by prompt bucket; paged mode by (tail
        bucket, burst width)."""
        fn = self._prefills.get(key)
        if fn is None:
            if self.paged:
                fn = jax.jit(
                    partial(_burst_prefill_fn, cfg=self.cfg,
                            page_size=self.page_size,
                            use_context=self._use_context),
                    donate_argnums=(1,),    # pool rebinding, as in _decode
                )
            else:
                fn = jax.jit(
                    partial(_prefill_fn, cfg=self.cfg, max_len=self.max_len),
                    donate_argnums=(1,),
                )
            self._prefills[key] = fn
        return fn

    def compile_counts(self) -> Dict[str, int]:
        """Jit-cache sizes: the scheduler's whole compiled-program
        budget, shared by every session and every trace."""
        counts = {
            "decode": int(self._decode._cache_size()),
            "prefill": {k: int(f._cache_size()) for k, f in self._prefills.items()},
        }
        counts["total"] = counts["decode"] + sum(counts["prefill"].values())
        return counts

    # ----------------------------- sessions ----------------------------------
    def session(self, fresh: bool = False) -> ServeSession:
        """The scheduler's persistent :class:`ServeSession` (created on
        first use).  ``fresh=True`` builds an independent session with
        its own device pool and prefix cache — compiled programs are
        still shared through this scheduler."""
        if fresh:
            return ServeSession(self)
        if self._session is None:
            self._session = ServeSession(self)
        return self._session

    def submit(self, request, seed: Optional[int] = None,
               on_token=None) -> StreamHandle:
        """Submit one request to the persistent session (see
        :meth:`ServeSession.submit`)."""
        return self.session().submit(request, seed=seed, on_token=on_token)

    def step(self) -> int:
        """One tick of the persistent session."""
        return self.session().step()

    def drain(self) -> None:
        self.session().drain()

    def serve(
        self,
        requests: Sequence[Union[Request, np.ndarray, list]],
        seed: Optional[int] = None,
    ) -> List[RequestResult]:
        """Serve an arrival trace to completion through the persistent
        session; results come back in submission order and the trace's
        ``ServeStats`` lands on ``self.last_stats``.  Unlike the
        pre-session scheduler this does NOT rebuild the device pool:
        prefix pages cached by an earlier ``serve()`` call are warm."""
        return self.session().serve(requests, seed=seed)


# ------------------------------ lint contract --------------------------------
@register_contract(
    "serve.scheduler",
    checks=("donation", "transfers", "recompile", "precision"),
    description="paged continuous-batching serve loop at a smoke config "
                "with the concurrent multi-tenant driver features on "
                "(priorities, preemption, chunked prefill, bounded queue): "
                "the pool donation must alias, the ServeSession.step() hot "
                "path must not transfer implicitly, a replayed mixed "
                "trace must stay within the one-decode + "
                "one-prefill-per-(bucket,width) compile budget, and the "
                "traced decode/prefill programs must satisfy the "
                "precision policy — including the exactness gates "
                "re-derived from the actual pool leaf dtypes",
)
def _build_serve_contract() -> Built:
    from repro import configs
    from repro.analysis.jaxpr_tools import (
        canonical_signature,
        compile_unit,
        pytree_leaf_specs,
    )
    from repro.analysis.registry import ExactnessGate, PrecisionPolicy

    # Lossless cache (cache_dtype == compute_dtype): the exactness gates
    # — prefix reuse, preemption-resume, chunked prefill — are ON, and
    # the precision check re-derives that from the traced pool leaves.
    cfg = configs.get_smoke_config("qwen2.5-3b")
    cfg = dataclasses.replace(cfg, cache_dtype=cfg.compute_dtype)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    # Multi-tenant knobs ON: the replayed trace exercises priority
    # admission, chunked prefill and the preemption path through the
    # same jitted programs the plain scheduler uses.  The transfer-guard
    # hot() stays single-threaded — jax.transfer_guard is thread-local,
    # so a background pump would escape it; the pump runs the very same
    # _step_locked() body this drives cooperatively.
    sched = Scheduler(cfg, params, max_slots=3, max_len=32, page_size=8,
                      max_queue=64, prefill_chunk=8)
    session = sched.session()

    # --- replay a mixed-length trace, recording abstract signatures ---
    signatures: List[Tuple[str, str]] = []
    orig_decode, orig_prefill_jit = sched._decode, sched._prefill_jit

    def spy_decode(*args):
        signatures.append(("decode", canonical_signature(args)))
        return orig_decode(*args)

    def spy_prefill_jit(key):
        fn = orig_prefill_jit(key)

        def wrapped(*args):
            signatures.append(("prefill", canonical_signature(args)))
            return fn(*args)

        return wrapped

    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(1, 64, p).astype(np.int32),
                n_tokens=t, rid=i, arrival=a, priority=pr,
                tenant=f"t{pr}")
        for i, (p, t, a, pr) in enumerate(
            [(3, 2, 0, 1), (5, 3, 0, 2), (9, 2, 0, 1), (3, 4, 1, 3),
             (17, 2, 2, 1), (6, 3, 2, 2)]
        )
    ]
    sched._decode, sched._prefill_jit = spy_decode, spy_prefill_jit
    try:
        session.serve(reqs)
    finally:
        sched._decode, sched._prefill_jit = orig_decode, orig_prefill_jit

    counts = sched.compile_counts()
    replay = Replay(
        signatures=signatures,
        # one decode signature ever; prefill signatures may differ only
        # as much as the (bucket, width) program keys actually used
        max_programs={"decode": 1, "prefill": len(sched._prefills)},
        live_counts={
            "decode": counts["decode"],
            "prefill": sum(counts["prefill"].values()),
        },
        live_budget={"decode": 1, "prefill": len(sched._prefills)},
    )

    # --- compiled units for the donation check ---
    S = sched.max_slots
    decode_args = (
        params, session.pool, jnp.asarray(session.cur),
        jnp.asarray(session.pos), jnp.asarray(session.active),
        jnp.asarray(session.btables), jnp.asarray(session.keys),
        jnp.asarray(session.steps), jnp.asarray(session.temps),
    )
    units = [compile_unit(
        "decode", sched._decode, decode_args, donate_argnums=(1,)
    )]
    if sched._prefills:
        bucket, width = sorted(
            k for k in sched._prefills if isinstance(k, tuple)
        )[0]
        prefill_args = (
            params, session.pool,
            jnp.zeros((width, bucket), jnp.int32),
            jnp.zeros((width, sched.pages_per_slot), jnp.int32),
            jnp.full((width,), S, jnp.int32),
            jnp.zeros((width,), jnp.int32),
            jnp.zeros((width,), jnp.int32),
            jnp.zeros((width, 2), jnp.uint32),
            jnp.zeros((width,), jnp.float32),
        )
        units.append(compile_unit(
            f"prefill[{bucket},{width}]", sched._prefill_jit((bucket, width)),
            prefill_args, donate_argnums=(1,),
        ))

    # --- hot path for the transfers check ---
    def hot():
        handle = session.submit(
            Request(prompt=rng.integers(1, 64, 7).astype(np.int32),
                    n_tokens=3, rid=9001, priority=2)
        )
        while not session.idle:
            session.step()
        return handle.result

    decode_jaxpr = jax.make_jaxpr(
        partial(_decode_paged_fn, cfg=cfg)
    )(*decode_args)
    hot_jaxprs = [("decode", decode_jaxpr)]
    pool_leaves = pytree_leaf_specs(session.pool)
    gates = [
        ExactnessGate("prefix_reuse", sched.prefix_reuse_active,
                      "decode", pool_leaves),
        ExactnessGate("preempt", sched.preempt_active, "decode",
                      pool_leaves),
    ]
    if sched._prefills:
        prefill_jaxpr = jax.make_jaxpr(partial(
            _burst_prefill_fn, cfg=cfg, page_size=sched.page_size,
            use_context=sched._use_context,
        ))(params, session.pool, *prefill_args[2:])
        hot_jaxprs.append(("prefill", prefill_jaxpr))
        gates.append(ExactnessGate(
            "chunked_prefill", sched.chunk_active, "prefill", pool_leaves
        ))

    return Built(
        compiled=units,
        hot=hot,
        hot_label="ServeSession.step()",
        hot_jaxprs=hot_jaxprs,
        replay=replay,
        precision=PrecisionPolicy(
            compute_dtype=cfg.compute_dtype, gates=gates
        ),
    )


@register_contract(
    "serve.scheduler_tp",
    checks=("donation", "recompile", "collectives", "precision"),
    description="tensor-parallel paged serve loop on a tp=<n_devices> "
                "('model',) mesh at a smoke config: the sharded pool "
                "donation must still alias, a replayed trace must stay "
                "within the single-device compile budget (sharding adds "
                "no programs), and the partitioned decode HLO must move "
                "data only — per-device all-gather bytes under budget, "
                "all-to-all forbidden for this non-MoE family (exact "
                "serving has no partial-sum collectives to reshuffle)",
)
def _build_serve_tp_contract() -> Built:
    from repro.analysis.jaxpr_tools import (
        canonical_signature,
        compile_unit,
        pytree_leaf_specs,
    )
    from repro.analysis.registry import (
        ContractSkip,
        ExactnessGate,
        PrecisionPolicy,
    )
    from repro import configs

    n_dev = jax.device_count()
    if n_dev < 2:
        raise ContractSkip(
            "tp serve contract needs >= 2 devices; run via "
            "`python -m repro.analysis.lint` (forces 8 host devices)"
        )

    cfg = configs.get_smoke_config("qwen2.5-3b")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    sched = Scheduler(cfg, params, max_slots=3, max_len=32, page_size=8,
                      max_queue=64, prefill_chunk=8, tp=n_dev)
    session = sched.session()

    signatures: List[Tuple[str, str]] = []
    orig_decode, orig_prefill_jit = sched._decode, sched._prefill_jit

    def spy_decode(*args):
        signatures.append(("decode", canonical_signature(args)))
        return orig_decode(*args)

    def spy_prefill_jit(key):
        fn = orig_prefill_jit(key)

        def wrapped(*args):
            signatures.append(("prefill", canonical_signature(args)))
            return fn(*args)

        return wrapped

    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(1, 64, p).astype(np.int32),
                n_tokens=t, rid=i, arrival=a, priority=pr)
        for i, (p, t, a, pr) in enumerate(
            [(3, 2, 0, 1), (9, 3, 0, 2), (17, 2, 1, 1), (6, 3, 1, 2)]
        )
    ]
    sched._decode, sched._prefill_jit = spy_decode, spy_prefill_jit
    try:
        session.serve(reqs)
    finally:
        sched._decode, sched._prefill_jit = orig_decode, orig_prefill_jit

    counts = sched.compile_counts()
    replay = Replay(
        signatures=signatures,
        max_programs={"decode": 1, "prefill": len(sched._prefills)},
        live_counts={
            "decode": counts["decode"],
            "prefill": sum(counts["prefill"].values()),
        },
        live_budget={"decode": 1, "prefill": len(sched._prefills)},
    )

    # Per-device all-gather budget: the biggest replicated-gather in one
    # decode step is the logits gather, vocab * n_slots * 4B per device
    # — everything else (heads/ff re-gathers) is smaller at this config.
    # Order-of-magnitude headroom, but far below a partial-sum-sized
    # rewrite; all-to-all at 0 is the real teeth for a non-MoE family.
    budget = {"all-gather": 1 << 20, "all-to-all": 0}
    S = sched.max_slots
    decode_args = (
        sched.params, session.pool, jnp.asarray(session.cur),
        jnp.asarray(session.pos), jnp.asarray(session.active),
        jnp.asarray(session.btables), jnp.asarray(session.keys),
        jnp.asarray(session.steps), jnp.asarray(session.temps),
    )
    with shd.use_mesh(sched.mesh_ctx):
        units = [compile_unit(
            "decode_tp", sched._decode, decode_args, donate_argnums=(1,),
            shard_divisors=(1, n_dev), collective_budget=budget,
        )]
        if sched._prefills:
            bucket, width = sorted(
                k for k in sched._prefills if isinstance(k, tuple)
            )[0]
            prefill_args = (
                sched.params, session.pool,
                jnp.zeros((width, bucket), jnp.int32),
                jnp.zeros((width, sched.pages_per_slot), jnp.int32),
                jnp.full((width,), S, jnp.int32),
                jnp.zeros((width,), jnp.int32),
                jnp.zeros((width,), jnp.int32),
                jnp.zeros((width, 2), jnp.uint32),
                jnp.zeros((width,), jnp.float32),
            )
            units.append(compile_unit(
                f"prefill_tp[{bucket},{width}]",
                sched._prefill_jit((bucket, width)), prefill_args,
                donate_argnums=(1,), shard_divisors=(1, n_dev),
                collective_budget=budget,
            ))
        decode_jaxpr = jax.make_jaxpr(
            partial(_decode_paged_fn, cfg=cfg)
        )(*decode_args)

    # Stock smoke config: lossy bf16 cache under f32 compute, so the
    # exactness gates must come out DISABLED — the precision check
    # re-derives that from the traced pool leaves.
    gates = [
        ExactnessGate("prefix_reuse", sched.prefix_reuse_active,
                      "decode_tp", pytree_leaf_specs(session.pool)),
    ]
    return Built(
        compiled=units, replay=replay,
        hot_jaxprs=[("decode_tp", decode_jaxpr)],
        precision=PrecisionPolicy(
            compute_dtype=cfg.compute_dtype, gates=gates
        ),
    )
