"""Continuous-batching serving: slot-based KV cache pool + scheduler.

The bucketed ``Engine`` holds every request of an equal-length batch
until the WHOLE batch finishes — one long generation stalls the bucket
and throughput collapses under mixed-length traffic.  The ``Scheduler``
instead owns a fixed pool of ``max_slots`` decode slots, each with its
own KV/SSM cache region and per-slot position, and runs ONE jitted
decode program per step over all slots:

  * admission — queued requests join as slots free up (admission control
    against ``max_len`` reuses the Engine's ValueError contract),
  * prefill — a joining request prefills alone, right-padded to a
    prompt-length *bucket* (``pad_to_bucket`` idiom: a handful of
    compiled prefill shapes serve every prompt length), and its cache is
    written over the slot's region (fully — nothing of the previous
    occupant survives),
  * decode — all slots step together with a per-slot position vector and
    an active-slot mask; requests join and retire without a single
    re-trace (the decode program compiles exactly once),
  * retirement — a slot frees on EOS or after ``n_tokens`` and is handed
    to the next queued request before the next decode step.

Throughput is bounded by slot count, not by the slowest request in a
bucket.  For greedy decoding the served tokens are *token-exact* against
``Engine.generate`` run per request (tests/test_serve_scheduler.py):
continuous batching is a scheduling change, not a numerics change.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from functools import partial
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import LMConfig

from .engine import (
    check_capacity,
    derive_request_keys,
    numerics_ctx,
    sample_tokens,
)


@dataclasses.dataclass
class Request:
    """One generation request for the continuous-batching scheduler."""
    prompt: np.ndarray                 # (P,) int32 token ids
    n_tokens: int = 32
    temperature: float = 0.0
    rid: Optional[int] = None          # defaults to submission index
    arrival: int = 0                   # earliest scheduler step it may join

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: np.ndarray                 # (P + generated,) prompt included
    prompt_len: int
    arrival: int
    admitted_step: int
    finished_step: int
    finished_wall_s: float             # seconds since serve() started

    @property
    def generated(self) -> np.ndarray:
        return self.tokens[self.prompt_len:]


@dataclasses.dataclass
class ServeStats:
    steps: int                         # scheduler ticks, idle ones included
    decode_steps: int
    prefills: int
    max_slots: int
    generated_tokens: int
    wall_s: float
    occupancy: float                   # mean fraction of slots active per decode step


class SlotAllocator:
    """Fixed pool of decode slot ids with LIFO reuse.

    LIFO keeps a just-retired slot's cache region hot: it is overwritten
    by the very next admission instead of cycling through the pool."""

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"need at least one slot, got {n_slots}")
        self.n_slots = n_slots
        self._free: List[int] = list(reversed(range(n_slots)))
        self._busy: set = set()

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def busy(self) -> frozenset:
        return frozenset(self._busy)

    def acquire(self) -> int:
        if not self._free:
            raise RuntimeError("no free slot")
        slot = self._free.pop()
        self._busy.add(slot)
        return slot

    def release(self, slot: int) -> None:
        if slot not in self._busy:
            raise ValueError(f"slot {slot} is not in use")
        self._busy.discard(slot)
        self._free.append(slot)


def default_prefill_buckets(max_len: int) -> List[int]:
    """Powers of two up to max_len (max_len always included): a bounded
    set of compiled prefill shapes serves every admissible prompt."""
    buckets = []
    b = 2
    while b < max_len:
        buckets.append(b)
        b *= 2
    buckets.append(max_len)
    return buckets


def _prefill_fn(params, pool, tokens, valid_len, slot, key, temp, *,
                cfg: LMConfig, max_len: int):
    """Jitted once per prompt bucket: prefill one request (right-padded
    to the bucket), overwrite slot ``slot`` of the pool with its cache,
    sample its first token at per-request step 0."""
    caches, logits = lm.prefill(
        params, {"tokens": tokens}, cfg, max_len=max_len, valid_len=valid_len
    )
    pool = lm.insert_cache_slot(pool, caches, slot)
    tok0 = sample_tokens(
        logits[:, -1], key[None], jnp.zeros((1,), jnp.int32), temp
    )[0]
    return pool, tok0


def _decode_fn(params, pool, cur, pos, active, keys, steps, temps, *,
               cfg: LMConfig):
    """Jitted exactly once: one decode step over ALL slots.  ``pos`` is
    the per-slot length vector; inactive slots are clamped to position 0
    so their (discarded) writes stay in bounds, and their sampled token
    is masked to -1 so host code can never mistake it for output."""
    pos_eff = jnp.where(active, pos, 0)
    logits, pool = lm.decode_step(
        params, {"tokens": cur[:, None]}, pos_eff, pool, cfg
    )
    nxt = sample_tokens(logits[:, -1], keys, steps, temps)
    return pool, jnp.where(active, nxt, -1)


class Scheduler:
    """Continuous-batching engine over a slot-based KV cache pool.

    Compiled-program budget across ANY trace: one decode program plus
    one prefill program per distinct prompt bucket actually used
    (``compile_counts`` exposes the jit cache sizes so tests assert this
    instead of eyeballing)."""

    def __init__(
        self,
        cfg: LMConfig,
        params,
        max_slots: int = 4,
        max_len: int = 512,
        prefill_buckets: Optional[Sequence[int]] = None,
        eos_id: Optional[int] = None,
        seed: int = 0,
        dcim_sim=None,
    ):
        self.cfg = cfg
        self.params = params
        self.max_slots = int(max_slots)
        self.max_len = int(max_len)
        self.eos_id = eos_id
        self.seed = seed
        self.dcim_sim = dcim_sim
        if prefill_buckets is None:
            prefill_buckets = default_prefill_buckets(self.max_len)
        buckets = sorted(set(int(b) for b in prefill_buckets))
        if not buckets or buckets[0] < 1 or buckets[-1] > self.max_len:
            raise ValueError(f"bad prefill buckets {buckets} for max_len {self.max_len}")
        if buckets[-1] != self.max_len:
            buckets.append(self.max_len)   # every admissible prompt fits somewhere
        self.prefill_buckets = buckets
        if max_slots < 1:
            raise ValueError(f"need at least one slot, got {max_slots}")

        # The cache pool is donated: serve() always rebinds it to the
        # returned value, and aliasing lets XLA update the biggest
        # buffer of the hot loop in place instead of copying it per step.
        self._decode = jax.jit(partial(_decode_fn, cfg=cfg), donate_argnums=(1,))
        self._prefills: Dict[int, "jax.stages.Wrapped"] = {}
        self.last_stats: Optional[ServeStats] = None

    # ----------------------------- plumbing ---------------------------------
    def _numerics(self):
        return numerics_ctx(self.dcim_sim)

    def _bucket_for(self, prompt_len: int) -> int:
        for b in self.prefill_buckets:
            if b >= prompt_len:
                return b
        raise ValueError(        # unreachable: buckets end at max_len
            f"prompt length {prompt_len} exceeds every bucket"
        )

    def _prefill_jit(self, bucket: int):
        fn = self._prefills.get(bucket)
        if fn is None:
            fn = jax.jit(
                partial(_prefill_fn, cfg=self.cfg, max_len=self.max_len),
                donate_argnums=(1,),    # pool rebinding, as in _decode
            )
            self._prefills[bucket] = fn
        return fn

    def compile_counts(self) -> Dict[str, int]:
        """Jit-cache sizes: the scheduler's whole compiled-program budget."""
        counts = {
            "decode": int(self._decode._cache_size()),
            "prefill": {b: int(f._cache_size()) for b, f in self._prefills.items()},
        }
        counts["total"] = counts["decode"] + sum(counts["prefill"].values())
        return counts

    # ----------------------------- serving ----------------------------------
    def serve(
        self,
        requests: Sequence[Union[Request, np.ndarray, list]],
        seed: Optional[int] = None,
    ) -> List[RequestResult]:
        """Serve an arrival trace to completion; results come back in
        submission order.  ``ServeStats`` lands on ``self.last_stats``."""
        seed = self.seed if seed is None else seed
        reqs: List[Request] = []
        for i, r in enumerate(requests):
            if not isinstance(r, Request):
                r = Request(prompt=r)
            if r.rid is None:
                r = dataclasses.replace(r, rid=i)
            if r.n_tokens < 1:
                raise ValueError(f"request {r.rid}: n_tokens must be >= 1")
            if r.prompt.size < 1:
                raise ValueError(f"request {r.rid}: empty prompt")
            check_capacity(r.prompt.size, r.n_tokens, self.max_len)
            reqs.append(r)
        rids = [r.rid for r in reqs]
        if len(set(rids)) != len(rids):
            # results are keyed (and PRNG streams derived) by rid — a
            # collision would silently drop one request's output and
            # give both the same sampling stream.
            dup = sorted({r for r in rids if rids.count(r) > 1})
            raise ValueError(f"duplicate request ids {dup}")

        t0 = time.perf_counter()
        S = self.max_slots
        # Arrival order; stable for equal arrival steps.
        queue = deque(sorted(reqs, key=lambda r: r.arrival))
        alloc = SlotAllocator(S)
        pool = lm.init_cache(self.cfg, S, self.max_len)

        pos = np.zeros(S, np.int32)
        active = np.zeros(S, bool)
        cur = np.zeros(S, np.int32)
        keys = np.zeros((S, 2), np.uint32)
        steps = np.zeros(S, np.int32)          # tokens sampled per occupant
        temps = np.zeros(S, np.float32)
        occupant: List[Optional[dict]] = [None] * S

        results: Dict[int, RequestResult] = {}
        step = 0
        decode_steps = 0
        prefills = 0
        active_slot_steps = 0

        def finish(slot: int) -> None:
            st = occupant[slot]
            results[st["req"].rid] = RequestResult(
                rid=st["req"].rid,
                tokens=np.concatenate(
                    [st["req"].prompt, np.asarray(st["out"], np.int32)]
                ),
                prompt_len=st["req"].prompt.size,
                arrival=st["req"].arrival,
                admitted_step=st["admitted"],
                finished_step=step,
                finished_wall_s=time.perf_counter() - t0,
            )
            occupant[slot] = None
            active[slot] = False
            alloc.release(slot)

        def admit(req: Request) -> None:
            nonlocal pool, prefills
            slot = alloc.acquire()
            P = req.prompt.size
            bucket = self._bucket_for(P)
            padded = np.zeros((1, bucket), np.int32)
            padded[0, :P] = req.prompt
            key_r = derive_request_keys(seed, [req.rid])[0]
            pool, tok0 = self._prefill_jit(bucket)(
                self.params, pool, jnp.asarray(padded),
                np.int32(P), np.int32(slot), key_r,
                np.float32(req.temperature),
            )
            prefills += 1
            tok0 = int(tok0)
            occupant[slot] = {
                "req": req, "out": [tok0], "remaining": req.n_tokens - 1,
                "admitted": step,
            }
            pos[slot] = P
            active[slot] = True
            cur[slot] = tok0
            keys[slot] = np.asarray(key_r)
            steps[slot] = 1
            temps[slot] = req.temperature
            if occupant[slot]["remaining"] == 0 or tok0 == self.eos_id:
                finish(slot)

        with self._numerics():
            while queue or active.any():
                while queue and queue[0].arrival <= step and alloc.free_count:
                    admit(queue.popleft())
                if not active.any():
                    # Nothing running: jump straight to the next arrival
                    # (queue is non-empty here, else the loop would have
                    # ended) instead of ticking through the gap.
                    step = max(step + 1, queue[0].arrival)
                    continue
                pool, nxt = self._decode(
                    self.params, pool, jnp.asarray(cur), jnp.asarray(pos),
                    jnp.asarray(active), jnp.asarray(keys),
                    jnp.asarray(steps), jnp.asarray(temps),
                )
                nxt = np.asarray(nxt)
                decode_steps += 1
                active_slot_steps += int(active.sum())
                step += 1
                pos[active] += 1
                steps[active] += 1
                for slot in np.flatnonzero(active):
                    tok = int(nxt[slot])
                    st = occupant[slot]
                    st["out"].append(tok)
                    st["remaining"] -= 1
                    cur[slot] = tok
                    if st["remaining"] == 0 or tok == self.eos_id:
                        finish(slot)

        self.last_stats = ServeStats(
            steps=step,
            decode_steps=decode_steps,
            prefills=prefills,
            max_slots=S,
            generated_tokens=sum(
                r.tokens.size - r.prompt_len for r in results.values()
            ),
            wall_s=time.perf_counter() - t0,
            occupancy=(
                active_slot_steps / (decode_steps * S) if decode_steps else 0.0
            ),
        )
        return [results[r.rid] for r in reqs]
