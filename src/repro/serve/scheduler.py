"""Continuous-batching serving: paged KV-cache pool + scheduler.

The bucketed ``Engine`` holds every request of an equal-length batch
until the WHOLE batch finishes — one long generation stalls the bucket
and throughput collapses under mixed-length traffic.  The ``Scheduler``
instead owns a fixed pool of ``max_slots`` decode slots and runs ONE
jitted decode program per step over all slots.

Since the paged-pool PR the cache is no longer a monolithic per-slot
region but a **paged pool** (vLLM-style): attention K/V lives in shared
fixed-size pages (``lm.init_paged_pool``), each slot holds a block
table of page ids, and the decode program reads/writes THROUGH the
block table (``lm.decode_step_paged``).  SSM state stays per-slot —
it is O(1) in sequence length, so there is nothing to page.  On top of
paging:

  * **shared-prefix reuse** — prompts are hashed at page granularity
    with a rolling chain (``serve.paging.PagePool``); a new request
    whose prefix pages are resident refcounts them and prefills only
    its tail, attending to the reused pages as context
    (``lm.prefill_paged``).  Retired requests' prefix pages stay cached
    (refcount 0, still indexed) until allocation pressure evicts them,
    so reuse works across sequential requests, not just concurrent
    ones.  Reuse auto-disables when it cannot be token-exact: configs
    with SSM layers (recurrent state is not per-position shareable) or
    a lossy ``cache_dtype`` (reused pages would round the context the
    reference prefill saw at compute precision).
  * **batched burst prefill** — all requests admitted at one step
    prefill together in one padded ``(B, bucket)`` program instead of
    one at a time; programs are keyed by (prompt-tail bucket,
    power-of-two batch width), keeping the compile budget bounded.

Both are ``Scheduler`` options that default ON; ``paged=False``
reproduces the previous monolithic per-slot behavior exactly (that
path still runs ``lm.prefill`` + ``lm.insert_cache_slot``).

Scheduling never changes numerics: for greedy decoding the served
tokens are *token-exact* against ``Engine.generate`` run per request
(tests/test_serve_scheduler.py), with paging, prefix reuse and burst
prefill all enabled.  Admission control raises the shared ``ValueError``
capacity contract (``serve.check_capacity`` + per-pool
``paging.check_page_capacity``).  See docs/serving.md for the full
design.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from functools import partial
from typing import Dict, List, Optional, Sequence, Set, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import LMConfig

from .engine import (
    check_capacity,
    derive_request_keys,
    numerics_ctx,
    sample_tokens,
)
from .paging import PagePool, check_page_capacity, pages_needed


@dataclasses.dataclass
class Request:
    """One generation request for the continuous-batching scheduler."""
    prompt: np.ndarray                 # (P,) int32 token ids
    n_tokens: int = 32
    temperature: float = 0.0
    rid: Optional[int] = None          # defaults to submission index
    arrival: int = 0                   # earliest scheduler step it may join

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: np.ndarray                 # (P + generated,) prompt included
    prompt_len: int
    arrival: int
    admitted_step: int
    finished_step: int
    finished_wall_s: float             # seconds since serve() started
    prefix_hit_tokens: int = 0         # prompt tokens served from cached pages

    @property
    def generated(self) -> np.ndarray:
        return self.tokens[self.prompt_len:]


@dataclasses.dataclass
class ServeStats:
    steps: int                         # scheduler ticks, idle ones included
    decode_steps: int
    prefills: int                      # requests prefilled
    max_slots: int
    generated_tokens: int
    wall_s: float
    occupancy: float                   # mean fraction of slots active per decode step
    prefill_batches: int = 0           # prefill programs launched (== prefills
                                       # without burst batching)
    prefix_reuse_active: bool = False
    paging: Optional[dict] = None      # PageStats.as_dict() in paged mode


class SlotAllocator:
    """Fixed pool of decode slot ids with LIFO reuse.

    LIFO keeps a just-retired slot's cache region hot: it is overwritten
    by the very next admission instead of cycling through the pool."""

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"need at least one slot, got {n_slots}")
        self.n_slots = n_slots
        self._free: List[int] = list(reversed(range(n_slots)))
        self._busy: set = set()

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def busy(self) -> frozenset:
        return frozenset(self._busy)

    def acquire(self) -> int:
        if not self._free:
            raise RuntimeError("no free slot")
        slot = self._free.pop()
        self._busy.add(slot)
        return slot

    def release(self, slot: int) -> None:
        if slot not in self._busy:
            raise ValueError(f"slot {slot} is not in use")
        self._busy.discard(slot)
        self._free.append(slot)


def default_prefill_buckets(max_len: int) -> List[int]:
    """Powers of two up to max_len (max_len always included): a bounded
    set of compiled prefill shapes serves every admissible prompt."""
    buckets = []
    b = 2
    while b < max_len:
        buckets.append(b)
        b *= 2
    buckets.append(max_len)
    return buckets


def _prefill_fn(params, pool, tokens, valid_len, slot, key, temp, *,
                cfg: LMConfig, max_len: int):
    """Legacy (paged=False) prefill, jitted once per prompt bucket:
    prefill one request (right-padded to the bucket), overwrite slot
    ``slot`` of the monolithic pool with its cache, sample its first
    token at per-request step 0."""
    caches, logits = lm.prefill(
        params, {"tokens": tokens}, cfg, max_len=max_len, valid_len=valid_len
    )
    pool = lm.insert_cache_slot(pool, caches, slot)
    tok0 = sample_tokens(
        logits[:, -1], key[None], jnp.zeros((1,), jnp.int32), temp
    )[0]
    return pool, tok0


def _decode_fn(params, pool, cur, pos, active, keys, steps, temps, *,
               cfg: LMConfig):
    """Legacy (paged=False) decode, jitted exactly once: one step over
    ALL slots.  ``pos`` is the per-slot length vector; inactive slots are
    clamped to position 0 so their (discarded) writes stay in bounds, and
    their sampled token is masked to -1 so host code can never mistake it
    for output."""
    pos_eff = jnp.where(active, pos, 0)
    logits, pool = lm.decode_step(
        params, {"tokens": cur[:, None]}, pos_eff, pool, cfg
    )
    nxt = sample_tokens(logits[:, -1], keys, steps, temps)
    return pool, jnp.where(active, nxt, -1)


def _decode_paged_fn(params, pool, cur, pos, active, block_tables, keys,
                     steps, temps, *, cfg: LMConfig):
    """Jitted exactly once: one decode step over ALL slots, reading the
    paged pool through the block tables.  Inactive slots clamp to
    position 0 AND carry an all-garbage block table row, so their
    discarded writes land in the reserved garbage page — never in a
    page another request owns."""
    pos_eff = jnp.where(active, pos, 0)
    logits, pool = lm.decode_step_paged(
        params, {"tokens": cur[:, None]}, pos_eff, pool, block_tables, cfg
    )
    nxt = sample_tokens(logits[:, -1], keys, steps, temps)
    return pool, jnp.where(active, nxt, -1)


def _burst_prefill_fn(params, pool, tokens, block_tables, slots, ctx_len,
                      tail_valid, keys, temps, *, cfg: LMConfig,
                      page_size: int, use_context: bool):
    """Jitted once per (tail bucket, burst width): prefill a whole
    admission burst into the paged pool and sample each member's first
    token at per-request step 0.  Padding rows carry tail_valid == 0,
    the garbage slot and an all-garbage block table; their sampled
    token is junk the host ignores.  ``use_context`` is False when the
    scheduler's prefix reuse is gated off — ctx_len is then always 0,
    and the compiled program skips the context gather entirely."""
    pool, logits = lm.prefill_paged(
        params, {"tokens": tokens}, cfg, pool, block_tables, slots,
        ctx_len, tail_valid, page_size, use_context,
    )
    toks = sample_tokens(
        logits[:, -1], keys, jnp.zeros((tokens.shape[0],), jnp.int32), temps
    )
    return pool, toks


class Scheduler:
    """Continuous-batching engine over a paged KV-cache pool.

    Compiled-program budget across ANY trace: one decode program plus —
    in paged mode — one prefill program per (tail bucket, power-of-two
    burst width) pair actually used; with ``paged=False`` one prefill
    program per prompt bucket.  ``compile_counts`` exposes the jit cache
    sizes so tests assert this instead of eyeballing."""

    def __init__(
        self,
        cfg: LMConfig,
        params,
        max_slots: int = 4,
        max_len: int = 512,
        prefill_buckets: Optional[Sequence[int]] = None,
        eos_id: Optional[int] = None,
        seed: int = 0,
        dcim_sim=None,
        paged: bool = True,
        page_size: int = 16,
        n_pages: Optional[int] = None,
        prefix_reuse: bool = True,
        burst_prefill: bool = True,
    ):
        self.cfg = cfg
        self.params = params
        self.max_slots = int(max_slots)
        self.max_len = int(max_len)
        self.eos_id = eos_id
        self.seed = seed
        self.dcim_sim = dcim_sim
        if prefill_buckets is None:
            prefill_buckets = default_prefill_buckets(self.max_len)
        buckets = sorted(set(int(b) for b in prefill_buckets))
        if not buckets or buckets[0] < 1 or buckets[-1] > self.max_len:
            raise ValueError(f"bad prefill buckets {buckets} for max_len {self.max_len}")
        if buckets[-1] != self.max_len:
            buckets.append(self.max_len)   # every admissible prompt fits somewhere
        self.prefill_buckets = buckets
        if max_slots < 1:
            raise ValueError(f"need at least one slot, got {max_slots}")

        self.paged = bool(paged)
        self.page_size = int(page_size)
        self.burst_prefill = bool(burst_prefill) and self.paged
        if self.paged:
            if self.max_len % self.page_size:
                raise ValueError(
                    f"max_len {self.max_len} must be a multiple of "
                    f"page_size {self.page_size}"
                )
            self.pages_per_slot = self.max_len // self.page_size
            if n_pages is None:
                # Every slot can hold a full max_len sequence even with
                # zero sharing, plus the garbage page.
                n_pages = self.max_slots * self.pages_per_slot + 1
            self.n_pages = int(n_pages)
            if self.n_pages < 2:
                raise ValueError(f"need >= 2 pages, got {self.n_pages}")
        else:
            self.pages_per_slot = 0
            self.n_pages = 0
        # Prefix reuse must be token-exact against full recompute:
        #  * SSM layers carry recurrent state — a page's K/V analogue
        #    does not exist, and skipping prefix prefill would skip the
        #    state the tail depends on;
        #  * a lossy cache dtype would hand the tail prefill ROUNDED
        #    context where the reference prefill attends compute-dtype
        #    values.
        period = cfg.scan_period()
        has_ssm = any(cfg.mixer_kind(i) == "mamba" for i in range(period))
        self.prefix_reuse = bool(prefix_reuse) and self.paged
        self.prefix_reuse_active = (
            self.prefix_reuse and not has_ssm
            and cfg.cache_dtype == cfg.compute_dtype
        )

        # The cache pool is donated: serve() always rebinds it to the
        # returned value, and aliasing lets XLA update the biggest
        # buffer of the hot loop in place instead of copying it per step.
        decode = _decode_paged_fn if self.paged else _decode_fn
        self._decode = jax.jit(partial(decode, cfg=cfg), donate_argnums=(1,))
        self._prefills: Dict[Union[int, Tuple[int, int]], "jax.stages.Wrapped"] = {}
        self.last_stats: Optional[ServeStats] = None

    # ----------------------------- plumbing ---------------------------------
    def _numerics(self):
        return numerics_ctx(self.dcim_sim)

    def _bucket_for(self, prompt_len: int) -> int:
        for b in self.prefill_buckets:
            if b >= prompt_len:
                return b
        raise ValueError(        # unreachable: buckets end at max_len
            f"prompt length {prompt_len} exceeds every bucket"
        )

    def _prefill_jit(self, key):
        """Legacy mode keys by prompt bucket; paged mode by (tail
        bucket, burst width)."""
        fn = self._prefills.get(key)
        if fn is None:
            if self.paged:
                fn = jax.jit(
                    partial(_burst_prefill_fn, cfg=self.cfg,
                            page_size=self.page_size,
                            use_context=self.prefix_reuse_active),
                    donate_argnums=(1,),    # pool rebinding, as in _decode
                )
            else:
                fn = jax.jit(
                    partial(_prefill_fn, cfg=self.cfg, max_len=self.max_len),
                    donate_argnums=(1,),
                )
            self._prefills[key] = fn
        return fn

    def compile_counts(self) -> Dict[str, int]:
        """Jit-cache sizes: the scheduler's whole compiled-program budget."""
        counts = {
            "decode": int(self._decode._cache_size()),
            "prefill": {k: int(f._cache_size()) for k, f in self._prefills.items()},
        }
        counts["total"] = counts["decode"] + sum(counts["prefill"].values())
        return counts

    # ----------------------------- serving ----------------------------------
    def serve(
        self,
        requests: Sequence[Union[Request, np.ndarray, list]],
        seed: Optional[int] = None,
    ) -> List[RequestResult]:
        """Serve an arrival trace to completion; results come back in
        submission order.  ``ServeStats`` lands on ``self.last_stats``."""
        seed = self.seed if seed is None else seed
        reqs: List[Request] = []
        for i, r in enumerate(requests):
            if not isinstance(r, Request):
                r = Request(prompt=r)
            if r.rid is None:
                r = dataclasses.replace(r, rid=i)
            if r.n_tokens < 1:
                raise ValueError(f"request {r.rid}: n_tokens must be >= 1")
            if r.prompt.size < 1:
                raise ValueError(f"request {r.rid}: empty prompt")
            check_capacity(r.prompt.size, r.n_tokens, self.max_len)
            if self.paged:
                check_page_capacity(
                    r.prompt.size, r.n_tokens, self.page_size, self.n_pages - 1
                )
            reqs.append(r)
        rids = [r.rid for r in reqs]
        if len(set(rids)) != len(rids):
            # results are keyed (and PRNG streams derived) by rid — a
            # collision would silently drop one request's output and
            # give both the same sampling stream.
            dup = sorted({r for r in rids if rids.count(r) > 1})
            raise ValueError(f"duplicate request ids {dup}")

        t0 = time.perf_counter()
        S = self.max_slots
        # Arrival order; stable for equal arrival steps.
        queue = deque(sorted(reqs, key=lambda r: r.arrival))
        alloc = SlotAllocator(S)
        if self.paged:
            pool = lm.init_paged_pool(
                self.cfg, S, self.n_pages, self.page_size
            )
            ppool = PagePool(self.n_pages, self.page_size)
            btables = np.zeros((S, self.pages_per_slot), np.int32)
        else:
            pool = lm.init_cache(self.cfg, S, self.max_len)
            ppool = None
            btables = None

        pos = np.zeros(S, np.int32)
        active = np.zeros(S, bool)
        cur = np.zeros(S, np.int32)
        keys = np.zeros((S, 2), np.uint32)
        steps = np.zeros(S, np.int32)          # tokens sampled per occupant
        temps = np.zeros(S, np.float32)
        occupant: List[Optional[dict]] = [None] * S

        results: Dict[int, RequestResult] = {}
        step = 0
        decode_steps = 0
        prefills = 0
        prefill_batches = 0
        active_slot_steps = 0

        def finish(slot: int) -> None:
            st = occupant[slot]
            results[st["req"].rid] = RequestResult(
                rid=st["req"].rid,
                tokens=np.concatenate(
                    [st["req"].prompt, np.asarray(st["out"], np.int32)]
                ),
                prompt_len=st["req"].prompt.size,
                arrival=st["req"].arrival,
                admitted_step=st["admitted"],
                finished_step=step,
                finished_wall_s=time.perf_counter() - t0,
                prefix_hit_tokens=st.get("prefix_hit_tokens", 0),
            )
            if self.paged:
                ppool.release(st["pages"])
                # An inactive slot's clamped decode write must land in
                # the garbage page, never in a (possibly reallocated)
                # page of the retired occupant.
                btables[slot, :] = 0
            occupant[slot] = None
            active[slot] = False
            alloc.release(slot)

        def seat(slot: int, req: Request, tok0: int, key_r, admitted: int,
                 pages: List[int], hit_tokens: int) -> None:
            """Common post-prefill bookkeeping for both modes."""
            occupant[slot] = {
                "req": req, "out": [tok0], "remaining": req.n_tokens - 1,
                "admitted": admitted, "pages": pages,
                "prefix_hit_tokens": hit_tokens,
            }
            pos[slot] = req.prompt.size
            active[slot] = True
            cur[slot] = tok0
            keys[slot] = np.asarray(key_r)
            steps[slot] = 1
            temps[slot] = req.temperature
            if occupant[slot]["remaining"] == 0 or tok0 == self.eos_id:
                finish(slot)

        # ------------------------- legacy admission --------------------------
        def admit_legacy(req: Request) -> None:
            nonlocal pool, prefills, prefill_batches
            slot = alloc.acquire()
            P = req.prompt.size
            bucket = self._bucket_for(P)
            padded = np.zeros((1, bucket), np.int32)
            padded[0, :P] = req.prompt
            key_r = derive_request_keys(seed, [req.rid])[0]
            pool, tok0 = self._prefill_jit(bucket)(
                self.params, pool, jnp.asarray(padded),
                np.int32(P), np.int32(slot), key_r,
                np.float32(req.temperature),
            )
            prefills += 1
            prefill_batches += 1
            seat(slot, req, int(tok0), key_r, step, [], 0)

        # ------------------------- paged admission ---------------------------
        def try_admit_paged(req: Request, pending: Set[int]):
            """Reserve a slot + pages for ``req``.  Returns an admission
            dict, None (cannot admit now: no slot / not enough pages),
            or "conflict" (its prefix pages are pending fill in the
            current burst group — flush the group first)."""
            if not alloc.free_count:
                return None
            P = req.prompt.size
            need = pages_needed(P, req.n_tokens, self.page_size)
            if self.prefix_reuse_active:
                matched, hashes = ppool.match_prefix(req.prompt)
                if pending.intersection(matched):
                    return "conflict"
            else:
                matched, hashes = [], []
            ppool.ref(matched)          # pin before allocation can evict
            fresh_needed = need - len(matched)
            if fresh_needed > ppool.available():
                ppool.unref(matched)    # roll back the pin (and its stats)
                return None
            fresh = ppool.allocate(fresh_needed)
            pages = matched + fresh
            if self.prefix_reuse_active and len(hashes) > len(matched):
                ppool.register_prefix(
                    hashes[len(matched):], pages[len(matched):len(hashes)]
                )
            slot = alloc.acquire()
            btables[slot, :need] = pages
            btables[slot, need:] = 0
            ctx = len(matched) * self.page_size
            return {
                "req": req, "slot": slot, "pages": pages, "ctx_len": ctx,
                "tail": req.prompt[ctx:], "fresh": fresh,
            }

        def run_group(group: List[dict]) -> None:
            nonlocal pool, prefills, prefill_batches
            Bg = len(group)
            Bpad = 1 << (Bg - 1).bit_length()
            bucket = self._bucket_for(max(len(g["tail"]) for g in group))
            tokens = np.zeros((Bpad, bucket), np.int32)
            bt = np.zeros((Bpad, self.pages_per_slot), np.int32)
            slots_arr = np.full(Bpad, S, np.int32)      # garbage slot default
            ctx = np.zeros(Bpad, np.int32)
            tv = np.zeros(Bpad, np.int32)
            temps_g = np.zeros(Bpad, np.float32)
            keys_g = np.zeros((Bpad, 2), np.uint32)
            reqs_keys = derive_request_keys(seed, [g["req"].rid for g in group])
            for i, g in enumerate(group):
                T = len(g["tail"])
                tokens[i, :T] = g["tail"]
                bt[i] = btables[g["slot"]]
                slots_arr[i] = g["slot"]
                ctx[i] = g["ctx_len"]
                tv[i] = T
                temps_g[i] = g["req"].temperature
                keys_g[i] = np.asarray(reqs_keys[i])
            pool_new, toks = self._prefill_jit((bucket, Bpad))(
                self.params, pool, jnp.asarray(tokens), jnp.asarray(bt),
                jnp.asarray(slots_arr), jnp.asarray(ctx), jnp.asarray(tv),
                jnp.asarray(keys_g), jnp.asarray(temps_g),
            )
            pool = pool_new
            toks = np.asarray(toks)
            prefills += Bg
            prefill_batches += 1
            for i, g in enumerate(group):
                seat(g["slot"], g["req"], int(toks[i]), reqs_keys[i], step,
                     g["pages"], g["ctx_len"])

        def admit_all_paged() -> None:
            """Admit as many queue heads as fit, in arrival order, in
            burst groups; a group flushes when a member's prefix pages
            are still pending fill by the group itself (its context
            gather must see them filled), or when burst batching is
            disabled."""
            while queue and queue[0].arrival <= step:
                group: List[dict] = []
                pending: Set[int] = set()
                flush = False
                while queue and queue[0].arrival <= step and not flush:
                    adm = try_admit_paged(queue[0], pending)
                    if adm is None:
                        break
                    if adm == "conflict":
                        flush = True
                        break
                    queue.popleft()
                    group.append(adm)
                    pending.update(adm["fresh"])
                    if not self.burst_prefill:
                        break
                if not group:
                    # No admission possible (no slot / not enough pages);
                    # a "conflict" with an empty group cannot happen —
                    # pending is empty until a member joins.
                    return
                run_group(group)        # may finish slots -> keep admitting

        with self._numerics():
            while queue or active.any():
                if self.paged:
                    admit_all_paged()
                else:
                    while (queue and queue[0].arrival <= step
                           and alloc.free_count):
                        admit_legacy(queue.popleft())
                if not active.any():
                    if queue and queue[0].arrival <= step:
                        raise RuntimeError(      # pragma: no cover
                            "admission stalled with an idle pool — "
                            "page accounting bug"
                        )
                    if not queue:
                        break
                    # Nothing running: jump straight to the next arrival
                    # instead of ticking through the gap.
                    step = max(step + 1, queue[0].arrival)
                    continue
                if self.paged:
                    pool, nxt = self._decode(
                        self.params, pool, jnp.asarray(cur), jnp.asarray(pos),
                        jnp.asarray(active), jnp.asarray(btables),
                        jnp.asarray(keys), jnp.asarray(steps),
                        jnp.asarray(temps),
                    )
                else:
                    pool, nxt = self._decode(
                        self.params, pool, jnp.asarray(cur), jnp.asarray(pos),
                        jnp.asarray(active), jnp.asarray(keys),
                        jnp.asarray(steps), jnp.asarray(temps),
                    )
                nxt = np.asarray(nxt)
                decode_steps += 1
                active_slot_steps += int(active.sum())
                step += 1
                pos[active] += 1
                steps[active] += 1
                for slot in np.flatnonzero(active):
                    tok = int(nxt[slot])
                    st = occupant[slot]
                    st["out"].append(tok)
                    st["remaining"] -= 1
                    cur[slot] = tok
                    if st["remaining"] == 0 or tok == self.eos_id:
                        finish(slot)

        self.last_stats = ServeStats(
            steps=step,
            decode_steps=decode_steps,
            prefills=prefills,
            max_slots=S,
            generated_tokens=sum(
                r.tokens.size - r.prompt_len for r in results.values()
            ),
            wall_s=time.perf_counter() - t0,
            occupancy=(
                active_slot_steps / (decode_steps * S) if decode_steps else 0.0
            ),
            prefill_batches=prefill_batches,
            prefix_reuse_active=self.prefix_reuse_active,
            paging=ppool.stats.as_dict() if ppool is not None else None,
        )
        return [results[r.rid] for r in reqs]
