from .engine import Engine, GenerationResult, bucket_requests  # noqa: F401
