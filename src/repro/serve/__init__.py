from .engine import (  # noqa: F401
    Engine,
    GenerationResult,
    bucket_requests,
    check_capacity,
    check_queue_capacity,
    check_unique_rids,
    derive_request_keys,
    sample_tokens,
)
from .paging import (  # noqa: F401
    PagePool,
    PageStats,
    check_page_capacity,
    pages_needed,
    prefix_page_hashes,
)
from .scheduler import (  # noqa: F401
    Request,
    RequestResult,
    Scheduler,
    ServeSession,
    ServeStats,
    SlotAllocator,
    StreamHandle,
    default_prefill_buckets,
)
