"""Distribution layer: logical-axis sharding over JAX meshes.

``repro.dist`` is the scale-out substrate every model/launch module
programs against.  The core idea (borrowed from GSPMD-style logical
axis annotation) is that model code names *logical* axes ("batch",
"heads", "fsdp", ...) and a per-mesh rule table resolves them to
physical mesh axes — with divisibility fallbacks so the same model code
runs unsharded on one device and fully sharded on a 512-chip mesh.

Public API (see :mod:`repro.dist.sharding` for details):

* ``MeshContext``       — logical-axis -> mesh-axis resolution.
* ``use_mesh``          — context manager installing the active context.
* ``current``           — the active ``MeshContext`` (or ``None``).
* ``shard_act``         — activation sharding constraint (identity when
                          no mesh context is installed).
* ``logical_for_path``  — parameter-path -> logical axes rules.
* ``param_sharding_tree`` — param pytree -> ``NamedSharding`` pytree.
* ``shard_map``         — version-compat wrapper over jax's shard_map.
"""
from repro.dist.compat import shard_map
from repro.dist.sharding import (
    MeshContext,
    current,
    logical_for_path,
    param_sharding_tree,
    shard_act,
    use_mesh,
)

__all__ = [
    "MeshContext",
    "current",
    "logical_for_path",
    "param_sharding_tree",
    "shard_act",
    "shard_map",
    "use_mesh",
]
