"""Logical-axis sharding: the resolution layer between model code and
physical meshes.

Model code annotates activations with *logical* axis names::

    x = shard_act(x, ("batch", "seq_sp", None))

and parameters are matched by path against :data:`PARAM_RULES`::

    logical_for_path("blocks/0/mixer/wq/w", 2)  ->  ("fsdp", "tp")

A :class:`MeshContext` resolves logical names to physical mesh axes via
a rule table (``logical -> tuple of mesh axes``), with two fallbacks
that let identical model code run on any mesh:

* **divisibility** — a dim that is not divisible by the resolved axis
  size replicates (``axes_for`` returns ``None``); for multi-axis rules
  the longest divisible *prefix* wins (e.g. ``batch -> ("pod", "data")``
  degrades to ``("pod",)`` and then to replicated).
* **each mesh axis used at most once per spec** — a later dim whose rule
  names an already-consumed axis replicates on that axis instead.

With no installed context (``use_mesh`` not entered) every annotation is
an exact no-op, so all model code runs unsharded by default — this is
what lets the serving stack (``repro.serve``: bucketed Engine, paged
continuous-batching Scheduler) and the CPU test suite run the exact
same model code that shards on a production mesh.  The DSE side reuses
the same logical axes for its 2-D scenario x island meshes
(``core.explorer.run_islands_multi``), and ``mamba``'s fused Pallas
scan wraps itself in ``repro.dist.compat.shard_map`` with specs
resolved through the active context.  See docs/architecture.md.
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Tuple[str, ...]
LogicalDims = Sequence[Optional[str]]

# Mesh axes that carry data parallelism, in nesting order (outermost
# first).  A 3-axis production mesh is ("pod", "data", "model"); a
# single pod drops "pod".
_DATA_AXES = ("pod", "data")
_MODEL_AXES = ("model",)

# Logical names that resolve to the tensor-parallel ("model") axis.
_MODEL_LOGICAL = (
    "tp", "heads", "kv_heads", "ff", "d_inner", "experts", "vocab", "seq_sp",
)


def default_rules(mesh: Mesh) -> Dict[str, Axes]:
    """Default logical->physical rules derived from the mesh axis names.

    ``batch`` (and ``fsdp``) map to every data-like axis present, in mesh
    order — on a 3-axis mesh that is the multi-axis rule
    ``("pod", "data")`` with prefix fallback handled at resolution time.
    """
    names = tuple(mesh.axis_names)
    data = tuple(a for a in _DATA_AXES if a in names)
    model = tuple(a for a in _MODEL_AXES if a in names)
    rules: Dict[str, Axes] = {"batch": data, "fsdp": data}
    for logical in _MODEL_LOGICAL:
        rules[logical] = model
    return rules


class MeshContext:
    """Resolves logical axis names against one physical mesh.

    ``exact=True`` marks a *serving* context: the program must stay
    bitwise-identical to its unsharded run, so :func:`repl_act` gathers
    activations back to replicated before every contraction over a
    sharded dim (all communication is all-gather — pure data movement).
    Training contexts leave it ``False`` and :func:`repl_act` is a no-op.
    """

    def __init__(self, mesh: Mesh, rules: Optional[Dict[str, Axes]] = None,
                 exact: bool = False):
        self.mesh = mesh
        self.rules = dict(rules) if rules is not None else default_rules(mesh)
        self.exact = bool(exact)

    # -- resolution -----------------------------------------------------------
    def _axis_size(self, axis: str) -> int:
        # Mesh.shape (name -> size) also exists on AbstractMesh, which has
        # no .devices — required for dry-runs over abstract meshes.
        return self.mesh.shape[axis]

    def _divisible_prefix(self, axes: Axes, dim: int) -> Axes:
        """Longest prefix of ``axes`` whose total size divides ``dim``."""
        for end in range(len(axes), 0, -1):
            size = 1
            for a in axes[:end]:
                size *= self._axis_size(a)
            if dim % size == 0:
                return axes[:end]
        return ()

    def axes_for(self, logical: str, dim: int) -> Optional[Axes]:
        """Mesh axes for one logical dim, or ``None`` -> replicate.

        ``None`` when the logical name has no rule, the rule names axes
        absent from this mesh, or ``dim`` is not divisible by the axis
        size (longest-divisible-prefix fallback for multi-axis rules).
        """
        axes = self.rules.get(logical)
        if not axes:
            return None
        axes = tuple(a for a in axes if a in self.mesh.axis_names)
        return self._divisible_prefix(axes, dim) or None

    def spec(self, logical_dims: LogicalDims, shape: Sequence[int]) -> P:
        """Resolve per-dim logical names into a ``PartitionSpec``.

        Raises ``ValueError`` on rank mismatch.  Each mesh axis is used
        at most once; a dim whose axes were already consumed replicates.
        """
        if len(logical_dims) != len(shape):
            raise ValueError(
                f"rank mismatch: {len(logical_dims)} logical dims "
                f"{tuple(logical_dims)} for shape {tuple(shape)}"
            )
        used: set = set()
        entries = []
        for logical, dim in zip(logical_dims, shape):
            axes = None if logical is None else self.axes_for(logical, dim)
            if axes:
                axes = tuple(a for a in axes if a not in used)
                if axes:
                    axes = self._divisible_prefix(axes, dim)
            if not axes:
                entries.append(None)
                continue
            used.update(axes)
            entries.append(axes[0] if len(axes) == 1 else axes)
        return P(*entries)

    def sharding(self, logical_dims: LogicalDims, shape: Sequence[int]) -> NamedSharding:
        # Canonicalise by dropping trailing replicated dims: jit emits
        # output shardings in this canonical form, and NamedSharding
        # equality is structural, so a device_put placement built with
        # the full-rank spec would MISS the jit cache the first time a
        # program sees a jit-produced array in that slot (one spurious
        # recompile per program whose first call saw the fresh pool).
        entries = tuple(self.spec(logical_dims, shape))
        while entries and entries[-1] is None:
            entries = entries[:-1]
        return NamedSharding(self.mesh, P(*entries))


# ------------------------------ active context --------------------------------
class _ContextStack(threading.local):
    def __init__(self):
        self.stack = []


_ACTIVE = _ContextStack()


def current() -> Optional[MeshContext]:
    """The innermost active :class:`MeshContext`, or ``None``."""
    return _ACTIVE.stack[-1] if _ACTIVE.stack else None


@contextlib.contextmanager
def use_mesh(mesh, rules: Optional[Dict[str, Axes]] = None):
    """Install ``mesh`` (or an existing ``MeshContext``) as the active
    context consumed by :func:`shard_act` / :func:`current`."""
    ctx = mesh if isinstance(mesh, MeshContext) else MeshContext(mesh, rules)
    _ACTIVE.stack.append(ctx)
    try:
        yield ctx
    finally:
        _ACTIVE.stack.pop()


def shard_act(x, logical_dims: LogicalDims):
    """Constrain ``x`` to the active context's resolution of
    ``logical_dims``; exact identity no-op when no context is installed."""
    ctx = current()
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, ctx.sharding(logical_dims, x.shape)
    )


def repl_act(x):
    """Gather ``x`` back to fully replicated under an ``exact`` (serving)
    context; identity otherwise.

    Exact tensor parallelism never lets a *contracted* dim stay sharded:
    a sharded contraction would finish with an all-reduce whose partial
    sums associate differently than the single-device dot, breaking
    bitwise identity.  Model code calls this immediately before every
    contraction over a potentially-sharded dim (attention output
    projection, FFN down projection, the MoE combine, the logits
    consumed by sampling) so the only collective the partitioner can
    emit there is an all-gather of the operand — exact data movement.
    """
    ctx = current()
    if ctx is None or not ctx.exact:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P())
    )


# ------------------------------ parameter rules --------------------------------
def _path_str(path) -> str:
    """jax key-path -> "a/b/0/c" string (DictKey/SequenceKey/GetAttrKey)."""
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:  # pragma: no cover - future key types
            parts.append(str(p))
    return "/".join(parts)


# Ordered (pattern, base logical axes) — first match wins.  ``base`` is
# the logical layout at the parameter's natural rank; a scan-stacked
# leaf (rank + 1, stacked over layer groups) gets a leading ``None``.
PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    # attention projections (GQA + MLA low-rank factors)
    (r"(?:^|/)(?:wq|wk|wv|q_a|q_b|kv_a|kv_b)/w$", ("fsdp", "tp")),
    (r"(?:^|/)wo/w$", ("tp", "fsdp")),
    # dense FFN (leaf dicts with /w) — includes MoE shared experts
    (r"(?:^|/)(?:w_up|w_gate)/w$", ("fsdp", "ff")),
    (r"(?:^|/)w_down/w$", ("ff", "fsdp")),
    # MoE expert banks: (E, d_model, d_ff) / (E, d_ff, d_model) — E on
    # the model axis, d_ff on the data axes (fully sharded, §Perf I6)
    (r"(?:^|/)(?:w_gate|w_up)$", ("experts", None, "fsdp")),
    (r"(?:^|/)w_down$", ("experts", "fsdp", None)),
    (r"(?:^|/)router/w$", ("fsdp", None)),
    # embedding / unembedding
    (r"(?:^|/)embed/w$", ("vocab", "fsdp")),
    (r"(?:^|/)head/w$", ("fsdp", "vocab")),
    # mamba mixer
    (r"(?:^|/)in_proj/w$", ("fsdp", "tp")),
    (r"(?:^|/)x_proj/w$", ("tp", None)),
    (r"(?:^|/)dt_proj/w$", (None, "tp")),
    (r"(?:^|/)out_proj/w$", ("tp", "fsdp")),
    (r"(?:^|/)conv_w$", ("tp", None)),
    (r"(?:^|/)A_log$", ("tp", None)),
    # MTP combiner
    (r"(?:^|/)proj/w$", ("fsdp", None)),
)
_PARAM_RULES = tuple((re.compile(pat), base) for pat, base in PARAM_RULES)


def logical_for_path(path: str, ndim: int) -> Tuple[Optional[str], ...]:
    """Logical axes for a parameter path at a given rank.

    Unmatched paths — norms, biases, raw optimizer-moment paths like
    ``.../w_gate/m`` (the caller strips moment suffixes first, see
    ``launch.dryrun.state_shardings``) — replicate.  A matched rule with
    an unreconcilable rank also replicates.
    """
    for pat, base in _PARAM_RULES:
        if pat.search(path):
            if ndim == len(base):
                return tuple(base)
            if ndim == len(base) + 1:  # scan-stacked over layer groups
                return (None,) + tuple(base)
            break
    return (None,) * ndim


def param_sharding_tree(shape_tree, mesh: Mesh, rules: Optional[Dict[str, Axes]] = None):
    """Map :func:`logical_for_path` over a param (shape) pytree into
    ``NamedSharding``s on ``mesh`` — the ``device_put`` layout for a
    freshly-initialized model and the dry-run's param shardings."""
    ctx = MeshContext(mesh, rules)

    def one(path, leaf):
        logical = logical_for_path(_path_str(path), len(leaf.shape))
        return ctx.sharding(logical, leaf.shape)

    return jax.tree_util.tree_map_with_path(one, shape_tree)


# ------------------------------ exact serving rules ----------------------------
# Logical names a *serving* mesh resolves — only non-contracting output
# dims.  ``tp``/``d_inner``/``batch``/``seq_sp``/``fsdp`` are deliberately
# absent: every existing shard_act annotation that names them resolves to
# replicated under a serving context, which is exactly what bitwise
# identity with the single-device program requires (see SERVE_PARAM_RULES).
_SERVE_MODEL_LOGICAL = ("heads", "kv_heads", "ff", "experts", "vocab")


def serve_rules(mesh: Mesh) -> Dict[str, Axes]:
    """Logical->physical rules for an exact tensor/expert-parallel
    serving mesh (axis name ``"model"``)."""
    names = tuple(mesh.axis_names)
    model = tuple(a for a in _MODEL_AXES if a in names)
    return {logical: model for logical in _SERVE_MODEL_LOGICAL}


def serving_context(mesh: Mesh) -> MeshContext:
    """The exact-serving :class:`MeshContext` for ``mesh``."""
    return MeshContext(mesh, rules=serve_rules(mesh), exact=True)


# Serving parameter layout (ordered, first match wins; unmatched ->
# replicate).  Only *output* dims shard, so every matmul contracts over a
# replicated dim and each output element is the same full-length dot
# product the single-device program computes — no partial-sum
# all-reduces anywhere, hence bitwise-exact decode.  Deliberately
# replicated (their outputs feed a contraction the activation side
# re-gathers anyway, or sharding them would break exactness):
#   * ``wo`` / dense ``w_down`` / MoE bank ``w_down`` output d_model;
#   * ``embed`` (token gather + tied-unembedding families);
#   * MLA factors: the absorbed attend contracts kv_lora_rank, so MLA
#     attention stays replicated — MLA+MoE families (deepseek) get their
#     parallelism from the expert banks;
#   * all mamba parameters (x_proj/out_proj contract d_inner).
SERVE_PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    (r"(?:^|/)wq/w$", (None, "heads")),
    (r"(?:^|/)(?:wk|wv)/w$", (None, "kv_heads")),
    (r"(?:^|/)(?:w_up|w_gate)/w$", (None, "ff")),
    (r"(?:^|/)(?:w_gate|w_up)$", ("experts", None, "ff")),
    (r"(?:^|/)w_down$", ("experts", None, None)),
    (r"(?:^|/)head/w$", (None, "vocab")),
)
_SERVE_PARAM_RULES = tuple(
    (re.compile(pat), base) for pat, base in SERVE_PARAM_RULES
)


def serve_logical_for_path(path: str, ndim: int) -> Tuple[Optional[str], ...]:
    """Serving logical axes for a parameter path (rank + 1 leaves are
    scan-stacked over layer groups, as in :func:`logical_for_path`)."""
    for pat, base in _SERVE_PARAM_RULES:
        if pat.search(path):
            if ndim == len(base):
                return tuple(base)
            if ndim == len(base) + 1:
                return (None,) + tuple(base)
            break
    return (None,) * ndim


def serve_param_sharding_tree(shape_tree, mesh: Mesh):
    """``NamedSharding`` per parameter for exact serving on ``mesh``."""
    ctx = serving_context(mesh)

    def one(path, leaf):
        logical = serve_logical_for_path(_path_str(path), len(leaf.shape))
        return ctx.sharding(logical, leaf.shape)

    return jax.tree_util.tree_map_with_path(one, shape_tree)


# Paged-pool leaves by key.  GQA K/V pages are (groups, n_pages, page,
# n_kv, hd) — sharded over kv heads, the one big serving buffer that
# scales down per-device.  MLA latent pages contract kv_lora_rank in the
# absorbed attend and SSM states feed elementwise recurrences whose
# surrounding projections contract d_inner: both replicate.
_SERVE_POOL_LOGICAL: Dict[str, Tuple[Optional[str], ...]] = {
    "k": (None, None, None, "kv_heads", None),
    "v": (None, None, None, "kv_heads", None),
}


def _pool_logical(path, ndim: int) -> Tuple[Optional[str], ...]:
    key = ""
    for p in reversed(path):
        if hasattr(p, "key"):
            key = str(p.key)
            break
    logical = _SERVE_POOL_LOGICAL.get(key, (None,) * ndim)
    if len(logical) != ndim:
        logical = (None,) * ndim
    return logical


def serve_pool_sharding_tree(shape_tree, mesh: Mesh):
    """``NamedSharding`` per paged-pool leaf for exact serving."""
    ctx = serving_context(mesh)

    def one(path, leaf):
        return ctx.sharding(_pool_logical(path, len(leaf.shape)), leaf.shape)

    return jax.tree_util.tree_map_with_path(one, shape_tree)


def constrain_pool(pool):
    """Pin a cache pool RETURNED by a jitted serve program to the same
    layout :func:`serve_pool_sharding_tree` committed its input to.

    Without this the partitioner is free to hand the (donated) pool back
    in whatever layout it liked best internally; the session rebinds the
    result as the next call's input, whose sharding then differs from
    the traced one — a recompile per step, and a different layout again
    the step after.  No-op outside an exact serving context."""
    ctx = current()
    if ctx is None or not ctx.exact:
        return pool

    def one(path, leaf):
        return jax.lax.with_sharding_constraint(
            leaf, ctx.sharding(_pool_logical(path, leaf.ndim), leaf.shape)
        )

    return jax.tree_util.tree_map_with_path(one, pool)
