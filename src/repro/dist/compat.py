"""Version-compat shims for jax distribution APIs.

``shard_map`` moved from ``jax.experimental.shard_map`` (where its
replication-check kwarg is ``check_rep``) to top-level ``jax.shard_map``
(where the kwarg was renamed ``check_vma``).  All repo code routes
through this wrapper so either jax generation works.
"""
from __future__ import annotations

from typing import Any, Callable

try:  # jax >= 0.5-ish: top-level export, kwarg is check_vma
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]

    _CHECK_KW = "check_vma"
except ImportError:  # jax 0.4.x: experimental module, kwarg is check_rep
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KW = "check_rep"


def shard_map(
    f: Callable,
    *,
    mesh: Any,
    in_specs: Any,
    out_specs: Any,
    check_vma: bool | None = None,
    **kwargs: Any,
):
    """``jax.shard_map`` with the ``check_vma``/``check_rep`` rename papered
    over.  ``check_vma=None`` leaves the jax default in place."""
    if check_vma is not None:
        kwargs[_CHECK_KW] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)
