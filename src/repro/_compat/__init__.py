"""Fallback shims for optional third-party test dependencies."""
