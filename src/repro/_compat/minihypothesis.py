"""Minimal, dependency-free fallback for the ``hypothesis`` API surface
this repo's tests use.

The real `hypothesis` package is declared in ``pyproject.toml`` and is
always preferred; :func:`install` is only called (from
``tests/conftest.py``) when it cannot be imported, so hermetic
environments without it can still collect and run the property tests.
The fallback is a plain deterministic fuzzer: each ``@given`` test runs
``max_examples`` times against examples drawn from a per-test seeded
``numpy`` generator.  No shrinking, no example database — failures
reproduce exactly (the seed is derived from the test's qualname) but
are not minimized.
"""
from __future__ import annotations

import functools
import inspect
import sys
import types
import zlib
from typing import Any, Callable, Sequence

import numpy as np

_FILTER_TRIES = 1000


class _Assume(Exception):
    """Raised by ``assume(False)`` — the example is silently discarded."""


class Strategy:
    def __init__(self, draw: Callable[[np.random.Generator], Any]):
        self._draw = draw

    def filter(self, pred: Callable[[Any], bool]) -> "Strategy":
        def draw(rng):
            for _ in range(_FILTER_TRIES):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError("minihypothesis: filter predicate too strict")

        return Strategy(draw)

    def map(self, fn: Callable[[Any], Any]) -> "Strategy":
        return Strategy(lambda rng: fn(self._draw(rng)))


# ------------------------------ strategies ------------------------------------
def integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(
    min_value: float = 0.0,
    max_value: float = 1.0,
    *,
    width: int = 64,
    allow_subnormal: bool = True,
    allow_nan: bool = False,
    allow_infinity: bool = False,
) -> Strategy:
    del allow_subnormal, allow_nan, allow_infinity  # uniform draws avoid all

    def draw(rng):
        # Occasionally hit the endpoints — the classic boundary bugs.
        r = rng.random()
        if r < 0.05:
            v = float(min_value)
        elif r < 0.1:
            v = float(max_value)
        else:
            v = float(rng.uniform(min_value, max_value))
        return float(np.float32(v)) if width == 32 else v

    return Strategy(draw)


def booleans() -> Strategy:
    return Strategy(lambda rng: bool(rng.integers(2)))


def sampled_from(elements: Sequence[Any]) -> Strategy:
    elements = list(elements)
    return Strategy(lambda rng: elements[int(rng.integers(len(elements)))])


def just(value: Any) -> Strategy:
    return Strategy(lambda rng: value)


def tuples(*strategies: Strategy) -> Strategy:
    return Strategy(lambda rng: tuple(s._draw(rng) for s in strategies))


def lists(elements: Strategy, *, min_size: int = 0, max_size: int = 10) -> Strategy:
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements._draw(rng) for _ in range(n)]

    return Strategy(draw)


# --------------------------- hypothesis.extra.numpy ----------------------------
def array_shapes(
    *, min_dims: int = 1, max_dims: int = 3, min_side: int = 1, max_side: int = 10
) -> Strategy:
    def draw(rng):
        nd = int(rng.integers(min_dims, max_dims + 1))
        return tuple(int(rng.integers(min_side, max_side + 1)) for _ in range(nd))

    return Strategy(draw)


def arrays(dtype, shape, *, elements: Strategy = None, fill=None, unique=False) -> Strategy:
    del fill, unique

    def draw(rng):
        shp = shape._draw(rng) if isinstance(shape, Strategy) else tuple(shape)
        n = int(np.prod(shp)) if shp else 1
        if elements is not None:
            vals = [elements._draw(rng) for _ in range(n)]
        elif np.issubdtype(np.dtype(dtype), np.integer):
            info = np.iinfo(np.dtype(dtype))
            vals = rng.integers(info.min, info.max, size=n, endpoint=True)
        else:
            vals = rng.random(n)
        return np.asarray(vals, dtype=dtype).reshape(shp)

    return Strategy(draw)


# ------------------------------ runner ----------------------------------------
class settings:
    """Decorator/settings object; only ``max_examples`` is honored."""

    def __init__(self, max_examples: int = 50, deadline=None, **_ignored):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        fn._mh_settings = self
        return fn


def assume(condition) -> None:
    if not condition:
        raise _Assume


def given(**strategy_kwargs: Strategy):
    if not strategy_kwargs:
        raise TypeError("minihypothesis: @given requires keyword strategies")

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_mh_settings", None)
            n = cfg.max_examples if cfg is not None else 20
            rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
            executed = 0
            for _ in range(n):
                drawn = {k: s._draw(rng) for k, s in strategy_kwargs.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                    executed += 1
                except _Assume:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"minihypothesis: falsifying example {drawn!r}"
                    ) from e
            if executed == 0:
                raise AssertionError(
                    f"minihypothesis: assume() discarded all {n} examples"
                )

        # Hide the drawn parameters from pytest's fixture resolution.
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(
            parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategy_kwargs
            ]
        )
        return wrapper

    return decorate


class HealthCheck:
    all = staticmethod(lambda: [])
    too_slow = data_too_large = filter_too_much = None


# ------------------------------ installer --------------------------------------
def install() -> None:
    """Register this module under the ``hypothesis`` import names.  Call
    only when the real package is absent; a no-op if already installed."""
    if "hypothesis" in sys.modules:
        return

    st_mod = types.ModuleType("hypothesis.strategies")
    for name in (
        "integers", "floats", "booleans", "sampled_from", "just", "tuples",
        "lists",
    ):
        setattr(st_mod, name, globals()[name])

    hnp_mod = types.ModuleType("hypothesis.extra.numpy")
    hnp_mod.arrays = arrays
    hnp_mod.array_shapes = array_shapes

    extra_mod = types.ModuleType("hypothesis.extra")
    extra_mod.numpy = hnp_mod

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = HealthCheck
    hyp.strategies = st_mod
    hyp.extra = extra_mod
    hyp.__version__ = "0.0-minihypothesis"

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod
    sys.modules["hypothesis.extra"] = extra_mod
    sys.modules["hypothesis.extra.numpy"] = hnp_mod
