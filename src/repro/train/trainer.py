"""Fault-tolerant training loop.

Production behaviors, all exercised by tests:
  * periodic (optionally async) checkpoints incl. the data cursor,
  * auto-resume from the latest checkpoint (crash/preemption restart),
  * preemption signal (SIGTERM/SIGINT) -> final checkpoint + clean exit,
  * straggler watchdog: per-step wall time tracked against a rolling
    median; outliers are logged and counted (on real fleets this signal
    feeds the reschedule policy; here it is surfaced in metrics).
"""
from __future__ import annotations

import dataclasses
import signal
import statistics
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from . import checkpoint as ckpt


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep_last: int = 3
    async_ckpt: bool = False
    log_every: int = 10
    straggler_factor: float = 3.0     # step > factor * median -> straggler
    straggler_window: int = 20


class Trainer:
    def __init__(
        self,
        state: Any,
        step_fn: Callable,
        dataset,
        tcfg: TrainerConfig,
        batch_transform: Optional[Callable] = None,
        jit: bool = True,
    ):
        self.state = state
        self.step_fn = jax.jit(step_fn) if jit else step_fn
        self.dataset = dataset
        self.tcfg = tcfg
        self.batch_transform = batch_transform or (lambda b: b)
        self.history: List[Dict[str, float]] = []
        self.step_times: List[float] = []
        self.stragglers = 0
        self._stop = False
        self._ckpt_thread = None

    # --- fault tolerance -----------------------------------------------------
    def _install_signals(self):
        def handler(signum, frame):
            self._stop = True

        try:
            signal.signal(signal.SIGTERM, handler)
            signal.signal(signal.SIGINT, handler)
        except ValueError:      # non-main thread (tests)
            pass

    def maybe_resume(self) -> int:
        if not self.tcfg.ckpt_dir:
            return 0
        last = ckpt.latest_step(self.tcfg.ckpt_dir)
        if last is None:
            return 0
        self.state, extras, step = ckpt.restore(
            self.tcfg.ckpt_dir, last, self.state
        )
        if "data_state" in extras and hasattr(self.dataset, "restore"):
            self.dataset.restore(extras["data_state"])
        return step

    def _checkpoint(self, step: int):
        if not self.tcfg.ckpt_dir:
            return
        if self._ckpt_thread is not None:
            self._ckpt_thread.join()
        extras = {"data_state": self.dataset.state()} if hasattr(
            self.dataset, "state") else {}
        self._ckpt_thread = ckpt.save(
            self.tcfg.ckpt_dir, step, self.state, extras,
            keep_last=self.tcfg.keep_last, async_write=self.tcfg.async_ckpt,
        )

    # --- straggler watchdog ----------------------------------------------------
    def _watch(self, dt: float) -> bool:
        self.step_times.append(dt)
        win = self.step_times[-self.tcfg.straggler_window:]
        if len(win) >= 5:
            med = statistics.median(win)
            if dt > self.tcfg.straggler_factor * med:
                self.stragglers += 1
                return True
        return False

    # --- loop --------------------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        self._install_signals()
        start = self.maybe_resume()
        step = start
        while step < self.tcfg.total_steps and not self._stop:
            batch = self.batch_transform(self.dataset.next_batch())
            t0 = time.perf_counter()
            self.state, metrics = self.step_fn(self.state, batch)
            loss = float(np.asarray(metrics["loss"]))
            dt = time.perf_counter() - t0
            straggle = self._watch(dt)
            step += 1
            if step % self.tcfg.log_every == 0 or step == self.tcfg.total_steps:
                self.history.append(
                    {"step": step, "loss": loss, "sec": dt,
                     "straggler": bool(straggle)}
                )
            if self.tcfg.ckpt_dir and step % self.tcfg.ckpt_every == 0:
                self._checkpoint(step)
        # Final (or preemption) checkpoint.
        self._checkpoint(step)
        if self._ckpt_thread is not None:
            self._ckpt_thread.join()
        return {
            "final_step": step,
            "interrupted": self._stop,
            "history": self.history,
            "stragglers": self.stragglers,
        }
