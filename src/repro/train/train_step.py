"""Train-step factory: loss -> grads -> optimizer, as one pure function
suitable for jit/pjit with sharded state.

TrainState is a plain dict pytree (checkpoint-friendly):
  {"params": ..., "opt": ..., "step": int32[, "err": error-feedback tree]}
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import LMConfig
from repro.optim import get_optimizer
from repro.optim.adamw import Transform, apply_updates
from repro.optim.grad_compress import compress_decompress, init_error_state


def init_state(key, cfg: LMConfig, opt: Transform, grad_compression: Optional[str] = None):
    params = lm.init(key, cfg)
    state = {
        "params": params,
        "opt": opt.init(params),
        "step": jnp.zeros((), jnp.int32),
    }
    if grad_compression == "int8":
        state["err"] = init_error_state(params)
    return state


def make_train_step(
    cfg: LMConfig,
    opt: Transform,
    grad_compression: Optional[str] = None,
    grad_clip: float = 1.0,
) -> Callable:
    """Returns step(state, batch) -> (state, metrics)."""

    def step(state, batch):
        def loss_of(p):
            return lm.loss_fn(p, batch, cfg)

        (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(
            state["params"]
        )

        new_err = state.get("err")
        if grad_compression == "int8":
            # Wire-precision emulation under pjit: quantize+dequantize with
            # error feedback (the explicit ring collective lives in
            # optim.grad_compress.ring_allreduce_int8 for shard_map mode).
            flat_g, td = jax.tree.flatten(grads)
            flat_e = td.flatten_up_to(state["err"])
            outs = [compress_decompress(g, e) for g, e in zip(flat_g, flat_e)]
            grads = td.unflatten([o[0] for o in outs])
            new_err = td.unflatten([o[1] for o in outs])

        # Global-norm clipping.
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads))
        )
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

        updates, new_opt = opt.update(grads, state["opt"], state["params"])
        new_params = apply_updates(state["params"], updates)
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        if new_err is not None:
            new_state["err"] = new_err
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return new_state, metrics

    return step


def build(cfg: LMConfig, optimizer: str = "adamw", lr=3e-4,
          grad_compression: Optional[str] = None, seed: int = 0, **opt_kw):
    opt = get_optimizer(optimizer, lr, **opt_kw)
    state = init_state(jax.random.PRNGKey(seed), cfg, opt, grad_compression)
    return state, make_train_step(cfg, opt, grad_compression)
