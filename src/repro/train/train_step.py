"""Train-step factory: loss -> grads -> optimizer, as one pure function
suitable for jit/pjit with sharded state.

TrainState is a plain dict pytree (checkpoint-friendly):
  {"params": ..., "opt": ..., "step": int32[, "err": error-feedback tree]}
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import common, lm
from repro.models.config import LMConfig
from repro.optim import get_optimizer
from repro.optim.adamw import Transform, apply_updates
from repro.optim.grad_compress import compress_decompress, init_error_state


def init_state(key, cfg: LMConfig, opt: Transform, grad_compression: Optional[str] = None):
    params = lm.init(key, cfg)
    state = {
        "params": params,
        "opt": opt.init(params),
        "step": jnp.zeros((), jnp.int32),
    }
    if grad_compression == "int8":
        state["err"] = init_error_state(params)
    return state


def make_train_step(
    cfg: LMConfig,
    opt: Transform,
    grad_compression: Optional[str] = None,
    grad_clip: float = 1.0,
) -> Callable:
    """Returns step(state, batch) -> (state, metrics)."""

    def step(state, batch):
        def loss_of(p):
            return lm.loss_fn(p, batch, cfg)

        (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(
            state["params"]
        )

        new_err = state.get("err")
        if grad_compression == "int8":
            # Wire-precision emulation under pjit: quantize+dequantize with
            # error feedback (the explicit ring collective lives in
            # optim.grad_compress.ring_allreduce_int8 for shard_map mode).
            flat_g, td = jax.tree.flatten(grads)
            flat_e = td.flatten_up_to(state["err"])
            outs = [compress_decompress(g, e) for g, e in zip(flat_g, flat_e)]
            grads = td.unflatten([o[0] for o in outs])
            new_err = td.unflatten([o[1] for o in outs])

        # Global-norm clipping (f32 accumulation over bf16 grads — part
        # of the optimizer's declared f32 island).
        with common.precision_island("optimizer"):
            gnorm = jnp.sqrt(
                sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                    for g in jax.tree.leaves(grads))
            )
            scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

        updates, new_opt = opt.update(grads, state["opt"], state["params"])
        new_params = apply_updates(state["params"], updates)
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        if new_err is not None:
            new_state["err"] = new_err
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return new_state, metrics

    return step


def build(cfg: LMConfig, optimizer: str = "adamw", lr=3e-4,
          grad_compression: Optional[str] = None, seed: int = 0, **opt_kw):
    opt = get_optimizer(optimizer, lr, **opt_kw)
    state = init_state(jax.random.PRNGKey(seed), cfg, opt, grad_compression)
    return state, make_train_step(cfg, opt, grad_compression)


# ------------------------------ lint contract --------------------------------
from repro.analysis.registry import Built, Replay, register_contract


@register_contract(
    "train.train_step",
    checks=("donation", "transfers", "recompile", "precision"),
    description="jitted train step at a smoke config with bf16 "
                "params/compute: the donated TrainState must alias "
                "output state leaf-for-leaf, repeated same-shape steps "
                "must not retrace, the state-rebinding loop must run "
                "clean under a transfer guard, and the traced step must "
                "satisfy the bf16 policy — f32 only inside the declared "
                "islands (norm/rope/attn/logits/xent and the optimizer's "
                "f32 moments), every low-precision dot accumulating at "
                "f32",
)
def _build_train_step_contract() -> Built:
    import dataclasses

    from repro import configs
    from repro.analysis.jaxpr_tools import canonical_signature, compile_unit
    from repro.analysis.registry import PrecisionPolicy

    # bf16 params + compute (the production mixed-precision recipe: f32
    # optimizer moments over bf16 weights) — this is the config the
    # widening audit has teeth at, since every f32 region must then be a
    # declared island.
    cfg = configs.get_smoke_config("qwen2.5-3b")
    cfg = dataclasses.replace(
        cfg, param_dtype="bfloat16", compute_dtype="bfloat16",
    )
    opt = get_optimizer("adamw", 1e-3)
    state = init_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))

    B, S = 2, 16
    def batch_of(seed: int):
        key = jax.random.PRNGKey(seed)
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
        return {
            "tokens": toks,
            "targets": jnp.roll(toks, -1, axis=1),
            "loss_mask": jnp.ones((B, S), jnp.float32),
        }

    unit = compile_unit(
        "train_step", step, (state, batch_of(0)), donate_argnums=(0,)
    )

    # Replay: two same-shape steps through the REAL jit, rebinding the
    # donated state, then compare the live cache size to the budget.
    signatures = []
    holder = {"state": state}
    for i in range(2):
        batch = batch_of(i)
        signatures.append(
            ("train_step", canonical_signature((holder["state"], batch)))
        )
        holder["state"], _ = step(holder["state"], batch)
    replay = Replay(
        signatures=signatures,
        max_programs={"train_step": 1},
        live_counts={"train_step": int(step._cache_size())},
        live_budget={"train_step": 1},
    )

    hot_batch = batch_of(2)  # PRNGKey(int) transfers its seed: keep it
    # outside the guarded hot path — only the step call is under test.

    def hot():
        new_state, metrics = step(holder["state"], hot_batch)
        holder["state"] = new_state
        return jax.block_until_ready(metrics["loss"])

    step_jaxpr = jax.make_jaxpr(make_train_step(cfg, opt))(
        holder["state"], hot_batch
    )
    return Built(
        compiled=[unit], hot=hot, hot_label="train_step call", replay=replay,
        hot_jaxprs=[("train_step", step_jaxpr)],
        precision=PrecisionPolicy(compute_dtype=cfg.compute_dtype),
    )
