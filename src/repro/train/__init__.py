from . import checkpoint  # noqa: F401
from .train_step import build, init_state, make_train_step  # noqa: F401
from .trainer import Trainer, TrainerConfig  # noqa: F401
