"""Sharded, elastic, async checkpointing.

Layout: ``<dir>/step_<N>/`` containing one ``.npy`` per pytree leaf
(named by its tree path hash) plus ``index.json`` with the tree
structure, shapes/dtypes, data-pipeline cursor, and the mesh shape the
run used.  Restore is *elastic*: arrays are stored logically (unsharded)
and re-placed under the restoring mesh's shardings, so a checkpoint
written on a (16,16) mesh restores onto (2,16,16) or a single CPU device
unchanged.

Writes are atomic (tmp dir + rename) and optionally async (background
thread); ``keep_last`` old checkpoints are garbage-collected.
"""
from __future__ import annotations

import hashlib
import json
import pathlib
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _leaf_name(path_str: str) -> str:
    return hashlib.sha1(path_str.encode()).hexdigest()[:16] + ".npy"


def _paths_and_leaves(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        pstr = jax.tree_util.keystr(path)
        out.append((pstr, leaf))
    return out, treedef


def save(
    directory,
    step: int,
    tree: Any,
    extras: Optional[dict] = None,
    keep_last: int = 3,
    async_write: bool = False,
):
    """Serialize ``tree`` (params/opt state/...) + ``extras`` metadata."""
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    flat, _ = _paths_and_leaves(tree)
    # Materialize on host before handing to the writer thread.
    host = [(p, np.asarray(jax.device_get(l))) for p, l in flat]

    def _write():
        tmp = directory / f".tmp_step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        index = {"step": step, "extras": extras or {}, "leaves": []}
        for pstr, arr in host:
            fname = _leaf_name(pstr)
            np.save(tmp / fname, arr)
            index["leaves"].append(
                {"path": pstr, "file": fname, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)}
            )
        (tmp / "index.json").write_text(json.dumps(index))
        final = directory / f"step_{step:08d}"
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        _gc(directory, keep_last)

    if async_write:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _gc(directory: pathlib.Path, keep_last: int):
    ckpts = sorted(directory.glob("step_*"))
    for old in ckpts[:-keep_last]:
        shutil.rmtree(old, ignore_errors=True)


def latest_step(directory) -> Optional[int]:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    ckpts = sorted(directory.glob("step_*"))
    if not ckpts:
        return None
    return int(ckpts[-1].name.split("_")[1])


def restore(directory, step: int, target_tree: Any, shardings: Any = None):
    """Load into the structure of ``target_tree``; if ``shardings`` (same
    structure) is given, arrays are device_put with them — this is the
    elastic re-shard path."""
    d = pathlib.Path(directory) / f"step_{step:08d}"
    index = json.loads((d / "index.json").read_text())
    by_path = {e["path"]: e for e in index["leaves"]}

    flat, treedef = _paths_and_leaves(target_tree)
    shard_flat = None
    if shardings is not None:
        shard_flat = [s for _, s in _paths_and_leaves(shardings)[0]]

    leaves = []
    for i, (pstr, leaf) in enumerate(flat):
        e = by_path.get(pstr)
        if e is None:
            raise KeyError(f"checkpoint missing leaf {pstr}")
        arr = np.load(d / e["file"])
        want_dtype = np.dtype(leaf.dtype) if hasattr(leaf, "dtype") else arr.dtype
        arr = arr.astype(want_dtype, copy=False)
        if shard_flat is not None:
            leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target_tree), leaves
    )
    return tree, index["extras"], index["step"]
