"""Finding records emitted by the program-contract linter.

A :class:`Finding` is one observation about one contract by one check:
``severity`` is one of ``error`` (fails the lint), ``warning`` (reported,
does not fail) or ``info`` (context: skipped contracts, fallback notes).
Findings are plain data — JSON-serializable via :func:`to_json` — so the
CLI can persist ``results/lint.json`` and tests can assert on exact
(check, contract, severity) triples.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

SEVERITIES = ("error", "warning", "info")


@dataclasses.dataclass
class Finding:
    check: str                      # which check produced it
    contract: str                   # which contract it is about
    severity: str                   # error | warning | info
    message: str                    # one-line human summary
    data: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity must be one of {SEVERITIES}, got {self.severity!r}"
            )

    def to_json(self) -> Dict[str, Any]:
        return {
            "check": self.check,
            "contract": self.contract,
            "severity": self.severity,
            "message": self.message,
            "data": self.data,
        }


def error(check: str, contract: str, message: str, **data) -> Finding:
    return Finding(check, contract, "error", message, data)


def warning(check: str, contract: str, message: str, **data) -> Finding:
    return Finding(check, contract, "warning", message, data)


def info(check: str, contract: str, message: str, **data) -> Finding:
    return Finding(check, contract, "info", message, data)


@dataclasses.dataclass
class Report:
    """Outcome of one lint run: every finding plus what actually executed
    (a check that never ran cannot have passed)."""
    findings: List[Finding] = dataclasses.field(default_factory=list)
    checks_executed: List[str] = dataclasses.field(default_factory=list)
    contracts_executed: List[str] = dataclasses.field(default_factory=list)
    backend: Optional[str] = None
    # Wall-clock seconds per unit of work: "<contract>:build" for the
    # contract build, "<contract>:<check>" for each check run on it.
    timings: Dict[str, float] = dataclasses.field(default_factory=dict)

    def extend(self, findings: List[Finding]) -> None:
        self.findings.extend(findings)

    def by_severity(self, severity: str) -> List[Finding]:
        return [f for f in self.findings if f.severity == severity]

    @property
    def ok(self) -> bool:
        return not self.by_severity("error")

    def summary(self) -> Dict[str, int]:
        return {s: len(self.by_severity(s)) for s in SEVERITIES}

    def to_json(self) -> Dict[str, Any]:
        return {
            "ok": self.ok,
            "backend": self.backend,
            "summary": self.summary(),
            "checks_executed": sorted(set(self.checks_executed)),
            "contracts_executed": sorted(set(self.contracts_executed)),
            "timings": {k: round(v, 3) for k, v in sorted(self.timings.items())},
            "findings": [f.to_json() for f in self.findings],
        }
