"""Program-contract lint runner + CLI.

Usage::

  PYTHONPATH=src python -m repro.analysis.lint --all
  PYTHONPATH=src python -m repro.analysis.lint --check donation --check pallas
  PYTHONPATH=src python -m repro.analysis.lint --list

``--all`` builds every registered contract at its miniature
configuration, runs the checks each contract declares, writes
``results/lint.json`` and exits nonzero on any ``error`` finding.
"""
import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    # Give the SPMD contract a real multi-device platform.  Must happen
    # before jax initializes; only when executed as a CLI — importing
    # this module from an already-running process never mutates its env.
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse
import json
import pathlib
import time
from typing import List, Optional, Sequence

from . import CHECKS, CONTRACTS, load_builtin_checks
from .findings import Finding, Report
from .registry import ContractSkip


def _load_all() -> None:
    from . import contracts

    load_builtin_checks()
    contracts.load_contracts()


def run_lint(
    checks: Optional[Sequence[str]] = None,
    contracts: Optional[Sequence[str]] = None,
) -> Report:
    """Build the selected contracts and run their selected checks."""
    import jax

    _load_all()
    want_checks = set(checks) if checks else set(CHECKS)
    want_contracts = set(contracts) if contracts else set(CONTRACTS)
    unknown = (want_checks - set(CHECKS)) | (want_contracts - set(CONTRACTS))
    if unknown:
        raise ValueError(
            f"unknown checks/contracts: {sorted(unknown)}; "
            f"known checks {sorted(CHECKS)}, contracts {sorted(CONTRACTS)}"
        )

    report = Report(backend=jax.default_backend())
    for name in sorted(want_contracts):
        contract = CONTRACTS[name]
        selected = [c for c in contract.checks if c in want_checks]
        if not selected:
            continue
        t_build = time.perf_counter()
        try:
            built = contract.build()
        except ContractSkip as e:
            report.findings.append(Finding(
                "contract", name, "info", f"skipped: {e}"))
            continue
        except Exception as e:
            # A contract that cannot even build is a lint failure: the
            # miniature program it describes no longer constructs.
            report.findings.append(Finding(
                "contract", name, "error",
                f"contract build failed: {type(e).__name__}: {e}"))
            continue
        finally:
            report.timings[f"{name}:build"] = time.perf_counter() - t_build
        report.contracts_executed.append(name)
        for check in selected:
            t_check = time.perf_counter()
            try:
                found = CHECKS[check](name, built)
            except Exception as e:
                found = [Finding(
                    check, name, "error",
                    f"check crashed: {type(e).__name__}: {e}")]
            report.timings[f"{name}:{check}"] = time.perf_counter() - t_check
            report.checks_executed.append(check)
            report.extend(found)
    return report


BENCH_PATH = "BENCH_lint.json"    # repo root, committed like BENCH_dse.json
BUDGET_FACTOR = 2.0


def check_runtime_budget(
    report: Report, wall_s: float, bench_path: str = BENCH_PATH,
    record: bool = True,
) -> Optional[str]:
    """Compare a full run's wall time to the recorded baseline.

    First full run records ``bench_path``; later runs fail (return an
    error string) when total wall time exceeds ``BUDGET_FACTOR`` x the
    baseline — a regression guard on the lint suite itself, so a new
    check or contract cannot silently double CI time.  Returns None when
    within budget.
    """
    bench = pathlib.Path(bench_path)
    if not bench.exists():
        if record:
            bench.parent.mkdir(parents=True, exist_ok=True)
            bench.write_text(json.dumps({
                "total_wall_s": round(wall_s, 2),
                "timings": {k: round(v, 3)
                            for k, v in sorted(report.timings.items())},
            }, indent=2, sort_keys=True) + "\n")
        return None
    baseline = float(json.loads(bench.read_text())["total_wall_s"])
    budget = BUDGET_FACTOR * baseline
    if wall_s > budget:
        return (
            f"lint runtime {wall_s:.1f}s exceeds budget {budget:.1f}s "
            f"({BUDGET_FACTOR}x recorded baseline {baseline:.1f}s in "
            f"{bench_path}); speed the suite up or re-record the baseline"
        )
    return None


def _print_timings(report: Report, wall_s: float) -> None:
    per_contract: dict = {}
    for key, secs in report.timings.items():
        contract, _, _phase = key.partition(":")
        per_contract[contract] = per_contract.get(contract, 0.0) + secs
    print("runtime per contract (build + checks):")
    for contract, secs in sorted(
        per_contract.items(), key=lambda kv: -kv[1]
    ):
        print(f"  {secs:7.2f}s  {contract}")
    print(f"  {wall_s:7.2f}s  total wall")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="static program-contract lint over jaxprs + compiled HLO",
    )
    ap.add_argument("--all", action="store_true",
                    help="run every check on every contract")
    ap.add_argument("--check", action="append", default=[],
                    help="run only this check (repeatable)")
    ap.add_argument("--contract", action="append", default=[],
                    help="run only this contract (repeatable)")
    ap.add_argument("--list", action="store_true",
                    help="list registered checks and contracts, then exit")
    ap.add_argument("--out", default="results/lint.json",
                    help="where to write the JSON report")
    args = ap.parse_args(argv)

    if args.list:
        _load_all()
        print("checks:")
        for name in sorted(CHECKS):
            print(f"  {name}")
        print("contracts:")
        for name, c in sorted(CONTRACTS.items()):
            print(f"  {name} [{', '.join(c.checks)}] — {c.description}")
        return 0
    if not (args.all or args.check or args.contract):
        ap.print_help()
        return 2

    t0 = time.time()
    report = run_lint(
        checks=args.check or None, contracts=args.contract or None
    )
    wall_s = time.time() - t0
    payload = report.to_json()
    payload["wall_s"] = round(wall_s, 2)
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")

    for f in report.findings:
        print(f"[{f.severity:7s}] {f.check}/{f.contract}: {f.message}")
    _print_timings(report, wall_s)

    # The runtime budget is only meaningful for the full suite — partial
    # runs neither record nor enforce the baseline.
    over_budget = None
    if args.all:
        over_budget = check_runtime_budget(report, wall_s)
        if over_budget:
            print(f"[error  ] runtime/budget: {over_budget}")

    summary = report.summary()
    print(
        f"lint: {len(report.findings)} finding(s) "
        f"({summary['error']} error, {summary['warning']} warning, "
        f"{summary['info']} info) over "
        f"{len(set(report.contracts_executed))} contract(s), "
        f"{len(set(report.checks_executed))} distinct check(s); "
        f"report -> {out}"
    )
    return 0 if (report.ok and over_budget is None) else 1


if __name__ == "__main__":
    raise SystemExit(main())
