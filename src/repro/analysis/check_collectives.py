"""Collective/remat lint: no budget-blowing collectives in compiled SPMD
programs.

The SPMD partitioner's failure mode for a missing/contradictory sharding
annotation is an *involuntary rematerialization*: it all-gathers the
full replicated operand (every device materializes the global array)
instead of keeping it partitioned.  In the compiled module that
manifests as an all-gather whose per-device output is the global shape —
orders of magnitude over the halo-exchange-sized collectives a correct
partition needs.

Each compiled unit declares per-collective byte budgets
(``collective_budget``: opcode -> max per-device output bytes, 0 forbids
the opcode).  Sites come from the trip-count-aware walk in
``launch.hlo_analysis.collective_sites`` via
``analysis.remat.oversized_collectives``, so a per-step all-gather
inside a scanned layer loop is reported with its real repeat count.
"""
from __future__ import annotations

from typing import List

from .findings import Finding, error, info
from .registry import Built, register_check
from .remat import oversized_collectives

CHECK = "collectives"


@register_check(CHECK)
def run(contract: str, built: Built) -> List[Finding]:
    findings: List[Finding] = []
    for unit in built.compiled:
        if unit.collective_budget is None:
            continue
        flagged = oversized_collectives(unit.hlo, unit.collective_budget)
        for site in flagged:
            verb = ("forbidden collective" if site["budget"] == 0
                    else "oversized collective")
            findings.append(error(
                CHECK, contract,
                f"{unit.label}: {verb} {site['collective']} "
                f"({site['bytes']} bytes/device > budget "
                f"{site['budget']}, x{site['trip_mult']:g} loop trips) "
                f"at {site['computation']}/{site['op']} — likely an "
                f"involuntary rematerialization of a replicated operand",
                unit=unit.label, site=site,
            ))
        if not flagged:
            findings.append(info(
                CHECK, contract,
                f"{unit.label}: all collective sites within budget",
                unit=unit.label,
            ))
    return findings
