"""Shared rematerialization / collective-pressure detectors.

Two complementary detectors live here, shared by ``launch.dryrun`` and
the lint's collectives check:

* :func:`capture_fd_stderr` + :data:`REMAT_WARNING` — the OS-level
  stderr capture around compilation.  XLA's SPMD partitioner reports
  "Involuntary full rematerialization" through C++ logging on fd 2
  (there is no Python-visible API for it), so the fd capture stays the
  source of truth for dryrun's ``--fail-on-remat`` gate.
* :func:`oversized_collectives` — HLO-text detection: trip-count-aware
  per-site collective listing (``launch.hlo_analysis.collective_sites``)
  filtered against per-collective byte budgets.  The remat the stderr
  warning describes *manifests* in the compiled module as a full
  all-gather of a partitioned operand inside the loop — this detector
  finds that site (and any other budget-blowing collective) from the
  artifact alone, which is what the lint gates on.
"""
from __future__ import annotations

import contextlib
import os
import sys
import tempfile
from typing import Dict, List, Optional

from repro.launch.hlo_analysis import collective_sites

REMAT_WARNING = "Involuntary full rematerialization"


@contextlib.contextmanager
def capture_fd_stderr(sink: Dict[str, str]):
    """Capture OS-level stderr around a block (XLA's C++ logging writes
    to fd 2 directly, bypassing ``sys.stderr``) and re-emit it
    afterwards, so compile-time partitioner warnings — notably the
    "Involuntary full rematerialization" copies a missing sharding
    annotation forces — become assertable data instead of scroll-by."""
    fd_saved = os.dup(2)
    with tempfile.TemporaryFile(mode="w+b") as tmp:
        sys.stderr.flush()
        os.dup2(tmp.fileno(), 2)
        try:
            yield
        finally:
            sys.stderr.flush()
            os.dup2(fd_saved, 2)
            os.close(fd_saved)
            tmp.seek(0)
            sink["text"] = tmp.read().decode("utf-8", "replace")
            # Re-emit INSIDE the finally so a failing compile still gets
            # its XLA diagnostics into the real stderr — the error case
            # is exactly when they matter.
            if sink["text"]:
                sys.stderr.write(sink["text"])
                sys.stderr.flush()


def count_remat_warnings(stderr_text: str) -> int:
    return stderr_text.count(REMAT_WARNING)


def oversized_collectives(
    hlo_text: str,
    budget: Dict[str, int],
    default_budget: Optional[int] = None,
) -> List[Dict]:
    """Collective sites whose per-device output bytes exceed their
    budget.  ``budget`` maps collective opcode -> max bytes (0 forbids
    the collective outright); opcodes absent from ``budget`` fall back
    to ``default_budget`` (``None`` = unbudgeted).  Each returned site
    carries the enclosing-loop trip multiplier, so a per-step all-gather
    inside a scanned body is attributable to its real repeat count."""
    flagged = []
    for site in collective_sites(hlo_text):
        limit = budget.get(site["collective"], default_budget)
        if limit is not None and site["bytes"] > limit:
            flagged.append(dict(site, budget=limit))
    return flagged
