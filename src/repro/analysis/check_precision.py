"""Precision-flow lint: dtype provenance over every traced hot program.

Four rules, driven by the contract's :class:`~.registry.PrecisionPolicy`
and evaluated on a :mod:`.dtype_flow` walk of each traced program
(``Built.hot_jaxprs`` plus Pallas kernel traces):

1. **forbidden dtypes** — no ``float64``/``complex128`` anywhere: a
   single weak-type promotion to f64 doubles every downstream buffer
   and silently changes numerics between hosts with different x64
   settings.
2. **widening casts** — a ``convert_element_type`` into a strictly
   wider float is only legal inside a declared precision island
   (``models.common.precision_island``): the deliberate f32 regions
   (norm, rope, attention softmax, logits, cross-entropy, optimizer
   moments, the dense accumulation, the DCIM pipeline).  Anything else
   is a silent promotion that belongs in the policy or out of the code.
3. **dot accumulation** — every accumulation-ambiguous ``dot_general``
   must declare ``preferred_element_type``: low-precision float
   operands (bf16/f16/fp8) must accumulate at the policy's
   ``accum_dtype``; integer operands must declare an integer
   accumulator.  Full-f32 dots are unambiguous and exempt.
4. **DCIM routing + exactness gates** — for programs the policy maps
   through ``sim.dcim_numerics`` (``dcim_programs``), the trace must
   contain **zero** raw floating-point ``dot_general`` inside the
   ``dense`` island — every dense MVM provably routes through the
   quantize → ``dcim_mvm`` / ``dcim_fp_matmul`` pipeline — and the
   quantizer's clip / pre-align constants must recover the
   ``core.precision`` bit widths (B_x/B_w, or B_M/B_w for FP) exactly:
   an asymmetric clip (the historical ``-qmax-1`` bug) or a mismatched
   mantissa scale is an error.  Lossless-context gates
   (``ExactnessGate``) are re-derived from the traced page-pool leaf
   dtypes instead of trusting config flags: a gate claimed enabled over
   a pool that is not at compute precision is an error, as is a pool
   leaf the traced program does not actually take as an input.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

from .dtype_flow import Flow, analyze
from .findings import Finding, error, info, warning
from .registry import Built, PrecisionPolicy, register_check

CHECK = "precision"

_LOW_PRECISION_FLOATS = {
    "bfloat16", "float16", "float8_e4m3fn", "float8_e5m2",
}
_EXPONENT_BIAS_F32 = 127


def _is_float(dtype: str) -> bool:
    return dtype.startswith(("float", "bfloat"))


def _is_int(dtype: str) -> bool:
    return dtype.startswith(("int", "uint"))


def _programs(built: Built):
    for label, cj in getattr(built, "hot_jaxprs", []) or []:
        yield label, cj
    for trace in getattr(built, "pallas", []) or []:
        yield f"pallas:{trace.label}", trace.closed_jaxpr


def _audit_dtypes(
    contract: str, label: str, flow: Flow, policy: PrecisionPolicy
) -> List[Finding]:
    out = []
    for dtype in sorted(flow.dtypes):
        if dtype in policy.forbid_dtypes:
            out.append(error(
                CHECK, contract,
                f"{label}: forbidden dtype {dtype} appears in the traced "
                f"program (first at {flow.dtypes[dtype]})",
                program=label, dtype=dtype, site=flow.dtypes[dtype],
            ))
    return out


def _audit_widening(
    contract: str, label: str, flow: Flow, policy: PrecisionPolicy
) -> List[Finding]:
    allowed = frozenset(policy.islands)
    out = []
    for cast in flow.casts:
        if not cast.widening:
            continue
        if cast.islands & allowed:
            continue
        out.append(error(
            CHECK, contract,
            f"{label}: widening cast {cast.src_dtype}->{cast.dst_dtype} at "
            f"{cast.path} outside any declared precision island "
            f"(islands seen: {sorted(cast.islands) or 'none'}); wrap the "
            f"deliberate f32 region in precision_island(...) or drop the "
            f"promotion",
            program=label, site=cast.path,
            src=cast.src_dtype, dst=cast.dst_dtype,
            islands=sorted(cast.islands),
        ))
    return out


def _audit_dots(
    contract: str, label: str, flow: Flow, policy: PrecisionPolicy
) -> List[Finding]:
    out = []
    for dot in flow.dots:
        lhs, rhs = dot.lhs_dtype, dot.rhs_dtype
        if _is_float(lhs) and _is_float(rhs):
            if lhs not in _LOW_PRECISION_FLOATS and \
                    rhs not in _LOW_PRECISION_FLOATS:
                continue            # full-width float dot: unambiguous
            if dot.preferred != policy.accum_dtype:
                out.append(error(
                    CHECK, contract,
                    f"{label}: {lhs}x{rhs} dot_general at {dot.path} must "
                    f"declare preferred_element_type={policy.accum_dtype} "
                    f"(got {dot.preferred})",
                    program=label, site=dot.path, lhs=lhs, rhs=rhs,
                    preferred=dot.preferred, required=policy.accum_dtype,
                ))
        elif _is_int(lhs) and _is_int(rhs):
            if dot.preferred is None or not _is_int(dot.preferred):
                out.append(error(
                    CHECK, contract,
                    f"{label}: integer {lhs}x{rhs} dot_general at {dot.path} "
                    f"must declare an integer preferred_element_type "
                    f"(got {dot.preferred})",
                    program=label, site=dot.path, lhs=lhs, rhs=rhs,
                    preferred=dot.preferred,
                ))
    return out


def _pow2_exp(value: float) -> Optional[int]:
    if value <= 0 or value != int(value):
        return None
    exp = int(math.log2(value))
    return exp if (1 << exp) == int(value) else None


def _audit_dcim(
    contract: str, label: str, flow: Flow, precision_name: str
) -> List[Finding]:
    from ..core import precision as core_precision

    fmt = core_precision.get(precision_name)
    out: List[Finding] = []

    # (a) structural routing: no raw fp dots may survive inside dense.
    fp_dense_dots = [
        d for d in flow.dots
        if "dense" in d.islands and _is_float(d.lhs_dtype)
    ]
    for d in fp_dense_dots:
        out.append(error(
            CHECK, contract,
            f"{label}: raw {d.lhs_dtype} dot_general at {d.path} inside the "
            f"dense island — this MVM bypasses the installed DCIM numerics "
            f"(_MVM_IMPL) instead of routing through "
            f"quantize->dcim_mvm/dcim_fp_matmul",
            program=label, site=d.path, dtype=d.lhs_dtype,
        ))
    call_names = {c.name for c in flow.calls}
    required = {"dcim_mvm"} | ({"dcim_fp_matmul", "fp_prealign"}
                               if fmt.is_fp else set())
    missing = sorted(required - call_names)
    if missing:
        out.append(error(
            CHECK, contract,
            f"{label}: DCIM-routed program never calls {missing} — dense "
            f"MVMs are not reaching the {precision_name} pipeline",
            program=label, missing=missing, precision=precision_name,
        ))

    if fmt.is_fp:
        # (b-fp) recover B_M from fp_prealign's mantissa scale (a
        # multiply by 1<<B_M) and B_w from dcim_fp_matmul's exp2 bias
        # offset 2*bias + (B_M-1) + (B_w-1).
        prealign_pow2 = sorted({
            e for c in flow.consts
            if c.primitive == "mul" and "fp_prealign" in c.fns
            for e in [_pow2_exp(c.value)] if e is not None and e >= 2
        })
        if fmt.B_M not in prealign_pow2:
            out.append(error(
                CHECK, contract,
                f"{label}: fp_prealign mantissa scale does not recover "
                f"B_M={fmt.B_M} for {precision_name} (power-of-two mul "
                f"constants seen: {[1 << e for e in prealign_pow2]})",
                program=label, expected_B_M=fmt.B_M,
                seen_pow2=[1 << e for e in prealign_pow2],
            ))
        expected_offset = (2 * _EXPONENT_BIAS_F32 + (fmt.B_M - 1)
                           + (fmt.B_w - 1))
        offsets = sorted({
            c.value for c in flow.consts
            if "dcim_fp_matmul" in c.fns
            and 2 * _EXPONENT_BIAS_F32 <= c.value
            < 2 * _EXPONENT_BIAS_F32 + 64
        })
        if float(expected_offset) not in offsets:
            out.append(error(
                CHECK, contract,
                f"{label}: dcim_fp_matmul exponent-bias offset does not "
                f"recover B_w={fmt.B_w} for {precision_name} (expected "
                f"constant {expected_offset}, saw {offsets})",
                program=label, expected=expected_offset, seen=offsets,
            ))
        else:
            out.append(info(
                CHECK, contract,
                f"{label}: DCIM fp routing verified — B_M={fmt.B_M} from "
                f"prealign scale, B_w={fmt.B_w} from bias offset "
                f"{expected_offset}",
                program=label, B_M=fmt.B_M, B_w=fmt.B_w,
            ))
    else:
        # (b-int) recover B_x/B_w from the quantizer clip constants.
        clips = [c for c in flow.clips
                 if "dense" in c.islands or "dcim" in c.islands]
        if not clips:
            out.append(error(
                CHECK, contract,
                f"{label}: no quantizer clip found inside the dense/dcim "
                f"islands — cannot recover B_x/B_w for {precision_name}",
                program=label, precision=precision_name,
            ))
        expected_bits = sorted({fmt.B_x, fmt.B_w})
        recovered = []
        for c in clips:
            if c.lo != -c.hi:
                out.append(error(
                    CHECK, contract,
                    f"{label}: asymmetric quantizer clip [{c.lo}, {c.hi}] at "
                    f"{c.path} — clip range must match the symmetric scale "
                    f"qmax (the -qmax-1 code would dequantize outside the "
                    f"representable range)",
                    program=label, site=c.path, lo=c.lo, hi=c.hi,
                ))
                continue
            exp = _pow2_exp(c.hi + 1)
            if exp is None:
                out.append(error(
                    CHECK, contract,
                    f"{label}: quantizer clip bound {c.hi} at {c.path} is "
                    f"not 2^(B-1)-1 for any bit width B",
                    program=label, site=c.path, hi=c.hi,
                ))
                continue
            recovered.append(exp + 1)
        bad = sorted(set(recovered) - set(expected_bits))
        if bad:
            out.append(error(
                CHECK, contract,
                f"{label}: quantizer clip recovers bit widths {bad} not in "
                f"the {precision_name} format (B_x={fmt.B_x}, B_w={fmt.B_w})",
                program=label, recovered=sorted(set(recovered)),
                expected=expected_bits,
            ))
        elif recovered:
            out.append(info(
                CHECK, contract,
                f"{label}: DCIM int routing verified — clip constants "
                f"recover B={sorted(set(recovered))} matching "
                f"{precision_name} (B_x={fmt.B_x}, B_w={fmt.B_w})",
                program=label, recovered=sorted(set(recovered)),
            ))
    return out


def _audit_gates(
    contract: str, flows: Dict[str, Flow], policy: PrecisionPolicy
) -> List[Finding]:
    out: List[Finding] = []
    for gate in policy.gates:
        flow = flows.get(gate.program)
        if flow is None:
            out.append(error(
                CHECK, contract,
                f"exactness gate {gate.name!r} references program "
                f"{gate.program!r} which the contract did not trace",
                gate=gate.name, program=gate.program,
            ))
            continue
        if not gate.pool_leaves:
            out.append(error(
                CHECK, contract,
                f"exactness gate {gate.name!r} declares no pool leaves — "
                f"nothing to verify against the traced program",
                gate=gate.name, program=gate.program,
            ))
            continue
        invars = set(flow.invar_avals)
        lossy = [(p, d) for p, d, _ in gate.pool_leaves
                 if _is_float(d) and d != policy.compute_dtype]
        unmatched = [
            (p, d, s) for p, d, s in gate.pool_leaves
            if (d, tuple(s)) not in invars
        ]
        for p, d, s in unmatched:
            out.append(error(
                CHECK, contract,
                f"exactness gate {gate.name!r}: pool leaf {p} "
                f"({d}{list(s)}) is not an input of the traced "
                f"{gate.program!r} program — the gate is not verifying the "
                f"pool the program actually reads",
                gate=gate.name, program=gate.program, leaf=p, dtype=d,
            ))
        if gate.enabled and lossy:
            out.append(error(
                CHECK, contract,
                f"exactness gate {gate.name!r} is claimed ENABLED but the "
                f"traced {gate.program!r} pool holds lossy leaves "
                f"{lossy[:4]} below compute precision "
                f"({policy.compute_dtype}) — reused context would not be "
                f"bit-exact",
                gate=gate.name, program=gate.program,
                lossy=[f"{p}:{d}" for p, d in lossy],
                compute_dtype=policy.compute_dtype,
            ))
        elif not gate.enabled and not lossy and not unmatched:
            out.append(warning(
                CHECK, contract,
                f"exactness gate {gate.name!r} is claimed DISABLED but every "
                f"traced pool leaf of {gate.program!r} is at compute "
                f"precision {policy.compute_dtype} — the gate condition "
                f"re-derives as losslessly satisfiable",
                gate=gate.name, program=gate.program,
                compute_dtype=policy.compute_dtype,
            ))
        elif gate.enabled and not unmatched:
            out.append(info(
                CHECK, contract,
                f"exactness gate {gate.name!r} verified: all "
                f"{len(gate.pool_leaves)} pool leaves of {gate.program!r} "
                f"are program inputs at {policy.compute_dtype}",
                gate=gate.name, program=gate.program,
                n_leaves=len(gate.pool_leaves),
            ))
    return out


@register_check(CHECK)
def run(contract: str, built: Built) -> List[Finding]:
    policy = getattr(built, "precision", None)
    if policy is None:
        return [warning(
            CHECK, contract,
            "contract declares the precision check but provides no "
            "PrecisionPolicy; nothing verified",
        )]
    findings: List[Finding] = []
    flows: Dict[str, Flow] = {}
    for label, cj in _programs(built):
        flow = analyze(cj)
        flows[label] = flow
        findings.extend(_audit_dtypes(contract, label, flow, policy))
        if policy.audit_widening:
            findings.extend(_audit_widening(contract, label, flow, policy))
        if policy.audit_dots:
            findings.extend(_audit_dots(contract, label, flow, policy))
        if label in policy.dcim_programs:
            findings.extend(_audit_dcim(
                contract, label, flow, policy.dcim_programs[label]))
    findings.extend(_audit_gates(contract, flows, policy))
    if not flows:
        findings.append(warning(
            CHECK, contract,
            "precision policy declared but the contract traced no programs",
        ))
    return findings
