"""Dtype-provenance dataflow analysis over jaxprs.

One recursive walk assigns every variable of a (closed) jaxpr — through
``convert_element_type``, ``dot_general``, ``scan``/``while``/``cond``/
``pjit`` sub-jaxprs and ``pallas_call`` kernel bodies — a
:class:`VarRecord`: its dtype, weak-type bit, a *provenance* string
naming the unique site that produced it, and the precision islands
(``models.common.precision_island`` named scopes) it was produced
inside.  Provenance forms a DAG over sites (SSA jaxprs cannot cycle;
the property tests assert it anyway), and because islands propagate
both from an equation's own ``name_stack`` and from the enclosing call
equation, a ``jax.jit``-ed helper traced inside an island inherits it.

On top of the records the walk classifies the sites the ``precision``
check consumes:

* :class:`CastSite` — every ``convert_element_type``, tagged widening
  when it moves a non-bool value into a strictly wider float;
* :class:`DotSite` — every ``dot_general`` with operand/output dtypes
  and its declared ``preferred_element_type`` accumulation;
* :class:`CallSite` — every named call (``pjit``/``custom_jvp`` …), so
  structural facts like "this dense routes through ``dcim_mvm``" are
  readable from the trace;
* :class:`ClipSite` — ``jnp.clip`` calls with literal bounds: the
  quantizer's clip constants, from which ``B_x``/``B_w`` are recovered;
* :class:`ConstSite` — scalar literals in mul/add/sub inside the
  FP-DCIM pipeline (``fp_prealign``'s ``1 << B_M`` mantissa scale and
  ``dcim_fp_matmul``'s exponent-bias offset), the FP analogue of the
  clip-constant recovery.

Everything here is pure introspection: nothing executes.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, FrozenSet, List, Optional, Tuple

ISLAND_RE = re.compile(r"island:([A-Za-z0-9_.\-]+)")

# pjit names whose scalar literals the FP bit-recovery needs.
_FP_DCIM_FNS = ("fp_prealign", "dcim_fp_matmul")


@dataclasses.dataclass(frozen=True)
class VarRecord:
    """Classification of one jaxpr variable (assigned exactly once)."""
    dtype: str
    weak: bool
    provenance: str                 # unique producing-site id
    islands: FrozenSet[str]         # islands the producer sits inside
    deps: Tuple[str, ...]           # provenance of the producer's operands


@dataclasses.dataclass(frozen=True)
class CastSite:
    path: str
    src_dtype: str
    dst_dtype: str
    widening: bool
    islands: FrozenSet[str]
    fns: Tuple[str, ...]            # enclosing named-call chain


@dataclasses.dataclass(frozen=True)
class DotSite:
    path: str
    lhs_dtype: str
    rhs_dtype: str
    out_dtype: str
    preferred: Optional[str]        # declared accumulation dtype, if any
    islands: FrozenSet[str]
    fns: Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class CallSite:
    path: str
    name: str                       # pjit/custom-call name ("dcim_mvm", ...)
    islands: FrozenSet[str]
    fns: Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class ClipSite:
    path: str
    lo: float
    hi: float
    islands: FrozenSet[str]
    fns: Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class ConstSite:
    path: str
    primitive: str                  # mul | add | sub
    value: float
    islands: FrozenSet[str]
    fns: Tuple[str, ...]


@dataclasses.dataclass
class Flow:
    """Result of one :func:`analyze` walk."""
    records: Dict[Any, VarRecord] = dataclasses.field(default_factory=dict)
    casts: List[CastSite] = dataclasses.field(default_factory=list)
    dots: List[DotSite] = dataclasses.field(default_factory=list)
    calls: List[CallSite] = dataclasses.field(default_factory=list)
    clips: List[ClipSite] = dataclasses.field(default_factory=list)
    consts: List[ConstSite] = dataclasses.field(default_factory=list)
    # every distinct dtype observed anywhere (vars and eqn outputs)
    dtypes: Dict[str, str] = dataclasses.field(default_factory=dict)  # dtype -> first site
    # top-level input avals, for the exactness-gate cross-check
    invar_avals: List[Tuple[str, Tuple[int, ...]]] = dataclasses.field(
        default_factory=list
    )
    n_eqns: int = 0

    def provenance_graph(self) -> Dict[str, Tuple[str, ...]]:
        """provenance -> dependency provenances, for acyclicity checks."""
        graph: Dict[str, Tuple[str, ...]] = {}
        for rec in self.records.values():
            graph.setdefault(rec.provenance, rec.deps)
        return graph


def _dtype_of(var: Any) -> str:
    return str(var.aval.dtype)


def _is_float(dtype: str) -> bool:
    return dtype.startswith(("float", "bfloat", "float8", "f8"))


_ITEMSIZE = {
    "bool": 1, "int8": 1, "uint8": 1, "int16": 2, "uint16": 2,
    "int32": 4, "uint32": 4, "int64": 8, "uint64": 8,
    "float8_e4m3fn": 1, "float8_e5m2": 1, "bfloat16": 2, "float16": 2,
    "float32": 4, "float64": 8, "complex64": 8, "complex128": 16,
}


def itemsize(dtype: str) -> int:
    return _ITEMSIZE.get(dtype, 4)


def is_widening_cast(src: str, dst: str) -> bool:
    """A silent precision promotion: a non-bool value converted into a
    strictly wider *float*.  Narrowings are always fine (they can only
    drop precision the program already had), int->same-width-float is
    a value conversion, bool->float is predicate arithmetic."""
    if src == "bool" or not _is_float(dst):
        return False
    return itemsize(dst) > itemsize(src)


def _islands_of(stack_str: str, inherited: FrozenSet[str]) -> FrozenSet[str]:
    found = ISLAND_RE.findall(stack_str)
    return inherited | frozenset(found) if found else inherited


def _literal_value(v: Any) -> Optional[float]:
    """Scalar value of a Literal invar, else None."""
    val = getattr(v, "val", None)
    if val is None or hasattr(v, "count"):        # Vars have .count
        return None
    try:
        arr = val if not hasattr(val, "shape") else val
        if getattr(arr, "shape", ()) not in ((), (1,)):
            return None
        return float(arr)
    except (TypeError, ValueError):
        return None


def _sub_jaxprs(params: Dict[str, Any]):
    """(sub_jaxpr, n_consts_hint) for every nested jaxpr in eqn params."""
    for val in params.values():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for item in vals:
            sub = getattr(item, "jaxpr", item)
            if hasattr(sub, "eqns"):
                yield sub


def analyze(closed_jaxpr: Any) -> Flow:
    """Walk a (closed) jaxpr and classify every variable and site."""
    flow = Flow()
    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    for i, v in enumerate(jaxpr.invars):
        rec = VarRecord(_dtype_of(v), bool(getattr(v.aval, "weak_type", False)),
                        f"invar:{i}", frozenset(), ())
        flow.records[v] = rec
        flow.dtypes.setdefault(rec.dtype, rec.provenance)
        shape = tuple(int(d) for d in getattr(v.aval, "shape", ()))
        flow.invar_avals.append((rec.dtype, shape))
    _walk(jaxpr, "", frozenset(), (), flow)
    return flow


def _bind_invars(jaxpr: Any, path: str, islands: FrozenSet[str],
                 deps: Tuple[str, ...], flow: Flow) -> None:
    allvars = list(getattr(jaxpr, "constvars", ())) + list(jaxpr.invars)
    for i, v in enumerate(allvars):
        if v in flow.records:       # pragma: no cover - jaxprs are SSA
            continue
        rec = VarRecord(_dtype_of(v), bool(getattr(v.aval, "weak_type", False)),
                        f"{path}:in{i}", islands, deps)
        flow.records[v] = rec
        flow.dtypes.setdefault(rec.dtype, rec.provenance)


def _walk(jaxpr: Any, path: str, inherited: FrozenSet[str],
          fns: Tuple[str, ...], flow: Flow) -> None:
    for cv in getattr(jaxpr, "constvars", ()):
        if cv not in flow.records:
            rec = VarRecord(_dtype_of(cv),
                            bool(getattr(cv.aval, "weak_type", False)),
                            f"{path}:const:{len(flow.records)}",
                            inherited, ())
            flow.records[cv] = rec
            flow.dtypes.setdefault(rec.dtype, rec.provenance)
    for i, eqn in enumerate(jaxpr.eqns):
        flow.n_eqns += 1
        site = f"{path}e{i}:{eqn.primitive.name}"
        stack = str(getattr(eqn.source_info, "name_stack", "") or "")
        islands = _islands_of(stack, inherited)
        deps = tuple(
            flow.records[v].provenance
            for v in eqn.invars
            if hasattr(v, "count") and v in flow.records
        )
        for ov in eqn.outvars:
            rec = VarRecord(_dtype_of(ov),
                            bool(getattr(ov.aval, "weak_type", False)),
                            site, islands, deps)
            flow.records[ov] = rec
            flow.dtypes.setdefault(rec.dtype, rec.provenance)

        prim = eqn.primitive.name
        name = str(eqn.params.get("name", "")) if "name" in eqn.params else ""
        if prim == "convert_element_type":
            src = _dtype_of(eqn.invars[0])
            dst = str(eqn.params["new_dtype"])
            flow.casts.append(CastSite(
                site, src, dst, is_widening_cast(src, dst), islands, fns))
        elif prim == "dot_general":
            pref = eqn.params.get("preferred_element_type")
            flow.dots.append(DotSite(
                site,
                _dtype_of(eqn.invars[0]), _dtype_of(eqn.invars[1]),
                _dtype_of(eqn.outvars[0]),
                None if pref is None else str(pref), islands, fns))
        elif prim in ("mul", "add", "sub") and any(
            f in fns for f in _FP_DCIM_FNS
        ):
            for v in eqn.invars:
                val = _literal_value(v)
                if val is not None:
                    flow.consts.append(ConstSite(site, prim, val, islands, fns))
        if name:
            flow.calls.append(CallSite(site, name, islands, fns))
            if name == "clip" and len(eqn.invars) == 3:
                lo = _literal_value(eqn.invars[1])
                hi = _literal_value(eqn.invars[2])
                if lo is not None and hi is not None:
                    flow.clips.append(ClipSite(site, lo, hi, islands, fns))
        sub_fns = fns + (name,) if name else fns
        for sub in _sub_jaxprs(eqn.params):
            sub_path = f"{site}/"
            _bind_invars(sub, f"{site}", islands, deps, flow)
            _walk(sub, sub_path, islands, sub_fns, flow)
