"""Donation lint: every buffer a contract donates must actually alias an
output in the compiled module.

XLA drops an unusable donation *silently* at run time (just a
UserWarning at compile time): the program stays correct but copies the
donated buffer — for the serve pool or the train state that is the
biggest buffer of the hot loop, every step.  This check reads the
``input_output_alias`` table out of ``compiled.as_text()`` and matches
the contract's donated-leaf inventory against the parameters XLA kept,
by byte size (post-SPMD parameter shapes are per-device, so SPMD units
declare ``shard_divisors`` to widen the match).
"""
from __future__ import annotations

from collections import Counter
from typing import List

from . import hlo
from .findings import Finding, error, info
from .registry import Built, register_check

CHECK = "donation"


@register_check(CHECK)
def run(contract: str, built: Built) -> List[Finding]:
    findings: List[Finding] = []
    for unit in built.compiled:
        if not unit.donated:
            continue
        available = Counter(hlo.aliased_param_bytes(unit.hlo))
        dropped = []
        for leaf in sorted(unit.donated, key=lambda d: -d["nbytes"]):
            if leaf["nbytes"] < unit.donate_min_bytes:
                continue
            matched = False
            for div in unit.shard_divisors:
                size = leaf["nbytes"] // div
                if available[size] > 0:
                    available[size] -= 1
                    matched = True
                    break
            if not matched:
                dropped.append(leaf)
        if dropped:
            findings.append(error(
                CHECK, contract,
                f"{unit.label}: {len(dropped)} donated buffer(s) were "
                f"dropped by XLA instead of aliased "
                f"(largest: {dropped[0]['path']}, {dropped[0]['nbytes']} "
                f"bytes) — the hot loop copies them every call",
                unit=unit.label,
                dropped=dropped,
                compile_warnings=unit.compile_warnings,
            ))
        elif unit.compile_warnings:
            # Aliasing held for every leaf we track, but XLA still
            # complained about some donation (e.g. one under
            # donate_min_bytes): surface it without failing.
            findings.append(info(
                CHECK, contract,
                f"{unit.label}: donation warnings at compile time "
                f"(all tracked leaves aliased)",
                unit=unit.label, compile_warnings=unit.compile_warnings,
            ))
    return findings
