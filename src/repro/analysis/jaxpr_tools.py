"""jax-level helpers shared by contracts and checks: canonical abstract
call signatures (recompile lint), donated-leaf inventories and compiled
units (donation lint), recursive jaxpr walks and ``pallas_call``
introspection (transfer + Pallas lints).

Split out of ``registry`` so declaring a contract stays import-light.
"""
from __future__ import annotations

import warnings
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax import api_util

from .registry import CompiledUnit

# ----------------------------- signatures --------------------------------


def _aval_of(x):
    return api_util.shaped_abstractify(x)


def canonical_signature(tree: Any) -> str:
    """Canonical abstract signature of an argument pytree.

    Two calls with equal signatures hit the same jit cache entry; any
    drift (shape, dtype, weak-type flag) is a retrace.  The weak-type
    bit is kept explicit (``|w1``/``|w0``) so the recompile check can
    attribute a signature split to weak-type promotion drift alone."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    parts = []
    for leaf in leaves:
        av = _aval_of(leaf)
        weak = "w1" if getattr(av, "weak_type", False) else "w0"
        parts.append(f"{av.dtype}{list(av.shape)}|{weak}")
    return f"{treedef}::" + ";".join(parts)


def strip_weak(sig: str) -> str:
    """Signature with the weak-type bits erased — if two signatures
    collide after stripping, they differ ONLY in weak typing."""
    return sig.replace("|w1", "|w?").replace("|w0", "|w?")


# --------------------------- donation helpers ----------------------------


def donated_leaves(
    args: Sequence[Any], donate_argnums: Sequence[int]
) -> List[Dict[str, Any]]:
    """Describe every leaf of the donated arguments: path, shape, dtype,
    nbytes.  Accepts arrays or ShapeDtypeStructs."""
    out: List[Dict[str, Any]] = []
    for i in donate_argnums:
        flat = jax.tree_util.tree_flatten_with_path(args[i])[0]
        for path, leaf in flat:
            av = _aval_of(leaf)
            nbytes = int(np.prod(av.shape, dtype=np.int64)) * av.dtype.itemsize
            out.append({
                "path": f"arg{i}{jax.tree_util.keystr(path)}",
                "shape": tuple(int(d) for d in av.shape),
                "dtype": str(av.dtype),
                "nbytes": nbytes,
            })
    return out


def compile_unit(
    label: str,
    jitted: Any,
    args: Sequence[Any],
    donate_argnums: Sequence[int] = (),
    donate_min_bytes: int = 0,
    shard_divisors: Tuple[int, ...] = (1,),
    collective_budget: Optional[Dict[str, int]] = None,
    **kwargs: Any,
) -> CompiledUnit:
    """Lower+compile an already-jitted callable and capture the
    artifacts the checks need: post-SPMD HLO text, the donated-leaf
    inventory, and any donation warnings XLA raised at compile time."""
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        compiled = jitted.lower(*args, **kwargs).compile()
    donation_warnings = [
        str(w.message) for w in caught
        if "donated" in str(w.message).lower()
    ]
    return CompiledUnit(
        label=label,
        hlo=compiled.as_text(),
        donated=donated_leaves(args, donate_argnums),
        donate_min_bytes=donate_min_bytes,
        shard_divisors=shard_divisors,
        compile_warnings=donation_warnings,
        collective_budget=collective_budget,
    )


def pytree_leaf_specs(tree: Any) -> List[Tuple[str, str, Tuple[int, ...]]]:
    """(path, dtype, shape) for every leaf of a pytree — the shape the
    precision check's :class:`~.registry.ExactnessGate` expects for
    ``pool_leaves``."""
    out: List[Tuple[str, str, Tuple[int, ...]]] = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        av = _aval_of(leaf)
        out.append((
            jax.tree_util.keystr(path), str(av.dtype),
            tuple(int(d) for d in av.shape),
        ))
    return out


# ----------------------------- jaxpr walking -----------------------------


def _subjaxprs(params: Dict[str, Any]) -> Iterator[Any]:
    for v in params.values():
        vals = v if isinstance(v, (tuple, list)) else (v,)
        for item in vals:
            sub = getattr(item, "jaxpr", item)
            if hasattr(sub, "eqns"):
                yield sub


def iter_eqns(closed_jaxpr: Any) -> Iterator[Any]:
    """Every equation in a (closed) jaxpr, recursing through nested
    call/control-flow/pallas jaxprs."""
    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _subjaxprs(eqn.params):
            yield from iter_eqns(sub)


def find_eqns(closed_jaxpr: Any, primitive_name: str) -> List[Any]:
    return [e for e in iter_eqns(closed_jaxpr)
            if e.primitive.name == primitive_name]


# --------------------------- pallas introspection ------------------------


def pallas_call_specs(closed_jaxpr: Any) -> List[Dict[str, Any]]:
    """Extract, for every ``pallas_call`` reachable from the jaxpr, the
    grid, per-operand block shapes/array shapes/dtypes, the evaluable
    index maps, and the interpret flag.  Pure introspection — nothing
    here executes the kernel."""
    out: List[Dict[str, Any]] = []
    for eqn in find_eqns(closed_jaxpr, "pallas_call"):
        gm = eqn.params["grid_mapping"]
        grid = tuple(int(g) for g in gm.grid)
        operands = []
        for bm in gm.block_mappings:
            sd = bm.array_shape_dtype
            operands.append({
                "array_shape": tuple(int(d) for d in sd.shape),
                "dtype": str(sd.dtype),
                "block_shape": tuple(
                    int(b) if isinstance(b, (int, np.integer)) else None
                    for b in bm.block_shape
                ),
                "index_map_jaxpr": bm.index_map_jaxpr,
            })
        out.append({
            "name": getattr(gm, "name", None) or str(
                eqn.params.get("name_and_src_info", "pallas_call")
            ),
            "grid": grid,
            "operands": operands,
            "interpret": bool(eqn.params.get("interpret", False)),
        })
    return out


def eval_index_map(index_map_jaxpr: Any, grid_idx: Sequence[int]) -> Tuple[int, ...]:
    """Evaluate one BlockSpec index map at a concrete grid point,
    returning the block indices it selects."""
    res = jax.core.eval_jaxpr(
        index_map_jaxpr.jaxpr, index_map_jaxpr.consts,
        *[int(i) for i in grid_idx],
    )
    return tuple(int(r) for r in res)
