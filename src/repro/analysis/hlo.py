"""HLO-text parsing for the donation check.

XLA records accepted donations in the module header::

    HloModule jit_step, input_output_alias={ {0}: (0, {}, may-alias), ... }

A ``donate_argnums`` buffer that XLA could NOT alias (shape/dtype
mismatch with every output, or a sharding change) is silently dropped —
the program still runs, it just copies the biggest buffer of the hot
loop every step.  ``aliased_params`` recovers which entry parameters
actually aliased an output, and ``entry_param_bytes`` their byte sizes,
so the check can match the contract's donated-leaf inventory against
what the compiler kept.
"""
from __future__ import annotations

import re
from typing import Dict, List

from repro.launch.hlo_analysis import HloAnalyzer, _shape_bytes

# one alias entry: {output_index}: (param_number, {param_index}, kind)
_ALIAS_ENTRY = re.compile(
    r"\{[\d,\s]*\}:\s*\((\d+),\s*\{[\d,\s]*\},\s*(?:may|must)-alias\)"
)


def aliased_params(hlo_text: str) -> List[int]:
    """Entry-parameter numbers that alias an output (with multiplicity:
    a tuple parameter aliasing several outputs appears once per entry)."""
    header = ""
    for line in hlo_text.splitlines():
        if "input_output_alias=" in line:
            header = line.split("input_output_alias=", 1)[1]
            break
    return [int(m.group(1)) for m in _ALIAS_ENTRY.finditer(header)]


def entry_param_bytes(hlo_text: str) -> Dict[int, int]:
    """Byte size of every entry-computation parameter, by number."""
    an = HloAnalyzer(hlo_text)
    out: Dict[int, int] = {}
    if an.entry is None:
        return out
    for op in an.comps[an.entry].ops:
        if op.opcode != "parameter":
            continue
        m = re.match(r"\s*(\d+)\)", op.rest)
        if m:
            out[int(m.group(1))] = _shape_bytes(op.shape)
    return out


def aliased_param_bytes(hlo_text: str) -> List[int]:
    """Byte sizes of the parameters that aliased an output — the
    multiset the donation check consumes."""
    sizes = entry_param_bytes(hlo_text)
    return [sizes[p] for p in aliased_params(hlo_text) if p in sizes]
