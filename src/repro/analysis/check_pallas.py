"""Pallas kernel lint: BlockSpec tiling, grid coverage, interpreter
fallbacks — statically, from the traced jaxpr.

The contract traces each kernel entry point (``jax.make_jaxpr``); the
check digs the ``pallas_call`` equations out (``pallas_call_specs``) and
verifies, per operand:

* **lane alignment** — the last block dim must be a multiple of 128
  (the TPU lane count) unless the block spans the full array dim (a
  sub-lane-sized array is padded into one tile);
* **sublane alignment** — the second-to-last block dim must be a
  multiple of the dtype's min sublane tile (f32: 8, bf16: 16,
  int8/fp8: 32), same full-dim escape;
* **grid coverage** — evaluating the BlockSpec's index map at the grid
  corners must cover the whole array: a grid that stops short silently
  computes on a prefix (the classic ``cdiv``-vs-``//`` bug);
* **interpreter fallback** — ``interpret=True`` is an error on TPU (the
  kernel never compiles) and an ``info`` elsewhere (expected on CPU).

Everything is derived from the trace — no kernel is executed.
"""
from __future__ import annotations

import itertools
from typing import List

import jax
import numpy as np

from .findings import Finding, error, info, warning
from .jaxpr_tools import eval_index_map, pallas_call_specs
from .registry import Built, register_check

CHECK = "pallas"

LANE = 128
_MIN_SUBLANE = {1: 32, 2: 16, 4: 8, 8: 8}     # itemsize -> min sublane tile


def _itemsize(dtype_str: str) -> int:
    try:
        return int(np.dtype(dtype_str).itemsize)
    except TypeError:
        return 1    # fp8/int4 custom dtypes: 1-byte class


def _check_operand(contract, kernel, which, idx, op, grid) -> List[Finding]:
    findings: List[Finding] = []
    block = op["block_shape"]
    shape = op["array_shape"]
    label = f"{kernel}[{which}{idx}]"

    # --- tile alignment ---------------------------------------------------
    if block and block[-1] is not None and shape:
        b_last, a_last = block[-1], shape[-1]
        if b_last != a_last and b_last % LANE:
            findings.append(error(
                CHECK, contract,
                f"{label}: last block dim {b_last} is neither the full "
                f"array dim ({a_last}) nor a multiple of the {LANE}-wide "
                f"lane tile",
                kernel=kernel, operand=idx, block=list(block),
                array=list(shape),
            ))
    if len(block) >= 2 and block[-2] is not None and len(shape) >= 2:
        min_sub = _MIN_SUBLANE.get(_itemsize(op["dtype"]), 8)
        b_sub, a_sub = block[-2], shape[-2]
        if b_sub != a_sub and b_sub % min_sub:
            findings.append(error(
                CHECK, contract,
                f"{label}: sublane block dim {b_sub} is neither the full "
                f"array dim ({a_sub}) nor a multiple of the "
                f"{op['dtype']} min sublane tile ({min_sub})",
                kernel=kernel, operand=idx, block=list(block),
                array=list(shape),
            ))

    # --- grid coverage ----------------------------------------------------
    if grid and all(b is not None for b in block):
        try:
            corners = itertools.product(*[(0, g - 1) for g in grid])
            covered = [0] * len(block)
            for corner in corners:
                out = eval_index_map(op["index_map_jaxpr"], corner)
                for d in range(len(block)):
                    covered[d] = max(covered[d], (out[d] + 1) * block[d])
            short = [d for d in range(len(shape)) if covered[d] < shape[d]]
            if short:
                findings.append(error(
                    CHECK, contract,
                    f"{label}: grid {grid} covers only "
                    f"{[covered[d] for d in short]} of array dims "
                    f"{[shape[d] for d in short]} (dims {short}) — part "
                    f"of the array is never visited",
                    kernel=kernel, operand=idx, grid=list(grid),
                    covered=covered, array=list(shape),
                ))
        except Exception as e:   # un-evaluable index map: report, don't crash
            findings.append(warning(
                CHECK, contract,
                f"{label}: could not evaluate BlockSpec index map "
                f"({type(e).__name__}: {e})",
                kernel=kernel, operand=idx,
            ))
    return findings


@register_check(CHECK)
def run(contract: str, built: Built) -> List[Finding]:
    findings: List[Finding] = []
    backend = jax.default_backend()
    for trace in built.pallas:
        specs = pallas_call_specs(trace.closed_jaxpr)
        if not specs:
            findings.append(warning(
                CHECK, contract,
                f"{trace.label}: no pallas_call found in the trace",
                kernel=trace.label,
            ))
            continue
        for spec in specs:
            ops = spec["operands"]
            # inputs and outputs are interleaved in block_mappings order;
            # index only — the distinction does not change the rules
            for idx, op in enumerate(ops):
                findings.extend(_check_operand(
                    contract, trace.label, "operand", idx, op, spec["grid"]
                ))
            if spec["interpret"]:
                if backend == "tpu":
                    findings.append(error(
                        CHECK, contract,
                        f"{trace.label}: pallas_call traced with "
                        f"interpret=True on TPU — the kernel never "
                        f"compiles",
                        kernel=trace.label,
                    ))
                else:
                    findings.append(info(
                        CHECK, contract,
                        f"{trace.label}: interpreter mode on "
                        f"{backend} (expected off-TPU)",
                        kernel=trace.label,
                    ))
        if trace.interpret_fallback:
            findings.append(info(
                CHECK, contract,
                f"{trace.label}: public wrapper auto-falls back to "
                f"interpreter/XLA on {backend}",
                kernel=trace.label,
            ))
    return findings
