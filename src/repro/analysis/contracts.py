"""Contract loading + the cross-module SPMD contract.

Per-program contracts live next to their jit sites (``serve.scheduler``,
``models.lm``, ``train.train_step``, ``core.nsga2``, ``kernels.ops``) —
importing those modules registers them.  The data-parallel training
contract lives here because it spans train_step + dist sharding and must
NOT import ``launch.dryrun`` (whose module preamble forces a 512-device
host platform).

The lint CLI forces an 8-device CPU host platform before jax
initializes, so the SPMD contract compiles a real multi-device module;
in an already-initialized single-device process it skips with an
``info`` finding.
"""
from __future__ import annotations

import importlib

from .registry import Built, ContractSkip, register_contract

# Importing these modules registers their contracts (decorator side
# effect at module scope).
CONTRACT_MODULES = (
    "repro.serve.scheduler",
    "repro.models.lm",
    "repro.train.train_step",
    "repro.core.nsga2",
    "repro.kernels.ops",
    "repro.sim.functional",
)


def load_contracts() -> None:
    for mod in CONTRACT_MODULES:
        importlib.import_module(mod)


@register_contract(
    "dist.train_dp",
    checks=("collectives", "donation"),
    description="data-parallel train step on a dp mesh: donated "
                "replicated state, gradient sync must stay all-reduce — "
                "no full-operand all-gather (involuntary remat), no "
                "all-to-all",
)
def _build_train_dp() -> Built:
    import jax

    if jax.device_count() < 2:
        raise ContractSkip(
            "needs >= 2 devices; run via `python -m repro.analysis.lint` "
            "(forces a multi-device CPU host platform)"
        )
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro import configs
    from repro.optim import get_optimizer
    from repro.train.train_step import init_state, make_train_step

    from .jaxpr_tools import compile_unit

    cfg = configs.get_smoke_config("qwen2.5-3b")
    opt = get_optimizer("adamw", 1e-3)
    state = init_state(jax.random.PRNGKey(0), cfg, opt)
    step = make_train_step(cfg, opt)

    mesh = Mesh(np.array(jax.devices()), ("dp",))
    repl = NamedSharding(mesh, P())
    dp_rows = NamedSharding(mesh, P("dp"))

    B, S = jax.device_count(), 16
    batch = {
        "tokens": jnp.zeros((B, S), jnp.int32),
        "targets": jnp.zeros((B, S), jnp.int32),
        "loss_mask": jnp.ones((B, S), jnp.float32),
    }
    state_sh = jax.tree.map(lambda _: repl, state)
    batch_sh = jax.tree.map(lambda _: dp_rows, batch)
    jitted = jax.jit(
        step,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
        donate_argnums=(0,),
    )
    unit = compile_unit(
        "train_dp_step", jitted, (state, batch),
        donate_argnums=(0,),
        # replicated state: per-device parameter shapes == global shapes
        shard_divisors=(1,),
        collective_budget={
            # gradient sync is all-reduce (unbudgeted here); a FULL
            # all-gather of a replicated operand is the involuntary-remat
            # signature — nothing in a clean dp step should gather more
            # than control scalars
            "all-gather": 1 << 16,
            "all-to-all": 0,
        },
    )
    return Built(compiled=[unit])
