"""Recompile-hazard lint: a replayed host loop must hit the jit caches
it promised.

The contract replays a realistic trace against its real jitted programs
while recording the canonical abstract signature of every call
(``jaxpr_tools.canonical_signature`` — shape, dtype AND weak-type bit
per leaf).  Three detectors:

* **signature budget** — more DISTINCT signatures for a program label
  than its declared ``max_programs`` means the host loop retraces where
  it promised cache hits;
* **weak-type drift** — two signatures that collide once the weak-type
  bits are erased differ *only* in weak typing: some call passed a
  Python scalar where another passed a committed array.  This is the
  classic silent cache-doubler, so it is attributed explicitly;
* **live cache sizes** — when the contract snapshots real jit cache
  counters (e.g. ``Scheduler.compile_counts()``), they are compared
  against the declared budget.  This catches retraces the signature
  recorder cannot see (e.g. different static argnums).
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List

from .findings import Finding, error
from .jaxpr_tools import strip_weak
from .registry import Built, register_check

CHECK = "recompile"


@register_check(CHECK)
def run(contract: str, built: Built) -> List[Finding]:
    findings: List[Finding] = []
    replay = built.replay
    if replay is None:
        return findings

    by_label: Dict[str, List[str]] = defaultdict(list)
    for label, sig in replay.signatures:
        by_label[label].append(sig)

    for label, sigs in sorted(by_label.items()):
        distinct = list(dict.fromkeys(sigs))

        # weak-type drift: report before the budget so the root cause
        # leads even when both fire
        buckets: Dict[str, List[str]] = defaultdict(list)
        for sig in distinct:
            buckets[strip_weak(sig)].append(sig)
        drifted = {k: v for k, v in buckets.items() if len(v) > 1}
        if drifted:
            findings.append(error(
                CHECK, contract,
                f"{label}: weak-type drift — {len(drifted)} signature "
                f"group(s) differ only in weak typing (a Python scalar "
                f"vs a committed array at the same argument)",
                program=label,
                groups={k: v for k, v in list(drifted.items())[:4]},
            ))

        budget = replay.max_programs.get(label)
        if budget is not None and len(distinct) > budget:
            findings.append(error(
                CHECK, contract,
                f"{label}: {len(distinct)} distinct abstract signatures "
                f"over the replayed trace, budget {budget} — the host "
                f"loop retraces where it promised cache hits",
                program=label, budget=budget,
                signatures=distinct[:8],
            ))

    for key, budget in sorted(replay.live_budget.items()):
        live = replay.live_counts.get(key)
        if live is not None and live > budget:
            findings.append(error(
                CHECK, contract,
                f"jit cache {key!r} holds {live} compiled programs, "
                f"budget {budget}",
                cache=key, live=live, budget=budget,
            ))
    return findings
