"""Transfer/host-sync lint: the hot path must not transfer implicitly.

Two detectors:

* **replay under guard** — the contract's ``hot`` callable (e.g. a
  miniature ``ServeSession`` submit+drain) runs under
  ``jax.transfer_guard("disallow")``.  Any *implicit* host-to-device
  transfer — a raw numpy array or scalar handed straight to a jitted
  program, a numpy operand folded into a jax op — raises, and the raise
  becomes an ``error`` finding.  Explicit conversions
  (``jnp.asarray`` / ``device_put``) pass: the point is not "no
  transfers" but "every transfer is a visible, deliberate call site".
* **jaxpr walk** — the contract's traced hot programs must not contain
  host-callback or infeed/outfeed primitives: those synchronize with
  the host *inside* the program, stalling every step.
"""
from __future__ import annotations

from typing import List

import jax

from .findings import Finding, error
from .jaxpr_tools import iter_eqns
from .registry import Built, register_check

CHECK = "transfers"

_HOST_SYNC_PRIMITIVES = {
    "pure_callback", "io_callback", "debug_callback", "callback",
    "infeed", "outfeed", "host_local_array_to_global_array",
}


@register_check(CHECK)
def run(contract: str, built: Built) -> List[Finding]:
    findings: List[Finding] = []
    if built.hot is not None:
        try:
            with jax.transfer_guard("disallow"):
                built.hot()
        except Exception as e:  # the guard raises XlaRuntimeError
            findings.append(error(
                CHECK, contract,
                f"{built.hot_label}: implicit transfer under "
                f"transfer_guard('disallow') — convert at the call site "
                f"(jnp.asarray / device_put) instead",
                exception=f"{type(e).__name__}: {e}"[:500],
            ))
    for label, closed_jaxpr in getattr(built, "hot_jaxprs", []) or []:
        hits = sorted({
            eqn.primitive.name for eqn in iter_eqns(closed_jaxpr)
            if eqn.primitive.name in _HOST_SYNC_PRIMITIVES
        })
        if hits:
            findings.append(error(
                CHECK, contract,
                f"{label}: host-sync primitive(s) {hits} inside the "
                f"compiled hot program",
                program=label, primitives=hits,
            ))
    return findings
