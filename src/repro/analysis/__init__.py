"""Program-contract linter over jaxprs and compiled HLO.

Hot programs declare :class:`~repro.analysis.registry.Contract` objects
at their jit sites; pluggable checks (donation, transfers, recompile,
collectives, pallas, precision) verify them from artifacts alone.  See
``docs/analysis.md`` and ``python -m repro.analysis.lint --help``.

This package root stays import-light: contract *declaration* must be
free for the hot modules, so the check and contract modules load only
on demand (:func:`load_builtin_checks`, ``contracts.load_contracts``).
"""
from .findings import Finding, Report  # noqa: F401
from .registry import (  # noqa: F401
    CHECKS,
    CONTRACTS,
    DEFAULT_ISLANDS,
    Built,
    CompiledUnit,
    Contract,
    ContractSkip,
    ExactnessGate,
    PallasTrace,
    PrecisionPolicy,
    Replay,
    register_check,
    register_contract,
)

_CHECK_MODULES = (
    "check_donation",
    "check_transfers",
    "check_recompile",
    "check_collectives",
    "check_pallas",
    "check_precision",
)


def load_builtin_checks() -> None:
    """Import every built-in check module (registration is a decorator
    side effect)."""
    import importlib

    for mod in _CHECK_MODULES:
        importlib.import_module(f"{__name__}.{mod}")
