"""Pluggable registries for lint checks and program contracts.

A **contract** is declared next to the jit site it describes (scheduler,
``lm.prefill_paged``, ``train_step``, ``nsga2.run_batched``, the Pallas
kernels): a build function that constructs the program at a miniature
configuration and returns the artifacts the checks need — compiled HLO
with the declared donated buffers, a hot callable to replay under a
transfer guard, recorded abstract call signatures, traced Pallas jaxprs.
Checks never import the modules they verify; they see only
:class:`Built`.

A **check** is a function ``(contract_name, Built) -> [Finding]``
registered under a short name.  The lint runner intersects each
contract's declared ``checks`` with the requested set, so a contract is
only exercised by checks it opted into.

This module is deliberately import-light (stdlib only): hot modules
import it at module scope to declare their contracts, and must not pay
for — or cycle into — jax-level helpers, which live in
``analysis.jaxpr_tools`` / ``analysis.hlo``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .findings import Finding


class ContractSkip(Exception):
    """Raised by a contract build to opt out at runtime (e.g. a mesh
    contract on a single-device host).  Reported as an ``info`` finding,
    never a failure."""


@dataclasses.dataclass
class CompiledUnit:
    """One lowered+compiled program, for artifact-level (HLO) checks.

    ``donated`` describes the buffers the call site donates — dicts with
    ``path``/``shape``/``dtype``/``nbytes`` (see
    ``jaxpr_tools.donated_leaves``).  ``shard_divisors`` widens the
    donation byte-match for SPMD programs whose post-partition parameter
    shapes are the global shape divided across devices."""
    label: str
    hlo: str                                        # compiled.as_text()
    donated: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    donate_min_bytes: int = 0
    shard_divisors: Tuple[int, ...] = (1,)
    compile_warnings: List[str] = dataclasses.field(default_factory=list)
    # per-collective byte budgets, e.g. {"all-gather": 1 << 20}; 0 forbids
    collective_budget: Optional[Dict[str, int]] = None


@dataclasses.dataclass
class Replay:
    """Abstract call signatures recorded while replaying a host loop
    against the real jitted programs (see the serve contract)."""
    # (program label, canonical abstract signature) per recorded call
    signatures: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    # per-label budget of DISTINCT signatures; a label absent here is
    # unbudgeted (reported, not enforced)
    max_programs: Dict[str, int] = dataclasses.field(default_factory=dict)
    # live jit-cache sizes vs budget (e.g. Scheduler.compile_counts())
    live_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    live_budget: Dict[str, int] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class PallasTrace:
    """One traced kernel entry point for the Pallas tiling check."""
    label: str
    closed_jaxpr: Any                       # jax.core.ClosedJaxpr
    # whether this kernel's public wrapper falls back to interpreter
    # mode on the current backend (info finding, error on TPU)
    interpret_fallback: bool = False


@dataclasses.dataclass
class ExactnessGate:
    """One lossless-context gate claim, re-verified from trace artifacts.

    The scheduler enables prefix reuse / preemption / chunked prefill
    only when ``cache_dtype == compute_dtype`` (reused pages must carry
    the exact values a reference prefill would attend).  Instead of
    trusting that config comparison, the precision check re-derives the
    condition from the ACTUAL page-pool leaves of the traced program:
    ``pool_leaves`` records (path, dtype, shape) of the very pool pytree
    the contract traced ``program`` with, and every leaf must both match
    an input aval of that traced program and be at compute precision
    whenever ``enabled`` claims the gate is on."""
    name: str                       # e.g. "prefix_reuse"
    enabled: bool                   # what the scheduler/config claims
    program: str                    # label in Built.hot_jaxprs to verify against
    # (pytree path, dtype str, shape) for every pool leaf handed to the trace
    pool_leaves: List[Tuple[str, str, Tuple[int, ...]]] = dataclasses.field(
        default_factory=list
    )


# Islands every policy allows by default: the deliberate f32 regions of
# the model stack (norm/rope/attention-softmax/logits/cross-entropy),
# the declared-f32-accumulation dense block, the optimizer's f32 moment
# arithmetic, and the DCIM quantize->MVM->dequantize pipeline.
DEFAULT_ISLANDS: Tuple[str, ...] = (
    "norm", "rope", "attn", "logits", "xent", "dense", "optimizer", "dcim",
)


@dataclasses.dataclass
class PrecisionPolicy:
    """Per-contract precision contract for the ``precision`` check.

    ``compute_dtype`` is the working dtype of the hot programs;
    ``accum_dtype`` the declared matmul accumulation dtype (every
    accumulation-ambiguous ``dot_general`` must carry it as
    ``preferred_element_type``).  Widening casts are only legal inside a
    declared island (``models.common.precision_island``) listed in
    ``islands``.  ``dcim_programs`` maps a ``hot_jaxprs`` label to the
    ``core.precision`` format name whose DCIM numerics must provably
    serve every dense MVM of that program."""
    compute_dtype: str                              # e.g. "bfloat16"
    accum_dtype: str = "float32"
    islands: Tuple[str, ...] = DEFAULT_ISLANDS
    forbid_dtypes: Tuple[str, ...] = ("float64", "complex128")
    audit_widening: bool = True     # kernels opt out: register upcasts are idiomatic
    audit_dots: bool = True
    # hot_jaxprs label -> precision name ("int8", "fp8", ...) routed via
    # sim.dcim_numerics; the program must contain zero raw fp dense
    # dot_generals and its clip/prealign constants must recover B_x/B_w.
    dcim_programs: Dict[str, str] = dataclasses.field(default_factory=dict)
    gates: List[ExactnessGate] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class Built:
    """Everything a contract hands to the checks."""
    compiled: List[CompiledUnit] = dataclasses.field(default_factory=list)
    hot: Optional[Callable[[], Any]] = None         # transfer-guard target
    hot_label: str = "hot path"
    # (label, ClosedJaxpr) traced hot programs for the jaxpr walks
    hot_jaxprs: List[Tuple[str, Any]] = dataclasses.field(default_factory=list)
    replay: Optional[Replay] = None
    pallas: List[PallasTrace] = dataclasses.field(default_factory=list)
    precision: Optional[PrecisionPolicy] = None


@dataclasses.dataclass
class Contract:
    name: str
    build: Callable[[], Built]
    checks: Tuple[str, ...]
    description: str = ""


CheckFn = Callable[[str, Built], List[Finding]]

CHECKS: Dict[str, CheckFn] = {}
CONTRACTS: Dict[str, Contract] = {}


def register_check(name: str) -> Callable[[CheckFn], CheckFn]:
    def deco(fn: CheckFn) -> CheckFn:
        if name in CHECKS and CHECKS[name] is not fn:
            raise ValueError(f"check {name!r} already registered")
        CHECKS[name] = fn
        return fn
    return deco


def register_contract(
    name: str, checks: Sequence[str], description: str = ""
) -> Callable[[Callable[[], Built]], Callable[[], Built]]:
    """Decorator declaring a program contract at its jit site."""
    def deco(build: Callable[[], Built]) -> Callable[[], Built]:
        if name in CONTRACTS and CONTRACTS[name].build is not build:
            raise ValueError(f"contract {name!r} already registered")
        CONTRACTS[name] = Contract(
            name=name, build=build, checks=tuple(checks),
            description=description,
        )
        return build
    return deco
