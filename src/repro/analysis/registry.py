"""Pluggable registries for lint checks and program contracts.

A **contract** is declared next to the jit site it describes (scheduler,
``lm.prefill_paged``, ``train_step``, ``nsga2.run_batched``, the Pallas
kernels): a build function that constructs the program at a miniature
configuration and returns the artifacts the checks need — compiled HLO
with the declared donated buffers, a hot callable to replay under a
transfer guard, recorded abstract call signatures, traced Pallas jaxprs.
Checks never import the modules they verify; they see only
:class:`Built`.

A **check** is a function ``(contract_name, Built) -> [Finding]``
registered under a short name.  The lint runner intersects each
contract's declared ``checks`` with the requested set, so a contract is
only exercised by checks it opted into.

This module is deliberately import-light (stdlib only): hot modules
import it at module scope to declare their contracts, and must not pay
for — or cycle into — jax-level helpers, which live in
``analysis.jaxpr_tools`` / ``analysis.hlo``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .findings import Finding


class ContractSkip(Exception):
    """Raised by a contract build to opt out at runtime (e.g. a mesh
    contract on a single-device host).  Reported as an ``info`` finding,
    never a failure."""


@dataclasses.dataclass
class CompiledUnit:
    """One lowered+compiled program, for artifact-level (HLO) checks.

    ``donated`` describes the buffers the call site donates — dicts with
    ``path``/``shape``/``dtype``/``nbytes`` (see
    ``jaxpr_tools.donated_leaves``).  ``shard_divisors`` widens the
    donation byte-match for SPMD programs whose post-partition parameter
    shapes are the global shape divided across devices."""
    label: str
    hlo: str                                        # compiled.as_text()
    donated: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    donate_min_bytes: int = 0
    shard_divisors: Tuple[int, ...] = (1,)
    compile_warnings: List[str] = dataclasses.field(default_factory=list)
    # per-collective byte budgets, e.g. {"all-gather": 1 << 20}; 0 forbids
    collective_budget: Optional[Dict[str, int]] = None


@dataclasses.dataclass
class Replay:
    """Abstract call signatures recorded while replaying a host loop
    against the real jitted programs (see the serve contract)."""
    # (program label, canonical abstract signature) per recorded call
    signatures: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    # per-label budget of DISTINCT signatures; a label absent here is
    # unbudgeted (reported, not enforced)
    max_programs: Dict[str, int] = dataclasses.field(default_factory=dict)
    # live jit-cache sizes vs budget (e.g. Scheduler.compile_counts())
    live_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    live_budget: Dict[str, int] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class PallasTrace:
    """One traced kernel entry point for the Pallas tiling check."""
    label: str
    closed_jaxpr: Any                       # jax.core.ClosedJaxpr
    # whether this kernel's public wrapper falls back to interpreter
    # mode on the current backend (info finding, error on TPU)
    interpret_fallback: bool = False


@dataclasses.dataclass
class Built:
    """Everything a contract hands to the checks."""
    compiled: List[CompiledUnit] = dataclasses.field(default_factory=list)
    hot: Optional[Callable[[], Any]] = None         # transfer-guard target
    hot_label: str = "hot path"
    # (label, ClosedJaxpr) traced hot programs for the jaxpr walks
    hot_jaxprs: List[Tuple[str, Any]] = dataclasses.field(default_factory=list)
    replay: Optional[Replay] = None
    pallas: List[PallasTrace] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class Contract:
    name: str
    build: Callable[[], Built]
    checks: Tuple[str, ...]
    description: str = ""


CheckFn = Callable[[str, Built], List[Finding]]

CHECKS: Dict[str, CheckFn] = {}
CONTRACTS: Dict[str, Contract] = {}


def register_check(name: str) -> Callable[[CheckFn], CheckFn]:
    def deco(fn: CheckFn) -> CheckFn:
        if name in CHECKS and CHECKS[name] is not fn:
            raise ValueError(f"check {name!r} already registered")
        CHECKS[name] = fn
        return fn
    return deco


def register_contract(
    name: str, checks: Sequence[str], description: str = ""
) -> Callable[[Callable[[], Built]], Callable[[], Built]]:
    """Decorator declaring a program contract at its jit site."""
    def deco(build: Callable[[], Built]) -> Callable[[], Built]:
        if name in CONTRACTS and CONTRACTS[name].build is not build:
            raise ValueError(f"contract {name!r} already registered")
        CONTRACTS[name] = Contract(
            name=name, build=build, checks=tuple(checks),
            description=description,
        )
        return build
    return deco
