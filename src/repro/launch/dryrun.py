import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the
# device count at first initialization).  Everything below is normal.
"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape) cell, lower + compile the real
train/prefill/decode step against abstract inputs on the production mesh
(single-pod 16x16 and multi-pod 2x16x16), record memory_analysis() /
cost_analysis() / the post-SPMD collective schedule, and persist a JSON
record per cell for the roofline layer.

``--tp N`` switches decode cells to the tensor-parallel sharded SERVING
program (the paged decode ``serve.Scheduler(tp=N)`` runs) on a 1-D
N-wide ``("model",)`` mesh — cells keyed ``{arch}__{shape}__tpN``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                    # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b \
      --shape train_4k --mesh both --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b \
      --shape decode_32k --tp 8 --out results/dryrun
"""
# (no __future__ import: the XLA_FLAGS lines must be the first statements)
import argparse
import pathlib
import re
import time
import traceback
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.core.results import ResultStore
from repro.dist import sharding as shd
from repro.launch import specs as specs_mod
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.models.config import LMConfig
from repro.optim import get_optimizer
from repro.train.train_step import make_train_step

# Archs whose parameter count makes full-Adam moments unaffordable at
# 512 chips -> factored second moments (see DESIGN.md §5).
ADAFACTOR_ARCHS = {"qwen2-vl-72b", "deepseek-v3-671b", "jamba-v0.1-52b"}


# ----------------------------- sharding helpers ------------------------------
_CACHE_LOGICAL = {
    "k": (None, "batch", None, "kv_heads", "head_dim"),
    "v": (None, "batch", None, "kv_heads", "head_dim"),
    "c_kv": (None, "batch", None, "tp"),
    "k_rope": (None, "batch", None, None),
    "h": (None, "batch", "d_inner", None),
    "conv": (None, "batch", None, "d_inner"),
}

_BATCH_LOGICAL = {
    "tokens": ("batch", None),
    "targets": ("batch", None),
    "loss_mask": ("batch", None),
    "embeds": ("batch", "seq_sp", None),
    "position_ids": (None, "batch", None),
}


def _leaf_key(path) -> str:
    for p in reversed(path):
        if hasattr(p, "key"):
            return str(p.key)
    return ""


def cache_shardings(cache_shapes, ctx: shd.MeshContext):
    def one(path, leaf):
        logical = _CACHE_LOGICAL.get(_leaf_key(path), (None,) * len(leaf.shape))
        return ctx.sharding(logical, leaf.shape)

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def batch_shardings(batch_shapes, ctx: shd.MeshContext):
    def one(path, leaf):
        logical = _BATCH_LOGICAL.get(_leaf_key(path), (None,) * len(leaf.shape))
        return ctx.sharding(logical, leaf.shape)

    return jax.tree_util.tree_map_with_path(one, batch_shapes)


_MOMENT_SUFFIX = re.compile(r"/(m|v|err)$")
_FACTORED_ROW = re.compile(r"/vr$")
_FACTORED_COL = re.compile(r"/vc$")
_QUANT = re.compile(r"/(m_q|m_s|v_q|v_s)$")


def state_shardings(state_shapes, ctx: shd.MeshContext):
    """Shardings for the full TrainState: params by PARAM_RULES; optimizer
    moments inherit their parameter's logical axes (factored moments drop
    the corresponding reduced dim)."""

    def one(path, leaf):
        pstr = shd._path_str(path)
        ndim = len(leaf.shape)
        base = pstr
        transform = None
        if _QUANT.search(pstr):
            return ctx.sharding((None,) * ndim, leaf.shape)
        if _FACTORED_ROW.search(pstr):
            base = _FACTORED_ROW.sub("", pstr)
            transform = "row"
        elif _FACTORED_COL.search(pstr):
            base = _FACTORED_COL.sub("", pstr)
            transform = "col"
        elif _MOMENT_SUFFIX.search(pstr):
            base = _MOMENT_SUFFIX.sub("", pstr)
        base = base.replace("/mu/", "/params/")
        logical = shd.logical_for_path(
            base, ndim if transform is None else ndim + 1
        )
        if transform == "row":          # vr: param shape minus last dim
            logical = logical[:-1]
        elif transform == "col":        # vc: minus second-to-last dim
            logical = logical[:-2] + logical[-1:]
        if len(logical) != ndim:
            logical = (None,) * ndim
        return ctx.sharding(logical, leaf.shape)

    return jax.tree_util.tree_map_with_path(one, state_shapes)


# ----------------------------- cell construction -------------------------------
def build_train_cell(cfg: LMConfig, shape, mesh):
    ctx = shd.MeshContext(mesh)
    opt = get_optimizer(
        "adafactor" if cfg.name in ADAFACTOR_ARCHS else "adamw", 1e-4
    )
    step = make_train_step(cfg, opt)

    def init_fn(key):
        params = lm.init(key, cfg)
        return {"params": params, "opt": opt.init(params),
                "step": jnp.zeros((), jnp.int32)}

    state_shapes = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
    batch_shapes = specs_mod.batch_struct(cfg, "train", shape.global_batch, shape.seq_len)
    in_sh = (state_shardings(state_shapes, ctx), batch_shardings(batch_shapes, ctx))
    out_sh = (in_sh[0], None)
    fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                 donate_argnums=(0,))
    return fn, (state_shapes, batch_shapes)


def build_prefill_cell(cfg: LMConfig, shape, mesh):
    ctx = shd.MeshContext(mesh)
    params_shapes = jax.eval_shape(partial(lm.init, cfg=cfg), jax.random.PRNGKey(0))
    batch_shapes = specs_mod.batch_struct(cfg, "prefill", shape.global_batch, shape.seq_len)
    p_sh = state_shardings(params_shapes, ctx)
    b_sh = batch_shardings(batch_shapes, ctx)

    def prefill_fn(params, batch):
        return lm.prefill(params, batch, cfg, max_len=shape.seq_len)

    fn = jax.jit(prefill_fn, in_shardings=(p_sh, b_sh))
    return fn, (params_shapes, batch_shapes)


def build_decode_cell(cfg: LMConfig, shape, mesh):
    ctx = shd.MeshContext(mesh)
    B, S = shape.global_batch, shape.seq_len
    params_shapes = jax.eval_shape(partial(lm.init, cfg=cfg), jax.random.PRNGKey(0))
    cache_shapes = jax.eval_shape(partial(lm.init_cache, cfg, B, S))
    inputs_shapes = specs_mod.batch_struct(cfg, "decode", B, S)
    p_sh = state_shardings(params_shapes, ctx)
    c_sh = cache_shardings(cache_shapes, ctx)
    i_sh = batch_shardings(inputs_shapes, ctx)
    pos_sh = NamedSharding(mesh, P())

    def serve_step(params, inputs, pos, caches):
        return lm.decode_step(params, inputs, pos, caches, cfg)

    fn = jax.jit(
        serve_step,
        in_shardings=(p_sh, i_sh, pos_sh, c_sh),
        out_shardings=(None, c_sh),
        donate_argnums=(3,),
    )
    pos_shape = jax.ShapeDtypeStruct((), jnp.int32)
    return fn, (params_shapes, inputs_shapes, pos_shape, cache_shapes)


def build_decode_tp_cell(cfg: LMConfig, shape, mesh, page_size: int = 16):
    """The tensor-parallel PAGED serving decode program — the program
    ``serve.Scheduler(tp=N)`` actually runs — on a 1-D ``("model",)``
    mesh: params laid out by the output-dim-only serving rules, K/V
    pages head-sharded, block tables / positions / inputs replicated
    (they are host-driven state), and the returned pool pinned back to
    its input layout so donation aliases without a relayout.

    Must be traced under ``shd.serving_context(mesh)`` (run_cell does
    this) so the in-model ``repl_act`` gathers are live — they are what
    keeps every contraction full-length and the tokens bitwise equal to
    single-device serving."""
    B, S = shape.global_batch, shape.seq_len
    n_pages = 1 + B * (S // page_size)
    params_shapes = jax.eval_shape(partial(lm.init, cfg=cfg), jax.random.PRNGKey(0))
    pool_shapes = jax.eval_shape(
        partial(lm.init_paged_pool, cfg, B, n_pages, page_size)
    )
    inputs_shapes = specs_mod.batch_struct(cfg, "decode", B, S)
    p_sh = shd.serve_param_sharding_tree(params_shapes, mesh)
    pool_sh = shd.serve_pool_sharding_tree(pool_shapes, mesh)
    repl = NamedSharding(mesh, P())
    i_sh = jax.tree.map(lambda _: repl, inputs_shapes)

    def serve_step(params, inputs, pos, pool, block_tables):
        return lm.decode_step_paged(params, inputs, pos, pool,
                                    block_tables, cfg)

    fn = jax.jit(
        serve_step,
        in_shardings=(p_sh, i_sh, repl, pool_sh, repl),
        out_shardings=(repl, pool_sh),
        donate_argnums=(3,),
    )
    pos_shape = jax.ShapeDtypeStruct((B,), jnp.int32)
    bt_shape = jax.ShapeDtypeStruct((B, S // page_size), jnp.int32)
    return fn, (params_shapes, inputs_shapes, pos_shape, pool_shapes,
                bt_shape)


# ----------------------------- analysis ----------------------------------------
# Shape/dtype parsing and the collective taxonomy live in
# repro.launch.hlo_analysis (shared with repro.analysis); this module
# keeps only the naive whole-text scan for the "collectives_naive"
# record field.
from repro.launch.hlo_analysis import _COLLECTIVES, op_output_bytes


def collective_stats(hlo_text: str) -> Dict[str, Any]:
    """Per-collective-type op counts + output bytes (per-device, post-SPMD).

    Naive: every op line counts once, regardless of loop trip counts —
    ``rec["analysis"]`` (``analyze_hlo``) holds the trip-aware totals."""
    stats = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.search(r"=\s*(?:\([^)]*\)\s*)?([a-z0-9-]+)", ls)
        if not m:
            continue
        op = m.group(1)
        # normalize: all-gather-start, all-reduce-done, etc.
        for coll in _COLLECTIVES:
            if op == coll or op.startswith(coll + "-"):
                if op.endswith("-done"):
                    break  # counted at -start
                stats[coll]["count"] += 1
                stats[coll]["bytes"] += op_output_bytes(ls)
                break
    return stats


def analyze_compiled(lowered, compiled, hlo_path: Optional[pathlib.Path] = None) -> Dict[str, Any]:
    rec: Dict[str, Any] = {}
    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)
        }
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        rec["cost"] = {
            "flops": float(cost.get("flops", -1)),
            "bytes_accessed": float(cost.get("bytes accessed", -1)),
            "transcendentals": float(cost.get("transcendentals", -1)),
        }
    except Exception as e:  # pragma: no cover
        rec["cost"] = {"error": str(e)}
    try:
        from repro.launch.hlo_analysis import analyze_hlo

        hlo = compiled.as_text()
        rec["hlo_ops"] = len(hlo.splitlines())
        rec["collectives_naive"] = collective_stats(hlo)
        # Trip-count-aware analysis (cost_analysis counts while bodies once).
        rec["analysis"] = analyze_hlo(hlo)
    except Exception as e:  # pragma: no cover
        rec["analysis"] = {"error": str(e)}
        return rec
    if hlo_path is not None:
        # Persist compressed HLO so §Perf iterations can re-analyze
        # offline without recompiling.  Persistence is best-effort and
        # must never clobber the computed analysis: zstandard is an
        # optional dependency (the ``hlo`` extra) and the write can fail.
        try:
            import zstandard

            hlo_path.write_bytes(
                zstandard.ZstdCompressor(level=6).compress(hlo.encode())
            )
        except Exception as e:  # pragma: no cover
            rec["hlo_persist_error"] = f"{type(e).__name__}: {e}"
    return rec


# ----------------------------- runner -------------------------------------------
# Stderr capture moved to repro.analysis.remat (shared with the lint's
# collectives/remat check); dryrun keeps the per-cell remat_warnings
# count and the stderr tail on FAILED cells.
from repro.analysis.remat import REMAT_WARNING
from repro.analysis.remat import capture_fd_stderr as _capture_fd_stderr


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: pathlib.Path, force: bool = False,
             overrides: Optional[Dict[str, Any]] = None,
             tp: int = 0) -> Dict[str, Any]:
    mesh_tag = f"tp{tp}" if tp else ("pod2x16x16" if multi_pod else "pod16x16")
    store = ResultStore(out_dir)
    name = f"{arch}__{shape_name}__{mesh_tag}"
    if name in store and not force:
        return store.get(name)

    entry = configs.entry(arch)
    shape = configs.SHAPES[shape_name]
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "kind": shape.kind, "seq_len": shape.seq_len,
        "global_batch": shape.global_batch, "status": "pending",
    }
    if shape_name not in entry.shape_names():
        rec["status"] = "skipped:full-attention-500k"
        store.put(name, rec, kind="dryrun")
        return rec
    if tp and shape.kind != "decode":
        rec["status"] = "skipped:tp-decode-only"
        store.put(name, rec, kind="dryrun")
        return rec

    if tp:
        mesh = jax.make_mesh((tp,), ("model",))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = entry.config(**(overrides or {}))
    if overrides:
        rec["overrides"] = dict(overrides)
    t_cell = time.time()
    try:
        captured: Dict[str, str] = {"text": ""}
        trace_ctx = shd.serving_context(mesh) if tp else mesh
        with shd.use_mesh(trace_ctx), _capture_fd_stderr(captured):
            t0 = time.time()
            if tp:
                fn, args = build_decode_tp_cell(cfg, shape, mesh)
            elif shape.kind == "train":
                fn, args = build_train_cell(cfg, shape, mesh)
            elif shape.kind == "prefill":
                fn, args = build_prefill_cell(cfg, shape, mesh)
            else:
                fn, args = build_decode_cell(cfg, shape, mesh)
            lowered = fn.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        rec["remat_warnings"] = captured["text"].count(REMAT_WARNING)
        rec.update(
            analyze_compiled(
                lowered, compiled,
                hlo_path=store.path(name).with_suffix(".hlo.zst"),
            )
        )
        rec["lower_s"] = round(t1 - t0, 2)
        rec["compile_s"] = round(t2 - t1, 2)
        rec["n_devices"] = mesh.devices.size
        rec["status"] = "ok"
        print(compiled.memory_analysis())
        cost = rec.get("cost", {})
        print(f"[{arch} x {shape_name} x {mesh_tag}] OK "
              f"flops={cost.get('flops'):.3e} lower={rec['lower_s']}s "
              f"compile={rec['compile_s']}s")
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        rec["remat_warnings"] = captured["text"].count(REMAT_WARNING)
        if captured["text"]:
            rec["stderr_tail"] = captured["text"][-4000:]
        print(f"[{arch} x {shape_name} x {mesh_tag}] FAILED: {rec['error']}")
    store.put(name, rec, kind="dryrun", wall_s=time.time() - t_cell)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--fail-on-remat", action="store_true",
                    help="exit nonzero if any cell compiled with XLA "
                         "'Involuntary full rematerialization' warnings "
                         "(missing/contradictory sharding annotations)")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (e.g. ssm_impl=pallas)")
    ap.add_argument("--tp", type=int, default=0,
                    help="compile decode cells as tensor-parallel sharded "
                         "serving programs (the Scheduler(tp=N) paged "
                         "decode) on a 1-D N-wide ('model',) mesh instead "
                         "of the production pod meshes; non-decode shapes "
                         "are skipped")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
        overrides[k] = v

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    archs = [args.arch] if args.arch else configs.ARCH_NAMES
    if args.tp:
        meshes = [False]        # one tp-mesh pass; --mesh is pod-only
    else:
        meshes = {"single": [False], "multi": [True],
                  "both": [False, True]}[args.mesh]

    n_ok = n_fail = n_skip = n_remat = 0
    for arch in archs:
        shapes = [args.shape] if args.shape else list(configs.SHAPES)
        for shape_name in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape_name, mp, out_dir,
                               force=args.force, overrides=overrides,
                               tp=args.tp)
                s = rec["status"]
                n_ok += s == "ok"
                n_fail += s == "error"
                n_skip += s.startswith("skipped")
                w = rec.get("remat_warnings", 0)
                if w:
                    n_remat += w
                    print(f"[{rec['arch']} x {rec['shape']} x {rec['mesh']}] "
                          f"{w} involuntary-rematerialization warning(s)")
    print(f"dry-run summary: ok={n_ok} failed={n_fail} skipped={n_skip} "
          f"remat_warnings={n_remat}")
    if args.fail_on_remat and n_remat:
        print("FAIL: involuntary full rematerializations — enrich the "
              "sharding annotations (see ROADMAP dry-run notes)")
        return 1
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
