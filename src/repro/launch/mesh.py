"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so that
importing this module never touches jax device state — required because
the dry-run must set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips ("data", "model").
    Multi-pod:  (2, 16, 16) = 512 chips ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Whatever devices exist, as ("data", "model") — for tests/examples."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
