"""Per-op breakdown of a dry-run cell's stored HLO: top contributors to
FLOPs / HBM bytes / collective bytes, with while-trip multipliers — the
"profile" used by the §Perf hypothesis loop (no real hardware here, so
the lowered IR is the profile, per the brief).

    PYTHONPATH=src python -m repro.launch.breakdown \
        results/dryrun/deepseek-v3-671b__train_4k__pod16x16.hlo.zst --top 15
"""
from __future__ import annotations

import argparse
import pathlib
import re
from collections import defaultdict
from typing import Dict, List, Tuple

try:
    import zstandard
except ModuleNotFoundError:  # optional: only .hlo.zst inputs need it
    zstandard = None

from .hlo_analysis import (
    _COLLECTIVES, _CONTRACT_RE, _OPERAND_RE, _shape_bytes, _shape_elems,
    _first_dims, _trip_count, HloAnalyzer, parse_computations,
)


def op_breakdown(text: str) -> Dict[str, List[Tuple[float, str]]]:
    comps = parse_computations(text)
    an = HloAnalyzer(text)

    # Effective multiplier per computation (product of enclosing trips).
    mult: Dict[str, float] = defaultdict(float)

    def walk(name: str, m: float):
        comp = comps.get(name)
        if comp is None:
            return
        mult[name] += m
        for op in comp.ops:
            if op.opcode == "while":
                body = re.search(r"body=%?([\w\.\-]+)", op.rest)
                cond = re.search(r"condition=%?([\w\.\-]+)", op.rest)
                trips = 1
                if cond and cond.group(1) in comps:
                    trips = _trip_count(comps[cond.group(1)], comp.symtab, op.rest)
                if body:
                    walk(body.group(1), m * trips)
            elif op.opcode in ("fusion", "call", "reduce", "map"):
                mm = re.search(r"(?:calls|to_apply)=%?([\w\.\-]+)", op.rest)
                if mm and mm.group(1) in comps:
                    walk(mm.group(1), m)

    entry = an.entry
    walk(entry, 1.0)

    flops: List[Tuple[float, str]] = []
    mem: List[Tuple[float, str]] = []
    coll: List[Tuple[float, str]] = []
    for cname, m in mult.items():
        comp = comps[cname]
        for op in comp.ops:
            meta = re.search(r'op_name="([^"]+)"', op.rest)
            tag = (meta.group(1)[-80:] if meta else op.name)
            label = f"{op.opcode:<12} {op.shape[:38]:<40} x{m:<6.0f} {tag}"
            if op.opcode == "dot":
                f = an._dot_flops(comp, op) * m
                flops.append((f, label))
            if op.opcode == "dynamic-update-slice" or an._is_dus_fusion(op):
                sizes = sorted(
                    _shape_bytes(comp.symtab.get(r, ""))
                    for r in _OPERAND_RE.findall(op.rest.split(")")[0])
                )
                moved = sum(sizes[:-1]) if len(sizes) > 1 else 0
                mem.append((2 * moved * m, label))
            elif op.opcode in ("dynamic-slice", "gather", "slice") or \
                    an._is_ds_fusion(op):
                mem.append((2 * _shape_bytes(op.shape) * m, label))
            elif op.opcode not in ("parameter", "constant", "get-tuple-element",
                                   "tuple", "bitcast", "after-all", "while",
                                   "conditional", "call", "convert"):
                ob = _shape_bytes(op.shape)
                head = op.rest.split(")")[0]
                opnd = sum(
                    _shape_bytes(comp.symtab.get(r, ""))
                    for r in _OPERAND_RE.findall(head)
                )
                mem.append(((ob + opnd) * m, label))
            for c in _COLLECTIVES:
                if op.opcode == c or op.opcode == c + "-start":
                    coll.append((_shape_bytes(op.shape) * m, label))
                    break
    for lst in (flops, mem, coll):
        lst.sort(key=lambda t: -t[0])
    return {"flops": flops, "mem": mem, "coll": coll}


def load_hlo(path: str) -> str:
    p = pathlib.Path(path)
    raw = p.read_bytes()
    if p.suffix == ".zst":
        if zstandard is None:
            raise ModuleNotFoundError(
                "reading .hlo.zst requires the optional 'zstandard' package"
            )
        raw = zstandard.ZstdDecompressor().decompress(raw)
    return raw.decode()


def main():  # pragma: no cover
    ap = argparse.ArgumentParser()
    ap.add_argument("hlo")
    ap.add_argument("--top", type=int, default=12)
    args = ap.parse_args()
    text = load_hlo(args.hlo)
    bd = op_breakdown(text)
    for section, unit, scale in (("flops", "GFLOP", 1e9), ("mem", "GB", 1e9),
                                 ("coll", "GB", 1e9)):
        rows = bd[section][: args.top]
        total = sum(v for v, _ in bd[section])
        print(f"\n== top {section} (total {total / scale:.2f} {unit}) ==")
        for v, label in rows:
            print(f"  {v / scale:>10.3f} {unit}  {label}")


if __name__ == "__main__":  # pragma: no cover
    main()
