"""Roofline analysis (deliverable g).

Reads the per-cell dry-run JSONs and derives, per (arch x shape) on the
single-pod mesh, the three roofline terms **per device per step**:

  compute    = HLO_dot_FLOPs / peak_FLOPs            (197 TFLOP/s bf16)
  memory     = HLO_mem_bytes / HBM_bw                (819 GB/s)
  collective = sum over collective ops of
                 bytes * ring_factor / link_bw       (~50 GB/s/link)

HLO_* come from the trip-count-aware analyzer (launch/hlo_analysis);
XLA's own cost_analysis (body-once) is kept for reference.  The ring
factor models per-device wire traffic: all-gather/reduce-scatter move
(n-1)/n of the payload, all-reduce 2(n-1)/n, all-to-all (n-1)/n, and
collective-permute 1.  Since axis membership per op is not recovered
from HLO, n is taken as the mesh size (upper bound, noted in
EXPERIMENTS.md).

MODEL_FLOPS uses 6*N_active*tokens (train) / 2*N_active*tokens
(prefill & decode) per the brief; the MODEL/HLO ratio flags
remat/redundancy waste.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Dict, List, Optional

from repro import configs
from repro.models.config import LMConfig
from repro.models.mamba import ssm_dims

PEAK_FLOPS = 197e12         # bf16 / chip
HBM_BW = 819e9              # bytes/s / chip
ICI_BW = 50e9               # bytes/s / link


# ---------------------------- parameter counting ------------------------------
def param_counts(cfg: LMConfig) -> Dict[str, float]:
    """Total and per-token-active parameter counts (analytic)."""
    D = cfg.d_model
    hd = cfg.hd
    total = active = 0.0

    for i in range(cfg.n_layers):
        mk, fk = cfg.mixer_kind(i), cfg.ffn_of(i)
        if mk == "gqa":
            p = D * cfg.n_heads * hd + 2 * D * cfg.n_kv * hd + cfg.n_heads * hd * D
        elif mk == "mla":
            m = cfg.mla
            p = (D * m.q_lora_rank
                 + m.q_lora_rank * cfg.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
                 + D * (m.kv_lora_rank + m.qk_rope_dim)
                 + m.kv_lora_rank * cfg.n_heads * (m.qk_nope_dim + m.v_head_dim)
                 + cfg.n_heads * m.v_head_dim * D)
        else:
            d_inner, dt_rank = ssm_dims(cfg)
            s = cfg.ssm
            p = (D * 2 * d_inner + d_inner * (dt_rank + 2 * s.d_state)
                 + dt_rank * d_inner + d_inner * D + d_inner * (s.d_conv + s.d_state + 2))
        total += p
        active += p

        if fk == "dense":
            f = (3 if cfg.act == "swiglu" else 2) * D * cfg.d_ff
            total += f
            active += f
        elif fk == "moe":
            m = cfg.moe
            per_expert = 3 * D * m.d_ff
            total += m.n_experts * per_expert + D * m.n_experts
            active += m.top_k * per_expert + D * m.n_experts
            if m.n_shared:
                sh = 3 * D * (m.d_ff * m.n_shared)
                total += sh
                active += sh

    if cfg.mtp:
        # depth-1 MTP: one extra block (same structure as layer 0) + proj
        one_layer = (total / cfg.n_layers) if cfg.n_layers else 0.0
        total += one_layer + 2 * D * D
        active += one_layer + 2 * D * D

    emb = cfg.vocab_size * D
    if not cfg.external_embed:
        total += emb
        active += emb
    if not cfg.tie_embeddings:
        total += emb       # head
        active += emb
    return {"total": total, "active": active}


def model_flops(cfg: LMConfig, kind: str, seq: int, batch: int) -> float:
    pc = param_counts(cfg)
    n_active = pc["active"]
    if kind == "train":
        tokens = seq * batch
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = seq * batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * batch


_RING_FACTOR = {
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-reduce": 2.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


@dataclasses.dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    status: str
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    hlo_flops: float = 0.0
    model_flops: float = 0.0
    flops_ratio: float = 0.0        # MODEL / HLO (per step, global)
    roofline_fraction: float = 0.0  # max-term time vs compute-bound ideal
    coll_bytes: float = 0.0
    mem_bytes: float = 0.0
    device_bytes: float = 0.0       # args+temps per device (fits-in-HBM check)
    note: str = ""


def analyze_record(rec: dict) -> RooflineRow:
    row = RooflineRow(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        status=rec.get("status", "?"),
    )
    if rec.get("status") != "ok" or "analysis" not in rec:
        row.note = rec.get("error", rec.get("status", ""))
        return row
    a = rec["analysis"]
    n_dev = rec.get("n_devices", 256)
    cfg = configs.entry(rec["arch"]).config()
    kind = rec["kind"]

    flops_dev = a["dot_flops"] + a.get("elem_flops", 0.0)
    row.hlo_flops = flops_dev * n_dev
    row.model_flops = model_flops(cfg, kind, rec["seq_len"], rec["global_batch"])
    row.flops_ratio = row.model_flops / max(row.hlo_flops, 1.0)

    row.compute_s = flops_dev / PEAK_FLOPS
    row.mem_bytes = a.get("mem_bytes", 0.0)
    row.memory_s = row.mem_bytes / HBM_BW
    coll_s = 0.0
    coll_b = 0.0
    n = n_dev
    for k, v in a["collectives"].items():
        eff = v["bytes"] * _RING_FACTOR[k] * (n - 1) / n
        coll_s += eff / ICI_BW
        coll_b += v["bytes"]
    row.collective_s = coll_s
    row.coll_bytes = coll_b

    terms = {"compute": row.compute_s, "memory": row.memory_s,
             "collective": row.collective_s}
    row.bottleneck = max(terms, key=terms.get)
    ideal = row.model_flops / (n_dev * PEAK_FLOPS)
    worst = max(terms.values())
    row.roofline_fraction = ideal / worst if worst > 0 else 0.0

    mem = rec.get("memory", {})
    if isinstance(mem, dict) and "temp_size_in_bytes" in mem:
        row.device_bytes = (
            mem.get("argument_size_in_bytes", 0)
            + mem.get("temp_size_in_bytes", 0)
        )
    return row


def load_rows(dryrun_dir="results/dryrun", mesh: Optional[str] = "pod16x16") -> List[RooflineRow]:
    rows = []
    for f in sorted(pathlib.Path(dryrun_dir).glob("*.json")):
        rec = json.loads(f.read_text())
        if mesh and rec.get("mesh") != mesh:
            continue
        # Prefer re-analyzing stored HLO (analyzer improvements apply
        # retroactively without recompiling).
        hlo_f = f.with_suffix(".hlo.zst")
        if rec.get("status") == "ok" and hlo_f.exists():
            import zstandard

            from repro.launch.hlo_analysis import analyze_hlo

            text = zstandard.ZstdDecompressor().decompress(
                hlo_f.read_bytes()
            ).decode()
            rec["analysis"] = analyze_hlo(text)
        rows.append(analyze_record(rec))
    return rows


def format_table(rows: List[RooflineRow]) -> str:
    hdr = (f"{'arch':<22}{'shape':<13}{'status':<9}{'compute_s':>11}"
           f"{'memory_s':>11}{'coll_s':>11}{'bottleneck':>12}"
           f"{'MODEL/HLO':>10}{'roofline%':>10}")
    out = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.status != "ok":
            out.append(f"{r.arch:<22}{r.shape:<13}{r.status:<9}  {r.note[:60]}")
            continue
        out.append(
            f"{r.arch:<22}{r.shape:<13}{r.status:<9}"
            f"{r.compute_s:>11.4f}{r.memory_s:>11.4f}{r.collective_s:>11.4f}"
            f"{r.bottleneck:>12}{r.flops_ratio:>10.3f}"
            f"{100 * r.roofline_fraction:>9.1f}%"
        )
    return "\n".join(out)


def format_markdown(rows: List[RooflineRow]) -> str:
    out = [
        "| arch | shape | status | compute_s | memory_s | coll_s |"
        " bottleneck | MODEL/HLO | roofline | HBM GB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.status != "ok":
            out.append(f"| {r.arch} | {r.shape} | {r.status} | | | | | | | |")
            continue
        gb = r.device_bytes / 2**30
        fits = "" if gb <= 16 else " ⚠"
        out.append(
            f"| {r.arch} | {r.shape} | {r.status} | {r.compute_s:.4f} |"
            f" {r.memory_s:.4f} | {r.collective_s:.4f} | {r.bottleneck} |"
            f" {r.flops_ratio:.3f} | {100 * r.roofline_fraction:.1f}% |"
            f" {gb:.1f}{fits} |"
        )
    return "\n".join(out)


def main():  # pragma: no cover
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--json-out", default=None)
    ap.add_argument("--summary", action="store_true",
                    help="emit a markdown table (for EXPERIMENTS.md)")
    args = ap.parse_args()
    rows = load_rows(args.dir, args.mesh)
    print(format_markdown(rows) if args.summary else format_table(rows))
    if args.json_out:
        pathlib.Path(args.json_out).write_text(
            json.dumps([dataclasses.asdict(r) for r in rows], indent=2)
        )


if __name__ == "__main__":  # pragma: no cover
    main()
