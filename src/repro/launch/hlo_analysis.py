"""Trip-count-aware static analysis of post-SPMD HLO.

XLA's ``cost_analysis()`` counts a ``while`` body ONCE — a scanned
80-layer transformer shows ~1-2% of its real FLOPs.  This analyzer
parses ``compiled.as_text()``, builds the computation call graph, reads
each loop's trip count out of its condition computation, and aggregates

  * dot FLOPs (2 * prod(out) * contraction),
  * elementwise/transcendental op counts,
  * per-collective-type bytes and op counts (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute),

each multiplied by the product of enclosing trip counts.  These are the
HLO_FLOPs / collective_bytes inputs to EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s2": 1, "u2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    # fp8 families (1 byte each): XLA prints the full IEEE-style name.
    # Missing entries silently undercounted fp8 collective/dot bytes.
    "f8e4m3": 1, "f8e4m3fn": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "f8e5m2": 1, "f8e5m2fnuz": 1, "f8e3m4": 1, "f8e8m0fnu": 1,
    "token": 0, "opaque": 0,
}

# Dtype tokens mix letters and digits (f8e4m3fn, bf16): match the full
# alphanumeric run, then filter through _DTYPE_BYTES.
_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s+([a-z][\w\-]*)\((.*)$"
)
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "select", "compare", "and", "or", "xor", "not",
    "clamp", "floor", "ceil", "round-nearest-afz", "sign",
}
_TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "logistic",
                   "power", "sine", "cosine", "expm1", "log1p", "erf"}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(shape_text: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(shape_text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _first_dims(shape_text: str) -> List[int]:
    m = _SHAPE_RE.search(shape_text)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    shape: str
    opcode: str
    rest: str        # text after the opening paren (operands + attrs)


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    symtab: Dict[str, str]     # op name -> output shape text


def parse_computations(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        h = _COMP_HEADER.match(line)
        if h:
            cur = Computation(h.group(2), [], {})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, shape, opcode, rest = m.groups()
        cur.ops.append(Op(name, shape, opcode, rest))
        cur.symtab[name] = shape
    return comps


_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CALL_TARGET = re.compile(
    r"(?:calls|to_apply|body|condition|branch_computations)=\{?%?([\w\.\-\{\}, %]+)"
)
_CONST_RE = re.compile(r"constant\((-?\d+)\)")


def _trip_count(cond: Computation, caller_symtab: Dict[str, str],
                call_rest: str) -> int:
    """Extract the loop bound from a while condition computation: the
    scalar s32 constant it compares the counter against.  Falls back to 1
    (conservative) when no constant is found."""
    best = None
    for op in cond.ops:
        if op.opcode == "constant" and "s32[]" in op.shape:
            # op.rest is the text after "constant(" -> e.g. "4), metadata=..."
            m = re.match(r"\s*(-?\d+)\)", op.rest)
            if m:
                val = int(m.group(1))
                if val > 0:
                    best = val if best is None else max(best, val)
    return best if best else 1


@dataclasses.dataclass
class Stats:
    dot_flops: float = 0.0
    elem_flops: float = 0.0
    transcendentals: float = 0.0
    mem_bytes: float = 0.0       # HBM-traffic model: each op/fusion reads
    #                              its operands once and writes its output
    collectives: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=lambda: {
            k: {"count": 0.0, "bytes": 0.0} for k in _COLLECTIVES
        }
    )

    def add(self, other: "Stats", mult: float = 1.0, mem: bool = True):
        self.dot_flops += other.dot_flops * mult
        self.elem_flops += other.elem_flops * mult
        self.transcendentals += other.transcendentals * mult
        if mem:
            self.mem_bytes += other.mem_bytes * mult
        for k in _COLLECTIVES:
            self.collectives[k]["count"] += other.collectives[k]["count"] * mult
            self.collectives[k]["bytes"] += other.collectives[k]["bytes"] * mult


class HloAnalyzer:
    def __init__(self, text: str):
        self.comps = parse_computations(text)
        self.entry = next(
            (c for c in self.comps
             if re.search(rf"^ENTRY\s+%?{re.escape(c)}\b", text, re.M)),
            None,
        )
        if self.entry is None:  # fall back: computation named main*
            for c in self.comps:
                if c.startswith("main"):
                    self.entry = c
                    break
        self._memo: Dict[str, Stats] = {}

    # --- fusion classification -------------------------------------------------
    def _fusion_root(self, op: Op) -> str:
        if op.opcode != "fusion":
            return ""
        m = re.search(r"calls=%?([\w\.\-]+)", op.rest)
        if not m or m.group(1) not in self.comps:
            return ""
        ops = self.comps[m.group(1)].ops
        return ops[-1].opcode if ops else ""

    def _is_dus_fusion(self, op: Op) -> bool:
        """A fusion whose root is a dynamic-update-slice updates a large
        aliased buffer in place (XLA wraps scan-output stacking this way)."""
        return self._fusion_root(op) == "dynamic-update-slice"

    def _is_ds_fusion(self, op: Op) -> bool:
        return self._fusion_root(op) in ("dynamic-slice", "gather", "slice")

    # --- per-op costs ---------------------------------------------------------
    def _dot_flops(self, comp: Computation, op: Op) -> float:
        out_elems = _shape_elems(op.shape)
        m = _CONTRACT_RE.search(op.rest)
        contract = 1
        if m:
            idxs = [int(i) for i in m.group(1).split(",") if i]
            operands = _OPERAND_RE.findall(op.rest.split(")")[0])
            if operands:
                lhs_shape = comp.symtab.get(operands[0], "")
                dims = _first_dims(lhs_shape)
                for i in idxs:
                    if i < len(dims):
                        contract *= dims[i]
        return 2.0 * out_elems * contract

    def _analyze_comp(self, name: str) -> Stats:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Stats()          # break cycles defensively
        comp = self.comps.get(name)
        if comp is None:
            return self._memo[name]
        st = Stats()
        for op in comp.ops:
            oc = op.opcode
            if oc == "dot":
                st.dot_flops += self._dot_flops(comp, op)
            elif oc == "convolution":
                # flops ~ 2 * out_elems * (kernel elems); approximate with
                # output * input feature window if available — rare here.
                st.dot_flops += 2.0 * _shape_elems(op.shape)
            elif oc in _ELEMENTWISE:
                st.elem_flops += _shape_elems(op.shape)
            elif oc in _TRANSCENDENTAL:
                st.transcendentals += _shape_elems(op.shape)
            for coll in _COLLECTIVES:
                if oc == coll or oc == coll + "-start":
                    st.collectives[coll]["count"] += 1
                    st.collectives[coll]["bytes"] += _shape_bytes(op.shape)
                    break
            # HBM-traffic model: every materializing op reads its operands
            # and writes its output once (fusions = one pass; views free).
            # In-place slicing ops only touch the slice, not the buffer:
            #   dynamic-update-slice: read+write the update region only
            #   dynamic-slice / gather: read+write the output region only
            if oc == "dynamic-update-slice" or self._is_dus_fusion(op):
                # In-place update: read+write the moved region only.  The
                # big aliased buffer (largest operand) is pass-through.
                head = op.rest.split(")")[0]
                sizes = sorted(
                    _shape_bytes(comp.symtab.get(r, ""))
                    for r in _OPERAND_RE.findall(head)
                )
                moved = sum(sizes[:-1]) if len(sizes) > 1 else 0
                st.mem_bytes += 2 * moved
            elif oc in ("dynamic-slice", "gather", "slice") or \
                    self._is_ds_fusion(op):
                st.mem_bytes += 2 * _shape_bytes(op.shape)
            elif oc not in ("parameter", "constant", "get-tuple-element",
                            "tuple", "bitcast", "after-all", "while",
                            "conditional", "call", "convert"):
                # (while/conditional/call bodies are charged recursively;
                # their carried tuples are aliased in place.  `convert` is
                # excluded: XLA:CPU lowers bf16 dots as f32-dot + explicit
                # dtype converts, which the TPU target fuses into the
                # producing/consuming op — counting them would charge the
                # TPU roofline for a CPU lowering artifact.)
                ob = _shape_bytes(op.shape)
                opnd = 0
                head = op.rest.split(")")[0]
                for ref in _OPERAND_RE.findall(head):
                    opnd += _shape_bytes(comp.symtab.get(ref, ""))
                st.mem_bytes += ob + opnd
            # recurse into called computations
            if oc == "while":
                body = re.search(r"body=%?([\w\.\-]+)", op.rest)
                cond = re.search(r"condition=%?([\w\.\-]+)", op.rest)
                trips = 1
                if cond and cond.group(1) in self.comps:
                    trips = _trip_count(self.comps[cond.group(1)], comp.symtab, op.rest)
                if body:
                    st.add(self._analyze_comp(body.group(1)), trips)
                if cond:
                    st.add(self._analyze_comp(cond.group(1)), trips, mem=False)
            elif oc in ("fusion", "call", "custom-call", "reduce", "map",
                        "reduce-window", "scatter", "sort", "select-and-scatter"):
                m = re.search(r"(?:calls|to_apply|select|scatter)=%?([\w\.\-]+)", op.rest)
                if m and m.group(1) in self.comps:
                    # flops from inside; bytes already counted at this site
                    st.add(self._analyze_comp(m.group(1)), 1.0, mem=False)
            elif oc == "conditional":
                for m in re.finditer(r"%([\w\.\-]+)", op.rest):
                    if m.group(1) in self.comps and "region" in m.group(1):
                        st.add(self._analyze_comp(m.group(1)), 1.0)
        self._memo[name] = st
        return st

    def totals(self) -> Stats:
        if self.entry is None:
            return Stats()
        # memo must be recomputed cleanly (cycle-breaking writes zeros first)
        self._memo.clear()
        return self._analyze_comp(self.entry)

    # --- per-site collective walk ---------------------------------------------
    def collective_sites(self) -> List[Dict]:
        """Every collective op site reachable from the entry computation,
        with the product of enclosing while trip counts attached.

        Unlike :meth:`totals` (which aggregates), this keeps one record
        per HLO op so a lint can point at the exact all-gather that blew
        a byte budget — and weight it by how many times the loop runs."""
        sites: List[Dict] = []
        if self.entry is None:
            return sites
        seen = set()

        def visit(name: str, mult: float) -> None:
            comp = self.comps.get(name)
            if comp is None or (name, mult) in seen:
                return
            seen.add((name, mult))
            for op in comp.ops:
                oc = op.opcode
                for coll in _COLLECTIVES:
                    if oc == coll or oc == coll + "-start":
                        sites.append({
                            "collective": coll,
                            "op": op.name,
                            "computation": name,
                            "shape": op.shape,
                            "bytes": _shape_bytes(op.shape),
                            "trip_mult": mult,
                        })
                        break
                if oc == "while":
                    body = re.search(r"body=%?([\w\.\-]+)", op.rest)
                    cond = re.search(r"condition=%?([\w\.\-]+)", op.rest)
                    trips = 1
                    if cond and cond.group(1) in self.comps:
                        trips = _trip_count(
                            self.comps[cond.group(1)], comp.symtab, op.rest
                        )
                    if body:
                        visit(body.group(1), mult * trips)
                elif oc in ("fusion", "call", "custom-call", "conditional",
                            "reduce", "map", "reduce-window", "scatter",
                            "sort", "select-and-scatter"):
                    for m in re.finditer(r"%([\w\.\-]+)", op.rest):
                        if m.group(1) in self.comps:
                            visit(m.group(1), mult)

        visit(self.entry, 1.0)
        return sites


def collective_sites(text: str) -> List[Dict]:
    """Per-site collective listing of an HLO module (see
    :meth:`HloAnalyzer.collective_sites`)."""
    return HloAnalyzer(text).collective_sites()


def op_output_bytes(line: str) -> int:
    """Sum byte sizes of the RESULT shape(s) on one HLO op line — the
    segment between ``=`` and the opcode (tuple shapes included).
    Shared with ``launch.dryrun``'s naive per-line collective counter.

    (The previous version scanned the text *before* ``=``, i.e. the op
    name, and silently returned 0 for every real HLO line.)"""
    m = _OP_RE.match(line)
    if not m:
        return 0
    return _shape_bytes(m.group(2))


def analyze_hlo(text: str) -> dict:
    st = HloAnalyzer(text).totals()
    return {
        "dot_flops": st.dot_flops,
        "elem_flops": st.elem_flops,
        "transcendentals": st.transcendentals,
        "mem_bytes": st.mem_bytes,
        "collectives": st.collectives,
        "collective_bytes_total": sum(
            v["bytes"] for v in st.collectives.values()
        ),
    }
