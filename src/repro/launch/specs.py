"""Input specs: abstract (ShapeDtypeStruct) stand-ins for every model
input, per (arch-config x shape x step-kind), plus concrete batch makers
for smoke tests and the training example.

``abstract_batch`` never allocates — it is what the multi-pod dry-run
lowers against.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import LMConfig


def _sds(shape, dtype, sharding=None):
    if sharding is not None:
        return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_struct(cfg: LMConfig, kind: str, batch: int, seq: int) -> Dict[str, Any]:
    """Abstract input pytree for one step kind.

    train:   full batch with targets
    prefill: prompt only
    decode:  one new token (seq == S_max of the existing cache)
    """
    s = 1 if kind == "decode" else seq
    out: Dict[str, Any] = {}
    if cfg.external_embed:
        out["embeds"] = _sds((batch, s, cfg.d_model), cfg.cdtype)
    else:
        out["tokens"] = _sds((batch, s), jnp.int32)
    if cfg.pos == "mrope":
        out["position_ids"] = _sds((3, batch, s), jnp.int32)
    if kind == "train":
        out["targets"] = _sds((batch, seq), jnp.int32)
    return out


def concrete_batch(
    cfg: LMConfig, kind: str, batch: int, seq: int, seed: int = 0
) -> Dict[str, Any]:
    """Deterministic synthetic batch with the same pytree as batch_struct."""
    rng = np.random.default_rng(seed)
    s = 1 if kind == "decode" else seq
    out: Dict[str, Any] = {}
    if cfg.external_embed:
        out["embeds"] = jnp.asarray(
            rng.normal(size=(batch, s, cfg.d_model)).astype(np.float32),
            cfg.cdtype,
        )
    else:
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(batch, s)), jnp.int32
        )
    if cfg.pos == "mrope":
        out["position_ids"] = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None, None], (3, batch, s)
        )
    if kind == "train":
        out["targets"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(batch, seq)), jnp.int32
        )
    return out
