"""Per-architecture GEMM workload extraction.

Walks an LMConfig and enumerates every weight-stationary MVM the model
executes per token (QKV/O projections, dense FFN, per-expert FFN, Mamba
projections, embedding head), with its (K, N) shape, weight count, and
activation rate (MoE experts are active top_k/E of the time).  This is
the demand side the SEGA-DCIM explorer provisions macros for.

Non-MVM compute is explicitly recorded as NOT mappable to DCIM
(arch-applicability, DESIGN.md §4): attention score*V products
(activation x activation) and the Mamba selective-scan recurrence.
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.models.config import LMConfig
from repro.models.mamba import ssm_dims


@dataclasses.dataclass
class GemmWorkload:
    name: str
    K: int                 # reduction dim
    N: int                 # output dim
    count: int             # instances (layers x experts ...)
    activation: float = 1.0  # fraction of tokens hitting each instance

    @property
    def weights(self) -> int:
        return self.K * self.N

    def macs_per_token(self) -> float:
        return self.K * self.N * self.count * self.activation


@dataclasses.dataclass
class ArchWorkload:
    arch: str
    gemms: List[GemmWorkload]
    unmappable: List[str]

    def total_weights(self) -> int:
        return sum(g.weights * g.count for g in self.gemms)

    def macs_per_token(self) -> float:
        return sum(g.macs_per_token() for g in self.gemms)


def extract(cfg: LMConfig) -> ArchWorkload:
    g: List[GemmWorkload] = []
    un: List[str] = []
    D = cfg.d_model
    hd = cfg.hd

    n_attn = sum(1 for i in range(cfg.n_layers) if cfg.mixer_kind(i) in ("gqa", "mla"))
    n_mamba = cfg.n_layers - n_attn
    n_moe = sum(1 for i in range(cfg.n_layers) if cfg.ffn_of(i) == "moe")
    n_dense = sum(1 for i in range(cfg.n_layers) if cfg.ffn_of(i) == "dense")

    if n_attn:
        if cfg.attn_kind == "mla":
            m = cfg.mla
            g += [
                GemmWorkload("mla_q_a", D, m.q_lora_rank, n_attn),
                GemmWorkload("mla_q_b", m.q_lora_rank,
                             cfg.n_heads * (m.qk_nope_dim + m.qk_rope_dim), n_attn),
                GemmWorkload("mla_kv_a", D, m.kv_lora_rank + m.qk_rope_dim, n_attn),
                GemmWorkload("mla_kv_b", m.kv_lora_rank,
                             cfg.n_heads * (m.qk_nope_dim + m.v_head_dim), n_attn),
                GemmWorkload("attn_o", cfg.n_heads * m.v_head_dim, D, n_attn),
            ]
        else:
            g += [
                GemmWorkload("attn_q", D, cfg.n_heads * hd, n_attn),
                GemmWorkload("attn_k", D, cfg.n_kv * hd, n_attn),
                GemmWorkload("attn_v", D, cfg.n_kv * hd, n_attn),
                GemmWorkload("attn_o", cfg.n_heads * hd, D, n_attn),
            ]
        un.append("attention score x value products (activation-dynamic)")

    if n_mamba:
        d_inner, dt_rank = ssm_dims(cfg)
        s = cfg.ssm
        g += [
            GemmWorkload("mamba_in", D, 2 * d_inner, n_mamba),
            GemmWorkload("mamba_x_proj", d_inner, dt_rank + 2 * s.d_state, n_mamba),
            GemmWorkload("mamba_dt", dt_rank, d_inner, n_mamba),
            GemmWorkload("mamba_out", d_inner, D, n_mamba),
        ]
        un.append("mamba selective-scan recurrence (stateful, non-MVM)")

    if n_dense:
        mult = 3 if cfg.act == "swiglu" else 2
        if cfg.act == "swiglu":
            g += [
                GemmWorkload("ffn_gate", D, cfg.d_ff, n_dense),
                GemmWorkload("ffn_up", D, cfg.d_ff, n_dense),
                GemmWorkload("ffn_down", cfg.d_ff, D, n_dense),
            ]
        else:
            g += [
                GemmWorkload("ffn_up", D, cfg.d_ff, n_dense),
                GemmWorkload("ffn_down", cfg.d_ff, D, n_dense),
            ]
        del mult

    if n_moe:
        m = cfg.moe
        act = m.top_k / m.n_experts
        g += [
            GemmWorkload("moe_gate", D, m.d_ff, n_moe * m.n_experts, act),
            GemmWorkload("moe_up", D, m.d_ff, n_moe * m.n_experts, act),
            GemmWorkload("moe_down", m.d_ff, D, n_moe * m.n_experts, act),
        ]
        if m.n_shared:
            g += [
                GemmWorkload("moe_shared_gate", D, m.d_ff * m.n_shared, n_moe),
                GemmWorkload("moe_shared_up", D, m.d_ff * m.n_shared, n_moe),
                GemmWorkload("moe_shared_down", m.d_ff * m.n_shared, D, n_moe),
            ]
        g += [GemmWorkload("moe_router", D, m.n_experts, n_moe)]

    g += [GemmWorkload("lm_head", D, cfg.vocab_size, 1)]
    if not cfg.external_embed and not cfg.tie_embeddings:
        un.append("embedding lookup (gather, not MVM)")

    return ArchWorkload(arch=cfg.name, gemms=g, unmappable=un)
