"""Workload -> DCIM macro plan: run the SEGA-DCIM explorer against an
architecture's GEMM demand and produce a chip-level provisioning report.

This is the integration that makes the paper's compiler a first-class
feature of the framework: ``plan(arch_name, precision)`` extracts the
arch's MVM workloads, explores the (precision, W_store) space, distills
by the user constraint set, and reports macro count / total area / power
/ per-token latency for serving the whole model from DCIM.

``precision`` may be a single format or a list: multiple candidate
precisions (and optionally multiple ``w_store`` budgets) are explored in
ONE batched ``explore_multi`` call — a single jitted NSGA-II over the
scenario table — and distillation then picks across the merged INT+FP
candidate set, exactly the paper's Fig. 4 flow.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Union

import numpy as np

from repro.configs import get_config
from repro.core import explorer, nsga2
from repro.core.cells import CALIBRATED, TechParams
from repro.core.precision import Precision, get as get_precision
from repro.sim.functional import DCIMMacroSim

from .workloads import ArchWorkload, extract


@dataclasses.dataclass
class MacroPlan:
    arch: str
    precision: str
    point: explorer.ParetoPoint
    n_macros: int
    total_area_mm2: float
    total_power_W: float
    macs_per_token: float
    token_latency_us: float
    tokens_per_s: float
    unmappable: List[str]

    def summary(self) -> str:
        return (
            f"{self.arch:<22} {self.precision:>5}: {self.n_macros:>6} macros"
            f" {self.total_area_mm2:9.1f} mm^2 {self.total_power_W:8.2f} W"
            f" {self.tokens_per_s:10.1f} tok/s"
        )


def plan(
    arch: str,
    precision: Union[str, Precision, Sequence] = "int8",
    w_store: Union[int, Sequence[int]] = 65536,
    cfg_nsga: Optional[nsga2.NSGA2Config] = None,
    tech: TechParams = CALIBRATED,
    activity: float = 0.1,
    max_area_mm2: Optional[float] = None,
    sort_by: str = "edp",
) -> MacroPlan:
    """Provision DCIM macros of one explored design for a whole arch.

    With a list of precisions (and/or ``w_store`` budgets) the full
    scenario cross-product runs as ONE batched NSGA-II; distillation
    then selects the winning design across the merged candidate set."""
    lmcfg = get_config(arch)
    wl: ArchWorkload = extract(lmcfg)

    if isinstance(precision, (str, Precision)):
        precisions = [precision]
    else:
        precisions = list(precision)
    if isinstance(w_store, (int, np.integer)):
        w_stores = [int(w_store)]
    else:
        w_stores = [int(w) for w in w_store]
    scenarios = [(p, w) for p in precisions for w in w_stores]
    pts = explorer.explore_multi(
        scenarios,
        cfg_nsga or nsga2.NSGA2Config(pop_size=96, generations=48),
        tech=tech, activity=activity,
    )
    pts = explorer.distill(pts, max_area_mm2=max_area_mm2, sort_by=sort_by)
    if not pts:
        raise ValueError("distillation removed every Pareto point")
    pt = pts[0]
    sim = DCIMMacroSim.from_point(pt, tech=tech, activity=activity)

    total_weights = wl.total_weights()
    n_macros = math.ceil(total_weights / sim.w_store)

    # Per-token latency: weights are resident (weight-stationary), each
    # GEMM (1, K) x (K, N) runs on its own macro slice; layers execute
    # sequentially, GEMMs inside a layer in parallel across macros.
    per_layer_us = 0.0
    for g in wl.gemms:
        acct = sim.account(1, g.K, g.N)
        # count instances serialized across layers, parallel across macros
        per_layer_us += acct["latency_us"] * g.count * g.activation / max(
            n_macros / max(len(wl.gemms), 1), 1.0
        )
    token_latency_us = per_layer_us
    power_W = pt.energy_nJ / max(pt.delay_ns, 1e-9) * n_macros

    return MacroPlan(
        arch=arch,
        precision=pt.precision,  # the distillation winner's format
        point=pt,
        n_macros=n_macros,
        total_area_mm2=pt.area_mm2 * n_macros,
        total_power_W=power_W,
        macs_per_token=wl.macs_per_token(),
        token_latency_us=token_latency_us,
        tokens_per_s=1e6 / max(token_latency_us, 1e-9),
        unmappable=wl.unmappable,
    )
