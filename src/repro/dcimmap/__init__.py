"""LM architecture -> DCIM macro provisioning (workloads + mapper)."""
from .mapper import MacroPlan, plan  # noqa: F401
from .workloads import ArchWorkload, GemmWorkload, extract  # noqa: F401
