"""Batched serving example: prefill + decode with KV caches and length
bucketing.

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import numpy as np

from repro import configs
from repro.models import lm
from repro.serve import Engine, bucket_requests


def main():
    cfg = configs.get_smoke_config("mistral-nemo-12b")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params, max_len=96)

    rng = np.random.default_rng(0)
    requests = [
        list(rng.integers(0, cfg.vocab_size, rng.integers(5, 20)))
        for _ in range(6)
    ]
    print(f"{len(requests)} requests, lengths {[len(r) for r in requests]}")
    for idx, batch in bucket_requests(requests):
        out = engine.generate(batch, n_tokens=16, temperature=0.8, seed=1)
        print(f"  bucket len={out.prompt_len}: served {len(idx)} requests "
              f"-> {out.tokens.shape[1]} tokens each")
        print(f"    first continuation: {out.tokens[0, out.prompt_len:].tolist()}")


if __name__ == "__main__":
    main()
