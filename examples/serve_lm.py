"""Serving example: the bucketed Engine vs the continuous-batching
Scheduler on the same mixed-length request set, shared-prefix reuse
over the paged KV-cache pool, a warm persistent session (two traces,
one device pool — cross-trace prefix hits), and streaming delivery.

    PYTHONPATH=src python examples/serve_lm.py
"""
import dataclasses

import jax
import numpy as np

from repro import configs
from repro.models import lm
from repro.serve import Engine, Request, Scheduler, bucket_requests


def main():
    cfg = configs.get_smoke_config("mistral-nemo-12b")
    params = lm.init(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(0)
    requests = [
        list(rng.integers(0, cfg.vocab_size, rng.integers(5, 20)))
        for _ in range(6)
    ]
    print(f"{len(requests)} requests, lengths {[len(r) for r in requests]}")

    print("\n-- bucketed Engine: equal-length batches, run to the longest --")
    engine = Engine(cfg, params, max_len=96)
    for idx, batch in bucket_requests(requests):
        out = engine.generate(batch, n_tokens=16, temperature=0.8, seed=1,
                              request_ids=idx)
        print(f"  bucket len={out.prompt_len}: served {len(idx)} requests "
              f"-> {out.tokens.shape[1]} tokens each")

    print("\n-- continuous Scheduler: slot pool, per-request n_tokens --")
    sched = Scheduler(cfg, params, max_slots=3, max_len=96, seed=1)
    reqs = [
        Request(prompt=np.asarray(p, np.int32),
                n_tokens=int(rng.integers(4, 24)),
                temperature=0.8,
                arrival=i // 2)           # staggered arrivals
        for i, p in enumerate(requests)
    ]
    for res in sched.serve(reqs):
        print(f"  rid={res.rid} prompt={res.prompt_len:2d} "
              f"gen={res.generated.size:2d} admitted@{res.admitted_step} "
              f"finished@{res.finished_step}")
    s = sched.last_stats
    print(f"  {s.decode_steps} decode steps, {s.prefills} prefills, "
          f"occupancy {s.occupancy:.0%}, "
          f"{sched.compile_counts()['total']} compiled programs")

    print("\n-- shared-prefix reuse: one system prompt, many requests --")
    # Reuse requires a lossless cache dtype (token-exactness gate).
    cfg_px = dataclasses.replace(cfg, cache_dtype="float32")
    params_px = lm.init(jax.random.PRNGKey(0), cfg_px)
    sched = Scheduler(cfg_px, params_px, max_slots=3, max_len=96, page_size=8)
    system = rng.integers(0, cfg.vocab_size, 40).astype(np.int32)
    reqs = [
        Request(prompt=np.concatenate(
            [system, rng.integers(0, cfg.vocab_size, t).astype(np.int32)]
        ), n_tokens=6)
        for t in (2, 3, 5, 2, 4, 3)
    ]
    for res in sched.serve(reqs):
        print(f"  rid={res.rid} prompt={res.prompt_len:2d} "
              f"prefix_hit_tokens={res.prefix_hit_tokens:2d}")
    pg = sched.last_stats.paging
    print(f"  page hits={pg['prefix_hits']} misses={pg['prefix_misses']} "
          f"hit_tokens={pg['prefix_hit_tokens']} "
          f"peak_pages={pg['peak_pages_in_use']}/{pg['n_pages']}")

    print("\n-- warm session: a second trace over the same system prompt --")
    # The scheduler's persistent ServeSession keeps the device pool and
    # the prefix index alive between serve() calls, so trace 2's very
    # first request hits the pages trace 1 filled (cross-trace hits).
    reqs2 = [
        Request(prompt=np.concatenate(
            [system, rng.integers(0, cfg.vocab_size, t).astype(np.int32)]
        ), n_tokens=6, rid=100 + i)
        for i, t in enumerate((3, 2, 4))
    ]
    before = sched.compile_counts()["total"]
    results = sched.serve(reqs2)
    s = sched.last_stats
    pg = s.paging
    print(f"  trace {s.trace_index}: first request hit "
          f"{results[0].prefix_hit_tokens} prompt tokens warm; "
          f"cross_trace_hit_tokens={pg['cross_trace_hit_tokens']} "
          f"misses={pg['prefix_misses']}")
    print(f"  compiled programs: {before} -> "
          f"{sched.compile_counts()['total']} (warm trace compiles nothing)")
    print(f"  persistent pool: {s.pool_bytes / 1024:.0f} KiB")

    print("\n-- streaming: tokens observable as they are produced --")
    handle = sched.submit(
        Request(prompt=system[:12], n_tokens=8, rid=200),
        on_token=lambda h, t: print(f"  step token: rid={h.rid} tok={t}"),
    )
    streamed = list(handle.stream())     # drains while the session steps
    print(f"  stream() got {streamed}; done={handle.done} "
          f"(== result: {list(handle.result.generated) == streamed})")


if __name__ == "__main__":
    main()
