"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps on synthetic data with checkpointing + auto-resume.

    PYTHONPATH=src python examples/train_lm.py --steps 300

This is the 'train a ~100M model for a few hundred steps' deliverable;
on CPU it takes a while — use --steps 30 for a quick look.  The config
is a scaled-down qwen2.5-family member (same code path as the full
configs; see repro/configs).
"""
import argparse

from repro import configs
from repro.data import SyntheticLM
from repro.optim import cosine_warmup
from repro.train import Trainer, TrainerConfig, build


def lm_100m():
    return configs.get_config(
        "qwen2.5-3b",
        n_layers=12, d_model=640, n_heads=10, n_kv=2, d_ff=2560,
        head_dim=64, vocab_size=32000,
        param_dtype="float32", compute_dtype="float32", remat=False,
        attn_chunk_q=256, attn_chunk_kv=256, loss_chunk=0,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="results/train_lm_ckpt")
    args = ap.parse_args()

    cfg = lm_100m()
    import jax

    from repro.models import lm as lm_mod

    n_params = sum(
        x.size for x in jax.tree.leaves(
            jax.eval_shape(lambda k: lm_mod.init(k, cfg), jax.random.PRNGKey(0))
        )
    )
    print(f"model: {cfg.name}-100m variant, {n_params / 1e6:.1f}M params")

    state, step_fn = build(
        cfg, optimizer="adamw",
        lr=cosine_warmup(3e-4, warmup=20, total=args.steps),
    )
    ds = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=0)
    tr = Trainer(
        state, step_fn, ds,
        TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                      ckpt_every=100, log_every=10, async_ckpt=True),
    )
    res = tr.run()
    for h in res["history"]:
        print(f"  step {h['step']:>4}  loss {h['loss']:.4f}  {h['sec'] * 1e3:.0f} ms")
    print(f"done at step {res['final_step']}; stragglers={res['stragglers']}; "
          f"checkpoints in {args.ckpt}")


if __name__ == "__main__":
    main()
