"""Quickstart: explore a DCIM design space, distill it, and generate RTL.

    PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's core flow (Fig. 4) in under a minute on CPU:
  1. MOGA-based design space exploration for INT8 / 8K weights,
  2. the merged INT+FP candidate set for an edge-inference scenario,
  3. user-defined distillation (area + power budget),
  4. template-based generation of the selected macro (RTL + floorplan).
"""
import pathlib

from repro.codegen import generate
from repro.core import distill, explore, explore_multi
from repro.core.nsga2 import NSGA2Config

CFG = NSGA2Config(pop_size=128, generations=64)


def main():
    print("=== 1. NSGA-II exploration: INT8, W_store=8K ===")
    pts = explore("int8", 8192, CFG)
    for p in pts[:8]:
        print("  " + p.summary())
    print(f"  ... Pareto front size: {len(pts)}")

    print("\n=== 2. Multi-precision union front (INT8 + BF16, 8K) ===")
    union = explore_multi([("int8", 8192), ("bf16", 8192)], CFG)
    n_fp = sum(p.precision == "bf16" for p in union)
    print(f"  union front: {len(union)} points ({n_fp} FP, {len(union) - n_fp} INT)")

    print("\n=== 3. User-defined distillation: area <= 0.15 mm^2, sort by EDP ===")
    sel = distill(union, max_area_mm2=0.15, sort_by="edp", top=3)
    for p in sel:
        print("  " + p.summary())

    print("\n=== 4. Template-based generation of the winner ===")
    out = pathlib.Path("results/quickstart_macro")
    rep = generate(sel[0], out)
    print(f"  RTL files : {rep['files']}")
    print(f"  gate census: {rep['census']}")
    print(f"  audit ok  : {rep['audit']['ok']} "
          f"(census area vs Table V/VI: rel err {rep['audit']['area_rel_err']:.2e})")
    print(f"  floorplan : {rep['floorplan']['die_area_mm2']:.4f} mm^2 die "
          f"-> {out}/floorplan.def")


if __name__ == "__main__":
    main()
