"""Provision DCIM macros for real LM architectures + execute a model
layer through the generated macro's numerics.

    PYTHONPATH=src python examples/dcim_for_llm.py

Shows the framework-level integration of SEGA-DCIM: the explorer sizes
macros for an architecture's GEMM workloads, and the bit-serial kernel
executes a real projection layer with INT8 DCIM numerics.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.nsga2 import NSGA2Config
from repro.core.precision import get as get_precision
from repro.dcimmap import extract, plan
from repro import configs
from repro.sim import DCIMMacroSim

CFG = NSGA2Config(pop_size=64, generations=32)


def main():
    print("=== GEMM workloads per architecture ===")
    for arch in ("qwen2.5-3b", "falcon-mamba-7b", "deepseek-v3-671b"):
        wl = extract(configs.get_config(arch))
        print(f"  {arch}: {len(wl.gemms)} GEMM classes, "
              f"{wl.total_weights() / 1e9:.2f}B weights, "
              f"{wl.macs_per_token() / 1e9:.2f} GMAC/token")
        for u in wl.unmappable:
            print(f"     not DCIM-mappable: {u}")

    print("\n=== INT8 macro provisioning (explorer-driven) ===")
    for arch in ("qwen2.5-3b", "phi4-mini-3.8b"):
        p = plan(arch, precision="int8", w_store=65536, cfg_nsga=CFG)
        print("  " + p.summary())
        print(f"     chosen macro: {p.point.summary()}")

    print("\n=== Execute a real projection through DCIM numerics ===")
    cfg = configs.get_smoke_config("qwen2.5-3b")
    from repro.models import lm

    params = lm.init(jax.random.PRNGKey(0), cfg)
    w = params["blocks"][0]["mixer"]["wq"]["w"][0]          # (D, H*hd)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, w.shape[0]))
    sim = DCIMMacroSim(get_precision("int8"), N=64, H=64, L=8, k=4)
    y_dcim = sim.mvm(x, w)
    y_ref = x @ w
    rel = np.median(
        np.abs(np.asarray(y_dcim - y_ref)) / np.maximum(np.abs(np.asarray(y_ref)), 1e-3)
    )
    acct = sim.account(8, w.shape[0], w.shape[1])
    print(f"  wq through INT8 DCIM: median rel err {rel:.3%} "
          f"(quantization-only; bit-serial MAC is exact)")
    print(f"  macro accounting: {acct['cycles']} cycles, "
          f"{acct['latency_us']:.1f} us, {acct['energy_uJ']:.2f} uJ")


if __name__ == "__main__":
    main()
