"""Island-model NSGA-II: the paper's DSE scaled across a device mesh.

    PYTHONPATH=src python examples/distributed_dse.py

Two layouts:

  * single scenario, one island per device along one mesh axis
    (``run_islands``), and
  * the scenario x island 2-D mesh (``run_islands_multi``): scenarios
    sharded (and locally vmapped) on one axis, islands with ring
    migration on the other, resolved through ``repro.dist`` logical
    axes.

On this CPU box the mesh is 1 device (rings degenerate gracefully); on a
pod the same code runs one island per chip with ring migration over ICI
— see tests/test_sharding_dist.py for the forced 8-device variant.
"""
import time

from repro.core import explorer, nsga2
from repro.core.precision import get
from repro.core.space import DesignSpace


def main():
    space = DesignSpace(prec=get("int8"), w_store=65536)
    cfg = nsga2.NSGA2Config(pop_size=64, generations=0, seed=3)

    t0 = time.perf_counter()
    res = explorer.run_islands(space, cfg, rounds=4, gens_per_round=16,
                               n_migrants=8)
    dt = time.perf_counter() - t0

    oracle = explorer.brute_force_front(space)
    got = {tuple(g) for g in res.front_genes}
    want = {tuple(g) for g in oracle}
    print(f"islands DSE: {dt:.2f}s wall, front={len(res.front_genes)}, "
          f"oracle coverage {len(got & want)}/{len(want)}")
    print("sample front points:")
    pts = explorer._points_from_genes(
        space, res.front_genes[:5], explorer.CALIBRATED, 1.0
    )
    for p in pts:
        print("  " + p.summary())

    # Scenario x island sharding: all scenarios evolve concurrently, each
    # with its own migration ring.
    scenarios = [("int8", 65536), ("bf16", 65536), ("int4", 16384),
                 ("fp16", 32768)]
    t0 = time.perf_counter()
    results = explorer.run_islands_multi(
        scenarios, cfg, rounds=4, gens_per_round=16, n_migrants=8
    )
    dt = time.perf_counter() - t0
    print(f"\nscenario x island DSE ({len(scenarios)} scenarios): "
          f"{dt:.2f}s wall")
    for (prec, w), r in zip(scenarios, results):
        oracle = explorer.brute_force_front(
            DesignSpace(prec=get(prec), w_store=w)
        )
        got = {tuple(g) for g in r.front_genes}
        want = {tuple(g) for g in oracle}
        print(f"  {prec:>5} W={w:<6} front={len(r.front_genes):<3} "
              f"oracle coverage {len(got & want)}/{len(want)}")


if __name__ == "__main__":
    main()
