"""Island-model NSGA-II: the paper's DSE scaled across a device mesh.

    PYTHONPATH=src python examples/distributed_dse.py

On this CPU box the mesh is 1 device (islands ring degenerates
gracefully); on a pod the same code runs one island per chip with ring
migration over ICI — see tests/test_sharding_dist.py for the forced
8-device variant.
"""
import time

from repro.core import explorer, nsga2
from repro.core.precision import get
from repro.core.space import DesignSpace


def main():
    space = DesignSpace(prec=get("int8"), w_store=65536)
    cfg = nsga2.NSGA2Config(pop_size=64, generations=0, seed=3)

    t0 = time.perf_counter()
    res = explorer.run_islands(space, cfg, rounds=4, gens_per_round=16,
                               n_migrants=8)
    dt = time.perf_counter() - t0

    oracle = explorer.brute_force_front(space)
    got = {tuple(g) for g in res.front_genes}
    want = {tuple(g) for g in oracle}
    print(f"islands DSE: {dt:.2f}s wall, front={len(res.front_genes)}, "
          f"oracle coverage {len(got & want)}/{len(want)}")
    print("sample front points:")
    pts = explorer._points_from_genes(
        space, res.front_genes[:5], explorer.CALIBRATED, 1.0
    )
    for p in pts:
        print("  " + p.summary())


if __name__ == "__main__":
    main()
