#!/usr/bin/env python
"""Execute every fenced ``python`` code block in README.md and docs/*.md.

Documentation snippets rot silently: an API rename leaves the prose
compiling in the reader's head and crashing in their shell.  This check
extracts each markdown file's fenced ``python`` blocks and runs them —
so a snippet that stops working fails CI like any other test.

Rules:
  * only fences tagged exactly ``python`` run; ``text``/``bash``/bare
    fences are prose, not contracts,
  * blocks within one FILE run sequentially in one interpreter and
    share a namespace (docs build up examples step by step); files are
    isolated from each other in separate subprocesses,
  * a line containing ``<!-- check-docs: skip -->`` anywhere before a
    fence (with only blank lines between) skips that one block — for
    illustrative fragments that need hardware or long wall time,
  * snippets run from the repo root with ``src/`` on PYTHONPATH, so
    they must be smoke-sized (CI runs this on every PR).

Usage:  python scripts/check_docs.py [files...]
"""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
SKIP_MARK = "<!-- check-docs: skip -->"
TIMEOUT_S = 900


def extract_blocks(text: str):
    """Yield (start_line, source) for each runnable ```python block."""
    lines = text.splitlines()
    blocks = []
    in_block = False
    skip_next = False
    buf, start = [], 0
    for i, line in enumerate(lines, start=1):
        stripped = line.strip()
        if not in_block:
            if SKIP_MARK in stripped:
                skip_next = True
            elif stripped == "```python":
                in_block = True
                buf, start = [], i
                if skip_next:
                    in_block = "skipped"
                skip_next = False
            elif stripped and not stripped.startswith("```"):
                # Any intervening prose cancels a pending skip marker.
                skip_next = False
        else:
            if stripped == "```":
                if in_block != "skipped":
                    blocks.append((start, "\n".join(buf)))
                in_block = False
            else:
                buf.append(line)
    if in_block:
        raise SystemExit(f"unterminated code fence starting at line {start}")
    return blocks


def run_file(path: pathlib.Path) -> bool:
    blocks = extract_blocks(path.read_text())
    if not blocks:
        print(f"  {path.relative_to(REPO_ROOT)}: no python blocks")
        return True
    script = []
    for start, src in blocks:
        script.append(f"# --- {path.name} block @ line {start}")
        script.append(f"print('--- running {path.name}:{start}')")
        script.append(src)
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        str(REPO_ROOT / "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    rel = path.relative_to(REPO_ROOT)
    try:
        proc = subprocess.run(
            [sys.executable, "-c", "\n".join(script)],
            cwd=REPO_ROOT, env=env, capture_output=True, text=True,
            timeout=TIMEOUT_S,
        )
    except subprocess.TimeoutExpired as e:
        print(f"  {rel}: FAILED (timed out after {TIMEOUT_S}s)")
        for stream in (e.stdout, e.stderr):
            if stream:
                out = stream if isinstance(stream, str) else stream.decode(
                    "utf-8", "replace"
                )
                print(out[-2000:])
        return False
    if proc.returncode != 0:
        print(f"  {rel}: FAILED")
        print(proc.stdout[-2000:])
        print(proc.stderr[-4000:])
        return False
    print(f"  {rel}: {len(blocks)} block(s) OK")
    return True


def main(argv) -> int:
    if argv:
        files = [pathlib.Path(a).resolve() for a in argv]
    else:
        files = [REPO_ROOT / "README.md"] + sorted(
            (REPO_ROOT / "docs").glob("*.md")
        )
    print(f"check_docs: executing python snippets from {len(files)} file(s)")
    ok = True
    for f in files:
        ok &= run_file(f)
    if not ok:
        print("check_docs: FAILED")
        return 1
    print("check_docs: all snippets executed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
