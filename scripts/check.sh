#!/usr/bin/env bash
# Local equivalent of .github/workflows/ci.yml: the tier-1 test command,
# perf record regeneration (BENCH_dse.json / BENCH_serve.json), a
# single-cell dry-run through the results store, and the docs-snippet
# check (every python block in README/docs must execute).
set -euo pipefail
cd "$(dirname "$0")/.."
python -m pytest -x -q -m "not slow" "$@"
PYTHONPATH=src python -m benchmarks.bench_dse --smoke
PYTHONPATH=src python -m benchmarks.bench_serve --smoke
PYTHONPATH=src python -m repro.launch.dryrun \
  --arch qwen2.5-3b --shape decode_32k --mesh single \
  --out results/dryrun-ci --force
python scripts/check_docs.py
