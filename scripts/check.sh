#!/usr/bin/env bash
# Local equivalent of .github/workflows/ci.yml: the tier-1 test command.
set -euo pipefail
cd "$(dirname "$0")/.."
python -m pytest -x -q -m "not slow" "$@"
