#!/usr/bin/env bash
# Local equivalent of .github/workflows/ci.yml: the tier-1 test command,
# DSE perf record regeneration (batched vs sequential explore_multi ->
# BENCH_dse.json), and a single-cell dry-run through the results store.
set -euo pipefail
cd "$(dirname "$0")/.."
python -m pytest -x -q -m "not slow" "$@"
PYTHONPATH=src python -m benchmarks.bench_dse --smoke
PYTHONPATH=src python -m benchmarks.bench_serve --smoke
PYTHONPATH=src python -m repro.launch.dryrun \
  --arch qwen2.5-3b --shape decode_32k --mesh single \
  --out results/dryrun-ci --force
