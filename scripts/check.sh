#!/usr/bin/env bash
# Local equivalent of .github/workflows/ci.yml: the tier-1 test command,
# the program-contract lint (results/lint.json), perf record
# regeneration (BENCH_dse.json / BENCH_serve.json / BENCH_kernels.json —
# bench_serve includes the warm-session and sharded traces), three
# single-cell dry-runs through the results store (the 2x16x16 train cell
# asserts the SPMD partitioner emits no involuntary-rematerialization
# warnings; the tp8 cell compiles the sharded serving decode), and the
# docs-snippet check (every python block in README/docs must execute).
set -euo pipefail
cd "$(dirname "$0")/.."
# Tier-1 / slow split: everything slow-marked (the 8-device subprocess
# suites) is excluded here and runs in the dedicated CI `sharded` job.
echo "tier-1: $(python -m pytest -q -m 'not slow' --collect-only 2>/dev/null | tail -1)"
echo "slow:   $(python -m pytest -q -m 'slow' --collect-only 2>/dev/null | tail -1)"
# Coverage floor on the serving + distribution layers when pytest-cov is
# installed (CI installs the [cov] extra; plain local runs skip it).
COV=()
if python -c "import pytest_cov" >/dev/null 2>&1; then
  COV=(--cov=repro.serve --cov=repro.dist --cov-report=term
       --cov-fail-under=75)
fi
python -m pytest -x -q -m "not slow" ${COV[@]+"${COV[@]}"} "$@"
# The persistent-session / streaming module already ran inside the full
# sweep above; when extra args filtered that sweep, run it explicitly so
# no invocation can skip it.
if [ "$#" -gt 0 ]; then
  python -m pytest -x -q -m "not slow" tests/test_serve_session.py
fi
# The threaded multi-tenant suite re-runs under a faulthandler timeout:
# a deadlocked pump/producer dumps every thread's stack and fails,
# instead of hanging CI until the job-level kill.
python -m pytest -x -q -m "not slow" --faulthandler-timeout=600 \
  tests/test_serve_concurrent.py
# Static toolchain (ruff/mypy) when installed — CI always installs the
# [lint] extra, so local runs without it only skip the style layer.
if command -v ruff >/dev/null 2>&1; then
  ruff check src tests benchmarks scripts
fi
if command -v mypy >/dev/null 2>&1; then
  mypy
fi
# Program-contract lint: donation/transfers/recompile/collectives/
# pallas/precision over every registered contract; hard gate (nonzero
# on any error finding, or when total wall time exceeds 2x the baseline
# recorded in BENCH_lint.json).
PYTHONPATH=src python -m repro.analysis.lint --all
PYTHONPATH=src python -m benchmarks.bench_dse --smoke
PYTHONPATH=src python -m benchmarks.bench_serve --smoke
PYTHONPATH=src python -m benchmarks.bench_kernels --smoke
PYTHONPATH=src python -m repro.launch.dryrun \
  --arch qwen2.5-3b --shape decode_32k --mesh single \
  --out results/dryrun-ci --force --fail-on-remat
PYTHONPATH=src python -m repro.launch.dryrun \
  --arch qwen2.5-3b --shape train_4k --mesh multi \
  --out results/dryrun-ci --force --fail-on-remat
# The tensor-parallel sharded serving decode program (Scheduler(tp=8)'s
# paged decode) on an 8-wide ("model",) mesh: must compile remat-free
# with the pool donation aliased.
PYTHONPATH=src python -m repro.launch.dryrun \
  --arch qwen2.5-3b --shape decode_32k --tp 8 \
  --out results/dryrun-ci --force --fail-on-remat
python scripts/check_docs.py
