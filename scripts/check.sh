#!/usr/bin/env bash
# Local equivalent of .github/workflows/ci.yml: the tier-1 test command,
# the program-contract lint (results/lint.json), perf record
# regeneration (BENCH_dse.json / BENCH_serve.json / BENCH_kernels.json —
# bench_serve includes the warm-session trace), two single-cell dry-runs
# through the results store (the 2x16x16 train cell asserts the SPMD
# partitioner emits no involuntary-rematerialization warnings), and the
# docs-snippet check (every python block in README/docs must execute).
set -euo pipefail
cd "$(dirname "$0")/.."
python -m pytest -x -q -m "not slow" "$@"
# The persistent-session / streaming module already ran inside the full
# sweep above; when extra args filtered that sweep, run it explicitly so
# no invocation can skip it.
if [ "$#" -gt 0 ]; then
  python -m pytest -x -q -m "not slow" tests/test_serve_session.py
fi
# The threaded multi-tenant suite re-runs under a faulthandler timeout:
# a deadlocked pump/producer dumps every thread's stack and fails,
# instead of hanging CI until the job-level kill.
python -m pytest -x -q -m "not slow" --faulthandler-timeout=600 \
  tests/test_serve_concurrent.py
# Static toolchain (ruff/mypy) when installed — CI always installs the
# [lint] extra, so local runs without it only skip the style layer.
if command -v ruff >/dev/null 2>&1; then
  ruff check src tests benchmarks scripts
fi
if command -v mypy >/dev/null 2>&1; then
  mypy
fi
# Program-contract lint: donation/transfers/recompile/collectives/pallas
# over every registered contract; hard gate (nonzero on any error).
PYTHONPATH=src python -m repro.analysis.lint --all
PYTHONPATH=src python -m benchmarks.bench_dse --smoke
PYTHONPATH=src python -m benchmarks.bench_serve --smoke
PYTHONPATH=src python -m benchmarks.bench_kernels --smoke
PYTHONPATH=src python -m repro.launch.dryrun \
  --arch qwen2.5-3b --shape decode_32k --mesh single \
  --out results/dryrun-ci --force --fail-on-remat
PYTHONPATH=src python -m repro.launch.dryrun \
  --arch qwen2.5-3b --shape train_4k --mesh multi \
  --out results/dryrun-ci --force --fail-on-remat
python scripts/check_docs.py
