"""§Perf before/after comparison across iteration directories."""
import json, pathlib, zstandard
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.roofline import analyze_record

def load(d, cell):
    rec = json.loads(pathlib.Path(f"{d}/{cell}.json").read_text())
    h = pathlib.Path(f"{d}/{cell}.hlo.zst")
    rec["analysis"] = analyze_hlo(zstandard.ZstdDecompressor().decompress(h.read_bytes()).decode())
    r = analyze_record(rec)
    mem = rec.get("memory", {})
    hbm = (mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)) / 2**30
    return r, hbm

RUNS = {
 "deepseek-v3-671b__train_4k__pod16x16": [
     ("baseline", "results/dryrun"), ("iter1 moe-act-sharding", "results/perf"),
     ("iter2 sharded-expert-acts", "results/perf2"), ("iter3 param-rule fix", "results/perf3")],
 "qwen2-vl-72b__train_4k__pod16x16": [
     ("baseline", "results/dryrun"), ("iter1 flash-bf16-stack", "results/perf"),
     ("iter2 causal-skip", "results/perf2")],
 "falcon-mamba-7b__prefill_32k__pod16x16": [
     ("baseline", "results/dryrun"), ("iter1 pallas-selective-scan", "results/perf")],
}

if __name__ == "__main__":
    for cell, chain in RUNS.items():
        print(f"\n== {cell} ==", flush=True)
        for tag, d in chain:
            try:
                r, hbm = load(d, cell)
                print(f"  {tag:<28} compute={r.compute_s:8.3f}s mem={r.memory_s:8.3f}s "
                      f"coll={r.collective_s:8.3f}s bneck={r.bottleneck:<10} "
                      f"roofline={100 * r.roofline_fraction:5.2f}% xla_mem={hbm:7.1f}GB",
                      flush=True)
            except Exception as e:
                print(f"  {tag:<28} ERROR {type(e).__name__} {e}", flush=True)
