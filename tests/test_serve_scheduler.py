"""Continuous-batching scheduler: token-exactness against the bucketed
Engine (through BOTH the paged pool and the legacy monolithic cache),
slot allocator / bucketing properties, EOS + slot-recycling invariants,
per-request PRNG reproducibility, bounded compile counts.  Prefix-cache
accounting and page-pool invariants live in tests/test_serve_paging.py."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.models import lm
from repro.serve import (
    Engine,
    Request,
    Scheduler,
    SlotAllocator,
    bucket_requests,
    default_prefill_buckets,
)

VOCAB = 512


def _mk(arch="qwen2.5-3b", seed=0):
    cfg = configs.get_smoke_config(arch)
    params = lm.init(jax.random.PRNGKey(seed), cfg)
    return cfg, params


def _trace(rng, n, plens, ntoks, arrivals=None):
    reqs = []
    for i in range(n):
        reqs.append(Request(
            prompt=rng.integers(0, VOCAB, plens[i % len(plens)]).astype(np.int32),
            n_tokens=ntoks[i % len(ntoks)],
            arrival=0 if arrivals is None else arrivals[i % len(arrivals)],
        ))
    return reqs


@pytest.fixture(scope="module", params=["paged", "legacy"])
def served16(request):
    """One mixed-length 16-request trace (interleaved arrivals, mixed
    n_tokens) served through a 3-slot scheduler; shared by the
    token-exactness and compile-count tests.  Runs once through the
    paged pool (burst prefill on) and once through the legacy monolithic
    per-slot path (paged=False) — both must serve identical tokens."""
    cfg, params = _mk()
    sched = Scheduler(cfg, params, max_slots=3, max_len=64,
                      paged=request.param == "paged", page_size=16)
    rng = np.random.default_rng(0)
    reqs = _trace(
        rng, 16,
        plens=[3, 5, 8, 11, 13, 16],
        ntoks=[2, 5, 7, 12],
        arrivals=[0, 0, 0, 1, 3, 3, 6, 10],
    )
    results = sched.serve(reqs)
    return cfg, params, sched, reqs, results


class TestTokenExactness:
    def test_greedy_matches_engine_per_request(self, served16):
        """The continuous path is a pure scheduling change: every request
        served through the Scheduler yields bit-identical tokens to
        Engine.generate run on that request alone."""
        cfg, params, sched, reqs, results = served16
        eng = Engine(cfg, params, max_len=64)
        for req, res in zip(reqs, results):
            ref = eng.generate(
                req.prompt[None], n_tokens=req.n_tokens,
                request_ids=[res.rid],
            )
            np.testing.assert_array_equal(ref.tokens[0], res.tokens)
            assert res.prompt_len == req.prompt.size
            assert res.tokens.size == req.prompt.size + req.n_tokens

    def test_results_keep_submission_order(self, served16):
        _, _, _, reqs, results = served16
        assert [r.rid for r in results] == list(range(len(reqs)))

    @pytest.mark.parametrize("arch", ["jamba-v0.1-52b", "deepseek-v3-671b"])
    def test_greedy_exact_hybrid_and_mla_moe(self, arch):
        """SSM state hand-off, MLA compressed caches and (drop-free)
        MoE routing all survive paging + burst prefill + prefix reuse.
        The trace includes shared-prefix requests and a lossless cache
        dtype, so prefix reuse actually hits for deepseek (paged MLA
        context reconstruction), while jamba exercises the automatic
        SSM gate (reuse off, paging + bursts still on)."""
        cfg, params = _mk(arch)
        cfg = dataclasses.replace(cfg, cache_dtype="float32")
        params = lm.init(jax.random.PRNGKey(0), cfg)
        eng = Engine(cfg, params, max_len=32)
        sched = Scheduler(cfg, params, max_slots=2, max_len=32, page_size=8)
        rng = np.random.default_rng(1)
        reqs = _trace(rng, 4, plens=[3, 6, 9], ntoks=[3, 5])
        pre = rng.integers(0, VOCAB, 17).astype(np.int32)
        for t in ([1, 2, 3], [4, 5]):
            reqs.append(Request(
                prompt=np.concatenate([pre, np.asarray(t, np.int32)]),
                n_tokens=4,
            ))
        for req, res in zip(reqs, sched.serve(reqs)):
            ref = eng.generate(
                req.prompt[None], n_tokens=req.n_tokens, request_ids=[res.rid]
            )
            np.testing.assert_array_equal(ref.tokens[0], res.tokens)
        stats = sched.last_stats
        if arch == "deepseek-v3-671b":
            assert stats.prefix_reuse_active
            assert stats.paging["prefix_hits"] > 0
        else:
            assert not stats.prefix_reuse_active   # SSM layers gate reuse off
            assert stats.paging["prefix_hits"] == 0
        assert stats.prefill_batches < stats.prefills   # bursts actually batched


class TestCompileBudget:
    def test_bounded_compiles_for_mixed_trace(self, served16):
        """Across the whole 16-request mixed-length trace: ONE decode
        program, and one prefill program per prompt bucket (legacy) or
        per (tail bucket, power-of-two burst width) pair (paged) —
        asserted from the jit cache sizes, not by inspection."""
        _, _, sched, reqs, _ = served16
        counts = sched.compile_counts()
        assert counts["decode"] == 1
        assert all(n == 1 for n in counts["prefill"].values())
        if sched.paged:
            widths = {1 << w for w in range((sched.max_slots - 1).bit_length() + 1)}
            assert all(
                b in sched.prefill_buckets and bw in widths
                for b, bw in counts["prefill"]
            )
            assert counts["total"] <= 1 + len(sched.prefill_buckets) * len(widths)
        else:
            used_buckets = {sched._bucket_for(r.prompt.size) for r in reqs}
            assert set(counts["prefill"]) == used_buckets
            assert counts["total"] <= 1 + len(sched.prefill_buckets)

    def test_second_trace_compiles_nothing_new(self, served16):
        """Legacy: any trace re-uses the per-bucket programs.  Paged:
        re-serving the SAME trace (same buckets, same burst widths)
        compiles nothing — the program cache is keyed only by padded
        shapes, never by trace content."""
        _, _, sched, reqs, _ = served16
        before = sched.compile_counts()["total"]
        if sched.paged:
            sched.serve(reqs)
        else:
            rng = np.random.default_rng(5)
            sched.serve(_trace(rng, 4, plens=[4, 9, 14], ntoks=[2, 4]))
        assert sched.compile_counts()["total"] == before


class TestAdmissionControl:
    def test_oversize_request_raises_value_error(self):
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=32)
        rng = np.random.default_rng(2)
        bad = Request(prompt=rng.integers(0, VOCAB, 30).astype(np.int32),
                      n_tokens=8)
        with pytest.raises(ValueError) as ei:
            sched.serve([bad])
        msg = str(ei.value)
        assert "30" in msg and "8" in msg and "max_len 32" in msg
        # Boundary case admitted: prompt + n_tokens == max_len.
        ok = Request(prompt=bad.prompt[:4], n_tokens=28)
        res = sched.serve([ok])[0]
        assert res.tokens.size == 32

    def test_duplicate_request_ids_rejected(self):
        """Results (and PRNG streams) are keyed by rid: a collision
        would silently drop one request's output, so it must raise."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=32)
        rng = np.random.default_rng(13)
        p = rng.integers(0, VOCAB, 4).astype(np.int32)
        with pytest.raises(ValueError, match="duplicate"):
            sched.serve([Request(prompt=p, n_tokens=2, rid=1),
                         Request(prompt=p, n_tokens=2)])  # defaults to rid 1

    def test_idle_gap_jumps_to_next_arrival(self):
        """An empty pool skips straight to the next arrival step instead
        of ticking through the gap one host iteration at a time."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=1, max_len=32)
        rng = np.random.default_rng(14)
        reqs = [Request(prompt=rng.integers(0, VOCAB, 4).astype(np.int32),
                        n_tokens=2, arrival=a) for a in (0, 10_000_000)]
        r0, r1 = sched.serve(reqs)
        assert r1.admitted_step == 10_000_000
        assert sched.last_stats.decode_steps == 2

    def test_default_buckets_cover_max_len(self):
        buckets = default_prefill_buckets(48)
        assert buckets[-1] == 48
        assert all(b <= 48 for b in buckets)
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=1, max_len=48,
                          prefill_buckets=[8])
        assert sched.prefill_buckets[-1] == 48   # always admissible


class TestEosAndRecycling:
    def test_eos_stops_and_frees_slot_within_one_step(self):
        """A request hitting EOS keeps the same token prefix, retires
        immediately, and its slot is handed to the queue before the next
        decode step."""
        cfg, params = _mk()
        rng = np.random.default_rng(3)
        prompt = rng.integers(0, VOCAB, 6).astype(np.int32)
        free_run = Scheduler(cfg, params, max_slots=1, max_len=64).serve(
            [Request(prompt=prompt, n_tokens=8)]
        )[0]
        gen = free_run.generated
        eos = int(gen[3])
        k = int(np.flatnonzero(gen == eos)[0])   # first occurrence wins

        sched = Scheduler(cfg, params, max_slots=1, max_len=64, eos_id=eos)
        reqs = [Request(prompt=prompt, n_tokens=8),
                Request(prompt=rng.integers(0, VOCAB, 6).astype(np.int32),
                        n_tokens=2)]
        r0, r1 = sched.serve(reqs)
        np.testing.assert_array_equal(r0.generated, gen[:k + 1])
        # Slot freed the step EOS was sampled: the queued request is
        # admitted at that very step (one slot total, so this is the
        # recycling path).
        assert r1.admitted_step == r0.finished_step
        assert sched.last_stats.prefills == 2

    def test_recycled_slot_output_independent_of_previous_occupant(self):
        """No cross-request KV leakage: a request served into a freshly
        recycled slot yields the same tokens as when it is served into a
        never-used pool."""
        cfg, params = _mk()
        rng = np.random.default_rng(4)
        probe = Request(prompt=rng.integers(0, VOCAB, 7).astype(np.int32),
                        n_tokens=6)
        alone = Scheduler(cfg, params, max_slots=1, max_len=64).serve(
            [dataclasses.replace(probe, rid=9)]
        )[0]
        for warm_len in (3, 13):   # different previous occupants
            warm = Request(
                prompt=rng.integers(0, VOCAB, warm_len).astype(np.int32),
                n_tokens=9,
            )
            sched = Scheduler(cfg, params, max_slots=1, max_len=64)
            _, again = sched.serve([warm, dataclasses.replace(probe, rid=9)])
            np.testing.assert_array_equal(alone.tokens, again.tokens)

    def test_prefill_insert_overwrites_whole_slot_region(self):
        """Recycling zeroes the cache beyond the new prompt: inserting a
        prefilled batch-of-1 cache replaces the slot's ENTIRE region,
        so K/V rows past the prompt hold init_cache zeros, not the
        previous occupant's keys."""
        cfg, params = _mk()
        P, max_len, slot = 5, 32, 1
        pool = jax.tree.map(
            lambda a: jnp.full_like(a, 7.0), lm.init_cache(cfg, 3, max_len)
        )
        tokens = np.arange(P, dtype=np.int32)[None] % VOCAB
        caches, _ = lm.prefill(params, {"tokens": jnp.asarray(tokens)}, cfg,
                               max_len=max_len)
        pool = lm.insert_cache_slot(pool, caches, slot)
        k = np.asarray(jnp.asarray(pool[0]["k"], jnp.float32))  # (groups, B, S, Hk, hd)
        assert np.all(k[:, slot, P:] == 0.0)       # old occupant gone
        assert np.any(k[:, slot, :P] != 0.0)       # new prompt present
        assert np.all(k[:, 0] == 7.0)              # untouched slots keep theirs

    def test_step_count_matches_analytic_schedule(self):
        """Scripted arrival trace vs an independent host-side simulation
        of the slot machine (admission before decode, retire on count)."""
        cfg, params = _mk()
        rng = np.random.default_rng(6)
        plens = [3, 4, 5, 6, 7, 9]
        ntoks = [4, 2, 7, 3, 5, 2]
        arrivals = [0, 0, 1, 4, 9, 9]
        reqs = [Request(prompt=rng.integers(0, VOCAB, p).astype(np.int32),
                        n_tokens=n, arrival=a)
                for p, n, a in zip(plens, ntoks, arrivals)]
        S = 2
        sched = Scheduler(cfg, params, max_slots=S, max_len=64)
        results = sched.serve(reqs)

        # Independent reference: tokens 2..n of a request each cost one
        # decode step; the first comes free with prefill at admission.
        queue = sorted(range(len(reqs)), key=lambda i: arrivals[i])
        remaining, admitted, finished = {}, {}, {}
        step = decode_steps = 0
        while queue or remaining:
            while queue and arrivals[queue[0]] <= step and len(remaining) < S:
                i = queue.pop(0)
                admitted[i] = step
                if ntoks[i] == 1:
                    finished[i] = step
                else:
                    remaining[i] = ntoks[i] - 1
            if not remaining:
                step += 1
                continue
            decode_steps += 1
            step += 1
            for i in [i for i in remaining]:
                remaining[i] -= 1
                if remaining[i] == 0:
                    del remaining[i]
                    finished[i] = step
        assert sched.last_stats.decode_steps == decode_steps
        assert sched.last_stats.steps == step
        for i, res in enumerate(results):
            assert res.admitted_step == admitted[i]
            assert res.finished_step == finished[i]


class TestSeedSemantics:
    def test_sampled_tokens_survive_arrival_permutation(self):
        """temperature > 0: per-request keys derive from (seed, rid), so
        permuting arrival order (different slots, different co-tenants)
        preserves every request's sampled tokens."""
        cfg, params = _mk(seed=1)
        sched = Scheduler(cfg, params, max_slots=2, max_len=64, seed=11)
        rng = np.random.default_rng(8)
        reqs = [Request(prompt=rng.integers(0, VOCAB, p).astype(np.int32),
                        n_tokens=5, temperature=1.3, rid=i)
                for i, p in enumerate([4, 7, 9, 12])]
        fwd = {r.rid: r.tokens for r in sched.serve(reqs)}
        rev = {r.rid: r.tokens for r in sched.serve(list(reversed(reqs)))}
        for rid in fwd:
            np.testing.assert_array_equal(fwd[rid], rev[rid])

    def test_sampled_tokens_match_engine_with_request_ids(self):
        cfg, params = _mk(seed=1)
        sched = Scheduler(cfg, params, max_slots=2, max_len=64, seed=11)
        eng = Engine(cfg, params, max_len=64)
        rng = np.random.default_rng(9)
        reqs = [Request(prompt=rng.integers(0, VOCAB, p).astype(np.int32),
                        n_tokens=4, temperature=0.9, rid=i)
                for i, p in enumerate([5, 8])]
        for req, res in zip(reqs, sched.serve(reqs)):
            ref = eng.generate(req.prompt[None], n_tokens=4, temperature=0.9,
                               seed=11, request_ids=[req.rid])
            np.testing.assert_array_equal(ref.tokens[0], res.tokens)

    def test_engine_batch_composition_independent(self):
        """Engine itself: sampling a request inside a batch equals
        sampling it alone when request_ids pin the PRNG streams."""
        cfg, params = _mk(seed=1)
        eng = Engine(cfg, params, max_len=64)
        rng = np.random.default_rng(10)
        prompts = rng.integers(0, VOCAB, (3, 6)).astype(np.int32)
        batch = eng.generate(prompts, n_tokens=5, temperature=1.1, seed=2,
                             request_ids=[20, 21, 22])
        for i, rid in enumerate([20, 21, 22]):
            solo = eng.generate(prompts[i:i + 1], n_tokens=5, temperature=1.1,
                                seed=2, request_ids=[rid])
            np.testing.assert_array_equal(batch.tokens[i], solo.tokens[0])

    def test_different_seeds_differ(self):
        cfg, params = _mk(seed=1)
        sched = Scheduler(cfg, params, max_slots=2, max_len=64)
        rng = np.random.default_rng(11)
        reqs = [Request(prompt=rng.integers(0, VOCAB, 8).astype(np.int32),
                        n_tokens=12, temperature=1.5)]
        a = sched.serve(reqs, seed=1)[0]
        b = sched.serve(reqs, seed=2)[0]
        assert not np.array_equal(a.tokens, b.tokens)


class TestProperties:
    @given(lens=st.lists(st.integers(1, 12), min_size=1, max_size=24))
    @settings(max_examples=30, deadline=None)
    def test_bucket_requests_partition(self, lens):
        """Original order recoverable, nothing dropped or duplicated,
        buckets equal-length."""
        rng = np.random.default_rng(sum(lens))
        prompts = [list(rng.integers(0, VOCAB, n)) for n in lens]
        buckets = bucket_requests(prompts)
        seen = []
        for idx, arr in buckets:
            assert arr.shape[0] == len(idx)
            for j, i in enumerate(idx):
                assert list(arr[j]) == prompts[i]
            seen.extend(idx)
        assert sorted(seen) == list(range(len(prompts)))

    @given(
        n_slots=st.integers(1, 6),
        ops=st.lists(st.integers(0, 1), min_size=1, max_size=60),
    )
    @settings(max_examples=30, deadline=None)
    def test_slot_allocator_never_double_assigns(self, n_slots, ops):
        alloc = SlotAllocator(n_slots)
        held = set()
        for op in ops:
            if op == 0 and alloc.free_count:
                s = alloc.acquire()
                assert s not in held          # never double-assigned
                assert 0 <= s < n_slots
                held.add(s)
            elif op == 1 and held:
                s = held.pop()
                alloc.release(s)
            assert alloc.free_count == n_slots - len(held)
            assert alloc.busy == frozenset(held)
        if alloc.free_count == 0:
            with pytest.raises(RuntimeError):
                alloc.acquire()

    def test_released_slot_reused_before_pool_grows(self):
        """LIFO recycling: the most recently retired slot is the next one
        handed out, and a full pool rejects acquisition rather than
        inventing slot ids."""
        alloc = SlotAllocator(3)
        a, b, c = alloc.acquire(), alloc.acquire(), alloc.acquire()
        alloc.release(b)
        assert alloc.acquire() == b
        with pytest.raises(RuntimeError):
            alloc.acquire()
        with pytest.raises(ValueError):
            alloc.release(9)


class TestDcimNumerics:
    def test_scheduler_matches_engine_under_dcim_numerics(self):
        """The DCIM execution path stays pluggable under the slotted
        decode: with every dense projection routed through the bit-serial
        INT8 macro sim, the Scheduler still serves token-exactly against
        the Engine running the same numerics."""
        from repro.core.precision import get as get_precision
        from repro.sim import DCIMMacroSim

        cfg, params = _mk()
        sim = DCIMMacroSim(get_precision("int8"), N=64, H=64, L=8, k=4)
        eng = Engine(cfg, params, max_len=32, dcim_sim=sim)
        sched = Scheduler(cfg, params, max_slots=2, max_len=32, dcim_sim=sim)
        rng = np.random.default_rng(12)
        reqs = [Request(prompt=rng.integers(0, VOCAB, p).astype(np.int32),
                        n_tokens=3) for p in (4, 6)]
        plain = Scheduler(cfg, params, max_slots=2, max_len=32).serve(reqs)
        for req, res in zip(reqs, sched.serve(reqs)):
            ref = eng.generate(req.prompt[None], n_tokens=3,
                               request_ids=[res.rid])
            np.testing.assert_array_equal(ref.tokens[0], res.tokens)
        # and the macro numerics actually changed the continuation
        assert any(
            not np.array_equal(p.tokens, d.tokens)
            for p, d in zip(plain, sched.serve(reqs))
        )
