"""Seeded-bad fixtures for the program-contract lint: every check must
FAIL on a program constructed to violate exactly its contract, and stay
quiet on the matching clean fixture.  The clean-repo pass itself is the
``python -m repro.analysis.lint --all`` gate in scripts/check.sh / CI;
here we pin down what each check detects."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import load_builtin_checks
from repro.analysis.registry import (
    CHECKS,
    Built,
    CompiledUnit,
    PallasTrace,
    PrecisionPolicy,
    Replay,
)
from repro.analysis.jaxpr_tools import (
    canonical_signature,
    compile_unit,
    strip_weak,
)
from repro.launch.hlo_analysis import collective_sites, op_output_bytes

load_builtin_checks()


def _errors(findings):
    return [f for f in findings if f.severity == "error"]


# --------------------------- donation ----------------------------------------
def test_donation_dropped_fixture():
    # Output is a scalar: XLA cannot alias the donated (256,256) input,
    # drops the donation silently (warning only) — the check must error.
    f = jax.jit(lambda x: x.sum(), donate_argnums=(0,))
    x = jnp.ones((256, 256), jnp.float32)
    unit = compile_unit("bad_donate", f, (x,), donate_argnums=(0,))
    findings = CHECKS["donation"]("fixture", Built(compiled=[unit]))
    errs = _errors(findings)
    assert len(errs) == 1
    assert "dropped" in errs[0].message
    assert errs[0].data["dropped"][0]["nbytes"] == 256 * 256 * 4


def test_donation_clean_fixture():
    # Same-shape output: the donation aliases, no findings at all.
    f = jax.jit(lambda x: x + 1.0, donate_argnums=(0,))
    x = jnp.ones((256, 256), jnp.float32)
    unit = compile_unit("good_donate", f, (x,), donate_argnums=(0,))
    findings = CHECKS["donation"]("fixture", Built(compiled=[unit]))
    assert not _errors(findings)


# --------------------------- transfers ---------------------------------------
def test_transfers_implicit_fixture():
    # A raw numpy array handed straight to a jitted program is an
    # implicit host-to-device transfer: the guard raises, the check errors.
    f = jax.jit(lambda x: x + 1.0)
    f(jnp.zeros(8, jnp.float32))  # warm: only the replay runs guarded
    built = Built(hot=lambda: f(np.zeros(8, np.float32)),
                  hot_label="raw-numpy call")
    errs = _errors(CHECKS["transfers"]("fixture", built))
    assert len(errs) == 1
    assert "implicit transfer" in errs[0].message


def test_transfers_clean_fixture():
    f = jax.jit(lambda x: x + 1.0)
    x = jnp.zeros(8, jnp.float32)
    f(x)
    built = Built(hot=lambda: jax.block_until_ready(f(x)))
    assert not CHECKS["transfers"]("fixture", built)


def test_transfers_host_callback_fixture():
    # A pure_callback inside the traced hot program is a per-step host
    # sync — flagged from the jaxpr walk alone, nothing is executed.
    def g(x):
        y = jax.pure_callback(
            lambda a: np.asarray(a) * 2,
            jax.ShapeDtypeStruct((4,), jnp.float32), x)
        return y + 1.0

    jaxpr = jax.make_jaxpr(g)(jnp.zeros(4, jnp.float32))
    built = Built(hot_jaxprs=[("g", jaxpr)])
    errs = _errors(CHECKS["transfers"]("fixture", built))
    assert len(errs) == 1
    assert "pure_callback" in errs[0].message


# --------------------------- recompile ---------------------------------------
def test_recompile_weak_type_drift_fixture():
    # Same program called with a committed array and a Python-scalar-weak
    # aval: signatures differ only in the weak bit.
    committed = canonical_signature((jnp.float32(1.0) * jnp.ones(()),))
    weak = canonical_signature((jnp.asarray(1.0),))
    if strip_weak(committed) == strip_weak(weak) and committed != weak:
        sigs = [("step", committed), ("step", weak)]
    else:  # fallback: handcrafted signatures with the same invariant
        sigs = [("step", "T::float32[]|w0"), ("step", "T::float32[]|w1")]
    replay = Replay(signatures=sigs, max_programs={"step": 1})
    errs = _errors(CHECKS["recompile"]("fixture", Built(replay=replay)))
    assert any("weak-type drift" in e.message for e in errs)
    assert any("retraces" in e.message for e in errs)


def test_recompile_budget_and_live_cache_fixture():
    replay = Replay(
        signatures=[("step", "T::float32[2]|w0"),
                    ("step", "T::float32[4]|w0")],
        max_programs={"step": 1},
        live_counts={"step": 3},
        live_budget={"step": 1},
    )
    errs = _errors(CHECKS["recompile"]("fixture", Built(replay=replay)))
    assert any("2 distinct abstract signatures" in e.message for e in errs)
    assert any("holds 3 compiled programs" in e.message for e in errs)
    assert not any("weak-type" in e.message for e in errs)


def test_recompile_clean_fixture():
    replay = Replay(
        signatures=[("step", "T::float32[2]|w0")] * 3,
        max_programs={"step": 1},
        live_counts={"step": 1}, live_budget={"step": 1},
    )
    assert not CHECKS["recompile"]("fixture", Built(replay=replay))


# --------------------------- collectives -------------------------------------
# Hand-written post-SPMD module: an all-gather inside a while loop whose
# condition bounds the counter at 8 — the site must be reported with its
# byte size AND the x8 trip multiplier.
_BAD_HLO = """\
HloModule fixture

%body (param.1: (s32[], f32[16,16])) -> (s32[], f32[16,16]) {
  %param.1 = (s32[], f32[16,16]) parameter(0)
  %gte.0 = s32[] get-tuple-element((s32[], f32[16,16]) %param.1), index=0
  %one = s32[] constant(1)
  %next = s32[] add(s32[] %gte.0, s32[] %one)
  %gte.1 = f32[16,16] get-tuple-element((s32[], f32[16,16]) %param.1), index=1
  %ag = f32[16,16] all-gather(f32[2,16] %gte.1), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  ROOT %tup = (s32[], f32[16,16]) tuple(s32[] %next, f32[16,16] %ag)
}

%cond (param.2: (s32[], f32[16,16])) -> pred[] {
  %param.2 = (s32[], f32[16,16]) parameter(0)
  %gte.2 = s32[] get-tuple-element((s32[], f32[16,16]) %param.2), index=0
  %trips = s32[] constant(8)
  ROOT %lt = pred[] compare(s32[] %gte.2, s32[] %trips), direction=LT
}

ENTRY %main (arg: f32[16,16]) -> f32[16,16] {
  %arg = f32[16,16] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[16,16]) tuple(s32[] %zero, f32[16,16] %arg)
  %loop = (s32[], f32[16,16]) while((s32[], f32[16,16]) %init), condition=%cond, body=%body
  ROOT %res = f32[16,16] get-tuple-element((s32[], f32[16,16]) %loop), index=1
}
"""


def test_collectives_oversized_fixture():
    unit = CompiledUnit(label="bad_spmd", hlo=_BAD_HLO,
                        collective_budget={"all-gather": 512})
    errs = _errors(CHECKS["collectives"]("fixture", Built(compiled=[unit])))
    assert len(errs) == 1
    site = errs[0].data["site"]
    assert site["collective"] == "all-gather"
    assert site["bytes"] == 16 * 16 * 4          # 1024 > 512 budget
    assert site["trip_mult"] == 8                # while trips attached


def test_collectives_forbidden_and_clean_fixture():
    unit0 = CompiledUnit(label="forbid", hlo=_BAD_HLO,
                         collective_budget={"all-gather": 0})
    errs = _errors(CHECKS["collectives"]("fixture", Built(compiled=[unit0])))
    assert len(errs) == 1 and "forbidden" in errs[0].message

    unit1 = CompiledUnit(label="roomy", hlo=_BAD_HLO,
                         collective_budget={"all-gather": 1 << 20})
    findings = CHECKS["collectives"]("fixture", Built(compiled=[unit1]))
    assert not _errors(findings)
    assert any("within budget" in f.message for f in findings)


# --------------------------- pallas ------------------------------------------
def _bad_pallas_trace():
    import jax.experimental.pallas as pl

    def kern(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def bad(x):
        return pl.pallas_call(
            kern,
            out_shape=jax.ShapeDtypeStruct((64, 700), x.dtype),
            grid=(4,),
            in_specs=[pl.BlockSpec((16, 100), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((16, 100), lambda i: (i, 0)),
            interpret=True,
        )(x)

    return jax.make_jaxpr(bad)(jnp.zeros((64, 700), jnp.float32))


def test_pallas_misaligned_and_short_grid_fixture():
    trace = PallasTrace(label="bad_kernel",
                        closed_jaxpr=_bad_pallas_trace())
    findings = CHECKS["pallas"]("fixture", Built(pallas=[trace]))
    errs = _errors(findings)
    # Last block dim 100: neither the full 700 nor a multiple of 128.
    assert any("lane tile" in e.message for e in errs)
    # Grid (4,) x block (16,100) via (i, 0) covers 100 of 700 in dim 1.
    assert any("never visited" in e.message for e in errs)


def test_pallas_clean_repo_kernels():
    # The real kernels' contract must lint clean: errors here mean either
    # a kernel regressed or the tiling rules drifted from reality.
    from repro.analysis.lint import run_lint

    report = run_lint(checks=["pallas"], contracts=["kernels.pallas"])
    assert report.ok, [f.message for f in report.findings]
    assert "kernels.pallas" in report.contracts_executed


# --------------------------- precision ---------------------------------------
def _precision(built):
    return CHECKS["precision"]("fixture", built)


def test_precision_hidden_f64_fixture():
    # x64 enabled during tracing: a single f64 constant promotes the
    # whole chain — the forbidden-dtype rule must name the dtype.
    with jax.experimental.enable_x64():
        jaxpr = jax.make_jaxpr(
            lambda x: x * np.float64(2.0)
        )(jnp.zeros(4, jnp.float64))
    built = Built(hot_jaxprs=[("f64", jaxpr)],
                  precision=PrecisionPolicy(compute_dtype="float32"))
    errs = _errors(_precision(built))
    assert any("float64" in e.message for e in errs)


def test_precision_widening_needs_island_fixture():
    from repro.models import common

    def bad(x):
        return x.astype(jnp.float32).sum()

    def good(x):
        with common.precision_island("logits"):
            return x.astype(jnp.float32).sum()

    x = jnp.zeros(4, jnp.bfloat16)
    policy = PrecisionPolicy(compute_dtype="bfloat16")
    errs = _errors(_precision(Built(
        hot_jaxprs=[("p", jax.make_jaxpr(bad)(x))], precision=policy)))
    assert len(errs) == 1 and "widening cast" in errs[0].message
    assert not _errors(_precision(Built(
        hot_jaxprs=[("p", jax.make_jaxpr(good)(x))], precision=policy)))


def test_precision_dot_accumulation_fixture():
    x = jnp.zeros((4, 8), jnp.bfloat16)
    w = jnp.zeros((8, 2), jnp.bfloat16)
    policy = PrecisionPolicy(compute_dtype="bfloat16")
    bad = jax.make_jaxpr(lambda a, b: jax.lax.dot(a, b))(x, w)
    errs = _errors(_precision(Built(
        hot_jaxprs=[("p", bad)], precision=policy)))
    assert len(errs) == 1
    assert "preferred_element_type=float32" in errs[0].message
    good = jax.make_jaxpr(lambda a, b: jnp.matmul(
        a, b, preferred_element_type=jnp.float32))(x, w)
    assert not _errors(_precision(Built(
        hot_jaxprs=[("p", good)], precision=policy)))


def test_precision_dcim_bypassed_dense_fixture():
    # A raw float matmul inside the dense island while the policy says
    # this program routes through the DCIM sim: structural bypass.
    from repro.models import common

    def bypass(x, w):
        with common.precision_island("dense"):
            return jnp.matmul(x, w, preferred_element_type=jnp.float32)

    jaxpr = jax.make_jaxpr(bypass)(
        jnp.zeros((4, 8), jnp.float32), jnp.zeros((8, 2), jnp.float32))
    built = Built(
        hot_jaxprs=[("decode", jaxpr)],
        precision=PrecisionPolicy(
            compute_dtype="float32", dcim_programs={"decode": "int8"}),
    )
    errs = _errors(_precision(built))
    assert any("bypasses the installed DCIM numerics" in e.message
               for e in errs)
    assert any("never calls" in e.message for e in errs)


def test_precision_asymmetric_clip_fixture():
    # The historical quantizer bug: clip to [-qmax-1, qmax] while the
    # scale is amax/qmax.  B-recovery from the clip constants flags it.
    from repro.kernels import ops
    from repro.models import common

    def bad_mvm(x, w):
        with common.precision_island("dense"):
            qmax = 127
            sx = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / qmax
            sw = jnp.maximum(jnp.max(jnp.abs(w)), 1e-12) / qmax
            qx = jnp.clip(jnp.round(x / sx), -qmax - 1, qmax)
            qw = jnp.clip(jnp.round(w / sw), -qmax, qmax)
            y = ops.dcim_mvm(qx.astype(jnp.int32), qw.astype(jnp.int32),
                             B_x=8, B_w=8, k=4)
            return y.astype(jnp.float32) * (sx * sw)

    jaxpr = jax.make_jaxpr(bad_mvm)(
        jnp.zeros((4, 8), jnp.float32), jnp.zeros((8, 4), jnp.float32))
    built = Built(
        hot_jaxprs=[("decode", jaxpr)],
        precision=PrecisionPolicy(
            compute_dtype="float32", dcim_programs={"decode": "int8"}),
    )
    errs = _errors(_precision(built))
    assert any("asymmetric quantizer clip [-128.0, 127.0]" in e.message
               for e in errs)
    # The symmetric clip still recovers B=8 — only the bad one errors.
    assert not any("recovers bit widths" in e.message for e in errs)


def test_precision_gate_lossy_and_unmatched_fixture():
    # The gate re-derives from traced pool leaves, not config flags: a
    # bf16 pool behind an enabled gate under f32 compute must error, as
    # must a leaf the program never takes as input.
    from repro.analysis.jaxpr_tools import pytree_leaf_specs
    from repro.analysis.registry import ExactnessGate

    pool = {"k": jnp.zeros((2, 4), jnp.bfloat16),
            "v": jnp.zeros((2, 4), jnp.bfloat16)}
    jaxpr = jax.make_jaxpr(
        lambda p, x: (p["k"].sum(), p["v"].sum(), x)
    )(pool, jnp.zeros((), jnp.float32))
    leaves = pytree_leaf_specs(pool)
    built = Built(
        hot_jaxprs=[("decode", jaxpr)],
        precision=PrecisionPolicy(
            compute_dtype="float32", audit_widening=False,
            gates=[
                ExactnessGate("prefix_reuse", True, "decode", leaves),
                ExactnessGate("preempt", True, "decode",
                              [("['missing']", "float32", (9, 9))]),
                ExactnessGate("orphan", True, "never_traced", leaves),
            ]),
    )
    errs = _errors(_precision(built))
    assert any("claimed ENABLED" in e.message and "lossy" in e.message
               for e in errs)
    assert any("not an input of the traced" in e.message for e in errs)
    assert any("did not trace" in e.message for e in errs)


def test_precision_gate_verified_fixture():
    from repro.analysis.jaxpr_tools import pytree_leaf_specs
    from repro.analysis.registry import ExactnessGate

    pool = {"k": jnp.zeros((2, 4), jnp.float32)}
    jaxpr = jax.make_jaxpr(lambda p: p["k"].sum())(pool)
    built = Built(
        hot_jaxprs=[("decode", jaxpr)],
        precision=PrecisionPolicy(
            compute_dtype="float32",
            gates=[ExactnessGate("prefix_reuse", True, "decode",
                                 pytree_leaf_specs(pool))]),
    )
    findings = _precision(built)
    assert not _errors(findings)
    assert any("verified" in f.message for f in findings)


def test_precision_clean_repo_contracts():
    # The dcim-serve contract must lint clean end-to-end AND positively
    # verify the routing (info findings, not silence).
    from repro.analysis.lint import run_lint

    report = run_lint(checks=["precision"], contracts=["sim.dcim_serve"])
    assert report.ok, [f.message for f in report.findings]
    msgs = [f.message for f in report.findings]
    assert any("DCIM int routing verified" in m for m in msgs)
    assert any("DCIM fp routing verified" in m for m in msgs)


def test_lint_runtime_budget(tmp_path):
    from repro.analysis.findings import Report
    from repro.analysis.lint import check_runtime_budget

    bench = tmp_path / "BENCH_lint.json"
    r = Report(timings={"c:build": 1.0})
    # First run records the baseline ...
    assert check_runtime_budget(r, 10.0, str(bench)) is None
    assert bench.exists()
    # ... within 2x passes, beyond 2x fails.
    assert check_runtime_budget(r, 19.0, str(bench)) is None
    msg = check_runtime_budget(r, 21.0, str(bench))
    assert msg is not None and "exceeds budget" in msg


# --------------------------- fp8 byte accounting (satellite) ------------------
def test_fp8_hlo_byte_accounting():
    line = ("  %ag = f8e4m3fn[2048]{0} all-gather(f8e4m3fn[256]{0} %x), "
            "replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}")
    assert op_output_bytes(line) == 2048          # 1 byte/element

    hlo = _BAD_HLO.replace("f32[16,16]", "f8e5m2[16,16]").replace(
        "f32[2,16]", "f8e5m2[2,16]")
    (site,) = collective_sites(hlo)
    assert site["bytes"] == 16 * 16               # fp8: 1 byte, not 4
    assert site["trip_mult"] == 8


def test_op_output_bytes_parses_result_not_name():
    # Regression: the byte counter must read the RESULT shape (after
    # '='), including tuple results — not the op name.
    dot = ("  ROOT %dot.2 = f32[8,8]{1,0} dot(f32[8,8]{1,0} %a, "
           "f32[8,8]{1,0} %b), lhs_contracting_dims={1}")
    assert op_output_bytes(dot) == 8 * 8 * 4
    tup = "  %t = (f32[64]{0}, s32[]) tuple(%a, %b)"
    assert op_output_bytes(tup) == 64 * 4 + 4


# --------------------------- runner ------------------------------------------
def test_lint_cli_list_and_unknown():
    from repro.analysis.lint import main, run_lint

    assert main(["--list"]) == 0
    with pytest.raises(ValueError, match="unknown"):
        run_lint(checks=["nope"])
