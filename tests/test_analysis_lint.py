"""Seeded-bad fixtures for the program-contract lint: every check must
FAIL on a program constructed to violate exactly its contract, and stay
quiet on the matching clean fixture.  The clean-repo pass itself is the
``python -m repro.analysis.lint --all`` gate in scripts/check.sh / CI;
here we pin down what each check detects."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import load_builtin_checks
from repro.analysis.registry import (
    CHECKS,
    Built,
    CompiledUnit,
    PallasTrace,
    Replay,
)
from repro.analysis.jaxpr_tools import (
    canonical_signature,
    compile_unit,
    strip_weak,
)
from repro.launch.hlo_analysis import collective_sites, op_output_bytes

load_builtin_checks()


def _errors(findings):
    return [f for f in findings if f.severity == "error"]


# --------------------------- donation ----------------------------------------
def test_donation_dropped_fixture():
    # Output is a scalar: XLA cannot alias the donated (256,256) input,
    # drops the donation silently (warning only) — the check must error.
    f = jax.jit(lambda x: x.sum(), donate_argnums=(0,))
    x = jnp.ones((256, 256), jnp.float32)
    unit = compile_unit("bad_donate", f, (x,), donate_argnums=(0,))
    findings = CHECKS["donation"]("fixture", Built(compiled=[unit]))
    errs = _errors(findings)
    assert len(errs) == 1
    assert "dropped" in errs[0].message
    assert errs[0].data["dropped"][0]["nbytes"] == 256 * 256 * 4


def test_donation_clean_fixture():
    # Same-shape output: the donation aliases, no findings at all.
    f = jax.jit(lambda x: x + 1.0, donate_argnums=(0,))
    x = jnp.ones((256, 256), jnp.float32)
    unit = compile_unit("good_donate", f, (x,), donate_argnums=(0,))
    findings = CHECKS["donation"]("fixture", Built(compiled=[unit]))
    assert not _errors(findings)


# --------------------------- transfers ---------------------------------------
def test_transfers_implicit_fixture():
    # A raw numpy array handed straight to a jitted program is an
    # implicit host-to-device transfer: the guard raises, the check errors.
    f = jax.jit(lambda x: x + 1.0)
    f(jnp.zeros(8, jnp.float32))  # warm: only the replay runs guarded
    built = Built(hot=lambda: f(np.zeros(8, np.float32)),
                  hot_label="raw-numpy call")
    errs = _errors(CHECKS["transfers"]("fixture", built))
    assert len(errs) == 1
    assert "implicit transfer" in errs[0].message


def test_transfers_clean_fixture():
    f = jax.jit(lambda x: x + 1.0)
    x = jnp.zeros(8, jnp.float32)
    f(x)
    built = Built(hot=lambda: jax.block_until_ready(f(x)))
    assert not CHECKS["transfers"]("fixture", built)


def test_transfers_host_callback_fixture():
    # A pure_callback inside the traced hot program is a per-step host
    # sync — flagged from the jaxpr walk alone, nothing is executed.
    def g(x):
        y = jax.pure_callback(
            lambda a: np.asarray(a) * 2,
            jax.ShapeDtypeStruct((4,), jnp.float32), x)
        return y + 1.0

    jaxpr = jax.make_jaxpr(g)(jnp.zeros(4, jnp.float32))
    built = Built(hot_jaxprs=[("g", jaxpr)])
    errs = _errors(CHECKS["transfers"]("fixture", built))
    assert len(errs) == 1
    assert "pure_callback" in errs[0].message


# --------------------------- recompile ---------------------------------------
def test_recompile_weak_type_drift_fixture():
    # Same program called with a committed array and a Python-scalar-weak
    # aval: signatures differ only in the weak bit.
    committed = canonical_signature((jnp.float32(1.0) * jnp.ones(()),))
    weak = canonical_signature((jnp.asarray(1.0),))
    if strip_weak(committed) == strip_weak(weak) and committed != weak:
        sigs = [("step", committed), ("step", weak)]
    else:  # fallback: handcrafted signatures with the same invariant
        sigs = [("step", "T::float32[]|w0"), ("step", "T::float32[]|w1")]
    replay = Replay(signatures=sigs, max_programs={"step": 1})
    errs = _errors(CHECKS["recompile"]("fixture", Built(replay=replay)))
    assert any("weak-type drift" in e.message for e in errs)
    assert any("retraces" in e.message for e in errs)


def test_recompile_budget_and_live_cache_fixture():
    replay = Replay(
        signatures=[("step", "T::float32[2]|w0"),
                    ("step", "T::float32[4]|w0")],
        max_programs={"step": 1},
        live_counts={"step": 3},
        live_budget={"step": 1},
    )
    errs = _errors(CHECKS["recompile"]("fixture", Built(replay=replay)))
    assert any("2 distinct abstract signatures" in e.message for e in errs)
    assert any("holds 3 compiled programs" in e.message for e in errs)
    assert not any("weak-type" in e.message for e in errs)


def test_recompile_clean_fixture():
    replay = Replay(
        signatures=[("step", "T::float32[2]|w0")] * 3,
        max_programs={"step": 1},
        live_counts={"step": 1}, live_budget={"step": 1},
    )
    assert not CHECKS["recompile"]("fixture", Built(replay=replay))


# --------------------------- collectives -------------------------------------
# Hand-written post-SPMD module: an all-gather inside a while loop whose
# condition bounds the counter at 8 — the site must be reported with its
# byte size AND the x8 trip multiplier.
_BAD_HLO = """\
HloModule fixture

%body (param.1: (s32[], f32[16,16])) -> (s32[], f32[16,16]) {
  %param.1 = (s32[], f32[16,16]) parameter(0)
  %gte.0 = s32[] get-tuple-element((s32[], f32[16,16]) %param.1), index=0
  %one = s32[] constant(1)
  %next = s32[] add(s32[] %gte.0, s32[] %one)
  %gte.1 = f32[16,16] get-tuple-element((s32[], f32[16,16]) %param.1), index=1
  %ag = f32[16,16] all-gather(f32[2,16] %gte.1), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  ROOT %tup = (s32[], f32[16,16]) tuple(s32[] %next, f32[16,16] %ag)
}

%cond (param.2: (s32[], f32[16,16])) -> pred[] {
  %param.2 = (s32[], f32[16,16]) parameter(0)
  %gte.2 = s32[] get-tuple-element((s32[], f32[16,16]) %param.2), index=0
  %trips = s32[] constant(8)
  ROOT %lt = pred[] compare(s32[] %gte.2, s32[] %trips), direction=LT
}

ENTRY %main (arg: f32[16,16]) -> f32[16,16] {
  %arg = f32[16,16] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[16,16]) tuple(s32[] %zero, f32[16,16] %arg)
  %loop = (s32[], f32[16,16]) while((s32[], f32[16,16]) %init), condition=%cond, body=%body
  ROOT %res = f32[16,16] get-tuple-element((s32[], f32[16,16]) %loop), index=1
}
"""


def test_collectives_oversized_fixture():
    unit = CompiledUnit(label="bad_spmd", hlo=_BAD_HLO,
                        collective_budget={"all-gather": 512})
    errs = _errors(CHECKS["collectives"]("fixture", Built(compiled=[unit])))
    assert len(errs) == 1
    site = errs[0].data["site"]
    assert site["collective"] == "all-gather"
    assert site["bytes"] == 16 * 16 * 4          # 1024 > 512 budget
    assert site["trip_mult"] == 8                # while trips attached


def test_collectives_forbidden_and_clean_fixture():
    unit0 = CompiledUnit(label="forbid", hlo=_BAD_HLO,
                         collective_budget={"all-gather": 0})
    errs = _errors(CHECKS["collectives"]("fixture", Built(compiled=[unit0])))
    assert len(errs) == 1 and "forbidden" in errs[0].message

    unit1 = CompiledUnit(label="roomy", hlo=_BAD_HLO,
                         collective_budget={"all-gather": 1 << 20})
    findings = CHECKS["collectives"]("fixture", Built(compiled=[unit1]))
    assert not _errors(findings)
    assert any("within budget" in f.message for f in findings)


# --------------------------- pallas ------------------------------------------
def _bad_pallas_trace():
    import jax.experimental.pallas as pl

    def kern(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def bad(x):
        return pl.pallas_call(
            kern,
            out_shape=jax.ShapeDtypeStruct((64, 700), x.dtype),
            grid=(4,),
            in_specs=[pl.BlockSpec((16, 100), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((16, 100), lambda i: (i, 0)),
            interpret=True,
        )(x)

    return jax.make_jaxpr(bad)(jnp.zeros((64, 700), jnp.float32))


def test_pallas_misaligned_and_short_grid_fixture():
    trace = PallasTrace(label="bad_kernel",
                        closed_jaxpr=_bad_pallas_trace())
    findings = CHECKS["pallas"]("fixture", Built(pallas=[trace]))
    errs = _errors(findings)
    # Last block dim 100: neither the full 700 nor a multiple of 128.
    assert any("lane tile" in e.message for e in errs)
    # Grid (4,) x block (16,100) via (i, 0) covers 100 of 700 in dim 1.
    assert any("never visited" in e.message for e in errs)


def test_pallas_clean_repo_kernels():
    # The real kernels' contract must lint clean: errors here mean either
    # a kernel regressed or the tiling rules drifted from reality.
    from repro.analysis.lint import run_lint

    report = run_lint(checks=["pallas"], contracts=["kernels.pallas"])
    assert report.ok, [f.message for f in report.findings]
    assert "kernels.pallas" in report.contracts_executed


# --------------------------- fp8 byte accounting (satellite) ------------------
def test_fp8_hlo_byte_accounting():
    line = ("  %ag = f8e4m3fn[2048]{0} all-gather(f8e4m3fn[256]{0} %x), "
            "replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}")
    assert op_output_bytes(line) == 2048          # 1 byte/element

    hlo = _BAD_HLO.replace("f32[16,16]", "f8e5m2[16,16]").replace(
        "f32[2,16]", "f8e5m2[2,16]")
    (site,) = collective_sites(hlo)
    assert site["bytes"] == 16 * 16               # fp8: 1 byte, not 4
    assert site["trip_mult"] == 8


def test_op_output_bytes_parses_result_not_name():
    # Regression: the byte counter must read the RESULT shape (after
    # '='), including tuple results — not the op name.
    dot = ("  ROOT %dot.2 = f32[8,8]{1,0} dot(f32[8,8]{1,0} %a, "
           "f32[8,8]{1,0} %b), lhs_contracting_dims={1}")
    assert op_output_bytes(dot) == 8 * 8 * 4
    tup = "  %t = (f32[64]{0}, s32[]) tuple(%a, %b)"
    assert op_output_bytes(tup) == 64 * 4 + 4


# --------------------------- runner ------------------------------------------
def test_lint_cli_list_and_unknown():
    from repro.analysis.lint import main, run_lint

    assert main(["--list"]) == 0
    with pytest.raises(ValueError, match="unknown"):
        run_lint(checks=["nope"])
