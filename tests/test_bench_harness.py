"""Regression tests for the benchmark subprocess-child harness
(``benchmarks.common.run_child``).

The hazard under test: benchmark drivers build their JSON record from
child stdout, and before the shared helper a child that crashed AFTER
printing partial output — or whose last line wasn't the record at all —
could let ``--smoke`` CI re-publish last run's BENCH_*.json section
looking current.  The helper must turn both cases into a loud failure.
"""
import pytest

from benchmarks import common


class TestRunChild:
    def test_returns_last_line_record(self, capsys):
        rec = common.run_child(
            ["-c", "print('progress line'); "
                   "print('{\"ok\": 1, \"n\": 2}')"]
        )
        assert rec == {"ok": 1, "n": 2}
        # without echo, progress lines stay captured
        assert "progress line" not in capsys.readouterr().out

    def test_echo_forwards_progress_lines_not_record(self, capsys):
        rec = common.run_child(
            ["-c", "print('k1,12.5,'); print('{\"ok\": true}')"], echo=True
        )
        assert rec == {"ok": True}
        out = capsys.readouterr().out
        assert "k1,12.5," in out
        assert '"ok"' not in out

    def test_nonzero_exit_raises_even_with_valid_json(self):
        """A child that prints a plausible record and THEN dies must not
        have that record believed."""
        with pytest.raises(RuntimeError, match=r"rc=3"):
            common.run_child(
                ["-c", "import sys; print('{\"ok\": 1}'); sys.exit(3)"],
                label="crashy",
            )

    def test_error_carries_stderr_tail(self):
        with pytest.raises(RuntimeError, match="boom-marker"):
            common.run_child(
                ["-c", "raise SystemExit('boom-marker')"]
            )

    def test_garbage_last_line_raises(self):
        with pytest.raises(RuntimeError, match="no JSON record"):
            common.run_child(["-c", "print('done in 3.2s')"])

    def test_non_dict_json_last_line_raises(self):
        # a bare list/number is not a benchmark record either
        with pytest.raises(RuntimeError, match="no JSON record"):
            common.run_child(["-c", "print('[1, 2]')"])

    def test_empty_stdout_raises(self):
        with pytest.raises(RuntimeError, match="no JSON record"):
            common.run_child(["-c", "pass"])

    def test_env_extra_reaches_child(self):
        rec = common.run_child(
            ["-c", "import os, json; "
                   "print(json.dumps({'v': os.environ.get('BENCH_TEST_VAR'),"
                   " 'pp': 'src' in os.environ['PYTHONPATH']}))"],
            env_extra={"BENCH_TEST_VAR": "42"},
        )
        assert rec == {"v": "42", "pp": True}


class TestDriversUseHarness:
    """The drivers must route every child through the shared helper —
    a local re-implementation would reintroduce the silent-stale hazard."""

    def test_bench_serve_spawn_delegates(self):
        from benchmarks import bench_serve

        assert bench_serve.run_child is common.run_child

    def test_bench_kernels_delegates(self):
        from benchmarks import bench_kernels

        assert bench_kernels.run_child is common.run_child

    def test_bench_serve_sharded_child_forces_devices(self):
        """The sharded child refuses to run without the forced-8-device
        platform — guards against the parent dropping the XLA_FLAGS
        env."""
        argv = ["-m", "benchmarks.bench_serve", "--run-one", "sharded",
                "--smoke"]
        with pytest.raises(RuntimeError, match="expected 8 forced devices"):
            common.run_child(argv, timeout=300)
