"""Property tests for the dtype-provenance walk (analysis.dtype_flow).

Synthetic jaxprs — random cast chains, nested islands, scan/cond
sub-jaxprs — drive the structural invariants the precision check relies
on: provenance forms a DAG, every variable is classified exactly once,
and an island annotation masks exactly the subtree traced inside it
(including jitted helpers, whose sub-jaxpr name stacks are relative and
must inherit the enclosing islands).
"""
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.analysis import dtype_flow
from repro.models import common

_DTYPES = ("float32", "bfloat16", "float16", "int32", "float32")


def _all_vars(jaxpr, acc=None):
    """Every Var reachable in a jaxpr, including sub-jaxpr binders."""
    acc = set() if acc is None else acc
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    acc.update(jaxpr.constvars)
    acc.update(jaxpr.invars)
    for eqn in jaxpr.eqns:
        acc.update(eqn.outvars)
        for sub in dtype_flow._sub_jaxprs(eqn.params):
            _all_vars(sub, acc)
    return acc


def _assert_acyclic(graph):
    state = {}                       # node -> 1 (on stack) | 2 (done)
    for root in graph:
        stack = [(root, iter(graph.get(root, ())))]
        if state.get(root):
            continue
        state[root] = 1
        while stack:
            node, it = stack[-1]
            dep = next(it, None)
            if dep is None:
                state[node] = 2
                stack.pop()
                continue
            mark = state.get(dep)
            assert mark != 1, f"provenance cycle through {dep}"
            if mark is None:
                state[dep] = 1
                stack.append((dep, iter(graph.get(dep, ()))))


def _chain_flow(dtypes):
    def prog(x):
        h = x
        with common.precision_island("outer"):
            for i, d in enumerate(dtypes):
                with common.precision_island(f"inner{i}"):
                    h = h.astype(d)
        return h

    jaxpr = jax.make_jaxpr(prog)(jnp.zeros((4,), jnp.float32))
    return jaxpr, dtype_flow.analyze(jaxpr)


@settings(max_examples=25, deadline=None)
@given(dtypes=st.lists(st.sampled_from(_DTYPES), min_size=1, max_size=6))
def test_chain_provenance_acyclic_and_complete(dtypes):
    jaxpr, flow = _chain_flow(dtypes)
    _assert_acyclic(flow.provenance_graph())
    # Every variable classified, and exactly once: the record map's keys
    # are precisely the variables the jaxpr binds anywhere.
    assert set(flow.records) == _all_vars(jaxpr)
    # Each realized dtype in the chain was observed by the walk.
    for d in dtypes:
        assert d in flow.dtypes


@settings(max_examples=25, deadline=None)
@given(dtypes=st.lists(st.sampled_from(_DTYPES), min_size=1, max_size=6))
def test_island_masks_exactly_its_subtree(dtypes):
    _, flow = _chain_flow(dtypes)
    # A cast eqn exists exactly where the chain's dtype changes; its
    # islands must be {outer, inner<i>} for that step and nothing else.
    prev = "float32"
    expected = set()
    for i, d in enumerate(dtypes):
        if d != prev:
            expected.add(f"inner{i}")
        prev = d
    seen = set()
    for cast in flow.casts:
        assert "outer" in cast.islands
        inner = {n for n in cast.islands if n.startswith("inner")}
        assert len(inner) == 1, cast
        seen |= inner
    assert seen == expected


def test_jitted_helper_inherits_enclosing_island():
    # Sub-jaxpr name stacks are relative: a helper traced inside an
    # island must still be attributed to it through the pjit boundary.
    @jax.jit
    def helper(v):
        return v.astype(jnp.float32)

    def prog(x):
        with common.precision_island("norm"):
            y = helper(x)
        z = x.astype(jnp.float32)        # identical cast, outside
        return y + z

    flow = dtype_flow.analyze(
        jax.make_jaxpr(prog)(jnp.zeros((4,), jnp.bfloat16))
    )
    widening = [c for c in flow.casts if c.widening]
    assert {frozenset(c.islands) for c in widening} == {
        frozenset({"norm"}), frozenset()
    }
    inside = next(c for c in widening if c.islands)
    assert "helper" in inside.fns


def test_scan_and_cond_subjaxprs_fully_classified():
    def prog(x, flag):
        def body(carry, _):
            c = carry.astype(jnp.float32) * 2.0
            return c.astype(x.dtype), c.sum()

        h, ys = jax.lax.scan(body, x, None, length=3)
        out = jax.lax.cond(
            flag, lambda v: v.astype(jnp.float32).sum(),
            lambda v: jnp.zeros((), jnp.float32), h
        )
        return out, ys

    jaxpr = jax.make_jaxpr(prog)(
        jnp.zeros((4,), jnp.bfloat16), jnp.asarray(True)
    )
    flow = dtype_flow.analyze(jaxpr)
    assert set(flow.records) == _all_vars(jaxpr)
    _assert_acyclic(flow.provenance_graph())
    # The widening casts live inside scan/cond sub-jaxprs; the walk must
    # have descended to see them.
    assert any(c.widening for c in flow.casts)
    assert flow.n_eqns > len(jaxpr.jaxpr.eqns)


@given(
    src=st.sampled_from(sorted(dtype_flow._ITEMSIZE)),
    dst=st.sampled_from(sorted(dtype_flow._ITEMSIZE)),
)
def test_widening_rule_reference(src, dst):
    expect = (
        src != "bool"
        and dst.startswith(("float", "bfloat"))
        and dtype_flow.itemsize(dst) > dtype_flow.itemsize(src)
    )
    assert dtype_flow.is_widening_cast(src, dst) == expect


def test_dot_and_clip_sites_recovered():
    def prog(x, w):
        with common.precision_island("dense"):
            q = jnp.clip(jnp.round(x * 4.0), -127, 127)
            y = jnp.matmul(x, w, preferred_element_type=jnp.float32)
        return y + q.sum()

    flow = dtype_flow.analyze(jax.make_jaxpr(prog)(
        jnp.zeros((4, 8), jnp.float32), jnp.zeros((8, 2), jnp.float32)
    ))
    (dot,) = flow.dots
    assert dot.preferred == "float32" and "dense" in dot.islands
    (clip,) = flow.clips
    assert (clip.lo, clip.hi) == (-127.0, 127.0)
    assert "dense" in clip.islands
