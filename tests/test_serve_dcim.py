"""Serving engine + DCIM functional-execution integration tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.precision import get as get_precision
from repro.dcimmap import extract, plan
from repro.core import nsga2
from repro.models import lm
from repro.serve import Engine, bucket_requests
from repro.sim import DCIMMacroSim, quantize_sym


@pytest.fixture(scope="module")
def engine():
    cfg = configs.get_smoke_config("qwen2.5-3b")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    return cfg, Engine(cfg, params, max_len=64)


class TestEngine:
    def test_greedy_deterministic(self, engine):
        cfg, eng = engine
        prompts = np.random.default_rng(0).integers(
            0, cfg.vocab_size, (2, 8)).astype(np.int32)
        a = eng.generate(prompts, n_tokens=8, temperature=0.0)
        b = eng.generate(prompts, n_tokens=8, temperature=0.0)
        np.testing.assert_array_equal(a.tokens, b.tokens)
        assert a.tokens.shape == (2, 16)

    def test_prompt_preserved(self, engine):
        cfg, eng = engine
        prompts = np.random.default_rng(1).integers(
            0, cfg.vocab_size, (3, 5)).astype(np.int32)
        out = eng.generate(prompts, n_tokens=4)
        np.testing.assert_array_equal(out.tokens[:, :5], prompts)

    def test_sampling_respects_temperature(self, engine):
        cfg, eng = engine
        prompts = np.random.default_rng(2).integers(
            0, cfg.vocab_size, (2, 6)).astype(np.int32)
        a = eng.generate(prompts, n_tokens=12, temperature=1.5, seed=1)
        b = eng.generate(prompts, n_tokens=12, temperature=1.5, seed=2)
        assert not np.array_equal(a.tokens, b.tokens)

    def test_oversize_request_raises_value_error(self, engine):
        """Oversize requests must raise a real ValueError naming prompt
        length, n_tokens and max_len — not a bare assert that vanishes
        under ``python -O``."""
        cfg, eng = engine
        prompts = np.random.default_rng(4).integers(
            0, cfg.vocab_size, (1, 60)).astype(np.int32)
        with pytest.raises(ValueError) as ei:
            eng.generate(prompts, n_tokens=8)
        msg = str(ei.value)
        assert "60" in msg and "8" in msg and "max_len 64" in msg
        # Boundary case is allowed: prompt + n_tokens == max_len.
        out = eng.generate(prompts[:, :4], n_tokens=60)
        assert out.tokens.shape == (1, 64)

    def test_bucketing(self):
        reqs = [[1, 2], [3, 4, 5], [6, 7], [8]]
        buckets = bucket_requests(reqs)
        lens = sorted(b[1].shape[1] for b in buckets)
        assert lens == [1, 2, 3]
        assert sum(len(b[0]) for b in buckets) == 4

    def test_greedy_matches_stepwise_forward(self, engine):
        """Engine output == naive re-forward argmax at each step."""
        cfg, eng = engine
        prompts = np.random.default_rng(3).integers(
            0, cfg.vocab_size, (1, 6)).astype(np.int32)
        out = eng.generate(prompts, n_tokens=3, temperature=0.0)
        params = eng.params
        toks = jnp.asarray(prompts)
        for _ in range(3):
            x = lm.embed_inputs(params, {"tokens": toks}, cfg)
            h, _, _ = lm.forward_hidden(params, x, cfg, None, training=False)
            h = lm.norm_apply(params["ln_f"], h, cfg.norm)
            logits = lm._head_logits(params, h, cfg)
            nxt = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
            toks = jnp.concatenate([toks, nxt], axis=1)
        np.testing.assert_array_equal(out.tokens, np.asarray(toks))


class TestDcimSim:
    def test_int8_execution_error_small(self):
        sim = DCIMMacroSim(get_precision("int8"), N=64, H=64, L=8, k=4)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32))
        y = np.asarray(sim.mvm(x, w))
        want = np.asarray(x @ w)
        rel = np.abs(y - want) / np.maximum(np.abs(want), 1e-1)
        assert np.median(rel) < 0.05

    def test_quantize_sym_roundtrip_bound(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(100,)).astype(np.float32))
        q, s = quantize_sym(x, 8)
        err = np.abs(np.asarray(q) * float(s) - np.asarray(x))
        assert err.max() <= float(s) / 2 + 1e-7

    def test_fp_execution(self):
        sim = DCIMMacroSim(get_precision("bf16"), N=64, H=64, L=16, k=4)
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))
        y = np.asarray(sim.mvm_fp(x, w))
        want = np.asarray(x @ w)
        rel = np.abs(y - want) / np.maximum(np.abs(want), 1e-1)
        assert np.median(rel) < 0.05

    def test_accounting_scales(self):
        sim = DCIMMacroSim(get_precision("int8"), N=64, H=64, L=8, k=4)
        a = sim.account(1, 1024, 1024)
        b = sim.account(2, 1024, 1024)
        assert b["cycles"] == 2 * a["cycles"]
        assert b["macs"] == 2 * a["macs"]
        c = sim.account(1, 2048, 1024)
        assert c["cycles"] == 2 * a["cycles"]


class TestDcimMap:
    def test_workloads_cover_families(self):
        wl_attn = extract(configs.get_config("qwen2.5-3b"))
        wl_ssm = extract(configs.get_config("falcon-mamba-7b"))
        wl_moe = extract(configs.get_config("moonshot-v1-16b-a3b"))
        assert any("attn" in g.name for g in wl_attn.gemms)
        assert any("mamba" in g.name for g in wl_ssm.gemms)
        assert any("moe" in g.name for g in wl_moe.gemms)
        assert any("selective-scan" in u for u in wl_ssm.unmappable)
        assert any("score" in u for u in wl_attn.unmappable)

    def test_weight_totals_close_to_param_counts(self):
        from repro.launch.roofline import param_counts

        cfg = configs.get_config("qwen2.5-3b")
        wl = extract(cfg)
        pc = param_counts(cfg)
        # GEMM weights are a large subset of total params (embed excluded)
        assert 0.5 * pc["total"] < wl.total_weights() <= 1.05 * pc["total"]

    def test_plan_end_to_end(self):
        p = plan("qwen2.5-3b", precision="int8", w_store=65536,
                 cfg_nsga=nsga2.NSGA2Config(pop_size=32, generations=12))
        assert p.n_macros > 0
        assert p.total_area_mm2 > 0
        assert p.tokens_per_s > 0
        assert p.macs_per_token > 1e9

    def test_plan_multi_precision_batched(self):
        """Candidate precisions explore as ONE batched scenario table;
        distillation picks the winner across the merged INT+FP front."""
        p = plan("qwen2.5-3b", precision=["int8", "bf16"], w_store=65536,
                 cfg_nsga=nsga2.NSGA2Config(pop_size=32, generations=12))
        assert p.precision in ("int8", "bf16")
        assert p.n_macros > 0 and p.tokens_per_s > 0

    def test_moe_activation_rate(self):
        wl = extract(configs.get_config("moonshot-v1-16b-a3b"))
        moe_gemms = [g for g in wl.gemms if g.name.startswith("moe_") and "shared" not in g.name and "router" not in g.name]
        for g in moe_gemms:
            assert g.activation == pytest.approx(6 / 64)
