"""Concurrent multi-tenant serving front-end: the single-pump
invariant (background driver vs ``stream()``/``step()`` from other
threads), pump-thread-pinned ``on_token`` delivery and the callback
reentrancy rule, priority-class fairness (stride scheduling) with
no-starvation and PagePool conservation properties, bounded-queue
overload shedding, slot preemption with bitwise-exact resume, chunked
prefill interleaving with co-tenant decode, the admission-stall
RuntimeError regression (transient waits vs real accounting bugs), and
the threaded acceptance sweep: producer threads hammering one session
across all 8 served families must yield tokens bitwise-identical to
per-request ``Engine.generate`` with the compile budget unchanged."""
import dataclasses
import threading

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.models import lm
from repro.serve import (
    Engine,
    Request,
    Scheduler,
    check_queue_capacity,
    pages_needed,
)

VOCAB = 512

# Keep in sync with tests/test_paged_attention.py::SERVED_ARCHS.
SERVED_ARCHS = [
    "qwen2.5-3b", "phi4-mini-3.8b", "mistral-nemo-12b", "musicgen-large",
    "falcon-mamba-7b", "jamba-v0.1-52b", "deepseek-v3-671b",
    "moonshot-v1-16b-a3b",
]

_PARAMS_CACHE = {}


def _mk(arch="qwen2.5-3b"):
    """Lossless cache dtype so prefix reuse / preemption / chunked
    prefill are active wherever the architecture permits them."""
    if arch not in _PARAMS_CACHE:
        cfg = configs.get_smoke_config(arch)
        cfg = dataclasses.replace(cfg, cache_dtype="float32")
        _PARAMS_CACHE[arch] = (cfg, lm.init(jax.random.PRNGKey(0), cfg))
    return _PARAMS_CACHE[arch]


def _prompt(rng, n):
    return rng.integers(0, VOCAB, n).astype(np.int32)


def _assert_engine_exact(eng, pairs):
    for req, res in pairs:
        ref = eng.generate(req.prompt[None], n_tokens=req.n_tokens,
                           request_ids=[res.rid])
        np.testing.assert_array_equal(ref.tokens[0], res.tokens)


# =========================== single-pump invariant ===========================
class TestSinglePump:
    def test_two_threads_stream_two_handles(self):
        """Satellite-1 regression: two threads each consuming a
        ``stream()`` iterator while a background pump drives the
        session.  Before the single-pump invariant, each stream() call
        pumped ``session.step()`` itself — two streaming threads (or a
        stream racing the pump) double-stepped one tick and corrupted
        slot state.  Now streams block on delivered tokens and both
        consumers see exactly the Engine-reference stream."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=32, page_size=8)
        eng = Engine(cfg, params, max_len=32)
        session = sched.session()
        rng = np.random.default_rng(0)
        reqs = [Request(prompt=_prompt(rng, 5), n_tokens=4, rid=0),
                Request(prompt=_prompt(rng, 8), n_tokens=6, rid=1)]

        outs = {0: [], 1: []}

        def consume(handle, out):
            for tok in handle.stream():
                out.append(tok)

        with session.driving():
            handles = [session.submit(r) for r in reqs]
            threads = [
                threading.Thread(target=consume, args=(h, outs[h.rid]))
                for h in handles
            ]
            for t in threads:
                t.start()
            # While the pump owns the session, stepping from any other
            # thread is refused instead of silently racing.
            with pytest.raises(RuntimeError, match="background pump"):
                session.step()
            for t in threads:
                t.join(timeout=300)
            assert not any(t.is_alive() for t in threads)

        for req, h in zip(reqs, handles):
            ref = eng.generate(req.prompt[None], n_tokens=req.n_tokens,
                               request_ids=[req.rid])
            np.testing.assert_array_equal(
                ref.tokens[0], np.concatenate([req.prompt, outs[req.rid]])
            )
            np.testing.assert_array_equal(ref.tokens[0], h.result.tokens)

    def test_cooperative_stream_still_pumps_without_driver(self):
        """No driver attached: stream() drives the session itself, as it
        always did (the cooperative single-thread mode)."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=1, max_len=32, page_size=8)
        eng = Engine(cfg, params, max_len=32)
        rng = np.random.default_rng(1)
        req = Request(prompt=_prompt(rng, 6), n_tokens=5, rid=7)
        handle = sched.submit(req)
        toks = list(handle.stream())
        ref = eng.generate(req.prompt[None], n_tokens=5, request_ids=[7])
        np.testing.assert_array_equal(
            ref.tokens[0], np.concatenate([req.prompt, toks])
        )

    def test_second_driver_refused_and_stop_is_clean(self):
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=1, max_len=32, page_size=8)
        session = sched.session()
        session.start()
        try:
            with pytest.raises(RuntimeError, match="already has"):
                session.start()
        finally:
            session.stop()
        # After stop() the session is cooperative again.
        rng = np.random.default_rng(2)
        res = session.serve([Request(prompt=_prompt(rng, 4), n_tokens=2)])
        assert res[0].tokens.size == 6


# ========================= event delivery / reentrancy =======================
class TestEventPinning:
    def test_callbacks_delivered_on_pump_thread_only(self):
        """Satellite-3 regression: deferred on_token events used to be
        delivered by whichever thread happened to call step()/drain().
        With a driver attached, every callback must run on the pump
        thread — even while other threads block in wait()/wait_idle()."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=32, page_size=8)
        session = sched.session()
        rng = np.random.default_rng(3)
        idents = []

        def cb(handle, tok):
            idents.append(threading.get_ident())

        waiter_idents = set()

        def waiter(h):
            waiter_idents.add(threading.get_ident())
            h.wait(timeout=300)

        with session.driving():
            handles = [
                session.submit(
                    Request(prompt=_prompt(rng, 4 + i), n_tokens=3, rid=i),
                    on_token=cb,
                )
                for i in range(3)
            ]
            threads = [threading.Thread(target=waiter, args=(h,))
                       for h in handles]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=300)
            session.wait_idle(timeout=300)

        assert len(idents) == 9                    # 3 requests x 3 tokens
        assert len(set(idents)) == 1               # one delivery thread...
        assert set(idents) != {threading.get_ident()}   # ...not this one
        assert not (set(idents) & waiter_idents)        # ...nor a waiter

    def test_callback_resubmits_while_other_thread_submits(self):
        """The reentrancy rule: an on_token callback (running on the
        pump thread, session lock held) may call submit() directly, and
        an unrelated producer thread may submit at the same time — both
        requests retire with Engine-exact tokens."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=32, page_size=8)
        eng = Engine(cfg, params, max_len=32)
        session = sched.session()
        rng = np.random.default_rng(4)
        follow_req = Request(prompt=_prompt(rng, 5), n_tokens=3, rid=50)
        side_req = Request(prompt=_prompt(rng, 7), n_tokens=2, rid=60)
        follow = {}

        def cb(handle, tok):
            if "h" not in follow:
                follow["h"] = session.submit(follow_req)

        def producer():
            follow["side"] = session.submit(side_req)

        with session.driving():
            first_req = Request(prompt=_prompt(rng, 4), n_tokens=4, rid=40)
            first = session.submit(first_req, on_token=cb)
            t = threading.Thread(target=producer)
            t.start()
            t.join(timeout=300)
            session.wait_idle(timeout=300)

        _assert_engine_exact(eng, [
            (first_req, first.result),
            (follow_req, follow["h"].result),
            (side_req, follow["side"].result),
        ])


# ============================ overload shedding ==============================
class TestShedding:
    def test_check_queue_capacity_contract(self):
        check_queue_capacity(5, 3, None)           # unbounded: never raises
        check_queue_capacity(5, 3, 8)              # exactly full is fine
        with pytest.raises(ValueError, match="queue overloaded"):
            check_queue_capacity(5, 4, 8)

    def test_submit_sheds_over_max_queue_and_session_survives(self):
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=1, max_len=32, page_size=8,
                          max_queue=2)
        eng = Engine(cfg, params, max_len=32)
        session = sched.session()
        rng = np.random.default_rng(5)
        reqs = [Request(prompt=_prompt(rng, 4), n_tokens=2, rid=i,
                        arrival=5)            # hold them queued
                for i in range(3)]
        h0 = session.submit(reqs[0])
        h1 = session.submit(reqs[1])
        with pytest.raises(ValueError, match="queue overloaded"):
            session.submit(reqs[2])
        session.drain()
        assert sched.last_stats.shed == 1
        # Shed requests never lose tokens for the admitted ones.
        _assert_engine_exact(eng, [(reqs[0], h0.result), (reqs[1], h1.result)])
        # Backlog drained: the shed request is admissible now.
        h2 = session.submit(dataclasses.replace(reqs[2], arrival=0))
        session.drain()
        _assert_engine_exact(eng, [(reqs[2], h2.result)])

    def test_batch_serve_sheds_atomically(self):
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=1, max_len=32, page_size=8,
                          max_queue=2)
        rng = np.random.default_rng(6)
        reqs = [Request(prompt=_prompt(rng, 4), n_tokens=2, rid=i)
                for i in range(3)]
        with pytest.raises(ValueError, match="queue overloaded"):
            sched.serve(reqs)
        assert not sched.session().queue        # nothing half-enqueued
        assert sched.serve(reqs[:2])            # still usable


# ========================= preemption + exact resume =========================
class TestPreemption:
    def test_high_priority_preempts_and_victim_resumes_exact(self):
        """One slot; a low-priority long generation is evicted by a
        higher-class arrival and later re-admitted: its re-prefill
        covers prompt + generated[:-1] (hitting its still-cached prefix
        pages), decode resumes mid-stream, and BOTH token streams are
        bitwise what Engine.generate produces in isolation."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=1, max_len=64, page_size=8)
        eng = Engine(cfg, params, max_len=64)
        rng = np.random.default_rng(7)
        lo = Request(prompt=_prompt(rng, 6), n_tokens=10, rid=0,
                     priority=1, arrival=0, tenant="batch")
        hi = Request(prompt=_prompt(rng, 5), n_tokens=3, rid=1,
                     priority=3, arrival=3, tenant="interactive")
        r_lo, r_hi = sched.serve([lo, hi])
        stats = sched.last_stats
        assert stats.preemptions == 1
        assert r_lo.preemptions == 1 and r_hi.preemptions == 0
        assert r_lo.tenant == "batch" and r_hi.priority == 3
        # The victim was seated at step 0 and keeps that admitted_step.
        assert r_lo.admitted_step == 0
        _assert_engine_exact(eng, [(lo, r_lo), (hi, r_hi)])

    def test_preempted_sampling_stream_resumes_exact(self):
        """Resume exactness for temperature > 0: the per-token PRNG is
        keyed by (rid, step), so a preempted sampled request continues
        the SAME stream it would have produced unpreempted."""
        cfg, params = _mk()
        rng = np.random.default_rng(8)
        prompt = _prompt(rng, 6)
        lone = Scheduler(cfg, params, max_slots=1, max_len=64,
                         page_size=8).serve(
            [Request(prompt=prompt, n_tokens=10, rid=0, temperature=0.8)]
        )[0]
        sched = Scheduler(cfg, params, max_slots=1, max_len=64, page_size=8)
        r_lo, _ = sched.serve([
            Request(prompt=prompt, n_tokens=10, rid=0, temperature=0.8,
                    priority=1),
            Request(prompt=_prompt(rng, 5), n_tokens=3, rid=1, priority=2,
                    arrival=3),
        ])
        assert sched.last_stats.preemptions == 1
        np.testing.assert_array_equal(lone.tokens, r_lo.tokens)

    def test_equal_priority_never_preempts(self):
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=1, max_len=32, page_size=8)
        rng = np.random.default_rng(9)
        sched.serve([
            Request(prompt=_prompt(rng, 4), n_tokens=6, rid=0, priority=2),
            Request(prompt=_prompt(rng, 4), n_tokens=2, rid=1, priority=2,
                    arrival=2),
        ])
        assert sched.last_stats.preemptions == 0


# ============================= chunked prefill ===============================
class TestChunkedPrefill:
    def test_long_prompt_fills_in_chunks_while_cotenant_decodes(self):
        """With ``prefill_chunk=4`` a 24-token prompt fills over several
        ticks; a co-tenant admitted alongside decodes DURING the fill
        instead of stalling behind one monolithic prefill — and both
        streams stay Engine-exact."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=32, page_size=8,
                          prefill_chunk=4)
        assert sched.chunk_active
        eng = Engine(cfg, params, max_len=32)
        session = sched.session()
        rng = np.random.default_rng(10)
        long_req = Request(prompt=_prompt(rng, 24), n_tokens=3, rid=0)
        short_req = Request(prompt=_prompt(rng, 4), n_tokens=6, rid=1)
        h_long = session.submit(long_req)
        h_short = session.submit(short_req)
        overlapped = False
        while not session.idle:
            session.step()
            if h_short.n_generated and not h_long.n_generated:
                overlapped = True
        assert overlapped            # co-tenant progressed during the fill
        stats_chunks = None
        session.drain()
        stats = sched.last_stats
        stats_chunks = stats.prefill_chunks
        assert stats_chunks == 6     # ceil(24 / 4) advances
        _assert_engine_exact(eng, [(long_req, h_long.result),
                                   (short_req, h_short.result)])

    def test_chunked_prefill_shares_compile_budget(self):
        """Chunk advances draw from the SAME (tail bucket, pow2 width)
        program space as burst prefill: the budget formula is unchanged
        and every cached program compiled exactly once."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=32, page_size=8,
                          prefill_chunk=4)
        rng = np.random.default_rng(11)
        reqs = [Request(prompt=_prompt(rng, n), n_tokens=t, rid=i)
                for i, (n, t) in enumerate([(17, 2), (24, 3), (4, 2), (7, 3)])]
        eng = Engine(cfg, params, max_len=32)
        results = sched.serve(reqs)
        _assert_engine_exact(eng, zip(reqs, results))
        counts = sched.compile_counts()
        assert counts["decode"] == 1
        assert all(n == 1 for n in counts["prefill"].values())
        widths = {1, 2}
        assert all(b in sched.prefill_buckets and w in widths
                   for b, w in counts["prefill"])
        # A warm re-serve hits the prefix cache (shorter tails may use a
        # smaller bucket) but stays inside the same budget formula: one
        # program per (bucket, width) key, each compiled exactly once.
        sched.serve([dataclasses.replace(r, rid=100 + i)
                     for i, r in enumerate(reqs)])
        counts = sched.compile_counts()
        assert counts["decode"] == 1
        assert all(n == 1 for n in counts["prefill"].values())
        assert counts["total"] <= 1 + len(sched.prefill_buckets) * len(widths)

    def test_chunking_gated_off_for_ssm(self):
        cfg, params = _mk("falcon-mamba-7b")
        sched = Scheduler(cfg, params, max_slots=2, max_len=32, page_size=8,
                          prefill_chunk=4)
        assert not sched.chunk_active and not sched.preempt_active
        rng = np.random.default_rng(12)
        req = Request(prompt=_prompt(rng, 20), n_tokens=2, rid=0)
        eng = Engine(cfg, params, max_len=32)
        _assert_engine_exact(eng, zip([req], sched.serve([req])))
        assert sched.last_stats.prefill_chunks == 0


# ======================= admission-stall error regression ====================
class TestAdmissionStallRegression:
    def test_transient_page_wait_during_chunk_fill_is_not_a_bug(self):
        """Satellite-2 regression: request A chunk-fills a long prompt
        holding most of a tight pool while eligible request B cannot fit
        — NOTHING decodes for several ticks.  The old check raised its
        'page accounting bug' RuntimeError at the first such tick (an
        eligible head + an inactive pool); it must instead recognize the
        live chunking occupant as a legitimate transient wait and let B
        admit once A retires."""
        cfg, params = _mk()
        # usable = 7 pages; A needs 6 for its lifetime, B needs 4:
        # individually admissible, jointly not.
        needs = (pages_needed(20, 2, 4), pages_needed(12, 2, 4))
        assert needs == (6, 4)
        sched = Scheduler(cfg, params, max_slots=2, max_len=32, page_size=4,
                          n_pages=8, prefill_chunk=4)
        eng = Engine(cfg, params, max_len=32)
        rng = np.random.default_rng(13)
        reqs = [Request(prompt=_prompt(rng, 20), n_tokens=2, rid=0),
                Request(prompt=_prompt(rng, 12), n_tokens=2, rid=1)]
        results = sched.serve(reqs)       # old check: RuntimeError here
        stats = sched.last_stats
        # ceil(20/4) advances for A (all with B blocked and nothing
        # decoding — each one a tick the old check misdiagnosed), then
        # ceil(12/4) for B once A's retirement freed its pages.
        assert stats.prefill_chunks == 5 + 3
        assert results[1].admitted_step >= results[0].finished_step
        _assert_engine_exact(eng, zip(reqs, results))

    def test_real_page_leak_still_raises(self):
        """The check still catches genuine accounting bugs: leak every
        page (allocated, never released, owned by no occupant) and an
        eligible request can never admit — step() must raise rather than
        spin forever."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=32, page_size=8)
        session = sched.session()
        rng = np.random.default_rng(14)
        leak = session.ppool.allocate(session.ppool.available())
        assert leak
        session.submit(Request(prompt=_prompt(rng, 6), n_tokens=2, rid=0))
        with pytest.raises(RuntimeError, match="page accounting bug"):
            session.step()


# ===================== fairness / conservation properties ====================
class TestFairness:
    def test_weighted_share_respects_priority_classes(self):
        """Stride scheduling on one slot: a fully backlogged priority-2
        class admits twice per priority-1 admission (pattern 2,1,2 in
        every 3), and the low class is never starved."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=1, max_len=32, page_size=8)
        rng = np.random.default_rng(15)
        reqs = [Request(prompt=_prompt(rng, 4), n_tokens=2, rid=i,
                        priority=2 if i < 6 else 1)
                for i in range(9)]
        results = sched.serve(reqs)
        order = sorted(results, key=lambda r: (r.admitted_step, r.rid))
        admitted_prios = [r.priority for r in order]
        # 2:1 interleave while both classes are backlogged.
        assert admitted_prios[:9] == [2, 1, 2, 2, 1, 2, 2, 1, 2]
        assert all(r.tokens.size == r.prompt_len + 2 for r in results)

    def test_single_class_is_plain_fifo(self):
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=1, max_len=32, page_size=8)
        rng = np.random.default_rng(16)
        reqs = [Request(prompt=_prompt(rng, 4), n_tokens=2, rid=i,
                        priority=3)
                for i in range(4)]
        results = sched.serve(reqs)
        admits = [r.admitted_step for r in results]
        assert admits == sorted(admits)

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10_000))
    def test_property_no_starvation_and_page_conservation(self, seed):
        """Property (minihypothesis-compatible): under random bursty
        multi-tenant traffic with mixed priorities on a tight pool —
        preemption and chunked prefill both reachable — every admitted
        request retires with its full token count, first admissions are
        FIFO within each priority class, and the PagePool conservation
        invariant (available + live == usable, no referenced cached
        page) holds after every single scheduler tick."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=32, page_size=4,
                          n_pages=12, prefill_chunk=6)
        session = sched.session()
        rng = np.random.default_rng(seed)
        n = int(rng.integers(3, 7))
        handles = []
        for i in range(n):
            handles.append(session.submit(Request(
                prompt=_prompt(rng, int(rng.integers(2, 14))),
                n_tokens=int(rng.integers(1, 5)),
                rid=i,
                arrival=int(rng.integers(0, 4)),
                priority=int(rng.integers(1, 4)),
                tenant=f"t{int(rng.integers(0, 3))}",
            )))
        session.ppool.check_conservation()
        while not session.idle:
            session.step()
            session.ppool.check_conservation()
        # No starvation: every admitted request retired, in full.
        for h in handles:
            assert h.done
            assert h.result.tokens.size == (h.request.prompt.size
                                            + h.request.n_tokens)
        # First admissions are FIFO within each class: the queue is
        # ordered by (arrival, submission), so earlier same-class
        # requests are seated first (preemption re-queues keep the
        # original admitted_step).
        by_class = {}
        for h in handles:    # submission order == rid order
            by_class.setdefault(h.request.priority, []).append(h.result)
        for results in by_class.values():
            results.sort(key=lambda r: (r.arrival, r.rid))
            admits = [r.admitted_step for r in results]
            assert admits == sorted(admits)
        # All pages accounted for at idle: only FREE or CACHED remain.
        assert (session.ppool.available()
                == session.ppool.usable_pages)


# ========================== threaded acceptance sweep ========================
class TestThreadedAcceptance:
    @pytest.mark.parametrize("arch", SERVED_ARCHS)
    def test_producer_threads_exact_all_families(self, arch):
        """The acceptance contract: N producer threads submitting
        interleaved multi-tenant traffic (mixed priorities, a
        chunk-length prompt, shared session) through ONE driven session
        produce greedy tokens bitwise-identical to per-request
        ``Engine.generate`` for every family, and the jit compile
        budget stays at one decode + one prefill per (tail bucket, pow2
        width) program actually used — asserted from the jit cache
        sizes."""
        cfg, params = _mk(arch)
        sched = Scheduler(cfg, params, max_slots=2, max_len=32, page_size=8,
                          max_queue=32, prefill_chunk=4)
        eng = Engine(cfg, params, max_len=32)
        session = sched.session()
        rng = np.random.default_rng(17)
        traces = {
            0: [Request(prompt=_prompt(rng, 3), n_tokens=2, rid=0,
                        priority=1, tenant="batch"),
                Request(prompt=_prompt(rng, 17), n_tokens=2, rid=1,
                        priority=1, tenant="batch")],
            1: [Request(prompt=_prompt(rng, 5), n_tokens=3, rid=10,
                        priority=2, tenant="web"),
                Request(prompt=_prompt(rng, 9), n_tokens=2, rid=11,
                        priority=3, tenant="interactive")],
        }
        handles = {}

        def producer(tid):
            for req in traces[tid]:
                handles[req.rid] = session.submit(req)

        with session.driving():
            threads = [threading.Thread(target=producer, args=(tid,))
                       for tid in traces]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=300)
            session.wait_idle(timeout=300)

        for trace in traces.values():
            _assert_engine_exact(
                eng, [(req, handles[req.rid].result) for req in trace]
            )
        counts = sched.compile_counts()
        assert counts["decode"] == 1
        assert all(n == 1 for n in counts["prefill"].values())
        assert all(b in sched.prefill_buckets and w in {1, 2}
                   for b, w in counts["prefill"])
