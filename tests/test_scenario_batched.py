"""Batched multi-scenario DSE: ScenarioTable evaluation parity, batched
vs sequential-loop vs brute-force-oracle front equivalence, scenario x
island sharding, and the results store."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import explorer, nsga2
from repro.core.precision import PAPER_SWEEP, get as get_precision
from repro.core.results import ResultStore, dump_json, to_jsonable
from repro.core.scenario import ScenarioTable, evaluate, evaluate_host
from repro.core.space import DesignSpace

# Small W_store budgets: the design spaces stay tiny enough (~100-250
# feasible genomes) that this NSGA-II budget deterministically covers
# them (seeded RNG), making exact front == oracle equality a sound
# assertion.  (pop 64 / gens 32 leaves a couple of corners unvisited.)
SMALL_SCENARIOS = [
    ("int8", 16384), ("bf16", 8192), ("int4", 4096),
    ("fp16", 16384), ("int16", 8192),
]
CFG = nsga2.NSGA2Config(pop_size=96, generations=48)


def _spaces(scenarios):
    return [
        DesignSpace(prec=get_precision(p), w_store=w) for p, w in scenarios
    ]


class TestScenarioTable:
    def test_from_specs_stacks_per_scenario_params(self):
        t = ScenarioTable.from_specs(SMALL_SCENARIOS)
        assert len(t) == len(SMALL_SCENARIOS)
        assert t.any_fp and not t.all_fp
        np.testing.assert_array_equal(
            np.asarray(t.b_w),
            [sp.prec.B_w for sp in _spaces(SMALL_SCENARIOS)],
        )

    @settings(max_examples=20, deadline=None)
    @given(
        idx=st.lists(
            st.integers(0, len(PAPER_SWEEP) - 1), min_size=1, max_size=4
        ),
        w_pow=st.integers(12, 17),
        seed=st.integers(0, 2**16),
    )
    def test_table_evaluate_matches_designspace(self, idx, w_pow, seed):
        """Batched table evaluation == per-scenario DesignSpace.evaluate,
        elementwise, for random genes and random mixed scenario sets."""
        scens = [(PAPER_SWEEP[i].name, 2**w_pow) for i in idx]
        spaces = _spaces(scens)
        table = ScenarioTable.from_spaces(spaces)
        rng = np.random.default_rng(seed)
        genes = rng.integers(0, 12, size=(len(scens), 7, 3)).astype(np.int32)
        F, v = evaluate(table, jnp.asarray(genes))
        for i, sp in enumerate(spaces):
            Fi, vi = sp.evaluate(jnp.asarray(genes[i]))
            np.testing.assert_array_equal(np.asarray(F)[i], np.asarray(Fi))
            np.testing.assert_array_equal(np.asarray(v)[i], np.asarray(vi))

    def test_vmap_over_rows_matches_table(self):
        table = ScenarioTable.from_specs(SMALL_SCENARIOS)
        genes = jnp.asarray(
            np.random.default_rng(0).integers(
                0, 12, size=(len(table), 5, 3)
            ).astype(np.int32)
        )
        F, v = evaluate(table, genes)
        Fv, vv = jax.vmap(lambda row, g: evaluate(row, g))(table, genes)
        np.testing.assert_array_equal(np.asarray(F), np.asarray(Fv))
        np.testing.assert_array_equal(np.asarray(v), np.asarray(vv))

    def test_evaluate_host_bucket_invariant(self):
        """Bucket padding must never change a real row's objectives: the
        jitted host evaluation is the canonical numerics every front
        comparison (archive vs oracle) runs through, so results must be
        identical whatever power-of-two bucket a gene set lands in.
        (Eager op-by-op evaluation may differ by 1 ULP from any jitted
        program — that's why ALL comparisons stay inside the pipeline.)"""
        sp = DesignSpace(prec=get_precision("int8"), w_store=16384)
        genes = sp.enumerate_feasible()
        F_full, v_full = evaluate_host(sp.scenario, genes)
        for n in (1, 2, 3, 100, genes.shape[0]):  # several buckets
            F, v = evaluate_host(sp.scenario, genes[:n])
            np.testing.assert_array_equal(F, F_full[:n])
            np.testing.assert_array_equal(v, v_full[:n])
        # And it tracks the eager reference to float32 tolerance.
        Fr, vr = sp.evaluate(jnp.asarray(genes))
        np.testing.assert_allclose(F_full, np.asarray(Fr), rtol=1e-6)
        np.testing.assert_allclose(v_full, np.asarray(vr), rtol=1e-6)

    def test_mixed_row_trace_matches_pure_row_trace(self):
        """An INT scenario evaluated through a mixed INT/FP table's
        where-select program must equal the pure INT program bitwise —
        otherwise batched mixed sweeps would drift from single-scenario
        runs."""
        sp = DesignSpace(prec=get_precision("int8"), w_store=16384)
        genes = sp.enumerate_feasible()
        t = ScenarioTable.from_specs([("int8", 16384), ("bf16", 8192)])
        F_mix, v_mix = evaluate_host(t.row(0), genes)
        F_pure, v_pure = evaluate_host(sp.scenario, genes)
        np.testing.assert_array_equal(F_mix, F_pure)
        np.testing.assert_array_equal(v_mix, v_pure)

    def test_mixed_static_knobs_rejected(self):
        a = DesignSpace(prec=get_precision("int8"), w_store=4096)
        b = DesignSpace(
            prec=get_precision("int8"), w_store=4096,
            include_selection_mux=True,
        )
        with pytest.raises(ValueError, match="static metadata"):
            ScenarioTable.from_spaces([a, b])


class TestBatchedEquivalence:
    @pytest.fixture(scope="class")
    def batched_results(self):
        table = ScenarioTable.from_specs(SMALL_SCENARIOS)
        return nsga2.run_batched(table, CFG)

    def test_one_trace_for_all_scenarios(self, batched_results):
        """S scenarios execute as ONE jitted batched program: the cache
        holds a single trace regardless of S (acceptance criterion)."""
        n0 = nsga2._run_batched_jit._cache_size()
        table = ScenarioTable.from_specs(SMALL_SCENARIOS)
        nsga2.run_batched(table, CFG)
        # Same (shape, config) signature -> no additional trace.
        assert nsga2._run_batched_jit._cache_size() == max(n0, 1)

    def test_batched_matches_sequential_loop_exactly(self, batched_results):
        """The batched front for S>=4 mixed INT/FP scenarios is
        bit-identical to the historical re-jit-per-scenario loop."""
        for (p, w), res in zip(SMALL_SCENARIOS, batched_results):
            ref = nsga2.run_static(
                DesignSpace(prec=get_precision(p), w_store=w), CFG
            )
            np.testing.assert_array_equal(res.genes, ref.genes)
            np.testing.assert_array_equal(res.front_genes, ref.front_genes)
            np.testing.assert_array_equal(
                res.front_objectives, ref.front_objectives
            )
            np.testing.assert_array_equal(res.ranks, ref.ranks)

    def test_batched_matches_oracle_exactly(self, batched_results):
        """On these small spaces the elitist archive covers the whole
        space, so the NSGA-II front must EQUAL the enumerated oracle."""
        for (p, w), res in zip(SMALL_SCENARIOS, batched_results):
            oracle = explorer.brute_force_front(
                DesignSpace(prec=get_precision(p), w_store=w)
            )
            got = {tuple(g) for g in res.front_genes}
            want = {tuple(g) for g in oracle}
            assert got == want, (p, w, len(got), len(want))

    def test_explore_multi_paths_agree(self):
        def key(pts):
            return sorted(
                (p.precision, p.w_store) + tuple(int(g) for g in p.genes)
                for p in pts
            )

        cfg = nsga2.NSGA2Config(pop_size=32, generations=12)
        b = explorer.explore_multi(SMALL_SCENARIOS[:4], cfg, batched=True)
        s = explorer.explore_multi(SMALL_SCENARIOS[:4], cfg, batched=False)
        assert key(b) == key(s)
        bx = explorer.explore_multi(
            SMALL_SCENARIOS[:4], cfg, batched=True, cross_dominate=True
        )
        sx = explorer.explore_multi(
            SMALL_SCENARIOS[:4], cfg, batched=False, cross_dominate=True
        )
        assert key(bx) == key(sx)
        assert len(bx) <= len(b)

    def test_explore_multi_records_to_store(self, tmp_path):
        store = ResultStore(tmp_path)
        pts = explorer.explore_multi(
            SMALL_SCENARIOS[:2],
            nsga2.NSGA2Config(pop_size=32, generations=8),
            store=store, record_name="dse_smoke",
        )
        assert "dse_smoke" in store
        rec = store.get("dse_smoke")
        assert rec["n_points"] == len(pts)
        assert rec["_record"]["kind"] == "dse"
        assert rec["_record"]["wall_s"] > 0
        assert len(rec["points"]) == len(pts)
        assert rec["points"][0]["area_mm2"] > 0


class TestIslandsMulti:
    def test_scenario_island_fronts_sound(self):
        """run_islands_multi: every returned front point must not be
        dominated by any oracle-front point of its own scenario."""
        scens = [("int8", 16384), ("bf16", 8192)]
        results = explorer.run_islands_multi(
            scens, nsga2.NSGA2Config(pop_size=64, generations=0),
            rounds=3, gens_per_round=12, n_migrants=4,
        )
        assert len(results) == len(scens)
        for (p, w), res in zip(scens, results):
            assert res.front_genes.shape[0] > 5
            sp = DesignSpace(prec=get_precision(p), w_store=w)
            oracle = explorer.brute_force_front(sp)
            oF, _ = evaluate_host(sp.scenario, oracle)
            for fo in res.front_objectives:
                assert not any(
                    bool(np.all(of <= fo) and np.any(of < fo)) for of in oF
                )

    def test_scenario_count_must_divide_mesh(self):
        from jax.sharding import Mesh

        dev = np.array(jax.devices())[:1]
        mesh = Mesh(dev.reshape(1, 1), ("scenario", "island"))
        # 1-device mesh: any S works (scenario axis size 1 divides all S).
        out = explorer.run_islands_multi(
            [("int4", 4096), ("int8", 4096), ("int16", 4096)],
            nsga2.NSGA2Config(pop_size=32, generations=0),
            mesh=mesh, rounds=1, gens_per_round=4, n_migrants=2,
        )
        assert len(out) == 3


class TestResultStore:
    def test_round_trip_and_envelope(self, tmp_path):
        store = ResultStore(tmp_path)
        p = store.put(
            "cell_a", {"status": "ok", "arr": np.arange(3)},
            kind="dryrun", wall_s=1.5,
        )
        assert p.exists() and not p.with_suffix(".json.tmp").exists()
        rec = store.get("cell_a")
        assert rec["status"] == "ok"
        assert rec["arr"] == [0, 1, 2]
        assert rec["_record"]["kind"] == "dryrun"
        assert store.names() == ["cell_a"]
        assert "cell_a" in store and "cell_b" not in store

    def test_flat_names_enforced(self, tmp_path):
        store = ResultStore(tmp_path)
        with pytest.raises(ValueError):
            store.put("../escape", {})

    def test_to_jsonable_handles_numpy_and_dataclasses(self, tmp_path):
        from repro.core.explorer import ParetoPoint

        pt = ParetoPoint(
            precision="int8", w_store=4096, N=64, H=8, L=8, k=4,
            genes=np.asarray([3, 3, 2], np.int32),
            area=1.0, delay=2.0, energy=3.0, throughput=4.0,
            area_mm2=0.1, delay_ns=1.0, energy_nJ=0.2, tops=5.0,
            tops_per_w=6.0, tops_per_mm2=7.0,
        )
        obj = to_jsonable(
            {"pt": pt, "f32": np.float32(1.5), "i64": np.int64(3),
             "b": np.bool_(True), "arr": np.ones((2, 2))}
        )
        s = json.dumps(obj)  # must not raise
        assert '"genes": [3, 3, 2]' in s
        path = dump_json(tmp_path / "x.json", obj)
        assert json.loads(path.read_text())["i64"] == 3
