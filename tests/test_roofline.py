"""Roofline layer tests: analytic parameter counts vs actual init sizes,
model-FLOPs sanity, record analysis."""
import jax
import numpy as np
import pytest

from repro import configs
from repro.launch.roofline import (
    RooflineRow, analyze_record, model_flops, param_counts,
)
from repro.models import lm


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_param_counts_match_init(name):
    """The analytic count must match the real (smoke-scale) init within
    ~2% (analytic skips norm scales and small biases)."""
    cfg = configs.get_smoke_config(name)
    shapes = jax.eval_shape(lambda k: lm.init(k, cfg), jax.random.PRNGKey(0))
    n_real = sum(x.size for x in jax.tree.leaves(shapes))
    n_analytic = param_counts(cfg)["total"]
    # exclude MTP extra block (not in the analytic per-layer count)
    assert n_analytic == pytest.approx(n_real, rel=0.06), (
        name, n_analytic, n_real)


def test_full_size_param_counts_plausible():
    """Full configs land near their nameplate sizes."""
    expect = {
        "deepseek-v3-671b": (600e9, 740e9),
        "qwen2-vl-72b": (60e9, 75e9),       # backbone only (no ViT)
        "falcon-mamba-7b": (6e9, 8e9),
        "qwen2.5-14b": (13e9, 16e9),
        "qwen2.5-3b": (2.5e9, 3.7e9),
        "mistral-nemo-12b": (11e9, 14e9),
        "jamba-v0.1-52b": (45e9, 56e9),
        # NB: the assigned dims (48L x 64e x d_ff 1408) imply 28B total
        # (top-6 active ~2.8B = the "a3b"); the "16b" nameplate refers to
        # the HF model's different layer mix — we follow the assignment.
        "moonshot-v1-16b-a3b": (24e9, 30e9),
        "phi4-mini-3.8b": (3.3e9, 4.6e9),
        "musicgen-large": (1.4e9, 2.8e9),
    }
    for name, (lo, hi) in expect.items():
        n = param_counts(configs.get_config(name))["total"]
        assert lo <= n <= hi, (name, n / 1e9)


def test_active_params_moe():
    cfg = configs.get_config("deepseek-v3-671b")
    pc = param_counts(cfg)
    # ~37-50B active vs ~671-704B total (all-61-MoE per assignment)
    assert pc["active"] / pc["total"] < 0.09
    assert 30e9 < pc["active"] < 55e9


def test_model_flops_kinds():
    cfg = configs.get_config("qwen2.5-3b")
    t = model_flops(cfg, "train", 4096, 256)
    p = model_flops(cfg, "prefill", 4096, 256)
    d = model_flops(cfg, "decode", 4096, 256)
    assert t == pytest.approx(3 * p)
    assert d == pytest.approx(p / 4096)


def test_analyze_record_roundtrip():
    rec = {
        "arch": "qwen2.5-3b", "shape": "train_4k", "mesh": "pod16x16",
        "status": "ok", "kind": "train", "seq_len": 4096,
        "global_batch": 256, "n_devices": 256,
        "analysis": {
            "dot_flops": 1e14, "elem_flops": 1e11, "transcendentals": 1e9,
            "mem_bytes": 1e12,
            "collectives": {
                k: {"count": 10, "bytes": 1e9}
                for k in ("all-gather", "all-reduce", "reduce-scatter",
                          "all-to-all", "collective-permute")
            },
        },
        "memory": {"argument_size_in_bytes": 2 << 30,
                   "temp_size_in_bytes": 8 << 30},
    }
    row = analyze_record(rec)
    assert row.status == "ok"
    assert row.compute_s == pytest.approx((1e14 + 1e11) / 197e12)
    assert row.memory_s == pytest.approx(1e12 / 819e9)
    assert row.bottleneck in ("compute", "memory", "collective")
    assert 0 < row.roofline_fraction < 1
    assert row.device_bytes == 10 << 30


def test_skipped_record():
    rec = {"arch": "phi4-mini-3.8b", "shape": "long_500k",
           "mesh": "pod16x16", "status": "skipped:full-attention-500k"}
    row = analyze_record(rec)
    assert row.status.startswith("skipped")
