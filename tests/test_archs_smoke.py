"""Per-architecture smoke tests (deliverable f): each assigned arch, in a
reduced same-family config, runs one forward/train step on CPU asserting
output shapes and no NaNs; decoders additionally run prefill + decode and
are checked for teacher-forcing consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch.specs import concrete_batch
from repro.models import lm

ARCHS = configs.ARCH_NAMES
B, S = 2, 16


@pytest.fixture(scope="module")
def smoke(request):
    return {}


def _setup(name):
    cfg = configs.get_smoke_config(name)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    batch = concrete_batch(cfg, "train", B, S, seed=3)
    return cfg, params, batch


@pytest.mark.parametrize("name", ARCHS)
class TestArchSmoke:
    def test_train_step(self, name):
        cfg, params, batch = _setup(name)

        @jax.jit
        def step(p, b):
            (loss, metrics), grads = jax.value_and_grad(
                lambda pp: lm.loss_fn(pp, b, cfg), has_aux=True
            )(p)
            new = jax.tree.map(lambda a, g: a - 1e-3 * g.astype(a.dtype), p, grads)
            return loss, metrics, new

        loss, metrics, new_params = step(params, batch)
        assert np.isfinite(float(loss)), name
        assert float(loss) > 0
        # params actually changed
        delta = jax.tree.reduce(
            lambda acc, x: acc + float(jnp.sum(jnp.abs(x[0] - x[1]))),
            jax.tree.map(lambda a, b_: (a.astype(jnp.float32), b_.astype(jnp.float32)), params, new_params),
            0.0,
        )
        assert delta > 0, name

    def test_forward_shapes_and_finite(self, name):
        cfg, params, batch = _setup(name)
        x = lm.embed_inputs(params, batch, cfg)
        assert x.shape == (B, S, cfg.d_model)
        h, _, aux = lm.forward_hidden(params, x, cfg, batch.get("position_ids"))
        assert h.shape == (B, S, cfg.d_model)
        assert np.all(np.isfinite(np.asarray(h, np.float32)))

    def test_prefill_decode(self, name):
        cfg, params, batch = _setup(name)
        pre = {k: v for k, v in batch.items() if k != "targets"}
        caches, logits = lm.prefill(params, pre, cfg, max_len=S + 4)
        assert logits.shape == (B, 1, cfg.vocab_size)
        if cfg.external_embed:
            nxt = {"embeds": jnp.zeros((B, 1, cfg.d_model), cfg.cdtype)}
        else:
            nxt = {"tokens": jnp.argmax(logits, -1).astype(jnp.int32)}
        if cfg.pos == "mrope":
            nxt["position_ids"] = jnp.full((3, B, 1), S, jnp.int32)
        logits2, caches = lm.decode_step(params, nxt, S, caches, cfg)
        assert logits2.shape == (B, 1, cfg.vocab_size)
        assert np.all(np.isfinite(np.asarray(logits2, np.float32)))


CONSISTENCY_ARCHS = [
    "qwen2.5-3b", "phi4-mini-3.8b", "mistral-nemo-12b", "musicgen-large",
    "falcon-mamba-7b", "jamba-v0.1-52b", "deepseek-v3-671b",
    "moonshot-v1-16b-a3b",
]


@pytest.mark.parametrize("name", CONSISTENCY_ARCHS)
def test_decode_matches_teacher_forcing(name):
    """logits from (prefill S tokens -> decode token S) must equal the
    full-sequence forward's logits at position S.  MoE capacity is raised
    so no tokens drop (dropping legitimately differs between batched
    prefill and single-token decode)."""
    cfg = configs.get_smoke_config(name)
    # f32 cache: the default bf16 cache legitimately rounds K/V vs the
    # teacher-forced forward (checked loosely in test_prefill_decode).
    cfg = dataclasses.replace(cfg, cache_dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0)
        )
    if cfg.mtp:
        cfg = dataclasses.replace(cfg, mtp=False)
    params = lm.init(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(7)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)), jnp.int32)

    # Full teacher-forced forward over S+1 tokens.
    x = lm.embed_inputs({"embed": params.get("embed"), **params}, {"tokens": tokens}, cfg)
    h, _, _ = lm.forward_hidden(params, x, cfg, None)
    h = lm.norm_apply(params["ln_f"], h, cfg.norm)
    full_logits = lm._head_logits(params, h, cfg)          # (B, S+1, V)

    # Prefill on S tokens, then decode token S.
    caches, _ = lm.prefill(params, {"tokens": tokens[:, :S]}, cfg, max_len=S + 8)
    dec_logits, _ = lm.decode_step(
        params, {"tokens": tokens[:, S:S + 1]}, S, caches, cfg
    )
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0], np.float32),
        np.asarray(full_logits[:, S], np.float32),
        rtol=2e-3, atol=2e-3,
    )


def test_all_archs_registered():
    assert len(ARCHS) == 10
    fams = {configs.entry(a).family for a in ARCHS}
    assert fams == {"vlm", "audio", "moe", "ssm", "dense", "hybrid"}


def test_cells_matrix():
    run_cells = configs.cells()
    all_cells = configs.cells(include_skipped=True)
    assert len(all_cells) == 40
    skipped = [c for c in all_cells if c[3] != "run"]
    assert len(skipped) == 8  # long_500k on the 8 full-attention archs
    assert len(run_cells) == 32


@pytest.mark.parametrize("name", ARCHS)
def test_full_config_matches_assignment(name):
    """The production configs carry the exact assigned dimensions."""
    spec = {
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
        "falcon-mamba-7b": (64, 4096, 1, 1, 0, 65024),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "mistral-nemo-12b": (40, 5120, 32, 8, 14336, 131072),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
    }[name]
    cfg = configs.get_config(name)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_ff, cfg.vocab_size)
    assert got == spec, (name, got, spec)
    if name == "deepseek-v3-671b":
        assert cfg.moe.n_experts == 256 and cfg.moe.top_k == 8 and cfg.moe.n_shared == 1
        assert cfg.attn_kind == "mla" and cfg.mtp
    if name == "moonshot-v1-16b-a3b":
        assert cfg.moe.n_experts == 64 and cfg.moe.top_k == 6
    if name == "jamba-v0.1-52b":
        assert cfg.moe.n_experts == 16 and cfg.moe.top_k == 2
        kinds = [cfg.mixer_kind(i) for i in range(8)]
        assert kinds.count("gqa") == 1 and kinds.count("mamba") == 7
    if name == "falcon-mamba-7b":
        assert cfg.ssm.d_state == 16 and cfg.mixer == "mamba"
