"""Tensor/expert-parallel sharded serving: bitwise exactness vs the
single-device scheduler.

The headline invariant: a ``Scheduler(tp=N)`` on the forced-8-device
host platform produces greedy tokens BITWISE-IDENTICAL to the
single-device scheduler for every served architecture family, at
tp=2/4/8, with the compile budget (one decode program + one prefill per
(bucket, width) key) unchanged by sharding.  Exactness is by
construction — the serving rules shard only non-contracting output dims
and ``repl_act`` gathers before every contraction, so the partitioned
program computes every dot product at full length in the same order —
and these tests are the enforcement.

The tp=1 test runs in tier-1 on the ordinary single-device host: it
drives the whole mesh code path (param/pool device_put, ``use_mesh``
around every trace, ``constrain_pool``) without a subprocess.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.serve.scheduler import Request, Scheduler

# Keep in sync with tests/test_serve_concurrent.py::SERVED_ARCHS.
SERVED_ARCHS = [
    "qwen2.5-3b", "phi4-mini-3.8b", "mistral-nemo-12b", "musicgen-large",
    "falcon-mamba-7b", "jamba-v0.1-52b", "deepseek-v3-671b",
    "moonshot-v1-16b-a3b",
]

# One subprocess per family: reference serve + tp=2/4/8 re-serves of the
# same trace, token lists compared bitwise in the child, budget asserted
# from the live jit cache sizes.  ``%(arch)s`` is the only template hole.
_EXACTNESS_SNIPPET = r"""
import dataclasses, json
import jax, numpy as np
from repro import configs
from repro.models import lm
from repro.serve.scheduler import Request, Scheduler

assert jax.device_count() == 8, jax.devices()
cfg = configs.get_smoke_config("%(arch)s")
# Lossless cache dtype turns ON every exactness-gated feature the
# architecture permits (prefix reuse, preemption, chunked prefill).
cfg = dataclasses.replace(cfg, cache_dtype="float32")
params = lm.init(jax.random.PRNGKey(0), cfg)

rng = np.random.default_rng(0)
reqs = [Request(prompt=rng.integers(1, 64, p).astype(np.int32),
                n_tokens=t, rid=i, arrival=a)
        for i, (p, t, a) in enumerate(
            [(3, 4, 0), (9, 3, 0), (17, 5, 1), (5, 2, 1), (12, 3, 2)])]
kw = dict(max_slots=3, max_len=32, page_size=8, prefill_chunk=8)

ref_sched = Scheduler(cfg, params, **kw)
ref = [list(map(int, r.tokens)) for r in ref_sched.serve(reqs)]
ref_counts = ref_sched.compile_counts()

for tp in (2, 4, 8):
    s = Scheduler(cfg, params, tp=tp, **kw)
    got = [list(map(int, r.tokens)) for r in s.serve(reqs)]
    c = s.compile_counts()
    print(json.dumps({
        "tp": tp,
        "bitwise": got == ref,
        "decode_compiles": c["decode"],
        "prefill_compiles": sum(c["prefill"].values()),
        "prefill_keys": len(c["prefill"]),
        "ref_decode_compiles": ref_counts["decode"],
        "ref_total": ref_counts["total"],
        "total": c["total"],
    }))
"""


@pytest.mark.slow
@pytest.mark.parametrize("arch", SERVED_ARCHS)
def test_tp_serving_bitwise_exact_8dev(arch, run_in_8dev_subprocess):
    records = run_in_8dev_subprocess(
        _EXACTNESS_SNIPPET % {"arch": arch}, timeout=600
    )
    assert [r["tp"] for r in records] == [2, 4, 8]
    for r in records:
        assert r["bitwise"], f"{arch} tp={r['tp']} tokens diverged: {r}"
        # Compile budget: sharding must not add programs — exactly one
        # decode, one prefill per (bucket, width) key actually used,
        # and the same total as the single-device reference.
        assert r["decode_compiles"] == 1, r
        assert r["prefill_compiles"] == r["prefill_keys"], r
        assert r["total"] == r["ref_total"], r


def test_tp1_mesh_serving_exact_single_device():
    """The mesh path itself (device_put layouts, use_mesh around every
    trace, constrain_pool) on a 1-device ("model",) mesh — tier-1
    coverage of the sharded code path without forcing host devices."""
    cfg = configs.get_smoke_config("qwen2.5-3b")
    cfg = dataclasses.replace(cfg, cache_dtype="float32")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(1, 64, p).astype(np.int32),
                    n_tokens=t, rid=i)
            for i, (p, t) in enumerate([(3, 4), (9, 3), (17, 5)])]
    kw = dict(max_slots=3, max_len=32, page_size=8, prefill_chunk=8)
    ref = [list(map(int, r.tokens))
           for r in Scheduler(cfg, params, **kw).serve(reqs)]
    sched = Scheduler(cfg, params, tp=1, **kw)
    got = [list(map(int, r.tokens)) for r in sched.serve(reqs)]
    assert got == ref
    assert sched.compile_counts()["decode"] == 1
    assert sched.mesh is not None and sched.mesh_ctx.exact


def test_tp_knob_validation():
    cfg = configs.get_smoke_config("qwen2.5-3b")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="not both"):
        Scheduler(cfg, params, tp=1,
                  mesh=jax.make_mesh((1,), ("model",)))
    with pytest.raises(ValueError, match="exceeds"):
        Scheduler(cfg, params, tp=jax.device_count() + 1)
    with pytest.raises(ValueError, match=">= 1"):
        Scheduler(cfg, params, tp=0)


def test_serving_param_rules_output_dims_only():
    """Every serving param rule shards only output dims: resolving the
    full smoke param tree must leave each matmul's contracting dim
    replicated (spec entry None at dim 0 of 2-dim leaves)."""
    from jax.sharding import PartitionSpec as P

    from repro.dist import sharding as shd

    cfg = configs.get_smoke_config("deepseek-v3-671b")  # MLA + MoE + MTP
    shapes = jax.eval_shape(lambda k: lm.init(k, cfg), jax.random.PRNGKey(0))
    mesh = jax.make_mesh((1,), ("model",))
    tree = shd.serve_param_sharding_tree(shapes, mesh)
    assert len(jax.tree.leaves(tree, is_leaf=lambda x: hasattr(x, "spec"))) \
        == len(jax.tree.leaves(shapes))

    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        p = shd._path_str(path)
        logical = shd.serve_logical_for_path(p, len(leaf.shape))
        # contraction safety: dense /w leaves never shard their input dim
        if p.endswith("/w") and len(leaf.shape) >= 2:
            assert logical[-2] is None, (p, logical)
        # MLA factors, mamba and wo/w_down/embed stay fully replicated
        for frag in ("wo/w", "q_a/w", "q_b/w", "kv_a/w", "kv_b/w",
                     "embed/w", "in_proj/w", "out_proj/w", "x_proj/w"):
            if p.endswith(frag):
                assert logical == (None,) * len(leaf.shape), (p, logical)
    # spot-check the sharded ones
    assert shd.serve_logical_for_path("blocks/0/mixer/wq/w", 2) == \
        (None, "heads")
    assert shd.serve_logical_for_path("blocks/0/ffn/w_gate", 3) == \
        ("experts", None, "ff")
    assert shd.serve_logical_for_path("blocks/0/ffn/w_down", 3) == \
        ("experts", None, None)
    assert shd.serve_logical_for_path("head/w", 2) == (None, "vocab")
    assert shd.serve_logical_for_path("blocks/ffn/w_up", 4) == \
        (None, "experts", None, "ff")


def test_repl_act_noop_outside_exact_context():
    import jax.numpy as jnp

    from repro.dist import sharding as shd

    x = jnp.ones((4, 4))
    assert shd.repl_act(x) is x                      # no context
    mesh = jax.make_mesh((1,), ("model",))
    with shd.use_mesh(mesh):                         # training ctx: not exact
        assert shd.repl_act(x) is x
    with shd.use_mesh(shd.serving_context(mesh)):
        y = shd.repl_act(x)                          # exact ctx: constrained
        assert y.shape == x.shape
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
