"""Persistent serving sessions: the device pool, the PagePool prefix
index and the jit caches are built once per ``ServeSession`` and
survive across traces — a system prompt cached by one trace is a
cross-trace HIT in the next, with greedy tokens still bitwise-identical
to per-request ``Engine.generate`` and no new compiles between traces.
Also covers streaming delivery (``submit()`` handles: per-token
callback + ``stream()`` iterator), session lifecycle edge cases
(interleaved submission, empty-session ``step()``, reuse after a
capacity ``ValueError``) and submission-time duplicate-rid rejection."""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.serve import Engine, Request, Scheduler, ServeSession

VOCAB = 512


def _mk(arch="qwen2.5-3b", cache="float32"):
    """Lossless cache dtype so prefix reuse (and thus cross-trace reuse)
    is active."""
    cfg = configs.get_smoke_config(arch)
    cfg = dataclasses.replace(cfg, cache_dtype=cache)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _shared_prefix_trace(rng, system, tails, n_tokens=4, rid0=0):
    return [
        Request(
            prompt=np.concatenate(
                [system, rng.integers(0, VOCAB, t).astype(np.int32)]
            ),
            n_tokens=n_tokens, rid=rid0 + i,
        )
        for i, t in enumerate(tails)
    ]


def _assert_engine_exact(eng, reqs, results):
    for req, res in zip(reqs, results):
        ref = eng.generate(req.prompt[None], n_tokens=req.n_tokens,
                           request_ids=[res.rid])
        np.testing.assert_array_equal(ref.tokens[0], res.tokens)


class TestWarmSession:
    def test_second_trace_hits_cross_trace_exact_no_new_compiles(self):
        """The tentpole contract: a second serve() through the same
        scheduler finds the first trace's system-prompt pages CACHED —
        every request of trace 2 (including the FIRST one, which was
        the cold miss before sessions) records cross-trace prefix hits
        — while tokens stay Engine-exact and the jit caches do not grow
        between the traces."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=4, max_len=64, page_size=8)
        eng = Engine(cfg, params, max_len=64)
        rng = np.random.default_rng(0)
        system = rng.integers(0, VOCAB, 24).astype(np.int32)
        t1 = _shared_prefix_trace(rng, system, [2, 3, 5, 2, 4, 3])
        t2 = _shared_prefix_trace(rng, system, [4, 2, 3, 5, 2, 4], rid0=100)

        r1 = sched.serve(t1)
        s1 = sched.last_stats
        c1 = sched.compile_counts()
        r2 = sched.serve(t2)
        s2 = sched.last_stats
        c2 = sched.compile_counts()

        assert s1.trace_index == 0 and s2.trace_index == 1
        # Trace 1 is all intra-trace: the prefix was filled by its own
        # first request.
        assert s1.paging["prefix_hits"] > 0
        assert s1.paging["cross_trace_hits"] == 0
        assert [r.prefix_hit_tokens for r in r1][0] == 0
        # Trace 2: every request (the first included) hits the pages the
        # previous trace filled — 3 pages x 8 tokens of the 24-token
        # system prompt, counted as cross-trace.
        assert s2.paging["prefix_misses"] == 0
        assert s2.paging["cross_trace_hits"] == 6 * 3
        assert s2.paging["cross_trace_hit_tokens"] == 6 * 24
        assert all(r.prefix_hit_tokens == 24 for r in r2)
        # Warm trace compiled nothing new.
        assert c1 == c2
        # Scheduling/caching never changes numerics.
        _assert_engine_exact(eng, t1, r1)
        _assert_engine_exact(eng, t2, r2)
        # The persistent pool was built once and is reported.
        assert s1.pool_bytes == s2.pool_bytes > 0
        assert sched.session() is sched.session()

    def test_fresh_session_is_cold_but_shares_compiles(self):
        """session(fresh=True) gets its own pool and prefix cache (cold
        misses again) while reusing the scheduler's compiled programs."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=64, page_size=8)
        rng = np.random.default_rng(1)
        system = rng.integers(0, VOCAB, 16).astype(np.int32)
        sched.serve(_shared_prefix_trace(rng, system, [2, 3]))
        before = sched.compile_counts()
        fresh = sched.session(fresh=True)
        assert isinstance(fresh, ServeSession)
        assert fresh is not sched.session()
        fresh.serve(_shared_prefix_trace(rng, system, [2, 3], rid0=50))
        assert fresh.last_stats.paging["cross_trace_hits"] == 0
        assert fresh.last_stats.paging["prefix_misses"] > 0
        assert sched.compile_counts() == before   # same shapes, shared cache

    def test_legacy_unpaged_session_persists_across_traces(self):
        """paged=False rides the same session machinery: the monolithic
        pool is built once, traces are numbered, tokens stay exact."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=64, paged=False)
        eng = Engine(cfg, params, max_len=64)
        rng = np.random.default_rng(2)
        reqs = [Request(prompt=rng.integers(0, VOCAB, p).astype(np.int32),
                        n_tokens=3, rid=i) for i, p in enumerate([4, 9, 6])]
        r1 = sched.serve(reqs)
        c1 = sched.compile_counts()
        r2 = sched.serve(reqs)
        assert sched.last_stats.trace_index == 1
        assert sched.last_stats.paging is None
        assert sched.compile_counts() == c1
        _assert_engine_exact(eng, reqs, r1)
        for a, b in zip(r1, r2):
            np.testing.assert_array_equal(a.tokens, b.tokens)


class TestStreaming:
    def test_tokens_observable_as_produced(self):
        """submit() returns a handle whose tokens appear one per step:
        the on_token callback sees every token, in order, BEFORE the
        trace completes; stream() yields exactly the generated tokens;
        the final result equals Engine.generate."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=64, page_size=8)
        eng = Engine(cfg, params, max_len=64)
        rng = np.random.default_rng(3)
        pa = rng.integers(0, VOCAB, 9).astype(np.int32)
        pb = rng.integers(0, VOCAB, 5).astype(np.int32)

        seen = []
        ha = sched.submit(Request(prompt=pa, n_tokens=6, rid=1),
                          on_token=lambda h, t: seen.append((h.n_generated, t)))
        hb = sched.submit(Request(prompt=pb, n_tokens=3, rid=2))
        assert not ha.done and ha.n_generated == 0

        streamed = []
        progress = []
        for tok in ha.stream():
            streamed.append(tok)
            progress.append(ha.n_generated)
        # Callbacks deliver every token in production order, each AFTER
        # its token was recorded (delivery is deferred to the end of the
        # step, so the handle may be a token ahead) — and they start
        # while the request is still mid-generation, not at completion.
        ns = [n for n, _ in seen]
        assert len(ns) == 6 and ns == sorted(ns)
        assert all(n >= i + 1 for i, n in enumerate(ns))
        assert ns[0] < 6                      # streaming, not end-of-trace
        assert [t for _, t in seen] == streamed
        # stream() never ran ahead of production.
        assert progress[0] >= 1 and progress[-1] == 6
        sched.drain()   # finish the co-submitted request
        assert ha.done and hb.done
        np.testing.assert_array_equal(ha.generated, np.asarray(streamed))
        np.testing.assert_array_equal(
            eng.generate(pa[None], n_tokens=6, request_ids=[1]).tokens[0],
            ha.result.tokens,
        )
        np.testing.assert_array_equal(
            eng.generate(pb[None], n_tokens=3, request_ids=[2]).tokens[0],
            hb.result.tokens,
        )
        # Draining the session finalized the trace stats.
        assert sched.last_stats.generated_tokens == 9

    def test_eos_retires_streaming_handle(self):
        """EOS keeps its retirement semantics under streaming: the
        handle is done at the EOS token, the result is truncated there,
        and the freed slot admits the queued request."""
        cfg, params = _mk()
        rng = np.random.default_rng(4)
        prompt = rng.integers(0, VOCAB, 6).astype(np.int32)
        free = Scheduler(cfg, params, max_slots=1, max_len=64).serve(
            [Request(prompt=prompt, n_tokens=8)]
        )[0]
        eos = int(free.generated[2])
        k = int(np.flatnonzero(free.generated == eos)[0])

        sched = Scheduler(cfg, params, max_slots=1, max_len=64, eos_id=eos)
        ha = sched.submit(Request(prompt=prompt, n_tokens=8, rid=0))
        hb = sched.submit(Request(prompt=prompt[:3], n_tokens=2, rid=1))
        got = list(ha.stream())
        assert got == list(free.generated[:k + 1])
        assert ha.done and got[-1] == eos
        sched.drain()
        assert hb.result.admitted_step == ha.result.finished_step

    def test_callback_fires_from_step_for_interleaved_requests(self):
        """Both handles' callbacks fire from the same step() calls —
        tokens interleave across concurrently-decoding requests."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=64, page_size=8)
        rng = np.random.default_rng(5)
        order = []
        for rid in (0, 1):
            sched.submit(
                Request(prompt=rng.integers(0, VOCAB, 4 + rid).astype(np.int32),
                        n_tokens=4, rid=rid),
                on_token=lambda h, t: order.append(h.rid),
            )
        sched.drain()
        # 2 admission tokens then 3 decode steps x 2 slots, interleaved.
        assert sorted(order) == [0] * 4 + [1] * 4
        assert order[2:] == [0, 1, 0, 1, 0, 1]


class TestSessionLifecycle:
    def test_empty_session_step_is_noop(self):
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=64)
        sess = sched.session()
        assert sess.idle
        assert sess.step() == 0
        assert sess.step() == 0
        assert sess.last_stats is None       # no trace ever ran
        # and the session still serves normally afterwards
        rng = np.random.default_rng(6)
        res = sess.serve([Request(prompt=rng.integers(0, VOCAB, 5), n_tokens=2)])
        assert res[0].tokens.size == 7

    def test_empty_serve_lands_fresh_zero_stats(self):
        """serve([]) must not leave a previous trace's stats in place —
        the contract is that every call lands fresh ServeStats."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=64)
        rng = np.random.default_rng(15)
        sched.serve([Request(prompt=rng.integers(0, VOCAB, 5), n_tokens=3)])
        busy = sched.last_stats
        assert busy.generated_tokens == 3
        assert sched.serve([]) == []
        empty = sched.last_stats
        assert empty is not busy
        assert empty.generated_tokens == 0 and empty.steps == 0
        assert empty.trace_index == busy.trace_index + 1

    def test_raising_on_token_callback_leaves_session_consistent(self):
        """A user callback that raises interrupts the caller AFTER the
        step's slot bookkeeping completed: resuming the session yields
        the exact tokens an undisturbed run produces, and the
        pre-empted callbacks fire on the next step."""
        cfg, params = _mk()
        rng = np.random.default_rng(16)
        pa = rng.integers(0, VOCAB, 6).astype(np.int32)
        pb = rng.integers(0, VOCAB, 4).astype(np.int32)
        mk_reqs = lambda: [Request(prompt=pa, n_tokens=5, rid=0),
                           Request(prompt=pb, n_tokens=5, rid=1)]
        clean = {r.rid: r.tokens for r in Scheduler(
            cfg, params, max_slots=2, max_len=64, page_size=8).serve(mk_reqs())}

        sched = Scheduler(cfg, params, max_slots=2, max_len=64, page_size=8)
        sess = sched.session()
        seen = []

        def boom(h, t):
            seen.append((h.rid, t))
            if len(seen) == 3:
                raise RuntimeError("user callback exploded")

        ha = sess.submit(Request(prompt=pa, n_tokens=5, rid=0), on_token=boom)
        hb = sess.submit(Request(prompt=pb, n_tokens=5, rid=1), on_token=boom)
        with pytest.raises(RuntimeError, match="exploded"):
            sess.drain()
        sess.drain()                         # resume: session not corrupted
        assert ha.done and hb.done
        np.testing.assert_array_equal(ha.result.tokens, clean[0])
        np.testing.assert_array_equal(hb.result.tokens, clean[1])
        # Every token was eventually delivered to the callback, in order.
        assert [t for rid, t in seen if rid == 0] == list(ha.generated)
        assert [t for rid, t in seen if rid == 1] == list(hb.generated)

    def test_empty_serve_mid_trace_does_not_finalize_live_trace(self):
        """serve([]) while submit() handles are in flight must not
        publish partial stats or reset the running trace's counters."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=64, page_size=8)
        sess = sched.session()
        rng = np.random.default_rng(17)
        h = sess.submit(Request(prompt=rng.integers(0, VOCAB, 6), n_tokens=6))
        sess.step()
        mid = sess.step_idx
        assert sched.serve([]) == []
        assert sess.last_stats is None          # nothing finalized
        assert not sess.idle and sess.step_idx == mid
        sess.drain()
        assert h.done
        assert sess.last_stats.trace_index == 0
        assert sess.last_stats.generated_tokens == 6

    def test_callback_submitting_follow_up_keeps_step_accounting_sane(self):
        """An on_token callback that submits a follow-up request when
        its handle retires (a streaming chain) starts a NEW trace from
        the callback — step() must still report non-negative token
        counts and both requests must complete."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=64, page_size=8)
        sess = sched.session()
        rng = np.random.default_rng(18)
        p2 = rng.integers(0, VOCAB, 4).astype(np.int32)
        chained = []

        def chain(h, t):
            if h.done and not chained:
                chained.append(
                    sess.submit(Request(prompt=p2, n_tokens=2, rid=50))
                )

        sess.submit(Request(prompt=rng.integers(0, VOCAB, 6), n_tokens=3,
                            rid=0), on_token=chain)
        returns = []
        while not sess.idle:
            returns.append(sess.step())
        assert all(r >= 0 for r in returns)
        assert sum(r for r in returns) == 3 + 2
        assert chained and chained[0].done

    def test_interleaved_submit_joins_active_trace(self):
        """A request submitted while the session is mid-trace joins the
        SAME trace (admitted at the current step) and both requests stay
        Engine-exact."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=64, page_size=8)
        eng = Engine(cfg, params, max_len=64)
        rng = np.random.default_rng(7)
        pa = rng.integers(0, VOCAB, 7).astype(np.int32)
        pb = rng.integers(0, VOCAB, 4).astype(np.int32)
        sess = sched.session()
        ha = sess.submit(Request(prompt=pa, n_tokens=8, rid=0))
        for _ in range(3):
            sess.step()
        mid_step = sess.step_idx
        assert not sess.idle and not ha.done
        hb = sess.submit(Request(prompt=pb, n_tokens=2, rid=1))
        sess.drain()
        assert hb.result.admitted_step >= mid_step
        assert sess.last_stats.trace_index == 0   # one trace, not two
        np.testing.assert_array_equal(
            eng.generate(pa[None], n_tokens=8, request_ids=[0]).tokens[0],
            ha.result.tokens,
        )
        np.testing.assert_array_equal(
            eng.generate(pb[None], n_tokens=2, request_ids=[1]).tokens[0],
            hb.result.tokens,
        )

    def test_session_usable_after_capacity_value_error(self):
        """A rejected submission (max_len or page-pool capacity) leaves
        the session untouched: nothing queued, later traces run."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=32, page_size=8,
                          n_pages=4)               # 3 usable pages
        sess = sched.session()
        rng = np.random.default_rng(8)
        with pytest.raises(ValueError, match="page-pool capacity"):
            sess.submit(Request(prompt=rng.integers(0, VOCAB, 20), n_tokens=8))
        with pytest.raises(ValueError, match="engine capacity"):
            sess.submit(Request(prompt=rng.integers(0, VOCAB, 30), n_tokens=8))
        assert sess.idle and not sess.queue
        ok = Request(prompt=rng.integers(0, VOCAB, 10), n_tokens=3)
        res = sess.serve([ok])
        assert res[0].tokens.size == 13
        # Mid-trace rejection also leaves the live request undisturbed.
        h = sess.submit(Request(prompt=rng.integers(0, VOCAB, 6), n_tokens=4))
        sess.step()
        with pytest.raises(ValueError):
            sess.submit(Request(prompt=rng.integers(0, VOCAB, 30), n_tokens=8))
        sess.drain()
        assert h.done and h.result.tokens.size == 10

    def test_batch_serve_validates_before_enqueuing(self):
        """serve() validates the WHOLE batch before touching session
        state: one bad request rejects the trace atomically."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=32)
        rng = np.random.default_rng(9)
        good = Request(prompt=rng.integers(0, VOCAB, 4), n_tokens=2)
        bad = Request(prompt=rng.integers(0, VOCAB, 30), n_tokens=8)
        with pytest.raises(ValueError):
            sched.serve([good, bad])
        assert sched.session().idle and not sched.session().queue

    def test_cross_trace_counters_on_serve_stats(self):
        """ServeStats.paging distinguishes intra- from cross-trace hits
        per trace: hits within a trace never count as cross, and the
        per-trace delta resets between serve() calls."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=64, page_size=8)
        rng = np.random.default_rng(10)
        system = rng.integers(0, VOCAB, 16).astype(np.int32)
        sched.serve(_shared_prefix_trace(rng, system, [2, 3, 4]))
        s1 = sched.last_stats
        assert s1.paging["prefix_hits"] == 2 * 2    # 2 later reqs x 2 pages
        assert s1.paging["cross_trace_hits"] == 0
        sched.serve(_shared_prefix_trace(rng, system, [5, 2], rid0=10))
        s2 = sched.last_stats
        assert s2.paging["prefix_hits"] == 2 * 2
        assert s2.paging["cross_trace_hits"] == 2 * 2
        assert s2.paging["cross_trace_hit_tokens"] == 2 * 16
        assert s2.paging["prefix_misses"] == 0


class TestDuplicateRids:
    def test_submit_time_duplicate_live_rid_raises(self):
        """Two live requests must never share a rid: results are keyed
        and PRNG streams derived by it.  The collision is caught AT
        SUBMISSION — before the duplicate can corrupt anything — and the
        rid becomes valid again once its owner retires."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=64)
        rng = np.random.default_rng(11)
        p = rng.integers(0, VOCAB, 4).astype(np.int32)
        sess = sched.session()
        h = sess.submit(Request(prompt=p, n_tokens=2, rid=7))
        with pytest.raises(ValueError, match="duplicate"):
            sess.submit(Request(prompt=p, n_tokens=2, rid=7))
        sess.drain()
        assert h.done
        h2 = sess.submit(Request(prompt=p, n_tokens=2, rid=7))   # reusable now
        sess.drain()
        np.testing.assert_array_equal(h.result.tokens, h2.result.tokens)

    def test_auto_rids_skip_live_collisions(self):
        """submit() without an explicit rid picks a fresh id that cannot
        collide with any queued or decoding request."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=64)
        rng = np.random.default_rng(12)
        sess = sched.session()
        manual = sess.submit(
            Request(prompt=rng.integers(0, VOCAB, 4), n_tokens=12, rid=0)
        )
        autos = [
            sess.submit(Request(prompt=rng.integers(0, VOCAB, 4), n_tokens=2))
            for _ in range(3)
        ]
        rids = [manual.rid] + [h.rid for h in autos]
        assert len(set(rids)) == len(rids)
        sess.drain()
        assert all(h.done for h in autos)

    def test_serve_default_rids_skip_live_submits(self):
        """A default-rid serve() batch alongside an in-flight submit()
        handle must not collide with its auto-rid: batch defaults count
        up from 0 but skip live ids."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=64)
        eng = Engine(cfg, params, max_len=64)
        rng = np.random.default_rng(14)
        p = rng.integers(0, VOCAB, 5).astype(np.int32)
        h = sched.submit(Request(prompt=p, n_tokens=20))   # auto-rid 0, live
        assert h.rid == 0
        batch = [Request(prompt=rng.integers(0, VOCAB, 4), n_tokens=2)
                 for _ in range(2)]
        results = sched.serve(batch)                       # rids 1, 2
        assert [r.rid for r in results] == [1, 2]
        assert h.done                                      # drained together
        np.testing.assert_array_equal(
            eng.generate(p[None], n_tokens=20, request_ids=[0]).tokens[0],
            h.result.tokens,
        )

    def test_batch_duplicate_message_unchanged(self):
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=32)
        rng = np.random.default_rng(13)
        p = rng.integers(0, VOCAB, 4).astype(np.int32)
        with pytest.raises(ValueError, match="duplicate request ids"):
            sched.serve([Request(prompt=p, n_tokens=2, rid=1),
                         Request(prompt=p, n_tokens=2)])  # defaults to rid 1
