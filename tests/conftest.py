"""Test bootstrap: put ``src/`` on ``sys.path`` so bare
``python -m pytest`` works without the ``PYTHONPATH=src`` incantation,
and fall back to the in-repo hypothesis shim when the real package is
not installed (hermetic CI images)."""
import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401  (real package preferred)
except ModuleNotFoundError as e:
    if e.name != "hypothesis":  # broken install of a transitive dep: surface it
        raise
    from repro._compat import minihypothesis

    minihypothesis.install()
