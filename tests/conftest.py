"""Test bootstrap: put ``src/`` on ``sys.path`` so bare
``python -m pytest`` works without the ``PYTHONPATH=src`` incantation,
and fall back to the in-repo hypothesis shim when the real package is
not installed (hermetic CI images).

Also home of the shared multi-device subprocess harness
(:func:`run_in_8dev_subprocess`): jax locks the device count at first
initialization, so every forced-N-device test must run its payload in a
fresh interpreter with ``XLA_FLAGS`` set before the jax import.
"""
import json
import os
import subprocess
import sys

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(_ROOT, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401  (real package preferred)
except ModuleNotFoundError as e:
    if e.name != "hypothesis":  # broken install of a transitive dep: surface it
        raise
    from repro._compat import minihypothesis

    minihypothesis.install()


def run_in_8dev_subprocess(snippet: str, timeout: int = 420,
                           n_devices: int = 8):
    """Run ``snippet`` in a fresh interpreter on a forced ``n_devices``
    CPU host platform and return its JSON records.

    The harness owns the boilerplate every multi-device test used to
    copy: ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set in
    the child's environment (before any jax import can lock the device
    count), ``src/`` on the child's path, repo root as cwd, a nonzero-rc
    assertion carrying the stderr tail, and parsing of every
    ``{``-prefixed stdout line as one JSON record.  Snippets therefore
    must NOT set XLA_FLAGS themselves (the env var wins) and report via
    ``print(json.dumps({...}))``.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={int(n_devices)}"
    )
    env["PYTHONPATH"] = _SRC + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    out = subprocess.run(
        [sys.executable, "-c", snippet],
        capture_output=True, text=True, env=env, cwd=_ROOT, timeout=timeout,
    )
    assert out.returncode == 0, (
        f"8dev subprocess rc={out.returncode}\n"
        f"--- stdout tail ---\n{out.stdout[-1000:]}\n"
        f"--- stderr tail ---\n{out.stderr[-2000:]}"
    )
    return [json.loads(l) for l in out.stdout.splitlines()
            if l.startswith("{")]


@pytest.fixture(name="run_in_8dev_subprocess")
def _run_in_8dev_subprocess_fixture():
    return run_in_8dev_subprocess
