"""Fast unit tests for ``repro.dist.sharding`` edge cases not covered by
the seed spec in ``test_sharding_dist.py``: empty rules, 1-D params,
rank-mismatch errors, context nesting, the no-mesh ``shard_act``
identity property, and property-based checks of the resolution rules
(``_divisible_prefix`` / ``axes_for`` / ``spec``) that now gate serving
correctness, not just training layouts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist import sharding as shd


def _mesh2():
    dev = np.array(jax.devices())
    return Mesh(dev.reshape(1, 1), ("data", "model"))


class TestSpecEdges:
    def test_empty_rules_replicates_everything(self):
        ctx = shd.MeshContext(_mesh2(), {})
        assert ctx.spec(("batch", "heads", "ff"), (4, 8, 16)) == P(None, None, None)
        assert ctx.axes_for("batch", 4) is None

    def test_unknown_logical_replicates(self):
        ctx = shd.MeshContext(_mesh2())
        assert ctx.spec(("no_such_axis",), (4,)) == P(None)

    def test_one_dim_param(self):
        ctx = shd.MeshContext(_mesh2(), {"ff": ("model",)})
        assert ctx.spec(("ff",), (8,)) == P("model")
        assert ctx.spec((None,), (8,)) == P(None)

    def test_rank_mismatch_raises(self):
        ctx = shd.MeshContext(_mesh2())
        with pytest.raises(ValueError, match="rank mismatch"):
            ctx.spec(("batch",), (4, 4))
        with pytest.raises(ValueError, match="rank mismatch"):
            ctx.spec(("batch", None, None), (4, 4))

    def test_rule_axis_absent_from_mesh_replicates(self):
        ctx = shd.MeshContext(_mesh2(), {"batch": ("pod", "data")})
        # "pod" is not on this 2-axis mesh -> resolution keeps only "data"
        assert ctx.spec(("batch",), (4,)) == P("data")
        ctx2 = shd.MeshContext(_mesh2(), {"batch": ("pod",)})
        assert ctx2.spec(("batch",), (4,)) == P(None)

    def test_multi_axis_prefix_divisibility(self):
        mesh = _mesh2()

        class Fake(shd.MeshContext):
            """Pretend pod=2, data=4 so prefix fallback is observable."""

            def __init__(self):
                self.mesh = mesh
                self.rules = {"batch": ("pod", "data")}

            def _axis_size(self, axis):
                return {"pod": 2, "data": 4}[axis]

            def axes_for(self, logical, dim):
                axes = self.rules.get(logical)
                if not axes:
                    return None
                return self._divisible_prefix(axes, dim) or None

        ctx = Fake()
        assert ctx.axes_for("batch", 16) == ("pod", "data")   # 16 % 8 == 0
        assert ctx.axes_for("batch", 4) == ("pod",)           # prefix fallback
        assert ctx.axes_for("batch", 3) is None               # replicate

    def test_sharding_returns_named_sharding(self):
        ctx = shd.MeshContext(_mesh2())
        s = ctx.sharding(("batch", None), (4, 4))
        assert isinstance(s, NamedSharding)
        # trailing replicated dims are canonicalised away so device_put
        # placements compare equal to jit-emitted output shardings
        assert s.spec == P("data")


class TestContext:
    def test_use_mesh_nesting_restores(self):
        mesh = _mesh2()
        assert shd.current() is None
        with shd.use_mesh(mesh) as outer:
            assert shd.current() is outer
            with shd.use_mesh(shd.MeshContext(mesh, {})) as inner:
                assert shd.current() is inner
            assert shd.current() is outer
        assert shd.current() is None

    def test_use_mesh_pops_on_exception(self):
        with pytest.raises(RuntimeError):
            with shd.use_mesh(_mesh2()):
                raise RuntimeError("boom")
        assert shd.current() is None

    def test_shard_act_identity_property_without_mesh(self):
        """No installed context -> shard_act returns its argument object
        unchanged for any shape/annotation pair."""
        assert shd.current() is None
        rng = np.random.default_rng(0)
        for nd in range(1, 5):
            shape = tuple(int(rng.integers(1, 5)) for _ in range(nd))
            x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
            logical = tuple(
                rng.choice([None, "batch", "heads", "ff"]) for _ in range(nd)
            )
            assert shd.shard_act(x, logical) is x

    def test_shard_act_constrains_under_mesh(self):
        """Under a mesh the constraint must appear in the jitted HLO and
        preserve values (on 1 device the eager path may be identity)."""
        x = jnp.ones((4, 8))
        with shd.use_mesh(_mesh2()):
            y = shd.shard_act(x, ("batch", None))
            hlo = (
                jax.jit(lambda a: shd.shard_act(a, ("batch", None)))
                .lower(x).as_text()
            )
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
        assert "sharding" in hlo


class TestParamRulesEdges:
    def test_bias_and_norm_leaves_replicate(self):
        assert shd.logical_for_path("blocks/mixer/wq/b", 1) == (None,)
        assert shd.logical_for_path("ln_f/bias", 1) == (None,)

    def test_rank_mismatch_falls_to_replicated(self):
        # matched rule, but rank neither base nor base+1
        assert shd.logical_for_path("embed/w", 4) == (None, None, None, None)

    def test_router_and_mamba_rules(self):
        assert shd.logical_for_path("blocks/ffn/router/w", 2) == ("fsdp", None)
        assert shd.logical_for_path("blocks/mixer/out_proj/w", 3) == (None, "tp", "fsdp")
        assert shd.logical_for_path("blocks/mixer/conv_w", 2) == ("tp", None)

    def test_param_sharding_tree_structure_and_fallback(self):
        mesh = _mesh2()
        tree = {
            "embed": {"w": jax.ShapeDtypeStruct((32, 16), jnp.float32)},
            "ln": {"scale": jax.ShapeDtypeStruct((16,), jnp.float32)},
        }
        out = shd.param_sharding_tree(tree, mesh)
        assert out["embed"]["w"].spec == P("model", "data")
        assert out["ln"]["scale"].spec == P()


def _fake_ctx(sizes, rules):
    """A MeshContext whose axis sizes are simulated (the host has one
    device); resolution logic — _divisible_prefix / axes_for / spec — is
    the REAL implementation."""
    mesh = _mesh2() if set(sizes) <= {"data", "model"} else None
    assert mesh is not None, sizes

    class Fake(shd.MeshContext):
        def __init__(self):
            self.mesh = mesh
            self.rules = dict(rules)
            self.exact = False

        def _axis_size(self, axis):
            return sizes[axis]

    return Fake()


class TestResolutionProperties:
    """Property-based invariants of the rule resolution that exact
    sharded serving stands on."""

    @given(d=st.integers(1, 16), m=st.integers(1, 16),
           dim=st.integers(1, 4096))
    @settings(max_examples=60, deadline=None)
    def test_divisible_prefix_is_longest_and_divides(self, d, m, dim):
        ctx = _fake_ctx({"data": d, "model": m}, {})
        axes = ("data", "model")
        got = ctx._divisible_prefix(axes, dim)
        size = 1
        for a in got:
            size *= {"data": d, "model": m}[a]
        assert dim % size == 0                      # result divides
        if len(got) < len(axes):                    # and is the LONGEST
            nxt = size * {"data": d, "model": m}[axes[len(got)]]
            assert dim % nxt != 0

    @given(m=st.integers(1, 16), dim=st.integers(1, 64))
    @settings(max_examples=60, deadline=None)
    def test_axes_for_divisibility_and_rule_miss(self, m, dim):
        ctx = _fake_ctx({"data": 1, "model": m},
                        {"heads": ("model",)})
        got = ctx.axes_for("heads", dim)
        if dim % m == 0:
            assert got == ("model",)                # 1-sized axes divide all
        else:
            assert got is None                      # indivisible -> replicate
        # rule miss and empty rule always replicate
        assert ctx.axes_for("no_such_logical", dim) is None
        ctx.rules["empty"] = ()
        assert ctx.axes_for("empty", dim) is None

    @given(d=st.integers(1, 8), m=st.integers(1, 8),
           dims=st.tuples(st.integers(1, 64), st.integers(1, 64),
                          st.integers(1, 64)),
           names=st.tuples(st.sampled_from([None, "batch", "heads", "x"]),
                           st.sampled_from([None, "batch", "heads", "x"]),
                           st.sampled_from([None, "batch", "heads", "x"])))
    @settings(max_examples=80, deadline=None)
    def test_spec_never_reuses_axes_and_always_divides(self, d, m, dims,
                                                       names):
        sizes = {"data": d, "model": m}
        ctx = _fake_ctx(sizes, {"batch": ("data",), "heads": ("model",)})
        spec = ctx.spec(names, dims)
        flat = []
        for entry, dim in zip(tuple(spec), dims):
            axes = () if entry is None else (
                (entry,) if isinstance(entry, str) else tuple(entry)
            )
            size = 1
            for a in axes:
                size *= sizes[a]
            assert dim % size == 0                  # every dim stays divisible
            flat.extend(axes)
        assert len(flat) == len(set(flat))          # each mesh axis used once
        # unknown ("x") and None entries must be replicated
        for entry, name in zip(tuple(spec), names):
            if name in (None, "x"):
                assert entry is None
