"""Generator tests: netlist census == analytic census == cost-model area;
floorplan geometry consistency; file emission."""
import json
import math
import pathlib

import pytest

from repro.codegen import DcimDesign, design_from_point, generate, generate_netlists
from repro.codegen import audit as audit_mod
from repro.codegen.floorplan import floorplan
from repro.core.cells import TSMC28


DESIGNS = [
    dict(precision="int2", w_store=4096, N=16, H=64, L=8, k=1),
    dict(precision="int8", w_store=8192, N=64, H=128, L=8, k=4),
    dict(precision="int8", w_store=65536, N=128, H=512, L=8, k=8),
    dict(precision="int16", w_store=16384, N=128, H=256, L=8, k=4),
    dict(precision="fp8", w_store=8192, N=32, H=128, L=8, k=2),
    dict(precision="bf16", w_store=8192, N=64, H=128, L=16, k=4),
    dict(precision="fp16", w_store=16384, N=88, H=256, L=8, k=8),
    dict(precision="fp32", w_store=65536, N=192, H=1024, L=8, k=8),
]


@pytest.mark.parametrize("spec", DESIGNS, ids=lambda s: f"{s['precision']}-{s['w_store']}")
class TestCensusAudit:
    def test_emitted_census_matches_analytic(self, spec):
        d = design_from_point(spec)
        net = generate_netlists(d)
        audit = audit_mod.audit(d, net["census"])
        assert audit["census_match"], audit["mismatches"]

    def test_census_area_matches_cost_model(self, spec):
        d = design_from_point(spec)
        net = generate_netlists(d)
        audit = audit_mod.audit(d, net["census"])
        tol = 0.01 if d.is_fp else 1e-5
        assert audit["area_rel_err"] < tol, audit

    def test_printed_model_without_selection_mux(self, spec):
        d = design_from_point(spec, include_selection_mux=False)
        net = generate_netlists(d)
        audit = audit_mod.audit(d, net["census"])
        assert audit["census_match"], audit["mismatches"]
        assert audit["area_rel_err"] < (0.01 if d.is_fp else 1e-5)


class TestStructure:
    def test_sram_count_is_exact(self):
        d = design_from_point(DESIGNS[1])
        net = generate_netlists(d)
        assert net["census"]["SRAM"] == d.N * d.H * d.L
        assert d.N * d.H * d.L == d.w_store * d.B_w

    def test_fp_has_prealign_and_converter(self):
        d = design_from_point(DESIGNS[5])
        net = generate_netlists(d)
        assert "fp_prealign.v" in net["files"]
        assert "int2fp.v" in net["files"]

    def test_int_has_no_fp_blocks(self):
        d = design_from_point(DESIGNS[1])
        net = generate_netlists(d)
        assert "fp_prealign.v" not in net["files"]

    def test_verilog_is_balanced(self):
        d = design_from_point(DESIGNS[1])
        net = generate_netlists(d)
        for name, text in net["files"].items():
            opens = sum(
                1 for ln in text.splitlines() if ln.lstrip().startswith("module ")
            )
            closes = sum(
                1 for ln in text.splitlines() if ln.strip() == "endmodule"
            )
            assert opens == closes >= 1, (name, opens, closes)

    def test_mux_tree_count_matches_table2(self):
        """N:1 mux == N-1 MUX2 for power-of-two N."""
        from repro.codegen.templates import Netlist

        for N in (2, 4, 8, 16, 64):
            n = Netlist("t")
            n.w("module t;")
            n.mux_n1(N, [f"i{j}" for j in range(N)], "s", "y")
            assert n.counts["MUX2"] == N - 1

    def test_barrel_shifter_count_matches_table2(self):
        from repro.codegen.templates import Netlist

        for N in (2, 4, 8):
            n = Netlist("t")
            n.barrel_shifter(N, "a", "sh", "y")
            assert n.counts["MUX2"] == N * (N - 1)


class TestFloorplan:
    def test_blocks_cover_die(self):
        d = design_from_point(DESIGNS[1])
        plan = floorplan(d)
        s = plan["summary"]
        covered = sum(b.area_um2 for b in plan["blocks"])
        die = s["die_w_um"] * s["die_h_um"]
        assert covered == pytest.approx(die, rel=1e-6)

    def test_die_area_equals_cell_area_over_utilization(self):
        d = design_from_point(DESIGNS[5])
        plan = floorplan(d, utilization=0.7)
        s = plan["summary"]
        assert s["die_area_mm2"] == pytest.approx(s["cell_area_mm2"] / 0.7, rel=1e-6)

    def test_no_overlaps(self):
        d = design_from_point(dict(precision="bf16", w_store=4096, N=16, H=64, L=32, k=2))
        plan = floorplan(d)
        bs = plan["blocks"]
        for i in range(len(bs)):
            for j in range(i + 1, len(bs)):
                a, b = bs[i], bs[j]
                overlap_w = min(a.x_um + a.w_um, b.x_um + b.w_um) - max(a.x_um, b.x_um)
                overlap_h = min(a.y_um + a.h_um, b.y_um + b.h_um) - max(a.y_um, b.y_um)
                assert overlap_w <= 1e-6 or overlap_h <= 1e-6, (a.name, b.name)


class TestEndToEnd:
    def test_generate_writes_everything(self, tmp_path):
        rep = generate(DESIGNS[1], tmp_path)
        assert (tmp_path / "rtl" / "dcim_macro.v").exists()
        assert (tmp_path / "rtl" / "cell_lib.v").exists()
        assert (tmp_path / "floorplan.def").exists()
        loaded = json.loads((tmp_path / "report.json").read_text())
        assert loaded["audit"]["ok"]

    def test_generate_from_explorer_point(self, tmp_path):
        from repro.core import explore
        from repro.core.nsga2 import NSGA2Config

        pts = explore("int8", 4096, NSGA2Config(pop_size=32, generations=12))
        rep = generate(pts[0], tmp_path)
        assert rep["audit"]["census_match"]
