"""Distribution layer tests: logical-axis resolution, divisibility
fallback, param rules, HLO analyzer, and (via the shared
``run_in_8dev_subprocess`` harness in conftest) sharded train-step
execution + compressed ring all-reduce."""
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist import sharding as shd
from repro.launch.hlo_analysis import analyze_hlo, parse_computations


class TestMeshContext:
    def _mesh(self):
        dev = np.array(jax.devices())
        return Mesh(dev.reshape(1, 1), ("data", "model"))

    def test_divisibility_fallback_replicates(self):
        mesh = self._mesh()
        ctx = shd.MeshContext(
            mesh, {"batch": ("data",), "heads": ("model",)}
        )
        # dims divisible by 1 -> sharded on the (trivial) axis
        assert ctx.spec(("batch", "heads"), (4, 8)) == P("data", "model")

    def test_fallback_on_indivisible(self):
        # Fake a bigger mesh via rules resolution logic only.
        dev = np.array(jax.devices())
        mesh = Mesh(dev.reshape(1, 1), ("data", "model"))

        class Fake(shd.MeshContext):
            def __init__(self):
                self.mesh = mesh
                self.rules = {"kv_heads": ("model",), "head_dim": ("model",)}

            def axes_for(self, logical, dim):
                axes = self.rules.get(logical)
                if not axes:
                    return None
                size = 16  # pretend model axis is 16-wide
                if dim % size != 0:
                    return None
                return axes

        ctx = Fake()
        # kv_heads=8 indivisible by 16 -> None; head_dim=128 -> model
        spec = ctx.spec((None, "kv_heads", "head_dim"), (2, 8, 128))
        assert spec == P(None, None, "model")

    def test_axis_used_once(self):
        mesh = self._mesh()
        ctx = shd.MeshContext(mesh, {"a": ("model",), "b": ("model",)})
        spec = ctx.spec(("a", "b"), (4, 4))
        assert spec == P("model", None)  # second use of model blocked

    def test_multi_axis_prefix_fallback(self):
        dev = np.array(jax.devices())
        mesh = Mesh(dev.reshape(1, 1, 1), ("pod", "data", "model"))
        ctx = shd.MeshContext(mesh)
        assert ctx.rules["batch"] == ("pod", "data")

    def test_shard_act_noop_without_context(self):
        x = jnp.ones((4, 4))
        y = shd.shard_act(x, ("batch", None))
        assert y is x


class TestParamRules:
    def test_attention_weights(self):
        assert shd.logical_for_path("blocks/mixer/wq/w", 2) == ("fsdp", "tp")
        assert shd.logical_for_path("blocks/0/mixer/wo/w", 3) == (None, "tp", "fsdp")

    def test_moe_experts(self):
        # fully-sharded expert weights: E on model, d_ff on data (§Perf I6)
        assert shd.logical_for_path("blocks/0/ffn/w_gate", 3) == ("experts", None, "fsdp")
        assert shd.logical_for_path("blocks/0/ffn/w_down", 3) == ("experts", "fsdp", None)
        # scan-stacked gets a leading None
        assert shd.logical_for_path("blocks/ffn/w_up", 4) == (None, "experts", None, "fsdp")
        # optimizer moments inherit via suffix stripping (dryrun.state_shardings)
        assert shd.logical_for_path("blocks/0/ffn/w_gate/m", 3) == (None, None, None)  # raw path w/o strip
        # dense FFN leaves (with /w) still hit the dense rules
        assert shd.logical_for_path("blocks/0/ffn/w_up/w", 2) == ("fsdp", "ff")

    def test_norms_replicated(self):
        assert shd.logical_for_path("ln1/scale", 1) == (None,)

    def test_embed_head(self):
        assert shd.logical_for_path("embed/w", 2) == ("vocab", "fsdp")
        assert shd.logical_for_path("head/w", 2) == ("fsdp", "vocab")

    def test_param_sharding_tree_runs(self):
        from repro import configs
        from repro.models import lm

        cfg = configs.get_smoke_config("qwen2.5-3b")
        shapes = jax.eval_shape(lambda k: lm.init(k, cfg), jax.random.PRNGKey(0))
        dev = np.array(jax.devices())
        mesh = Mesh(dev.reshape(1, 1), ("data", "model"))
        tree = shd.param_sharding_tree(shapes, mesh)
        assert len(jax.tree.leaves(tree, is_leaf=lambda x: hasattr(x, "spec"))) \
            == len(jax.tree.leaves(shapes))


HLO_SAMPLE = textwrap.dedent("""\
    %body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
      %p = (s32[], f32[8,8]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,8] get-tuple-element(%p), index=1
      %w = f32[8,8] constant({...})
      %d = f32[8,8] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,8] all-reduce(%d), to_apply=%sum
      %one = s32[] constant(1)
      %ni = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[8,8]) tuple(%ni, %ar)
    }
    %cond (p2: (s32[], f32[8,8])) -> pred[] {
      %p2 = (s32[], f32[8,8]) parameter(0)
      %i2 = s32[] get-tuple-element(%p2), index=0
      %n = s32[] constant(10)
      ROOT %lt = pred[] compare(%i2, %n), direction=LT
    }
    ENTRY %main (a: f32[8,8]) -> f32[8,8] {
      %a = f32[8,8] parameter(0)
      %z = s32[] constant(0)
      %tup = (s32[], f32[8,8]) tuple(%z, %a)
      %w2 = (s32[], f32[8,8]) while(%tup), condition=%cond, body=%body
      ROOT %out = f32[8,8] get-tuple-element(%w2), index=1
    }
""")


class TestHloAnalyzer:
    def test_trip_count_multiplies_flops(self):
        res = analyze_hlo(HLO_SAMPLE)
        # dot: 2*8*8*8 = 1024 flops, x10 trips
        assert res["dot_flops"] == pytest.approx(10240)

    def test_collectives_multiplied(self):
        res = analyze_hlo(HLO_SAMPLE)
        ar = res["collectives"]["all-reduce"]
        assert ar["count"] == 10
        assert ar["bytes"] == pytest.approx(10 * 8 * 8 * 4)

    def test_parse_computations(self):
        comps = parse_computations(HLO_SAMPLE)
        assert set(comps) == {"body", "cond", "main"}
        assert len(comps["body"].ops) == 9

    def test_real_compiled_module(self):
        """End-to-end on an actual compiled jitted scan."""

        def f(x):
            def body(c, _):
                return c @ c * 0.5, None

            y, _ = jax.lax.scan(body, x, None, length=7)
            return y

        compiled = jax.jit(f).lower(jnp.ones((16, 16))).compile()
        res = analyze_hlo(compiled.as_text())
        # 7 iterations x 2*16^3 flops
        assert res["dot_flops"] == pytest.approx(7 * 2 * 16**3, rel=0.01)


SUBPROC_SNIPPET = r"""
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.dist import sharding as shd
from repro import configs
from repro.models import lm
from repro.launch.specs import concrete_batch

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = configs.get_smoke_config("qwen2.5-3b")

with shd.use_mesh(mesh):
    params = lm.init(jax.random.PRNGKey(0), cfg)
    p_sh = shd.param_sharding_tree(jax.eval_shape(lambda: params), mesh)
    params = jax.device_put(params, p_sh)
    batch = concrete_batch(cfg, "train", 4, 16, seed=0)
    b_sh = {k: NamedSharding(mesh, P("data")) for k in batch}
    batch = jax.device_put(batch, b_sh)
    loss, _ = jax.jit(lambda p, b: lm.loss_fn(p, b, cfg))(params, batch)
    loss_sharded = float(loss)

# unsharded reference
params_r = jax.device_get(params)
batch_r = jax.device_get(batch)
loss_ref, _ = jax.jit(lambda p, b: lm.loss_fn(p, b, cfg))(params_r, batch_r)
print(json.dumps({"sharded": loss_sharded, "ref": float(loss_ref)}))

# compressed ring all-reduce numerics on 8 devices
from repro.dist.compat import shard_map
from repro.optim.grad_compress import ring_allreduce_int8
x = np.random.default_rng(0).normal(size=(8, 1000)).astype(np.float32)
ring_mesh = jax.make_mesh((8,), ("d",))
def body(v):
    return ring_allreduce_int8(v[0], "d", 8)[None]
out = jax.jit(shard_map(body, mesh=ring_mesh, in_specs=P("d"),
                        out_specs=P("d"), check_vma=False))(x)
got = np.asarray(out)[0]
want = x.sum(0)
err = np.abs(got - want) / np.maximum(np.abs(want), 1e-3)
print(json.dumps({"ring_median_rel": float(np.median(err)),
                  "ring_p99_rel": float(np.percentile(err, 99))}))
"""


@pytest.mark.slow
def test_sharded_execution_8dev_subprocess(run_in_8dev_subprocess):
    """Run a sharded train loss on a forced 8-device host platform and
    compare against the unsharded value; also checks the int8 ring
    all-reduce numerics on a real 8-way mesh."""
    r1, r2 = run_in_8dev_subprocess(SUBPROC_SNIPPET)
    assert r1["sharded"] == pytest.approx(r1["ref"], rel=2e-3)
    assert r2["ring_median_rel"] < 0.02, r2
