"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
sweeping shapes/dtypes/bit-widths, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import pareto
from repro.kernels import ops, ref
from repro.kernels.dcim_mvm import dcim_mvm_pallas
from repro.kernels.fp_prealign import fp_prealign_pallas
from repro.kernels.pareto_rank import dominance_matrix_pallas


class TestParetoRankKernel:
    @pytest.mark.parametrize("P", [1, 7, 128, 131, 300])
    @pytest.mark.parametrize("M", [2, 4])
    def test_matches_ref_shapes(self, P, M):
        rng = np.random.default_rng(P * 10 + M)
        F = jnp.asarray(rng.normal(size=(P, M)).astype(np.float32))
        got = np.asarray(ops.dominance_matrix(F))
        want = np.asarray(ref.dominance_matrix_ref(F))
        np.testing.assert_array_equal(got, want)

    def test_constrained_matches_ref(self):
        rng = np.random.default_rng(0)
        F = jnp.asarray(rng.normal(size=(90, 4)).astype(np.float32))
        v = jnp.asarray(
            (rng.random(90) < 0.4) * rng.random(90).astype(np.float32)
        )
        got = np.asarray(ops.dominance_matrix(F, v))
        want = np.asarray(ref.dominance_matrix_ref(F, v))
        np.testing.assert_array_equal(got, want)

    def test_matches_core_pareto(self):
        rng = np.random.default_rng(3)
        F = jnp.asarray(rng.normal(size=(64, 4)).astype(np.float32))
        got = np.asarray(ops.dominance_matrix(F))
        want = np.asarray(pareto.dominance_matrix(F))
        np.testing.assert_array_equal(got, want)

    def test_duplicate_rows_no_self_domination(self):
        F = jnp.ones((5, 4), jnp.float32)
        D = np.asarray(ops.dominance_matrix(F))
        assert not D.any()

    @settings(max_examples=20, deadline=None)
    @given(P=st.integers(2, 40), seed=st.integers(0, 2**16))
    def test_antisymmetry_property(self, P, seed):
        rng = np.random.default_rng(seed)
        F = jnp.asarray(rng.normal(size=(P, 4)).astype(np.float32))
        D = np.asarray(ops.dominance_matrix(F))
        assert not np.any(D & D.T), "dominance must be antisymmetric"
        assert not np.any(np.diag(D)), "no self-domination"

    @pytest.mark.parametrize("P", [5, 100, 127, 129, 250, 300, 511])
    def test_interpreter_matches_jnp_non_multiple_of_block(self, P):
        """Pallas-interpreter dominance parity with the jnp path on
        population sizes that are NOT multiples of the 128 block grid —
        the padding rows must never leak into the sliced result."""
        rng = np.random.default_rng(P)
        F = jnp.asarray(rng.normal(size=(P, 4)).astype(np.float32))
        v = jnp.asarray(
            (rng.random(P) < 0.4) * rng.random(P).astype(np.float32)
        )
        got = np.asarray(
            dominance_matrix_pallas(F, v, interpret=True)
        ).astype(bool)
        want = np.asarray(pareto.dominance_matrix(F, v))
        np.testing.assert_array_equal(got, want)
        # Unconstrained variant too.
        got0 = np.asarray(
            dominance_matrix_pallas(F, interpret=True)
        ).astype(bool)
        want0 = np.asarray(pareto.dominance_matrix(F))
        np.testing.assert_array_equal(got0, want0)

    def test_default_path_matches_forced_interpreter(self):
        """ops.dominance_matrix on CPU (XLA fallback) == forced Pallas
        interpreter == jnp reference: all three produce one truth."""
        rng = np.random.default_rng(7)
        F = jnp.asarray(rng.normal(size=(130, 4)).astype(np.float32))
        v = jnp.asarray(rng.random(130).astype(np.float32) * 0.5)
        a = np.asarray(ops.dominance_matrix(F, v))             # auto (CPU->XLA)
        b = np.asarray(ops.dominance_matrix(F, v, interpret=True))  # kernel
        c = np.asarray(pareto.dominance_matrix(F, v))
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, c)


class TestDcimMvmKernel:
    @pytest.mark.parametrize("shape", [(1, 1, 1), (3, 5, 7), (50, 300, 70),
                                       (128, 128, 128), (129, 257, 65)])
    def test_exact_int8(self, shape):
        M, K, N = shape
        rng = np.random.default_rng(sum(shape))
        x = jnp.asarray(rng.integers(-128, 128, size=(M, K)).astype(np.int32))
        w = jnp.asarray(rng.integers(-128, 128, size=(K, N)).astype(np.int32))
        got = np.asarray(ops.dcim_mvm(x, w, B_x=8, B_w=8, k=4))
        np.testing.assert_array_equal(got, np.asarray(ref.dcim_mvm_ref(x, w)))

    @pytest.mark.parametrize("B_x,B_w,k", [
        (2, 2, 1), (2, 2, 2), (4, 4, 2), (4, 8, 4), (8, 4, 1),
        (8, 8, 8), (8, 8, 3), (16, 8, 4), (16, 16, 8),
    ])
    def test_bitwidth_sweep(self, B_x, B_w, k):
        """Sweep (B_x, B_w, k) incl. non-dividing k (ceil slices)."""
        rng = np.random.default_rng(B_x * 100 + B_w * 10 + k)
        lo_x, hi_x = -(2 ** (B_x - 1)), 2 ** (B_x - 1)
        lo_w, hi_w = -(2 ** (B_w - 1)), 2 ** (B_w - 1)
        # int32 envelope: K * 2^(B_x-1) * 2^(B_w-1) < 2^31
        K = min(64, 2 ** max(31 - B_x - B_w, 0))
        x = jnp.asarray(rng.integers(lo_x, hi_x, size=(9, K)).astype(np.int32))
        w = jnp.asarray(rng.integers(lo_w, hi_w, size=(K, 11)).astype(np.int32))
        got = np.asarray(ops.dcim_mvm(x, w, B_x=B_x, B_w=B_w, k=k))
        np.testing.assert_array_equal(got, np.asarray(ref.dcim_mvm_ref(x, w)))

    @pytest.mark.parametrize("x_signed,w_signed", [
        (False, False), (True, False), (False, True), (True, True),
    ])
    def test_signedness(self, x_signed, w_signed):
        rng = np.random.default_rng(int(x_signed) * 2 + int(w_signed))
        lo_x = -8 if x_signed else 0
        lo_w = -8 if w_signed else 0
        x = jnp.asarray(rng.integers(lo_x, 8 if x_signed else 16, size=(7, 33)).astype(np.int32))
        w = jnp.asarray(rng.integers(lo_w, 8 if w_signed else 16, size=(33, 5)).astype(np.int32))
        got = np.asarray(
            ops.dcim_mvm(x, w, B_x=4, B_w=4, k=2, x_signed=x_signed, w_signed=w_signed)
        )
        np.testing.assert_array_equal(got, np.asarray(ref.dcim_mvm_ref(x, w)))

    def test_structural_ref_matches_kernel(self):
        rng = np.random.default_rng(9)
        x = jnp.asarray(rng.integers(-128, 128, size=(21, 130)).astype(np.int32))
        w = jnp.asarray(rng.integers(-128, 128, size=(130, 17)).astype(np.int32))
        a = np.asarray(ops.dcim_mvm(x, w, B_x=8, B_w=8, k=2))
        b = np.asarray(ref.dcim_mvm_structural_ref(x, w, B_x=8, B_w=8, k=2))
        np.testing.assert_array_equal(a, b)

    def test_extreme_values(self):
        """Two's-complement corners: min/max of the range."""
        x = jnp.asarray([[-128, 127, -1, 0]], dtype=jnp.int32)
        w = jnp.asarray([[-128], [127], [-128], [127]], dtype=jnp.int32)
        got = np.asarray(ops.dcim_mvm(x, w, B_x=8, B_w=8, k=4))
        np.testing.assert_array_equal(got, np.asarray(ref.dcim_mvm_ref(x, w)))

    @settings(max_examples=25, deadline=None)
    @given(
        M=st.integers(1, 16), K=st.integers(1, 96), N=st.integers(1, 16),
        k=st.sampled_from([1, 2, 4, 8]), seed=st.integers(0, 2**16),
    )
    def test_exactness_property(self, M, K, N, k, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.integers(-128, 128, size=(M, K)).astype(np.int32))
        w = jnp.asarray(rng.integers(-128, 128, size=(K, N)).astype(np.int32))
        got = np.asarray(ops.dcim_mvm(x, w, B_x=8, B_w=8, k=k))
        np.testing.assert_array_equal(got, np.asarray(ref.dcim_mvm_ref(x, w)))

    def test_block_shape_independence(self):
        """Tiling must not change results (padding/accumulation safety)."""
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.integers(-128, 128, size=(40, 200)).astype(np.int32))
        w = jnp.asarray(rng.integers(-128, 128, size=(200, 30)).astype(np.int32))
        a = np.asarray(dcim_mvm_pallas(x, w, block_m=128, block_n=128, block_k=128))
        b = np.asarray(dcim_mvm_pallas(x, w, block_m=16, block_n=8, block_k=32))
        np.testing.assert_array_equal(a, b)


class TestFpPrealignKernel:
    @pytest.mark.parametrize("shape", [(1, 1, 2), (6, 4, 16), (3, 7, 64), (2, 2, 256)])
    @pytest.mark.parametrize("B_M", [4, 8, 11, 24])
    def test_matches_ref(self, shape, B_M):
        rng = np.random.default_rng(shape[0] * B_M)
        x = jnp.asarray(
            (rng.normal(size=shape) * 10.0 ** rng.integers(-3, 4, size=shape)).astype(np.float32)
        )
        m1, e1 = fp_prealign_pallas(x, B_M=B_M)
        m2, e2 = ref.fp_prealign_ref(x, B_M=B_M)
        np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
        np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))

    def test_zeros_and_mixed_signs(self):
        x = jnp.asarray(
            [[[0.0, -1.5, 3.25, -0.0, 1e-30, 7.0, -128.0, 0.5]]], jnp.float32
        )
        m1, e1 = fp_prealign_pallas(x, B_M=8)
        m2, e2 = ref.fp_prealign_ref(x, B_M=8)
        np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
        np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))

    def test_max_element_alignment_invariant(self):
        """The group max element keeps its full B_M-bit mantissa
        (shift 0); every aligned mantissa is bounded by 2^B_M."""
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.normal(size=(4, 5, 32)).astype(np.float32))
        m, e = fp_prealign_pallas(x, B_M=8)
        m = np.asarray(m)
        assert np.all(np.abs(m) < 2**8)
        assert np.all(np.max(np.abs(m), axis=-1) >= 2**7)  # hidden bit of max

    @settings(max_examples=20, deadline=None)
    @given(B_M=st.sampled_from([4, 8, 11]), seed=st.integers(0, 2**16))
    def test_reconstruction_error_bound(self, B_M, seed):
        """|x - mant * 2^(emax-127-(B_M-1))| <= 2^(emax-127-(B_M-1))
        (one ULP of the aligned grid, from truncation)."""
        rng = np.random.default_rng(seed)
        x = np.asarray(rng.normal(size=(3, 2, 16)).astype(np.float32))
        m, e = fp_prealign_pallas(jnp.asarray(x), B_M=B_M)
        m, e = np.asarray(m, np.float64), np.asarray(e)
        scale = 2.0 ** (e[..., None] - 127.0 - (B_M - 1))
        recon = m * scale
        # <= 1 ULP lost to mantissa truncation + <= 1 ULP to the
        # alignment shift (both floor) => error < 2 ULP of the group grid.
        err = np.broadcast_to(scale, x.shape) * 2.0 + 1e-30
        np.testing.assert_array_less(np.abs(x - recon), err)


class TestFpDcimMatmul:
    @pytest.mark.parametrize("B_M,H,tol", [(4, 32, 1.5), (8, 32, 0.08),
                                           (11, 64, 0.01), (24, 64, 1e-4)])
    def test_accuracy_vs_f32(self, B_M, H, tol):
        rng = np.random.default_rng(B_M)
        x = jnp.asarray(rng.normal(size=(16, 128)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(128, 24)).astype(np.float32))
        got = np.asarray(ops.dcim_fp_matmul(x, w, H=H, B_M=B_M, B_w=B_M, k=4))
        want = np.asarray(ref.fp_matmul_f32_ref(x, w))
        rel = np.abs(got - want) / np.maximum(np.abs(want), 1.0)
        assert np.percentile(rel, 90) < tol, f"p90 rel err {np.percentile(rel, 90)}"

    def test_error_monotone_in_mantissa_width(self):
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.normal(size=(12, 64)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(64, 12)).astype(np.float32))
        want = np.asarray(ref.fp_matmul_f32_ref(x, w))
        errs = []
        for bm in (4, 8, 11):
            got = np.asarray(ops.dcim_fp_matmul(x, w, H=32, B_M=bm, B_w=bm, k=4))
            errs.append(np.median(np.abs(got - want) / np.maximum(np.abs(want), 1.0)))
        assert errs[0] > errs[1] > errs[2], errs

    def test_wide_path_guard(self):
        x = jnp.zeros((4, 512), jnp.float32)
        w = jnp.zeros((512, 4), jnp.float32)
        with pytest.raises(ValueError):
            ops.dcim_fp_matmul(x, w, H=512, B_M=24, B_w=24, k=4)


@pytest.mark.skipif(jax.default_backend() == "tpu",
                    reason="on TPU the wrappers run the compiled kernels")
class TestCPUAutoFallback:
    """Off TPU the public wrappers must dispatch to the XLA structural
    refs — never the Pallas interpreter (~60x slower on CPU) — while
    ``interpret=True`` still forces the kernel for parity testing."""

    def test_dcim_mvm_no_pallas_in_trace(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.integers(-128, 128, size=(8, 32)).astype(np.int32))
        w = jnp.asarray(rng.integers(-128, 128, size=(32, 8)).astype(np.int32))
        jaxpr = jax.make_jaxpr(lambda a, b: ops.dcim_mvm(a, b))(x, w)
        assert "pallas_call" not in str(jaxpr)
        interp = jax.make_jaxpr(
            lambda a, b: ops.dcim_mvm(a, b, interpret=True)
        )(x, w)
        assert "pallas_call" in str(interp)
        np.testing.assert_array_equal(
            np.asarray(ops.dcim_mvm(x, w)),
            np.asarray(ops.dcim_mvm(x, w, interpret=True)),
        )

    def test_fp_prealign_no_pallas_in_trace(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(6, 64)).astype(np.float32))
        jaxpr = jax.make_jaxpr(lambda a: ops.fp_prealign(a, H=16))(x)
        assert "pallas_call" not in str(jaxpr)
        m_auto, e_auto = ops.fp_prealign(x, H=16)
        m_int, e_int = ops.fp_prealign(x, H=16, interpret=True)
        np.testing.assert_array_equal(np.asarray(m_auto), np.asarray(m_int))
        np.testing.assert_array_equal(np.asarray(e_auto), np.asarray(e_int))

    def test_dcim_fp_matmul_routes_through_dispatch(self):
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))
        jaxpr = jax.make_jaxpr(
            lambda a, b: ops.dcim_fp_matmul(a, b, H=32, B_M=8, B_w=8, k=4)
        )(x, w)
        assert "pallas_call" not in str(jaxpr)
        np.testing.assert_array_equal(
            np.asarray(ops.dcim_fp_matmul(x, w, H=32, B_M=8, B_w=8, k=4)),
            np.asarray(ops.dcim_fp_matmul(x, w, H=32, B_M=8, B_w=8, k=4,
                                          interpret=True)),
        )


class TestSelectiveScanKernel:
    @pytest.mark.parametrize("shape", [(1, 8, 8, 4), (2, 64, 32, 8),
                                       (3, 128, 64, 16)])
    def test_matches_sequential_oracle(self, shape):
        from repro.kernels.selective_scan import selective_scan_pallas

        B, S, D, N = shape
        rng = np.random.default_rng(sum(shape))
        u = jnp.asarray(rng.normal(size=(B, S, D)).astype(np.float32))
        dt = jnp.asarray(np.abs(rng.normal(size=(B, S, D))).astype(np.float32) * 0.1)
        Bc = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
        Cc = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
        A = jnp.asarray(-np.abs(rng.normal(size=(D, N))).astype(np.float32))
        Ds = jnp.asarray(rng.normal(size=(D,)).astype(np.float32))
        y1, h1 = selective_scan_pallas(u, dt, Bc, Cc, A, Ds,
                                       block_d=min(16, D), block_s=min(16, S))
        y2, h2 = ref.selective_scan_ref(u, dt, Bc, Cc, A, Ds)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-5)

    def test_block_shape_independence(self):
        from repro.kernels.selective_scan import selective_scan_pallas

        rng = np.random.default_rng(1)
        B, S, D, N = 2, 64, 32, 8
        args = (
            jnp.asarray(rng.normal(size=(B, S, D)).astype(np.float32)),
            jnp.asarray(np.abs(rng.normal(size=(B, S, D))).astype(np.float32) * 0.1),
            jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32)),
            jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32)),
            jnp.asarray(-np.abs(rng.normal(size=(D, N))).astype(np.float32)),
            jnp.asarray(rng.normal(size=(D,)).astype(np.float32)),
        )
        y1, h1 = selective_scan_pallas(*args, block_d=32, block_s=64)
        y2, h2 = selective_scan_pallas(*args, block_d=8, block_s=16)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5)

    def test_initial_state_carried(self):
        """Scanning [first half] then [second half with h0] must equal one
        full scan — the chunked-serving contract."""
        from repro.kernels.selective_scan import selective_scan_pallas

        rng = np.random.default_rng(2)
        B, S, D, N = 1, 32, 16, 4
        u = jnp.asarray(rng.normal(size=(B, S, D)).astype(np.float32))
        dt = jnp.asarray(np.abs(rng.normal(size=(B, S, D))).astype(np.float32) * 0.1)
        Bc = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
        Cc = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
        A = jnp.asarray(-np.abs(rng.normal(size=(D, N))).astype(np.float32))
        Ds = jnp.asarray(rng.normal(size=(D,)).astype(np.float32))
        y_full, h_full = selective_scan_pallas(u, dt, Bc, Cc, A, Ds,
                                               block_d=16, block_s=16)
        h = S // 2
        y_a, h_a = selective_scan_pallas(u[:, :h], dt[:, :h], Bc[:, :h],
                                         Cc[:, :h], A, Ds, block_d=16, block_s=16)
        y_b, h_b = selective_scan_pallas(u[:, h:], dt[:, h:], Bc[:, h:],
                                         Cc[:, h:], A, Ds, h0=h_a,
                                         block_d=16, block_s=16)
        np.testing.assert_allclose(
            np.asarray(jnp.concatenate([y_a, y_b], axis=1)),
            np.asarray(y_full), atol=1e-5)
        np.testing.assert_allclose(np.asarray(h_b), np.asarray(h_full), atol=1e-5)
