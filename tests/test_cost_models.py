"""Unit tests for the paper's cost models (Tables II-VI), vs hand-computed
values from the printed formulas, plus structural identities."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import components as c
from repro.core import macros, modules as m, precision
from repro.core.cells import CellLibrary, TSMC28

A = pytest.approx


def f(x):
    return float(np.asarray(x))


class TestModules:
    def test_adder(self):
        assert f(m.add_area(4)) == A(3 * 5.7 + 4.3)
        assert f(m.add_delay(4)) == A(3 * 3.3 + 2.5)
        assert f(m.add_energy(4)) == A(3 * 8.4 + 6.9)

    def test_mux(self):
        assert f(m.sel_area(4)) == A(3 * 2.2)
        assert f(m.sel_delay(4)) == A(2 * 2.2)
        assert f(m.sel_energy(4)) == A(3 * 3.0)

    def test_shifter_as_printed(self):
        # A_shift(N) = N*A_sel(N);  D_shift(N) = log2(N)*D_sel(N)
        assert f(m.shift_area(4)) == A(4 * 3 * 2.2)
        assert f(m.shift_delay(4)) == A(2 * (2 * 2.2))
        assert f(m.shift_energy(4)) == A(4 * 3 * 3.0)

    def test_shifter_mux_tree_variant(self):
        lib = CellLibrary(shifter_delay_model="mux_tree")
        assert f(m.shift_delay(4, lib)) == A(2 * 2.2)

    def test_multiplier(self):
        assert f(m.mul_area(8)) == A(8.0)
        assert f(m.mul_delay(8)) == A(1.0)

    def test_comparator_equals_adder(self):
        for n in (2, 4, 8):
            assert f(m.comp_area(n)) == f(m.add_area(n))
            assert f(m.comp_delay(n)) == f(m.add_delay(n))


class TestComponents:
    def test_adder_tree_h4_k2(self):
        # level0: A_add(2)*H/2, level1: A_add(3)*H/4
        assert f(c.tree_area(4, 2)) == A((5.7 + 4.3) * 2 + (2 * 5.7 + 4.3) * 1)
        assert f(c.tree_delay(4, 2)) == A((3.3 + 2.5) + (2 * 3.3 + 2.5))

    def test_accumulator_bx4_h4(self):
        B = 6
        assert f(c.accu_area(4, 4)) == A(B * 6.6 + B * (B - 1) * 2.2 + (B - 1) * 5.7 + 4.3)

    def test_fusion_bw4_bx4_h4(self):
        w = 4 + 2  # B_x + log2 H
        assert f(c.fusion_area(4, 4, 4)) == A(3 * (w - 1) * 5.7 + (4 + w - 1) * 4.3)
        assert f(c.fusion_delay(4, 4, 4)) == A((w - 1) * 2.5 + 3 * 3.3)

    def test_align_h4(self):
        assert f(c.align_area(4, 4, 4)) == A(3 * f(m.comp_area(4)) + 4 * f(m.shift_area(4)))
        assert f(c.align_delay(4, 4, 4)) == A(
            max(2 * f(m.comp_delay(4)), f(m.shift_delay(4)))
        )

    def test_convert_br10(self):
        # B_r = 4+4+2 = 10, levels ceil(log2 10)=4, real halving
        per = 0.0
        br = 10.0
        for l in range(1, 5):
            frac = br / 2**l
            per += max(frac - 1, 0) * 1.3 + frac * 2.2
        per += f(m.add_area(4))
        assert f(c.convert_area(16, 4, 4, br)) == A(16 / 4 * per, rel=1e-5)

    def test_tree_vectorized_matches_scalar(self):
        H = jnp.array([4.0, 16.0, 256.0])
        k = jnp.array([2.0, 1.0, 8.0])
        vec = np.asarray(c.tree_area(H, k))
        for i in range(3):
            assert vec[i] == A(f(c.tree_area(H[i], k[i])))


class TestMacros:
    def test_int_macro_assembly(self):
        N, H, L, k, Bw, Bx = 64.0, 128.0, 16.0, 4.0, 8.0, 8.0
        mc = macros.int_macro(N, H, L, k, Bw, Bx)
        # Table V identities
        assert f(mc.area) == A(
            N * H * L * 2.2
            + N * H * k * 1.0
            + N * f(c.tree_area(H, k))
            + N * f(c.accu_area(Bx, H))
            + N / Bw * f(c.fusion_area(Bw, Bx, H)),
            rel=1e-5,
        )
        d_path = 1.0 + f(c.tree_delay(H, k)) + f(c.accu_delay(Bx, H))
        assert f(mc.delay) == A(max(d_path, f(c.fusion_delay(Bw, Bx, H))))
        assert f(mc.throughput) == A(N / Bw * H * 2 * (k / Bx) / f(mc.delay), rel=1e-5)
        assert f(mc.sram_bits) == A(N * H * L)

    def test_fp_macro_assembly(self):
        p = precision.BF16
        N, H, L, k = 64.0, 128.0, 16.0, 4.0
        mc = macros.fp_macro(N, H, L, k, p.B_w, p.B_E, p.B_M)
        core = macros.int_macro(N, H, L, k, p.B_w, p.B_M)
        br = p.B_w + p.B_M + np.log2(H)
        assert f(mc.area) == A(
            f(core.area) + f(c.align_area(H, p.B_E, p.B_M))
            + f(c.convert_area(N, p.B_w, p.B_E, br)),
            rel=1e-5,
        )
        assert f(mc.delay) == A(
            max(
                f(c.align_delay(H, p.B_E, p.B_M)),
                f(core.delay),
                f(c.convert_delay(p.B_E, br)),
            )
        )

    def test_bf16_close_to_int8(self):
        """Paper §IV: 'the overhead of BF16 is almost the same compared to
        INT8' — same B_w=B_x=8 core, small align/convert additions."""
        N, H, L, k = 128.0, 256.0, 8.0, 4.0
        mi = macros.int_macro(N, H, L, k, 8, 8)
        mf = macros.fp_macro(N, H, L, k, 8, 8, 8)
        assert f(mf.area) / f(mi.area) < 1.35
        assert f(mf.energy) / f(mi.energy) < 1.35

    def test_selection_mux_variant_strictly_larger(self):
        mi0 = macros.int_macro(64, 128, 16, 4, 8, 8, include_selection_mux=False)
        mi1 = macros.int_macro(64, 128, 16, 4, 8, 8, include_selection_mux=True)
        assert f(mi1.area) > f(mi0.area)
        assert f(mi1.delay) > f(mi0.delay)

    @settings(max_examples=40, deadline=None)
    @given(
        j=st.integers(3, 8),
        h=st.integers(1, 11),
        l=st.integers(0, 6),
        kk=st.integers(0, 3),
    )
    def test_monotonicity_properties(self, j, h, l, kk):
        """Area/energy grow with N; doubling k never lowers throughput-per-
        delay numerator; all costs positive & finite."""
        N, H, L, k = float(8 * 2**j), float(2**h), float(2**l), float(2**kk)
        mc = macros.int_macro(N, H, L, k, 8, 8)
        mc2 = macros.int_macro(2 * N, H, L, k, 8, 8)
        for field in ("area", "delay", "energy", "throughput"):
            val = f(getattr(mc, field))
            assert np.isfinite(val) and val > 0
        assert f(mc2.area) > f(mc.area)
        assert f(mc2.energy) > f(mc.energy)
        assert f(mc2.throughput) == A(2 * f(mc.throughput), rel=1e-4)

    def test_physical_conversion_roundtrip(self):
        mc = macros.int_macro(64, 128, 16, 4, 8, 8)
        ph = macros.physical(mc)
        # TOPS/W == T / (E/D) independent of D_gate/E_gate consistency check
        p_w = f(ph.energy_nJ) * 1e-9 / (f(ph.delay_ns) * 1e-9)
        assert f(ph.tops_per_w) == A(f(ph.tops) / p_w, rel=1e-4)

    def test_activity_scales_energy_only(self):
        mc = macros.int_macro(64, 128, 16, 4, 8, 8)
        p1 = macros.physical(mc, activity=1.0)
        p2 = macros.physical(mc, activity=0.1)
        assert f(p2.energy_nJ) == A(0.1 * f(p1.energy_nJ), rel=1e-5)
        assert f(p2.tops_per_w) == A(10 * f(p1.tops_per_w), rel=1e-4)
        assert f(p2.area_mm2) == A(f(p1.area_mm2))
