"""Property tests for Pareto utilities + NSGA-II vs the brute-force oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import nsga2, pareto
from repro.core.explorer import brute_force_front, explore, run_islands
from repro.core.precision import get as get_precision
from repro.core.space import DesignSpace

OBJ = hnp.arrays(
    np.float32,
    hnp.array_shapes(min_dims=2, max_dims=2, min_side=2, max_side=24).filter(
        lambda s: s[1] <= 5
    ),
    # allow_subnormal=False: XLA CPU flushes denormals to zero, numpy doesn't.
    elements=st.floats(-100, 100, width=32, allow_subnormal=False),
)


def np_dominates(u, v):
    return bool(np.all(u <= v) and np.any(u < v))


class TestPareto:
    @settings(max_examples=60, deadline=None)
    @given(F=OBJ)
    def test_front_mask_is_exactly_nondominated(self, F):
        mask = np.asarray(pareto.pareto_front_mask(jnp.asarray(F)))
        P = F.shape[0]
        for i in range(P):
            dominated = any(np_dominates(F[j], F[i]) for j in range(P) if j != i)
            assert mask[i] == (not dominated)

    @settings(max_examples=40, deadline=None)
    @given(F=OBJ)
    def test_rank0_equals_front_mask(self, F):
        ranks = np.asarray(pareto.non_dominated_sort(jnp.asarray(F)))
        mask = np.asarray(pareto.pareto_front_mask(jnp.asarray(F)))
        np.testing.assert_array_equal(ranks == 0, mask)

    @settings(max_examples=40, deadline=None)
    @given(F=OBJ)
    def test_ranks_monotone_under_domination(self, F):
        """If i dominates j then rank(i) < rank(j)."""
        ranks = np.asarray(pareto.non_dominated_sort(jnp.asarray(F)))
        P = F.shape[0]
        for i in range(P):
            for j in range(P):
                if i != j and np_dominates(F[i], F[j]):
                    assert ranks[i] < ranks[j]

    def test_constrained_domination_feasible_beats_infeasible(self):
        F = jnp.asarray([[0.0, 0.0], [100.0, 100.0]])
        v = jnp.asarray([1.0, 0.0])  # point 0 better objectives but infeasible
        D = np.asarray(pareto.dominance_matrix(F, v))
        assert D[1, 0] and not D[0, 1]

    def test_crowding_boundaries_inf(self):
        F = jnp.asarray([[0.0, 3.0], [1.0, 2.0], [2.0, 1.0], [3.0, 0.0]])
        ranks = pareto.non_dominated_sort(F)
        d = np.asarray(pareto.crowding_distance(F, ranks))
        assert np.isinf(d[0]) and np.isinf(d[3])
        assert np.isfinite(d[1]) and np.isfinite(d[2])

    def test_nan_objectives_lose(self):
        F = jnp.asarray([[np.nan, 0.0], [1.0, 1.0]])
        mask = np.asarray(pareto.pareto_front_mask(F))
        assert mask[1]

    def test_hypervolume_sanity(self):
        F = jnp.asarray([[0.0, 0.0]])
        ref = jnp.asarray([1.0, 1.0])
        hv = float(pareto.hypervolume_mc(F, ref, jax.random.PRNGKey(0), 20000))
        assert hv == pytest.approx(1.0, abs=0.02)


@pytest.fixture(scope="module")
def int8_space():
    return DesignSpace(prec=get_precision("int8"), w_store=16384)


@pytest.fixture(scope="module")
def oracle(int8_space):
    genes = brute_force_front(int8_space)
    # Evaluate through the shared jitted pipeline (scenario.evaluate_host)
    # — the same numerics front extraction uses; eager per-op evaluation
    # can differ by 1 ULP from any jitted program.
    from repro.core.scenario import evaluate_host

    F, _ = evaluate_host(int8_space.scenario, genes)
    return genes, F


class TestNSGA2:
    def test_constraint_always_satisfied_on_front(self, int8_space):
        res = nsga2.run(int8_space, nsga2.NSGA2Config(pop_size=64, generations=24))
        sp = int8_space
        for g in res.front_genes:
            N, H, L, k = (float(x) for x in sp.decode(jnp.asarray(g)))
            assert N * H * L == sp.w_store * sp.prec.B_w
            assert k <= sp.prec.B_x
            assert N > 4 * sp.prec.B_w
            assert L <= 64 and H <= 2048

    def test_front_points_are_oracle_optimal(self, int8_space, oracle):
        """Every NSGA-II front point must be Pareto-optimal in the *full
        enumerated space* (soundness: no spurious 'optimal' designs).
        Domination uses a 1e-5 relative tolerance: float32 ULP noise must
        not count as 'strictly better'."""
        _, oracle_F = oracle
        res = nsga2.run(int8_space, nsga2.NSGA2Config(pop_size=96, generations=48))

        def dominates_tol(u, v):
            tol = 1e-5 * np.maximum(1.0, np.abs(v))
            return bool(np.all(u <= v + tol) and np.any(u < v - tol))

        for fo in res.front_objectives:
            assert not any(dominates_tol(of, fo) for of in oracle_F)

    def test_front_coverage_vs_oracle(self, int8_space, oracle):
        """With a production budget NSGA-II recovers >=90% of the exact
        front (completeness)."""
        oracle_genes, _ = oracle
        res = nsga2.run(int8_space, nsga2.NSGA2Config(pop_size=256, generations=96))
        got = {tuple(g) for g in res.front_genes}
        want = {tuple(g) for g in oracle_genes}
        cov = len(got & want) / len(want)
        assert cov >= 0.9, f"coverage {cov:.2f} ({len(got & want)}/{len(want)})"

    def test_fp_space_explores(self):
        pts = explore("bf16", 8192, nsga2.NSGA2Config(pop_size=64, generations=24))
        assert len(pts) > 3
        for p in pts:
            assert p.precision == "bf16"
            assert p.genes.shape == (3,)
            assert p.area_mm2 > 0 and p.tops > 0

    def test_islands_run_and_match_quality(self, int8_space, oracle):
        oracle_genes, oracle_F = oracle
        res = run_islands(
            int8_space,
            nsga2.NSGA2Config(pop_size=64, generations=0),
            rounds=3,
            gens_per_round=12,
            n_migrants=4,
        )
        assert res.front_genes.shape[0] > 5
        for fo in res.front_objectives:
            assert not any(np_dominates(of, fo) for of in oracle_F)

    def test_determinism(self, int8_space):
        cfg = nsga2.NSGA2Config(pop_size=64, generations=16, seed=7)
        a = nsga2.run(int8_space, cfg)
        b = nsga2.run(int8_space, cfg)
        np.testing.assert_array_equal(a.genes, b.genes)
