"""Fused paged-attention kernels: bitwise parity against the XLA
gather+attend reference (interpret mode), dispatch semantics of the
``AttnBackend`` enum, and end-to-end greedy token-exactness across all
served families with the Pallas backend forced in interpret mode.

Parity is asserted with ``assert_array_equal`` — the kernels keep the
reference's exact compute structure (single-normalization softmax, one
dot-general per contraction), so any drift at all is a bug, not a
tolerance question."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops

VOCAB = 512


def _paged_kv(rng, n_pages, page, Hk, hd, hdv, dtype=jnp.bfloat16):
    k = jnp.asarray(rng.standard_normal((n_pages, page, Hk, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((n_pages, page, Hk, hdv)), dtype)
    return k, v


def _block_table(rng, B, nb, n_pages):
    """Distinct non-garbage pages per (slot, idx) — page 0 is reserved."""
    ids = rng.permutation(np.arange(1, n_pages))[: B * nb]
    return jnp.asarray(ids.reshape(B, nb), jnp.int32)


# ============================ decode: GQA ====================================
class TestPagedDecodeGQA:
    @pytest.mark.parametrize("B,Hk,G,hd,hdv,page,nb", [
        (1, 1, 1, 8, 8, 4, 1),       # minimal
        (3, 2, 4, 16, 16, 8, 3),     # GQA broadcast, several pages
        (2, 2, 1, 16, 8, 8, 2),      # MQA-ish, hdv != hd
        (4, 1, 6, 32, 32, 16, 2),    # wide groups
    ])
    def test_bitwise_vs_xla(self, B, Hk, G, hd, hdv, page, nb):
        rng = np.random.default_rng(B * 100 + nb)
        n_pages = 1 + B * nb + 3     # spare pages the tables never touch
        kp, vp = _paged_kv(rng, n_pages, page, Hk, hd, hdv)
        bt = _block_table(rng, B, nb, n_pages)
        q = jnp.asarray(rng.standard_normal((B, 1, Hk * G, hd)), jnp.float32)
        pos = jnp.asarray(rng.integers(0, nb * page, B), jnp.int32)
        want = ops.paged_decode_gqa(q, kp, vp, bt, pos, backend="xla")
        got = ops.paged_decode_gqa(q, kp, vp, bt, pos,
                                   backend="pallas_interpret")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_garbage_page_rows(self):
        """Inactive slots point every table entry at the reserved page 0
        with pos clamped to 0 — the kernel must mask exactly like the
        reference (only position 0 attended, out of garbage data)."""
        rng = np.random.default_rng(7)
        B, Hk, G, hd, page, nb = 3, 2, 2, 16, 8, 2
        n_pages = 1 + B * nb
        kp, vp = _paged_kv(rng, n_pages, page, Hk, hd, hd)
        bt = np.array(_block_table(rng, B, nb, n_pages))
        bt[1] = 0                                      # inactive slot
        bt = jnp.asarray(bt)
        q = jnp.asarray(rng.standard_normal((B, 1, Hk * G, hd)), jnp.float32)
        pos = jnp.asarray([5, 0, nb * page - 1], jnp.int32)
        want = ops.paged_decode_gqa(q, kp, vp, bt, pos, backend="xla")
        got = ops.paged_decode_gqa(q, kp, vp, bt, pos,
                                   backend="pallas_interpret")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @settings(max_examples=15, deadline=None)
    @given(
        B=st.integers(1, 4), nb=st.integers(1, 4),
        page=st.sampled_from([4, 8]), G=st.integers(1, 4),
        seed=st.integers(0, 2**16),
    )
    def test_parity_property(self, B, nb, page, G, seed):
        """Ragged pos (including 0 and the last slot position) and
        non-power-of-two page-pool sizes never break parity."""
        rng = np.random.default_rng(seed)
        Hk, hd = 2, 8
        n_pages = 1 + B * nb + int(rng.integers(0, 3))   # often non-pow2
        kp, vp = _paged_kv(rng, n_pages, page, Hk, hd, hd)
        bt = _block_table(rng, B, nb, n_pages)
        q = jnp.asarray(rng.standard_normal((B, 1, Hk * G, hd)), jnp.float32)
        pos = np.asarray(rng.integers(0, nb * page, B), np.int32)
        pos[0] = 0                                      # fresh slot edge
        pos[-1] = nb * page - 1                         # full slot edge
        pos = jnp.asarray(pos)
        want = ops.paged_decode_gqa(q, kp, vp, bt, pos, backend="xla")
        got = ops.paged_decode_gqa(q, kp, vp, bt, pos,
                                   backend="pallas_interpret")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ============================ decode: MLA ====================================
class TestPagedDecodeMLA:
    @pytest.mark.parametrize("B,H,r,dr,page,nb", [
        (1, 1, 8, 4, 4, 1),
        (3, 4, 32, 16, 8, 3),
        (2, 8, 64, 32, 8, 2),
    ])
    def test_bitwise_vs_xla(self, B, H, r, dr, page, nb):
        rng = np.random.default_rng(B * 10 + H)
        n_pages = 1 + B * nb + 2
        cp = jnp.asarray(rng.standard_normal((n_pages, page, r)), jnp.bfloat16)
        rp = jnp.asarray(rng.standard_normal((n_pages, page, dr)), jnp.bfloat16)
        bt = _block_table(rng, B, nb, n_pages)
        qa = jnp.asarray(rng.standard_normal((B, 1, H, r)), jnp.float32)
        qr = jnp.asarray(rng.standard_normal((B, 1, H, dr)), jnp.float32)
        pos = jnp.asarray(rng.integers(0, nb * page, B), jnp.int32)
        scale = 1.0 / np.sqrt(r + dr)
        want = ops.paged_decode_mla(qa, qr, cp, rp, bt, pos, scale,
                                    backend="xla")
        got = ops.paged_decode_mla(qa, qr, cp, rp, bt, pos, scale,
                                   backend="pallas_interpret")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @settings(max_examples=10, deadline=None)
    @given(B=st.integers(1, 3), nb=st.integers(1, 3),
           seed=st.integers(0, 2**16))
    def test_parity_property(self, B, nb, seed):
        rng = np.random.default_rng(seed)
        H, r, dr, page = 2, 16, 8, 4
        n_pages = 1 + B * nb + int(rng.integers(0, 2))
        cp = jnp.asarray(rng.standard_normal((n_pages, page, r)), jnp.bfloat16)
        rp = jnp.asarray(rng.standard_normal((n_pages, page, dr)), jnp.bfloat16)
        bt = _block_table(rng, B, nb, n_pages)
        qa = jnp.asarray(rng.standard_normal((B, 1, H, r)), jnp.float32)
        qr = jnp.asarray(rng.standard_normal((B, 1, H, dr)), jnp.float32)
        pos = np.asarray(rng.integers(0, nb * page, B), np.int32)
        pos[0] = 0
        want = ops.paged_decode_mla(qa, qr, cp, rp, bt, jnp.asarray(pos),
                                    0.125, backend="xla")
        got = ops.paged_decode_mla(qa, qr, cp, rp, bt, jnp.asarray(pos),
                                   0.125, backend="pallas_interpret")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ===================== prefill: [ctx ; causal tail] ==========================
class TestPrefixPrefill:
    @pytest.mark.parametrize("B,T,Hk,G,hd,L", [
        (1, 1, 1, 1, 8, 0),          # single token, no context
        (3, 7, 2, 4, 16, 16),        # T not a multiple of the q tile
        (2, 8, 2, 1, 16, 24),        # tile-aligned T, bigger context
        (2, 5, 1, 3, 8, 8),
    ])
    def test_bitwise_vs_xla(self, B, T, Hk, G, hd, L):
        rng = np.random.default_rng(B + T + L)
        q = jnp.asarray(rng.standard_normal((B, T, Hk * G, hd)), jnp.float32)
        kt = jnp.asarray(rng.standard_normal((B, T, Hk, hd)), jnp.float32)
        vt = jnp.asarray(rng.standard_normal((B, T, Hk, hd)), jnp.float32)
        if L:
            kc = jnp.asarray(rng.standard_normal((B, L, Hk, hd)), jnp.float32)
            vc = jnp.asarray(rng.standard_normal((B, L, Hk, hd)), jnp.float32)
            ctx = np.asarray(rng.integers(0, L + 1, B), np.int32)
            ctx[0] = 0                               # no-hit burst member
            ctx[-1] = L                              # fully valid context
        else:
            kc = vc = None
            ctx = np.zeros(B, np.int32)
        ctx = jnp.asarray(ctx)
        want = ops.prefix_prefill(q, kc, vc, kt, vt, ctx, backend="xla")
        got = ops.prefix_prefill(q, kc, vc, kt, vt, ctx,
                                 backend="pallas_interpret")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_bf16_context_pages(self):
        """Serving gathers bf16 context pages cast to the compute dtype
        before attending; parity must hold on that exact input too."""
        rng = np.random.default_rng(3)
        B, T, Hk, G, hd, L = 2, 4, 2, 2, 16, 16
        q = jnp.asarray(rng.standard_normal((B, T, Hk * G, hd)), jnp.float32)
        mk = lambda s, d: jnp.asarray(rng.standard_normal(s), d)
        kc = mk((B, L, Hk, hd), jnp.bfloat16).astype(jnp.float32)
        vc = mk((B, L, Hk, hd), jnp.bfloat16).astype(jnp.float32)
        kt = mk((B, T, Hk, hd), jnp.float32)
        vt = mk((B, T, Hk, hd), jnp.float32)
        ctx = jnp.asarray([7, L], jnp.int32)
        want = ops.prefix_prefill(q, kc, vc, kt, vt, ctx, backend="xla")
        got = ops.prefix_prefill(q, kc, vc, kt, vt, ctx,
                                 backend="pallas_interpret")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @settings(max_examples=15, deadline=None)
    @given(B=st.integers(1, 3), T=st.integers(1, 12),
           L=st.sampled_from([0, 8, 16]), seed=st.integers(0, 2**16))
    def test_parity_property(self, B, T, L, seed):
        rng = np.random.default_rng(seed)
        Hk, G, hd = 2, 2, 8
        q = jnp.asarray(rng.standard_normal((B, T, Hk * G, hd)), jnp.float32)
        kt = jnp.asarray(rng.standard_normal((B, T, Hk, hd)), jnp.float32)
        vt = jnp.asarray(rng.standard_normal((B, T, Hk, hd)), jnp.float32)
        if L:
            kc = jnp.asarray(rng.standard_normal((B, L, Hk, hd)), jnp.float32)
            vc = jnp.asarray(rng.standard_normal((B, L, Hk, hd)), jnp.float32)
        else:
            kc = vc = None
        ctx = jnp.asarray(rng.integers(0, L + 1, B), jnp.int32)
        want = ops.prefix_prefill(q, kc, vc, kt, vt, ctx, backend="xla")
        got = ops.prefix_prefill(q, kc, vc, kt, vt, ctx,
                                 backend="pallas_interpret")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ============================== dispatch =====================================
class TestBackendDispatch:
    def test_resolve(self):
        on_tpu = jax.default_backend() == "tpu"
        want_auto = ops.AttnBackend.PALLAS if on_tpu else ops.AttnBackend.XLA
        assert ops.resolve_attn_backend() is want_auto
        assert ops.resolve_attn_backend("auto") is want_auto
        assert ops.resolve_attn_backend("xla") is ops.AttnBackend.XLA
        assert ops.resolve_attn_backend("pallas") is ops.AttnBackend.PALLAS
        assert (ops.resolve_attn_backend("pallas_interpret")
                is ops.AttnBackend.PALLAS_INTERPRET)
        with pytest.raises(ValueError):
            ops.resolve_attn_backend("cudnn")

    @pytest.mark.skipif(jax.default_backend() == "tpu",
                        reason="auto resolves to the Pallas kernel on TPU")
    def test_auto_avoids_pallas_off_tpu(self):
        """The default backend must never pay interpreter overhead on
        CPU: the traced decode program contains no pallas_call."""
        rng = np.random.default_rng(0)
        B, Hk, G, hd, page, nb = 2, 1, 2, 8, 4, 2
        n_pages = 1 + B * nb
        kp, vp = _paged_kv(rng, n_pages, page, Hk, hd, hd)
        bt = _block_table(rng, B, nb, n_pages)
        q = jnp.asarray(rng.standard_normal((B, 1, Hk * G, hd)), jnp.float32)
        pos = jnp.asarray([1, 3], jnp.int32)
        jaxpr = jax.make_jaxpr(
            lambda *a: ops.paged_decode_gqa(*a)
        )(q, kp, vp, bt, pos)
        assert "pallas_call" not in str(jaxpr)
        np.testing.assert_array_equal(
            np.asarray(ops.paged_decode_gqa(q, kp, vp, bt, pos)),
            np.asarray(ops.paged_decode_gqa(q, kp, vp, bt, pos,
                                            backend="xla")),
        )

    def test_config_validates_backend(self):
        from repro import configs
        cfg = configs.get_smoke_config("qwen2.5-3b")
        for b in ("auto", "xla", "pallas", "pallas_interpret"):
            dataclasses.replace(cfg, attn_backend=b).validate()
        with pytest.raises(AssertionError):
            dataclasses.replace(cfg, attn_backend="cuda").validate()


# ====================== end-to-end serving exactness =========================
# Keep this list in sync with tests/test_archs_smoke.py::CONSISTENCY_ARCHS.
SERVED_ARCHS = [
    "qwen2.5-3b", "phi4-mini-3.8b", "mistral-nemo-12b", "musicgen-large",
    "falcon-mamba-7b", "jamba-v0.1-52b", "deepseek-v3-671b",
    "moonshot-v1-16b-a3b",
]


class TestServingExactnessPallas:
    @pytest.mark.parametrize("arch", SERVED_ARCHS)
    def test_greedy_exact_with_pallas_interpret(self, arch):
        """Every served family produces bit-identical greedy tokens with
        the fused kernels forced (interpret mode on CPU) vs per-request
        ``Engine.generate`` on the monolithic XLA path — the end-to-end
        form of the per-kernel parity assertions above."""
        from repro import configs
        from repro.models import lm
        from repro.serve import Engine, Request, Scheduler

        cfg = configs.get_smoke_config(arch)
        params = lm.init(jax.random.PRNGKey(0), cfg)
        eng = Engine(cfg, params, max_len=32)
        sched = Scheduler(cfg, params, max_slots=2, max_len=32, page_size=8,
                          attn_backend="pallas_interpret")
        assert sched.cfg.attn_backend == "pallas_interpret"
        rng = np.random.default_rng(2)
        reqs = [
            Request(prompt=rng.integers(0, VOCAB, n).astype(np.int32),
                    n_tokens=t)
            for n, t in [(3, 3), (6, 2), (9, 3)]
        ]
        for req, res in zip(reqs, sched.serve(reqs)):
            ref = eng.generate(
                req.prompt[None], n_tokens=req.n_tokens, request_ids=[res.rid]
            )
            np.testing.assert_array_equal(ref.tokens[0], res.tokens)
