"""End-to-end ``launch.dryrun`` sweep on the 256/512-chip abstract meshes
(closes the ROADMAP "exercise dryrun end-to-end" item).

The dry-run pins ``XLA_FLAGS=--xla_force_host_platform_device_count=512``
before importing jax, so it must run in a subprocess.  By default this
test sweeps one representative architecture across every input shape on
BOTH production meshes (single-pod 16x16 = 256 chips and multi-pod
2x16x16 = 512 chips) and checks the roofline records persisted through
``repro.core.results.ResultStore``.  Set ``DRYRUN_SWEEP=all`` to run the
full all-cells sweep (every architecture; ~30-60 min on a laptop-class
CPU — the configuration CI's slow lane records in CHANGES.md).
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_dryrun_sweep_persists_roofline_records(tmp_path):
    full = os.environ.get("DRYRUN_SWEEP", "") == "all"
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun",
        "--out", str(tmp_path), "--force",
    ]
    if not full:
        cmd += ["--arch", "qwen2.5-3b"]
    out = subprocess.run(
        cmd, capture_output=True, text=True, env=env, cwd=REPO,
        timeout=(6 * 3600 if full else 1800),
    )
    assert out.returncode == 0, out.stdout[-1500:] + out.stderr[-1500:]
    assert "failed=0" in out.stdout

    from repro.core.results import ResultStore
    from repro.launch.roofline import analyze_record

    store = ResultStore(tmp_path)
    names = store.names()
    assert names, "sweep persisted no records"
    oks = 0
    for rec in store.records():
        # Every record carries the store envelope and a cell status.
        assert rec["_record"]["kind"] == "dryrun"
        assert rec["status"] == "ok" or rec["status"].startswith("skipped"), (
            rec.get("arch"), rec.get("error"),
        )
        if rec["status"] != "ok":
            continue
        oks += 1
        assert rec["_record"]["wall_s"] > 0
        assert rec["n_devices"] in (256, 512)
        # Sharding annotations must be rich enough that the SPMD
        # partitioner never falls back to an involuntary full
        # rematerialization (the copies the old scanned-transpose
        # cross-entropy path forced on the 2x16x16 mesh).
        assert rec.get("remat_warnings", 0) == 0, (
            rec["arch"], rec["shape"], rec["mesh"], rec["remat_warnings"],
        )
        # The record must round-trip into the roofline layer.
        row = analyze_record(rec)
        assert row.status == "ok"
        assert row.hlo_flops > 0 and row.model_flops > 0
    # qwen2.5-3b: train_4k/prefill_32k/decode_32k on both meshes.
    assert oks >= (40 if full else 6)
